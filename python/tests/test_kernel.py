"""L1 correctness: Pallas kernel-matrix MVM vs the pure-jnp oracle.

This is the CORE correctness signal for the compute hot-spot: every kernel
kind, shapes both tile-aligned and ragged (exercising the padding path),
plus a hypothesis sweep over shapes and hyperparameters.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kernel_mvm as km
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def _rand_problem(n, d, b, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, b)), jnp.float32)
    return x, v


def _check(kind, x, v, hypers, tol=None):
    if tol is None:
        # mat12 has a kink at r=0: f32 cancellation in pairwise distances is
        # amplified first-order in r, so its tolerance is wider.
        tol = 2e-3 if kind == "mat12" else 5e-4
    out = np.asarray(km.kernel_mvm(kind, x, v, hypers))
    want = np.asarray(ref.kernel_mvm_ref(kind, x, v, hypers))
    scale = 1.0 + np.max(np.abs(want))
    assert np.max(np.abs(out - want)) / scale < tol, (
        f"{kind}: rel err {np.max(np.abs(out - want)) / scale}"
    )


@pytest.mark.parametrize("kind", ref.KINDS)
@pytest.mark.parametrize("n,d,b", [(64, 1, 1), (256, 2, 4), (300, 3, 8),
                                   (512, 2, 8), (129, 5, 3)])
def test_mvm_matches_ref(kind, n, d, b):
    x, v = _rand_problem(n, d, b, seed=n * 7 + d)
    hypers = jnp.asarray([0.7, 1.3, 0.25], jnp.float32)
    _check(kind, x, v, hypers)


@pytest.mark.parametrize("kind", ref.KINDS)
def test_mvm_tile_aligned_exact_shape(kind):
    # n a multiple of both tile sizes: no padding branch.
    x, v = _rand_problem(512, 2, 8, seed=9)
    hypers = jnp.asarray([0.4, 0.9, 0.1], jnp.float32)
    _check(kind, x, v, hypers)


def test_mvm_identity_like_at_tiny_lengthscale():
    # ell -> 0: K ~ sf^2 I, so (K + sigma^2 I) v ~ (sf^2 + sigma^2) v.
    x, v = _rand_problem(128, 2, 2, seed=3)
    hypers = jnp.asarray([1e-4, 1.5, 0.5], jnp.float32)
    out = np.asarray(km.kernel_mvm("rbf", x, v, hypers))
    want = (1.5**2 + 0.5**2) * np.asarray(v)
    assert np.max(np.abs(out - want)) < 1e-3


def test_mvm_symmetry():
    # u^T (K v) == v^T (K u): the operator the kernel implements is symmetric.
    x, u = _rand_problem(200, 2, 1, seed=5)
    _, v = _rand_problem(200, 2, 1, seed=6)
    hypers = jnp.asarray([0.6, 1.0, 0.2], jnp.float32)
    ku = np.asarray(km.kernel_mvm("rbf", x, u, hypers))
    kv = np.asarray(km.kernel_mvm("rbf", x, v, hypers))
    lhs = (np.asarray(u).T @ kv).item()
    rhs = (np.asarray(v).T @ ku).item()
    assert abs(lhs - rhs) / (1 + abs(lhs)) < 1e-4


def test_mvm_positive_definite_quadform():
    # z^T (K + sigma^2 I) z > 0 for any z != 0.
    x, z = _rand_problem(150, 3, 1, seed=11)
    hypers = jnp.asarray([0.5, 1.0, 0.3], jnp.float32)
    for kind in ref.KINDS:
        kz = np.asarray(km.kernel_mvm(kind, x, z, hypers))
        q = (np.asarray(z).T @ kz).item()
        assert q > 0.0


def test_cross_mvm_matches_ref():
    rng = np.random.default_rng(21)
    xs = jnp.asarray(rng.normal(size=(100, 2)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(260, 2)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(260, 1)), jnp.float32)
    hypers = jnp.asarray([0.8, 1.1, 0.2], jnp.float32)
    out = np.asarray(km.kernel_cross_mvm("rbf", xs, x, a, hypers))
    want = np.asarray(ref.kernel_matrix("rbf", xs, x, hypers) @ a)
    assert np.max(np.abs(out - want)) / (1 + np.max(np.abs(want))) < 5e-4


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=8, max_value=320),
    d=st.integers(min_value=1, max_value=6),
    b=st.integers(min_value=1, max_value=8),
    ell=st.floats(min_value=0.05, max_value=3.0),
    sf=st.floats(min_value=0.1, max_value=3.0),
    sigma=st.floats(min_value=0.01, max_value=1.0),
    kind=st.sampled_from(ref.KINDS),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mvm_hypothesis_sweep(n, d, b, ell, sf, sigma, kind, seed):
    x, v = _rand_problem(n, d, b, seed=seed)
    hypers = jnp.asarray([ell, sf, sigma], jnp.float32)
    # mat12's kink at r=0 turns the f32 O(eps) squared-distance cancellation
    # into a first-order O(sqrt(eps)/ell) kernel error for near-coincident
    # points, so its bound scales with 1/ell; the smooth kernels stay
    # second-order. This is intrinsic to f32, not a kernel bug — the
    # estimators' stochastic error dominates it by orders of magnitude.
    tol = max(2e-3, 1.5e-3 / ell) if kind == "mat12" else 2e-3
    _check(kind, x, v, hypers, tol=tol)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=16, max_value=200),
    b=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mvm_linearity(n, b, seed):
    # K(u + 2v) == K u + 2 K v
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(n, b)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, b)), jnp.float32)
    h = jnp.asarray([0.5, 1.0, 0.2], jnp.float32)
    lhs = np.asarray(km.kernel_mvm("rbf", x, u + 2.0 * v, h))
    rhs = np.asarray(km.kernel_mvm("rbf", x, u, h)) + \
        2.0 * np.asarray(km.kernel_mvm("rbf", x, v, h))
    assert np.max(np.abs(lhs - rhs)) / (1 + np.max(np.abs(rhs))) < 1e-3
