"""AOT path: every artifact config lowers to parseable HLO text with the
shapes the manifest advertises, and the MVM artifact's HLO evaluates to the
same numbers as the eager path (via jax's own HLO round-trip)."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


@pytest.mark.parametrize("cfg", aot.artifact_configs(),
                         ids=lambda c: c["name"])
def test_lower_config_produces_hlo(cfg):
    text, ins, outs = aot.lower_config(cfg)
    assert "ENTRY" in text
    assert "HloModule" in text
    # One leading f32 input per declared arg.
    assert len(ins) >= 2
    for dtype, shape in ins + outs:
        assert dtype == "f32"
        assert all(s > 0 for s in shape)


def test_manifest_written(tmp_path):
    import subprocess
    import sys
    out = tmp_path / "arts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--only", "mvm_rbf_n512_d2_b8"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert "mvm_rbf_n512_d2_b8" in manifest
    entry = manifest["mvm_rbf_n512_d2_b8"]
    assert entry["inputs"] == [["f32", [512, 2]], ["f32", [512, 8]],
                               ["f32", [3]]]
    assert (out / entry["file"]).exists()


def test_mvm_artifact_numerics_roundtrip():
    # Compile the lowered stablehlo back through jax and compare outputs —
    # proves the artifact computes what the eager graph computes.
    cfg = {"name": "t", "graph": "mvm", "kind": "rbf", "n": 512, "d": 2,
           "b": 8}
    kind = cfg["kind"]
    fn = lambda x, v, h: (model.mvm(kind, x, v, h),)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(512, 2)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(512, 8)), jnp.float32)
    h = jnp.asarray([0.5, 1.0, 0.2], jnp.float32)
    lowered = jax.jit(fn).lower(x, v, h)
    compiled = lowered.compile()
    got = np.asarray(compiled(x, v, h)[0])
    want = np.asarray(fn(x, v, h)[0])
    assert np.max(np.abs(got - want)) < 1e-4
