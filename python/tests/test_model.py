"""L2 correctness: Lanczos graph vs dense oracles.

Checks the three guarantees the rust runtime relies on:
  1. (alphas, betas) define a tridiagonal T whose Gauss quadrature
     reproduces log|K + sigma^2 I| (the paper's §3.2 estimator);
  2. g = Q T^-1 e1 ||z|| approximates K^-1 z (the free derivative solve);
  3. the Thomas tridiagonal solve inside the graph matches dense solve.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

HYPERS = jnp.asarray([0.5, 1.2, 0.3], jnp.float32)


def _data(n, d, p, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    z = jnp.asarray(rng.choice([-1.0, 1.0], size=(n, p)), jnp.float32)
    return x, z


@pytest.mark.parametrize("kind", ["rbf", "mat32"])
def test_slq_logdet_close_to_exact(kind):
    x, z = _data(300, 2, 8, seed=42)
    est = model.slq_logdet_ref(kind, x, 30, z, HYPERS)
    exact = model.dense_logdet_ref(kind, x, HYPERS)
    assert abs(est - exact) / abs(exact) < 0.05, (est, exact)


def test_slq_logdet_improves_with_steps():
    x, z = _data(256, 2, 8, seed=7)
    exact = model.dense_logdet_ref("rbf", x, HYPERS)
    err5 = abs(model.slq_logdet_ref("rbf", x, 5, z, HYPERS) - exact)
    err30 = abs(model.slq_logdet_ref("rbf", x, 30, z, HYPERS) - exact)
    assert err30 <= err5 + 1e-6


def test_lanczos_g_solves_system():
    # g should approximate (K + sigma^2 I)^-1 z.
    x, z = _data(200, 2, 4, seed=3)
    _, _, g, _, _ = model.lanczos("rbf", x, 40, z, HYPERS)
    k = np.asarray(ref.kernel_matrix("rbf", x, x, HYPERS), np.float64)
    k += float(HYPERS[2]) ** 2 * np.eye(200)
    want = np.linalg.solve(k, np.asarray(z, np.float64))
    got = np.asarray(g, np.float64)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 5e-2, rel


def test_lanczos_tridiag_orthonormal_alpha_range():
    # alphas are Rayleigh quotients of an SPD operator: all positive, and
    # bounded by the operator norm estimate.
    x, z = _data(180, 2, 4, seed=9)
    alphas, betas, _, _, _ = model.lanczos("rbf", x, 20, z, HYPERS)
    a = np.asarray(alphas)
    b = np.asarray(betas)
    assert np.all(a > 0)
    assert np.all(b >= -1e-6)


def test_tridiag_solve_matches_dense():
    rng = np.random.default_rng(11)
    m, p = 12, 3
    # Build diagonally-dominant SPD tridiagonals.
    alphas = jnp.asarray(rng.uniform(2.0, 4.0, size=(m, p)), jnp.float32)
    betas = jnp.asarray(rng.uniform(0.1, 0.8, size=(m - 1, p)), jnp.float32)
    znorm = jnp.asarray(rng.uniform(0.5, 2.0, size=(p,)), jnp.float32)
    got = np.asarray(model._tridiag_solve_e1(alphas, betas, znorm))
    for i in range(p):
        t = np.diag(np.asarray(alphas)[:, i]) + \
            np.diag(np.asarray(betas)[:, i], 1) + \
            np.diag(np.asarray(betas)[:, i], -1)
        e1 = np.zeros(m)
        e1[0] = float(znorm[i])
        want = np.linalg.solve(t, e1)
        assert np.max(np.abs(got[:, i] - want)) < 1e-4


def test_lanczos_exact_when_m_equals_n():
    # With m = n (and full reorth) the quadrature is exact.
    x, z = _data(48, 1, 6, seed=5)
    est = model.slq_logdet_ref("rbf", x, 48, z, HYPERS)
    exact = model.dense_logdet_ref("rbf", x, HYPERS)
    assert abs(est - exact) / abs(exact) < 5e-2  # f32 Lanczos, 1-D inputs
