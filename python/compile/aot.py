"""AOT-lower the L2 graphs to HLO *text* artifacts for the rust runtime.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per configuration plus ``manifest.json``
describing shapes so the rust runtime can marshal buffers without guessing.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def artifact_configs():
    """The artifact set baked for the rust runtime.

    Sizes are chosen to cover the experiments that use the PJRT dense path:
    the deep-kernel-learning experiment (n=2048 train rows, d=2 features out
    of the MLP), 3-D dense blocks for precipitation-style data, and the
    Lanczos graph used by the accelerated SLQ path and the perf bench.
    """
    cfgs = []
    for kind, n, d, b in [
        ("rbf", 2048, 2, 8),
        ("rbf", 512, 2, 8),
        ("rbf", 1024, 3, 8),
        ("mat32", 1024, 2, 8),
        ("mat52", 1024, 3, 8),
    ]:
        cfgs.append({
            "name": f"mvm_{kind}_n{n}_d{d}_b{b}",
            "graph": "mvm", "kind": kind, "n": n, "d": d, "b": b,
        })
    cfgs.append({
        "name": "cross_rbf_q512_n2048_d2_b1",
        "graph": "cross_mvm", "kind": "rbf", "q": 512, "n": 2048, "d": 2,
        "b": 1,
    })
    for kind, n, d, p, m in [("rbf", 2048, 2, 8, 30)]:
        cfgs.append({
            "name": f"lanczos_{kind}_n{n}_d{d}_p{p}_m{m}",
            "graph": "lanczos", "kind": kind, "n": n, "d": d, "p": p, "m": m,
        })
    return cfgs


def lower_config(cfg):
    kind = cfg["kind"]
    if cfg["graph"] == "mvm":
        fn = lambda x, v, h: (model.mvm(kind, x, v, h),)
        args = (spec(cfg["n"], cfg["d"]), spec(cfg["n"], cfg["b"]), spec(3))
        outs = [["f32", [cfg["n"], cfg["b"]]]]
    elif cfg["graph"] == "cross_mvm":
        fn = lambda xs, x, a, h: (model.cross_mvm(kind, xs, x, a, h),)
        args = (spec(cfg["q"], cfg["d"]), spec(cfg["n"], cfg["d"]),
                spec(cfg["n"], cfg["b"]), spec(3))
        outs = [["f32", [cfg["q"], cfg["b"]]]]
    elif cfg["graph"] == "lanczos":
        m = cfg["m"]
        fn = lambda x, z, h: model.lanczos(kind, x, m, z, h)
        args = (spec(cfg["n"], cfg["d"]), spec(cfg["n"], cfg["p"]), spec(3))
        outs = [["f32", [m, cfg["p"]]], ["f32", [m - 1, cfg["p"]]],
                ["f32", [cfg["n"], cfg["p"]]], ["f32", [cfg["p"]]],
                ["f32", [m, cfg["n"], cfg["p"]]]]
    else:
        raise ValueError(cfg["graph"])
    lowered = jax.jit(fn).lower(*args)
    ins = [["f32", list(a.shape)] for a in args]
    return to_hlo_text(lowered), ins, outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names to (re)build")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    only = set(args.only.split(",")) if args.only else None
    for cfg in artifact_configs():
        name = cfg["name"]
        if only is not None and name not in only:
            continue
        text, ins, outs = lower_config(cfg)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = dict(cfg)
        entry["file"] = f"{name}.hlo.txt"
        entry["inputs"] = ins
        entry["outputs"] = outs
        manifest[name] = entry
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    if only is not None and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old.update(manifest)
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    # TSV twin for the rust runtime (no JSON dependency offline):
    # name \t file \t graph \t kind \t in-shapes \t out-shapes
    tpath = os.path.join(args.out, "manifest.tsv")
    with open(tpath, "w") as f:
        for name in sorted(manifest):
            e = manifest[name]
            ins = ";".join("x".join(map(str, s)) for _, s in e["inputs"])
            outs = ";".join("x".join(map(str, s)) for _, s in e["outputs"])
            f.write(f"{name}\t{e['file']}\t{e['graph']}\t{e['kind']}\t"
                    f"{ins}\t{outs}\n")
    print(f"wrote {mpath} + {tpath} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
