"""Layer-2 JAX compute graphs, all built on the L1 Pallas MVM kernel.

Three graph families are AOT-lowered by :mod:`aot`:

  * ``mvm``          — ``(K + sigma^2 I) V`` batch MVM (the estimator
                       building block; rust drives Chebyshev/Lanczos/CG
                       iterations against it).
  * ``cross_mvm``    — ``K(X*, X) alpha`` for predictive means.
  * ``lanczos``      — a complete m-step batched Lanczos factorization with
                       full reorthogonalization: probes in, tridiagonal
                       coefficients (alpha, beta), the solve vector
                       ``g = Q T^-1 e1 ||z||`` (the paper's free derivative
                       estimator, §3.2), and probe norms out. The rust side
                       finishes with an m x m tridiagonal eigensolve
                       (Gauss quadrature) — O(m^2) scalar work.

Everything is shape-static; aot.py bakes one artifact per configuration.
Python never runs at serving time.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import kernel_mvm as km
from .kernels import ref


def mvm(kind, x, v, hypers):
    """(K + sigma^2 I) V — thin wrapper so model-level code owns the API."""
    return km.kernel_mvm(kind, x, v, hypers)


def cross_mvm(kind, xstar, x, alpha, hypers):
    """K(X*, X) @ alpha — predictive mean block."""
    return km.kernel_cross_mvm(kind, xstar, x, alpha, hypers)


def _tridiag_solve_e1(alphas, betas, znorm):
    """Solve T g_T = e1 * ||z|| for the (m x m) tridiagonal T per probe.

    alphas: (m, p), betas: (m-1, p), znorm: (p,). Returns (m, p).
    Thomas algorithm, vectorized over probes; T from Lanczos on an SPD
    operator is positive definite, so no pivoting is needed.
    """
    m = alphas.shape[0]
    p = alphas.shape[1]

    def fwd(carry, idx):
        cprime, dprime = carry  # previous modified coefs, shape (p,)
        a = alphas[idx]
        b_lo = jnp.where(idx > 0, betas[jnp.maximum(idx - 1, 0)], 0.0)
        b_up = jnp.where(idx < m - 1, betas[jnp.minimum(idx, m - 2)], 0.0)
        denom = a - b_lo * cprime
        c_new = b_up / denom
        rhs = jnp.where(idx == 0, znorm, jnp.zeros_like(znorm))
        d_new = (rhs - b_lo * dprime) / denom
        return (c_new, d_new), (c_new, d_new)

    init = (jnp.zeros((p,), alphas.dtype), jnp.zeros((p,), alphas.dtype))
    _, (cs, ds) = jax.lax.scan(fwd, init, jnp.arange(m))

    def bwd(x_next, idx):
        x_i = ds[idx] - cs[idx] * x_next
        return x_i, x_i

    _, xs_rev = jax.lax.scan(bwd, jnp.zeros((p,), alphas.dtype),
                             jnp.arange(m - 1, -1, -1))
    return xs_rev[::-1]  # (m, p)


@functools.partial(jax.jit, static_argnums=(0, 2))
def lanczos(kind, x, m, z, hypers):
    """Batched m-step Lanczos on A = K(x,x) + sigma^2 I with starts z.

    Args:
      kind: kernel kind (static).
      x: (n, d) inputs.
      m: number of Lanczos steps (static).
      z: (n, p) probe block (columns are independent probes).
      hypers: (3,) [ell, sf, sigma].

    Returns:
      alphas (m, p), betas (m-1, p), g (n, p) with g ~= A^-1 z, znorm (p,),
      qbuf (m, n, p) — the Krylov basis, returned so the AOT consumer can
      redo the T^-1 e1 solve in f64 (the in-graph Thomas scan is kept for
      eager use/tests, but the rust runtime recombines Q itself).

    Full reorthogonalization: each new Krylov vector is re-projected against
    all stored Q columns (the paper notes Lanczos is numerically unstable
    and cites practical fixes [33, 34]; full reorth is the simplest sound
    one at m <= ~100).
    """
    n, p = z.shape
    znorm = jnp.sqrt(jnp.sum(z * z, axis=0))  # (p,)
    q0 = z / znorm[None, :]

    qbuf0 = jnp.zeros((m, n, p), z.dtype)
    qbuf0 = qbuf0.at[0].set(q0)

    def step(carry, j):
        qbuf, q, q_prev, beta_prev = carry
        w = mvm(kind, x, q, hypers)                       # (n, p) — the MVM
        alpha = jnp.sum(q * w, axis=0)                    # (p,)
        w = w - alpha[None, :] * q - beta_prev[None, :] * q_prev
        # Full reorthogonalization against stored columns (mask k <= j).
        mask = (jnp.arange(m) <= j).astype(w.dtype)       # (m,)
        proj = jnp.einsum("knp,np->kp", qbuf, w) * mask[:, None]
        w = w - jnp.einsum("knp,kp->np", qbuf, proj)
        beta = jnp.sqrt(jnp.sum(w * w, axis=0))
        # Guard breakdown (beta ~ 0): keep the vector at zero.
        safe = jnp.where(beta > 1e-12, beta, 1.0)
        q_next = jnp.where(beta[None, :] > 1e-12, w / safe[None, :], 0.0)
        write_at = jnp.minimum(j + 1, m - 1)
        upd = jnp.where(j + 1 < m, 1.0, 0.0).astype(w.dtype)
        cur = jax.lax.dynamic_index_in_dim(qbuf, write_at, 0, keepdims=False)
        qbuf = jax.lax.dynamic_update_index_in_dim(
            qbuf, cur * (1.0 - upd) + q_next * upd, write_at, 0)
        return (qbuf, q_next, q, beta), (alpha, beta)

    (qbuf, _, _, _), (alphas, betas_all) = jax.lax.scan(
        step, (qbuf0, q0, jnp.zeros_like(q0), jnp.zeros((p,), z.dtype)),
        jnp.arange(m))
    betas = betas_all[:-1]                                # (m-1, p)

    # g = Q (T^-1 e1 ||z||): the derivative/solve estimator, re-using the
    # decomposition at zero extra MVMs (paper §3.2).
    gt = _tridiag_solve_e1(alphas, betas, znorm)          # (m, p)
    g = jnp.einsum("knp,kp->np", qbuf, gt)
    return alphas, betas, g, znorm, qbuf


def slq_logdet_ref(kind, x, m, z, hypers):
    """SLQ estimate of log|K + sigma^2 I| finished in numpy (test oracle).

    Mirrors exactly what the rust side does with the (alphas, betas)
    artifact outputs: per-probe tridiagonal eigensolve, Gauss-quadrature
    weights from squared first-row eigenvector entries.
    """
    import numpy as np

    alphas, betas, _, znorm, _ = lanczos(kind, x, m, z, hypers)
    alphas = np.asarray(alphas, dtype=np.float64)
    betas = np.asarray(betas, dtype=np.float64)
    znorm = np.asarray(znorm, dtype=np.float64)
    p = z.shape[1]
    est = 0.0
    for i in range(p):
        t = np.diag(alphas[:, i])
        if m > 1:
            t += np.diag(betas[:, i], 1) + np.diag(betas[:, i], -1)
        lam, vecs = np.linalg.eigh(t)
        lam = np.maximum(lam, 1e-300)
        tau = vecs[0, :] ** 2
        est += znorm[i] ** 2 * float(np.sum(tau * np.log(lam)))
    # E[z^T log(A) z] = tr(log A) for unit-variance probes; the mean over
    # probes is the trace estimate.
    return est / p


def dense_logdet_ref(kind, x, hypers):
    """Exact log|K + sigma^2 I| via dense slogdet (test oracle)."""
    import numpy as np

    k = np.asarray(ref.kernel_matrix(kind, x, x, hypers), dtype=np.float64)
    sigma = float(hypers[2])
    k += sigma * sigma * np.eye(k.shape[0])
    sign, val = np.linalg.slogdet(k)
    assert sign > 0
    return val
