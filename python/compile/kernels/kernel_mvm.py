"""Layer-1 Pallas kernel: materialization-free kernel-matrix MVM.

Computes ``(K(X, X) + sigma^2 I) @ V`` for ``X: (n, d)``, ``V: (n, b)``
without ever forming the ``n x n`` kernel matrix in HBM.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is
``(n // BR, n // BC)``; each program holds in VMEM

  * an ``(BR, d)`` tile of X rows            (re-used across the j loop)
  * a  ``(BC, d)`` tile of X "columns"
  * a  ``(BC, b)`` tile of V
  * the ``(BR, b)`` output accumulator

The pairwise squared-distance tile is assembled from an MXU matmul
(``-2 X_i X_j^T``) plus rank-1 row/column norms on the VPU, the kernel
function is applied elementwise on the VPU, and the ``(BR, BC) @ (BC, b)``
product accumulates on the MXU.  This is the threadblock/shared-memory
schedule of a CUDA streaming kernel re-expressed with BlockSpec.

The CPU build uses ``interpret=True`` (real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute); correctness is asserted
against :mod:`ref` by pytest, and TPU performance is estimated analytically
in DESIGN.md §Perf-model.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile sizes. 8/128-aligned for the TPU VPU/MXU; the row tile is
# clamped to n when n < BR so small problems still work.
BR = 256
BC = 256


def _tile_kernel(kind, selfk, x_ref, xc_ref, v_ref, h_ref, o_ref):
    """One (i, j) grid step: o[i] += k(X[i], X[j]) @ V[j] (+ sigma^2 V diag)."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]          # (BR, d) rows
    z = xc_ref[...]         # (BC, d) cols
    ell = h_ref[0]
    sf = h_ref[1]

    # Squared distances: ||x||^2 + ||z||^2 - 2 x z^T. The cross term is the
    # MXU-friendly matmul; the norms are cheap VPU reductions.
    xx = jnp.sum(x * x, axis=1)[:, None]
    zz = jnp.sum(z * z, axis=1)[None, :]
    sq = jnp.maximum(xx + zz - 2.0 * jnp.dot(x, z.T), 0.0)

    if selfk:
        # Self-kernel: pin the true diagonal to distance exactly 0. The
        # f32 cancellation in xx + zz - 2 x.z leaves O(1e-6) residue, which
        # kernels with a kink at 0 (Matern) or tiny lengthscales amplify.
        br, bc = sq.shape
        rows = i * br + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0)
        cols = j * bc + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
        sq = jnp.where(rows == cols, 0.0, sq)

    k_tile = ref.kernel_value(kind, sq, ell, sf)
    o_ref[...] += jnp.dot(k_tile, v_ref[...])


@functools.partial(jax.jit, static_argnums=(0,))
def kernel_mvm(kind, x, v, hypers):
    """(K + sigma^2 I) @ V via the tiled Pallas kernel.

    Args:
      kind: one of ``ref.KINDS`` (static).
      x: ``(n, d)`` f32 inputs.
      v: ``(n, b)`` f32 probe/solve block.
      hypers: ``(3,)`` f32 ``[ell, sf, sigma]`` (raw, not log).

    Returns:
      ``(n, b)`` f32.
    """
    n, d = x.shape
    b = v.shape[1]
    br = min(BR, n)
    bc = min(BC, n)
    if n % br != 0 or n % bc != 0:
        # Fallback: pad rows/cols up to tile multiples with far-away points
        # whose kernel values underflow to ~0 and zero probe entries.
        n_pad = ((n + bc - 1) // bc) * bc
        n_pad = ((n_pad + br - 1) // br) * br
        pad = n_pad - n
        # 1e6 offset => exp(-huge) == 0 for all supported kernels.
        x_pad = jnp.concatenate([x, jnp.full((pad, d), 1e6, x.dtype)], axis=0)
        v_pad = jnp.concatenate([v, jnp.zeros((pad, b), v.dtype)], axis=0)
        out = kernel_mvm(kind, x_pad, v_pad, hypers)
        return out[:n]

    grid = (n // br, n // bc)
    out = pl.pallas_call(
        functools.partial(_tile_kernel, kind, True),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i, j: (i, 0)),   # X row tile
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),   # X col tile
            pl.BlockSpec((bc, b), lambda i, j: (j, 0)),   # V tile
            pl.BlockSpec((3,), lambda i, j: (0,)),        # hypers (replicated)
        ],
        out_specs=pl.BlockSpec((br, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), v.dtype),
        interpret=True,
    )(x, x, v, hypers)

    sigma = hypers[2]
    return out + (sigma * sigma) * v


@functools.partial(jax.jit, static_argnums=(0,))
def kernel_cross_mvm(kind, x, z, v, hypers):
    """Cross-covariance product K(x, z) @ V (no noise), for prediction.

    ``x: (n, d)``, ``z: (m, d)``, ``v: (m, b)`` -> ``(n, b)``.
    Implemented with the same tiling; row tiles come from x, column tiles
    from z.
    """
    n, d = x.shape
    m = z.shape[0]
    b = v.shape[1]
    br = min(BR, n)
    bc = min(BC, m)
    if n % br != 0 or m % bc != 0:
        n_pad = ((n + br - 1) // br) * br
        m_pad = ((m + bc - 1) // bc) * bc
        x_pad = jnp.concatenate(
            [x, jnp.full((n_pad - n, d), 1e6, x.dtype)], axis=0)
        z_pad = jnp.concatenate(
            [z, jnp.full((m_pad - m, d), -1e6, z.dtype)], axis=0)
        v_pad = jnp.concatenate(
            [v, jnp.zeros((m_pad - m, b), v.dtype)], axis=0)
        return kernel_cross_mvm(kind, x_pad, z_pad, v_pad, hypers)[:n]

    grid = (n // br, m // bc)
    return pl.pallas_call(
        functools.partial(_tile_kernel, kind, False),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bc, b), lambda i, j: (j, 0)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((br, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), v.dtype),
        interpret=True,
    )(x, z, v, hypers)
