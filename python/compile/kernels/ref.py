"""Pure-jnp reference oracle for the Pallas kernel-matrix MVM.

This is the correctness ground truth for :mod:`kernel_mvm`.  Everything here
is deliberately naive: materialize the full n x n kernel matrix, multiply.
The Pallas kernel must match these numerics (up to f32 accumulation order).

Kernels follow the paper's supplementary material (Appendix A):

  RBF           k(r) = sf^2 exp(-r^2 / (2 l^2))
  Matern-1/2    k(r) = sf^2 exp(-r / l)
  Matern-3/2    k(r) = sf^2 (1 + sqrt(3) r / l) exp(-sqrt(3) r / l)
  Matern-5/2    k(r) = sf^2 (1 + sqrt(5) r / l + 5 r^2 / (3 l^2)) exp(-sqrt(5) r / l)

Hyperparameters are passed *raw* (not log-transformed) as an f32[3] array
``[ell, sf, sigma]``; sigma enters as the diagonal noise ``sigma^2 I``.
"""

import jax.numpy as jnp

KINDS = ("rbf", "mat12", "mat32", "mat52")


def sqdist(x, z):
    """Pairwise squared Euclidean distances between rows of x (n,d), z (m,d)."""
    xx = jnp.sum(x * x, axis=1)[:, None]
    zz = jnp.sum(z * z, axis=1)[None, :]
    sq = xx + zz - 2.0 * (x @ z.T)
    return jnp.maximum(sq, 0.0)


def kernel_value(kind, sq, ell, sf):
    """Elementwise kernel value from squared distances ``sq``."""
    sf2 = sf * sf
    if kind == "rbf":
        return sf2 * jnp.exp(-0.5 * sq / (ell * ell))
    r = jnp.sqrt(sq + 1e-30)  # eps guards the sqrt grad/denorm at r=0
    if kind == "mat12":
        return sf2 * jnp.exp(-r / ell)
    if kind == "mat32":
        a = jnp.sqrt(3.0) * r / ell
        return sf2 * (1.0 + a) * jnp.exp(-a)
    if kind == "mat52":
        a = jnp.sqrt(5.0) * r / ell
        return sf2 * (1.0 + a + a * a / 3.0) * jnp.exp(-a)
    raise ValueError(f"unknown kernel kind {kind!r}")


def kernel_matrix(kind, x, z, hypers):
    """Dense cross-kernel matrix K(x, z); no noise term."""
    ell, sf = hypers[0], hypers[1]
    return kernel_value(kind, sqdist(x, z), ell, sf)


def kernel_mvm_ref(kind, x, v, hypers):
    """Reference (K(x,x) + sigma^2 I) @ v with v of shape (n, b)."""
    sigma = hypers[2]
    k = kernel_matrix(kind, x, x, hypers)
    return k @ v + (sigma * sigma) * v
