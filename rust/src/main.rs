//! gpsld CLI — the Layer-3 coordinator entry point.
//!
//! `gpsld exp <id>` regenerates any of the paper's tables/figures;
//! `gpsld artifacts` verifies the PJRT artifact set. See `gpsld --help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(gpsld::coordinator::cli::main_with_args(&args));
}
