//! Crate-wide error type.

use thiserror::Error;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Error, Debug)]
pub enum Error {
    #[error("matrix is not positive definite (pivot {pivot}, value {value})")]
    NotPositiveDefinite { pivot: usize, value: f64 },

    #[error("matrix is singular at pivot {pivot}")]
    Singular { pivot: usize },

    #[error("eigensolver failed to converge at index {index}")]
    EigFailed { index: usize },

    #[error("CG did not converge: residual {residual:.3e} after {iters} iterations")]
    CgNoConvergence { residual: f64, iters: usize },

    #[error("dimension mismatch: {context} (expected {expected}, got {got})")]
    DimMismatch { context: &'static str, expected: usize, got: usize },

    #[error("invalid configuration: {0}")]
    Config(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("xla error: {0}")]
    Xla(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("optimization failed: {0}")]
    Optim(String),

    #[error("{0}")]
    Msg(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(format!("{e:?}"))
    }
}
