//! Crate-wide error type (hand-rolled `Display`/`Error` impls — `thiserror`
//! is not in the offline registry).

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    NotPositiveDefinite { pivot: usize, value: f64 },
    Singular { pivot: usize },
    EigFailed { index: usize },
    CgNoConvergence { residual: f64, iters: usize },
    DimMismatch { context: &'static str, expected: usize, got: usize },
    Config(String),
    Artifact(String),
    Xla(String),
    Io(std::io::Error),
    Optim(String),
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::NotPositiveDefinite { pivot, value } => {
                write!(f, "matrix is not positive definite (pivot {pivot}, value {value})")
            }
            Error::Singular { pivot } => write!(f, "matrix is singular at pivot {pivot}"),
            Error::EigFailed { index } => {
                write!(f, "eigensolver failed to converge at index {index}")
            }
            Error::CgNoConvergence { residual, iters } => {
                write!(f, "CG did not converge: residual {residual:.3e} after {iters} iterations")
            }
            Error::DimMismatch { context, expected, got } => {
                write!(f, "dimension mismatch: {context} (expected {expected}, got {got})")
            }
            Error::Config(s) => write!(f, "invalid configuration: {s}"),
            Error::Artifact(s) => write!(f, "artifact error: {s}"),
            Error::Xla(s) => write!(f, "xla error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Optim(s) => write!(f, "optimization failed: {s}"),
            Error::Msg(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(format!("{e:?}"))
    }
}
