//! Synthetic dataset generators standing in for the paper's proprietary /
//! external datasets (substitution table in DESIGN.md §4). Every generator
//! is seeded and exercises exactly the code paths the original data did:
//! Toeplitz-SKI (sound), 3-D Kronecker SKI (precipitation), LGCP grids
//! (hickory, crime), and high-dim features with low-dim structure (gas).

use crate::grid::{Grid, GridDim};
use crate::kernels::{Kernel, SeparableKernel};
use crate::linalg::chol::Cholesky;
use crate::linalg::dense::Mat;
use crate::operators::kron::{KronFactor, KronOp};
use crate::operators::LinOp;
use crate::util::rng::Rng;

/// A regression dataset split into train/test.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x_train: Vec<Vec<f64>>,
    pub y_train: Vec<f64>,
    pub x_test: Vec<Vec<f64>>,
    pub y_test: Vec<f64>,
}

impl Dataset {
    pub fn n_train(&self) -> usize {
        self.y_train.len()
    }
    pub fn n_test(&self) -> usize {
        self.y_test.len()
    }
}

/// Exact GP sample on a separable-kernel grid via per-factor Cholesky:
/// `f = (L_1 ⊗ ... ⊗ L_d) z * sf` with `K_j = L_j L_j^T`.
pub fn sample_grid_gp(grid: &Grid, kernel: &SeparableKernel, jitter: f64, rng: &mut Rng) -> Vec<f64> {
    let mut factors = Vec::new();
    for (j, dim) in grid.dims.iter().enumerate() {
        let f = &kernel.factors[j];
        let mut k = Mat::from_fn(dim.m, dim.m, |a, b| {
            f.eval(&[dim.point(a)], &[dim.point(b)])
        });
        k.add_diag(jitter);
        let chol = Cholesky::new_jittered(&k, 1e-10, 10).expect("grid factor chol");
        factors.push(KronFactor::Dense(chol.l));
    }
    let lop = KronOp::new(factors, kernel.sf2().sqrt());
    let mut z = vec![0.0; grid.size()];
    rng.fill_gaussian(&mut z);
    lop.apply_vec(&z)
}

/// §5.1 substitute: an audio-like 1-D signal (chirps under AM envelopes plus
/// weak noise), sampled at `n` uniform times with `gaps` contiguous missing
/// regions of length `gap_len` forming the test set.
pub fn sound(n: usize, gaps: usize, gap_len: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let dt = 1.0 / n as f64;
    let y_full: Vec<f64> = (0..n)
        .map(|i| {
            let t = i as f64 * dt;
            let chirp1 = (2.0 * std::f64::consts::PI * (40.0 * t + 120.0 * t * t)).sin();
            let chirp2 = (2.0 * std::f64::consts::PI * (90.0 * t + 20.0 * (3.0 * t).sin())).sin();
            let env1 = 0.6 + 0.4 * (2.0 * std::f64::consts::PI * 2.0 * t).sin();
            let env2 = 0.5 + 0.5 * (2.0 * std::f64::consts::PI * 3.3 * t + 0.7).cos();
            env1 * chirp1 + 0.7 * env2 * chirp2 + 0.02 * rng.gaussian()
        })
        .collect();
    let mut is_test = vec![false; n];
    for g in 0..gaps {
        // Deterministically spread gaps, jittered.
        let start = ((g + 1) * n) / (gaps + 2) + rng.below(n / (gaps + 2) / 2 + 1);
        for k in 0..gap_len.min(n.saturating_sub(start)) {
            is_test[start + k] = true;
        }
    }
    let mut d = Dataset { x_train: vec![], y_train: vec![], x_test: vec![], y_test: vec![] };
    for i in 0..n {
        let x = vec![i as f64 * dt];
        if is_test[i] {
            d.x_test.push(x);
            d.y_test.push(y_full[i]);
        } else {
            d.x_train.push(x);
            d.y_train.push(y_full[i]);
        }
    }
    d
}

/// §5.2 substitute: daily precipitation over (lon, lat, day). A smooth
/// latent GP field on a coarse grid, cubic-interpolated to station
/// locations, plus seasonal structure and noise. `n` total points;
/// `test_frac` held out at random.
pub fn precipitation(n: usize, test_frac: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    // Latent field on a coarse 3-D grid.
    let grid = Grid::new(vec![
        GridDim { lo: 0.0, hi: 1.0, m: 24 },
        GridDim { lo: 0.0, hi: 1.0, m: 24 },
        GridDim { lo: 0.0, hi: 1.0, m: 32 },
    ]);
    let kern = SeparableKernel::iso(crate::kernels::Shape::Matern32, 3, 0.25, 1.0);
    let field = sample_grid_gp(&grid, &kern, 1e-8, &mut rng);
    // Stations: clustered in space, dense in time.
    let n_stations = (n / 64).max(10);
    let stations: Vec<(f64, f64)> = (0..n_stations)
        .map(|_| (rng.uniform(), rng.uniform()))
        .collect();
    let pts: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let (sx, sy) = stations[rng.below(n_stations)];
            vec![
                (sx + 0.01 * rng.gaussian()).clamp(0.0, 1.0),
                (sy + 0.01 * rng.gaussian()).clamp(0.0, 1.0),
                rng.uniform(),
            ]
        })
        .collect();
    let (wmat, _) = grid.interp_matrix(&pts, crate::grid::InterpOrder::Cubic);
    let mut latent = vec![0.0; n];
    wmat.apply(&field, &mut latent);
    let ys: Vec<f64> = (0..n)
        .map(|i| {
            let t = pts[i][2];
            let seasonal = 0.8 * (2.0 * std::f64::consts::PI * (t - 0.2)).sin();
            latent[i] + seasonal + 0.3 * rng.gaussian()
        })
        .collect();
    let mut d = Dataset { x_train: vec![], y_train: vec![], x_test: vec![], y_test: vec![] };
    for i in 0..n {
        if rng.uniform() < test_frac {
            d.x_test.push(pts[i].clone());
            d.y_test.push(ys[i]);
        } else {
            d.x_train.push(pts[i].clone());
            d.y_train.push(ys[i]);
        }
    }
    d
}

/// LGCP dataset: counts per grid cell plus the generating latent field.
#[derive(Clone, Debug)]
pub struct CountGrid {
    pub grid: Grid,
    pub counts: Vec<f64>,
    /// True latent log-intensity (for recovery checks).
    pub latent: Vec<f64>,
    /// Log offset used in generation.
    pub offset: f64,
}

/// §5.3 substitute: hickory-like point pattern discretized on an
/// `m x m` grid. Intensity from a known smooth log-field sampled from a GP
/// with `(sf, ell1, ell2)` — so recovered hypers can be compared with truth.
pub fn hickory(m: usize, sf: f64, ell: f64, total_points: f64, seed: u64) -> CountGrid {
    let mut rng = Rng::new(seed);
    let grid = Grid::new(vec![
        GridDim { lo: 0.0, hi: 1.0, m },
        GridDim { lo: 0.0, hi: 1.0, m },
    ]);
    let kern = SeparableKernel::iso(crate::kernels::Shape::Rbf, 2, ell, sf);
    let latent = sample_grid_gp(&grid, &kern, 1e-8, &mut rng);
    // Offset so that total expected count ≈ total_points.
    let mean_exp: f64 =
        latent.iter().map(|&f| f.exp()).sum::<f64>() / latent.len() as f64;
    let offset = (total_points / (mean_exp * latent.len() as f64)).ln();
    let counts: Vec<f64> = latent
        .iter()
        .map(|&f| rng.poisson((f + offset).exp()) as f64)
        .collect();
    CountGrid { grid, counts, latent, offset }
}

/// §5.4 substitute: assault-like counts on a (space x space x weeks) grid
/// with weekly-seasonal + trending intensity and negative-binomial noise.
pub fn crime(nx: usize, ny: usize, weeks: usize, dispersion: f64, seed: u64) -> CountGrid {
    let mut rng = Rng::new(seed);
    let grid = Grid::new(vec![
        GridDim { lo: 0.0, hi: 1.0, m: nx },
        GridDim { lo: 0.0, hi: 1.0, m: ny },
        GridDim { lo: 0.0, hi: 1.0, m: weeks },
    ]);
    // Two spatial hot-spots + seasonality + slow decline.
    let mut latent = vec![0.0; grid.size()];
    for i in 0..grid.size() {
        let p = grid.point(i);
        let (x, y, t) = (p[0], p[1], p[2]);
        let hot1 = 1.4 * (-((x - 0.3).powi(2) + (y - 0.6).powi(2)) / 0.03).exp();
        let hot2 = 1.0 * (-((x - 0.7).powi(2) + (y - 0.25).powi(2)) / 0.05).exp();
        let season = 0.35 * (2.0 * std::f64::consts::PI * t * (weeks as f64 / 52.0)).sin();
        let trend = -0.3 * t;
        latent[i] = hot1 + hot2 + season + trend - 0.5;
    }
    let offset = 0.6;
    let counts: Vec<f64> = latent
        .iter()
        .map(|&f| rng.neg_binomial((f + offset).exp(), dispersion) as f64)
        .collect();
    CountGrid { grid, counts, latent, offset }
}

/// §5.5 substitute: gas-sensor-like data — `dim`-dimensional feature vectors
/// generated from a 2-D latent manifold (the DKL premise), with a smooth
/// response. Returned as (X_train, y_train, X_test, y_test) matrices.
pub fn gas(n_train: usize, n_test: usize, dim: usize, seed: u64) -> (Mat, Vec<f64>, Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut make = |count: usize| {
        let mut x = Mat::zeros(count, dim);
        let mut y = vec![0.0; count];
        for i in 0..count {
            let t = rng.uniform_in(-2.0, 2.0);
            let u = rng.uniform_in(-1.0, 1.0);
            for j in 0..dim {
                let a = j as f64 * 0.37 + 0.2;
                let b = j as f64 * 0.11;
                x[(i, j)] = (a * t).sin() + 0.6 * (b * u + t * 0.2).cos()
                    + 0.05 * rng.gaussian();
            }
            y[i] = (1.5 * t).sin() + 0.4 * u * u + 0.05 * rng.gaussian();
        }
        (x, y)
    };
    let (xtr, ytr) = make(n_train);
    let (xte, yte) = make(n_test);
    (xtr, ytr, xte, yte)
}

/// Supplementary C.1/C.5 data: n points either equispaced on [lo, hi] or
/// uniform random, with y sampled from the exact GP prior at `hypers`.
pub fn gp_1d(
    n: usize,
    lo: f64,
    hi: f64,
    equispaced: bool,
    kernel: &dyn Kernel,
    sigma: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut xs: Vec<f64> = if equispaced {
        (0..n).map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64).collect()
    } else {
        (0..n).map(|_| rng.uniform_in(lo, hi)).collect()
    };
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pts: Vec<Vec<f64>> = xs.iter().map(|&x| vec![x]).collect();
    // Exact prior sample (dense Cholesky; keep n <= ~4000 here).
    let mut k = Mat::from_fn(n, n, |i, j| kernel.eval(&pts[i], &pts[j]));
    k.add_diag(sigma * sigma + 1e-10);
    let chol = Cholesky::new_jittered(&k, 1e-10, 10).expect("prior chol");
    let mut z = vec![0.0; n];
    rng.fill_gaussian(&mut z);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = 0.0;
        for j in 0..=i {
            s += chol.l[(i, j)] * z[j];
        }
        y[i] = s;
    }
    Dataset { x_train: pts, y_train: y, x_test: vec![], y_test: vec![] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Shape;

    #[test]
    fn sound_split_sizes() {
        let d = sound(2000, 3, 50, 1);
        assert_eq!(d.n_train() + d.n_test(), 2000);
        assert!(d.n_test() >= 100 && d.n_test() <= 160, "{}", d.n_test());
        // Test points form contiguous runs.
        assert!(d.x_test.windows(2).any(|w| (w[1][0] - w[0][0]) < 1.0 / 1000.0));
    }

    #[test]
    fn grid_gp_sample_has_right_marginal_scale() {
        let grid = Grid::new(vec![
            GridDim { lo: 0.0, hi: 1.0, m: 12 },
            GridDim { lo: 0.0, hi: 1.0, m: 12 },
        ]);
        let kern = SeparableKernel::iso(Shape::Rbf, 2, 0.2, 1.5);
        let mut rng = Rng::new(2);
        // Average marginal variance over several samples ≈ sf^2.
        let mut acc = 0.0;
        let reps = 30;
        for _ in 0..reps {
            let f = sample_grid_gp(&grid, &kern, 1e-8, &mut rng);
            acc += f.iter().map(|v| v * v).sum::<f64>() / f.len() as f64;
        }
        let var = acc / reps as f64;
        assert!((var - 2.25).abs() < 0.8, "marginal var {var}");
    }

    #[test]
    fn hickory_counts_total_matches_target() {
        let cg = hickory(30, 1.0, 0.2, 700.0, 3);
        let total: f64 = cg.counts.iter().sum();
        assert!((total - 700.0).abs() < 250.0, "total {total}");
        assert_eq!(cg.counts.len(), 900);
    }

    #[test]
    fn crime_grid_dims() {
        let cg = crime(17, 26, 52, 3.0, 4);
        assert_eq!(cg.counts.len(), 17 * 26 * 52);
        assert!(cg.counts.iter().all(|&c| c >= 0.0));
        // Hot-spot cells should out-count the corner cells on average.
        let hot = cg.grid.lin_index(&[5, 15, 10]); // near (0.3, 0.6)
        let cold = cg.grid.lin_index(&[16, 0, 10]);
        assert!(cg.latent[hot] > cg.latent[cold]);
    }

    #[test]
    fn precipitation_split() {
        let d = precipitation(3000, 0.2, 5);
        assert_eq!(d.n_train() + d.n_test(), 3000);
        assert!(d.n_test() > 400 && d.n_test() < 800);
        assert_eq!(d.x_train[0].len(), 3);
    }

    #[test]
    fn gas_shapes() {
        let (xtr, ytr, xte, yte) = gas(100, 25, 16, 6);
        assert_eq!((xtr.rows, xtr.cols), (100, 16));
        assert_eq!(ytr.len(), 100);
        assert_eq!((xte.rows, xte.cols), (25, 16));
        assert_eq!(yte.len(), 25);
    }

    #[test]
    fn gp_1d_reproducible() {
        let k = crate::kernels::IsoKernel::new(Shape::Rbf, 1, 0.1, 1.0);
        let a = gp_1d(100, 0.0, 4.0, true, &k, 0.1, 7);
        let b = gp_1d(100, 0.0, 4.0, true, &k, 0.1, 7);
        assert_eq!(a.y_train, b.y_train);
    }
}
