//! Stochastic Chebyshev estimation of `log|K̃|` and its derivatives
//! (paper §3.1).
//!
//! The spectrum is mapped to `[-1, 1]` via `B = (2 K̃ - (b+a) I) / (b - a)`
//! with `[a, b]` bracketing the eigenvalues; the Chebyshev interpolant of
//! `f(t) = log(((b-a) t + (b+a)) / 2)` then gives
//! `log|K̃| ≈ sum_j c_j tr(T_j(B))`, estimated stochastically by coupled
//! three-term recurrences `w_j = T_j(B) z` and `∂w_j/∂θ_i` — each
//! derivative costs two extra MVMs per term (§3.1).
//!
//! The driver is **blocked**: the recurrences run over `n x b` probe
//! blocks, so every Chebyshev term costs one block MVM (plus `2 nh` block
//! MVMs for the coupled derivative recurrences) regardless of how many
//! probes ride in the block. Per-column arithmetic is identical to the
//! single-probe recurrence, so estimates are bit-identical across block
//! sizes.

use super::confidence;
use super::lanczos::extremal_eigs;
use super::probes::{combine, ProbeKind, ProbeSet};
use super::{BlockPartition, LogdetEstimate, SpectralEvidence};
use crate::error::Result;
use crate::linalg::dense::Mat;
use crate::operators::{KernelOp, LinOp};
use crate::util::obs;
use crate::util::parallel;

/// Options for the Chebyshev estimator.
#[derive(Clone, Copy, Debug)]
pub struct ChebOptions {
    /// Polynomial degree / number of moments (paper uses 100 for Fig. 1).
    /// Defaults to the process `--steps` override when set (the CLI's
    /// per-probe step budget covers Lanczos steps and Chebyshev degree
    /// alike), else 100.
    pub degree: usize,
    /// Number of probe vectors. With `target_tol` set this is only the
    /// seed of the adaptive schedule (see [`super::slq::SlqOptions`]).
    pub probes: usize,
    pub kind: ProbeKind,
    pub seed: u64,
    pub grads: bool,
    /// Eigenvalue bracket; estimated via Lanczos Ritz values when `None`.
    pub lambda_bounds: Option<(f64, f64)>,
    /// Worker threads across probe blocks (shared `util::parallel` pool;
    /// bit-identical estimates for every thread count). Defaults to the
    /// process default (CLI `--threads`).
    pub threads: usize,
    /// Probe-block width b for blocked MVMs (1 reproduces the per-probe
    /// path apply-for-apply; estimates are identical either way).
    pub block_size: usize,
    /// MVM precision for the `K̃`-applies of the Chebyshev recurrences
    /// (every `B x` in both the moment and the coupled derivative
    /// recurrence): `F64` is bit-identical to the pre-knob estimator;
    /// `F32F64` runs the recurrences on the storage-rounded operator. The
    /// spectrum bracket, Chebyshev coefficients, and derivative passes
    /// (`apply_grad_all_mat`) always stay f64. Defaults to the process
    /// default (CLI `--precision`).
    pub precision: crate::util::precision::Precision,
    /// Adaptive stopping tolerance — same contract as
    /// [`super::slq::SlqOptions::target_tol`]: `Some(tol)` grows the probe
    /// set until the 95% half-width clears `tol`; `None` (default, CLI
    /// `--logdet-tol`) is the fixed budget, bit-identical to the
    /// pre-evidence estimator.
    pub target_tol: Option<f64>,
    /// Probe ceiling for adaptive mode (clamped to >= 2).
    pub max_probes: usize,
    /// Degree ceiling for the adaptive driver's **degree axis** (the
    /// Chebyshev analogue of [`super::slq::SlqOptions::max_steps`]): the
    /// driver starts at `degree` and may extend the retained sessions up
    /// to this ceiling when the truncation term dominates. `0` (default)
    /// = auto (`2 × degree`); `max_steps == degree` disables growth.
    /// Ignored when `target_tol` is `None`.
    pub max_steps: usize,
}

impl Default for ChebOptions {
    fn default() -> Self {
        ChebOptions {
            degree: super::default_steps().unwrap_or(100),
            probes: super::default_probes().unwrap_or(5),
            kind: ProbeKind::Rademacher,
            seed: 0,
            grads: true,
            lambda_bounds: None,
            threads: parallel::default_threads(),
            block_size: super::default_block_size(),
            precision: crate::util::precision::default_precision(),
            target_tol: super::default_logdet_tol(),
            max_probes: 64,
            max_steps: super::default_max_steps(),
        }
    }
}

/// Chebyshev interpolation coefficients of `f` of degree `m` on [-1, 1].
pub fn cheb_coeffs(f: impl Fn(f64) -> f64, m: usize) -> Vec<f64> {
    let n = m + 1;
    let fv: Vec<f64> = (0..n)
        .map(|k| {
            let x = (std::f64::consts::PI * (k as f64 + 0.5) / n as f64).cos();
            f(x)
        })
        .collect();
    (0..n)
        .map(|j| {
            let scale = if j == 0 { 1.0 } else { 2.0 } / n as f64;
            let mut s = 0.0;
            for (k, fk) in fv.iter().enumerate() {
                s += fk * (std::f64::consts::PI * j as f64 * (k as f64 + 0.5) / n as f64).cos();
            }
            scale * s
        })
        .collect()
}

/// Per-block partial results, kept per-column for block-width-independent
/// reduction.
#[derive(Clone)]
struct PerBlock {
    quads: Vec<f64>,
    grad_terms: Vec<Vec<f64>>,
    /// Per column: the raw moments `z^T T_j(B) z`, j = 0..=degree.
    moments: Vec<Vec<f64>>,
    mvms: usize,
    block_applies: usize,
}

/// Resumable Chebyshev moment + coupled-derivative state for one probe
/// block. Retains the last two iterates of both recurrences plus the
/// **raw** per-column moments `m_j = z^T T_j(B) z` and derivative dots
/// `d_{j,i} = z^T ∂w_j/∂θ_i` — never the coefficient-weighted sums,
/// because `cheb_coeffs` interpolates at degree-dependent nodes (every
/// coefficient changes when the degree grows). Weighting is deferred to
/// [`quads`](Self::quads)/[`grad_terms`](Self::grad_terms), which apply
/// the same left-to-right accumulation the run-to-completion driver
/// used, so a session extended to degree d is **bitwise identical** to a
/// from-scratch degree-d run. The spectrum bracket is fixed at `new` and
/// reused by every `extend` (the session's whole point: the recurrence
/// is on `B`, which must not move).
pub struct ChebSession {
    zblk: Mat,
    w_prev: Mat,
    w: Mat,
    dw_prev: Vec<Mat>,
    dw: Vec<Mat>,
    grads: bool,
    precision: crate::util::precision::Precision,
    scale: f64,
    shift: f64,
    degree: usize,
    /// Per column: raw moments, j = 0..=degree.
    moments: Vec<Vec<f64>>,
    /// Per column, per hyper: raw derivative dots, j = 1..=degree.
    grad_dots: Vec<Vec<Vec<f64>>>,
    mvms: usize,
    block_applies: usize,
}

impl std::fmt::Debug for ChebSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChebSession")
            .field("cols", &self.zblk.cols)
            .field("degree", &self.degree)
            .finish()
    }
}

impl ChebSession {
    /// Start a session on a probe block: runs the j = 0, 1 initialization
    /// (one block MVM, plus the derivative seeding when `grads`), so
    /// `degree()` is 1 afterwards.
    pub fn new(
        op: &dyn KernelOp,
        zblk: Mat,
        bracket: (f64, f64),
        grads: bool,
        precision: crate::util::precision::Precision,
    ) -> Self {
        let n = op.n();
        let nh = op.num_hypers();
        let (a, b) = bracket;
        let scale = 2.0 / (b - a);
        let shift = (b + a) / (b - a);
        let wcols = zblk.cols;
        let mut mvms = 0;
        let mut block_applies = 0;
        // w recurrence over the whole block.
        let w_prev = zblk.clone(); // w_0 = z
        let w = apply_b_mat(op, &zblk, scale, shift, precision); // w_1 = B z
        mvms += wcols;
        block_applies += 1;
        // dw recurrences per hyper.
        let mut dw_prev: Vec<Mat> = Vec::new();
        let mut dw: Vec<Mat> = Vec::new();
        if grads {
            dw_prev = vec![Mat::zeros(n, wcols); nh];
            dw = op.apply_grad_all_mat(&zblk);
            mvms += nh * wcols;
            block_applies += nh;
            for m in dw.iter_mut() {
                for v in m.data.iter_mut() {
                    *v *= scale;
                }
            }
        }
        let mut moments: Vec<Vec<f64>> = Vec::with_capacity(wcols);
        let mut grad_dots: Vec<Vec<Vec<f64>>> = Vec::with_capacity(wcols);
        for c in 0..wcols {
            let m0 = zblk.col_dot_pair(&w_prev, c);
            let m1 = zblk.col_dot_pair(&w, c);
            moments.push(vec![m0, m1]);
            if grads {
                grad_dots.push(
                    (0..nh).map(|i| vec![zblk.col_dot_pair(&dw[i], c)]).collect(),
                );
            }
        }
        ChebSession {
            zblk,
            w_prev,
            w,
            dw_prev,
            dw,
            grads,
            precision,
            scale,
            shift,
            degree: 1,
            moments,
            grad_dots,
            mvms,
            block_applies,
        }
    }

    /// Number of probe columns.
    pub fn num_cols(&self) -> usize {
        self.zblk.cols
    }

    /// Current expansion degree (1 after `new`).
    pub fn degree(&self) -> usize {
        self.degree
    }

    /// Raw per-column moments `z^T T_j(B) z`, j = 0..=degree.
    pub fn moments(&self) -> &[Vec<f64>] {
        &self.moments
    }

    /// MVMs consumed (probe-column units, block-size independent).
    pub fn mvms(&self) -> usize {
        self.mvms
    }

    /// Block-amortized operator applications consumed.
    pub fn block_applies(&self) -> usize {
        self.block_applies
    }

    /// Continue both recurrences to `degree` (no-op at or below the
    /// current degree). Must be driven by the same operator the session
    /// was opened on; the bracket stays fixed.
    pub fn extend(&mut self, op: &dyn KernelOp, degree: usize) {
        let _span = crate::span!("cheb_extend");
        let n = op.n();
        let nh = self.dw.len();
        let wcols = self.zblk.cols;
        for _ in self.degree + 1..=degree {
            // w_{j} = 2 B w_{j-1} - w_{j-2}
            let bw = apply_b_mat(op, &self.w, self.scale, self.shift, self.precision);
            self.mvms += wcols;
            self.block_applies += 1;
            let mut w_next = Mat::zeros(n, wcols);
            for ((o, bwt), wp) in
                w_next.data.iter_mut().zip(&bw.data).zip(&self.w_prev.data)
            {
                *o = 2.0 * bwt - wp;
            }
            if self.grads {
                // dw_{j} = 2 (dB w_{j-1} + B dw_{j-1}) - dw_{j-2}
                let dk_w = op.apply_grad_all_mat(&self.w);
                self.mvms += nh * wcols;
                self.block_applies += nh;
                for i in 0..nh {
                    let b_dw =
                        apply_b_mat(op, &self.dw[i], self.scale, self.shift, self.precision);
                    self.mvms += wcols;
                    self.block_applies += 1;
                    let mut next = Mat::zeros(n, wcols);
                    for (((o, dk), bd), dp) in next
                        .data
                        .iter_mut()
                        .zip(&dk_w[i].data)
                        .zip(&b_dw.data)
                        .zip(&self.dw_prev[i].data)
                    {
                        *o = 2.0 * (self.scale * dk + bd) - dp;
                    }
                    self.dw_prev[i] = std::mem::replace(&mut self.dw[i], next);
                }
            }
            self.w_prev = std::mem::replace(&mut self.w, w_next);
            for c in 0..wcols {
                self.moments[c].push(self.zblk.col_dot_pair(&self.w, c));
                if self.grads {
                    for i in 0..nh {
                        self.grad_dots[c][i].push(self.zblk.col_dot_pair(&self.dw[i], c));
                    }
                }
            }
            self.degree += 1;
        }
    }

    /// Coefficient-weighted per-column quadratures at the current degree:
    /// `Σ_j c_j m_j`, accumulated left-to-right exactly like the
    /// run-to-completion driver (pinned by the evidence-reproduction
    /// test). `coeffs.len()` must be `degree + 1`.
    pub fn quads(&self, coeffs: &[f64]) -> Vec<f64> {
        assert_eq!(coeffs.len(), self.degree + 1, "coeffs/degree mismatch");
        self.moments
            .iter()
            .map(|m| {
                let mut acc = coeffs[0] * m[0] + coeffs[1] * m[1];
                for j in 2..m.len() {
                    acc += coeffs[j] * m[j];
                }
                acc
            })
            .collect()
    }

    /// Coefficient-weighted per-column derivative terms (one per hyper),
    /// same deferred accumulation as [`quads`](Self::quads). Empty when
    /// the session was opened without gradients.
    pub fn grad_terms(&self, coeffs: &[f64]) -> Vec<Vec<f64>> {
        self.grad_dots
            .iter()
            .map(|per_hyper| {
                per_hyper
                    .iter()
                    .map(|dots| {
                        let mut acc = coeffs[1] * dots[0];
                        for (j, d) in dots.iter().enumerate().skip(1) {
                            acc += coeffs[j + 1] * d;
                        }
                        acc
                    })
                    .collect()
            })
            .collect()
    }
}

/// `B X = scale * K̃ X - shift * X`. The `K̃` MVM honors `precision`; the
/// affine map stays f64.
fn apply_b_mat(
    op: &dyn KernelOp,
    x: &Mat,
    scale: f64,
    shift: f64,
    precision: crate::util::precision::Precision,
) -> Mat {
    let mut y = op.apply_mat_prec(x, precision);
    for (yi, xi) in y.data.iter_mut().zip(&x.data) {
        *yi = scale * *yi - shift * *xi;
    }
    y
}

/// Estimate `log|K̃|` (and optionally all derivatives) via stochastic
/// Chebyshev moments. With `opts.target_tol` unset this is the fixed
/// budget, bit-identical to the pre-evidence estimator; with it set, the
/// two-axis adaptive driver grows probes or degree — whichever component
/// of the interval half-width dominates — until the tolerance clears
/// (never stopping before 2 probes). See [`super::slq`] for the shared
/// axis mechanics; the degree axis is capped at `max_steps` when set,
/// `2 × degree` when 0, and closed entirely when `max_steps == degree`.
pub fn chebyshev_logdet(op: &dyn KernelOp, opts: &ChebOptions) -> Result<LogdetEstimate> {
    let _span = crate::span!("cheb");
    let n = op.n();
    let nh = op.num_hypers();
    let (a, b) = match opts.lambda_bounds {
        Some(ab) => ab,
        None => {
            // Bracket MVMs are not charged to `LogdetEstimate::mvms`, so
            // they must stay off the counters too (the span still times).
            let _bspan = crate::span!("cheb_bracket");
            let _quiet = obs::suppress_applies();
            let (lo, hi) = extremal_eigs(op, 20.min(n), opts.seed ^ 0x5eed)?;
            // The noise floor lower-bounds the spectrum.
            (lo.max(op.noise_var() * 0.5), hi)
        }
    };
    assert!(b > a && a > 0.0, "invalid spectrum bracket [{a}, {b}]");
    let f = |t: f64| (0.5 * ((b - a) * t + (b + a))).ln();

    let audit = obs::audit_begin();
    let est = match opts.target_tol {
        None => {
            let degree = opts.degree;
            let coeffs = cheb_coeffs(f, degree);
            let probes = ProbeSet::new(n, opts.probes, opts.kind, opts.seed);
            let z = probes.as_mat();
            let blocks = run_blocks(op, opts, &z, 0, opts.probes, degree, &coeffs, (a, b));
            Ok(assemble(&blocks, opts, nh, opts.probes, &coeffs, (a, b)))
        }
        Some(tol) => cheb_adaptive(op, opts, tol, (a, b), &f, nh),
    }?;
    obs::add(obs::Counter::Probes, est.probes_used as u64);
    obs::add(obs::Counter::Steps, est.steps_used as u64);
    audit.end_assert(
        "cheb",
        &[
            (obs::Counter::Mvms, est.mvms as u64),
            (obs::Counter::BlockApplies, est.block_applies as u64),
        ],
    );
    Ok(est)
}

/// Two-axis adaptive Chebyshev driver — the same shape as
/// `slq::slq_adaptive`: probe chunks (2 first, then
/// `(done/2).clamp(1, block_size)`, the probe matrix drawn once at
/// `max_probes` width so prefixes never redraw) retained as live
/// [`ChebSession`]s; after each budget change the half-width splits into
/// Monte-Carlo vs truncation ([`confidence::half_width_parts`]) and the
/// dominant axis grows. Degree growth recomputes the coefficient vector
/// at the new degree (interpolation nodes move) but reuses every raw
/// moment — only plain re-weighting, no MVMs. Unlike Lanczos there is no
/// breakdown: the degree axis closes only at its cap.
fn cheb_adaptive(
    op: &dyn KernelOp,
    opts: &ChebOptions,
    tol: f64,
    bracket: (f64, f64),
    f: &(dyn Fn(f64) -> f64),
    nh: usize,
) -> Result<LogdetEstimate> {
    use super::slq::{next_step_budget, step_axis_cap};
    let n = op.n();
    let max_probes = opts.max_probes.max(2);
    let start_degree = opts.degree.max(1);
    let cap = step_axis_cap(start_degree, opts.max_steps, usize::MAX);
    let probes = ProbeSet::new(n, max_probes, opts.kind, opts.seed);
    let z = probes.as_mat();
    let mut blocks: Vec<ChebSession> = Vec::new();
    let mut done = 0usize;
    let mut degree = start_degree;
    let mut coeffs = cheb_coeffs(f, degree);
    let mut degree_axis_open = cap > degree;
    loop {
        let chunk = if done == 0 {
            2.min(max_probes)
        } else {
            (done / 2).clamp(1, opts.block_size.max(1)).min(max_probes - done)
        };
        let part = BlockPartition::new(chunk, opts.block_size);
        let cur_degree = degree;
        let new_blocks = {
            let _chunk_span = crate::span!("cheb_probe_chunk");
            parallel::par_map(part.nblocks, opts.threads, |bi| {
                let (j0, wcols) = part.range(bi);
                let zblk = z.sub_cols(done + j0, wcols);
                let mut s = ChebSession::new(op, zblk, bracket, opts.grads, opts.precision);
                s.extend(op, cur_degree);
                s
            })
        };
        blocks.extend(new_blocks);
        done += chunk;
        loop {
            let per_probe: Vec<f64> =
                blocks.iter().flat_map(|s| s.quads(&coeffs)).collect();
            let moments: Vec<Vec<f64>> =
                blocks.iter().flat_map(|s| s.moments().iter().cloned()).collect();
            let probe_view = SpectralEvidence::Chebyshev {
                moments,
                coeffs: coeffs.clone(),
                bracket,
                resume: None,
            };
            let (mc, trunc) = confidence::half_width_parts(
                &per_probe,
                &probe_view,
                confidence::DEFAULT_LEVEL,
            );
            let probe_room = done < max_probes;
            if (done >= 2 && mc + trunc <= tol) || (!probe_room && !degree_axis_open) {
                return Ok(assemble_sessions(opts, nh, blocks, per_probe, &coeffs, bracket));
            }
            if degree_axis_open && (trunc > mc || !probe_room) {
                let target = next_step_budget(degree, cap);
                let _ext_span = crate::span!("cheb_degree_extend");
                let slots: Vec<std::sync::Mutex<&mut ChebSession>> =
                    blocks.iter_mut().map(std::sync::Mutex::new).collect();
                parallel::par_map(slots.len(), opts.threads, |i| {
                    let mut slot = slots[i].lock().expect("session slot");
                    slot.extend(op, target);
                });
                degree = target;
                coeffs = cheb_coeffs(f, degree);
                degree_axis_open = degree < cap;
                continue;
            }
            break;
        }
    }
}

/// Final assembly of the adaptive Chebyshev driver: probe-order gradient
/// accumulation from the retained raw dots (bitwise the fixed path's
/// arithmetic at the final degree), MVM accounting off the sessions, and
/// evidence carrying resume handles.
fn assemble_sessions(
    opts: &ChebOptions,
    nh: usize,
    blocks: Vec<ChebSession>,
    per_probe: Vec<f64>,
    coeffs: &[f64],
    bracket: (f64, f64),
) -> LogdetEstimate {
    let probes_used = per_probe.len();
    let mut grad = vec![0.0; if opts.grads { nh } else { 0 }];
    let mut mvms = 0;
    let mut block_applies = 0;
    let mut moments = Vec::with_capacity(probes_used);
    for s in &blocks {
        moments.extend(s.moments().iter().cloned());
        for gt in s.grad_terms(coeffs) {
            for (gi, t) in grad.iter_mut().zip(&gt) {
                *gi += t;
            }
        }
        mvms += s.mvms();
        block_applies += s.block_applies();
    }
    for gi in grad.iter_mut() {
        *gi /= probes_used as f64;
    }
    let (value, std_err) = combine(&per_probe);
    let steps_used =
        moments.iter().map(|m| m.len().saturating_sub(1)).max().unwrap_or(0);
    let resume = Some(std::sync::Arc::new(blocks));
    let evidence = SpectralEvidence::Chebyshev {
        moments,
        coeffs: coeffs.to_vec(),
        bracket,
        resume,
    };
    let interval =
        confidence::interval_from_parts(value, &per_probe, &evidence, confidence::DEFAULT_LEVEL);
    LogdetEstimate {
        value,
        grad,
        std_err,
        per_probe,
        mvms,
        block_applies,
        evidence,
        interval,
        probes_used,
        steps_used,
    }
}

/// Run the blocked Chebyshev recurrences over `count` probe columns of `z`
/// starting at `base` — one `PerBlock` per partition block, in probe
/// order. Since the session refactor this is a driver over
/// [`ChebSession`] (`new` + `extend(degree)` + deferred weighting), which
/// is bitwise identical to the historical run-to-completion recurrence.
#[allow(clippy::too_many_arguments)]
fn run_blocks(
    op: &dyn KernelOp,
    opts: &ChebOptions,
    z: &Mat,
    base: usize,
    count: usize,
    degree: usize,
    coeffs: &[f64],
    bracket: (f64, f64),
) -> Vec<PerBlock> {
    let part = BlockPartition::new(count, opts.block_size);
    let _span = crate::span!("cheb_probe_chunk");
    parallel::par_map(part.nblocks, opts.threads, |bi| {
        let (j0, wcols) = part.range(bi);
        let zblk = z.sub_cols(base + j0, wcols);
        let mut sess = ChebSession::new(op, zblk, bracket, opts.grads, opts.precision);
        sess.extend(op, degree);
        PerBlock {
            quads: sess.quads(coeffs),
            grad_terms: sess.grad_terms(coeffs),
            moments: sess.moments.clone(),
            mvms: sess.mvms,
            block_applies: sess.block_applies,
        }
    })
}

/// Cross-block reduction: accumulates per-probe values and gradient terms
/// in probe order, attaches the retained moment evidence, and synthesizes
/// the confidence interval. `probes_used` is the gradient divisor.
fn assemble(
    blocks: &[PerBlock],
    opts: &ChebOptions,
    nh: usize,
    probes_used: usize,
    coeffs: &[f64],
    bracket: (f64, f64),
) -> LogdetEstimate {
    let mut per_probe = Vec::with_capacity(probes_used);
    let mut moments = Vec::with_capacity(probes_used);
    let mut grad = vec![0.0; if opts.grads { nh } else { 0 }];
    let mut mvms = 0;
    let mut block_applies = 0;
    for r in blocks {
        per_probe.extend_from_slice(&r.quads);
        moments.extend(r.moments.iter().cloned());
        for gt in &r.grad_terms {
            for (gi, t) in grad.iter_mut().zip(gt) {
                *gi += t;
            }
        }
        mvms += r.mvms;
        block_applies += r.block_applies;
    }
    for gi in grad.iter_mut() {
        *gi /= probes_used as f64;
    }
    let (value, std_err) = combine(&per_probe);
    let steps_used =
        moments.iter().map(|m| m.len().saturating_sub(1)).max().unwrap_or(0);
    let evidence = SpectralEvidence::Chebyshev {
        moments,
        coeffs: coeffs.to_vec(),
        bracket,
        resume: None,
    };
    let interval =
        confidence::interval_from_parts(value, &per_probe, &evidence, confidence::DEFAULT_LEVEL);
    LogdetEstimate {
        value,
        grad,
        std_err,
        per_probe,
        mvms,
        block_applies,
        evidence,
        interval,
        probes_used,
        steps_used,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimators::exact;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::DenseKernelOp;
    use crate::util::rng::Rng;

    fn op(n: usize, sigma: f64, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.4, 1.0)),
            sigma,
        )
    }

    #[test]
    fn coeffs_reproduce_function() {
        let c = cheb_coeffs(|x| (2.0 + x).ln(), 30);
        // Evaluate the expansion at a few points via Clenshaw.
        for &x in &[-0.9, -0.3, 0.2, 0.8] {
            let mut b1 = 0.0;
            let mut b2 = 0.0;
            for j in (1..c.len()).rev() {
                let b0 = 2.0 * x * b1 - b2 + c[j];
                b2 = b1;
                b1 = b0;
            }
            let val = x * b1 - b2 + c[0];
            assert!((val - (2.0f64 + x).ln()).abs() < 1e-10, "x={x}");
        }
    }

    #[test]
    fn logdet_close_to_exact_well_conditioned() {
        let o = op(120, 0.5, 1); // large noise: small condition number
        let opts = ChebOptions { degree: 80, probes: 8, seed: 2, ..Default::default() };
        let est = chebyshev_logdet(&o, &opts).unwrap();
        let truth = exact::exact_logdet(&o).unwrap();
        assert!(
            (est.value - truth).abs() < 0.05 * truth.abs().max(1.0) + 4.0 * est.std_err,
            "{} vs {}",
            est.value,
            truth
        );
    }

    #[test]
    fn grads_close_to_exact() {
        let o = op(80, 0.5, 3);
        let opts = ChebOptions { degree: 60, probes: 64, seed: 4, ..Default::default() };
        let est = chebyshev_logdet(&o, &opts).unwrap();
        let (_, tg) = exact::exact_logdet_grads_dense(&o).unwrap();
        for i in 0..tg.len() {
            assert!(
                (est.grad[i] - tg[i]).abs() < 0.2 * tg[i].abs().max(1.0),
                "hyper {i}: {} vs {}",
                est.grad[i],
                tg[i]
            );
        }
    }

    #[test]
    fn struggles_at_small_noise_relative_to_lanczos() {
        // The paper's supp. C.1/C.2: Chebyshev degrades as sigma -> 0 (log
        // singularity near the spectrum's floor); Lanczos doesn't. This is a
        // *shape* assertion, not a strict inequality on every seed.
        let o = op(100, 0.05, 5);
        let truth = exact::exact_logdet(&o).unwrap();
        let cheb = chebyshev_logdet(
            &o,
            &ChebOptions { degree: 40, probes: 8, grads: false, seed: 6, ..Default::default() },
        )
        .unwrap();
        let slq = crate::estimators::slq::slq_logdet(
            &o,
            &crate::estimators::slq::SlqOptions {
                steps: 40,
                probes: 8,
                grads: false,
                seed: 6,
                ..Default::default()
            },
        )
        .unwrap();
        let cheb_err = (cheb.value - truth).abs();
        let slq_err = (slq.value - truth).abs();
        assert!(
            slq_err <= cheb_err + 3.0 * slq.std_err,
            "slq {slq_err} vs cheb {cheb_err}"
        );
    }

    #[test]
    fn mvm_count_scales_with_degree() {
        let o = op(40, 0.3, 7);
        let lo = chebyshev_logdet(
            &o,
            &ChebOptions { degree: 10, probes: 2, grads: false, ..Default::default() },
        )
        .unwrap();
        let hi = chebyshev_logdet(
            &o,
            &ChebOptions { degree: 40, probes: 2, grads: false, ..Default::default() },
        )
        .unwrap();
        assert!(hi.mvms > 3 * lo.mvms);
    }

    #[test]
    fn block_size_does_not_change_estimates() {
        let o = op(70, 0.4, 9);
        let bounds = Some((0.05, 40.0));
        let base = chebyshev_logdet(
            &o,
            &ChebOptions {
                degree: 30,
                probes: 6,
                seed: 11,
                lambda_bounds: bounds,
                block_size: 1,
                ..Default::default()
            },
        )
        .unwrap();
        for bs in [2, 4, 6, 32] {
            let blocked = chebyshev_logdet(
                &o,
                &ChebOptions {
                    degree: 30,
                    probes: 6,
                    seed: 11,
                    lambda_bounds: bounds,
                    block_size: bs,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(base.value.to_bits(), blocked.value.to_bits(), "bs={bs}");
            assert_eq!(base.std_err.to_bits(), blocked.std_err.to_bits(), "bs={bs}");
            for (a, b) in base.grad.iter().zip(&blocked.grad) {
                assert_eq!(a.to_bits(), b.to_bits(), "bs={bs} grad");
            }
            assert_eq!(base.mvms, blocked.mvms, "bs={bs} probe-column mvms");
        }
    }

    /// Inert adaptive knobs leave the fixed-budget path bit-identical.
    #[test]
    fn inert_adaptive_knobs_are_bitwise_noop() {
        let o = op(60, 0.4, 13);
        let base = chebyshev_logdet(
            &o,
            &ChebOptions { degree: 25, probes: 5, seed: 2, ..Default::default() },
        )
        .unwrap();
        let knobs = chebyshev_logdet(
            &o,
            &ChebOptions {
                degree: 25,
                probes: 5,
                seed: 2,
                target_tol: None,
                max_probes: 3,
                max_steps: 7,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(base.value.to_bits(), knobs.value.to_bits());
        assert_eq!(base.std_err.to_bits(), knobs.std_err.to_bits());
        for (x, y) in base.per_probe.iter().zip(&knobs.per_probe) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in base.grad.iter().zip(&knobs.grad) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(base.mvms, knobs.mvms);
        assert_eq!(base.block_applies, knobs.block_applies);
    }

    /// Adaptive mode stops with fewer probes than a generous fixed budget
    /// when the tolerance is loose, and never stops before 2 probes.
    #[test]
    fn adaptive_stops_early_and_never_at_one_probe() {
        let o = op(80, 0.5, 17);
        let fixed = chebyshev_logdet(
            &o,
            &ChebOptions { degree: 40, probes: 16, grads: false, seed: 3, ..Default::default() },
        )
        .unwrap();
        let tol = fixed.interval.half_width() * 2.0;
        let adaptive = chebyshev_logdet(
            &o,
            &ChebOptions {
                degree: 40,
                probes: 16,
                grads: false,
                seed: 3,
                target_tol: Some(tol),
                max_probes: 64,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            adaptive.probes_used >= 2 && adaptive.probes_used < 16,
            "adaptive used {} probes",
            adaptive.probes_used
        );
        assert!(adaptive.interval.half_width() <= tol);
        // An absurdly loose tolerance still needs 2 probes.
        let loose = chebyshev_logdet(
            &o,
            &ChebOptions {
                degree: 40,
                probes: 1,
                grads: false,
                seed: 3,
                target_tol: Some(1e12),
                max_probes: 64,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(loose.probes_used >= 2);
    }

    /// The retained moments reproduce the per-probe quadratures through
    /// the retained coefficients, bit-for-bit.
    #[test]
    fn moment_evidence_reproduces_quadratures() {
        let o = op(50, 0.3, 21);
        let est = chebyshev_logdet(
            &o,
            &ChebOptions { degree: 20, probes: 4, grads: false, seed: 5, ..Default::default() },
        )
        .unwrap();
        match &est.evidence {
            SpectralEvidence::Chebyshev { moments, coeffs, bracket, .. } => {
                assert_eq!(moments.len(), est.per_probe.len());
                assert!(bracket.1 > bracket.0);
                for (m, q) in moments.iter().zip(&est.per_probe) {
                    assert_eq!(m.len(), coeffs.len());
                    // Same left-to-right accumulation as the estimator.
                    let mut acc = coeffs[0] * m[0] + coeffs[1] * m[1];
                    for j in 2..m.len() {
                        acc += coeffs[j] * m[j];
                    }
                    assert_eq!(acc.to_bits(), q.to_bits());
                }
            }
            other => panic!("expected Chebyshev evidence, got {other:?}"),
        }
        assert_eq!(est.steps_used, 20);
        assert!(est.interval.contains(est.value));
    }

    /// A session extended in stages is bitwise identical to a from-scratch
    /// run at the final degree: raw moments, derivative dots (via the
    /// weighted terms), and MVM counts all match, in both precisions.
    #[test]
    fn session_extend_matches_from_scratch_bitwise() {
        use crate::util::precision::Precision;
        let o = op(40, 0.3, 23);
        let bracket = (0.05, 30.0);
        let probes = ProbeSet::new(40, 3, ProbeKind::Rademacher, 7);
        let z = probes.as_mat();
        for prec in [Precision::F64, Precision::F32F64] {
            let mut staged = ChebSession::new(&o, z.clone(), bracket, true, prec);
            staged.extend(&o, 5);
            staged.extend(&o, 11);
            staged.extend(&o, 18);
            let mut scratch = ChebSession::new(&o, z.clone(), bracket, true, prec);
            scratch.extend(&o, 18);
            assert_eq!(staged.degree(), 18);
            assert_eq!(staged.mvms(), scratch.mvms(), "{prec:?}");
            assert_eq!(staged.block_applies(), scratch.block_applies(), "{prec:?}");
            for (ms, mf) in staged.moments().iter().zip(scratch.moments()) {
                assert_eq!(ms.len(), 19);
                for (a, b) in ms.iter().zip(mf) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{prec:?} moment");
                }
            }
            let coeffs = cheb_coeffs(|t| (2.0 + t).ln(), 18);
            for (qs, qf) in staged.quads(&coeffs).iter().zip(&scratch.quads(&coeffs)) {
                assert_eq!(qs.to_bits(), qf.to_bits(), "{prec:?} quad");
            }
            for (gs, gf) in
                staged.grad_terms(&coeffs).iter().zip(&scratch.grad_terms(&coeffs))
            {
                for (a, b) in gs.iter().zip(gf) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{prec:?} grad term");
                }
            }
            // Extending to the current degree or below is a free no-op.
            let before = staged.mvms();
            staged.extend(&o, 18);
            staged.extend(&o, 4);
            assert_eq!(staged.mvms(), before);
            assert_eq!(staged.degree(), 18);
        }
    }

    /// The adaptive final estimate is bitwise a fixed from-scratch run at
    /// `(probes_used, steps_used)` — the master pin — and on a tight
    /// tolerance the degree axis actually grows past the seed degree while
    /// the evidence carries resume handles.
    #[test]
    fn two_axis_driver_grows_degree_and_pins_to_fixed_budget() {
        let o = op(70, 0.15, 27);
        let adaptive = chebyshev_logdet(
            &o,
            &ChebOptions {
                degree: 8,
                probes: 4,
                seed: 9,
                target_tol: Some(1e-9),
                max_probes: 8,
                max_steps: 0,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            adaptive.steps_used > 8 && adaptive.steps_used <= 16,
            "degree axis should grow within the auto cap, got {}",
            adaptive.steps_used
        );
        match &adaptive.evidence {
            SpectralEvidence::Chebyshev { resume: Some(s), .. } => {
                let cols: usize = s.iter().map(|b| b.num_cols()).sum();
                assert_eq!(cols, adaptive.probes_used);
                let mvms: usize = s.iter().map(|b| b.mvms()).sum();
                assert_eq!(mvms, adaptive.mvms);
            }
            other => panic!("expected resume handles, got {other:?}"),
        }
        let fixed = chebyshev_logdet(
            &o,
            &ChebOptions {
                degree: adaptive.steps_used,
                probes: adaptive.probes_used,
                seed: 9,
                target_tol: None,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(adaptive.value.to_bits(), fixed.value.to_bits());
        for (a, b) in adaptive.per_probe.iter().zip(&fixed.per_probe) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in adaptive.grad.iter().zip(&fixed.grad) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(adaptive.mvms, fixed.mvms);
        // `max_steps == degree` is the probes-only escape hatch.
        let flat = chebyshev_logdet(
            &o,
            &ChebOptions {
                degree: 8,
                probes: 4,
                seed: 9,
                grads: false,
                target_tol: Some(1e-9),
                max_probes: 8,
                max_steps: 8,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(flat.steps_used, 8, "closed degree axis must stay at the seed");
    }
}
