//! Posterior confidence intervals over `log|K̃|` from retained spectral
//! evidence — the panel behind adaptive probe budgets.
//!
//! Both Fitzsimons et al. lines of work (*Bayesian Inference of Log
//! Determinants*: a GP posterior over the spectral measure conditioned on
//! Chebyshev/Lanczos moments; *Entropic Trace Estimates*: max-ent spectral
//! densities under the same moment constraints) observe that the quantities
//! the stochastic estimators already compute — Lanczos tridiagonals and
//! Chebyshev moment vectors — determine how uncertain the point estimate
//! is, at no additional MVM cost. This module is the moment-matched
//! version of that idea: the posterior over `log|K̃|` is summarized by a
//! Gaussian/Student-t interval whose two variance components are read
//! directly off the evidence:
//!
//! 1. **Monte-Carlo (cross-probe) term.** The per-probe quadratures
//!    `q_i = z_iᵀ f(K̃) z_i` are i.i.d. unbiased samples of the trace, so
//!    the sample mean's error is Student-t with `n_probes − 1` degrees of
//!    freedom: half-width `t_{level, n−1} · std_err`. With one probe the
//!    standard error is `+inf` ([`crate::util::stats::std_err`]), so a
//!    1-probe interval is infinite *by construction* — no adaptive rule
//!    can stop on it.
//! 2. **Truncation (within-probe) term.** Each probe's quadrature is
//!    itself truncated:
//!    * Lanczos: an m-point Gauss quadrature. Its convergence is
//!      measured post hoc by how much the estimate moved at the last
//!      step, `|q^{(m)} − q^{(m−1)}|` on the retained tridiagonal prefix
//!      (the same signal `lanczos::quadrature_steps_to_tol` uses) —
//!      averaged across probes and added to the half-width.
//!    * Chebyshev: a degree-d expansion. The coefficient tail is bounded
//!      from the observed geometric decay of the last retained
//!      coefficients: `|c_d| ρ/(1−ρ) · m_0` with `ρ` estimated from
//!      `|c_{d−L}| → |c_d|` and `m_0 = zᵀz ≥ |zᵀT_j(B)z|` the moment
//!      mass bound.
//!
//! The interval is deliberately *conservative* (terms add, tails are upper
//! bounds): the calibration contract tested in `tests/proptests.rs` is
//! that the 95% interval contains the exact log determinant at ≥ the
//! advertised rate, so adaptive stopping never reports a tolerance it did
//! not reach.

use super::{LanczosProbe, LogdetEstimate, SpectralEvidence};
use crate::linalg::tridiag::lanczos_quadrature;
use crate::util::stats;

/// A two-sided posterior interval `[lo, hi]` at confidence `level`
/// (e.g. 0.95). Degenerate (`lo == hi`) for deterministic estimates;
/// infinite when the evidence cannot bound the error (fewer than 2
/// probes, or a quadrature eigen-solve failure).
#[derive(Clone, Copy, Debug)]
pub struct ConfidenceInterval {
    pub lo: f64,
    pub hi: f64,
    pub level: f64,
}

/// The confidence level every estimator attaches by default.
pub const DEFAULT_LEVEL: f64 = 0.95;

impl ConfidenceInterval {
    /// Degenerate zero-width interval for an exact value.
    pub fn exact(value: f64) -> Self {
        ConfidenceInterval { lo: value, hi: value, level: 1.0 }
    }

    /// Full width `hi − lo` (`+inf` for an unbounded interval).
    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }

    /// Half width — what adaptive stopping compares against `target_tol`.
    pub fn half_width(&self) -> f64 {
        0.5 * (self.hi - self.lo)
    }

    /// Whether `x` lies inside the interval (closed on both ends).
    pub fn contains(&self, x: f64) -> bool {
        x >= self.lo && x <= self.hi
    }
}

/// Synthesize the interval for an assembled estimate: Student-t
/// Monte-Carlo term from `per_probe` plus the evidence's truncation term.
/// Total over every [`SpectralEvidence`] variant.
pub fn logdet_interval(est: &LogdetEstimate, level: f64) -> ConfidenceInterval {
    interval_from_parts(est.value, &est.per_probe, &est.evidence, level)
}

/// Interval from raw parts (used while an adaptive run is still growing
/// its probe set, before the final estimate exists).
pub fn interval_from_parts(
    value: f64,
    per_probe: &[f64],
    evidence: &SpectralEvidence,
    level: f64,
) -> ConfidenceInterval {
    if matches!(evidence, SpectralEvidence::Exact) {
        return ConfidenceInterval { lo: value, hi: value, level };
    }
    let (mc, trunc) = half_width_parts(per_probe, evidence, level);
    let hw = mc + trunc;
    ConfidenceInterval { lo: value - hw, hi: value + hw, level }
}

/// The half-width split into its `(monte_carlo, truncation)` components —
/// the two-axis adaptive drivers grow the probe axis when the first
/// dominates and the step/degree axis when the second does. The interval
/// built by [`interval_from_parts`] is exactly `value ± (mc + trunc)`,
/// same floating-point operations, so acting on the split is acting on
/// the interval itself. `Exact` evidence returns `(0, 0)`.
pub fn half_width_parts(
    per_probe: &[f64],
    evidence: &SpectralEvidence,
    level: f64,
) -> (f64, f64) {
    if matches!(evidence, SpectralEvidence::Exact) {
        return (0.0, 0.0);
    }
    let n = per_probe.len();
    // Monte-Carlo term: +inf below 2 probes (std_err's documented
    // sentinel), Student-t scaled otherwise.
    let mc = t_quantile(level, n.saturating_sub(1)) * stats::std_err(per_probe);
    let trunc = match evidence {
        SpectralEvidence::Exact => 0.0,
        SpectralEvidence::Lanczos { probes, .. } => lanczos_truncation(probes),
        SpectralEvidence::Chebyshev { moments, coeffs, .. } => {
            chebyshev_truncation(moments, coeffs)
        }
    };
    (mc, trunc)
}

/// Mean last-step quadrature movement across probes — the within-probe
/// Gauss-quadrature truncation estimate. A probe whose tridiagonal eigen
/// solve fails contributes `+inf` (the evidence cannot bound the error);
/// a 1-step tridiagonal contributes 0 (Lanczos broke down at step 1, i.e.
/// the probe's quadrature is exact on its Krylov space).
fn lanczos_truncation(probes: &[LanczosProbe]) -> f64 {
    if probes.is_empty() {
        return f64::INFINITY;
    }
    let f = |lam: f64| lam.max(1e-300).ln();
    let mut total = 0.0;
    for p in probes {
        let m = p.alphas.len();
        if m < 2 {
            continue;
        }
        let full = lanczos_quadrature(&p.alphas, &p.betas, p.znorm2, f);
        let prev =
            lanczos_quadrature(&p.alphas[..m - 1], &p.betas[..m - 2], p.znorm2, f);
        match (full, prev) {
            (Ok(a), Ok(b)) => total += (a - b).abs(),
            _ => return f64::INFINITY,
        }
    }
    total / probes.len() as f64
}

/// Coefficient-tail bound for a truncated Chebyshev expansion: estimate
/// the geometric decay rate ρ from the last `L` retained coefficient
/// magnitudes and bound `Σ_{j>d} |c_j| |zᵀT_j(B)z|` by
/// `|c_d| ρ/(1−ρ) · mean(m_0)` (|T_j| ≤ 1 on the bracket, so every moment
/// is bounded by the probe mass `m_0 = zᵀz`). Degrees too low to estimate
/// a decay rate give an unbounded term.
fn chebyshev_truncation(moments: &[Vec<f64>], coeffs: &[f64]) -> f64 {
    if moments.is_empty() {
        return f64::INFINITY;
    }
    let d = coeffs.len().saturating_sub(1);
    if d < 3 {
        return f64::INFINITY;
    }
    let lag = 5.min(d - 1);
    let cd = coeffs[d].abs().max(1e-300);
    let c0 = coeffs[d - lag].abs().max(1e-300);
    // Clamp: a non-decaying (or growing) tail estimate saturates at a
    // conservative ρ rather than exceeding 1.
    let rho = (cd / c0).powf(1.0 / lag as f64).clamp(1e-6, 0.95);
    let tail_coeff = cd * rho / (1.0 - rho);
    let mean_mass: f64 =
        moments.iter().map(|m| m[0].abs()).sum::<f64>() / moments.len() as f64;
    tail_coeff * mean_mass
}

/// Two-sided Student-t quantile `t` with `P(|T_df| ≤ t) = level`.
/// Exact for df ∈ {1, 2}, Cornish-Fisher expansion around the normal
/// quantile for df ≥ 3 (relative error < 1% at the 95% level, on the
/// conservative-enough side once the truncation term is added);
/// `+inf` for df = 0 — the no-information case.
pub fn t_quantile(level: f64, df: usize) -> f64 {
    let level = level.clamp(0.0, 1.0 - 1e-12);
    let p = 0.5 + 0.5 * level;
    match df {
        0 => f64::INFINITY,
        1 => (std::f64::consts::PI * (p - 0.5)).tan(),
        2 => {
            let a = 2.0 * p - 1.0;
            std::f64::consts::SQRT_2 * a / (1.0 - a * a).sqrt()
        }
        _ => {
            let z = normal_quantile(p);
            let v = df as f64;
            let z2 = z * z;
            z + (z * (z2 + 1.0)) / (4.0 * v)
                + (z * (5.0 * z2 * z2 + 16.0 * z2 + 3.0)) / (96.0 * v * v)
                + (z * (3.0 * z2 * z2 * z2 + 19.0 * z2 * z2 + 17.0 * z2 - 15.0))
                    / (384.0 * v * v * v)
        }
    }
}

/// Standard normal quantile (Acklam's rational approximation, |ε| < 1e-9
/// over (0, 1)).
fn normal_quantile(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    assert!(p > 0.0 && p < 1.0, "normal_quantile needs p in (0, 1)");
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -normal_quantile(1.0 - p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t_quantile_matches_tables() {
        // Two-sided 95% quantiles from standard t tables.
        let cases = [
            (1usize, 12.706),
            (2, 4.303),
            (3, 3.182),
            (5, 2.571),
            (10, 2.228),
            (30, 2.042),
            (1000, 1.962),
        ];
        for (df, want) in cases {
            let got = t_quantile(0.95, df);
            assert!(
                (got - want).abs() < 0.03 * want,
                "df={df}: {got} vs {want}"
            );
        }
        assert!(t_quantile(0.95, 0).is_infinite());
    }

    #[test]
    fn normal_quantile_symmetry_and_values() {
        assert!((normal_quantile(0.975) - 1.959964).abs() < 1e-6);
        assert!((normal_quantile(0.5)).abs() < 1e-12);
        assert!((normal_quantile(0.025) + normal_quantile(0.975)).abs() < 1e-9);
        assert!((normal_quantile(1e-6) + normal_quantile(1.0 - 1e-6)).abs() < 1e-6);
    }

    #[test]
    fn exact_interval_is_degenerate() {
        let ci = ConfidenceInterval::exact(-12.5);
        assert_eq!(ci.lo, ci.hi);
        assert!(ci.contains(-12.5));
        assert_eq!(ci.width(), 0.0);
    }

    #[test]
    fn single_probe_interval_is_infinite() {
        let ev = SpectralEvidence::Lanczos {
            probes: vec![LanczosProbe {
                alphas: vec![2.0, 2.1, 1.9],
                betas: vec![0.3, 0.2],
                znorm2: 10.0,
            }],
            offset: 0.0,
            resume: None,
        };
        let ci = interval_from_parts(5.0, &[5.0], &ev, 0.95);
        assert!(ci.lo.is_infinite() && ci.lo < 0.0, "{:?}", ci);
        assert!(ci.hi.is_infinite() && ci.hi > 0.0, "{:?}", ci);
        assert!(ci.half_width().is_infinite());
    }

    #[test]
    fn lanczos_interval_shrinks_with_agreeing_probes() {
        // Many probes with identical well-converged tridiagonals: tiny MC
        // spread + tiny last-step movement -> finite, narrow interval.
        let probe = LanczosProbe {
            // A converged tridiagonal: last beta nearly 0, so the m-1 vs m
            // quadratures agree closely.
            alphas: vec![2.0, 3.0, 2.5, 2.5],
            betas: vec![0.5, 0.1, 1e-9],
            znorm2: 4.0,
        };
        let ev = SpectralEvidence::Lanczos {
            probes: vec![probe.clone(), probe.clone(), probe.clone(), probe],
            offset: 0.0,
            resume: None,
        };
        let per_probe = [4.1, 4.1, 4.1, 4.1];
        let ci = interval_from_parts(4.1, &per_probe, &ev, 0.95);
        assert!(ci.half_width().is_finite());
        assert!(ci.half_width() < 1e-6, "half width {}", ci.half_width());
        assert!(ci.contains(4.1));
    }

    #[test]
    fn chebyshev_tail_uses_coefficient_decay() {
        // Geometrically decaying coefficients -> finite tail bound that
        // shrinks as the decay steepens.
        let moments = vec![vec![8.0; 21]; 4];
        let slow: Vec<f64> = (0..21).map(|j| 0.5f64.powi(j)).collect();
        let fast: Vec<f64> = (0..21).map(|j| 0.1f64.powi(j)).collect();
        let per_probe = [1.0, 1.0, 1.0, 1.0];
        let ev_slow = SpectralEvidence::Chebyshev {
            moments: moments.clone(),
            coeffs: slow,
            bracket: (0.1, 10.0),
            resume: None,
        };
        let ev_fast = SpectralEvidence::Chebyshev {
            moments,
            coeffs: fast,
            bracket: (0.1, 10.0),
            resume: None,
        };
        let hw_slow = interval_from_parts(1.0, &per_probe, &ev_slow, 0.95).half_width();
        let hw_fast = interval_from_parts(1.0, &per_probe, &ev_fast, 0.95).half_width();
        assert!(hw_slow.is_finite() && hw_fast.is_finite());
        assert!(hw_fast < hw_slow, "{hw_fast} vs {hw_slow}");
    }
}
