//! Second-derivative (Hessian) estimation for the log marginal likelihood
//! (paper §3.4): unbiased estimators that need **no additional solves**
//! beyond those already used for first derivatives — only fast products
//! with first/second kernel derivatives.
//!
//! For independent probes z, w with q = K̃^{-1}z, h = K̃^{-1}w:
//!   ∂²/∂θi∂θj log|K̃| = E[ q^T (∂²K̃) z − (q^T ∂iK̃ w)(h^T ∂jK̃ z) ]
//!
//! Second kernel-derivative MVMs are obtained by central finite differences
//! of `apply_grad` (exact analytic ∂²K̃ is plumbed where available).

use super::probes::{ProbeKind, ProbeSet};
use super::slq::slq_solves;
use crate::error::Result;
use crate::operators::KernelOp;
use crate::solvers::{build_preconditioner, pcg_block, CgOptions, Preconditioner};
use crate::util::stats::dot;

/// How the probe solves `q = K̃^{-1} z` are produced.
#[derive(Clone, Copy, Debug)]
pub enum HessianSolves {
    /// Re-use the truncated Lanczos run (`steps` MVMs per probe column) —
    /// the paper's §3.4 "no additional solves" default.
    Lanczos,
    /// High-accuracy solves through the block-CG engine: one lockstep
    /// block solve per probe set, iterating to the CG tolerance instead of
    /// a fixed Lanczos depth. Costs extra MVMs but removes the truncation
    /// bias on ill-conditioned operators. When the options' `precond`
    /// knob has a nonzero rank, the solves run through PCG with a pivoted-
    /// Cholesky preconditioner built once per estimate.
    BlockCg(CgOptions),
}

/// Options for the stochastic Hessian estimator.
#[derive(Clone, Copy, Debug)]
pub struct HessianOptions {
    pub steps: usize,
    pub probes: usize,
    pub seed: u64,
    /// Worker threads for the probe-block solves: the Lanczos backend fans
    /// probe blocks over `util::parallel` directly, and the BlockCg backend
    /// additionally honors its own `CgOptions::threads` for the RHS-group
    /// fan-out. Defaults to the process default (CLI `--threads`).
    pub threads: usize,
    /// FD step for second kernel derivatives.
    pub fd_eps: f64,
    /// Backend for the probe solves.
    pub solves: HessianSolves,
}

impl Default for HessianOptions {
    fn default() -> Self {
        HessianOptions {
            steps: 30,
            probes: 10,
            seed: 0,
            threads: crate::util::parallel::default_threads(),
            fd_eps: 1e-4,
            solves: HessianSolves::Lanczos,
        }
    }
}

/// `y = (∂²K̃/∂θi∂θj) x` by central differences of the first derivative MVM.
fn apply_grad2_fd(
    op: &mut dyn KernelOp,
    i: usize,
    j: usize,
    x: &[f64],
    eps: f64,
) -> Vec<f64> {
    let h0 = op.hypers();
    let n = op.n();
    let mut hp = h0.clone();
    hp[j] += eps;
    op.set_hypers(&hp);
    let mut up = vec![0.0; n];
    op.apply_grad(i, x, &mut up);
    hp[j] -= 2.0 * eps;
    op.set_hypers(&hp);
    let mut dn = vec![0.0; n];
    op.apply_grad(i, x, &mut dn);
    op.set_hypers(&h0);
    for t in 0..n {
        up[t] = (up[t] - dn[t]) / (2.0 * eps);
    }
    up
}

/// Hessian estimate with a-posteriori per-entry standard errors (the
/// product-of-bilinear-forms term has much higher variance than the
/// first-derivative estimators — callers should consult `std_err`).
pub struct HessianEstimate {
    pub mean: Vec<Vec<f64>>,
    pub std_err: Vec<Vec<f64>>,
    /// Probe *pairs* consumed (each entry's sample count) — the budget
    /// accounting the confidence refactor threads through every stochastic
    /// estimator surface.
    pub probes_used: usize,
}

/// Stochastic estimate of the Hessian of `log|K̃|` w.r.t. all hypers.
///
/// All first-derivative work runs blocked: the probe pairs are drawn as two
/// `n x p` matrices, the Lanczos solves go through the block driver inside
/// [`slq_solves`], and `∂iK̃ Z` / `∂iK̃ W` are computed as whole-probe-set
/// blocks by `apply_grad_all_mat` (one pass over kernel entries per set
/// instead of one per probe). Only the FD second-derivative MVMs stay
/// per-probe — they mutate the operator's hypers.
pub fn logdet_hessian(op: &mut dyn KernelOp, opts: &HessianOptions) -> Result<HessianEstimate> {
    let n = op.n();
    let nh = op.num_hypers();
    // Independent probe pairs: z_p and w_p.
    let zs = ProbeSet::new(n, opts.probes, ProbeKind::Rademacher, opts.seed);
    let ws = ProbeSet::new(n, opts.probes, ProbeKind::Rademacher, opts.seed ^ 0x9E3779B97F4A7C15);
    // One pivoted-Cholesky preconditioner per estimate, shared by both
    // probe-set solves (only the BlockCg backend uses it).
    let pc = match opts.solves {
        HessianSolves::BlockCg(cg_opts) => build_preconditioner(&*op, cg_opts.precond),
        HessianSolves::Lanczos => None,
    };
    // Probe solves: either the free Lanczos byproduct (§3.2) or the
    // block-(P)CG engine when the caller wants solves at CG accuracy.
    let solve_set = |ps: &ProbeSet| -> Vec<Vec<f64>> {
        match opts.solves {
            HessianSolves::Lanczos => slq_solves(&*op, ps, opts.steps, opts.threads),
            HessianSolves::BlockCg(cg_opts) => {
                let pcd = pc.as_ref().map(|p| p as &dyn Preconditioner);
                let (x, info) = pcg_block(&*op, &ps.as_mat(), None, pcd, &cg_opts);
                if !info.all_converged() {
                    let bad = info.cols.iter().filter(|c| !c.converged).count();
                    eprintln!(
                        "logdet_hessian: {bad}/{} probe solves did not converge \
                         (worst residual {:.3e}); Hessian estimate may be biased",
                        info.cols.len(),
                        info.worst_residual()
                    );
                }
                (0..x.cols).map(|j| x.col(j)).collect()
            }
        }
    };
    let qs = solve_set(&zs); // q = K^-1 z
    let hs = solve_set(&ws); // h = K^-1 w

    // Blocked first-derivative MVMs over the whole probe sets:
    // dkz[i] column p = ∂iK z_p ; dkw[i] column p = ∂iK w_p.
    let zmat = zs.as_mat();
    let wmat = ws.as_mat();
    let dkz = op.apply_grad_all_mat(&zmat);
    let dkw = op.apply_grad_all_mat(&wmat);

    let mut mean = vec![vec![0.0; nh]; nh];
    let mut std_err = vec![vec![0.0; nh]; nh];
    for i in 0..nh {
        for j in i..nh {
            let mut samples = Vec::with_capacity(opts.probes);
            for p in 0..opts.probes {
                // First term: q^T (∂²K) z.
                let d2kz = apply_grad2_fd(op, i, j, &zs.z[p], opts.fd_eps);
                let t1 = dot(&qs[p], &d2kz);
                // Second term: (q^T ∂iK w)(h^T ∂jK z).
                let t2 = dkw[i].col_dot(p, &qs[p]) * dkz[j].col_dot(p, &hs[p]);
                samples.push(t1 - t2);
            }
            let v = crate::util::stats::mean(&samples);
            let se = crate::util::stats::std_err(&samples);
            mean[i][j] = v;
            mean[j][i] = v;
            std_err[i][j] = se;
            std_err[j][i] = se;
        }
    }
    Ok(HessianEstimate { mean, std_err, probes_used: opts.probes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::DenseKernelOp;
    use crate::util::rng::Rng;

    /// Exact Hessian of log|K̃| by finite differences of the exact gradient.
    fn exact_hessian(op: &mut DenseKernelOp) -> Vec<Vec<f64>> {
        let nh = op.num_hypers();
        let h0 = op.hypers();
        let eps = 1e-5;
        let mut hess = vec![vec![0.0; nh]; nh];
        for j in 0..nh {
            let mut hp = h0.clone();
            hp[j] += eps;
            op.set_hypers(&hp);
            let (_, gu) = crate::estimators::exact::exact_logdet_grads_dense(op).unwrap();
            hp[j] -= 2.0 * eps;
            op.set_hypers(&hp);
            let (_, gd) = crate::estimators::exact::exact_logdet_grads_dense(op).unwrap();
            for i in 0..nh {
                hess[i][j] = (gu[i] - gd[i]) / (2.0 * eps);
            }
        }
        op.set_hypers(&h0);
        hess
    }

    #[test]
    fn stochastic_hessian_tracks_exact() {
        let mut rng = Rng::new(23);
        let pts: Vec<Vec<f64>> =
            (0..60).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let mut op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.6, 1.0)),
            0.4,
        );
        let truth = exact_hessian(&mut op);
        let est = logdet_hessian(
            &mut op,
            &HessianOptions { steps: 50, probes: 300, seed: 3, ..Default::default() },
        )
        .unwrap();
        for i in 0..3 {
            for j in 0..3 {
                let scale = truth[i][j].abs().max(1.0);
                // Statistically principled check: within 6 standard errors
                // plus a small absolute slack for the FD second derivative.
                assert!(
                    (est.mean[i][j] - truth[i][j]).abs()
                        < 6.0 * est.std_err[i][j] + 0.05 * scale,
                    "({i},{j}): {} vs {} (se {})",
                    est.mean[i][j],
                    truth[i][j],
                    est.std_err[i][j]
                );
            }
        }
    }

    #[test]
    fn block_cg_solves_track_exact_too() {
        // The block-CG backend replaces the truncated-Lanczos probe solves
        // with solves at CG accuracy; the estimate must still track the
        // exact Hessian.
        let mut rng = Rng::new(31);
        let pts: Vec<Vec<f64>> =
            (0..50).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let mut op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.6, 1.0)),
            0.4,
        );
        let truth = exact_hessian(&mut op);
        let est = logdet_hessian(
            &mut op,
            &HessianOptions {
                steps: 40,
                probes: 200,
                seed: 7,
                solves: HessianSolves::BlockCg(crate::solvers::CgOptions::new(1e-10, 400)),
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..3 {
            for j in 0..3 {
                let scale = truth[i][j].abs().max(1.0);
                assert!(
                    (est.mean[i][j] - truth[i][j]).abs()
                        < 6.0 * est.std_err[i][j] + 0.05 * scale,
                    "({i},{j}): {} vs {} (se {})",
                    est.mean[i][j],
                    truth[i][j],
                    est.std_err[i][j]
                );
            }
        }
    }

    /// PCG-backed probe solves (precond rank > 0) leave the estimator
    /// tracking the exact Hessian, like the unpreconditioned backend.
    #[test]
    fn preconditioned_block_cg_solves_track_exact() {
        let mut rng = Rng::new(43);
        let pts: Vec<Vec<f64>> =
            (0..40).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let mut op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.6, 1.0)),
            0.4,
        );
        let truth = exact_hessian(&mut op);
        let mut cg_opts = crate::solvers::CgOptions::new(1e-10, 400);
        cg_opts.precond = crate::solvers::PrecondOptions::rank(12);
        let est = logdet_hessian(
            &mut op,
            &HessianOptions {
                steps: 40,
                probes: 150,
                seed: 11,
                solves: HessianSolves::BlockCg(cg_opts),
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..3 {
            for j in 0..3 {
                let scale = truth[i][j].abs().max(1.0);
                assert!(
                    (est.mean[i][j] - truth[i][j]).abs()
                        < 6.0 * est.std_err[i][j] + 0.06 * scale,
                    "({i},{j}): {} vs {} (se {})",
                    est.mean[i][j],
                    truth[i][j],
                    est.std_err[i][j]
                );
            }
        }
    }

    #[test]
    fn hessian_is_symmetric() {
        let mut rng = Rng::new(29);
        let pts: Vec<Vec<f64>> =
            (0..30).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
        let mut op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Matern32, 1, 0.5, 0.8)),
            0.3,
        );
        let est = logdet_hessian(
            &mut op,
            &HessianOptions { steps: 20, probes: 6, seed: 1, ..Default::default() },
        )
        .unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(est.mean[i][j], est.mean[j][i]);
            }
        }
    }
}
