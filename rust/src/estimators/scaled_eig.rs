//! Scaled-eigenvalue baseline (paper Appendix B.1, from Wilson et al. 2014)
//! and its Fiedler-bound extension for non-Gaussian likelihoods (Flaxman et
//! al. 2015, used in the paper's §5.3/§5.4 comparisons).
//!
//! `log|K_XX + σ² I| ≈ sum_{i=1}^n log((n/m) λ̃_i + σ²)` where `λ̃_i` are the
//! largest eigenvalues of `K_UU`. This *requires a fast eigendecomposition*
//! of `K_UU` — available for Kronecker/Toeplitz grids (at O(sum_j m_j^3)
//! dense-factor cost), but NOT for diagonal corrections, additive kernels,
//! or the Laplace B matrices; those are exactly the cases the paper's
//! MVM-only estimators unlock.

use super::LogdetEstimate;
use crate::error::Result;
use crate::operators::ski::{KronKernelOp, SkiOp};
use crate::operators::{KernelOp, LinOp};

/// Top-n eigenvalues (descending) of the scaled K_UU spectrum.
fn top_n_desc(mut eigs: Vec<f64>, n: usize) -> Vec<f64> {
    eigs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    eigs.truncate(n);
    // If the grid is smaller than the data (m < n), pad with zeros: the
    // approximate kernel has rank <= m.
    while eigs.len() < n {
        eigs.push(0.0);
    }
    eigs
}

/// Scaled-eigenvalue log determinant for a SKI operator.
///
/// Fails (by construction, like the real method) when a diagonal correction
/// is active — the correction destroys the eigenvalue relationship (§3.3).
pub fn scaled_eig_logdet_ski(op: &SkiOp) -> Result<f64> {
    if op.diag_correction {
        return Err(crate::error::Error::Config(
            "scaled-eigenvalue method cannot handle diagonal corrections (paper §3.3)".into(),
        ));
    }
    let n = op.n() as f64;
    let m = op.m() as f64;
    let eigs = op.kuu().all_eigvals()?;
    let s2 = op.noise_var();
    let top = top_n_desc(eigs, op.n());
    Ok(top
        .iter()
        .map(|&lam| ((n / m) * lam.max(0.0) + s2).ln())
        .sum())
}

/// Scaled-eigenvalue log determinant for a grid kernel operator (n = m).
pub fn scaled_eig_logdet_kron(op: &KronKernelOp) -> Result<f64> {
    let eigs = op.kuu().all_eigvals()?;
    let s2 = op.noise_var();
    Ok(eigs.iter().map(|&lam| (lam.max(0.0) + s2).ln()).sum())
}

/// Scaled-eigenvalue estimate with gradients by central finite differences
/// (each probe re-eigendecomposes — this is the O(m^3)-ish cost profile the
/// paper's Fig. 1 measures for this baseline).
pub fn scaled_eig_estimate_ski(op: &mut SkiOp, grads: bool) -> Result<LogdetEstimate> {
    let value = scaled_eig_logdet_ski(op)?;
    let mut grad = Vec::new();
    if grads {
        let h0 = op.hypers();
        let eps = 1e-5;
        grad = vec![0.0; h0.len()];
        for i in 0..h0.len() {
            let mut hp = h0.clone();
            hp[i] += eps;
            op.set_hypers(&hp);
            let up = scaled_eig_logdet_ski(op)?;
            hp[i] -= 2.0 * eps;
            op.set_hypers(&hp);
            let dn = scaled_eig_logdet_ski(op)?;
            grad[i] = (up - dn) / (2.0 * eps);
        }
        op.set_hypers(&h0);
    }
    Ok(LogdetEstimate::exact(value, grad))
}

/// Fiedler-bound approximation of `log|I + K W|` for diagonal `W >= 0`
/// (the scaled-eigenvalue route to non-Gaussian likelihoods):
/// pair the descending eigenvalues of K with the descending entries of W,
/// `log|I + K W| ≈ sum_i log(1 + λ_i w_(i))`.
///
/// This becomes increasingly misspecified as the likelihood curvature W
/// departs from constant — which is exactly what Table 2/3 of the paper
/// exhibit (scaled-eig recovers distorted hypers on non-Gaussian data).
pub fn fiedler_logdet_b(k_eigs: &[f64], w_diag: &[f64]) -> f64 {
    let mut lam: Vec<f64> = k_eigs.to_vec();
    lam.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut w: Vec<f64> = w_diag.to_vec();
    w.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let n = w.len().min(lam.len());
    (0..n)
        .map(|i| (1.0 + lam[i].max(0.0) * w[i].max(0.0)).ln())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimators::exact::exact_logdet;
    use crate::grid::{Grid, GridDim, InterpOrder};
    use crate::kernels::{SeparableKernel, Shape};
    use crate::linalg::dense::Mat;
    use crate::linalg::eigh::eigh;
    use crate::util::rng::Rng;

    #[test]
    fn kron_version_is_exact_on_grid_data() {
        // With data ON the grid and no interpolation error, the scaled
        // eigenvalue method with n = m is exact.
        let kern = SeparableKernel::iso(Shape::Rbf, 2, 0.5, 1.0);
        let grid = Grid::new(vec![
            GridDim { lo: 0.0, hi: 1.0, m: 5 },
            GridDim { lo: 0.0, hi: 1.0, m: 4 },
        ]);
        let op = KronKernelOp::new(grid, kern, 0.2);
        let got = scaled_eig_logdet_kron(&op).unwrap();
        let truth = exact_logdet(&op).unwrap();
        assert!((got - truth).abs() < 1e-7, "{got} vs {truth}");
    }

    #[test]
    fn ski_version_approximates_exact() {
        let mut rng = Rng::new(3);
        let pts: Vec<Vec<f64>> =
            (0..60).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        let kern = SeparableKernel::iso(Shape::Rbf, 1, 0.6, 1.0);
        let grid = Grid::new(vec![GridDim { lo: -0.2, hi: 4.2, m: 150 }]);
        let ski = SkiOp::new(&pts, grid, kern, 0.3, InterpOrder::Cubic, false);
        let got = scaled_eig_logdet_ski(&ski).unwrap();
        let truth = exact_logdet(&ski).unwrap();
        // Approximate method: generous tolerance, but same ballpark.
        assert!(
            (got - truth).abs() < 0.1 * truth.abs().max(1.0) + 2.0,
            "{got} vs {truth}"
        );
    }

    #[test]
    fn rejects_diag_correction() {
        let mut rng = Rng::new(4);
        let pts: Vec<Vec<f64>> =
            (0..20).map(|_| vec![rng.uniform_in(0.0, 1.0)]).collect();
        let kern = SeparableKernel::iso(Shape::Matern12, 1, 0.3, 1.0);
        let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 1.1, m: 16 }]);
        let ski = SkiOp::new(&pts, grid, kern, 0.1, InterpOrder::Cubic, true);
        assert!(scaled_eig_logdet_ski(&ski).is_err());
    }

    #[test]
    fn fiedler_exact_for_constant_w() {
        // W = c I: log|I + c K| = sum log(1 + c λ_i) exactly.
        let mut rng = Rng::new(5);
        let mut b = Mat::from_fn(10, 10, |_, _| rng.gaussian());
        let mut k = b.matmul(&b.transpose());
        k.scale(0.1);
        b = k.clone();
        let eigs = eigh(&b).unwrap().eigvals;
        let c = 0.7;
        let w = vec![c; 10];
        let got = fiedler_logdet_b(&eigs, &w);
        let want: f64 = eigs.iter().map(|&l| (1.0 + c * l.max(0.0)).ln()).sum();
        assert!((got - want).abs() < 1e-10);
    }

    #[test]
    fn fiedler_biased_for_heterogeneous_w() {
        // Non-constant W: the pairing is only an approximation — verify it
        // deviates from the true log|I + K W| (the model misspecification
        // the paper reports for non-Gaussian likelihoods).
        let mut rng = Rng::new(6);
        let mut b = Mat::from_fn(12, 12, |_, _| rng.gaussian());
        let mut k = b.matmul(&b.transpose());
        k.scale(0.2);
        let eigs = eigh(&k).unwrap().eigvals;
        let w: Vec<f64> = (0..12).map(|i| 0.05 + (i as f64) * 0.3).collect();
        // True value: log|I + K W| via LU determinant.
        let mut ikw = Mat::zeros(12, 12);
        for i in 0..12 {
            for j in 0..12 {
                ikw[(i, j)] = k[(i, j)] * w[j] + if i == j { 1.0 } else { 0.0 };
            }
        }
        let truth = crate::linalg::lu::Lu::new(&ikw).unwrap().det().ln();
        let approx = fiedler_logdet_b(&eigs, &w);
        assert!((approx - truth).abs() > 1e-3, "expected visible bias");
        b = ikw; // silence
        let _ = b;
    }
}
