//! Log-determinant (and derivative) estimators — the paper's contribution.
//!
//! All of these consume a [`crate::operators::KernelOp`] *only* through
//! MVMs:
//!
//! * [`slq`] — stochastic Lanczos quadrature (§3.2), the recommended method;
//! * [`chebyshev`] — stochastic Chebyshev expansion (§3.1);
//! * [`surrogate`] — RBF interpolation of the log determinant over
//!   hyperparameter space (§3.5);
//! * [`scaled_eig`] — the scaled-eigenvalue baseline (Appendix B.1), which
//!   needs fast *eigendecompositions* and is what the paper improves on;
//! * [`exact`] — O(n^3) Cholesky ground truth;
//! * [`hessian`] — second-derivative estimators (§3.4).
//!
//! # Block-probe drivers
//!
//! The stochastic estimators average over independent probe vectors
//! (Hutchinson, §3). They draw the whole probe set as one `n x p`
//! [`crate::linalg::dense::Mat`] ([`probes::ProbeSet::as_mat`]), slice it
//! into `n x b` blocks (`block_size` in [`slq::SlqOptions`] /
//! [`chebyshev::ChebOptions`], default [`default_block_size`]), and drive
//! the operator through the blocked MVM entry points
//! (`apply_mat` / `apply_grad_all_mat` — see `operators` module docs for
//! the contract). The per-probe tridiagonal/Chebyshev recurrences are kept
//! mathematically identical to the single-vector path, so estimates are
//! **bit-identical for every block size** — blocking changes only how many
//! columns each pass over the operator's structure amortizes.
//!
//! ## Preconditioned SLQ
//!
//! On ill-conditioned `K̃` (small σ), Lanczos needs many steps to resolve
//! the quadrature near the spectrum's low end. [`slq::slq_logdet_pc`]
//! accepts a [`crate::solvers::Preconditioner`] `P ≈ K̃` (rank-k pivoted
//! Cholesky + noise, built by `solvers::build_preconditioner`) and uses
//! the exact identity
//!
//! ```text
//! log|K̃| = log|P| + tr log(P^{-1/2} K̃ P^{-1/2})
//! ```
//!
//! so the stochastic part only sees the *flattened* spectrum of the
//! symmetric split `M = P^{-1/2} K̃ P^{-1/2}` (applied through the
//! preconditioner's low-rank factor; each `M` apply costs exactly one
//! `K̃` MVM). `log|P|` is closed-form and exact, so the correction adds no
//! stochastic error. Derivatives use
//! `tr(K̃⁻¹ ∂K̃) = E[(P^{-1/2} M⁻¹ z)ᵀ ∂K̃ (P^{-1/2} z)]`, with `M⁻¹ z`
//! the free Lanczos byproduct. The identity holds for any *fixed* SPD `P`,
//! so the estimate stays unbiased even though `P` was built at the current
//! hypers. With `pc = None` (or `--precond-rank 0`) the preconditioned
//! entry points are bit-identical to the plain ones.
//!
//! ## MVM accounting
//!
//! [`LogdetEstimate`] reports cost in two units:
//! * `mvms` — probe-column MVMs (what the b=1 path would count): the
//!   resolution-independent number used in the paper's cost figures;
//! * `block_applies` — block-amortized MVM count: one per `apply_mat`
//!   call plus one **per hyper** per derivative pass. It divides the
//!   per-column count by the block width; it does *not* model further
//!   fusion inside an operator (`DenseKernelOp::apply_grad_all_mat`
//!   computes all hypers in a single sweep but still counts `nh`).
//!   At `block_size = 1` the two units coincide.
//!
//! The solver layer reports cost in the same two units
//! (`solvers::BlockCgInfo::{mvms, block_applies}`) so solve and logdet
//! budgets are directly comparable.

pub mod chebyshev;
pub mod exact;
pub mod hessian;
pub mod lanczos;
pub mod probes;
pub mod scaled_eig;
pub mod slq;
pub mod surrogate;

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide default probe-block width used by `SlqOptions::default` /
/// `ChebOptions::default` and the helpers without an options struct
/// (`slq_trace_fn`, `slq_solves`). The coordinator CLI's `--block` flag
/// threads through here.
static DEFAULT_BLOCK_SIZE: AtomicUsize = AtomicUsize::new(8);

/// Set the process-wide default probe-block width (clamped to >= 1).
pub fn set_default_block_size(b: usize) {
    DEFAULT_BLOCK_SIZE.store(b.max(1), Ordering::Relaxed);
}

/// Current process-wide default probe-block width.
pub fn default_block_size() -> usize {
    DEFAULT_BLOCK_SIZE.load(Ordering::Relaxed)
}

/// Probe-column partitioning — shared with the block-CG solver so probe
/// sets and right-hand-side sets slice identically
/// ([`crate::util::blocks::BlockPartition`]).
pub(crate) use crate::util::blocks::BlockPartition;

/// A stochastic estimate of `log|K̃|` and its hyper-derivatives.
#[derive(Clone, Debug)]
pub struct LogdetEstimate {
    /// Estimated log determinant.
    pub value: f64,
    /// d log|K̃| / d θ_i for every hyper (empty if gradients not requested).
    pub grad: Vec<f64>,
    /// A-posteriori standard error of `value` across probes (paper §4).
    pub std_err: f64,
    /// Per-probe values of z^T log(K̃) z (for diagnostics/tests).
    pub per_probe: Vec<f64>,
    /// Total probe-column MVM count consumed (cost accounting for the
    /// figures; independent of `block_size`).
    pub mvms: usize,
    /// Block-amortized MVM count: one per block apply, one per hyper per
    /// derivative pass (in-operator fusion across hypers not modeled).
    /// Equals `mvms` at `block_size = 1`.
    pub block_applies: usize,
}

impl LogdetEstimate {
    pub fn exact(value: f64, grad: Vec<f64>) -> Self {
        LogdetEstimate {
            value,
            grad,
            std_err: 0.0,
            per_probe: vec![value],
            mvms: 0,
            block_applies: 0,
        }
    }
}
