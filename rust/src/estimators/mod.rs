//! Log-determinant (and derivative) estimators — the paper's contribution.
//!
//! All of these consume a [`crate::operators::KernelOp`] *only* through
//! MVMs:
//!
//! * [`slq`] — stochastic Lanczos quadrature (§3.2), the recommended method;
//! * [`chebyshev`] — stochastic Chebyshev expansion (§3.1);
//! * [`surrogate`] — RBF interpolation of the log determinant over
//!   hyperparameter space (§3.5);
//! * [`scaled_eig`] — the scaled-eigenvalue baseline (Appendix B.1), which
//!   needs fast *eigendecompositions* and is what the paper improves on;
//! * [`exact`] — O(n^3) Cholesky ground truth;
//! * [`hessian`] — second-derivative estimators (§3.4).
//!
//! # Block-probe drivers
//!
//! The stochastic estimators average over independent probe vectors
//! (Hutchinson, §3). They draw the whole probe set as one `n x p`
//! [`crate::linalg::dense::Mat`] ([`probes::ProbeSet::as_mat`]), slice it
//! into `n x b` blocks (`block_size` in [`slq::SlqOptions`] /
//! [`chebyshev::ChebOptions`], default [`default_block_size`]), and drive
//! the operator through the blocked MVM entry points
//! (`apply_mat` / `apply_grad_all_mat` — see `operators` module docs for
//! the contract). The per-probe tridiagonal/Chebyshev recurrences are kept
//! mathematically identical to the single-vector path, so estimates are
//! **bit-identical for every block size** — blocking changes only how many
//! columns each pass over the operator's structure amortizes.
//!
//! ## Preconditioned SLQ
//!
//! On ill-conditioned `K̃` (small σ), Lanczos needs many steps to resolve
//! the quadrature near the spectrum's low end. [`slq::slq_logdet_pc`]
//! accepts a [`crate::solvers::Preconditioner`] `P ≈ K̃` (rank-k pivoted
//! Cholesky + noise, built by `solvers::build_preconditioner`) and uses
//! the exact identity
//!
//! ```text
//! log|K̃| = log|P| + tr log(P^{-1/2} K̃ P^{-1/2})
//! ```
//!
//! so the stochastic part only sees the *flattened* spectrum of the
//! symmetric split `M = P^{-1/2} K̃ P^{-1/2}` (applied through the
//! preconditioner's low-rank factor; each `M` apply costs exactly one
//! `K̃` MVM). `log|P|` is closed-form and exact, so the correction adds no
//! stochastic error. Derivatives use
//! `tr(K̃⁻¹ ∂K̃) = E[(P^{-1/2} M⁻¹ z)ᵀ ∂K̃ (P^{-1/2} z)]`, with `M⁻¹ z`
//! the free Lanczos byproduct. The identity holds for any *fixed* SPD `P`,
//! so the estimate stays unbiased even though `P` was built at the current
//! hypers. With `pc = None` (or `--precond-rank 0`) the preconditioned
//! entry points are bit-identical to the plain ones.
//!
//! ## MVM accounting
//!
//! [`LogdetEstimate`] reports cost in two units:
//! * `mvms` — probe-column MVMs (what the b=1 path would count): the
//!   resolution-independent number used in the paper's cost figures;
//! * `block_applies` — block-amortized MVM count: one per `apply_mat`
//!   call plus one **per hyper** per derivative pass. It divides the
//!   per-column count by the block width; it does *not* model further
//!   fusion inside an operator (`DenseKernelOp::apply_grad_all_mat`
//!   computes all hypers in a single sweep but still counts `nh`).
//!   At `block_size = 1` the two units coincide.
//!
//! The solver layer reports cost in the same two units
//! (`solvers::BlockCgInfo::{mvms, block_applies}`) so solve and logdet
//! budgets are directly comparable.
//!
//! # Spectral evidence and confidence
//!
//! Every stochastic estimate *retains* the per-probe spectral evidence it
//! was computed from instead of discarding it ([`SpectralEvidence`] inside
//! [`LogdetEstimate`]): SLQ keeps each probe's Lanczos tridiagonal
//! `(alphas, betas, ||z||²)`, Chebyshev keeps each probe's moment vector
//! `z^T T_j(B) z` together with the coefficient vector and spectrum
//! bracket. The deterministic estimators (`exact`, `scaled_eig`,
//! `surrogate`) return [`SpectralEvidence::Exact`] so the API is total.
//!
//! [`confidence`] turns the retained evidence into a moment-matched
//! posterior interval over `log|K̃|` ([`confidence::ConfidenceInterval`],
//! populated in `LogdetEstimate::interval`) at near-zero extra MVM cost:
//! the cross-probe spread gives a Student-t Monte-Carlo term, and the
//! evidence gives a quadrature/expansion truncation term (last-step Gauss
//! quadrature movement for Lanczos, coefficient tail decay for Chebyshev).
//! A single-probe estimate has an *infinite* interval by construction
//! (`util::stats::std_err` of one sample is `+inf`), so no stopping rule
//! can act on it.
//!
//! # Resumable sessions and two-axis adaptive budgets
//!
//! The recurrences themselves are **resumable**: [`lanczos::LanczosSession`]
//! retains, per probe column, the tridiagonal prefix, the orthonormal basis,
//! and the budget-stop residual, so `extend(steps)` continues the three-term
//! recurrence *bit-identically* to a from-scratch run at the larger step
//! count; [`chebyshev::ChebSession`] retains the last two Chebyshev iterates
//! plus the raw (unweighted) moments and derivative dots, so
//! `extend(degree)` continues the expansion on the fixed bracket and the
//! coefficient weighting is deferred to assembly. `lanczos_block[_prec]`
//! and the fixed Chebyshev path are thin drivers over these sessions —
//! one `new` + `extend(budget)` — so the invariant holds everywhere by
//! construction and is proptest-pinned across operators, block sizes,
//! thread counts, and precisions.
//!
//! The interval drives **two-axis adaptive budgets**: when
//! `SlqOptions::target_tol` / `ChebOptions::target_tol` is `Some(tol)`,
//! the driver grows the probe set incrementally (probe `j` is the same
//! vector at every budget, so earlier work is never redrawn) *and* deepens
//! the retained sessions. After each chunk it splits the interval
//! half-width into its Monte-Carlo and truncation components
//! ([`confidence::half_width_parts`]) and grows whichever axis dominates:
//! new probes when the Student-t term does, `extend()` on every retained
//! session when the truncation term does. It stops as soon as the 95%
//! half-width clears `tol` (never before 2 probes, never past
//! `max_probes`; the step axis is capped at `max_steps` when set, at
//! `2 × steps` when `max_steps = 0`, and `max_steps == steps` disables
//! step growth — the probes-only driver of PR 6). The final estimate is
//! bit-identical to a fixed-budget run at `(probes_used, steps_used)`,
//! and its evidence carries **resume handles** (the live sessions) so a
//! caller can keep extending where the driver stopped. With
//! `target_tol = None` the fixed-budget path is **bit-identical** to the
//! pre-evidence estimators: same probe set, same block partition, same
//! accumulation order — the evidence is recorded on the side and
//! `probes_used`/`steps_used` simply report the fixed budget.
//!
//! # Trace span sites ([`crate::util::obs`])
//!
//! With `--trace` the estimators contribute these spans (inert and
//! bitwise invisible when tracing is off — proptest-pinned by
//! `prop_tracing_enabled_bitwise_inert`):
//!
//! * `slq` — one per [`slq::slq_logdet`] / [`slq::slq_logdet_pc`] call;
//!   wraps the whole estimate in an accounting **audit window** asserting
//!   the traced `Mvms`/`BlockApplies` counters equal
//!   `LogdetEstimate::{mvms, block_applies}` exactly.
//! * `slq_probe_chunk` — one per probe block (fixed path) or per adaptive
//!   chunk; `slq_step_extend` — deepening retained Lanczos sessions on
//!   the step axis; `lanczos_extend` — the underlying per-session
//!   tridiagonal extension.
//! * `slq_trace` — the §3.4 trace estimator entry.
//! * `cheb` — one per [`chebyshev::chebyshev_logdet`] call (same audit
//!   contract as `slq`); `cheb_bracket` — the `lambda_bounds: None`
//!   spectrum bracket, whose helper MVMs are *timed* but
//!   counter-suppressed ([`crate::util::obs::suppress_applies`]) because
//!   they are outside the estimate's accounting; `cheb_probe_chunk` /
//!   `cheb_degree_extend` / `cheb_extend` — probe blocks and degree
//!   deepening.
//! * Beneath all of these, every operator apply opens its
//!   [`crate::util::obs::apply_site`] span (`LinOp::obs_kind`, e.g.
//!   `dense_kernel`, `ski`, `toeplitz`) and charges the
//!   `Mvms`/`BlockApplies` counters — so the
//!   span tree's per-path rollups decompose an estimate's cost by
//!   operator structure.
//!
//! The [`slq::SlqOptions::probes`]/steps actually consumed are also
//! counted globally (`Counter::Probes`, `Counter::Steps`), once per
//! estimator call, so a run-level profile reports total probe budget
//! spent without walking the tree.

pub mod chebyshev;
pub mod confidence;
pub mod exact;
pub mod hessian;
pub mod lanczos;
pub mod probes;
pub mod scaled_eig;
pub mod slq;
pub mod surrogate;

pub use confidence::ConfidenceInterval;

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide default probe-block width used by `SlqOptions::default` /
/// `ChebOptions::default` and the helpers without an options struct
/// (`slq_trace_fn`, `slq_solves`). The coordinator CLI's `--block` flag
/// threads through here.
static DEFAULT_BLOCK_SIZE: AtomicUsize = AtomicUsize::new(8);

/// Set the process-wide default probe-block width (clamped to >= 1).
pub fn set_default_block_size(b: usize) {
    DEFAULT_BLOCK_SIZE.store(b.max(1), Ordering::Relaxed);
}

/// Current process-wide default probe-block width.
pub fn default_block_size() -> usize {
    DEFAULT_BLOCK_SIZE.load(Ordering::Relaxed)
}

/// Process-wide default probe count (0 = unset: `SlqOptions`/`ChebOptions`
/// fall back to their built-in default of 5). The CLI `--probes` flag
/// threads through here.
static DEFAULT_PROBES: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide default probe count (0 restores the built-in).
pub fn set_default_probes(p: usize) {
    DEFAULT_PROBES.store(p, Ordering::Relaxed);
}

/// Current process-wide default probe count (`None` = built-in default).
pub fn default_probes() -> Option<usize> {
    match DEFAULT_PROBES.load(Ordering::Relaxed) {
        0 => None,
        p => Some(p),
    }
}

/// Process-wide default per-probe step budget (0 = unset: `SlqOptions`
/// falls back to its built-in 25 Lanczos steps, `ChebOptions` to its
/// built-in degree 100 — the CLI's `--steps` budget covers Lanczos steps
/// and Chebyshev degree alike).
static DEFAULT_STEPS: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide default per-probe step budget (0 restores the
/// built-ins).
pub fn set_default_steps(s: usize) {
    DEFAULT_STEPS.store(s, Ordering::Relaxed);
}

/// Current process-wide default per-probe step budget.
pub fn default_steps() -> Option<usize> {
    match DEFAULT_STEPS.load(Ordering::Relaxed) {
        0 => None,
        s => Some(s),
    }
}

/// Process-wide default adaptive logdet tolerance, stored as f64 bits
/// (0 bits = unset → fixed-budget estimation). The CLI `--logdet-tol`
/// flag threads through here; `SlqOptions::default`/`ChebOptions::default`
/// read it into `target_tol`.
static DEFAULT_LOGDET_TOL_BITS: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(0);

/// Set the process-wide default adaptive logdet tolerance (`None` or a
/// non-positive value unsets it — estimators then run fixed budgets).
pub fn set_default_logdet_tol(tol: Option<f64>) {
    let bits = match tol {
        Some(t) if t > 0.0 => t.to_bits(),
        _ => 0,
    };
    DEFAULT_LOGDET_TOL_BITS.store(bits, Ordering::Relaxed);
}

/// Current process-wide default adaptive logdet tolerance.
pub fn default_logdet_tol() -> Option<f64> {
    match DEFAULT_LOGDET_TOL_BITS.load(Ordering::Relaxed) {
        0 => None,
        bits => Some(f64::from_bits(bits)),
    }
}

/// Process-wide ceiling for the adaptive drivers' step/degree axis
/// (0 = auto: the axis may grow to `2 × steps`). The CLI `--max-steps`
/// flag threads through here; `SlqOptions::default`/`ChebOptions::default`
/// read it into `max_steps`. Fixed-budget runs (`target_tol = None`)
/// ignore it entirely.
static DEFAULT_MAX_STEPS: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide adaptive step/degree ceiling (0 restores auto).
pub fn set_default_max_steps(s: usize) {
    DEFAULT_MAX_STEPS.store(s, Ordering::Relaxed);
}

/// Current process-wide adaptive step/degree ceiling (0 = auto).
pub fn default_max_steps() -> usize {
    DEFAULT_MAX_STEPS.load(Ordering::Relaxed)
}

/// Probe-column partitioning — shared with the block-CG solver so probe
/// sets and right-hand-side sets slice identically
/// ([`crate::util::blocks::BlockPartition`]).
pub(crate) use crate::util::blocks::BlockPartition;

/// One probe's retained Lanczos evidence: the tridiagonal the quadrature
/// was read off, plus the probe's squared norm (the quadrature weight).
/// `alphas.len()` is the number of Lanczos steps that actually ran for
/// this probe (breakdown can stop a column early).
#[derive(Clone, Debug)]
pub struct LanczosProbe {
    /// Tridiagonal diagonal (length = steps run).
    pub alphas: Vec<f64>,
    /// Tridiagonal off-diagonal (length = steps run − 1).
    pub betas: Vec<f64>,
    /// `||z||²` — the total quadrature mass of this probe.
    pub znorm2: f64,
}

/// Per-probe spectral evidence retained by an estimator — the raw material
/// the [`confidence`] module turns into posterior intervals, kept instead
/// of being discarded after the point estimate is read off.
#[derive(Clone, Debug)]
pub enum SpectralEvidence {
    /// Deterministic estimate (exact Cholesky, scaled-eig, surrogate):
    /// no stochastic evidence exists; the interval is degenerate.
    Exact,
    /// Stochastic Lanczos quadrature: one tridiagonal per probe. `offset`
    /// is the exact constant folded into every per-probe value (the
    /// preconditioner's `log|P|` correction; 0 unpreconditioned).
    Lanczos {
        probes: Vec<LanczosProbe>,
        offset: f64,
        /// Resume handles: the live [`lanczos::LanczosSession`]s of an
        /// adaptive run, one per probe block in probe order — `extend`
        /// them to keep deepening where the driver stopped. `None` on
        /// fixed-budget paths (nothing to resume; keeps them lean).
        resume: Option<std::sync::Arc<Vec<lanczos::LanczosSession>>>,
    },
    /// Stochastic Chebyshev expansion: one moment vector
    /// `[z^T T_0(B) z, …, z^T T_d(B) z]` per probe, the shared coefficient
    /// vector `c_j` of `f` on the bracket, and the spectrum bracket
    /// `(a, b)` the operator was mapped to `[-1, 1]` from.
    Chebyshev {
        moments: Vec<Vec<f64>>,
        coeffs: Vec<f64>,
        bracket: (f64, f64),
        /// Resume handles: the live [`chebyshev::ChebSession`]s of an
        /// adaptive run, one per probe block in probe order. `None` on
        /// fixed-budget paths.
        resume: Option<std::sync::Arc<Vec<chebyshev::ChebSession>>>,
    },
}

impl SpectralEvidence {
    /// Number of probes the evidence covers (0 for `Exact`).
    pub fn probe_count(&self) -> usize {
        match self {
            SpectralEvidence::Exact => 0,
            SpectralEvidence::Lanczos { probes, .. } => probes.len(),
            SpectralEvidence::Chebyshev { moments, .. } => moments.len(),
        }
    }
}

/// A stochastic estimate of `log|K̃|` and its hyper-derivatives.
#[derive(Clone, Debug)]
pub struct LogdetEstimate {
    /// Estimated log determinant.
    pub value: f64,
    /// d log|K̃| / d θ_i for every hyper (empty if gradients not requested).
    pub grad: Vec<f64>,
    /// A-posteriori standard error of `value` across probes (paper §4).
    /// `+inf` when fewer than 2 probes ran (a single sample carries no
    /// spread information — see `util::stats::std_err`).
    pub std_err: f64,
    /// Per-probe values of z^T log(K̃) z (for diagnostics/tests).
    pub per_probe: Vec<f64>,
    /// Total probe-column MVM count consumed (cost accounting for the
    /// figures; independent of `block_size`).
    pub mvms: usize,
    /// Block-amortized MVM count: one per block apply, one per hyper per
    /// derivative pass (in-operator fusion across hypers not modeled).
    /// Equals `mvms` at `block_size = 1`.
    pub block_applies: usize,
    /// Retained per-probe spectral evidence (see module docs).
    pub evidence: SpectralEvidence,
    /// Moment-matched 95% posterior interval over `value` synthesized from
    /// the evidence ([`confidence::logdet_interval`]); degenerate
    /// (zero-width) for deterministic estimators.
    pub interval: ConfidenceInterval,
    /// Probes actually consumed (== `per_probe.len()` on stochastic paths;
    /// 0 for deterministic estimators).
    pub probes_used: usize,
    /// Per-probe budget actually used: the longest Lanczos tridiagonal /
    /// the Chebyshev degree (0 for deterministic estimators).
    pub steps_used: usize,
}

impl LogdetEstimate {
    pub fn exact(value: f64, grad: Vec<f64>) -> Self {
        LogdetEstimate {
            value,
            grad,
            std_err: 0.0,
            per_probe: vec![value],
            mvms: 0,
            block_applies: 0,
            evidence: SpectralEvidence::Exact,
            interval: ConfidenceInterval::exact(value),
            probes_used: 0,
            steps_used: 0,
        }
    }
}
