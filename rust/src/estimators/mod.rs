//! Log-determinant (and derivative) estimators — the paper's contribution.
//!
//! All of these consume a [`crate::operators::KernelOp`] *only* through
//! MVMs (`apply`, `apply_grad`):
//!
//! * [`slq`] — stochastic Lanczos quadrature (§3.2), the recommended method;
//! * [`chebyshev`] — stochastic Chebyshev expansion (§3.1);
//! * [`surrogate`] — RBF interpolation of the log determinant over
//!   hyperparameter space (§3.5);
//! * [`scaled_eig`] — the scaled-eigenvalue baseline (Appendix B.1), which
//!   needs fast *eigendecompositions* and is what the paper improves on;
//! * [`exact`] — O(n^3) Cholesky ground truth;
//! * [`hessian`] — second-derivative estimators (§3.4).

pub mod chebyshev;
pub mod exact;
pub mod hessian;
pub mod lanczos;
pub mod probes;
pub mod scaled_eig;
pub mod slq;
pub mod surrogate;

/// A stochastic estimate of `log|K̃|` and its hyper-derivatives.
#[derive(Clone, Debug)]
pub struct LogdetEstimate {
    /// Estimated log determinant.
    pub value: f64,
    /// d log|K̃| / d θ_i for every hyper (empty if gradients not requested).
    pub grad: Vec<f64>,
    /// A-posteriori standard error of `value` across probes (paper §4).
    pub std_err: f64,
    /// Per-probe values of z^T log(K̃) z (for diagnostics/tests).
    pub per_probe: Vec<f64>,
    /// Total MVM count consumed (cost accounting for the figures).
    pub mvms: usize,
}

impl LogdetEstimate {
    pub fn exact(value: f64, grad: Vec<f64>) -> Self {
        LogdetEstimate { value, grad, std_err: 0.0, per_probe: vec![value], mvms: 0 }
    }
}
