//! Lanczos tridiagonalization with full reorthogonalization (paper §3.2).
//!
//! `K̃ Q_m = Q_m T + beta_m q_{m+1} e_m^T` with orthonormal `Q_m`,
//! `q_1 = z / ||z||`. The paper notes plain Lanczos is numerically unstable
//! and cites practical fixes [33, 34]; at the small step counts used here
//! (m ≤ ~100) full reorthogonalization is the simplest sound remedy.

use crate::operators::LinOp;
use crate::util::rng::Rng;
use crate::util::stats::{axpy, dot, norm2};

/// Result of an m-step Lanczos run.
pub struct LanczosResult {
    /// Diagonal of T (length = steps actually taken).
    pub alphas: Vec<f64>,
    /// Off-diagonal of T (length = steps - 1).
    pub betas: Vec<f64>,
    /// Orthonormal Krylov basis, one vector per step.
    pub q: Vec<Vec<f64>>,
    /// ||z|| of the start vector.
    pub znorm: f64,
    /// MVMs consumed.
    pub mvms: usize,
}

impl LanczosResult {
    /// Solve `T t = e_1 ||z||` and map back: `g = Q t ≈ K̃^{-1} z` — the
    /// derivative estimator's solve, free given the decomposition (§3.2).
    pub fn solve_e1(&self) -> Vec<f64> {
        let n = self.q[0].len();
        let t = thomas_solve_e1(&self.alphas, &self.betas, self.znorm);
        let mut g = vec![0.0; n];
        for (k, qk) in self.q.iter().enumerate() {
            axpy(t[k], qk, &mut g);
        }
        g
    }
}

/// Thomas solve of the SPD tridiagonal system `T t = e_1 * rhs0`
/// (also used by the PJRT Lanczos artifact path to finish in f64).
pub fn thomas_solve_e1(alphas: &[f64], betas: &[f64], rhs0: f64) -> Vec<f64> {
    let m = alphas.len();
    let mut c = vec![0.0; m];
    let mut d = vec![0.0; m];
    for i in 0..m {
        let blo = if i > 0 { betas[i - 1] } else { 0.0 };
        let bup = if i + 1 < m { betas[i] } else { 0.0 };
        let denom = alphas[i] - blo * if i > 0 { c[i - 1] } else { 0.0 };
        c[i] = bup / denom;
        let rhs = if i == 0 { rhs0 } else { 0.0 };
        d[i] = (rhs - blo * if i > 0 { d[i - 1] } else { 0.0 }) / denom;
    }
    let mut t = vec![0.0; m];
    for i in (0..m).rev() {
        t[i] = d[i] - c[i] * if i + 1 < m { t[i + 1] } else { 0.0 };
    }
    t
}

/// Run `m` Lanczos steps on `op` starting from `z`.
pub fn lanczos(op: &dyn LinOp, z: &[f64], m: usize) -> LanczosResult {
    let n = op.n();
    assert_eq!(z.len(), n);
    let znorm = norm2(z);
    assert!(znorm > 0.0, "zero start vector");
    let mut q: Vec<Vec<f64>> = Vec::with_capacity(m);
    q.push(z.iter().map(|v| v / znorm).collect());
    let mut alphas = Vec::with_capacity(m);
    let mut betas = Vec::with_capacity(m.saturating_sub(1));
    let mut w = vec![0.0; n];
    let mut mvms = 0;
    for j in 0..m {
        op.apply(&q[j], &mut w);
        mvms += 1;
        let alpha = dot(&q[j], &w);
        alphas.push(alpha);
        axpy(-alpha, &q[j], &mut w);
        if j > 0 {
            let b: f64 = betas[j - 1];
            axpy(-b, &q[j - 1], &mut w);
        }
        // Full reorthogonalization. One modified-Gram-Schmidt pass, with a
        // second pass only when the first one removed a large component
        // ("twice is enough" — Parlett — but the second pass is usually a
        // no-op and costs O(n m) per step; §Perf opt 2).
        let before = norm2(&w);
        let mut removed = 0.0f64;
        for qk in q.iter() {
            let p = dot(qk, &w);
            if p != 0.0 {
                axpy(-p, qk, &mut w);
                removed = removed.max(p.abs());
            }
        }
        if removed > 0.5 * before {
            for qk in q.iter() {
                let p = dot(qk, &w);
                if p != 0.0 {
                    axpy(-p, qk, &mut w);
                }
            }
        }
        if j + 1 == m {
            break;
        }
        let beta = norm2(&w);
        if beta < 1e-12 * znorm {
            // Invariant subspace found: T is exact at this size.
            break;
        }
        betas.push(beta);
        q.push(w.iter().map(|v| v / beta).collect());
    }
    LanczosResult { alphas, betas, q, znorm, mvms }
}

/// Extremal eigenvalue estimates from a short Lanczos run on a random
/// probe, with safety margins — used to scale the Chebyshev expansion
/// (which, unlike Lanczos, needs to know the spectrum's interval; supp. C.2
/// lists this as one of Lanczos' advantages).
pub fn extremal_eigs(op: &dyn LinOp, steps: usize, seed: u64) -> crate::error::Result<(f64, f64)> {
    let n = op.n();
    let mut rng = Rng::new(seed);
    let mut z = vec![0.0; n];
    rng.fill_gaussian(&mut z);
    let res = lanczos(op, &z, steps.min(n));
    let eig = crate::linalg::tridiag::tridiag_eig_first_row(&res.alphas, &res.betas)?;
    let lo = *eig.eigvals.first().unwrap();
    let hi = *eig.eigvals.last().unwrap();
    // Ritz values are interior: widen. The lower end matters most for the
    // Chebyshev log singularity; the noise floor sigma^2 (when known by the
    // caller) should be max'd in on top of this.
    Ok((0.9 * lo.max(1e-12), 1.1 * hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::operators::DenseMatOp;
    use crate::util::rng::Rng;

    fn spd_op(n: usize, seed: u64) -> DenseMatOp {
        let mut rng = Rng::new(seed);
        let mut b = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut a = b.matmul(&b.transpose());
        a.scale(1.0 / n as f64);
        a.add_diag(0.5);
        b = a; // silence unused warnings path
        DenseMatOp::new(b)
    }

    #[test]
    fn q_is_orthonormal() {
        let op = spd_op(30, 1);
        let mut rng = Rng::new(2);
        let mut z = vec![0.0; 30];
        rng.fill_gaussian(&mut z);
        let res = lanczos(&op, &z, 12);
        for i in 0..res.q.len() {
            for j in 0..=i {
                let d = dot(&res.q[i], &res.q[j]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-10, "({i},{j}) -> {d}");
            }
        }
    }

    #[test]
    fn three_term_recurrence_holds() {
        // K q_j = beta_{j-1} q_{j-1} + alpha_j q_j + beta_j q_{j+1}
        let op = spd_op(25, 3);
        let mut rng = Rng::new(4);
        let mut z = vec![0.0; 25];
        rng.fill_gaussian(&mut z);
        let res = lanczos(&op, &z, 10);
        for j in 1..res.q.len() - 1 {
            let kq = op.apply_vec(&res.q[j]);
            for i in 0..25 {
                let want = res.betas[j - 1] * res.q[j - 1][i]
                    + res.alphas[j] * res.q[j][i]
                    + res.betas[j] * res.q[j + 1][i];
                assert!((kq[i] - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn solve_e1_approximates_inverse() {
        let op = spd_op(20, 5);
        let mut rng = Rng::new(6);
        let mut z = vec![0.0; 20];
        rng.fill_gaussian(&mut z);
        let res = lanczos(&op, &z, 20); // full dimension: exact
        let g = res.solve_e1();
        let dense = op.to_dense();
        let chol = crate::linalg::chol::Cholesky::new(&dense).unwrap();
        let want = chol.solve(&z);
        for i in 0..20 {
            assert!((g[i] - want[i]).abs() < 1e-7, "{} vs {}", g[i], want[i]);
        }
    }

    #[test]
    fn extremal_eigs_bracket_spectrum() {
        let op = spd_op(40, 7);
        let dense = op.to_dense();
        let eig = crate::linalg::eigh::eigh(&dense).unwrap();
        let (lo, hi) = extremal_eigs(&op, 30, 8).unwrap();
        assert!(lo <= eig.eigvals[0] + 1e-8, "{lo} vs {}", eig.eigvals[0]);
        assert!(hi >= eig.eigvals[39] - 1e-8, "{hi} vs {}", eig.eigvals[39]);
    }

    #[test]
    fn breakdown_on_low_rank_plus_identity() {
        // A = I + u u^T has 2 distinct eigenvalues: Lanczos should stop at 2.
        let n = 15;
        let u: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = u[i] * u[j];
            }
            a[(i, i)] += 1.0;
        }
        let op = DenseMatOp::new(a);
        let mut rng = Rng::new(9);
        let mut z = vec![0.0; n];
        rng.fill_gaussian(&mut z);
        let res = lanczos(&op, &z, 10);
        assert!(res.alphas.len() <= 3, "took {} steps", res.alphas.len());
    }
}
