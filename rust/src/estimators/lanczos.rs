//! Lanczos tridiagonalization with full reorthogonalization (paper §3.2).
//!
//! `K̃ Q_m = Q_m T + beta_m q_{m+1} e_m^T` with orthonormal `Q_m`,
//! `q_1 = z / ||z||`. The paper notes plain Lanczos is numerically unstable
//! and cites practical fixes [33, 34]; at the small step counts used here
//! (m ≤ ~100) full reorthogonalization is the simplest sound remedy.

use crate::linalg::dense::Mat;
use crate::operators::LinOp;
use crate::util::precision::Precision;
use crate::util::rng::Rng;
use crate::util::stats::{axpy, dot, norm2};

/// Result of an m-step Lanczos run.
pub struct LanczosResult {
    /// Diagonal of T (length = steps actually taken).
    pub alphas: Vec<f64>,
    /// Off-diagonal of T (length = steps - 1).
    pub betas: Vec<f64>,
    /// Orthonormal Krylov basis, one vector per step.
    pub q: Vec<Vec<f64>>,
    /// ||z|| of the start vector.
    pub znorm: f64,
    /// MVMs consumed.
    pub mvms: usize,
}

impl LanczosResult {
    /// Solve `T t = e_1 ||z||` and map back: `g = Q t ≈ K̃^{-1} z` — the
    /// derivative estimator's solve, free given the decomposition (§3.2).
    pub fn solve_e1(&self) -> Vec<f64> {
        solve_e1_parts(&self.alphas, &self.betas, self.znorm, &self.q)
    }
}

/// Shared `T t = e_1 ||z||` solve + basis map-back for [`LanczosResult`]
/// and [`SessionCol`] (one code path, so results and live sessions cannot
/// drift). Iterates over `t`, so a basis holding one extra vector (a
/// session column mid-extension) is handled the same as an exact-length
/// one.
fn solve_e1_parts(alphas: &[f64], betas: &[f64], znorm: f64, q: &[Vec<f64>]) -> Vec<f64> {
    let n = q[0].len();
    let t = thomas_solve_e1(alphas, betas, znorm);
    let mut g = vec![0.0; n];
    for (k, tk) in t.iter().enumerate() {
        axpy(*tk, &q[k], &mut g);
    }
    g
}

/// Thomas solve of the SPD tridiagonal system `T t = e_1 * rhs0`
/// (also used by the PJRT Lanczos artifact path to finish in f64).
pub fn thomas_solve_e1(alphas: &[f64], betas: &[f64], rhs0: f64) -> Vec<f64> {
    let m = alphas.len();
    let mut c = vec![0.0; m];
    let mut d = vec![0.0; m];
    for i in 0..m {
        let blo = if i > 0 { betas[i - 1] } else { 0.0 };
        let bup = if i + 1 < m { betas[i] } else { 0.0 };
        let denom = alphas[i] - blo * if i > 0 { c[i - 1] } else { 0.0 };
        c[i] = bup / denom;
        let rhs = if i == 0 { rhs0 } else { 0.0 };
        d[i] = (rhs - blo * if i > 0 { d[i - 1] } else { 0.0 }) / denom;
    }
    let mut t = vec![0.0; m];
    for i in (0..m).rev() {
        t[i] = d[i] - c[i] * if i + 1 < m { t[i + 1] } else { 0.0 };
    }
    t
}

/// Run `m` Lanczos steps on `op` starting from `z` — thin wrapper over the
/// single-column case of [`lanczos_block`], so the two paths cannot drift.
pub fn lanczos<O: LinOp + ?Sized>(op: &O, z: &[f64], m: usize) -> LanczosResult {
    assert_eq!(z.len(), op.n());
    lanczos_block(op, &Mat::from_col(z), m).pop().expect("one column in, one result out")
}

/// Per-column state of a [`LanczosSession`]: the tridiagonal prefix, the
/// orthonormal basis built so far (full reorthogonalization needs all of
/// it), and — the piece that makes resumption exact — the post-
/// reorthogonalization residual `w` that a budget-stopped run would
/// otherwise discard. Consuming `pending` on the next [`LanczosSession::
/// extend`] replays precisely the tail of a from-scratch step whose
/// budget had not yet run out: β-check, breakdown test, normalization.
pub struct SessionCol {
    q: Vec<Vec<f64>>,
    alphas: Vec<f64>,
    betas: Vec<f64>,
    znorm: f64,
    mvms: usize,
    pending: Option<Vec<f64>>,
}

impl SessionCol {
    /// Diagonal of T (length = steps taken so far).
    pub fn alphas(&self) -> &[f64] {
        &self.alphas
    }

    /// Off-diagonal of T.
    pub fn betas(&self) -> &[f64] {
        &self.betas
    }

    /// ||z|| of the start vector.
    pub fn znorm(&self) -> f64 {
        self.znorm
    }

    /// MVMs this column has consumed.
    pub fn mvms(&self) -> usize {
        self.mvms
    }

    /// Whether the column found an invariant subspace — terminal: no
    /// budget increase can advance it, T is exact at its current size.
    pub fn broken_down(&self) -> bool {
        self.pending.is_none() && self.q.len() == self.alphas.len()
    }

    /// `T t = e_1 ||z||` solve mapped back through the basis (same code
    /// path as [`LanczosResult::solve_e1`]).
    pub fn solve_e1(&self) -> Vec<f64> {
        solve_e1_parts(&self.alphas, &self.betas, self.znorm, &self.q)
    }
}

/// Resumable block-Lanczos state: one [`SessionCol`] per probe column.
///
/// The invariant that makes sessions safe to thread everywhere:
/// `new(z)` + `extend(op, m1, prec)` + `extend(op, m2, prec)` is
/// **bitwise identical** (tridiagonals, basis, MVM counts) to
/// `new(z)` + `extend(op, m2, prec)` — and both equal the historical
/// from-scratch `lanczos_block_prec(op, z, m2, prec)`, which is now a
/// thin wrapper over this type. The recurrence body is unchanged; the
/// only new state is the per-column `pending` residual captured at the
/// budget stop, exactly where the old driver dropped it.
pub struct LanczosSession {
    n: usize,
    cols: Vec<SessionCol>,
}

impl std::fmt::Debug for LanczosSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LanczosSession")
            .field("cols", &self.cols.len())
            .field("steps", &self.steps())
            .finish()
    }
}

impl LanczosSession {
    /// Start a session on the columns of `z` (an `n x b` probe block).
    /// No MVMs are spent until [`extend`](Self::extend).
    pub fn new(z: &Mat) -> Self {
        let n = z.rows;
        let cols = (0..z.cols)
            .map(|c| {
                let zc = z.col(c);
                let znorm = norm2(&zc);
                assert!(znorm > 0.0, "zero start vector");
                SessionCol {
                    q: vec![zc.iter().map(|v| v / znorm).collect()],
                    alphas: Vec::new(),
                    betas: Vec::new(),
                    znorm,
                    mvms: 0,
                    pending: None,
                }
            })
            .collect();
        LanczosSession { n, cols }
    }

    /// Number of probe columns.
    pub fn num_cols(&self) -> usize {
        self.cols.len()
    }

    /// Per-column state.
    pub fn col(&self, c: usize) -> &SessionCol {
        &self.cols[c]
    }

    /// Steps taken so far (max over columns — breakdown columns lag).
    pub fn steps(&self) -> usize {
        self.cols.iter().map(|c| c.alphas.len()).max().unwrap_or(0)
    }

    /// Total steps summed over columns — a monotone progress counter the
    /// adaptive driver uses to detect that every column has terminally
    /// broken down (an `extend` that moves this not at all).
    pub fn total_steps(&self) -> usize {
        self.cols.iter().map(|c| c.alphas.len()).sum()
    }

    /// MVMs consumed, summed over columns (block-size independent).
    pub fn mvms(&self) -> usize {
        self.cols.iter().map(|c| c.mvms).sum()
    }

    /// Batched operator applications charged to this block: the per-step
    /// block MVM serves every active column at once, so the count is the
    /// deepest column's MVM count.
    pub fn block_applies(&self) -> usize {
        self.cols.iter().map(|c| c.mvms).max().unwrap_or(0)
    }

    /// Advance every column to `m` steps (columns at or past `m`, and
    /// broken-down columns, are untouched). Each iteration batches the
    /// still-active columns' MVMs into one [`LinOp::apply_mat_prec`]
    /// call, exactly like the historical block driver.
    pub fn extend<O: LinOp + ?Sized>(&mut self, op: &O, m: usize, prec: Precision) {
        let _span = crate::span!("lanczos_extend");
        let n = self.n;
        assert_eq!(op.n(), n);
        // Phase 1: consume budget-stop residuals — the tail of a
        // from-scratch step whose budget had not yet run out: β, breakdown
        // test, normalization into the next basis vector.
        for st in self.cols.iter_mut() {
            if st.alphas.len() >= m {
                continue;
            }
            if let Some(w) = st.pending.take() {
                let beta = norm2(&w);
                if beta < 1e-12 * st.znorm {
                    // Invariant subspace found: T is exact at this size.
                    continue;
                }
                st.betas.push(beta);
                st.q.push(w.iter().map(|v| v / beta).collect());
            }
        }
        // Phase 2: the three-term recurrence, lockstep over the active
        // columns (all active columns share a step index by construction).
        let mut w = vec![0.0; n];
        loop {
            let act: Vec<usize> = (0..self.cols.len())
                .filter(|&c| {
                    let st = &self.cols[c];
                    st.alphas.len() < m && st.q.len() == st.alphas.len() + 1
                })
                .collect();
            if act.is_empty() {
                break;
            }
            let j = self.cols[act[0]].alphas.len();
            debug_assert!(act.iter().all(|&c| self.cols[c].alphas.len() == j));
            // One block MVM for every active column's current basis vector.
            let mut xb = Mat::zeros(n, act.len());
            for (k, &c) in act.iter().enumerate() {
                for i in 0..n {
                    xb[(i, k)] = self.cols[c].q[j][i];
                }
            }
            let wb = op.apply_mat_prec(&xb, prec);
            for (k, &c) in act.iter().enumerate() {
                let st = &mut self.cols[c];
                st.mvms += 1;
                wb.col_into(k, &mut w);
                let alpha = dot(&st.q[j], &w);
                st.alphas.push(alpha);
                axpy(-alpha, &st.q[j], &mut w);
                if j > 0 {
                    let bprev: f64 = st.betas[j - 1];
                    axpy(-bprev, &st.q[j - 1], &mut w);
                }
                // Full reorthogonalization. One modified-Gram-Schmidt pass,
                // with a second pass only when the first removed a large
                // component ("twice is enough" — Parlett — but the second pass
                // is usually a no-op and costs O(n m) per step; §Perf opt 2).
                let before = norm2(&w);
                let mut removed = 0.0f64;
                for qk in st.q.iter() {
                    let p = dot(qk, &w);
                    if p != 0.0 {
                        axpy(-p, qk, &mut w);
                        removed = removed.max(p.abs());
                    }
                }
                if removed > 0.5 * before {
                    for qk in st.q.iter() {
                        let p = dot(qk, &w);
                        if p != 0.0 {
                            axpy(-p, qk, &mut w);
                        }
                    }
                }
                if j + 1 == m {
                    // Budget stop: retain the residual so a later extend
                    // continues bit-identically to a from-scratch run.
                    st.pending = Some(w.clone());
                    continue;
                }
                let beta = norm2(&w);
                if beta < 1e-12 * st.znorm {
                    // Invariant subspace found: T is exact at this size.
                    continue;
                }
                st.betas.push(beta);
                st.q.push(w.iter().map(|v| v / beta).collect());
            }
        }
    }

    /// Freeze into per-column [`LanczosResult`]s (drops resume state).
    pub fn into_results(self) -> Vec<LanczosResult> {
        self.cols
            .into_iter()
            .map(|st| LanczosResult {
                alphas: st.alphas,
                betas: st.betas,
                q: st.q,
                znorm: st.znorm,
                mvms: st.mvms,
            })
            .collect()
    }
}

/// Run `m` Lanczos steps on **each column** of `z` (an `n x b` probe
/// block), batching every iteration's MVMs into one [`LinOp::apply_mat`]
/// call over the still-active columns.
///
/// This is the batched-probe driver of the paper's SLQ estimator: the
/// per-column three-term recurrence, full reorthogonalization, and
/// breakdown handling are *identical* to [`lanczos`] (columns never mix),
/// so results are bitwise equal to running `lanczos` per probe — only the
/// number of passes over the operator's structure changes. A column that
/// finds an invariant subspace (`beta ~ 0`) drops out of subsequent block
/// applies; the block shrinks rather than padding with dead columns.
pub fn lanczos_block<O: LinOp + ?Sized>(op: &O, z: &Mat, m: usize) -> Vec<LanczosResult> {
    lanczos_block_prec(op, z, m, Precision::F64)
}

/// [`lanczos_block`] with the block MVMs routed through
/// [`LinOp::apply_mat_prec`]. `Precision::F64` **is** `lanczos_block`
/// (same code, and the trait routes the F64 arm to `apply_mat`).
/// `F32F64` runs the recurrence on the reduced-precision operator: the
/// Lanczos vectors, reorthogonalization, and T entries all stay f64, so
/// the result is an *exact* tridiagonalization of the (deterministic)
/// rounded operator — the quadrature values it feeds move by the
/// operator's storage-rounding perturbation, which the SLQ estimator's
/// own Monte-Carlo noise dominates at the paper's probe counts.
///
/// Since the session refactor this is a driver over [`LanczosSession`]:
/// one `new` + `extend(m)`, frozen into results.
pub fn lanczos_block_prec<O: LinOp + ?Sized>(
    op: &O,
    z: &Mat,
    m: usize,
    prec: Precision,
) -> Vec<LanczosResult> {
    assert_eq!(z.rows, op.n());
    let mut session = LanczosSession::new(z);
    session.extend(op, m, prec);
    session.into_results()
}

/// Smallest Lanczos step count at which the Gauss quadrature estimate of
/// `weight * e_1ᵀ f(T) e_1` has converged: the first prefix length m where
/// consecutive estimates differ by less than `tol * (|estimate| + 1)`.
/// Returns `alphas.len()` if the run never settles. This is the
/// "Lanczos steps per probe" metric of the preconditioning benchmarks —
/// computed post hoc from one full run, so measuring it costs nothing
/// beyond the run itself.
pub fn quadrature_steps_to_tol(
    alphas: &[f64],
    betas: &[f64],
    weight: f64,
    f: impl Fn(f64) -> f64 + Copy,
    tol: f64,
) -> crate::error::Result<usize> {
    use crate::linalg::tridiag::lanczos_quadrature;
    let m = alphas.len();
    if m == 0 {
        return Ok(0);
    }
    let mut prev = lanczos_quadrature(&alphas[..1], &[], weight, f)?;
    for k in 2..=m {
        let cur = lanczos_quadrature(&alphas[..k], &betas[..k - 1], weight, f)?;
        if (cur - prev).abs() <= tol * (cur.abs() + 1.0) {
            return Ok(k);
        }
        prev = cur;
    }
    Ok(m)
}

/// The "Lanczos steps per probe" metric shared by the CLI perf experiment
/// and `bench_perf_mvm --json-precond`: run one (optionally preconditioned)
/// Lanczos pass from probe `z` and report the quadrature convergence point
/// of the log-determinant integrand via [`quadrature_steps_to_tol`]. With a
/// preconditioner the pass runs on the split `P^{-1/2} K̃ P^{-1/2}`.
/// Defining the metric once keeps the perf table and the JSON sweep from
/// drifting apart.
pub fn logdet_steps_to_tol<O: LinOp + ?Sized>(
    op: &O,
    pc: Option<&dyn crate::solvers::Preconditioner>,
    z: &[f64],
    max_steps: usize,
    tol: f64,
) -> crate::error::Result<usize> {
    let f = |lam: f64| lam.max(1e-300).ln();
    let r = match pc {
        Some(p) => {
            let pop = crate::solvers::PreconditionedOp::new(op, p);
            lanczos(&pop, z, max_steps)
        }
        None => lanczos(op, z, max_steps),
    };
    quadrature_steps_to_tol(&r.alphas, &r.betas, r.znorm * r.znorm, f, tol)
}

/// Extremal eigenvalue estimates from a short Lanczos run on a random
/// probe, with safety margins — used to scale the Chebyshev expansion
/// (which, unlike Lanczos, needs to know the spectrum's interval; supp. C.2
/// lists this as one of Lanczos' advantages).
pub fn extremal_eigs<O: LinOp + ?Sized>(op: &O, steps: usize, seed: u64) -> crate::error::Result<(f64, f64)> {
    let n = op.n();
    let mut rng = Rng::new(seed);
    let mut z = vec![0.0; n];
    rng.fill_gaussian(&mut z);
    let res = lanczos(op, &z, steps.min(n));
    let eig = crate::linalg::tridiag::tridiag_eig_first_row(&res.alphas, &res.betas)?;
    let lo = *eig.eigvals.first().unwrap();
    let hi = *eig.eigvals.last().unwrap();
    // Ritz values are interior: widen. The lower end matters most for the
    // Chebyshev log singularity; the noise floor sigma^2 (when known by the
    // caller) should be max'd in on top of this.
    Ok((0.9 * lo.max(1e-12), 1.1 * hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::operators::DenseMatOp;
    use crate::util::rng::Rng;

    fn spd_op(n: usize, seed: u64) -> DenseMatOp {
        let mut rng = Rng::new(seed);
        let mut b = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut a = b.matmul(&b.transpose());
        a.scale(1.0 / n as f64);
        a.add_diag(0.5);
        b = a; // silence unused warnings path
        DenseMatOp::new(b)
    }

    #[test]
    fn q_is_orthonormal() {
        let op = spd_op(30, 1);
        let mut rng = Rng::new(2);
        let mut z = vec![0.0; 30];
        rng.fill_gaussian(&mut z);
        let res = lanczos(&op, &z, 12);
        for i in 0..res.q.len() {
            for j in 0..=i {
                let d = dot(&res.q[i], &res.q[j]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-10, "({i},{j}) -> {d}");
            }
        }
    }

    #[test]
    fn three_term_recurrence_holds() {
        // K q_j = beta_{j-1} q_{j-1} + alpha_j q_j + beta_j q_{j+1}
        let op = spd_op(25, 3);
        let mut rng = Rng::new(4);
        let mut z = vec![0.0; 25];
        rng.fill_gaussian(&mut z);
        let res = lanczos(&op, &z, 10);
        for j in 1..res.q.len() - 1 {
            let kq = op.apply_vec(&res.q[j]);
            for i in 0..25 {
                let want = res.betas[j - 1] * res.q[j - 1][i]
                    + res.alphas[j] * res.q[j][i]
                    + res.betas[j] * res.q[j + 1][i];
                assert!((kq[i] - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn solve_e1_approximates_inverse() {
        let op = spd_op(20, 5);
        let mut rng = Rng::new(6);
        let mut z = vec![0.0; 20];
        rng.fill_gaussian(&mut z);
        let res = lanczos(&op, &z, 20); // full dimension: exact
        let g = res.solve_e1();
        let dense = op.to_dense();
        let chol = crate::linalg::chol::Cholesky::new(&dense).unwrap();
        let want = chol.solve(&z);
        for i in 0..20 {
            assert!((g[i] - want[i]).abs() < 1e-7, "{} vs {}", g[i], want[i]);
        }
    }

    #[test]
    fn extremal_eigs_bracket_spectrum() {
        let op = spd_op(40, 7);
        let dense = op.to_dense();
        let eig = crate::linalg::eigh::eigh(&dense).unwrap();
        let (lo, hi) = extremal_eigs(&op, 30, 8).unwrap();
        assert!(lo <= eig.eigvals[0] + 1e-8, "{lo} vs {}", eig.eigvals[0]);
        assert!(hi >= eig.eigvals[39] - 1e-8, "{hi} vs {}", eig.eigvals[39]);
    }

    #[test]
    fn block_matches_single_column_bitwise() {
        let op = spd_op(28, 11);
        let mut rng = Rng::new(12);
        let z = Mat::from_fn(28, 5, |_, _| rng.gaussian());
        let rs = lanczos_block(&op, &z, 9);
        assert_eq!(rs.len(), 5);
        for (j, r) in rs.iter().enumerate() {
            let single = lanczos(&op, &z.col(j), 9);
            assert_eq!(r.alphas.len(), single.alphas.len(), "col {j}");
            for (a, b) in r.alphas.iter().zip(&single.alphas) {
                assert_eq!(a.to_bits(), b.to_bits(), "col {j} alpha");
            }
            for (a, b) in r.betas.iter().zip(&single.betas) {
                assert_eq!(a.to_bits(), b.to_bits(), "col {j} beta");
            }
            let g = r.solve_e1();
            let gs = single.solve_e1();
            for (a, b) in g.iter().zip(&gs) {
                assert_eq!(a.to_bits(), b.to_bits(), "col {j} solve");
            }
        }
    }

    /// The precision knob on the block driver: F64 is `lanczos_block`
    /// bitwise, and F32F64 is exactly Lanczos run (in f64) on the rounded
    /// operator — pinned by building that operator explicitly.
    #[test]
    fn block_prec_f64_identity_and_mixed_is_rounded_operator() {
        let op = spd_op(26, 21);
        let mut rng = Rng::new(22);
        let z = Mat::from_fn(26, 3, |_, _| rng.gaussian());
        let plain = lanczos_block(&op, &z, 8);
        let f64_path = lanczos_block_prec(&op, &z, 8, Precision::F64);
        let rounded = DenseMatOp::new(Mat {
            rows: op.a.rows,
            cols: op.a.cols,
            data: op.a.data.iter().map(|&v| f64::from(v as f32)).collect(),
        });
        let mixed = lanczos_block_prec(&op, &z, 8, Precision::F32F64);
        let want = lanczos_block(&rounded, &z, 8);
        for j in 0..3 {
            for (a, b) in f64_path[j].alphas.iter().zip(&plain[j].alphas) {
                assert_eq!(a.to_bits(), b.to_bits(), "f64 col {j}");
            }
            assert_eq!(mixed[j].alphas.len(), want[j].alphas.len(), "col {j}");
            for (a, b) in mixed[j].alphas.iter().zip(&want[j].alphas) {
                assert_eq!(a.to_bits(), b.to_bits(), "mixed col {j} alpha");
            }
            for (a, b) in mixed[j].betas.iter().zip(&want[j].betas) {
                assert_eq!(a.to_bits(), b.to_bits(), "mixed col {j} beta");
            }
        }
    }

    /// The session invariant in its rawest form: chained `extend` calls
    /// are bitwise identical — basis vectors included — to one
    /// from-scratch run at the final step count, and the MVM accounting
    /// matches too.
    #[test]
    fn session_extend_matches_from_scratch_bitwise() {
        let op = spd_op(24, 31);
        let mut rng = Rng::new(32);
        let z = Mat::from_fn(24, 4, |_, _| rng.gaussian());
        for &prec in &[Precision::F64, Precision::F32F64] {
            let mut sess = LanczosSession::new(&z);
            sess.extend(&op, 3, prec);
            sess.extend(&op, 7, prec);
            sess.extend(&op, 12, prec);
            let scratch = lanczos_block_prec(&op, &z, 12, prec);
            let resumed = sess.into_results();
            for (c, (a, b)) in resumed.iter().zip(&scratch).enumerate() {
                assert_eq!(a.alphas.len(), b.alphas.len(), "col {c}");
                assert_eq!(a.mvms, b.mvms, "col {c} mvms");
                for (x, y) in a.alphas.iter().zip(&b.alphas) {
                    assert_eq!(x.to_bits(), y.to_bits(), "col {c} alpha");
                }
                for (x, y) in a.betas.iter().zip(&b.betas) {
                    assert_eq!(x.to_bits(), y.to_bits(), "col {c} beta");
                }
                assert_eq!(a.q.len(), b.q.len(), "col {c} basis");
                for (qa, qb) in a.q.iter().zip(&b.q) {
                    for (x, y) in qa.iter().zip(qb) {
                        assert_eq!(x.to_bits(), y.to_bits(), "col {c} q");
                    }
                }
            }
        }
    }

    /// Breakdown columns are terminal: extending past the invariant
    /// subspace is a no-op, bitwise equal to a from-scratch run with the
    /// larger budget (which also stops at the subspace).
    #[test]
    fn session_extend_past_breakdown_is_noop() {
        let n = 15;
        let u: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = u[i] * u[j];
            }
            a[(i, i)] += 1.0;
        }
        let op = DenseMatOp::new(a);
        let mut rng = Rng::new(33);
        let z = Mat::from_fn(n, 2, |_, _| rng.gaussian());
        let mut sess = LanczosSession::new(&z);
        sess.extend(&op, 2, Precision::F64);
        sess.extend(&op, 10, Precision::F64);
        assert!(sess.cols.iter().all(|c| c.broken_down()), "rank-2 spectrum must break down");
        let mvms_at_10 = sess.mvms();
        sess.extend(&op, 14, Precision::F64);
        assert_eq!(sess.mvms(), mvms_at_10, "terminal columns must not spend MVMs");
        let scratch = lanczos_block(&op, &z, 14);
        for (a, b) in sess.into_results().iter().zip(&scratch) {
            assert_eq!(a.alphas.len(), b.alphas.len());
            assert_eq!(a.mvms, b.mvms);
            for (x, y) in a.alphas.iter().zip(&b.alphas) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn breakdown_on_low_rank_plus_identity() {
        // A = I + u u^T has 2 distinct eigenvalues: Lanczos should stop at 2.
        let n = 15;
        let u: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = u[i] * u[j];
            }
            a[(i, i)] += 1.0;
        }
        let op = DenseMatOp::new(a);
        let mut rng = Rng::new(9);
        let mut z = vec![0.0; n];
        rng.fill_gaussian(&mut z);
        let res = lanczos(&op, &z, 10);
        assert!(res.alphas.len() <= 3, "took {} steps", res.alphas.len());
    }
}
