//! RBF-surrogate estimation of the log determinant over hyperparameter
//! space (paper §3.5 and Appendix B.2).
//!
//! `log|K̃(θ)|` is evaluated (by SLQ) at a few systematically chosen design
//! points in log-hyper space, then interpolated by a cubic RBF
//! `s(θ) = sum_i λ_i ||θ - θ_i||^3 + p(θ)` with a linear polynomial tail,
//! fit by the standard saddle system with the discrete orthogonality
//! condition (Eq. 6). Both the value and the analytic gradient of the
//! surrogate are cheap — this is the "(——) surrogate" line of Fig. 1.

use super::slq::{slq_logdet, SlqOptions};
use crate::error::{Error, Result};
use crate::linalg::dense::Mat;
use crate::linalg::lu::Lu;
use crate::operators::KernelOp;
use crate::util::rng::Rng;

/// Fitted cubic RBF interpolant with a linear tail.
pub struct RbfSurrogate {
    /// Design points (in whatever space the caller interpolates over).
    pub points: Vec<Vec<f64>>,
    /// RBF coefficients λ_i.
    pub lambda: Vec<f64>,
    /// Polynomial tail: constant + linear coefficients (length d + 1).
    pub poly: Vec<f64>,
}

fn phi(r: f64) -> f64 {
    r * r * r
}

impl RbfSurrogate {
    /// Fit to (points, values) by solving the (n + d + 1) saddle system
    /// `[Φ P; P^T 0] [λ; c] = [f; 0]`.
    pub fn fit(points: Vec<Vec<f64>>, values: &[f64]) -> Result<Self> {
        let n = points.len();
        assert_eq!(values.len(), n);
        if n == 0 {
            return Err(Error::Config("surrogate needs at least one design point".into()));
        }
        let d = points[0].len();
        let size = n + d + 1;
        let mut a = Mat::zeros(size, size);
        for i in 0..n {
            for j in 0..n {
                let r = crate::kernels::dist(&points[i], &points[j]);
                a[(i, j)] = phi(r);
            }
            a[(i, n)] = 1.0;
            a[(n, i)] = 1.0;
            for k in 0..d {
                a[(i, n + 1 + k)] = points[i][k];
                a[(n + 1 + k, i)] = points[i][k];
            }
        }
        let mut rhs = vec![0.0; size];
        rhs[..n].copy_from_slice(values);
        let sol = Lu::new(&a)?.solve(&rhs);
        Ok(RbfSurrogate {
            points,
            lambda: sol[..n].to_vec(),
            poly: sol[n..].to_vec(),
        })
    }

    pub fn dim(&self) -> usize {
        self.poly.len() - 1
    }

    /// Surrogate value at `x`.
    pub fn eval(&self, x: &[f64]) -> f64 {
        let mut s = self.poly[0];
        for k in 0..self.dim() {
            s += self.poly[1 + k] * x[k];
        }
        for (p, lam) in self.points.iter().zip(&self.lambda) {
            s += lam * phi(crate::kernels::dist(x, p));
        }
        s
    }

    /// Analytic gradient: `∇ φ(||x - p||) = 3 r (x - p)` for the cubic.
    pub fn grad(&self, x: &[f64]) -> Vec<f64> {
        let d = self.dim();
        let mut g = self.poly[1..].to_vec();
        for (p, lam) in self.points.iter().zip(&self.lambda) {
            let r = crate::kernels::dist(x, p);
            for k in 0..d {
                g[k] += lam * 3.0 * r * (x[k] - p[k]);
            }
        }
        g
    }
}

/// A surrogate for `log|K̃(θ)|` over a box in log-hyper space, built from
/// SLQ evaluations at Latin-hypercube design points.
pub struct LogdetSurrogate {
    pub surrogate: RbfSurrogate,
    /// Box: per-hyper (lo, hi) in log space.
    pub bounds: Vec<(f64, f64)>,
    /// Total MVMs spent building it.
    pub build_mvms: usize,
    /// Total probe vectors consumed across all design-point SLQ
    /// evaluations (adaptive budgets make this data-dependent).
    pub build_probes_used: usize,
    /// Widest 95% confidence interval among the design-point evaluations —
    /// an upper bound on the stochastic error baked into the interpolant's
    /// training values (the surrogate itself is deterministic afterwards,
    /// which is why its estimates report degenerate evidence).
    pub build_max_interval_width: f64,
}

impl LogdetSurrogate {
    /// Build over `bounds` with `n_design` points (paper: 50 design points
    /// for the supp. fig. 7 study; Fig. 1 builds one per dataset).
    pub fn build(
        op: &mut dyn KernelOp,
        bounds: &[(f64, f64)],
        n_design: usize,
        slq: &SlqOptions,
        seed: u64,
    ) -> Result<Self> {
        let d = bounds.len();
        assert_eq!(d, op.num_hypers());
        let mut rng = Rng::new(seed);
        let unit = rng.latin_hypercube(n_design, d);
        let pts: Vec<Vec<f64>> = unit
            .iter()
            .map(|u| {
                (0..d)
                    .map(|k| bounds[k].0 + (bounds[k].1 - bounds[k].0) * u[k])
                    .collect()
            })
            .collect();
        let h0 = op.hypers();
        let mut vals = Vec::with_capacity(n_design);
        let mut build_mvms = 0;
        let mut build_probes_used = 0;
        let mut build_max_interval_width: f64 = 0.0;
        let mut opts = *slq;
        opts.grads = false;
        // The design loop mutates the operator's hyperparameters, so the
        // original setting must be restored on *every* exit path — a
        // mid-loop SLQ failure must not leave the operator parked at an
        // arbitrary design point (a `?` here used to skip the restore).
        let mut failure = None;
        for p in &pts {
            op.set_hypers(p);
            match slq_logdet(op, &opts) {
                Ok(est) => {
                    vals.push(est.value);
                    build_mvms += est.mvms;
                    build_probes_used += est.probes_used;
                    build_max_interval_width =
                        build_max_interval_width.max(est.interval.width());
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        op.set_hypers(&h0);
        if let Some(e) = failure {
            return Err(e);
        }
        Ok(LogdetSurrogate {
            surrogate: RbfSurrogate::fit(pts, &vals)?,
            bounds: bounds.to_vec(),
            build_mvms,
            build_probes_used,
            build_max_interval_width,
        })
    }

    /// Clamp a query into the box (the surrogate extrapolates poorly).
    pub fn clamp(&self, theta: &[f64]) -> Vec<f64> {
        theta
            .iter()
            .zip(&self.bounds)
            .map(|(&t, &(lo, hi))| t.clamp(lo, hi))
            .collect()
    }

    pub fn eval(&self, theta: &[f64]) -> f64 {
        self.surrogate.eval(&self.clamp(theta))
    }

    /// Gradient of the *clamped* surrogate `θ ↦ s(clamp(θ))` — what
    /// [`LogdetSurrogate::eval`] actually computes. By the chain rule of
    /// `clamp`, coordinates strictly outside the box have zero derivative:
    /// the function is constant along them there. (Returning the interior
    /// gradient at the clamped point — the old behavior — pushed
    /// optimizers at the boundary with the derivative of a function they
    /// were not on.) Exactly *at* a bound the one-sided interior
    /// derivative is kept, matching the inward direction an optimizer can
    /// still move in.
    pub fn grad(&self, theta: &[f64]) -> Vec<f64> {
        let mut g = self.surrogate.grad(&self.clamp(theta));
        for (gk, (&t, &(lo, hi))) in g.iter_mut().zip(theta.iter().zip(&self.bounds)) {
            if t < lo || t > hi {
                *gk = 0.0;
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::{DenseKernelOp, LinOp};

    /// A kernel operator that produces garbage (NaN) MVMs whenever its
    /// first hyper exceeds a threshold — SLQ on it fails with a clean
    /// `Err` (the tridiagonal eigensolver refuses NaN input), which is
    /// exactly the mid-build failure mode the restore bugfix guards.
    struct FailingOp {
        inner: DenseKernelOp,
        fail_above: f64,
    }

    impl LinOp for FailingOp {
        fn n(&self) -> usize {
            self.inner.n()
        }
        fn apply(&self, x: &[f64], y: &mut [f64]) {
            self.inner.apply(x, y);
            if self.inner.hypers()[0] > self.fail_above {
                for v in y.iter_mut() {
                    *v = f64::NAN;
                }
            }
        }
    }

    impl KernelOp for FailingOp {
        fn num_hypers(&self) -> usize {
            self.inner.num_hypers()
        }
        fn hypers(&self) -> Vec<f64> {
            self.inner.hypers()
        }
        fn set_hypers(&mut self, h: &[f64]) {
            self.inner.set_hypers(h)
        }
        fn hyper_names(&self) -> Vec<String> {
            self.inner.hyper_names()
        }
        fn apply_grad(&self, i: usize, x: &[f64], y: &mut [f64]) {
            self.inner.apply_grad(i, x, y)
        }
    }

    #[test]
    fn interpolates_exactly_at_design_points() {
        let pts = vec![
            vec![0.0, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
            vec![0.5, 0.5],
        ];
        let vals = vec![1.0, 2.0, 3.0, 4.0, 2.5];
        let s = RbfSurrogate::fit(pts.clone(), &vals).unwrap();
        for (p, v) in pts.iter().zip(&vals) {
            assert!((s.eval(p) - v).abs() < 1e-9);
        }
    }

    #[test]
    fn reproduces_linear_functions_exactly() {
        // Linear tail => linear functions are in the span.
        let f = |x: &[f64]| 2.0 - 3.0 * x[0] + 0.5 * x[1];
        let pts: Vec<Vec<f64>> = (0..7)
            .map(|i| vec![(i as f64 * 0.37) % 1.0, (i as f64 * 0.71) % 1.0])
            .collect();
        let vals: Vec<f64> = pts.iter().map(|p| f(p)).collect();
        let s = RbfSurrogate::fit(pts, &vals).unwrap();
        for &(x, y) in &[(0.2, 0.9), (0.66, 0.13), (0.5, 0.5)] {
            assert!((s.eval(&[x, y]) - f(&[x, y])).abs() < 1e-8);
        }
    }

    #[test]
    fn gradient_matches_fd() {
        let pts: Vec<Vec<f64>> = (0..9)
            .map(|i| vec![(i as f64 * 0.31) % 1.0, (i as f64 * 0.63) % 1.0])
            .collect();
        let vals: Vec<f64> =
            pts.iter().map(|p| (p[0] * 3.0).sin() + p[1] * p[1]).collect();
        let s = RbfSurrogate::fit(pts, &vals).unwrap();
        let x = [0.4, 0.6];
        let g = s.grad(&x);
        let eps = 1e-6;
        for k in 0..2 {
            let mut xp = x;
            xp[k] += eps;
            let up = s.eval(&xp);
            xp[k] -= 2.0 * eps;
            let dn = s.eval(&xp);
            let fd = (up - dn) / (2.0 * eps);
            assert!((g[k] - fd).abs() < 1e-5 * (1.0 + fd.abs()));
        }
    }

    /// Bugfix regression: a design-point SLQ failure mid-build must leave
    /// the operator at the hypers it entered with, and surface the error.
    #[test]
    fn build_restores_hypers_when_slq_fails() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        let pts: Vec<Vec<f64>> =
            (0..40).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let inner = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.3,
        );
        let h0 = inner.hypers();
        // Poison the top 40% of the box in the first hyper: the Latin
        // hypercube puts one design point per stratum, so with 8 points at
        // least three land above the threshold — the build *must* fail.
        let mut op = FailingOp { inner, fail_above: h0[0] + 0.1 };
        let bounds: Vec<(f64, f64)> =
            h0.iter().map(|&h| (h - 0.5, h + 0.5)).collect();
        let slq = SlqOptions { steps: 10, probes: 3, seed: 1, ..Default::default() };
        let res = LogdetSurrogate::build(&mut op, &bounds, 8, &slq, 5);
        assert!(res.is_err(), "poisoned design points should fail the build");
        assert_eq!(op.hypers(), h0, "hypers must be restored on the error path");
    }

    /// Bugfix regression: the gradient of the clamped surrogate is zero
    /// along coordinates strictly outside the box (the clamped function is
    /// constant there), and matches finite differences of `eval` — the
    /// function callers actually optimize — on both sides of the boundary.
    #[test]
    fn clamped_gradient_matches_fd_across_boundary() {
        let pts: Vec<Vec<f64>> = (0..9)
            .map(|i| vec![(i as f64 * 0.29) % 1.0, (i as f64 * 0.53) % 1.0])
            .collect();
        let vals: Vec<f64> =
            pts.iter().map(|p| (p[0] * 2.0).sin() + p[1] * p[1] - p[0] * p[1]).collect();
        let sur = LogdetSurrogate {
            surrogate: RbfSurrogate::fit(pts, &vals).unwrap(),
            bounds: vec![(0.0, 1.0), (0.0, 1.0)],
            build_mvms: 0,
            build_probes_used: 0,
            build_max_interval_width: 0.0,
        };
        let eps = 1e-6;
        // Above the box in dim 0, below it in dim 0, and interior.
        for theta in [[1.3, 0.4], [-0.2, 0.6], [0.5, 0.5]] {
            let g = sur.grad(&theta);
            for k in 0..2 {
                let mut tp = theta;
                tp[k] += eps;
                let up = sur.eval(&tp);
                tp[k] -= 2.0 * eps;
                let dn = sur.eval(&tp);
                let fd = (up - dn) / (2.0 * eps);
                assert!(
                    (g[k] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                    "theta {theta:?} dim {k}: grad {} vs fd {fd}",
                    g[k]
                );
            }
            let out0 = theta[0] < 0.0 || theta[0] > 1.0;
            if out0 {
                assert_eq!(g[0], 0.0, "clamped coordinate must have zero gradient");
                assert!(g[1] != 0.0, "interior coordinate keeps its derivative");
            }
        }
    }

    #[test]
    fn logdet_surrogate_tracks_slq() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        let pts: Vec<Vec<f64>> =
            (0..80).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let mut op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.3,
        );
        let h0 = op.hypers();
        let bounds: Vec<(f64, f64)> =
            h0.iter().map(|&h| (h - 0.7, h + 0.7)).collect();
        let slq = SlqOptions { steps: 25, probes: 10, seed: 1, ..Default::default() };
        let sur = LogdetSurrogate::build(&mut op, &bounds, 50, &slq, 7).unwrap();
        assert_eq!(sur.build_probes_used, 50 * 10, "fixed budget: 10 probes per design point");
        assert!(
            sur.build_max_interval_width.is_finite() && sur.build_max_interval_width > 0.0,
            "design evaluations should carry finite nonzero interval widths"
        );
        // Compare surrogate to fresh SLQ at interior points.
        for shift in [-0.3, 0.0, 0.25] {
            let theta: Vec<f64> = h0.iter().map(|&h| h + shift).collect();
            op.set_hypers(&theta);
            let direct = slq_logdet(
                &op,
                &SlqOptions { steps: 25, probes: 6, grads: false, seed: 2, ..Default::default() },
            )
            .unwrap();
            let sv = sur.eval(&theta);
            // The surrogate is an interpolation over a wide box in 3-D log
            // space: ~10% accuracy is the realistic bar (the paper uses it
            // for optimizer guidance, not for final likelihood values).
            assert!(
                (sv - direct.value).abs() < 0.10 * direct.value.abs().max(1.0) + 5.0,
                "shift {shift}: surrogate {sv} vs slq {}",
                direct.value
            );
        }
        op.set_hypers(&h0);
    }
}
