//! Exact O(n^3) log determinant and gradients — the Cholesky baseline the
//! paper's estimators replace, and the ground truth for our tests/figures.

use super::LogdetEstimate;
use crate::error::Result;
use crate::linalg::chol::Cholesky;
use crate::operators::{DenseKernelOp, KernelOp, LinOp};

/// Exact `log|A|` of any operator by densifying + Cholesky.
pub fn exact_logdet(op: &dyn LinOp) -> Result<f64> {
    let a = op.to_dense();
    Ok(Cholesky::new_jittered(&a, 1e-10, 8)?.logdet())
}

/// Exact log determinant *and* gradient for a dense kernel operator:
/// `∂_i log|K̃| = tr(K̃^{-1} ∂K̃/∂θ_i)` with an explicit inverse.
pub fn exact_logdet_grads_dense(op: &DenseKernelOp) -> Result<(f64, Vec<f64>)> {
    let a = op.full_matrix();
    let chol = Cholesky::new_jittered(&a, 1e-10, 8)?;
    let value = chol.logdet();
    let inv = chol.inverse();
    let nh = op.num_hypers();
    let mut grad = vec![0.0; nh];
    for i in 0..nh {
        let dk = op.grad_matrix(i);
        grad[i] = inv.trace_product(&dk);
    }
    Ok((value, grad))
}

/// Exact estimate packaged as a [`LogdetEstimate`] for uniform handling in
/// the experiment harness.
pub fn exact_estimate(op: &DenseKernelOp) -> Result<LogdetEstimate> {
    let (v, g) = exact_logdet_grads_dense(op)?;
    Ok(LogdetEstimate::exact(v, g))
}

/// Exact gradient for *any* KernelOp by densifying everything (test oracle;
/// O(n^3 + nh n^2 MVMs)).
pub fn exact_logdet_grads_any(op: &dyn KernelOp) -> Result<(f64, Vec<f64>)> {
    let n = op.n();
    let a = op.to_dense();
    let chol = Cholesky::new_jittered(&a, 1e-10, 8)?;
    let value = chol.logdet();
    let inv = chol.inverse();
    let nh = op.num_hypers();
    let mut grad = vec![0.0; nh];
    let mut e = vec![0.0; n];
    let mut col = vec![0.0; n];
    for i in 0..nh {
        // tr(K^{-1} dK) = sum_j (K^{-1})_{:,j} . (dK)_{:,j}
        let mut tr = 0.0;
        for j in 0..n {
            e[j] = 1.0;
            op.apply_grad(i, &e, &mut col);
            e[j] = 0.0;
            for r in 0..n {
                tr += inv[(r, j)] * col[r];
            }
        }
        grad[i] = tr;
    }
    Ok((value, grad))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::util::rng::Rng;

    fn op(n: usize) -> DenseKernelOp {
        let mut rng = Rng::new(17);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.gaussian(), rng.gaussian()]).collect();
        DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Matern32, 2, 0.8, 1.1)),
            0.25,
        )
    }

    #[test]
    fn grads_match_finite_difference_of_logdet() {
        let mut o = op(40);
        let (_, g) = exact_logdet_grads_dense(&o).unwrap();
        let h0 = o.hypers();
        let eps = 1e-5;
        for i in 0..o.num_hypers() {
            let mut hp = h0.clone();
            hp[i] += eps;
            o.set_hypers(&hp);
            let up = exact_logdet(&o).unwrap();
            hp[i] -= 2.0 * eps;
            o.set_hypers(&hp);
            let dn = exact_logdet(&o).unwrap();
            o.set_hypers(&h0);
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (g[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "hyper {i}: {} vs {}",
                g[i],
                fd
            );
        }
    }

    #[test]
    fn any_version_matches_dense_version() {
        let o = op(25);
        let (v1, g1) = exact_logdet_grads_dense(&o).unwrap();
        let (v2, g2) = exact_logdet_grads_any(&o).unwrap();
        assert!((v1 - v2).abs() < 1e-9);
        for i in 0..g1.len() {
            assert!((g1[i] - g2[i]).abs() < 1e-7);
        }
    }
}
