//! Stochastic Lanczos quadrature for `log|K̃|` and its derivatives
//! (paper §3.2) — the method the paper recommends.
//!
//! Per probe z:
//!   1. m Lanczos steps give tridiagonal T and basis Q (m MVMs);
//!   2. `z^T log(K̃) z ≈ ||z||^2 e_1^T log(T) e_1` (Gauss quadrature, Eq. 3);
//!   3. `g = Q T^{-1} e_1 ||z|| ≈ K̃^{-1} z` — *no additional MVMs*;
//!   4. `∂_i log|K̃| ≈ mean_z [ g^T (∂K̃/∂θ_i) z ]` — one derivative MVM per
//!      hyper per probe.
//!
//! The driver is **blocked**: probes are drawn as one `n x p` matrix,
//! sliced into `block_size`-wide blocks, and each Lanczos iteration /
//! derivative pass is a single block MVM over the whole block
//! ([`super::lanczos::lanczos_block`], `apply_grad_all_mat`). Per-probe
//! arithmetic is unchanged, so estimates are bit-identical across block
//! sizes; see the module docs of [`crate::estimators`] for the accounting
//! convention (`mvms` vs `block_applies`).

use super::lanczos::{lanczos_block, lanczos_block_prec};
use super::probes::{combine, ProbeKind, ProbeSet};
use super::{BlockPartition, LogdetEstimate};
use crate::error::Result;
use crate::linalg::tridiag::lanczos_quadrature;
use crate::operators::{KernelOp, LinOp};
use crate::solvers::precond::{PreconditionedOp, Preconditioner};
use crate::util::parallel;

/// Options for the SLQ estimator.
#[derive(Clone, Copy, Debug)]
pub struct SlqOptions {
    /// Lanczos steps m (paper uses 25–30 in the experiments).
    pub steps: usize,
    /// Number of probe vectors (paper: 5–10).
    pub probes: usize,
    pub kind: ProbeKind,
    pub seed: u64,
    /// Also estimate all hyper-derivatives.
    pub grads: bool,
    /// Worker threads across probe blocks (the same `util::parallel` pool
    /// the block-CG engine fans RHS groups over; estimates are
    /// bit-identical for every thread count). Defaults to the process
    /// default (`util::parallel::default_threads`, CLI `--threads`).
    pub threads: usize,
    /// Probe-block width b for blocked MVMs (1 reproduces the per-probe
    /// path apply-for-apply; estimates are identical either way).
    pub block_size: usize,
    /// MVM precision for the Lanczos block applies
    /// ([`super::lanczos::lanczos_block_prec`]): `F64` is bit-identical to
    /// the pre-knob estimator; `F32F64` tridiagonalizes the (deterministic)
    /// storage-rounded operator, perturbing the quadrature values well
    /// below the estimator's own Monte-Carlo noise. Derivative passes
    /// (`apply_grad_all_mat`) and preconditioner algebra always stay f64.
    /// Defaults to the process default (CLI `--precision`).
    pub precision: crate::util::precision::Precision,
}

impl Default for SlqOptions {
    fn default() -> Self {
        SlqOptions {
            steps: 25,
            probes: 5,
            kind: ProbeKind::Rademacher,
            seed: 0,
            grads: true,
            threads: parallel::default_threads(),
            block_size: super::default_block_size(),
            precision: crate::util::precision::default_precision(),
        }
    }
}

/// Per-block partial results (kept per-column so the cross-block reduction
/// accumulates in probe order, independent of the block width).
struct PerBlock {
    quads: Vec<f64>,
    /// Per column: one term per hyper.
    grad_terms: Vec<Vec<f64>>,
    mvms: usize,
    block_applies: usize,
}

/// Estimate `log|K̃|` (and optionally all derivatives) via SLQ, optionally
/// through a preconditioner — the single driver behind [`slq_logdet`].
/// `pc = None` runs plain SLQ (every conditional below falls back to the
/// raw operator and probe block, so nothing changes bitwise).
///
/// With a preconditioner, the estimator uses the identity
/// `log|K̃| = log|P| + tr log(M)` with `M = P^{-1/2} K̃ P^{-1/2}`: Lanczos
/// runs on the split operator (whose spectrum is flattened, so fewer steps
/// resolve the quadrature), the exact `log|P|` is folded into every
/// per-probe value, and the derivative terms use
/// `tr(K̃⁻¹ ∂K̃) = E[(P^{-1/2} M⁻¹ z)ᵀ ∂K̃ (P^{-1/2} z)]` — the Lanczos
/// solve `M⁻¹ z` is the same free §3.2 byproduct, mapped back through the
/// low-rank `P^{-1/2}`. The identity holds for any fixed SPD `P`, so no
/// `∂P` terms arise even though `P` was built at the current hypers.
pub fn slq_logdet_pc(
    op: &dyn KernelOp,
    pc: Option<&dyn Preconditioner>,
    opts: &SlqOptions,
) -> Result<LogdetEstimate> {
    let n = op.n();
    let probes = ProbeSet::new(n, opts.probes, opts.kind, opts.seed);
    let z = probes.as_mat();
    let nh = op.num_hypers();
    let part = BlockPartition::new(opts.probes, opts.block_size);
    let ld_p = pc.map(|p| p.logdet());
    let pop = pc.map(|p| PreconditionedOp::new(op, p));

    let results: Vec<Result<PerBlock>> =
        parallel::par_map(part.nblocks, opts.threads, |bi| {
            let (j0, w) = part.range(bi);
            let zblk = z.sub_cols(j0, w);
            let res = match &pop {
                Some(pop) => lanczos_block_prec(pop, &zblk, opts.steps.min(n), opts.precision),
                None => lanczos_block_prec(op, &zblk, opts.steps.min(n), opts.precision),
            };
            let mut quads = Vec::with_capacity(w);
            let mut mvms = 0;
            let mut block_applies = 0;
            for r in &res {
                let q = lanczos_quadrature(&r.alphas, &r.betas, r.znorm * r.znorm, |lam| {
                    lam.max(1e-300).ln()
                })?;
                // Each preconditioned per-probe value carries its share of
                // the exact log|P| correction so the combine step needs no
                // special casing.
                quads.push(match ld_p {
                    Some(ld) => q + ld,
                    None => q,
                });
                mvms += r.mvms;
                // The block loop runs as long as its longest column.
                block_applies = block_applies.max(r.mvms);
            }
            let mut grad_terms = Vec::new();
            if opts.grads {
                // One blocked derivative pass per hyper covers all probes;
                // preconditioned, the pass runs over V = P^{-1/2} Z.
                let vblk;
                let vref = match pc {
                    Some(p) => {
                        vblk = p.apply_inv_sqrt_mat(&zblk);
                        &vblk
                    }
                    None => &zblk,
                };
                let dks = op.apply_grad_all_mat(vref);
                mvms += nh * w;
                block_applies += nh;
                for (c, r) in res.iter().enumerate() {
                    let g = r.solve_e1(); // ≈ M^{-1} z_c (K̃^{-1} z_c when pc is off)
                    let u = match pc {
                        Some(p) => p.apply_inv_sqrt_vec(&g),
                        None => g,
                    };
                    grad_terms.push(dks.iter().map(|dk| dk.col_dot(c, &u)).collect());
                }
            }
            Ok(PerBlock { quads, grad_terms, mvms, block_applies })
        });

    reduce_blocks(results, opts, nh)
}

/// Cross-block reduction of the SLQ driver: accumulates per-probe values
/// and gradient terms in probe order (independent of block width) and
/// assembles the estimate.
fn reduce_blocks(
    results: Vec<Result<PerBlock>>,
    opts: &SlqOptions,
    nh: usize,
) -> Result<LogdetEstimate> {
    let mut per_probe = Vec::with_capacity(opts.probes);
    let mut grad = vec![0.0; if opts.grads { nh } else { 0 }];
    let mut mvms = 0;
    let mut block_applies = 0;
    for r in results {
        let r = r?;
        per_probe.extend(r.quads);
        for gt in &r.grad_terms {
            for (gi, t) in grad.iter_mut().zip(gt) {
                *gi += t;
            }
        }
        mvms += r.mvms;
        block_applies += r.block_applies;
    }
    for gi in grad.iter_mut() {
        *gi /= opts.probes as f64;
    }
    let (value, std_err) = combine(&per_probe);
    Ok(LogdetEstimate { value, grad, std_err, per_probe, mvms, block_applies })
}

/// Estimate `log|K̃|` (and optionally all derivatives) via SLQ.
pub fn slq_logdet(op: &dyn KernelOp, opts: &SlqOptions) -> Result<LogdetEstimate> {
    slq_logdet_pc(op, None, opts)
}

/// Generic SLQ trace estimate of `tr(f(A))` for any SPD [`LinOp`] — used by
/// the Laplace approximation for `log|B|` where B has no hyper structure.
/// Probes are processed in [`super::default_block_size`]-wide blocks.
pub fn slq_trace_fn<O: LinOp + ?Sized>(
    op: &O,
    f: impl Fn(f64) -> f64 + Sync,
    steps: usize,
    probes: usize,
    seed: u64,
    threads: usize,
) -> Result<(f64, f64)> {
    let n = op.n();
    let ps = ProbeSet::new(n, probes, ProbeKind::Rademacher, seed);
    let z = ps.as_mat();
    let part = BlockPartition::new(probes, super::default_block_size());
    let blocks: Vec<Result<Vec<f64>>> = parallel::par_map(part.nblocks, threads, |bi| {
        let (j0, w) = part.range(bi);
        let zblk = z.sub_cols(j0, w);
        lanczos_block(op, &zblk, steps.min(n))
            .iter()
            .map(|r| lanczos_quadrature(&r.alphas, &r.betas, r.znorm * r.znorm, &f))
            .collect()
    });
    let mut vals = Vec::with_capacity(probes);
    for blk in blocks {
        vals.extend(blk?);
    }
    Ok(combine(&vals))
}

/// Solve estimates `g_p ≈ K̃^{-1} z_p` for a probe set, re-using one Lanczos
/// run per probe (used by the Hessian estimator and error analysis §4).
/// Runs in [`super::default_block_size`]-wide blocks of probes.
pub fn slq_solves(
    op: &dyn KernelOp,
    probes: &ProbeSet,
    steps: usize,
    threads: usize,
) -> Vec<Vec<f64>> {
    let count = probes.count();
    let z = probes.as_mat();
    let part = BlockPartition::new(count, super::default_block_size());
    let groups: Vec<Vec<Vec<f64>>> = parallel::par_map(part.nblocks, threads, |bi| {
        let (j0, w) = part.range(bi);
        let zblk = z.sub_cols(j0, w);
        lanczos_block(op, &zblk, steps.min(op.n()))
            .iter()
            .map(|r| r.solve_e1())
            .collect()
    });
    groups.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimators::exact;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::DenseKernelOp;
    use crate::util::rng::Rng;

    fn op(n: usize, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.3,
        )
    }

    #[test]
    fn logdet_close_to_exact() {
        let o = op(150, 1);
        let opts = SlqOptions { steps: 30, probes: 8, seed: 3, ..Default::default() };
        let est = slq_logdet(&o, &opts).unwrap();
        let truth = exact::exact_logdet(&o).unwrap();
        assert!(
            (est.value - truth).abs() < 0.05 * truth.abs().max(1.0) + 4.0 * est.std_err,
            "{} vs {} (se {})",
            est.value,
            truth,
            est.std_err
        );
    }

    #[test]
    fn grads_close_to_exact() {
        let o = op(100, 2);
        let opts = SlqOptions { steps: 60, probes: 64, seed: 5, ..Default::default() };
        let est = slq_logdet(&o, &opts).unwrap();
        let (_, tg) = exact::exact_logdet_grads_dense(&o).unwrap();
        for i in 0..tg.len() {
            assert!(
                (est.grad[i] - tg[i]).abs() < 0.15 * tg[i].abs().max(1.0),
                "hyper {i}: {} vs {}",
                est.grad[i],
                tg[i]
            );
        }
    }

    #[test]
    fn more_probes_reduce_stderr() {
        let o = op(120, 3);
        let few = slq_logdet(
            &o,
            &SlqOptions { steps: 25, probes: 3, grads: false, seed: 1, ..Default::default() },
        )
        .unwrap();
        let many = slq_logdet(
            &o,
            &SlqOptions { steps: 25, probes: 24, grads: false, seed: 1, ..Default::default() },
        )
        .unwrap();
        assert!(many.std_err < few.std_err + 1e-9);
    }

    #[test]
    fn trace_fn_identity_is_trace() {
        let o = op(60, 4);
        // f(x) = x: tr(K̃) = sum diag.
        let (est, se) = slq_trace_fn(&o, |x| x, 25, 32, 9, 4).unwrap();
        let truth: f64 = o.diag().unwrap().iter().sum();
        assert!((est - truth).abs() < 5.0 * se + 0.05 * truth.abs());
    }

    #[test]
    fn mvm_accounting() {
        let o = op(50, 5);
        let opts =
            SlqOptions { steps: 10, probes: 2, grads: true, block_size: 2, ..Default::default() };
        let est = slq_logdet(&o, &opts).unwrap();
        // Probe-column MVMs are block-size independent: 10 Lanczos + nh
        // derivative MVMs per probe.
        assert_eq!(est.mvms, 2 * (10 + o.num_hypers()));
        // Block-amortized: one 2-wide block -> 10 Lanczos block applies +
        // nh derivative block applies.
        assert_eq!(est.block_applies, 10 + o.num_hypers());
        // At block_size 1 the two units coincide.
        let est1 = slq_logdet(
            &o,
            &SlqOptions { steps: 10, probes: 2, grads: true, block_size: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(est1.block_applies, est1.mvms);
    }

    #[test]
    fn pc_none_is_plain_slq_bitwise() {
        let o = op(70, 11);
        let opts = SlqOptions { steps: 20, probes: 6, seed: 9, ..Default::default() };
        let plain = slq_logdet(&o, &opts).unwrap();
        let pc = slq_logdet_pc(&o, None, &opts).unwrap();
        assert_eq!(plain.value.to_bits(), pc.value.to_bits());
        assert_eq!(plain.std_err.to_bits(), pc.std_err.to_bits());
        for (a, b) in plain.grad.iter().zip(&pc.grad) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(plain.mvms, pc.mvms);
    }

    /// Preconditioned SLQ + the exact log|P| correction reproduces the
    /// exact log determinant on a small ill-conditioned matrix.
    #[test]
    fn preconditioned_logdet_close_to_exact() {
        use crate::solvers::precond::{build_preconditioner, PrecondOptions};
        let o = {
            // Small sigma: the regime plain SLQ struggles in.
            let mut rng = Rng::new(31);
            let pts: Vec<Vec<f64>> =
                (0..120).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
            DenseKernelOp::new(
                pts,
                Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
                0.05,
            )
        };
        let truth = exact::exact_logdet(&o).unwrap();
        let pc = build_preconditioner(&o, PrecondOptions::rank(32)).unwrap();
        let est = slq_logdet_pc(
            &o,
            Some(&pc),
            &SlqOptions { steps: 30, probes: 16, seed: 5, ..Default::default() },
        )
        .unwrap();
        assert!(
            (est.value - truth).abs() < 0.02 * truth.abs().max(1.0) + 4.0 * est.std_err,
            "{} vs {} (se {})",
            est.value,
            truth,
            est.std_err
        );
    }

    /// At full rank P == K̃: the stochastic part sees the identity, so the
    /// estimate collapses onto the exact value with near-zero error.
    #[test]
    fn full_rank_preconditioner_gives_exact_logdet() {
        use crate::solvers::precond::{build_preconditioner, PrecondOptions};
        let o = op(60, 13);
        let truth = exact::exact_logdet(&o).unwrap();
        let pc =
            build_preconditioner(&o, PrecondOptions { rank: 60, rel_tol: 0.0 }).unwrap();
        let est = slq_logdet_pc(
            &o,
            Some(&pc),
            &SlqOptions { steps: 10, probes: 3, grads: false, seed: 7, ..Default::default() },
        )
        .unwrap();
        assert!(
            (est.value - truth).abs() < 1e-5 * (1.0 + truth.abs()),
            "{} vs {truth}",
            est.value
        );
        assert!(est.std_err < 1e-5, "std_err {}", est.std_err);
    }

    /// Preconditioned derivative estimates track the exact gradients.
    #[test]
    fn preconditioned_grads_close_to_exact() {
        use crate::solvers::precond::{build_preconditioner, PrecondOptions};
        let o = op(100, 17);
        let pc = build_preconditioner(&o, PrecondOptions::rank(24)).unwrap();
        let est = slq_logdet_pc(
            &o,
            Some(&pc),
            &SlqOptions { steps: 40, probes: 64, seed: 5, ..Default::default() },
        )
        .unwrap();
        let (_, tg) = exact::exact_logdet_grads_dense(&o).unwrap();
        for i in 0..tg.len() {
            assert!(
                (est.grad[i] - tg[i]).abs() < 0.15 * tg[i].abs().max(1.0),
                "hyper {i}: {} vs {}",
                est.grad[i],
                tg[i]
            );
        }
    }

    /// The flattened spectrum needs fewer Lanczos steps: quadrature
    /// convergence on the split operator is at least 2x faster than on the
    /// raw ill-conditioned operator.
    #[test]
    fn preconditioning_cuts_lanczos_steps() {
        use super::super::lanczos::logdet_steps_to_tol;
        use crate::solvers::precond::{build_preconditioner, PrecondOptions, Preconditioner};
        let mut rng = Rng::new(37);
        let pts: Vec<Vec<f64>> =
            (0..150).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        let o = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            1e-2,
        );
        let pc = build_preconditioner(&o, PrecondOptions::rank(32)).unwrap();
        let mut z = vec![0.0; 150];
        rng.fill_gaussian(&mut z);
        let tol = 1e-4;
        let raw_steps = logdet_steps_to_tol(&o, None, &z, 150, tol).unwrap();
        let pc_steps =
            logdet_steps_to_tol(&o, Some(&pc as &dyn Preconditioner), &z, 150, tol).unwrap();
        assert!(
            2 * pc_steps <= raw_steps,
            "preconditioning saved less than 2x Lanczos steps: {pc_steps} vs {raw_steps}"
        );
    }

    #[test]
    fn block_size_does_not_change_estimates() {
        let o = op(90, 7);
        let base = slq_logdet(
            &o,
            &SlqOptions { steps: 20, probes: 10, seed: 3, block_size: 1, ..Default::default() },
        )
        .unwrap();
        for bs in [3, 8, 10, 64] {
            let blocked = slq_logdet(
                &o,
                &SlqOptions { steps: 20, probes: 10, seed: 3, block_size: bs, ..Default::default() },
            )
            .unwrap();
            assert_eq!(
                base.value.to_bits(),
                blocked.value.to_bits(),
                "bs={bs}: {} vs {}",
                base.value,
                blocked.value
            );
            assert_eq!(base.std_err.to_bits(), blocked.std_err.to_bits(), "bs={bs}");
            for (a, b) in base.grad.iter().zip(&blocked.grad) {
                assert_eq!(a.to_bits(), b.to_bits(), "bs={bs} grad");
            }
            assert_eq!(base.mvms, blocked.mvms, "bs={bs} probe-column mvms");
        }
    }
}
