//! Stochastic Lanczos quadrature for `log|K̃|` and its derivatives
//! (paper §3.2) — the method the paper recommends.
//!
//! Per probe z:
//!   1. m Lanczos steps give tridiagonal T and basis Q (m MVMs);
//!   2. `z^T log(K̃) z ≈ ||z||^2 e_1^T log(T) e_1` (Gauss quadrature, Eq. 3);
//!   3. `g = Q T^{-1} e_1 ||z|| ≈ K̃^{-1} z` — *no additional MVMs*;
//!   4. `∂_i log|K̃| ≈ mean_z [ g^T (∂K̃/∂θ_i) z ]` — one derivative MVM per
//!      hyper per probe.
//!
//! The driver is **blocked**: probes are drawn as one `n x p` matrix,
//! sliced into `block_size`-wide blocks, and each Lanczos iteration /
//! derivative pass is a single block MVM over the whole block
//! ([`super::lanczos::lanczos_block`], `apply_grad_all_mat`). Per-probe
//! arithmetic is unchanged, so estimates are bit-identical across block
//! sizes; see the module docs of [`crate::estimators`] for the accounting
//! convention (`mvms` vs `block_applies`).

use super::confidence;
use super::lanczos::{lanczos_block, lanczos_block_prec, LanczosSession};
use super::probes::{combine, ProbeKind, ProbeSet};
use super::{BlockPartition, LanczosProbe, LogdetEstimate, SpectralEvidence};
use crate::error::Result;
use crate::linalg::dense::Mat;
use crate::linalg::tridiag::lanczos_quadrature;
use crate::operators::{KernelOp, LinOp};
use crate::solvers::precond::{PreconditionedOp, Preconditioner};
use crate::util::obs;
use crate::util::parallel;

/// Options for the SLQ estimator.
#[derive(Clone, Copy, Debug)]
pub struct SlqOptions {
    /// Lanczos steps m (paper uses 25–30 in the experiments).
    pub steps: usize,
    /// Number of probe vectors (paper: 5–10). With `target_tol` set this
    /// is only the *seed* of the adaptive schedule; the driver may stop
    /// earlier (never before 2 probes) or grow up to `max_probes`.
    pub probes: usize,
    pub kind: ProbeKind,
    pub seed: u64,
    /// Also estimate all hyper-derivatives.
    pub grads: bool,
    /// Worker threads across probe blocks (the same `util::parallel` pool
    /// the block-CG engine fans RHS groups over; estimates are
    /// bit-identical for every thread count). Defaults to the process
    /// default (`util::parallel::default_threads`, CLI `--threads`).
    pub threads: usize,
    /// Probe-block width b for blocked MVMs (1 reproduces the per-probe
    /// path apply-for-apply; estimates are identical either way).
    pub block_size: usize,
    /// MVM precision for the Lanczos block applies
    /// ([`super::lanczos::lanczos_block_prec`]): `F64` is bit-identical to
    /// the pre-knob estimator; `F32F64` tridiagonalizes the (deterministic)
    /// storage-rounded operator, perturbing the quadrature values well
    /// below the estimator's own Monte-Carlo noise. Derivative passes
    /// (`apply_grad_all_mat`) and preconditioner algebra always stay f64.
    /// Defaults to the process default (CLI `--precision`).
    pub precision: crate::util::precision::Precision,
    /// Adaptive stopping tolerance: `Some(tol)` switches the probe loop to
    /// an incremental-budget driver that grows the probe set until the
    /// 95% confidence half-width ([`super::confidence`]) clears `tol` (or
    /// `max_probes` is hit). `None` (the default, also CLI
    /// `--logdet-tol`) runs the fixed budget — **bit-identical** to the
    /// pre-evidence estimator: same probe set, same partition, same
    /// accumulation order.
    pub target_tol: Option<f64>,
    /// Probe ceiling for adaptive mode (clamped to >= 2; ignored when
    /// `target_tol` is `None`).
    pub max_probes: usize,
    /// Lanczos-step ceiling for the adaptive driver's **step axis**:
    /// the two-axis driver starts every probe at `steps` and may extend
    /// the retained sessions up to this ceiling when the truncation term
    /// dominates the interval. `0` (the default) means *auto*: the axis
    /// may grow to `2 × steps`. `max_steps == steps` disables step growth
    /// (the probes-only driver). Ignored when `target_tol` is `None`.
    pub max_steps: usize,
}

impl Default for SlqOptions {
    fn default() -> Self {
        SlqOptions {
            steps: super::default_steps().unwrap_or(25),
            probes: super::default_probes().unwrap_or(5),
            kind: ProbeKind::Rademacher,
            seed: 0,
            grads: true,
            threads: parallel::default_threads(),
            block_size: super::default_block_size(),
            precision: crate::util::precision::default_precision(),
            target_tol: super::default_logdet_tol(),
            max_probes: 64,
            max_steps: super::default_max_steps(),
        }
    }
}

/// Per-block partial results (kept per-column so the cross-block reduction
/// accumulates in probe order, independent of the block width).
#[derive(Clone)]
struct PerBlock {
    quads: Vec<f64>,
    /// Per column: one term per hyper.
    grad_terms: Vec<Vec<f64>>,
    /// Per column: the retained Lanczos tridiagonal.
    evidence: Vec<LanczosProbe>,
    mvms: usize,
    block_applies: usize,
}

/// Estimate `log|K̃|` (and optionally all derivatives) via SLQ, optionally
/// through a preconditioner — the single driver behind [`slq_logdet`].
/// `pc = None` runs plain SLQ (every conditional below falls back to the
/// raw operator and probe block, so nothing changes bitwise).
///
/// With a preconditioner, the estimator uses the identity
/// `log|K̃| = log|P| + tr log(M)` with `M = P^{-1/2} K̃ P^{-1/2}`: Lanczos
/// runs on the split operator (whose spectrum is flattened, so fewer steps
/// resolve the quadrature), the exact `log|P|` is folded into every
/// per-probe value, and the derivative terms use
/// `tr(K̃⁻¹ ∂K̃) = E[(P^{-1/2} M⁻¹ z)ᵀ ∂K̃ (P^{-1/2} z)]` — the Lanczos
/// solve `M⁻¹ z` is the same free §3.2 byproduct, mapped back through the
/// low-rank `P^{-1/2}`. The identity holds for any fixed SPD `P`, so no
/// `∂P` terms arise even though `P` was built at the current hypers.
pub fn slq_logdet_pc(
    op: &dyn KernelOp,
    pc: Option<&dyn Preconditioner>,
    opts: &SlqOptions,
) -> Result<LogdetEstimate> {
    let _span = crate::span!("slq");
    let audit = obs::audit_begin();
    let est = match opts.target_tol {
        None => slq_fixed(op, pc, opts),
        Some(tol) => slq_adaptive(op, pc, opts, tol),
    }?;
    obs::add(obs::Counter::Probes, est.probes_used as u64);
    obs::add(obs::Counter::Steps, est.steps_used as u64);
    audit.end_assert(
        "slq",
        &[
            (obs::Counter::Mvms, est.mvms as u64),
            (obs::Counter::BlockApplies, est.block_applies as u64),
        ],
    );
    Ok(est)
}

/// Fixed-budget path: one probe set of exactly `opts.probes` columns, one
/// pass over the block partition — the accumulation order (and therefore
/// every output bit) matches the pre-evidence estimator.
fn slq_fixed(
    op: &dyn KernelOp,
    pc: Option<&dyn Preconditioner>,
    opts: &SlqOptions,
) -> Result<LogdetEstimate> {
    let n = op.n();
    let probes = ProbeSet::new(n, opts.probes, opts.kind, opts.seed);
    let z = probes.as_mat();
    let nh = op.num_hypers();
    let results = run_blocks(op, pc, opts, &z, 0, opts.probes, opts.steps.min(n), nh);
    let mut blocks = Vec::with_capacity(results.len());
    for r in results {
        blocks.push(r?);
    }
    Ok(assemble(&blocks, opts, nh, opts.probes, pc.map(|p| p.logdet()).unwrap_or(0.0)))
}

/// One retained probe block of the two-axis adaptive driver: the live
/// Lanczos session plus the original probe columns (kept verbatim for the
/// deferred derivative pass — reconstructing them from the normalized
/// basis would not be bitwise faithful).
struct SessionBlock {
    zblk: Mat,
    session: LanczosSession,
}

/// Ceiling of the adaptive step/degree axis: `max_steps` when set
/// (clamped to `[start, hi]`), else auto — `2 × start` (still capped at
/// `hi`, which is `n` for Lanczos). `cap == start` means the axis is
/// closed from the outset (the probes-only driver).
pub(super) fn step_axis_cap(start: usize, max_steps: usize, hi: usize) -> usize {
    match max_steps {
        0 => (2 * start).min(hi),
        m => m.clamp(start, hi),
    }
}

/// Next step budget on the step axis: 1.5× growth, at least +1, capped.
pub(super) fn next_step_budget(cur: usize, cap: usize) -> usize {
    (cur + (cur / 2).max(1)).min(cap)
}

/// Two-axis incremental-budget path. The probe matrix is drawn once at
/// `max_probes` width (`ProbeSet` draws column-by-column, so the first j
/// columns are identical for any width >= j — growing the budget never
/// redraws earlier probes) and consumed in chunks, each chunk's blocks
/// retained as live [`LanczosSession`]s. After each budget change the
/// interval half-width is split into its Monte-Carlo and truncation
/// components ([`confidence::half_width_parts`]) and the dominant axis
/// grows: **probes** when the Student-t term dominates (chunk schedule:
/// 2 first — the minimum yielding a finite interval — then
/// `(done/2).clamp(1, block_size)`), **steps** when the truncation term
/// does (`extend()` on every retained session, 1.5× growth up to
/// [`step_axis_cap`]). The loop stops once the half-width clears `tol` —
/// never before 2 probes — or both axes are exhausted. An extension that
/// advances no column (every column terminally broke down) closes the
/// step axis.
///
/// Because `extend` is bit-identical to a from-scratch run at the final
/// step count and probe chunks never redraw, the returned estimate
/// (value, per-probe quadratures, gradients, `mvms`, budgets — not
/// `block_applies`, whose amortization depends on the chunk partition)
/// is **bitwise equal** to a fixed-budget run at
/// `(probes: probes_used, steps: steps_used)`. Gradients are deferred to
/// one pass per retained block at the final budget, accumulated in probe
/// order exactly like the fixed path.
fn slq_adaptive(
    op: &dyn KernelOp,
    pc: Option<&dyn Preconditioner>,
    opts: &SlqOptions,
    tol: f64,
) -> Result<LogdetEstimate> {
    let n = op.n();
    let nh = op.num_hypers();
    let max_probes = opts.max_probes.max(2);
    let start_steps = opts.steps.min(n).max(1);
    let step_cap = step_axis_cap(start_steps, opts.max_steps, n);
    let probes = ProbeSet::new(n, max_probes, opts.kind, opts.seed);
    let z = probes.as_mat();
    let ld_p = pc.map(|p| p.logdet());
    let offset = ld_p.unwrap_or(0.0);
    let pop = pc.map(|p| PreconditionedOp::new(op, p));
    let mut blocks: Vec<SessionBlock> = Vec::new();
    let mut done = 0usize;
    let mut steps = start_steps;
    let mut step_axis_open = step_cap > steps;
    loop {
        // Grow the probe axis (also the entry path: the 2-probe seed).
        let chunk = if done == 0 {
            2.min(max_probes)
        } else {
            (done / 2).clamp(1, opts.block_size.max(1)).min(max_probes - done)
        };
        let part = BlockPartition::new(chunk, opts.block_size);
        let cur_steps = steps;
        let new_blocks = {
            let _chunk_span = crate::span!("slq_probe_chunk");
            parallel::par_map(part.nblocks, opts.threads, |bi| {
                let (j0, w) = part.range(bi);
                let zblk = z.sub_cols(done + j0, w);
                let mut session = LanczosSession::new(&zblk);
                match &pop {
                    Some(pop) => session.extend(pop, cur_steps, opts.precision),
                    None => session.extend(op, cur_steps, opts.precision),
                }
                SessionBlock { zblk, session }
            })
        };
        blocks.extend(new_blocks);
        done += chunk;
        // Deepen the step axis while the truncation term dominates; fall
        // through to grow probes once the Monte-Carlo term does.
        loop {
            let (per_probe, probe_ev) = eval_sessions(&blocks, ld_p)?;
            let probe_view =
                SpectralEvidence::Lanczos { probes: probe_ev, offset, resume: None };
            let (mc, trunc) = confidence::half_width_parts(
                &per_probe,
                &probe_view,
                confidence::DEFAULT_LEVEL,
            );
            let probe_room = done < max_probes;
            if (done >= 2 && mc + trunc <= tol) || (!probe_room && !step_axis_open) {
                let probe_ev = match probe_view {
                    SpectralEvidence::Lanczos { probes, .. } => probes,
                    _ => unreachable!(),
                };
                return assemble_sessions(op, pc, opts, nh, blocks, per_probe, probe_ev, offset);
            }
            if step_axis_open && (trunc > mc || !probe_room) {
                let target = next_step_budget(steps, step_cap);
                let before: usize = blocks.iter().map(|b| b.session.total_steps()).sum();
                extend_blocks(&mut blocks, op, &pop, target, opts);
                let after: usize = blocks.iter().map(|b| b.session.total_steps()).sum();
                if after == before {
                    // Every column terminally broke down: the axis is dead.
                    step_axis_open = false;
                } else {
                    steps = target;
                    step_axis_open = steps < step_cap;
                }
                continue;
            }
            break;
        }
    }
}

/// Extend every retained session to `target` steps, fanned across the
/// worker pool (sessions are independent, so the schedule cannot change
/// any bit of any column).
fn extend_blocks(
    blocks: &mut [SessionBlock],
    op: &dyn KernelOp,
    pop: &Option<PreconditionedOp>,
    target: usize,
    opts: &SlqOptions,
) {
    let _span = crate::span!("slq_step_extend");
    let slots: Vec<std::sync::Mutex<&mut SessionBlock>> =
        blocks.iter_mut().map(std::sync::Mutex::new).collect();
    parallel::par_map(slots.len(), opts.threads, |i| {
        let mut slot = slots[i].lock().expect("session slot");
        match pop {
            Some(pop) => slot.session.extend(pop, target, opts.precision),
            None => slot.session.extend(op, target, opts.precision),
        }
    });
}

/// Read per-probe quadratures + evidence off the retained sessions, in
/// probe order — the same arithmetic `run_blocks` applies to frozen
/// results, so re-evaluating after an `extend` stays bitwise faithful to
/// a from-scratch run at the current budget.
fn eval_sessions(
    blocks: &[SessionBlock],
    ld_p: Option<f64>,
) -> Result<(Vec<f64>, Vec<LanczosProbe>)> {
    let mut per_probe = Vec::new();
    let mut probe_ev = Vec::new();
    for b in blocks {
        for c in 0..b.session.num_cols() {
            let col = b.session.col(c);
            let znorm2 = col.znorm() * col.znorm();
            let q = lanczos_quadrature(col.alphas(), col.betas(), znorm2, |lam| {
                lam.max(1e-300).ln()
            })?;
            per_probe.push(match ld_p {
                Some(ld) => q + ld,
                None => q,
            });
            probe_ev.push(LanczosProbe {
                alphas: col.alphas().to_vec(),
                betas: col.betas().to_vec(),
                znorm2,
            });
        }
    }
    Ok((per_probe, probe_ev))
}

/// Final assembly of the two-axis driver: deferred derivative pass (one
/// per retained block, probe-order accumulation — bitwise the fixed
/// path's arithmetic), MVM accounting off the sessions, and the evidence
/// carrying **resume handles** so a caller can keep extending where the
/// driver stopped.
#[allow(clippy::too_many_arguments)]
fn assemble_sessions(
    op: &dyn KernelOp,
    pc: Option<&dyn Preconditioner>,
    opts: &SlqOptions,
    nh: usize,
    blocks: Vec<SessionBlock>,
    per_probe: Vec<f64>,
    probe_ev: Vec<LanczosProbe>,
    offset: f64,
) -> Result<LogdetEstimate> {
    let probes_used = per_probe.len();
    let mut grad = vec![0.0; if opts.grads { nh } else { 0 }];
    let mut mvms: usize = blocks.iter().map(|b| b.session.mvms()).sum();
    let mut block_applies: usize =
        blocks.iter().map(|b| b.session.block_applies()).sum();
    if opts.grads {
        let terms: Vec<Vec<Vec<f64>>> =
            parallel::par_map(blocks.len(), opts.threads, |bi| {
                let b = &blocks[bi];
                let vblk;
                let vref = match pc {
                    Some(p) => {
                        vblk = p.apply_inv_sqrt_mat(&b.zblk);
                        &vblk
                    }
                    None => &b.zblk,
                };
                let dks = op.apply_grad_all_mat(vref);
                (0..b.session.num_cols())
                    .map(|c| {
                        let g = b.session.col(c).solve_e1();
                        let u = match pc {
                            Some(p) => p.apply_inv_sqrt_vec(&g),
                            None => g,
                        };
                        dks.iter().map(|dk| dk.col_dot(c, &u)).collect()
                    })
                    .collect()
            });
        for (b, block_terms) in blocks.iter().zip(&terms) {
            mvms += nh * b.session.num_cols();
            block_applies += nh;
            for gt in block_terms {
                for (gi, t) in grad.iter_mut().zip(gt) {
                    *gi += t;
                }
            }
        }
        for gi in grad.iter_mut() {
            *gi /= probes_used as f64;
        }
    }
    let (value, std_err) = combine(&per_probe);
    let steps_used = probe_ev.iter().map(|p| p.alphas.len()).max().unwrap_or(0);
    let resume = Some(std::sync::Arc::new(
        blocks.into_iter().map(|b| b.session).collect::<Vec<_>>(),
    ));
    let evidence = SpectralEvidence::Lanczos { probes: probe_ev, offset, resume };
    let interval =
        confidence::interval_from_parts(value, &per_probe, &evidence, confidence::DEFAULT_LEVEL);
    Ok(LogdetEstimate {
        value,
        grad,
        std_err,
        per_probe,
        mvms,
        block_applies,
        evidence,
        interval,
        probes_used,
        steps_used,
    })
}

/// Run the blocked Lanczos + quadrature (+ optional derivative) pass over
/// `count` probe columns of `z` starting at `base`. One `PerBlock` per
/// partition block, in probe order — shared by the fixed and adaptive
/// drivers so their per-probe arithmetic is byte-for-byte the same code.
fn run_blocks(
    op: &dyn KernelOp,
    pc: Option<&dyn Preconditioner>,
    opts: &SlqOptions,
    z: &Mat,
    base: usize,
    count: usize,
    steps: usize,
    nh: usize,
) -> Vec<Result<PerBlock>> {
    let part = BlockPartition::new(count, opts.block_size);
    let ld_p = pc.map(|p| p.logdet());
    let pop = pc.map(|p| PreconditionedOp::new(op, p));
    let _span = crate::span!("slq_probe_chunk");
    parallel::par_map(part.nblocks, opts.threads, |bi| {
        let (j0, w) = part.range(bi);
        let zblk = z.sub_cols(base + j0, w);
        let res = match &pop {
            Some(pop) => lanczos_block_prec(pop, &zblk, steps, opts.precision),
            None => lanczos_block_prec(op, &zblk, steps, opts.precision),
        };
        let mut quads = Vec::with_capacity(w);
        let mut evidence = Vec::with_capacity(w);
        let mut mvms = 0;
        let mut block_applies = 0;
        for r in &res {
            let q = lanczos_quadrature(&r.alphas, &r.betas, r.znorm * r.znorm, |lam| {
                lam.max(1e-300).ln()
            })?;
            // Each preconditioned per-probe value carries its share of
            // the exact log|P| correction so the combine step needs no
            // special casing.
            quads.push(match ld_p {
                Some(ld) => q + ld,
                None => q,
            });
            evidence.push(LanczosProbe {
                alphas: r.alphas.clone(),
                betas: r.betas.clone(),
                znorm2: r.znorm * r.znorm,
            });
            mvms += r.mvms;
            // The block loop runs as long as its longest column.
            block_applies = block_applies.max(r.mvms);
        }
        let mut grad_terms = Vec::new();
        if opts.grads {
            // One blocked derivative pass per hyper covers all probes;
            // preconditioned, the pass runs over V = P^{-1/2} Z.
            let vblk;
            let vref = match pc {
                Some(p) => {
                    vblk = p.apply_inv_sqrt_mat(&zblk);
                    &vblk
                }
                None => &zblk,
            };
            let dks = op.apply_grad_all_mat(vref);
            mvms += nh * w;
            block_applies += nh;
            for (c, r) in res.iter().enumerate() {
                let g = r.solve_e1(); // ≈ M^{-1} z_c (K̃^{-1} z_c when pc is off)
                let u = match pc {
                    Some(p) => p.apply_inv_sqrt_vec(&g),
                    None => g,
                };
                grad_terms.push(dks.iter().map(|dk| dk.col_dot(c, &u)).collect());
            }
        }
        Ok(PerBlock { quads, grad_terms, evidence, mvms, block_applies })
    })
}

/// Cross-block reduction of the SLQ driver: accumulates per-probe values
/// and gradient terms in probe order (independent of block width),
/// re-synthesizes the confidence interval from the retained evidence, and
/// assembles the estimate. `probes_used` is the gradient divisor (== the
/// number of probe columns the blocks cover).
fn assemble(
    blocks: &[PerBlock],
    opts: &SlqOptions,
    nh: usize,
    probes_used: usize,
    offset: f64,
) -> LogdetEstimate {
    let mut per_probe = Vec::with_capacity(probes_used);
    let mut probe_ev = Vec::with_capacity(probes_used);
    let mut grad = vec![0.0; if opts.grads { nh } else { 0 }];
    let mut mvms = 0;
    let mut block_applies = 0;
    for r in blocks {
        per_probe.extend_from_slice(&r.quads);
        probe_ev.extend(r.evidence.iter().cloned());
        for gt in &r.grad_terms {
            for (gi, t) in grad.iter_mut().zip(gt) {
                *gi += t;
            }
        }
        mvms += r.mvms;
        block_applies += r.block_applies;
    }
    for gi in grad.iter_mut() {
        *gi /= probes_used as f64;
    }
    let (value, std_err) = combine(&per_probe);
    let steps_used = probe_ev.iter().map(|p| p.alphas.len()).max().unwrap_or(0);
    let evidence = SpectralEvidence::Lanczos { probes: probe_ev, offset, resume: None };
    let interval =
        confidence::interval_from_parts(value, &per_probe, &evidence, confidence::DEFAULT_LEVEL);
    LogdetEstimate {
        value,
        grad,
        std_err,
        per_probe,
        mvms,
        block_applies,
        evidence,
        interval,
        probes_used,
        steps_used,
    }
}

/// Estimate `log|K̃|` (and optionally all derivatives) via SLQ.
pub fn slq_logdet(op: &dyn KernelOp, opts: &SlqOptions) -> Result<LogdetEstimate> {
    slq_logdet_pc(op, None, opts)
}

/// Generic SLQ trace estimate of `tr(f(A))` for any SPD [`LinOp`] with the
/// full evidence/interval surface — used by the Laplace approximation for
/// `log|B|` where B has no hyper structure (the returned `grad` is empty).
/// Probes are processed in [`super::default_block_size`]-wide blocks.
///
/// Note the interval's truncation term is derived from the retained
/// tridiagonals under the *logdet* integrand; for `f` far from `ln` it is
/// only a convergence heuristic (the Monte-Carlo term is exact either way).
pub fn slq_trace_fn_ev<O: LinOp + ?Sized>(
    op: &O,
    f: impl Fn(f64) -> f64 + Sync,
    steps: usize,
    probes: usize,
    seed: u64,
    threads: usize,
) -> Result<LogdetEstimate> {
    let _span = crate::span!("slq_trace");
    let audit = obs::audit_begin();
    let n = op.n();
    let ps = ProbeSet::new(n, probes, ProbeKind::Rademacher, seed);
    let z = ps.as_mat();
    let part = BlockPartition::new(probes, super::default_block_size());
    let blocks: Vec<Result<(Vec<f64>, Vec<LanczosProbe>, usize, usize)>> =
        parallel::par_map(part.nblocks, threads, |bi| {
            let (j0, w) = part.range(bi);
            let zblk = z.sub_cols(j0, w);
            let res = lanczos_block(op, &zblk, steps.min(n));
            let mut quads = Vec::with_capacity(w);
            let mut ev = Vec::with_capacity(w);
            let mut mvms = 0;
            let mut applies = 0;
            for r in &res {
                quads.push(lanczos_quadrature(&r.alphas, &r.betas, r.znorm * r.znorm, &f)?);
                ev.push(LanczosProbe {
                    alphas: r.alphas.clone(),
                    betas: r.betas.clone(),
                    znorm2: r.znorm * r.znorm,
                });
                mvms += r.mvms;
                applies = applies.max(r.mvms);
            }
            Ok((quads, ev, mvms, applies))
        });
    let mut per_probe = Vec::with_capacity(probes);
    let mut probe_ev = Vec::with_capacity(probes);
    let mut mvms = 0;
    let mut block_applies = 0;
    for blk in blocks {
        let (quads, ev, m, a) = blk?;
        per_probe.extend(quads);
        probe_ev.extend(ev);
        mvms += m;
        block_applies += a;
    }
    let (value, std_err) = combine(&per_probe);
    let steps_used = probe_ev.iter().map(|p| p.alphas.len()).max().unwrap_or(0);
    let evidence = SpectralEvidence::Lanczos { probes: probe_ev, offset: 0.0, resume: None };
    let interval =
        confidence::interval_from_parts(value, &per_probe, &evidence, confidence::DEFAULT_LEVEL);
    obs::add(obs::Counter::Probes, probes as u64);
    obs::add(obs::Counter::Steps, steps_used as u64);
    audit.end_assert(
        "slq_trace",
        &[
            (obs::Counter::Mvms, mvms as u64),
            (obs::Counter::BlockApplies, block_applies as u64),
        ],
    );
    Ok(LogdetEstimate {
        value,
        grad: Vec::new(),
        std_err,
        per_probe,
        mvms,
        block_applies,
        evidence,
        interval,
        probes_used: probes,
        steps_used,
    })
}

/// Generic SLQ trace estimate of `tr(f(A))` — `(value, std_err)` view of
/// [`slq_trace_fn_ev`] (same probes, same arithmetic, same bits).
pub fn slq_trace_fn<O: LinOp + ?Sized>(
    op: &O,
    f: impl Fn(f64) -> f64 + Sync,
    steps: usize,
    probes: usize,
    seed: u64,
    threads: usize,
) -> Result<(f64, f64)> {
    let est = slq_trace_fn_ev(op, f, steps, probes, seed, threads)?;
    Ok((est.value, est.std_err))
}

/// Solve estimates `g_p ≈ K̃^{-1} z_p` for a probe set, re-using one Lanczos
/// run per probe (used by the Hessian estimator and error analysis §4).
/// Runs in [`super::default_block_size`]-wide blocks of probes.
pub fn slq_solves(
    op: &dyn KernelOp,
    probes: &ProbeSet,
    steps: usize,
    threads: usize,
) -> Vec<Vec<f64>> {
    let count = probes.count();
    let z = probes.as_mat();
    let part = BlockPartition::new(count, super::default_block_size());
    let groups: Vec<Vec<Vec<f64>>> = parallel::par_map(part.nblocks, threads, |bi| {
        let (j0, w) = part.range(bi);
        let zblk = z.sub_cols(j0, w);
        lanczos_block(op, &zblk, steps.min(op.n()))
            .iter()
            .map(|r| r.solve_e1())
            .collect()
    });
    groups.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimators::exact;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::DenseKernelOp;
    use crate::util::rng::Rng;

    fn op(n: usize, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.3,
        )
    }

    #[test]
    fn logdet_close_to_exact() {
        let o = op(150, 1);
        let opts = SlqOptions { steps: 30, probes: 8, seed: 3, ..Default::default() };
        let est = slq_logdet(&o, &opts).unwrap();
        let truth = exact::exact_logdet(&o).unwrap();
        assert!(
            (est.value - truth).abs() < 0.05 * truth.abs().max(1.0) + 4.0 * est.std_err,
            "{} vs {} (se {})",
            est.value,
            truth,
            est.std_err
        );
    }

    #[test]
    fn grads_close_to_exact() {
        let o = op(100, 2);
        let opts = SlqOptions { steps: 60, probes: 64, seed: 5, ..Default::default() };
        let est = slq_logdet(&o, &opts).unwrap();
        let (_, tg) = exact::exact_logdet_grads_dense(&o).unwrap();
        for i in 0..tg.len() {
            assert!(
                (est.grad[i] - tg[i]).abs() < 0.15 * tg[i].abs().max(1.0),
                "hyper {i}: {} vs {}",
                est.grad[i],
                tg[i]
            );
        }
    }

    #[test]
    fn more_probes_reduce_stderr() {
        let o = op(120, 3);
        let few = slq_logdet(
            &o,
            &SlqOptions { steps: 25, probes: 3, grads: false, seed: 1, ..Default::default() },
        )
        .unwrap();
        let many = slq_logdet(
            &o,
            &SlqOptions { steps: 25, probes: 24, grads: false, seed: 1, ..Default::default() },
        )
        .unwrap();
        assert!(many.std_err < few.std_err + 1e-9);
    }

    #[test]
    fn trace_fn_identity_is_trace() {
        let o = op(60, 4);
        // f(x) = x: tr(K̃) = sum diag.
        let (est, se) = slq_trace_fn(&o, |x| x, 25, 32, 9, 4).unwrap();
        let truth: f64 = o.diag().unwrap().iter().sum();
        assert!((est - truth).abs() < 5.0 * se + 0.05 * truth.abs());
    }

    #[test]
    fn mvm_accounting() {
        let o = op(50, 5);
        let opts =
            SlqOptions { steps: 10, probes: 2, grads: true, block_size: 2, ..Default::default() };
        let est = slq_logdet(&o, &opts).unwrap();
        // Probe-column MVMs are block-size independent: 10 Lanczos + nh
        // derivative MVMs per probe.
        assert_eq!(est.mvms, 2 * (10 + o.num_hypers()));
        // Block-amortized: one 2-wide block -> 10 Lanczos block applies +
        // nh derivative block applies.
        assert_eq!(est.block_applies, 10 + o.num_hypers());
        // At block_size 1 the two units coincide.
        let est1 = slq_logdet(
            &o,
            &SlqOptions { steps: 10, probes: 2, grads: true, block_size: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(est1.block_applies, est1.mvms);
    }

    #[test]
    fn pc_none_is_plain_slq_bitwise() {
        let o = op(70, 11);
        let opts = SlqOptions { steps: 20, probes: 6, seed: 9, ..Default::default() };
        let plain = slq_logdet(&o, &opts).unwrap();
        let pc = slq_logdet_pc(&o, None, &opts).unwrap();
        assert_eq!(plain.value.to_bits(), pc.value.to_bits());
        assert_eq!(plain.std_err.to_bits(), pc.std_err.to_bits());
        for (a, b) in plain.grad.iter().zip(&pc.grad) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(plain.mvms, pc.mvms);
    }

    /// Preconditioned SLQ + the exact log|P| correction reproduces the
    /// exact log determinant on a small ill-conditioned matrix.
    #[test]
    fn preconditioned_logdet_close_to_exact() {
        use crate::solvers::precond::{build_preconditioner, PrecondOptions};
        let o = {
            // Small sigma: the regime plain SLQ struggles in.
            let mut rng = Rng::new(31);
            let pts: Vec<Vec<f64>> =
                (0..120).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
            DenseKernelOp::new(
                pts,
                Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
                0.05,
            )
        };
        let truth = exact::exact_logdet(&o).unwrap();
        let pc = build_preconditioner(&o, PrecondOptions::rank(32)).unwrap();
        let est = slq_logdet_pc(
            &o,
            Some(&pc),
            &SlqOptions { steps: 30, probes: 16, seed: 5, ..Default::default() },
        )
        .unwrap();
        assert!(
            (est.value - truth).abs() < 0.02 * truth.abs().max(1.0) + 4.0 * est.std_err,
            "{} vs {} (se {})",
            est.value,
            truth,
            est.std_err
        );
    }

    /// At full rank P == K̃: the stochastic part sees the identity, so the
    /// estimate collapses onto the exact value with near-zero error.
    #[test]
    fn full_rank_preconditioner_gives_exact_logdet() {
        use crate::solvers::precond::{build_preconditioner, PrecondOptions};
        let o = op(60, 13);
        let truth = exact::exact_logdet(&o).unwrap();
        let pc =
            build_preconditioner(&o, PrecondOptions { rank: 60, rel_tol: 0.0 }).unwrap();
        let est = slq_logdet_pc(
            &o,
            Some(&pc),
            &SlqOptions { steps: 10, probes: 3, grads: false, seed: 7, ..Default::default() },
        )
        .unwrap();
        assert!(
            (est.value - truth).abs() < 1e-5 * (1.0 + truth.abs()),
            "{} vs {truth}",
            est.value
        );
        assert!(est.std_err < 1e-5, "std_err {}", est.std_err);
    }

    /// Preconditioned derivative estimates track the exact gradients.
    #[test]
    fn preconditioned_grads_close_to_exact() {
        use crate::solvers::precond::{build_preconditioner, PrecondOptions};
        let o = op(100, 17);
        let pc = build_preconditioner(&o, PrecondOptions::rank(24)).unwrap();
        let est = slq_logdet_pc(
            &o,
            Some(&pc),
            &SlqOptions { steps: 40, probes: 64, seed: 5, ..Default::default() },
        )
        .unwrap();
        let (_, tg) = exact::exact_logdet_grads_dense(&o).unwrap();
        for i in 0..tg.len() {
            assert!(
                (est.grad[i] - tg[i]).abs() < 0.15 * tg[i].abs().max(1.0),
                "hyper {i}: {} vs {}",
                est.grad[i],
                tg[i]
            );
        }
    }

    /// The flattened spectrum needs fewer Lanczos steps: quadrature
    /// convergence on the split operator is at least 2x faster than on the
    /// raw ill-conditioned operator.
    #[test]
    fn preconditioning_cuts_lanczos_steps() {
        use super::super::lanczos::logdet_steps_to_tol;
        use crate::solvers::precond::{build_preconditioner, PrecondOptions, Preconditioner};
        let mut rng = Rng::new(37);
        let pts: Vec<Vec<f64>> =
            (0..150).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        let o = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            1e-2,
        );
        let pc = build_preconditioner(&o, PrecondOptions::rank(32)).unwrap();
        let mut z = vec![0.0; 150];
        rng.fill_gaussian(&mut z);
        let tol = 1e-4;
        let raw_steps = logdet_steps_to_tol(&o, None, &z, 150, tol).unwrap();
        let pc_steps =
            logdet_steps_to_tol(&o, Some(&pc as &dyn Preconditioner), &z, 150, tol).unwrap();
        assert!(
            2 * pc_steps <= raw_steps,
            "preconditioning saved less than 2x Lanczos steps: {pc_steps} vs {raw_steps}"
        );
    }

    /// The inert adaptive knobs (`target_tol: None` with any
    /// `max_probes`/`max_steps`) leave every output bit of the fixed-budget
    /// path unchanged.
    #[test]
    fn inert_adaptive_knobs_are_bitwise_noop() {
        let o = op(80, 23);
        let base = slq_logdet(
            &o,
            &SlqOptions { steps: 20, probes: 6, seed: 4, ..Default::default() },
        )
        .unwrap();
        let knobs = slq_logdet(
            &o,
            &SlqOptions {
                steps: 20,
                probes: 6,
                seed: 4,
                target_tol: None,
                max_probes: 7,
                max_steps: 3,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(base.value.to_bits(), knobs.value.to_bits());
        assert_eq!(base.std_err.to_bits(), knobs.std_err.to_bits());
        assert_eq!(base.per_probe.len(), knobs.per_probe.len());
        for (a, b) in base.per_probe.iter().zip(&knobs.per_probe) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in base.grad.iter().zip(&knobs.grad) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(base.mvms, knobs.mvms);
        assert_eq!(base.block_applies, knobs.block_applies);
    }

    /// Adaptive mode on an easy (large-noise) operator stops with strictly
    /// fewer probes than the fixed default while clearing the tolerance.
    #[test]
    fn adaptive_uses_fewer_probes_when_easy() {
        let o = op(120, 41);
        let fixed = slq_logdet(
            &o,
            &SlqOptions { steps: 30, probes: 16, grads: false, seed: 2, ..Default::default() },
        )
        .unwrap();
        // Pick a tolerance the fixed 16-probe run comfortably clears, so the
        // adaptive driver can stop earlier.
        let tol = fixed.interval.half_width() * 2.0;
        let adaptive = slq_logdet(
            &o,
            &SlqOptions {
                steps: 30,
                probes: 16,
                grads: false,
                seed: 2,
                target_tol: Some(tol),
                max_probes: 64,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            adaptive.probes_used < 16,
            "adaptive used {} probes, fixed default 16",
            adaptive.probes_used
        );
        assert!(adaptive.interval.half_width() <= tol);
        assert_eq!(adaptive.per_probe.len(), adaptive.probes_used);
    }

    /// The adaptive driver never stops on a 1-probe interval, even with an
    /// absurdly loose tolerance: a single probe carries no spread
    /// information (its half-width is +inf by construction).
    #[test]
    fn adaptive_never_stops_at_one_probe() {
        let o = op(60, 8);
        let est = slq_logdet(
            &o,
            &SlqOptions {
                steps: 15,
                probes: 1,
                grads: false,
                seed: 6,
                target_tol: Some(1e12),
                max_probes: 64,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(est.probes_used >= 2, "stopped at {} probes", est.probes_used);
        assert!(est.interval.half_width().is_finite());
    }

    /// The two-axis driver's master invariant: whatever budgets it lands
    /// on, the final estimate is bitwise equal to a fixed-budget run at
    /// `(probes: probes_used, steps: steps_used)` — probe growth extends
    /// the same probe sequence and session extension is bit-identical to
    /// from-scratch Lanczos, so the adaptive path cannot drift.
    #[test]
    fn adaptive_probes_extend_fixed_sequence() {
        let o = op(70, 9);
        let adaptive = slq_logdet(
            &o,
            &SlqOptions {
                steps: 20,
                probes: 4,
                grads: true,
                seed: 11,
                block_size: 1,
                target_tol: Some(1e-9),
                max_probes: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let fixed = slq_logdet(
            &o,
            &SlqOptions {
                steps: adaptive.steps_used,
                probes: adaptive.probes_used,
                grads: true,
                seed: 11,
                block_size: 1,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(adaptive.per_probe.len(), fixed.per_probe.len());
        for (a, b) in adaptive.per_probe.iter().zip(&fixed.per_probe) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(adaptive.value.to_bits(), fixed.value.to_bits());
        for (a, b) in adaptive.grad.iter().zip(&fixed.grad) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(adaptive.mvms, fixed.mvms);
        assert_eq!(adaptive.steps_used, fixed.steps_used);
    }

    /// With a tight tolerance the step axis actually engages: the driver
    /// extends the retained sessions past the starting budget (up to the
    /// auto cap of 2x steps), and the final estimate carries resume
    /// handles that can be extended further.
    #[test]
    fn two_axis_driver_grows_steps_and_carries_resume_handles() {
        let o = op(80, 45);
        let est = slq_logdet(
            &o,
            &SlqOptions {
                steps: 6,
                probes: 4,
                grads: false,
                seed: 13,
                target_tol: Some(1e-9),
                max_probes: 8,
                ..Default::default()
            },
        )
        .unwrap();
        // Truncation at 6 steps dwarfs 1e-9, so the axis must have grown.
        assert!(
            est.steps_used > 6,
            "step axis never engaged: steps_used = {}",
            est.steps_used
        );
        assert!(est.steps_used <= 12, "auto cap 2x: {}", est.steps_used);
        let sessions = match &est.evidence {
            SpectralEvidence::Lanczos { resume: Some(s), .. } => s,
            other => panic!("adaptive estimate must carry resume handles, got {other:?}"),
        };
        let total_cols: usize = sessions.iter().map(|s| s.num_cols()).sum();
        assert_eq!(total_cols, est.probes_used);
        assert_eq!(
            sessions.iter().map(|s| s.mvms()).sum::<usize>(),
            est.mvms,
            "session MVM accounting must match the estimate"
        );
        // max_steps == steps is the probes-only escape hatch: no growth.
        let flat = slq_logdet(
            &o,
            &SlqOptions {
                steps: 6,
                probes: 4,
                grads: false,
                seed: 13,
                target_tol: Some(1e-9),
                max_probes: 8,
                max_steps: 6,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(flat.steps_used, 6);
    }

    /// Evidence retention: per-probe quadratures are recomputable from the
    /// retained tridiagonals, and the interval brackets the estimate.
    #[test]
    fn evidence_reproduces_per_probe_quadratures() {
        let o = op(50, 19);
        let est = slq_logdet(
            &o,
            &SlqOptions { steps: 15, probes: 4, grads: false, seed: 3, ..Default::default() },
        )
        .unwrap();
        match &est.evidence {
            SpectralEvidence::Lanczos { probes, offset, .. } => {
                assert_eq!(probes.len(), est.per_probe.len());
                for (p, q) in probes.iter().zip(&est.per_probe) {
                    let r = lanczos_quadrature(&p.alphas, &p.betas, p.znorm2, |lam| {
                        lam.max(1e-300).ln()
                    })
                    .unwrap();
                    assert_eq!((r + offset).to_bits(), q.to_bits());
                }
            }
            other => panic!("expected Lanczos evidence, got {other:?}"),
        }
        assert!(est.interval.contains(est.value));
        assert!(est.steps_used <= 15 && est.steps_used > 0);
    }

    #[test]
    fn block_size_does_not_change_estimates() {
        let o = op(90, 7);
        let base = slq_logdet(
            &o,
            &SlqOptions { steps: 20, probes: 10, seed: 3, block_size: 1, ..Default::default() },
        )
        .unwrap();
        for bs in [3, 8, 10, 64] {
            let blocked = slq_logdet(
                &o,
                &SlqOptions { steps: 20, probes: 10, seed: 3, block_size: bs, ..Default::default() },
            )
            .unwrap();
            assert_eq!(
                base.value.to_bits(),
                blocked.value.to_bits(),
                "bs={bs}: {} vs {}",
                base.value,
                blocked.value
            );
            assert_eq!(base.std_err.to_bits(), blocked.std_err.to_bits(), "bs={bs}");
            for (a, b) in base.grad.iter().zip(&blocked.grad) {
                assert_eq!(a.to_bits(), b.to_bits(), "bs={bs} grad");
            }
            assert_eq!(base.mvms, blocked.mvms, "bs={bs} probe-column mvms");
        }
    }
}
