//! Stochastic Lanczos quadrature for `log|K̃|` and its derivatives
//! (paper §3.2) — the method the paper recommends.
//!
//! Per probe z:
//!   1. m Lanczos steps give tridiagonal T and basis Q (m MVMs);
//!   2. `z^T log(K̃) z ≈ ||z||^2 e_1^T log(T) e_1` (Gauss quadrature, Eq. 3);
//!   3. `g = Q T^{-1} e_1 ||z|| ≈ K̃^{-1} z` — *no additional MVMs*;
//!   4. `∂_i log|K̃| ≈ mean_z [ g^T (∂K̃/∂θ_i) z ]` — one derivative MVM per
//!      hyper per probe.

use super::lanczos::lanczos;
use super::probes::{combine, ProbeKind, ProbeSet};
use super::LogdetEstimate;
use crate::error::Result;
use crate::linalg::tridiag::lanczos_quadrature;
use crate::operators::{KernelOp, LinOp};
use crate::util::parallel;
use crate::util::stats::dot;

/// Options for the SLQ estimator.
#[derive(Clone, Copy, Debug)]
pub struct SlqOptions {
    /// Lanczos steps m (paper uses 25–30 in the experiments).
    pub steps: usize,
    /// Number of probe vectors (paper: 5–10).
    pub probes: usize,
    pub kind: ProbeKind,
    pub seed: u64,
    /// Also estimate all hyper-derivatives.
    pub grads: bool,
    /// Worker threads across probes.
    pub threads: usize,
}

impl Default for SlqOptions {
    fn default() -> Self {
        SlqOptions {
            steps: 25,
            probes: 5,
            kind: ProbeKind::Rademacher,
            seed: 0,
            grads: true,
            threads: parallel::default_threads(),
        }
    }
}

/// Estimate `log|K̃|` (and optionally all derivatives) via SLQ.
pub fn slq_logdet(op: &dyn KernelOp, opts: &SlqOptions) -> Result<LogdetEstimate> {
    let n = op.n();
    let probes = ProbeSet::new(n, opts.probes, opts.kind, opts.seed);
    let nh = op.num_hypers();

    struct PerProbe {
        quad: f64,
        grad_terms: Vec<f64>,
        mvms: usize,
    }

    let results: Vec<Result<PerProbe>> =
        parallel::par_map(probes.count(), opts.threads, |p| {
            let z = &probes.z[p];
            let res = lanczos(op, z, opts.steps.min(n));
            let quad = lanczos_quadrature(
                &res.alphas,
                &res.betas,
                res.znorm * res.znorm,
                |lam| lam.max(1e-300).ln(),
            )?;
            let mut mvms = res.mvms;
            let mut grad_terms = Vec::new();
            if opts.grads {
                let g = res.solve_e1();
                let mut ys: Vec<Vec<f64>> = vec![vec![0.0; n]; nh];
                op.apply_grad_all(z, &mut ys);
                mvms += nh; // derivative MVMs
                grad_terms = ys.iter().map(|dkz| dot(&g, dkz)).collect();
            }
            Ok(PerProbe { quad, grad_terms, mvms })
        });

    let mut per_probe = Vec::with_capacity(opts.probes);
    let mut grad = vec![0.0; if opts.grads { nh } else { 0 }];
    let mut mvms = 0;
    for r in results {
        let r = r?;
        per_probe.push(r.quad);
        for (gi, t) in grad.iter_mut().zip(&r.grad_terms) {
            *gi += t;
        }
        mvms += r.mvms;
    }
    for gi in grad.iter_mut() {
        *gi /= opts.probes as f64;
    }
    let (value, std_err) = combine(&per_probe);
    Ok(LogdetEstimate { value, grad, std_err, per_probe, mvms })
}

/// Generic SLQ trace estimate of `tr(f(A))` for any SPD [`LinOp`] — used by
/// the Laplace approximation for `log|B|` where B has no hyper structure.
pub fn slq_trace_fn(
    op: &dyn LinOp,
    f: impl Fn(f64) -> f64 + Sync,
    steps: usize,
    probes: usize,
    seed: u64,
    threads: usize,
) -> Result<(f64, f64)> {
    let n = op.n();
    let ps = ProbeSet::new(n, probes, ProbeKind::Rademacher, seed);
    let samples: Vec<Result<f64>> = parallel::par_map(probes, threads, |p| {
        let res = lanczos(op, &ps.z[p], steps.min(n));
        lanczos_quadrature(&res.alphas, &res.betas, res.znorm * res.znorm, &f)
    });
    let mut vals = Vec::with_capacity(probes);
    for s in samples {
        vals.push(s?);
    }
    Ok(combine(&vals))
}

/// Solve estimates `g_p ≈ K̃^{-1} z_p` for a probe set, re-using one Lanczos
/// run per probe (used by the Hessian estimator and error analysis §4).
pub fn slq_solves(op: &dyn KernelOp, probes: &ProbeSet, steps: usize, threads: usize) -> Vec<Vec<f64>> {
    parallel::par_map(probes.count(), threads, |p| {
        lanczos(op, &probes.z[p], steps.min(op.n())).solve_e1()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimators::exact;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::DenseKernelOp;
    use crate::util::rng::Rng;

    fn op(n: usize, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.3,
        )
    }

    #[test]
    fn logdet_close_to_exact() {
        let o = op(150, 1);
        let opts = SlqOptions { steps: 30, probes: 8, seed: 3, ..Default::default() };
        let est = slq_logdet(&o, &opts).unwrap();
        let truth = exact::exact_logdet(&o).unwrap();
        assert!(
            (est.value - truth).abs() < 0.05 * truth.abs().max(1.0) + 4.0 * est.std_err,
            "{} vs {} (se {})",
            est.value,
            truth,
            est.std_err
        );
    }

    #[test]
    fn grads_close_to_exact() {
        let o = op(100, 2);
        let opts = SlqOptions { steps: 60, probes: 64, seed: 5, ..Default::default() };
        let est = slq_logdet(&o, &opts).unwrap();
        let (_, tg) = exact::exact_logdet_grads_dense(&o).unwrap();
        for i in 0..tg.len() {
            assert!(
                (est.grad[i] - tg[i]).abs() < 0.15 * tg[i].abs().max(1.0),
                "hyper {i}: {} vs {}",
                est.grad[i],
                tg[i]
            );
        }
    }

    #[test]
    fn more_probes_reduce_stderr() {
        let o = op(120, 3);
        let few = slq_logdet(
            &o,
            &SlqOptions { steps: 25, probes: 3, grads: false, seed: 1, ..Default::default() },
        )
        .unwrap();
        let many = slq_logdet(
            &o,
            &SlqOptions { steps: 25, probes: 24, grads: false, seed: 1, ..Default::default() },
        )
        .unwrap();
        assert!(many.std_err < few.std_err + 1e-9);
    }

    #[test]
    fn trace_fn_identity_is_trace() {
        let o = op(60, 4);
        // f(x) = x: tr(K̃) = sum diag.
        let (est, se) = slq_trace_fn(&o, |x| x, 25, 32, 9, 4).unwrap();
        let truth: f64 = o.diag().unwrap().iter().sum();
        assert!((est - truth).abs() < 5.0 * se + 0.05 * truth.abs());
    }

    #[test]
    fn mvm_accounting() {
        let o = op(50, 5);
        let opts = SlqOptions { steps: 10, probes: 2, grads: true, ..Default::default() };
        let est = slq_logdet(&o, &opts).unwrap();
        // 10 MVMs + nh derivative MVMs per probe.
        assert_eq!(est.mvms, 2 * (10 + o.num_hypers()));
    }
}
