//! Hutchinson stochastic trace estimation probes (paper §3):
//! `tr(A) = E[z^T A z]` for probes with zero mean and unit variance.

use crate::util::rng::Rng;
use crate::util::stats;

/// Probe distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeKind {
    /// ±1 entries — the common (lowest-variance for many matrices) choice.
    Rademacher,
    /// Standard normal entries.
    Gaussian,
}

/// A set of probe vectors.
#[derive(Clone, Debug)]
pub struct ProbeSet {
    pub z: Vec<Vec<f64>>,
}

impl ProbeSet {
    pub fn new(n: usize, count: usize, kind: ProbeKind, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let z = (0..count)
            .map(|_| {
                let mut v = vec![0.0; n];
                match kind {
                    ProbeKind::Rademacher => rng.fill_rademacher(&mut v),
                    ProbeKind::Gaussian => rng.fill_gaussian(&mut v),
                }
                v
            })
            .collect();
        ProbeSet { z }
    }

    pub fn count(&self) -> usize {
        self.z.len()
    }

    pub fn n(&self) -> usize {
        self.z.first().map_or(0, |v| v.len())
    }

    /// Pack the whole set as one `n x count` probe matrix — the estimators'
    /// block drivers slice column ranges out of this and feed them to
    /// blocked MVMs. Column `p` is `z[p]`; draws are per-probe, so the
    /// matrix (and therefore every estimate) is identical for any block
    /// size.
    pub fn as_mat(&self) -> crate::linalg::dense::Mat {
        let mut m = crate::linalg::dense::Mat::zeros(self.n(), self.count());
        for (p, z) in self.z.iter().enumerate() {
            m.set_col(p, z);
        }
        m
    }
}

/// Combine per-probe quadratic-form samples into (trace estimate,
/// standard error) — the paper's a-posteriori error estimate (§4).
pub fn combine(samples: &[f64]) -> (f64, f64) {
    (stats::mean(samples), stats::std_err(samples))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;

    #[test]
    fn rademacher_entries() {
        let p = ProbeSet::new(50, 4, ProbeKind::Rademacher, 1);
        assert_eq!(p.count(), 4);
        assert_eq!(p.n(), 50);
        for z in &p.z {
            assert!(z.iter().all(|&v| v == 1.0 || v == -1.0));
        }
    }

    #[test]
    fn hutchinson_estimates_trace() {
        // tr(A) for a small symmetric A, averaged over many probes.
        let n = 8;
        let mut a = Mat::from_fn(n, n, |i, j| ((i * 3 + j) % 5) as f64 * 0.2);
        a.symmetrize();
        let tr: f64 = a.diag().iter().sum();
        for kind in [ProbeKind::Rademacher, ProbeKind::Gaussian] {
            let probes = ProbeSet::new(n, 4000, kind, 7);
            let samples: Vec<f64> = probes
                .z
                .iter()
                .map(|z| {
                    let az = a.matvec(z);
                    crate::util::stats::dot(z, &az)
                })
                .collect();
            let (est, se) = combine(&samples);
            assert!((est - tr).abs() < 4.0 * se + 0.1, "{kind:?}: {est} vs {tr}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = ProbeSet::new(10, 2, ProbeKind::Gaussian, 99);
        let b = ProbeSet::new(10, 2, ProbeKind::Gaussian, 99);
        assert_eq!(a.z, b.z);
    }
}
