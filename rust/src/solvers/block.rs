//! Block conjugate gradients: all right-hand sides advance in lockstep
//! through **one blocked [`LinOp::apply_mat`] per iteration**, so every
//! pass over the operator's structure (dense kernel entries, circulant
//! spectra, Kronecker modes) is amortized across the whole block — the
//! solver-side counterpart of the estimators' block-probe engine.
//!
//! Per-column arithmetic (alpha, beta, residual recurrences, convergence
//! and indefiniteness tests) is exactly the scalar path of
//! [`super::cg::cg_with_guess`]; combined with the operators' column-
//! independence contract (`apply_mat` column j ≡ `apply` of column j,
//! bitwise) the block solve is **bit-identical** to solving column by
//! column. Converged (or bailed) columns are *deflated*: they drop out of
//! the active block, so late stragglers don't force redundant applies for
//! the columns that finished early.
//!
//! When the right-hand sides span more than one `block_size`-wide group,
//! the groups run on `CgOptions::threads` [`crate::util::parallel`]
//! workers (one lockstep solve per worker at a time). Groups share no
//! state — each keeps its own lockstep/deflation/true-residual machinery
//! and writes disjoint columns of the output — so results stay
//! bit-identical for every thread count; the nested thread-budget guard
//! caps operator-level threading inside one group at the worker's share
//! of the requested threads, so the two levels compose instead of
//! multiplying.
//!
//! # Mixed precision as iterative refinement
//!
//! `CgOptions::precision = F32F64` routes the per-iteration block apply
//! through [`LinOp::apply_mat_prec`], so the *search* runs on cheap
//! reduced-precision MVMs — but the machinery that decides convergence is
//! untouched: the batched true-residual confirmation and the warm-start
//! residual go through [`LinOp::residual_mat`], which has **no precision
//! knob** and always evaluates `B − A X` in full f64. When the (mixed)
//! recurrence claims convergence but the f64 true residual disagrees, the
//! existing drift-restart path re-seeds the recurrence from the f64 true
//! residual and keeps iterating — that loop *is* iterative refinement
//! (inner: low-precision CG steps; outer: f64 residual correction),
//! bounded by `max_iters` like everything else. Consequences:
//!
//! * `converged == true` means `‖b − A x‖ ≤ tol · scale` **in f64**, in
//!   both precision modes — mixed precision can cost extra refinement
//!   restarts, never a falsely-converged answer;
//! * `precision = F64` calls the same `apply_mat` the pre-knob engine
//!   called (the trait routes `F64` straight there), so the default mode
//!   stays bit-identical.

use crate::linalg::dense::Mat;
use crate::operators::LinOp;
use crate::util::blocks::BlockPartition;
use crate::util::obs;
use crate::util::parallel;
use crate::util::stats::{axpy, dot, norm2};

use super::cg::{residual_scale, CgInfo, CgOptions};
use super::precond::Preconditioner;

/// Statistics for one block solve, mirroring
/// `LogdetEstimate::{mvms, block_applies}`.
#[derive(Clone, Debug)]
pub struct BlockCgInfo {
    /// Per-column run statistics — identical to what column-by-column
    /// [`super::cg::cg_with_guess`] reports for that column.
    pub cols: Vec<CgInfo>,
    /// Total probe-column MVMs (the sum of `cols[j].mvms`): the
    /// block-width-independent cost the paper's figures count.
    pub mvms: usize,
    /// Block-amortized applies: one per `apply_mat` call, however many
    /// columns it carried. Always `<= mvms`; equal when `block_size = 1`.
    /// Preconditioner applications are low-rank products, not operator
    /// MVMs, and are not counted here.
    pub block_applies: usize,
    /// Iterations observed saved by a warm-start strategy, relative to the
    /// caller's cold baseline (0 for plain cold solves). Set by callers
    /// that orchestrate warm starts across column groups — see
    /// `GpRegression::predict_var_info` — not by the solver itself.
    pub warm_saved_iters: usize,
}

impl BlockCgInfo {
    pub fn all_converged(&self) -> bool {
        self.cols.iter().all(|c| c.converged)
    }

    /// Largest per-column iteration count.
    pub fn max_iters(&self) -> usize {
        self.cols.iter().map(|c| c.iters).max().unwrap_or(0)
    }

    /// Largest per-column exit residual (NaN if any column's residual is
    /// NaN — a non-finite solve must not masquerade as a perfect one).
    pub fn worst_residual(&self) -> f64 {
        self.cols
            .iter()
            .map(|c| c.residual)
            .fold(0.0, |a, b| if a.is_nan() || b.is_nan() { f64::NAN } else { a.max(b) })
    }
}

/// Per-column lockstep state.
struct Col {
    /// Global column index in the RHS matrix.
    j: usize,
    x: Vec<f64>,
    r: Vec<f64>,
    p: Vec<f64>,
    rs_old: f64,
    scale: f64,
    info: CgInfo,
}

/// A finished column: just the solution and its statistics. The CG
/// scratch (`r`, `p`) is dropped when a group's lockstep loop exits, so
/// groups awaiting the merge hold only what the merge consumes — not ~3x
/// the solution memory per column.
struct SolvedCol {
    j: usize,
    x: Vec<f64>,
    info: CgInfo,
}

/// Strip a group's column states down to their [`SolvedCol`] results,
/// releasing the scratch vectors.
fn finish_group(cols: Vec<Col>) -> Vec<SolvedCol> {
    cols.into_iter().map(|s| SolvedCol { j: s.j, x: s.x, info: s.info }).collect()
}

/// Solve `A X = B` for all columns of `B`, `block_size` columns at a time.
///
/// `x0` supplies warm starts for every column (shape must match `b`).
/// Returns the solution block and per-column + block-amortized statistics.
pub fn cg_block<O: LinOp + ?Sized>(
    op: &O,
    b: &Mat,
    x0: Option<&Mat>,
    opts: &CgOptions,
) -> (Mat, BlockCgInfo) {
    let n = op.n();
    assert_eq!(b.rows, n);
    if let Some(g) = x0 {
        assert_eq!((g.rows, g.cols), (b.rows, b.cols));
    }
    let mut out = Mat::zeros(n, b.cols);
    let mut infos = vec![CgInfo { iters: 0, residual: 0.0, converged: false, mvms: 0 }; b.cols];
    if b.cols == 0 {
        return (
            out,
            BlockCgInfo { cols: infos, mvms: 0, block_applies: 0, warm_saved_iters: 0 },
        );
    }
    // Work-stealing over RHS groups: the groups are fully independent
    // (each keeps its own lockstep state and owns a disjoint column
    // range), so which worker solves a group — and in what steal order —
    // changes scheduling only, never results. Stealing matters because
    // group convergence is ragged: a worker whose group deflates early
    // pulls the next unsolved group instead of idling.
    let _span = crate::span!("cg_block");
    let audit = obs::audit_begin();
    let part = BlockPartition::new(b.cols, opts.block_size);
    let groups = parallel::par_map_steal(part.nblocks, opts.threads, |bi| {
        let (j0, w) = part.range(bi);
        solve_lockstep(op, b, x0, j0, w, opts)
    });
    let block_applies = merge_groups(groups, &mut out, &mut infos);
    let mvms = infos.iter().map(|c| c.mvms).sum();
    audit.end_assert(
        "cg_block",
        &[
            (obs::Counter::Mvms, mvms as u64),
            (obs::Counter::BlockApplies, block_applies as u64),
        ],
    );
    (out, BlockCgInfo { cols: infos, mvms, block_applies, warm_saved_iters: 0 })
}

/// Preconditioned block CG. `pc = None` is *exactly* [`cg_block`] — same
/// code path, bit-identical results. With a preconditioner, every column
/// runs the scalar PCG recurrences of [`super::cg::pcg_with_guess`] in
/// lockstep: one blocked operator apply **and one blocked `P⁻¹` apply**
/// per iteration, with the same convergence deflation and batched
/// true-residual confirmation as the unpreconditioned engine. Column `j`
/// is bitwise identical to scalar `pcg_with_guess` on column `j`.
pub fn pcg_block<O: LinOp + ?Sized>(
    op: &O,
    b: &Mat,
    x0: Option<&Mat>,
    pc: Option<&dyn Preconditioner>,
    opts: &CgOptions,
) -> (Mat, BlockCgInfo) {
    let Some(pc) = pc else {
        return cg_block(op, b, x0, opts);
    };
    let n = op.n();
    assert_eq!(b.rows, n);
    assert_eq!(pc.n(), n);
    if let Some(g) = x0 {
        assert_eq!((g.rows, g.cols), (b.rows, b.cols));
    }
    let mut out = Mat::zeros(n, b.cols);
    let mut infos = vec![CgInfo { iters: 0, residual: 0.0, converged: false, mvms: 0 }; b.cols];
    if b.cols == 0 {
        return (
            out,
            BlockCgInfo { cols: infos, mvms: 0, block_applies: 0, warm_saved_iters: 0 },
        );
    }
    // Same work-stealing group fan-out as [`cg_block`]; the blocked `P⁻¹`
    // applies are column-independent, so groups stay data-independent.
    let _span = crate::span!("pcg_block");
    let audit = obs::audit_begin();
    let part = BlockPartition::new(b.cols, opts.block_size);
    let groups = parallel::par_map_steal(part.nblocks, opts.threads, |bi| {
        let (j0, w) = part.range(bi);
        solve_lockstep_pc(op, pc, b, x0, j0, w, opts)
    });
    let block_applies = merge_groups(groups, &mut out, &mut infos);
    let mvms = infos.iter().map(|c| c.mvms).sum();
    audit.end_assert(
        "pcg_block",
        &[
            (obs::Counter::Mvms, mvms as u64),
            (obs::Counter::BlockApplies, block_applies as u64),
        ],
    );
    (out, BlockCgInfo { cols: infos, mvms, block_applies, warm_saved_iters: 0 })
}

/// Batched CG over independent column vectors — a thin wrapper that packs
/// the right-hand sides into one block and runs [`cg_block`].
pub fn cg_batch<O: LinOp + ?Sized>(
    op: &O,
    bs: &[Vec<f64>],
    opts: &CgOptions,
) -> Vec<(Vec<f64>, CgInfo)> {
    let n = op.n();
    let mut b = Mat::zeros(n, bs.len());
    for (j, col) in bs.iter().enumerate() {
        b.set_col(j, col);
    }
    let (x, info) = cg_block(op, &b, None, opts);
    info.cols
        .iter()
        .enumerate()
        .map(|(j, ci)| (x.col(j), *ci))
        .collect()
}

/// Merge per-group worker results back into the shared output — solved
/// columns land at their global column index (`SolvedCol::j`), so the
/// merged solution and per-column statistics are identical to the serial
/// engine's regardless of which worker finished when. Returns the summed
/// block-amortized apply count. The one merge contract for both the
/// plain and the preconditioned engine.
fn merge_groups(
    groups: Vec<(Vec<SolvedCol>, usize)>,
    out: &mut Mat,
    infos: &mut [CgInfo],
) -> usize {
    let mut block_applies = 0usize;
    for (cols, group_applies) in groups {
        block_applies += group_applies;
        for s in cols {
            out.set_col(s.j, &s.x);
            infos[s.j] = s.info;
        }
    }
    block_applies
}

/// Run one `w`-wide column group `[j0, j0 + w)` in lockstep to completion.
///
/// This is the per-worker unit of the RHS-group fan-out: it owns all its
/// state and returns the finished column states plus the group's
/// block-amortized apply count, so concurrent groups never touch shared
/// mutable data.
fn solve_lockstep<O: LinOp + ?Sized>(
    op: &O,
    b: &Mat,
    x0: Option<&Mat>,
    j0: usize,
    w: usize,
    opts: &CgOptions,
) -> (Vec<SolvedCol>, usize) {
    let n = op.n();
    let mut block_applies = 0usize;
    let mut cols: Vec<Col> = (j0..j0 + w)
        .map(|j| {
            let bj = b.col(j);
            let scale = residual_scale(norm2(&bj));
            let x = match x0 {
                Some(g) => g.col(j),
                None => vec![0.0; n],
            };
            Col {
                j,
                x,
                r: bj,
                p: Vec::new(),
                rs_old: 0.0,
                scale,
                info: CgInfo { iters: 0, residual: 0.0, converged: false, mvms: 0 },
            }
        })
        .collect();

    // Warm-start residual R = B − A X0 — one blocked apply for the group.
    if x0.is_some() {
        let all: Vec<usize> = (0..w).collect();
        let xblk = assemble(&cols, &all, Field::X);
        let rmat = op.residual_mat(&b.sub_cols(j0, w), &xblk);
        block_applies += 1;
        for (c, s) in cols.iter_mut().enumerate() {
            s.info.mvms += 1;
            rmat.col_into(c, &mut s.r);
        }
    }

    // Initial residual check (already the true residual) + deflation.
    let mut active: Vec<usize> = Vec::new();
    for (c, s) in cols.iter_mut().enumerate() {
        s.p = s.r.clone();
        s.rs_old = dot(&s.r, &s.r);
        s.info.residual = s.rs_old.sqrt() / s.scale;
        if s.info.residual <= opts.tol {
            s.info.converged = true;
        } else {
            active.push(c);
        }
    }

    let mut ap = vec![0.0; n];
    let mut rt = vec![0.0; n];
    for it in 0..opts.max_iters {
        if active.is_empty() {
            break;
        }
        // One blocked apply over all still-active search directions — in
        // `opts.precision` (the only reduced-precision step in the loop).
        let pblk = assemble(&cols, &active, Field::P);
        let apblk = op.apply_mat_prec(&pblk, opts.precision);
        block_applies += 1;

        let mut next_active: Vec<usize> = Vec::new();
        let mut bail: Vec<usize> = Vec::new();
        let mut check: Vec<usize> = Vec::new();
        for (c, &ci) in active.iter().enumerate() {
            let s = &mut cols[ci];
            s.info.mvms += 1;
            apblk.col_into(c, &mut ap);
            let pap = dot(&s.p, &ap);
            if pap <= 0.0 || !pap.is_finite() {
                // Indefiniteness bail: report the true residual (batched
                // below) and deflate with the best iterate.
                s.info.iters = it;
                bail.push(ci);
                continue;
            }
            let alpha = s.rs_old / pap;
            axpy(alpha, &s.p, &mut s.x);
            axpy(-alpha, &ap, &mut s.r);
            let rs_new = dot(&s.r, &s.r);
            s.info.iters = it + 1;
            s.info.residual = rs_new.sqrt() / s.scale;
            if s.info.residual <= opts.tol {
                // Recurrence passed — confirm against the true residual
                // (batched below); defer the beta/p update.
                check.push(ci);
                continue;
            }
            let beta = rs_new / s.rs_old;
            for i in 0..n {
                s.p[i] = s.r[i] + beta * s.p[i];
            }
            s.rs_old = rs_new;
            next_active.push(ci);
        }

        // Batched true-residual pass: convergence confirmations + bails
        // share one blocked apply.
        if !bail.is_empty() || !check.is_empty() {
            let idxs: Vec<usize> = bail.iter().chain(check.iter()).copied().collect();
            let xblk = assemble(&cols, &idxs, Field::X);
            let mut bblk = Mat::zeros(n, idxs.len());
            for (c, &ci) in idxs.iter().enumerate() {
                bblk.set_col(c, &b.col(cols[ci].j));
            }
            let rmat = op.residual_mat(&bblk, &xblk);
            block_applies += 1;
            let nbail = bail.len();
            for (c, &ci) in idxs.iter().enumerate() {
                let s = &mut cols[ci];
                s.info.mvms += 1;
                rmat.col_into(c, &mut rt);
                let rs_true = dot(&rt, &rt);
                s.info.residual = rs_true.sqrt() / s.scale;
                if c < nbail {
                    // Bailed column: stays non-converged, deflated.
                } else if s.info.residual <= opts.tol {
                    s.info.converged = true;
                } else {
                    // Drift: restart from the true residual, stay active.
                    s.r.copy_from_slice(&rt);
                    s.p.copy_from_slice(&rt);
                    s.rs_old = rs_true;
                    next_active.push(ci);
                }
            }
        }
        active = next_active;
    }

    (finish_group(cols), block_applies)
}

/// Run one `w`-wide column group `[j0, j0 + w)` of **preconditioned** CG in
/// lockstep to completion. Per-column arithmetic is exactly
/// [`super::cg::pcg_with_guess`]; the blocked `P⁻¹` applications go through
/// [`Preconditioner::apply_inv_mat`], whose columns are bitwise identical
/// to the scalar `apply_inv`, so the lockstep solve stays bit-identical to
/// column-by-column scalar PCG. Like [`solve_lockstep`], this is the
/// self-contained per-worker unit of the RHS-group fan-out.
fn solve_lockstep_pc<O: LinOp + ?Sized>(
    op: &O,
    pc: &dyn Preconditioner,
    b: &Mat,
    x0: Option<&Mat>,
    j0: usize,
    w: usize,
    opts: &CgOptions,
) -> (Vec<SolvedCol>, usize) {
    let n = op.n();
    let mut block_applies = 0usize;
    let mut cols: Vec<Col> = (j0..j0 + w)
        .map(|j| {
            let bj = b.col(j);
            let scale = residual_scale(norm2(&bj));
            let x = match x0 {
                Some(g) => g.col(j),
                None => vec![0.0; n],
            };
            Col {
                j,
                x,
                r: bj,
                p: Vec::new(),
                // Holds the PCG inner product r^T z (not r^T r).
                rs_old: 0.0,
                scale,
                info: CgInfo { iters: 0, residual: 0.0, converged: false, mvms: 0 },
            }
        })
        .collect();

    // Warm-start residual R = B − A X0 — one blocked apply for the group.
    if x0.is_some() {
        let all: Vec<usize> = (0..w).collect();
        let xblk = assemble(&cols, &all, Field::X);
        let rmat = op.residual_mat(&b.sub_cols(j0, w), &xblk);
        block_applies += 1;
        for (c, s) in cols.iter_mut().enumerate() {
            s.info.mvms += 1;
            rmat.col_into(c, &mut s.r);
        }
    }

    // Initial residual check (already the true residual) + deflation.
    let mut active: Vec<usize> = Vec::new();
    for (c, s) in cols.iter_mut().enumerate() {
        s.info.residual = norm2(&s.r) / s.scale;
        if s.info.residual <= opts.tol {
            s.info.converged = true;
        } else {
            active.push(c);
        }
    }

    // Initial preconditioned direction: one blocked P⁻¹ over the group.
    if !active.is_empty() {
        let rblk = assemble(&cols, &active, Field::R);
        let zblk = pc.apply_inv_mat(&rblk);
        let mut z = vec![0.0; n];
        for (c, &ci) in active.iter().enumerate() {
            let s = &mut cols[ci];
            zblk.col_into(c, &mut z);
            s.p = z.clone();
            s.rs_old = dot(&s.r, &z);
        }
    }

    let mut ap = vec![0.0; n];
    let mut rt = vec![0.0; n];
    let mut z = vec![0.0; n];
    for it in 0..opts.max_iters {
        if active.is_empty() {
            break;
        }
        // One blocked operator apply over all still-active directions — in
        // `opts.precision`; the P⁻¹ applies and the true-residual
        // confirmations below stay f64.
        let pblk = assemble(&cols, &active, Field::P);
        let apblk = op.apply_mat_prec(&pblk, opts.precision);
        block_applies += 1;

        let mut cont: Vec<usize> = Vec::new();
        let mut bail: Vec<usize> = Vec::new();
        let mut check: Vec<usize> = Vec::new();
        for (c, &ci) in active.iter().enumerate() {
            let s = &mut cols[ci];
            s.info.mvms += 1;
            apblk.col_into(c, &mut ap);
            let pap = dot(&s.p, &ap);
            if pap <= 0.0 || !pap.is_finite() {
                s.info.iters = it;
                bail.push(ci);
                continue;
            }
            let alpha = s.rs_old / pap;
            axpy(alpha, &s.p, &mut s.x);
            axpy(-alpha, &ap, &mut s.r);
            s.info.iters = it + 1;
            s.info.residual = norm2(&s.r) / s.scale;
            if s.info.residual <= opts.tol {
                // Recurrence passed — confirm the true residual (batched).
                check.push(ci);
                continue;
            }
            cont.push(ci);
        }

        let mut next_active: Vec<usize> = Vec::new();

        // Batched P⁻¹ over the columns that simply continue iterating.
        if !cont.is_empty() {
            let rblk = assemble(&cols, &cont, Field::R);
            let zblk = pc.apply_inv_mat(&rblk);
            for (c, &ci) in cont.iter().enumerate() {
                let s = &mut cols[ci];
                zblk.col_into(c, &mut z);
                let rz_new = dot(&s.r, &z);
                let beta = rz_new / s.rs_old;
                for i in 0..n {
                    s.p[i] = z[i] + beta * s.p[i];
                }
                s.rs_old = rz_new;
                next_active.push(ci);
            }
        }

        // Batched true-residual pass: confirmations + bails share one
        // blocked apply; drifted columns restart from the true residual
        // with one more blocked P⁻¹.
        if !bail.is_empty() || !check.is_empty() {
            let idxs: Vec<usize> = bail.iter().chain(check.iter()).copied().collect();
            let xblk = assemble(&cols, &idxs, Field::X);
            let mut bblk = Mat::zeros(n, idxs.len());
            for (c, &ci) in idxs.iter().enumerate() {
                bblk.set_col(c, &b.col(cols[ci].j));
            }
            let rmat = op.residual_mat(&bblk, &xblk);
            block_applies += 1;
            let nbail = bail.len();
            let mut drift: Vec<usize> = Vec::new();
            for (c, &ci) in idxs.iter().enumerate() {
                let s = &mut cols[ci];
                s.info.mvms += 1;
                rmat.col_into(c, &mut rt);
                s.info.residual = norm2(&rt) / s.scale;
                if c < nbail {
                    // Bailed column: stays non-converged, deflated.
                } else if s.info.residual <= opts.tol {
                    s.info.converged = true;
                } else {
                    s.r.copy_from_slice(&rt);
                    drift.push(ci);
                }
            }
            if !drift.is_empty() {
                let rblk = assemble(&cols, &drift, Field::R);
                let zblk = pc.apply_inv_mat(&rblk);
                for (c, &ci) in drift.iter().enumerate() {
                    let s = &mut cols[ci];
                    zblk.col_into(c, &mut z);
                    s.p.copy_from_slice(&z);
                    s.rs_old = dot(&s.r, &z);
                    next_active.push(ci);
                }
            }
        }
        active = next_active;
    }

    (finish_group(cols), block_applies)
}

/// Which per-column vector to pack into a block.
#[derive(Clone, Copy)]
enum Field {
    /// Current iterate `x`.
    X,
    /// Search direction `p`.
    P,
    /// Residual `r` (the input of the blocked `P⁻¹` applies).
    R,
}

/// Pack the selected column states' `field` vectors into an `n x k` block.
fn assemble(cols: &[Col], idxs: &[usize], field: Field) -> Mat {
    let n = cols[idxs[0]].x.len();
    let mut m = Mat::zeros(n, idxs.len());
    for (c, &ci) in idxs.iter().enumerate() {
        let v: &[f64] = match field {
            Field::X => &cols[ci].x,
            Field::P => &cols[ci].p,
            Field::R => &cols[ci].r,
        };
        m.set_col(c, v);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::super::cg::{cg, cg_with_guess};
    use super::*;
    use crate::operators::DenseMatOp;

    fn spd_op(n: usize) -> DenseMatOp {
        let b = Mat::from_fn(n, n, |i, j| (((i + 2) * (j + 3)) % 11) as f64 / 11.0);
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64 * 0.4);
        DenseMatOp::new(a)
    }

    fn rhs(n: usize, k: usize) -> Mat {
        Mat::from_fn(n, k, |i, j| ((i * 7 + j * 13) % 19) as f64 / 19.0 - 0.4)
    }

    #[test]
    fn block_matches_scalar_bitwise() {
        let n = 24;
        let op = spd_op(n);
        let b = rhs(n, 5);
        for bs in [1usize, 2, 3, 5, 8] {
            let opts = CgOptions { tol: 1e-10, max_iters: 200, block_size: bs, ..Default::default() };
            let (x, info) = cg_block(&op, &b, None, &opts);
            assert_eq!(info.cols.len(), 5);
            for j in 0..5 {
                let (xs, si) = cg(&op, &b.col(j), &opts);
                for i in 0..n {
                    assert_eq!(x[(i, j)].to_bits(), xs[i].to_bits(), "bs={bs} ({i},{j})");
                }
                assert_eq!(info.cols[j].iters, si.iters, "bs={bs} col {j}");
                assert_eq!(info.cols[j].converged, si.converged);
                assert_eq!(info.cols[j].mvms, si.mvms);
                assert_eq!(info.cols[j].residual.to_bits(), si.residual.to_bits());
            }
            assert!(info.block_applies <= info.mvms);
            if bs == 1 {
                assert_eq!(info.block_applies, info.mvms);
            }
        }
    }

    #[test]
    fn warm_start_block_matches_scalar_bitwise() {
        let n = 18;
        let op = spd_op(n);
        let b = rhs(n, 4);
        let g = Mat::from_fn(n, 4, |i, j| ((i + j) % 5) as f64 * 0.1);
        let opts = CgOptions { tol: 1e-9, max_iters: 150, block_size: 4, ..Default::default() };
        let (x, info) = cg_block(&op, &b, Some(&g), &opts);
        for j in 0..4 {
            let gj = g.col(j);
            let (xs, si) = cg_with_guess(&op, &b.col(j), Some(&gj), &opts);
            for i in 0..n {
                assert_eq!(x[(i, j)].to_bits(), xs[i].to_bits(), "({i},{j})");
            }
            assert_eq!(info.cols[j].mvms, si.mvms);
        }
    }

    #[test]
    fn deflation_stops_charging_converged_columns() {
        let n = 16;
        let op = spd_op(n);
        // Column 0 is zero (converges instantly, 0 MVMs); column 1 is hard.
        let mut b = Mat::zeros(n, 2);
        b.set_col(1, &(0..n).map(|i| (i as f64 * 0.3).sin()).collect::<Vec<_>>());
        let opts = CgOptions { tol: 1e-10, max_iters: 200, block_size: 2, ..Default::default() };
        let (_, info) = cg_block(&op, &b, None, &opts);
        assert!(info.cols[0].converged);
        assert_eq!(info.cols[0].mvms, 0);
        assert!(info.cols[1].converged);
        assert!(info.cols[1].mvms > 0);
        assert!(info.block_applies <= info.mvms);
    }

    #[test]
    fn empty_rhs_is_fine() {
        let op = spd_op(6);
        let b = Mat::zeros(6, 0);
        let (x, info) = cg_block(&op, &b, None, &CgOptions::default());
        assert_eq!((x.rows, x.cols), (6, 0));
        assert!(info.cols.is_empty());
        assert_eq!(info.mvms, 0);
        assert_eq!(info.block_applies, 0);
        assert!(info.all_converged());
    }

    #[test]
    fn pcg_block_none_is_cg_block_bitwise() {
        let n = 20;
        let op = spd_op(n);
        let b = rhs(n, 4);
        let opts = CgOptions { tol: 1e-10, max_iters: 200, block_size: 3, ..Default::default() };
        let (xc, ic) = cg_block(&op, &b, None, &opts);
        let (xp, ip) = pcg_block(&op, &b, None, None, &opts);
        assert_eq!(xc.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   xp.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
        assert_eq!(ic.mvms, ip.mvms);
        assert_eq!(ic.block_applies, ip.block_applies);
    }

    #[test]
    fn pcg_block_matches_scalar_pcg_bitwise() {
        use super::super::cg::pcg_with_guess;
        use super::super::precond::{build_preconditioner, PrecondOptions};
        use crate::kernels::{IsoKernel, Shape};
        use crate::operators::DenseKernelOp;
        use crate::util::rng::Rng;
        let n = 26;
        let mut rng = Rng::new(41);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.05,
        );
        let pc = build_preconditioner(&op, PrecondOptions::rank(6)).unwrap();
        let b = rhs(n, 5);
        let g = Mat::from_fn(n, 5, |i, j| ((i + 2 * j) % 7) as f64 * 0.05);
        for x0 in [None, Some(&g)] {
            for bs in [1usize, 2, 5] {
                let opts =
                    CgOptions { tol: 1e-9, max_iters: 400, block_size: bs, ..Default::default() };
                let (x, info) = pcg_block(&op, &b, x0, Some(&pc), &opts);
                for j in 0..5 {
                    let gj = x0.map(|m| m.col(j));
                    let (xs, si) =
                        pcg_with_guess(&op, &b.col(j), gj.as_deref(), Some(&pc), &opts);
                    for i in 0..n {
                        assert_eq!(
                            x[(i, j)].to_bits(),
                            xs[i].to_bits(),
                            "warm={} bs={bs} ({i},{j})",
                            x0.is_some()
                        );
                    }
                    assert_eq!(info.cols[j].iters, si.iters, "bs={bs} col {j}");
                    assert_eq!(info.cols[j].converged, si.converged);
                    assert_eq!(info.cols[j].mvms, si.mvms);
                    assert_eq!(info.cols[j].residual.to_bits(), si.residual.to_bits());
                }
                assert!(info.block_applies <= info.mvms);
            }
        }
    }

    /// RHS-group fan-out changes scheduling only: solutions, per-column
    /// statistics, and block-amortized accounting are bit-identical for
    /// every thread count, cold and warm.
    #[test]
    fn thread_count_does_not_change_results() {
        let n = 22;
        let op = spd_op(n);
        let b = rhs(n, 7);
        let g = Mat::from_fn(n, 7, |i, j| ((i + 3 * j) % 6) as f64 * 0.07);
        for x0 in [None, Some(&g)] {
            for bs in [1usize, 2, 3] {
                let base = CgOptions {
                    tol: 1e-10,
                    max_iters: 200,
                    block_size: bs,
                    threads: 1,
                    ..Default::default()
                };
                let (x1, i1) = cg_block(&op, &b, x0, &base);
                for threads in [2usize, 8] {
                    let opts = CgOptions { threads, ..base };
                    let (xt, it) = cg_block(&op, &b, x0, &opts);
                    assert_eq!(
                        x1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        xt.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "warm={} bs={bs} threads={threads}",
                        x0.is_some()
                    );
                    assert_eq!(i1.mvms, it.mvms, "bs={bs} threads={threads}");
                    assert_eq!(i1.block_applies, it.block_applies);
                    for (a, c) in i1.cols.iter().zip(&it.cols) {
                        assert_eq!(a.iters, c.iters);
                        assert_eq!(a.converged, c.converged);
                        assert_eq!(a.residual.to_bits(), c.residual.to_bits());
                    }
                }
            }
        }
    }

    /// Same invariance through the preconditioned engine.
    #[test]
    fn pcg_thread_count_does_not_change_results() {
        use super::super::precond::{build_preconditioner, PrecondOptions};
        use crate::kernels::{IsoKernel, Shape};
        use crate::operators::DenseKernelOp;
        use crate::util::rng::Rng;
        let n = 24;
        let mut rng = Rng::new(53);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.1,
        );
        let pc = build_preconditioner(&op, PrecondOptions::rank(6)).unwrap();
        let b = rhs(n, 6);
        let base = CgOptions {
            tol: 1e-9,
            max_iters: 400,
            block_size: 2,
            threads: 1,
            ..Default::default()
        };
        let (x1, i1) = pcg_block(&op, &b, None, Some(&pc), &base);
        for threads in [2usize, 8] {
            let opts = CgOptions { threads, ..base };
            let (xt, it) = pcg_block(&op, &b, None, Some(&pc), &opts);
            assert_eq!(
                x1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                xt.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
            assert_eq!(i1.mvms, it.mvms);
            assert_eq!(i1.block_applies, it.block_applies);
        }
    }

    /// Mixed-precision refinement contract: with `precision = F32F64` the
    /// inner applies are reduced-precision, but every column that reports
    /// `converged` still satisfies `‖b − A x‖ ≤ tol · scale` measured in
    /// **f64** — cold and warm, plain and preconditioned.
    #[test]
    fn mixed_precision_converged_means_f64_residual() {
        use super::super::cg::residual_scale;
        use crate::util::precision::Precision;
        use crate::util::stats::norm2;
        let n = 30;
        let op = spd_op(n);
        let b = rhs(n, 5);
        let g = Mat::from_fn(n, 5, |i, j| ((i + 2 * j) % 9) as f64 * 0.04);
        for x0 in [None, Some(&g)] {
            let opts = CgOptions {
                tol: 1e-8,
                max_iters: 500,
                block_size: 3,
                precision: Precision::F32F64,
                ..Default::default()
            };
            let (x, info) = cg_block(&op, &b, x0, &opts);
            assert!(info.all_converged(), "warm={}: {:?}", x0.is_some(), info.cols);
            for j in 0..5 {
                let bj = b.col(j);
                let mut ax = vec![0.0; n];
                op.apply(&x.col(j), &mut ax);
                let rtrue: Vec<f64> = (0..n).map(|i| bj[i] - ax[i]).collect();
                let rel = norm2(&rtrue) / residual_scale(norm2(&bj));
                assert!(rel <= opts.tol, "warm={} col {j}: f64 residual {rel}", x0.is_some());
            }
        }
    }

    /// Same contract through the preconditioned engine, and the F64 arm of
    /// the knob stays bitwise the default path.
    #[test]
    fn mixed_precision_pcg_and_f64_identity() {
        use super::super::cg::residual_scale;
        use super::super::precond::{build_preconditioner, PrecondOptions};
        use crate::kernels::{IsoKernel, Shape};
        use crate::operators::DenseKernelOp;
        use crate::util::precision::Precision;
        use crate::util::rng::Rng;
        use crate::util::stats::norm2;
        let n = 28;
        let mut rng = Rng::new(67);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.1,
        );
        let pc = build_preconditioner(&op, PrecondOptions::rank(6)).unwrap();
        let b = rhs(n, 4);
        let base = CgOptions { tol: 1e-8, max_iters: 600, block_size: 2, ..Default::default() };
        // F64 knob == default path, bit for bit.
        let (xd, _) = pcg_block(&op, &b, None, Some(&pc), &base);
        let f64_opts = CgOptions { precision: Precision::F64, ..base };
        let (xf, _) = pcg_block(&op, &b, None, Some(&pc), &f64_opts);
        assert_eq!(
            xd.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            xf.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // Mixed: converged ⇒ f64 true residual within tol.
        let opts = CgOptions { precision: Precision::F32F64, ..base };
        let (x, info) = pcg_block(&op, &b, None, Some(&pc), &opts);
        assert!(info.all_converged(), "{:?}", info.cols);
        for j in 0..4 {
            let bj = b.col(j);
            let mut ax = vec![0.0; n];
            op.apply(&x.col(j), &mut ax);
            let rtrue: Vec<f64> = (0..n).map(|i| bj[i] - ax[i]).collect();
            let rel = norm2(&rtrue) / residual_scale(norm2(&bj));
            assert!(rel <= opts.tol, "col {j}: f64 residual {rel}");
        }
    }

    #[test]
    fn cg_batch_wraps_block() {
        let n = 20;
        let op = spd_op(n);
        let bs: Vec<Vec<f64>> = (0..3)
            .map(|j| (0..n).map(|i| ((i + j * 5) as f64 * 0.21).cos()).collect())
            .collect();
        let opts = CgOptions { tol: 1e-10, max_iters: 200, block_size: 3, ..Default::default() };
        let results = cg_batch(&op, &bs, &opts);
        assert_eq!(results.len(), 3);
        for (j, (x, info)) in results.iter().enumerate() {
            let (xs, si) = cg(&op, &bs[j], &opts);
            assert!(info.converged);
            assert_eq!(info.iters, si.iters);
            for i in 0..n {
                assert_eq!(x[i].to_bits(), xs[i].to_bits(), "col {j} row {i}");
            }
        }
    }
}
