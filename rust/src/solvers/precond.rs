//! Pivoted-Cholesky preconditioning for the iterative solvers and the
//! stochastic estimators.
//!
//! # The preconditioner contract
//!
//! A [`Preconditioner`] represents a fixed SPD operator `P ≈ K̃` whose
//! inverse, inverse square root, and log determinant are all cheap:
//!
//! * **`apply_inv`** applies `P⁻¹` (PCG's `z = P⁻¹ r`). The blocked entry
//!   point [`Preconditioner::apply_inv_mat`] obeys the same
//!   column-independence contract as [`LinOp::apply_mat`]: column `j` is
//!   bitwise identical to the single-vector path, so the block-PCG engine
//!   stays bit-identical to scalar PCG per column.
//! * **`apply_inv_sqrt`** applies a symmetric `P^{-1/2}` — used by the
//!   preconditioned SLQ split `P^{-1/2} K̃ P^{-1/2}`. It must satisfy
//!   `(P^{-1/2})² = P⁻¹` (up to the factor's orthonormality error) and be
//!   symmetric, so the split operator stays SPD.
//! * **`logdet`** is `log|P|` in closed form — the exact correction in the
//!   identity `log|K̃| = log|P| + tr log(P^{-1/2} K̃ P^{-1/2})`, so the
//!   stochastic part of the estimate only sees the flattened spectrum.
//!
//! [`PivCholPrecond`] is the concrete implementation over a rank-k pivoted
//! Cholesky factor ([`crate::linalg::pchol`]): `P = L Lᵀ + σ² I`. A thin
//! eigendecomposition of the k×k Gram matrix `Lᵀ L = V S² Vᵀ` yields
//! `L Lᵀ = U S² Uᵀ` with `U = L V S⁻¹` orthonormal, and then everything is
//! closed-form low-rank + scalar identity:
//!
//! ```text
//! P⁻¹      = σ⁻² I + U diag(1/(s²+σ²) − 1/σ²) Uᵀ          (Woodbury)
//! P^{-1/2} = σ⁻¹ I + U diag(1/√(s²+σ²) − 1/σ) Uᵀ
//! log|P|   = Σ_i log(s_i² + σ²) + (n − k) log σ²
//! ```
//!
//! Every application costs one `n×k` and one `k×n` product — no extra
//! kernel MVMs.

use crate::linalg::dense::Mat;
use crate::linalg::eigh::eigh;
use crate::linalg::pchol::{pivoted_cholesky, PivotedCholesky};
use crate::operators::{KernelOp, LinOp};

/// Configuration knob for building a pivoted-Cholesky preconditioner —
/// carried by `CgOptions` so every solve/estimate entry point shares it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecondOptions {
    /// Maximum factor rank k; 0 disables preconditioning entirely (every
    /// code path is then bit-identical to the unpreconditioned one).
    pub rank: usize,
    /// Early-stop tolerance on the pivoted Cholesky trace error, relative
    /// to the initial kernel trace.
    pub rel_tol: f64,
}

impl Default for PrecondOptions {
    fn default() -> Self {
        PrecondOptions { rank: super::default_precond_rank(), rel_tol: 1e-8 }
    }
}

impl PrecondOptions {
    /// Explicit-rank constructor (0 = off).
    pub fn rank(rank: usize) -> Self {
        PrecondOptions { rank, ..Default::default() }
    }
}

/// A fixed SPD preconditioner `P ≈ K̃`; see the module docs for the full
/// contract (`P⁻¹`, symmetric `P^{-1/2}`, exact `log|P|`).
pub trait Preconditioner: Send + Sync {
    fn n(&self) -> usize;

    /// y = P⁻¹ x.
    fn apply_inv(&self, x: &[f64], y: &mut [f64]);

    /// Y = P⁻¹ X, column j bitwise identical to [`Preconditioner::apply_inv`]
    /// on column j.
    fn apply_inv_mat(&self, x: &Mat) -> Mat;

    /// y = P^{-1/2} x (symmetric square root).
    fn apply_inv_sqrt(&self, x: &[f64], y: &mut [f64]);

    /// Y = P^{-1/2} X, column-independent like
    /// [`Preconditioner::apply_inv_mat`].
    fn apply_inv_sqrt_mat(&self, x: &Mat) -> Mat;

    /// log|P|, exact (no stochastic error) — the logdet-correction term.
    fn logdet(&self) -> f64;

    /// Allocating convenience wrapper over [`Preconditioner::apply_inv`].
    fn apply_inv_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n()];
        self.apply_inv(x, &mut y);
        y
    }

    /// Allocating convenience wrapper over
    /// [`Preconditioner::apply_inv_sqrt`].
    fn apply_inv_sqrt_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n()];
        self.apply_inv_sqrt(x, &mut y);
        y
    }
}

/// Woodbury/low-rank preconditioner `P = L Lᵀ + σ² I` over a pivoted
/// Cholesky factor (see module docs for the algebra).
pub struct PivCholPrecond {
    n: usize,
    sigma2: f64,
    /// Orthonormal column basis of the factor's range, `n x k`.
    u: Mat,
    /// `u` transposed (`k x n`), cached so blocked applies need no
    /// per-call transpose.
    ut: Mat,
    /// Eigenvalues `s²` of `L Lᵀ` restricted to the kept basis.
    s2: Vec<f64>,
    /// Weights `1/(s²+σ²) − 1/σ²` for `P⁻¹`.
    w_inv: Vec<f64>,
    /// Weights `1/√(s²+σ²) − 1/σ` for `P^{-1/2}`.
    w_sqrt: Vec<f64>,
    /// Exact residual trace `tr(K − L Lᵀ)` of the pivoted Cholesky this
    /// factor was built from (0 when constructed directly from a factor).
    /// The adaptive rank-growth loop reads this as its error signal.
    trace_error: f64,
}

impl PivCholPrecond {
    /// Build from an `n x k` factor and the noise level. `sigma2` must be
    /// positive (it is the smallest eigenvalue of P).
    pub fn new(l: &Mat, sigma2: f64) -> Self {
        assert!(sigma2 > 0.0, "preconditioner needs a positive noise floor");
        let n = l.rows;
        let k = l.cols;
        let (u, s2) = if k == 0 {
            (Mat::zeros(n, 0), Vec::new())
        } else {
            // Thin eigendecomposition of the k×k Gram matrix.
            let gram = l.transpose().matmul(l);
            let eig = eigh(&gram).expect("Gram matrix of a real factor is symmetric PSD");
            // Keep only numerically positive modes (ascending order from
            // eigh; take from the top).
            let smax = eig.eigvals.last().copied().unwrap_or(0.0).max(0.0);
            let floor = smax * 1e-14;
            let kept: Vec<usize> = (0..k)
                .rev()
                .filter(|&i| eig.eigvals[i] > floor && eig.eigvals[i] > 0.0)
                .collect();
            let mut u = Mat::zeros(n, kept.len());
            let mut s2 = Vec::with_capacity(kept.len());
            for (c, &i) in kept.iter().enumerate() {
                let si = eig.eigvals[i].sqrt();
                // u[:, c] = L v_i / s_i
                let vi = eig.eigvecs.col(i);
                let lv = l.matvec(&vi);
                u.set_col(c, &lv.iter().map(|x| x / si).collect::<Vec<_>>());
                s2.push(eig.eigvals[i]);
            }
            (u, s2)
        };
        let w_inv: Vec<f64> =
            s2.iter().map(|&s| 1.0 / (s + sigma2) - 1.0 / sigma2).collect();
        let sig = sigma2.sqrt();
        let w_sqrt: Vec<f64> =
            s2.iter().map(|&s| 1.0 / (s + sigma2).sqrt() - 1.0 / sig).collect();
        let ut = u.transpose();
        PivCholPrecond { n, sigma2, u, ut, s2, w_inv, w_sqrt, trace_error: 0.0 }
    }

    /// Rank actually kept (numerically positive modes of `L Lᵀ`).
    pub fn rank(&self) -> usize {
        self.s2.len()
    }

    /// Exact residual trace `tr(K − L Lᵀ)` of the factor this
    /// preconditioner was built from (0 for hand-built factors). Growing
    /// the build rank drives this toward 0; the adaptive `--logdet-tol`
    /// path grows `--precond-rank` until it clears a fraction of the
    /// requested tolerance.
    pub fn trace_error(&self) -> f64 {
        self.trace_error
    }

    /// Shared low-rank apply: `y = c0 x + U diag(w) Uᵀ x`.
    fn apply_lowrank(&self, w: &[f64], c0: f64, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        let t = self.u.matvec_t(x);
        let tw: Vec<f64> = t.iter().zip(w).map(|(ti, wi)| ti * wi).collect();
        self.u.matvec_into(&tw, y);
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += c0 * xi;
        }
    }

    /// Blocked counterpart of [`PivCholPrecond::apply_lowrank`], bitwise
    /// identical per column (the contractions run in the same ascending
    /// order as the single-vector path).
    fn apply_lowrank_mat(&self, w: &[f64], c0: f64, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.n);
        let mut t = self.ut.matmul(x);
        for (i, &wi) in w.iter().enumerate() {
            for v in t.row_mut(i) {
                *v *= wi;
            }
        }
        let mut y = self.u.matmul(&t);
        for (yi, xi) in y.data.iter_mut().zip(&x.data) {
            *yi += c0 * xi;
        }
        y
    }
}

impl Preconditioner for PivCholPrecond {
    fn n(&self) -> usize {
        self.n
    }
    fn apply_inv(&self, x: &[f64], y: &mut [f64]) {
        self.apply_lowrank(&self.w_inv, 1.0 / self.sigma2, x, y);
    }
    fn apply_inv_mat(&self, x: &Mat) -> Mat {
        self.apply_lowrank_mat(&self.w_inv, 1.0 / self.sigma2, x)
    }
    fn apply_inv_sqrt(&self, x: &[f64], y: &mut [f64]) {
        self.apply_lowrank(&self.w_sqrt, 1.0 / self.sigma2.sqrt(), x, y);
    }
    fn apply_inv_sqrt_mat(&self, x: &Mat) -> Mat {
        self.apply_lowrank_mat(&self.w_sqrt, 1.0 / self.sigma2.sqrt(), x)
    }
    fn logdet(&self) -> f64 {
        let k = self.s2.len();
        self.s2.iter().map(|&s| (s + self.sigma2).ln()).sum::<f64>()
            + (self.n - k) as f64 * self.sigma2.ln()
    }
}

/// Build a pivoted-Cholesky preconditioner for a kernel operator, or `None`
/// when preconditioning is off (`rank == 0`) or structurally unavailable
/// (the operator cannot supply its diagonal, or has no noise floor).
pub fn build_preconditioner(
    op: &dyn KernelOp,
    opts: PrecondOptions,
) -> Option<PivCholPrecond> {
    if opts.rank == 0 {
        return None;
    }
    let s2 = op.noise_var();
    if !(s2 > 0.0) {
        eprintln!("precond: operator has no positive noise floor; solves run unpreconditioned");
        return None;
    }
    let Some(pchol) = pivoted_cholesky(op, opts.rank, opts.rel_tol) else {
        eprintln!(
            "precond: operator does not expose diag(); solves run unpreconditioned"
        );
        return None;
    };
    Some(precond_from_factor(&pchol, s2))
}

/// Build a preconditioner directly from a retained pivoted-Cholesky
/// factor, carrying its trace-error bound. This is the incremental
/// rank-growth entry point: callers keep the [`PivotedCholesky`], call
/// [`PivotedCholesky::grow`] to append pivots (one kernel MVM each), and
/// rebuild only the cheap k×k eigendecomposition here — instead of
/// refactorizing from scratch at every rank bump.
pub fn precond_from_factor(pchol: &PivotedCholesky, sigma2: f64) -> PivCholPrecond {
    let mut pc = PivCholPrecond::new(&pchol.l, sigma2);
    pc.trace_error = pchol.trace_error;
    pc
}

/// The symmetric split `P^{-1/2} K̃ P^{-1/2}` as a [`LinOp`] — what the
/// preconditioned SLQ estimator runs Lanczos on. Its spectrum is the
/// flattened one; `log|K̃| = log|P| + tr log` of this operator.
pub struct PreconditionedOp<'a, O: LinOp + ?Sized> {
    pub op: &'a O,
    pub pc: &'a dyn Preconditioner,
}

impl<'a, O: LinOp + ?Sized> PreconditionedOp<'a, O> {
    pub fn new(op: &'a O, pc: &'a dyn Preconditioner) -> Self {
        assert_eq!(op.n(), pc.n());
        PreconditionedOp { op, pc }
    }
}

impl<O: LinOp + ?Sized> LinOp for PreconditionedOp<'_, O> {
    fn n(&self) -> usize {
        self.op.n()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let n = self.n();
        assert_eq!(x.len(), n);
        assert_eq!(y.len(), n);
        let s = self.pc.apply_inv_sqrt_vec(x);
        let t = self.op.apply_vec(&s);
        self.pc.apply_inv_sqrt(&t, y);
    }
    fn apply_mat(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs =
            crate::util::obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let s = self.pc.apply_inv_sqrt_mat(x);
        let t = self.op.apply_mat(&s);
        self.pc.apply_inv_sqrt_mat(&t)
    }
    /// Precision reaches only the wrapped operator's apply — the low-rank
    /// `P^{-1/2}` algebra on both sides stays f64 (it is a small-rank
    /// product, not the bandwidth-bound part). F64 forwards to `apply_mat`
    /// of the inner op, keeping the F64 arm bit-identical.
    fn apply_mat_prec(&self, x: &Mat, prec: crate::util::precision::Precision) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs =
            crate::util::obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let s = self.pc.apply_inv_sqrt_mat(x);
        let t = self.op.apply_mat_prec(&s, prec);
        self.pc.apply_inv_sqrt_mat(&t)
    }
    /// One split-operator apply is charged as one `block_applies` — the
    /// inner `K̃` apply is suppressed as nested, matching the estimators'
    /// convention (the `P^{-1/2}` low-rank algebra is outside the MVM
    /// accounting).
    fn obs_kind(&self) -> &'static str {
        "precond_split"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::linalg::chol::Cholesky;
    use crate::operators::DenseKernelOp;
    use crate::util::rng::Rng;

    fn rbf_op(n: usize, sigma: f64, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            sigma,
        )
    }

    /// Dense materialization of the preconditioner P = U S² Uᵀ + σ² I.
    fn dense_p(pc: &PivCholPrecond) -> Mat {
        let n = pc.n();
        let mut p = Mat::zeros(n, n);
        for (c, &s) in pc.s2.iter().enumerate() {
            let uc = pc.u.col(c);
            for i in 0..n {
                for j in 0..n {
                    p[(i, j)] += s * uc[i] * uc[j];
                }
            }
        }
        p.add_diag(pc.sigma2);
        p
    }

    #[test]
    fn apply_inv_matches_dense_inverse() {
        let op = rbf_op(25, 0.3, 1);
        let pc = build_preconditioner(&op, PrecondOptions::rank(8)).unwrap();
        let p = dense_p(&pc);
        let chol = Cholesky::new(&p).unwrap();
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..25).map(|_| rng.gaussian()).collect();
        let got = pc.apply_inv_vec(&x);
        let want = chol.solve(&x);
        for i in 0..25 {
            assert!(
                (got[i] - want[i]).abs() < 1e-8 * (1.0 + want[i].abs()),
                "i={i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn inv_sqrt_squares_to_inv() {
        let op = rbf_op(20, 0.2, 3);
        let pc = build_preconditioner(&op, PrecondOptions::rank(6)).unwrap();
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..20).map(|_| rng.gaussian()).collect();
        let h = pc.apply_inv_sqrt_vec(&x);
        let hh = pc.apply_inv_sqrt_vec(&h);
        let inv = pc.apply_inv_vec(&x);
        for i in 0..20 {
            assert!(
                (hh[i] - inv[i]).abs() < 1e-9 * (1.0 + inv[i].abs()),
                "i={i}: {} vs {}",
                hh[i],
                inv[i]
            );
        }
    }

    #[test]
    fn logdet_matches_dense() {
        let op = rbf_op(22, 0.4, 5);
        let pc = build_preconditioner(&op, PrecondOptions::rank(7)).unwrap();
        let want = Cholesky::new(&dense_p(&pc)).unwrap().logdet();
        assert!(
            (pc.logdet() - want).abs() < 1e-8 * (1.0 + want.abs()),
            "{} vs {want}",
            pc.logdet()
        );
    }

    /// Blocked preconditioner applies are bitwise identical per column to
    /// the single-vector path — the contract the block-PCG engine needs.
    #[test]
    fn blocked_applies_match_columns_bitwise() {
        let op = rbf_op(18, 0.25, 6);
        let pc = build_preconditioner(&op, PrecondOptions::rank(5)).unwrap();
        let mut rng = Rng::new(7);
        let x = Mat::from_fn(18, 4, |_, _| rng.gaussian());
        let inv = pc.apply_inv_mat(&x);
        let sq = pc.apply_inv_sqrt_mat(&x);
        for j in 0..4 {
            let col = x.col(j);
            let want_inv = pc.apply_inv_vec(&col);
            let want_sq = pc.apply_inv_sqrt_vec(&col);
            for i in 0..18 {
                assert_eq!(inv[(i, j)].to_bits(), want_inv[i].to_bits(), "inv ({i},{j})");
                assert_eq!(sq[(i, j)].to_bits(), want_sq[i].to_bits(), "sqrt ({i},{j})");
            }
        }
    }

    /// At full rank with a tight trace tolerance, P == K̃ and the split
    /// operator is (numerically) the identity.
    #[test]
    fn full_rank_split_is_identity() {
        let op = rbf_op(15, 0.3, 8);
        let pc = build_preconditioner(
            &op,
            PrecondOptions { rank: 15, rel_tol: 0.0 },
        )
        .unwrap();
        let pop = PreconditionedOp::new(&op, &pc);
        let mut rng = Rng::new(9);
        let x: Vec<f64> = (0..15).map(|_| rng.gaussian()).collect();
        let y = pop.apply_vec(&x);
        for i in 0..15 {
            assert!((y[i] - x[i]).abs() < 1e-6, "i={i}: {} vs {}", y[i], x[i]);
        }
        // And log|P| equals the exact log|K̃|.
        let want = Cholesky::new(&op.full_matrix()).unwrap().logdet();
        assert!((pc.logdet() - want).abs() < 1e-6 * (1.0 + want.abs()));
    }

    #[test]
    fn rank_zero_and_missing_diag_disable() {
        let op = rbf_op(10, 0.3, 10);
        assert!(build_preconditioner(&op, PrecondOptions::rank(0)).is_none());
        // An operator without diag(): a raw Toeplitz wrapped as KernelOp is
        // not available here, so exercise the degenerate-factor path
        // instead: an all-zero factor keeps rank 0 but stays usable.
        let pc = PivCholPrecond::new(&Mat::zeros(10, 0), 0.09);
        assert_eq!(pc.rank(), 0);
        let x = vec![1.0; 10];
        let y = pc.apply_inv_vec(&x);
        for v in y {
            assert!((v - 1.0 / 0.09).abs() < 1e-12);
        }
        assert!((pc.logdet() - 10.0 * (0.09f64).ln()).abs() < 1e-10);
    }
}
