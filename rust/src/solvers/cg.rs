//! Conjugate gradients for SPD operators — used to compute
//! `alpha = K̃^{-1}(y - mu)` (the data-fit term of the marginal likelihood)
//! and the inner solves of the Laplace approximation. Only MVMs are needed,
//! which is exactly the structural assumption of the paper.
//!
//! This file holds the **scalar** (one right-hand-side) path and the shared
//! [`CgOptions`]/[`CgInfo`] types; the batched lockstep engine lives in
//! [`super::block`]. The two paths are kept bit-identical per column (see
//! the module docs of [`crate::solvers`] for the contract), so the scalar
//! path doubles as the reference implementation the proptests compare the
//! block engine against.
//!
//! Convergence is declared on the **true** residual `‖b − A x‖`: the
//! recurrence residual CG carries drifts away from the true residual over
//! long runs, so when the recurrence passes the tolerance the solver spends
//! one extra MVM to confirm, and restarts from the true residual if the
//! confirmation fails.

use super::precond::{PrecondOptions, Preconditioner};
use crate::operators::LinOp;
use crate::util::stats::{axpy, dot, norm2};

/// Options shared by every CG entry point.
#[derive(Clone, Copy, Debug)]
pub struct CgOptions {
    /// Residual tolerance, relative to `‖b‖` (absolute when `‖b‖` is tiny;
    /// see [`residual_scale`]).
    pub tol: f64,
    /// Iteration cap per column.
    pub max_iters: usize,
    /// Right-hand-side block width for [`super::block::cg_block`] /
    /// [`super::block::cg_batch`]; scalar solves ignore it.
    pub block_size: usize,
    /// Worker threads across RHS groups for the block engine: each
    /// `block_size`-wide group of a multi-group solve runs on its own
    /// `util::parallel` worker (results are bit-identical for every
    /// thread count — see the module docs of [`crate::solvers`]). Scalar
    /// solves and single-group blocks ignore it. Defaults to the process
    /// default ([`crate::util::parallel::default_threads`], CLI
    /// `--threads`).
    pub threads: usize,
    /// Pivoted-Cholesky preconditioner knob (`rank` 0 = off). The solver
    /// functions take the *built* [`Preconditioner`] as an argument; this
    /// knob is how the entry points that own a kernel operator
    /// (`GpRegression`, Laplace, DKL, the Hessian estimator) decide what
    /// to build. CLI: `--precond-rank`.
    pub precond: PrecondOptions,
    /// MVM precision for the block engine's inner iterations
    /// ([`super::block::cg_block`] / [`super::block::pcg_block`]):
    /// `F32F64` runs the per-iteration block applies through
    /// [`LinOp::apply_mat_prec`] and treats the solve as iterative
    /// refinement — convergence is still only ever declared from the f64
    /// true-residual confirmation, so `converged == true` keeps its
    /// `‖b − A x‖ ≤ tol` (in f64) meaning in both modes. `F64` is
    /// bit-identical to the pre-knob engine. The **scalar** paths in this
    /// file always run f64 and ignore the field (one RHS is latency- not
    /// bandwidth-bound, and the scalar path is the bitwise reference the
    /// block engine is pinned against). Defaults to the process default
    /// ([`crate::util::precision::default_precision`], CLI `--precision`).
    pub precision: crate::util::precision::Precision,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions {
            tol: 1e-8,
            max_iters: 1000,
            block_size: super::default_cg_block_size(),
            threads: crate::util::parallel::default_threads(),
            precond: PrecondOptions::default(),
            precision: crate::util::precision::default_precision(),
        }
    }
}

impl CgOptions {
    /// Convenience constructor for the common (tol, max_iters) pair.
    pub fn new(tol: f64, max_iters: usize) -> Self {
        CgOptions { tol, max_iters, ..Default::default() }
    }
}

/// Below this `‖b‖` the convergence test switches from relative to
/// absolute: dividing the residual by a near-zero (or denormal) `‖b‖`
/// makes the relative test unreachable for a near-zero RHS with a nonzero
/// warm start, even though `x ≈ 0` is trivially available.
pub const TINY_RHS_NORM: f64 = 1e-30;

/// Residual scale: `‖b‖`, falling back to 1 (absolute tolerance) when the
/// RHS is tiny per [`TINY_RHS_NORM`].
#[inline]
pub fn residual_scale(bnorm: f64) -> f64 {
    if bnorm >= TINY_RHS_NORM {
        bnorm
    } else {
        1.0
    }
}

/// CG run statistics for one right-hand side.
#[derive(Clone, Copy, Debug)]
pub struct CgInfo {
    pub iters: usize,
    /// Scaled residual at exit. This is the **true** residual
    /// `‖b − A x‖ / scale` whenever `converged` is set and on an
    /// indefiniteness bail; only when the iteration budget runs out is it
    /// the (possibly drifted) recurrence residual of the last step.
    pub residual: f64,
    pub converged: bool,
    /// Operator applies this column consumed: one per iteration, plus one
    /// for a warm-start residual and one per true-residual confirmation.
    pub mvms: usize,
}

/// Solve A x = b with (preconditioner-free) CG. Returns (x, info).
///
/// For the kernel matrices in this codebase the noise term sigma^2 I bounds
/// the condition number, so plain CG is adequate; the paper's estimators
/// are about the *logdet*, not the solve.
pub fn cg<O: LinOp + ?Sized>(op: &O, b: &[f64], opts: &CgOptions) -> (Vec<f64>, CgInfo) {
    cg_with_guess(op, b, None, opts)
}

/// CG with an optional warm start (used across optimizer steps where the
/// hyperparameters move slowly).
pub fn cg_with_guess<O: LinOp + ?Sized>(
    op: &O,
    b: &[f64],
    x0: Option<&[f64]>,
    opts: &CgOptions,
) -> (Vec<f64>, CgInfo) {
    let n = op.n();
    assert_eq!(b.len(), n);
    let scale = residual_scale(norm2(b));
    let mut x = match x0 {
        Some(g) => g.to_vec(),
        None => vec![0.0; n],
    };
    let mut r = b.to_vec();
    let mut tmp = vec![0.0; n];
    let mut info = CgInfo { iters: 0, residual: 0.0, converged: false, mvms: 0 };
    if x0.is_some() {
        op.apply(&x, &mut tmp);
        info.mvms += 1;
        for i in 0..n {
            r[i] -= tmp[i];
        }
    }
    let mut p = r.clone();
    let mut rs_old = dot(&r, &r);
    info.residual = rs_old.sqrt() / scale;
    // The initial residual is already the true one — no confirmation needed.
    if info.residual <= opts.tol {
        info.converged = true;
        return (x, info);
    }
    let mut ap = vec![0.0; n];
    for it in 0..opts.max_iters {
        op.apply(&p, &mut ap);
        info.mvms += 1;
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // Operator numerically lost definiteness; bail with the best
            // iterate, reporting the current true residual.
            info.iters = it;
            op.apply(&x, &mut tmp);
            info.mvms += 1;
            for i in 0..n {
                tmp[i] = b[i] - tmp[i];
            }
            info.residual = norm2(&tmp) / scale;
            return (x, info);
        }
        let alpha = rs_old / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        info.iters = it + 1;
        info.residual = rs_new.sqrt() / scale;
        if info.residual <= opts.tol {
            // Recurrence passed — confirm against the true residual.
            op.apply(&x, &mut tmp);
            info.mvms += 1;
            for i in 0..n {
                r[i] = b[i] - tmp[i];
            }
            let rs_true = dot(&r, &r);
            info.residual = rs_true.sqrt() / scale;
            if info.residual <= opts.tol {
                info.converged = true;
                return (x, info);
            }
            // Drift: restart the recurrence from the true residual.
            rs_old = rs_true;
            p.copy_from_slice(&r);
            continue;
        }
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    (x, info)
}

/// Preconditioned CG. `pc = None` is *exactly* [`cg`] — same code path,
/// bit-identical results — so a disabled preconditioner changes nothing.
pub fn pcg<O: LinOp + ?Sized>(
    op: &O,
    b: &[f64],
    pc: Option<&dyn Preconditioner>,
    opts: &CgOptions,
) -> (Vec<f64>, CgInfo) {
    pcg_with_guess(op, b, None, pc, opts)
}

/// Preconditioned CG with an optional warm start.
///
/// The machinery is the scalar path of [`cg_with_guess`] with the standard
/// PCG recurrences (`z = P⁻¹ r`, `α = rᵀz / pᵀAp`, `β = r'ᵀz' / rᵀz`).
/// Convergence is still declared on the **unpreconditioned** true residual
/// `‖b − A x‖` — confirmed with one extra MVM, restarting from the true
/// residual on drift — so iteration counts at equal `tol` are directly
/// comparable with the unpreconditioned solver.
pub fn pcg_with_guess<O: LinOp + ?Sized>(
    op: &O,
    b: &[f64],
    x0: Option<&[f64]>,
    pc: Option<&dyn Preconditioner>,
    opts: &CgOptions,
) -> (Vec<f64>, CgInfo) {
    let Some(pc) = pc else {
        return cg_with_guess(op, b, x0, opts);
    };
    let n = op.n();
    assert_eq!(b.len(), n);
    assert_eq!(pc.n(), n);
    let scale = residual_scale(norm2(b));
    let mut x = match x0 {
        Some(g) => g.to_vec(),
        None => vec![0.0; n],
    };
    let mut r = b.to_vec();
    let mut tmp = vec![0.0; n];
    let mut info = CgInfo { iters: 0, residual: 0.0, converged: false, mvms: 0 };
    if x0.is_some() {
        op.apply(&x, &mut tmp);
        info.mvms += 1;
        for i in 0..n {
            r[i] -= tmp[i];
        }
    }
    info.residual = norm2(&r) / scale;
    if info.residual <= opts.tol {
        info.converged = true;
        return (x, info);
    }
    let mut z = pc.apply_inv_vec(&r);
    let mut p = z.clone();
    let mut rz_old = dot(&r, &z);
    let mut ap = vec![0.0; n];
    for it in 0..opts.max_iters {
        op.apply(&p, &mut ap);
        info.mvms += 1;
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            info.iters = it;
            op.apply(&x, &mut tmp);
            info.mvms += 1;
            for i in 0..n {
                tmp[i] = b[i] - tmp[i];
            }
            info.residual = norm2(&tmp) / scale;
            return (x, info);
        }
        let alpha = rz_old / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        info.iters = it + 1;
        info.residual = norm2(&r) / scale;
        if info.residual <= opts.tol {
            // Recurrence passed — confirm against the true residual.
            op.apply(&x, &mut tmp);
            info.mvms += 1;
            for i in 0..n {
                r[i] = b[i] - tmp[i];
            }
            info.residual = norm2(&r) / scale;
            if info.residual <= opts.tol {
                info.converged = true;
                return (x, info);
            }
            // Drift: restart the recurrence from the true residual.
            pc.apply_inv(&r, &mut z);
            p.copy_from_slice(&z);
            rz_old = dot(&r, &z);
            continue;
        }
        pc.apply_inv(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz_old;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
        rz_old = rz_new;
    }
    (x, info)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::operators::DenseMatOp;

    fn spd_op(n: usize) -> DenseMatOp {
        let b = Mat::from_fn(n, n, |i, j| (((i + 1) * (j + 2)) % 7) as f64 / 7.0);
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64 * 0.5);
        DenseMatOp::new(a)
    }

    #[test]
    fn solves_spd_system() {
        let op = spd_op(20);
        let x_true: Vec<f64> = (0..20).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut b = vec![0.0; 20];
        op.apply(&x_true, &mut b);
        let (x, info) = cg(&op, &b, &CgOptions::new(1e-12, 200));
        assert!(info.converged, "residual {}", info.residual);
        for i in 0..20 {
            assert!((x[i] - x_true[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn warm_start_converges_faster() {
        let op = spd_op(40);
        let x_true: Vec<f64> = (0..40).map(|i| (i as f64 * 0.11).cos()).collect();
        let mut b = vec![0.0; 40];
        op.apply(&x_true, &mut b);
        let opts = CgOptions::new(1e-10, 500);
        let (x_cold, cold) = cg(&op, &b, &opts);
        let (_, warm) = cg_with_guess(&op, &b, Some(&x_cold), &opts);
        assert!(warm.iters <= cold.iters);
    }

    #[test]
    fn zero_rhs_is_trivially_converged() {
        let op = spd_op(5);
        let (x, info) = cg(&op, &[0.0; 5], &CgOptions::new(1e-10, 10));
        assert!(info.converged);
        assert_eq!(info.mvms, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    /// Bugfix: the reported residual on convergence is the *true* residual
    /// `‖b − A x‖ / ‖b‖`, recomputed from the final iterate, not the
    /// drift-prone recurrence value.
    #[test]
    fn converged_residual_is_true_residual() {
        let op = spd_op(30);
        let b: Vec<f64> = (0..30).map(|i| ((i * 13 % 7) as f64) - 3.0).collect();
        let (x, info) = cg(&op, &b, &CgOptions::new(1e-10, 300));
        assert!(info.converged);
        let mut ax = vec![0.0; 30];
        op.apply(&x, &mut ax);
        let rtrue: Vec<f64> = (0..30).map(|i| b[i] - ax[i]).collect();
        let want = norm2(&rtrue) / norm2(&b);
        assert_eq!(info.residual.to_bits(), want.to_bits());
        assert!(info.residual <= 1e-10);
    }

    /// Bugfix: on an ill-conditioned system the recurrence residual dives
    /// below any tolerance long before the true residual does (the old
    /// code declared convergence off the recurrence at a true residual
    /// orders of magnitude above tol). The fixed solver must either
    /// converge for real or honestly report failure.
    #[test]
    fn drifted_recurrence_does_not_fake_convergence() {
        // Hilbert matrix: condition number ~1e10 at n=8.
        let a = Mat::from_fn(8, 8, |i, j| 1.0 / ((i + j + 1) as f64));
        let op = DenseMatOp::new(a);
        let b: Vec<f64> = (0..8).map(|i| (i as f64 * 0.9).sin()).collect();
        let (x, info) = cg(&op, &b, &CgOptions::new(1e-13, 500));
        let mut ax = vec![0.0; 8];
        op.apply(&x, &mut ax);
        let rtrue: Vec<f64> = (0..8).map(|i| b[i] - ax[i]).collect();
        let rel = norm2(&rtrue) / norm2(&b);
        if info.converged {
            assert!(rel <= 1e-13 * (1.0 + 1e-12), "fake convergence: {rel}");
        } else {
            // The recurrence *did* pass tol along the way (that is the
            // drift) — visible as confirmation MVMs beyond the one per
            // iteration.
            assert!(info.mvms > info.iters, "expected confirmation MVMs");
        }
    }

    /// Bugfix: a near-zero RHS with a nonzero warm start must still
    /// converge — the residual scale falls back to an absolute tolerance
    /// instead of dividing by a (de)normal-tiny `‖b‖`.
    #[test]
    fn tiny_rhs_with_warm_start_converges() {
        let op = spd_op(20);
        let b = vec![1e-200; 20];
        let x0 = vec![1.0; 20];
        let (x, info) = cg_with_guess(&op, &b, Some(&x0), &CgOptions::new(1e-8, 200));
        assert!(info.converged, "residual {}", info.residual);
        // The solution of A x = ~0 is ~0.
        assert!(x.iter().all(|&v| v.abs() < 1e-6), "{x:?}");
    }

    /// Bugfix: the indefiniteness bail reports a finite, current true
    /// residual (previously the recurrence value, which can be stale).
    #[test]
    fn indefinite_bail_reports_true_residual() {
        // A = diag(2, -1): the first iteration has p^T A p = 1 > 0, the
        // second hits p^T A p < 0 and bails.
        let op = DenseMatOp::new(Mat::from_rows(&[vec![2.0, 0.0], vec![0.0, -1.0]]));
        let b = vec![1.0, 1.0];
        let (x, info) = cg(&op, &b, &CgOptions::new(1e-12, 50));
        assert!(!info.converged);
        assert!(info.residual.is_finite());
        let mut ax = vec![0.0; 2];
        op.apply(&x, &mut ax);
        let rtrue: Vec<f64> = (0..2).map(|i| b[i] - ax[i]).collect();
        let want = norm2(&rtrue) / norm2(&b);
        assert_eq!(info.residual.to_bits(), want.to_bits());
    }

    /// The scale falls back to absolute exactly below [`TINY_RHS_NORM`].
    #[test]
    fn residual_scale_fallback() {
        assert_eq!(residual_scale(2.5), 2.5);
        assert_eq!(residual_scale(TINY_RHS_NORM), TINY_RHS_NORM);
        assert_eq!(residual_scale(TINY_RHS_NORM / 2.0), 1.0);
        assert_eq!(residual_scale(0.0), 1.0);
    }

    fn rbf_op(n: usize, sigma: f64, seed: u64) -> crate::operators::DenseKernelOp {
        use crate::kernels::{IsoKernel, Shape};
        let mut rng = crate::util::rng::Rng::new(seed);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        crate::operators::DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            sigma,
        )
    }

    /// `pcg` without a preconditioner is the `cg` code path, bit for bit.
    #[test]
    fn pcg_none_is_cg_bitwise() {
        let op = rbf_op(30, 0.3, 11);
        let b: Vec<f64> = (0..30).map(|i| (i as f64 * 0.7).sin()).collect();
        let opts = CgOptions::new(1e-10, 300);
        let (xc, ic) = cg(&op, &b, &opts);
        let (xp, ip) = pcg(&op, &b, None, &opts);
        for i in 0..30 {
            assert_eq!(xc[i].to_bits(), xp[i].to_bits());
        }
        assert_eq!(ic.iters, ip.iters);
        assert_eq!(ic.mvms, ip.mvms);
        assert_eq!(ic.residual.to_bits(), ip.residual.to_bits());
    }

    /// Preconditioned and plain CG agree on the solution (both converge to
    /// the same system's solution within tolerance).
    #[test]
    fn pcg_matches_cg_solution() {
        use crate::solvers::precond::{build_preconditioner, PrecondOptions};
        let op = rbf_op(50, 0.1, 12);
        let pc = build_preconditioner(&op, PrecondOptions::rank(12)).unwrap();
        let b: Vec<f64> = (0..50).map(|i| (i as f64 * 0.3).cos()).collect();
        let opts = CgOptions::new(1e-10, 2000);
        let (xc, ic) = cg(&op, &b, &opts);
        let (xp, ip) = pcg(&op, &b, Some(&pc), &opts);
        assert!(ic.converged && ip.converged);
        for i in 0..50 {
            assert!(
                (xc[i] - xp[i]).abs() < 1e-7 * (1.0 + xc[i].abs()),
                "i={i}: {} vs {}",
                xc[i],
                xp[i]
            );
        }
    }

    /// Small-σ regression: on an ill-conditioned dense RBF kernel, PCG
    /// iteration counts strictly drop as the preconditioner rank grows —
    /// the whole point of the subsystem.
    #[test]
    fn small_sigma_iterations_strictly_drop_with_rank() {
        use crate::solvers::precond::{build_preconditioner, PrecondOptions};
        let op = rbf_op(150, 1e-2, 13);
        let b: Vec<f64> = (0..150).map(|i| (i as f64 * 0.17).sin()).collect();
        let opts = CgOptions::new(1e-8, 10_000);
        let mut iters = Vec::new();
        for rank in [0usize, 8, 32] {
            let pc = build_preconditioner(&op, PrecondOptions { rank, rel_tol: 0.0 });
            assert_eq!(pc.is_some(), rank > 0);
            let pcd = pc.as_ref().map(|p| p as &dyn crate::solvers::Preconditioner);
            let (_, info) = pcg(&op, &b, pcd, &opts);
            assert!(info.converged, "rank {rank}: residual {}", info.residual);
            iters.push(info.iters);
        }
        assert!(
            iters[2] < iters[1] && iters[1] < iters[0],
            "iteration counts did not strictly drop: {iters:?}"
        );
        // Acceptance bar: rank 32 cuts iterations by at least 2x.
        assert!(
            2 * iters[2] <= iters[0],
            "rank-32 PCG saved less than 2x: {} vs {}",
            iters[2],
            iters[0]
        );
    }
}
