//! Conjugate gradients for SPD operators — used to compute
//! `alpha = K̃^{-1}(y - mu)` (the data-fit term of the marginal likelihood)
//! and the inner solves of the Laplace approximation. Only MVMs are needed,
//! which is exactly the structural assumption of the paper.

use crate::operators::LinOp;
use crate::util::stats::{axpy, dot, norm2};

/// CG run statistics.
#[derive(Clone, Copy, Debug)]
pub struct CgInfo {
    pub iters: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Solve A x = b with (preconditioner-free) CG. Returns (x, info).
///
/// Stops at relative residual `tol` or `max_iters`. For the kernel matrices
/// in this codebase the noise term sigma^2 I bounds the condition number, so
/// plain CG is adequate; the paper's estimators are about the *logdet*, not
/// the solve.
pub fn cg(op: &dyn LinOp, b: &[f64], tol: f64, max_iters: usize) -> (Vec<f64>, CgInfo) {
    cg_with_guess(op, b, None, tol, max_iters)
}

/// CG with an optional warm start (used across optimizer steps where the
/// hyperparameters move slowly).
pub fn cg_with_guess(
    op: &dyn LinOp,
    b: &[f64],
    x0: Option<&[f64]>,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, CgInfo) {
    let n = op.n();
    assert_eq!(b.len(), n);
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut x = match x0 {
        Some(g) => g.to_vec(),
        None => vec![0.0; n],
    };
    let mut r = b.to_vec();
    let mut tmp = vec![0.0; n];
    if x0.is_some() {
        op.apply(&x, &mut tmp);
        for i in 0..n {
            r[i] -= tmp[i];
        }
    }
    let mut p = r.clone();
    let mut rs_old = dot(&r, &r);
    let mut info = CgInfo { iters: 0, residual: rs_old.sqrt() / bnorm, converged: false };
    if info.residual <= tol {
        info.converged = true;
        return (x, info);
    }
    let mut ap = vec![0.0; n];
    for it in 0..max_iters {
        op.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // Operator numerically lost definiteness; bail with best iterate.
            info.iters = it;
            info.residual = rs_old.sqrt() / bnorm;
            return (x, info);
        }
        let alpha = rs_old / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        info.iters = it + 1;
        info.residual = rs_new.sqrt() / bnorm;
        if info.residual <= tol {
            info.converged = true;
            return (x, info);
        }
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    (x, info)
}

/// Batched CG: solves A X = B column by column (columns are independent;
/// parallelized by the caller when profitable).
pub fn cg_batch(
    op: &dyn LinOp,
    bs: &[Vec<f64>],
    tol: f64,
    max_iters: usize,
) -> Vec<(Vec<f64>, CgInfo)> {
    bs.iter().map(|b| cg(op, b, tol, max_iters)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::operators::DenseMatOp;

    fn spd_op(n: usize) -> DenseMatOp {
        let b = Mat::from_fn(n, n, |i, j| (((i + 1) * (j + 2)) % 7) as f64 / 7.0);
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64 * 0.5);
        DenseMatOp::new(a)
    }

    #[test]
    fn solves_spd_system() {
        let op = spd_op(20);
        let x_true: Vec<f64> = (0..20).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut b = vec![0.0; 20];
        op.apply(&x_true, &mut b);
        let (x, info) = cg(&op, &b, 1e-12, 200);
        assert!(info.converged, "residual {}", info.residual);
        for i in 0..20 {
            assert!((x[i] - x_true[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn warm_start_converges_faster() {
        let op = spd_op(40);
        let x_true: Vec<f64> = (0..40).map(|i| (i as f64 * 0.11).cos()).collect();
        let mut b = vec![0.0; 40];
        op.apply(&x_true, &mut b);
        let (x_cold, cold) = cg(&op, &b, 1e-10, 500);
        let (_, warm) = cg_with_guess(&op, &b, Some(&x_cold), 1e-10, 500);
        assert!(warm.iters <= cold.iters);
    }

    #[test]
    fn zero_rhs_is_trivially_converged() {
        let op = spd_op(5);
        let (x, info) = cg(&op, &[0.0; 5], 1e-10, 10);
        assert!(info.converged);
        assert!(x.iter().all(|&v| v == 0.0));
    }
}
