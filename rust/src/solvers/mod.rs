//! Iterative solvers over [`crate::operators::LinOp`].
//!
//! # The block-solve contract
//!
//! Stochastic estimators and predictive equations generate many
//! simultaneous right-hand sides (probe sets, test-point cross-covariance
//! columns), so the **hot path is the block solve**: [`block::cg_block`]
//! advances every column in lockstep through one blocked
//! [`crate::operators::LinOp::apply_mat`] per iteration, mirroring the
//! estimators' block-probe engine. The contract:
//!
//! * **Bit-identical to scalar.** Alpha/beta/residual recurrences and every
//!   convergence or indefiniteness test are per-column; combined with the
//!   operators' column-independence contract, column `j` of a block solve
//!   is bitwise identical to a scalar [`cg::cg_with_guess`] on column `j`
//!   (enforced by `tests/proptests.rs` for every operator type and block
//!   width). Blocking changes only how many columns each pass over the
//!   operator's structure amortizes.
//! * **Deflation.** Converged and bailed columns drop out of the active
//!   block; late stragglers never force redundant applies for columns that
//!   finished early.
//! * **True-residual convergence.** `converged` is only reported after the
//!   recurrence residual is confirmed against `‖b − A x‖` (one extra MVM);
//!   on drift the recurrence restarts from the true residual. The
//!   relative-residual scale falls back to absolute for near-zero
//!   right-hand sides ([`cg::residual_scale`]).
//! * **Accounting.** [`block::BlockCgInfo`] mirrors
//!   `LogdetEstimate::{mvms, block_applies}`: per-column MVMs (comparable
//!   across block widths) and block-amortized applies (what the hardware
//!   executes; one per `apply_mat` call). Per-group counts are merged back
//!   by global column index, so the merged report is identical to the
//!   serial engine's.
//! * **RHS-group parallelism (work-stealing).** A multi-group solve fans
//!   its `block_size`-wide groups across `CgOptions::threads` workers
//!   pulling from a shared atomic group queue
//!   ([`crate::util::parallel::par_map_steal`] owns the pool; the CLI
//!   `--threads` flag sets the process default). Groups are
//!   data-independent — each worker runs one complete lockstep solve with
//!   its own deflation and true-residual state and writes a disjoint
//!   column range — so results are **bit-identical for every thread count
//!   and every steal order** (proptest-enforced across
//!   `threads ∈ {1, 2, 8}`, and against the static-partition reference):
//!   which worker solves a group is unobservable in the solutions,
//!   per-column `CgInfo`, `mvms`, and `block_applies`. Stealing exists
//!   because group convergence is ragged — a worker whose group deflates
//!   in a few iterations pulls the next unsolved group instead of idling
//!   behind the hardest group. The nested thread-*budget* guard
//!   keeps operator-level threading from multiplying under the group
//!   workers: each worker's nested fan-out is capped by its share of the
//!   requested threads (serial when there are as many groups as threads;
//!   leftover threads flow down to the blocked applies when groups are
//!   few), and with one group (or `threads = 1`) the group runs on the
//!   caller's thread with the operators' full internal parallelism.
//!
//! Scalar entry points ([`cg::cg`], [`cg::cg_with_guess`]) remain for
//! one-RHS sites (the training-loop `alpha` solve, Laplace Newton inner
//! solves) and as the reference implementation; [`block::cg_batch`] is a
//! thin wrapper over the block engine. All entry points share
//! [`cg::CgOptions`]; the default `block_size` is process-wide
//! ([`default_cg_block_size`], CLI `--cg-block`).
//!
//! # Preconditioning
//!
//! Both Chebyshev/Lanczos step counts and CG iteration counts degrade with
//! the condition number of `K̃ = K + σ²I` — exactly the small-σ regime
//! kernel learning drives into. [`precond`] supplies the remedy: a rank-k
//! pivoted Cholesky `K ≈ L Lᵀ` becomes the SPD preconditioner
//! `P = L Lᵀ + σ² I` with closed-form `P⁻¹`, symmetric `P^{-1/2}`, and
//! exact `log|P|` (the [`precond::Preconditioner`] contract — see that
//! module's docs for what an implementation must satisfy).
//!
//! * **Solves** go through [`cg::pcg`] / [`cg::pcg_with_guess`] /
//!   [`block::pcg_block`]: the PR 2 lockstep/deflation/true-residual
//!   machinery, iterating on the preconditioned system. Convergence is
//!   still declared on the unpreconditioned `‖b − A x‖`, so iteration
//!   counts at equal tolerance are directly comparable. With `pc = None`
//!   these **are** the unpreconditioned entry points (same code path,
//!   bit-identical), so `--precond-rank 0` changes nothing.
//! * **Log determinants** use the identity
//!   `log|K̃| = log|P| + tr log(P^{-1/2} K̃ P^{-1/2})` — the stochastic
//!   estimator only sees the flattened spectrum
//!   (`estimators::slq::slq_logdet_pc`).
//! * The `precond` knob on [`cg::CgOptions`] ([`precond::PrecondOptions`],
//!   CLI `--precond-rank`, process default [`default_precond_rank`])
//!   tells the entry points that own a kernel operator what rank to build;
//!   the built [`precond::Preconditioner`] is then passed down explicitly.
//!
//! # Precision contract
//!
//! The block engine owns the mixed-precision story
//! ([`cg::CgOptions::precision`], CLI `--precision`, process default
//! [`crate::util::precision::default_precision`]):
//!
//! * **`F64` is bit-identical to the pre-knob engine.** Every operator's
//!   `apply_mat_prec(x, F64)` IS `apply_mat(x)`, so a solve with
//!   `precision: F64` produces bitwise the same iterates, counters, and
//!   convergence flags as before the knob existed (proptest-pinned).
//! * **`F32F64` is iterative refinement, not a weaker solve.** Inner
//!   lockstep iterations drive the recurrence with the operator's mixed
//!   apply (f32 storage panels, f64 accumulators — see
//!   [`crate::operators`]); the periodic true-residual confirmation and
//!   any drift restart always recompute `‖b − A x‖` with the full f64
//!   operator (`residual_mat` deliberately has no precision knob). The
//!   restart re-seeds the recurrence from the f64 true residual, which is
//!   exactly a refinement cycle: each one contracts the true residual by
//!   roughly `eps_f32 · κ(A)` until the f64 tolerance is met or
//!   `max_iters` runs out.
//! * **`converged == true` means the f64 residual test passed** —
//!   `‖b − A x‖ ≤ tol · scale` evaluated in full f64 — in *both* modes.
//!   Mixed mode may spend extra iterations (refinement restarts); it never
//!   weakens what convergence asserts. Scalar entry points ignore the
//!   field entirely (always f64) and remain the bitwise reference.
//!
//! # Trace span sites ([`crate::util::obs`])
//!
//! With `--trace` the solvers contribute (inert and bitwise invisible
//! when tracing is off — proptest-pinned by
//! `prop_tracing_enabled_bitwise_inert`):
//!
//! * `cg_block` — one per [`block::cg_block`] call, wrapping the whole
//!   blocked solve in an accounting **audit window** that asserts the
//!   traced `Mvms`/`BlockApplies` counters equal
//!   [`block::BlockCgInfo`]'s `mvms`/`block_applies` exactly (release
//!   builds included).
//! * `pcg_block` — one per *preconditioned* [`block::pcg_block`] call
//!   (with `pc = None` the call delegates to `cg_block` before any span
//!   opens, so the unpreconditioned path keeps its name). Same audit
//!   contract; preconditioner applications are low-rank products, not
//!   operator MVMs, and charge no apply counters — matching
//!   `BlockCgInfo`'s convention.
//! * `pchol_grow` — each [`crate::linalg::pchol::PivotedCholesky::grow`]
//!   during [`precond::build_preconditioner`], charging
//!   `Counter::PcholCols` with the columns added.
//! * Beneath these, every operator apply opens its
//!   [`crate::util::obs::apply_site`] span (`LinOp::obs_kind`), so the
//!   per-path rollup splits solve time into iteration overhead vs.
//!   operator structure. Worker threads of the RHS-group fan-out stitch
//!   their spans under the calling solve's span
//!   ([`crate::util::parallel`] forwards the parent id through
//!   `par_map`/`par_map_steal`), so multi-threaded solves profile as one
//!   tree, not per-thread fragments.
pub mod block;
pub mod cg;
pub mod precond;

pub use block::{cg_batch, cg_block, pcg_block, BlockCgInfo};
pub use cg::{cg, cg_with_guess, pcg, pcg_with_guess, CgInfo, CgOptions};
pub use precond::{
    build_preconditioner, precond_from_factor, PivCholPrecond, PrecondOptions,
    PreconditionedOp, Preconditioner,
};

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide default right-hand-side block width used by
/// `CgOptions::default`. The coordinator CLI's `--cg-block` flag threads
/// through here (the estimators' probe width has its own knob,
/// `estimators::default_block_size`).
static DEFAULT_CG_BLOCK_SIZE: AtomicUsize = AtomicUsize::new(16);

/// Set the process-wide default RHS block width (clamped to >= 1).
pub fn set_default_cg_block_size(b: usize) {
    DEFAULT_CG_BLOCK_SIZE.store(b.max(1), Ordering::Relaxed);
}

/// Current process-wide default RHS block width.
pub fn default_cg_block_size() -> usize {
    DEFAULT_CG_BLOCK_SIZE.load(Ordering::Relaxed)
}

/// Process-wide default pivoted-Cholesky preconditioner rank used by
/// `PrecondOptions::default` (and therefore `CgOptions::default`). 0 (the
/// default) disables preconditioning; the coordinator CLI's
/// `--precond-rank` flag threads through here.
static DEFAULT_PRECOND_RANK: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide default preconditioner rank (0 = off).
pub fn set_default_precond_rank(rank: usize) {
    DEFAULT_PRECOND_RANK.store(rank, Ordering::Relaxed);
}

/// Current process-wide default preconditioner rank.
pub fn default_precond_rank() -> usize {
    DEFAULT_PRECOND_RANK.load(Ordering::Relaxed)
}
