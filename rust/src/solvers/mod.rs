//! Iterative solvers over [`crate::operators::LinOp`].
pub mod cg;
