//! Batch evaluation service: a worker pool that fans a queue of
//! hyperparameter vectors out to per-thread evaluators (each worker builds
//! its own operator once, then streams evaluations). Used for surrogate
//! design-point evaluation and ablation sweeps, where evaluations are
//! embarrassingly parallel but the evaluator itself is stateful (`&mut`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Evaluate `f_builder()(h)` for every hyper vector, in parallel, preserving
/// order. Each worker thread builds exactly one evaluator.
pub fn map_hyper_batch<B, E, T>(builder: B, hypers: &[Vec<f64>], threads: usize) -> Vec<T>
where
    B: Fn() -> E + Sync,
    E: FnMut(&[f64]) -> T,
    T: Send,
{
    let n = hypers.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        let mut eval = builder();
        return hypers.iter().map(|h| eval(h)).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let out = &out;
            let builder = &builder;
            scope.spawn(move || {
                crate::util::parallel::mark_pool_worker();
                let mut eval = builder();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = eval(&hypers[i]);
                    *out[i].lock().unwrap() = Some(v);
                }
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("service slot"))
        .collect()
}

/// Simple progress/throughput counters for long experiment runs.
#[derive(Default)]
pub struct Metrics {
    pub evaluations: AtomicUsize,
    pub mvms: AtomicUsize,
}

impl Metrics {
    pub fn add_eval(&self) {
        self.evaluations.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_mvms(&self, k: usize) {
        self.mvms.fetch_add(k, Ordering::Relaxed);
    }
    pub fn snapshot(&self) -> (usize, usize) {
        (
            self.evaluations.load(Ordering::Relaxed),
            self.mvms.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_matches_serial_and_counts_builders() {
        let built = AtomicUsize::new(0);
        let hypers: Vec<Vec<f64>> = (0..37).map(|i| vec![i as f64]).collect();
        let got = map_hyper_batch(
            || {
                built.fetch_add(1, Ordering::Relaxed);
                |h: &[f64]| h[0] * 2.0
            },
            &hypers,
            4,
        );
        let want: Vec<f64> = hypers.iter().map(|h| h[0] * 2.0).collect();
        assert_eq!(got, want);
        assert!(built.load(Ordering::Relaxed) <= 4);
    }

    #[test]
    fn single_thread_path() {
        let hypers = vec![vec![1.0], vec![2.0]];
        let got = map_hyper_batch(|| |h: &[f64]| h[0] + 1.0, &hypers, 1);
        assert_eq!(got, vec![2.0, 3.0]);
    }

    #[test]
    fn metrics_counters() {
        let m = Metrics::default();
        m.add_eval();
        m.add_mvms(10);
        m.add_mvms(5);
        assert_eq!(m.snapshot(), (1, 15));
    }
}
