//! Streaming GP inference service: a cached-factor model registry, an
//! MPSC request queue with bounded depth, and a dispatcher that coalesces
//! concurrent predictive requests into one block solve.
//!
//! # Registry / coalescing / back-pressure contract
//!
//! * **Model registry.** [`ModelRegistry`] holds long-lived
//!   [`GpRegression`] models keyed by insertion index. The expensive
//!   per-model artifacts live *inside* each model and persist across
//!   requests: the pivoted-Cholesky preconditioner (`pc_cache`, rebuilt
//!   only when hypers or options change) and the training solve `alpha`
//!   (`alpha_cache`, solved once and reused by every mean request).
//!   [`ModelRegistry::warm`] pre-solves both so the first live request
//!   doesn't pay the cold-start cost.
//! * **Request coalescing.** [`dispatch`] drains *all* pending requests
//!   from the queue, groups them by model id, and fuses every
//!   `predict_var` request for the same model into **one** cold
//!   [`pcg_block`](crate::solvers::pcg_block) solve: each request's
//!   `k(X, x*)` column becomes one column of the fused right-hand-side
//!   block, and the per-request answers are sliced back out by column
//!   index. By the block-solve lockstep invariant (column `j` of a block
//!   solve is bitwise identical to the scalar solve of column `j`), the
//!   coalesced answers are **bit-identical to solo per-request solves** —
//!   coalescing changes cost, never results. The dispatcher forces the
//!   *cold* solve path (`warm_start_predict_var = false` for the fused
//!   solve): the group-sequential warm-start path seeds groups from
//!   neighbors and is deliberately not bitwise-reproducible against solo
//!   answers. Mean requests share the model's cached `alpha` and cost one
//!   cross-kernel apply each — no solve at all after the first.
//! * **Back-pressure.** The queue has a bounded depth
//!   ([`RequestQueue::bounded`]); [`RequestQueue::submit`] fails with
//!   [`QueueFull`] instead of growing without bound, and the rejection is
//!   counted in [`Metrics::rejected`]. Callers decide whether to retry,
//!   shed, or block — the service never silently drops an accepted
//!   request.
//! * **Metrics.** [`Metrics`] extends the original evaluation counters
//!   with the serving-layer accounting: block solves dispatched
//!   (`solves`), fused columns per batch (`coalesced_cols`), the solver's
//!   `mvms`/`block_applies`, back-pressure rejections, and per-request
//!   latency recorded in a fixed-bucket log-spaced
//!   [`Histogram`](crate::util::stats::Histogram) (p50/p99 readout, no
//!   deps). The amortization headline is `solves`/`block_applies` vs. the
//!   solo baseline: N coalesced single-column requests cost one fused
//!   solve whose applies are bounded by the *worst* column, not the sum.
//!
//! # Trace span / counter sites (`util::obs`)
//!
//! * `dispatch` — one span per [`dispatch`] sweep; `dispatch_model` nests
//!   under it, one per model with traffic in the batch (the fused solve's
//!   `pcg_block`/`cg_block` spans nest under `dispatch_model`).
//! * [`Counter::QueueFull`](crate::util::obs::Counter::QueueFull) — bumped
//!   by [`RequestQueue::submit`] on each back-pressure rejection.
//! * [`Counter::QueueWaitNs`](crate::util::obs::Counter::QueueWaitNs) —
//!   summed submit→response latency per batch, measured as differences of
//!   [`obs::now_ns`] readings (submit stamps, one dispatch-side batch
//!   read) so both ends share a single monotonic clock.
//! * Cache hits/misses come from [`GpRegression`] itself (alpha +
//!   preconditioner caches), surfaced per model via
//!   [`GpRegression::cache_stats`].
//!
//! The original hyper-batch helper ([`map_hyper_batch`]) stays: it fans a
//! queue of hyperparameter vectors out to per-thread evaluators (each
//! worker builds its own operator once, then streams evaluations), used
//! for surrogate design-point evaluation and ablation sweeps.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::gp::{GpRegression, PredictiveOp};
use crate::util::obs;
use crate::util::stats::Histogram;

/// Evaluate `f_builder()(h)` for every hyper vector, in parallel, preserving
/// order. Each worker thread builds exactly one evaluator.
///
/// Workers pull indices from a shared atomic queue (ragged evaluation
/// costs don't strand threads) and buffer their `(index, value)` results
/// privately; buffers are merged into the ordered output after the scope
/// joins, so the hot path takes no locks (the previous implementation
/// paid one `Mutex<Option<T>>` lock + heap slot per evaluation).
pub fn map_hyper_batch<B, E, T>(builder: B, hypers: &[Vec<f64>], threads: usize) -> Vec<T>
where
    B: Fn() -> E + Sync,
    E: FnMut(&[f64]) -> T,
    T: Send,
{
    let n = hypers.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        let mut eval = builder();
        return hypers.iter().map(|h| eval(h)).collect();
    }
    let next = AtomicUsize::new(0);
    let buffers: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                let builder = &builder;
                scope.spawn(move || {
                    crate::util::parallel::mark_pool_worker();
                    let mut eval = builder();
                    let mut buf: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        buf.push((i, eval(&hypers[i])));
                    }
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("batch worker panicked")).collect()
    });
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for buf in buffers {
        for (i, v) in buf {
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|o| o.expect("service slot")).collect()
}

// ---------------- request queue ----------------

/// What a request asks of its model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// Predictive mean `μ + k_*ᵀ α` — served from the cached `alpha`.
    Mean,
    /// Predictive variance `k(x*,x*) + σ² − k_*ᵀ K̃^{-1} k_*` — one column
    /// of the model's fused block solve.
    Var,
}

/// One pending inference request.
#[derive(Debug)]
pub struct Request {
    pub model: usize,
    pub kind: RequestKind,
    pub x: Vec<f64>,
    /// Submission timestamp for the latency histogram, in nanoseconds on
    /// the shared [`obs::now_ns`] clock. Submit and dispatch previously
    /// each read their own `Instant`; routing both ends through the one
    /// process clock makes every latency a difference of readings off a
    /// single monotonic anchor.
    submitted_ns: u64,
}

/// One answered request, in the order requests were drained.
#[derive(Clone, Debug)]
pub struct Response {
    pub model: usize,
    pub kind: RequestKind,
    pub value: f64,
    /// For `Var`: this request's column of the fused solve converged (the
    /// f64 true-residual criterion). For `Mean`: the cached alpha solve
    /// converged.
    pub converged: bool,
    /// For `Var`: a deterministic upper bound on the solve-induced error
    /// of the answer. With `r = k_* − K̃u` the returned variance is off by
    /// `rᵀ K̃^{-1} k_*`, and `‖K̃^{-1}‖ ≤ 1/σ²` bounds that by
    /// `‖r‖ · ‖k_*‖ / σ²` — computed from the column's exit residual, so
    /// it is tight exactly when the solve converged and column `j` of the
    /// fused solve gives the same bound as a solo solve of column `j`.
    /// `None` for `Mean` requests (served from the cached alpha, no
    /// per-request solve) and when the bound is not finite (σ² = 0 or an
    /// unknown model).
    pub half_width: Option<f64>,
}

/// Back-pressure signal: the queue is at its bounded depth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueFull;

/// MPSC request queue with bounded depth. Producers [`submit`] from any
/// thread; the dispatcher drains everything pending in one sweep.
///
/// [`submit`]: RequestQueue::submit
pub struct RequestQueue {
    inner: Mutex<Vec<Request>>,
    cap: usize,
}

impl RequestQueue {
    /// A queue rejecting submissions beyond `cap` pending requests.
    pub fn bounded(cap: usize) -> Self {
        RequestQueue { inner: Mutex::new(Vec::new()), cap: cap.max(1) }
    }

    /// Enqueue a request; `Err(QueueFull)` applies back-pressure instead
    /// of unbounded growth (each rejection also bumps the global
    /// `queue_full` trace counter). The submission time is recorded here
    /// on the shared obs clock, so queueing delay counts toward the
    /// request's latency.
    pub fn submit(&self, model: usize, kind: RequestKind, x: Vec<f64>) -> Result<(), QueueFull> {
        let mut q = self.inner.lock().unwrap();
        if q.len() >= self.cap {
            obs::add(obs::Counter::QueueFull, 1);
            return Err(QueueFull);
        }
        q.push(Request { model, kind, x, submitted_ns: obs::now_ns() });
        Ok(())
    }

    /// Number of pending requests.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take every pending request, preserving submission order.
    fn drain(&self) -> Vec<Request> {
        std::mem::take(&mut *self.inner.lock().unwrap())
    }
}

// ---------------- model registry ----------------

/// Long-lived registry of trained models. The cached artifacts (pivoted
/// Cholesky factor, `alpha`) live inside each [`GpRegression`] and
/// survive across dispatch batches; model ids are insertion indices.
pub struct ModelRegistry<O: PredictiveOp> {
    models: Vec<GpRegression<O>>,
}

impl<O: PredictiveOp> Default for ModelRegistry<O> {
    fn default() -> Self {
        Self::new()
    }
}

impl<O: PredictiveOp> ModelRegistry<O> {
    pub fn new() -> Self {
        ModelRegistry { models: Vec::new() }
    }

    /// Register a model; returns its id.
    pub fn insert(&mut self, gp: GpRegression<O>) -> usize {
        self.models.push(gp);
        self.models.len() - 1
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn get_mut(&mut self, id: usize) -> Option<&mut GpRegression<O>> {
        self.models.get_mut(id)
    }

    /// Pre-solve the cached artifacts for model `id` (the `alpha` solve,
    /// which also builds the preconditioner when the model's `cg.precond`
    /// knob asks for one), so the first live request is served from warm
    /// caches.
    pub fn warm(&mut self, id: usize) {
        if let Some(gp) = self.models.get_mut(id) {
            let _ = gp.alpha();
        }
    }
}

// ---------------- metrics ----------------

/// Per-model serving rollup, accumulated by [`dispatch`] and keyed by
/// model id in [`Metrics::per_model_snapshot`]. Everything here is a
/// restriction of the global counters to one model's traffic, so the
/// column sums across models reconcile with [`Metrics::serving_snapshot`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PerModelMetrics {
    /// Mean requests answered (from the cached alpha).
    pub mean_requests: usize,
    /// Variance requests answered (columns of fused solves).
    pub var_requests: usize,
    /// Fused block solves dispatched for this model.
    pub solves: usize,
    /// Columns fused across this model's solves (== `var_requests`).
    pub coalesced_cols: usize,
    /// Solver MVMs spent on this model (alpha refreshes + fused solves).
    pub mvms: usize,
    /// Blocked operator applies spent on this model's fused solves.
    pub block_applies: usize,
}

/// Service counters: the original evaluation/mvm counters plus the
/// serving-layer accounting (solves dispatched, fused columns,
/// back-pressure rejections), a per-request latency histogram, and a
/// per-model rollup for the replay report.
pub struct Metrics {
    pub evaluations: AtomicUsize,
    pub mvms: AtomicUsize,
    /// Block solves dispatched (one per fused predict-var batch).
    pub solves: AtomicUsize,
    /// Blocked operator applies executed by dispatched solves.
    pub block_applies: AtomicUsize,
    /// Total columns fused across all dispatched solves — divide by
    /// `solves` for the mean coalesced batch width.
    pub coalesced_cols: AtomicUsize,
    /// Submissions rejected by queue back-pressure.
    pub rejected: AtomicUsize,
    /// Per-request latency in nanoseconds (submit → response).
    latency_ns: Mutex<Histogram>,
    /// Per-model rollups, keyed by model id.
    per_model: Mutex<BTreeMap<usize, PerModelMetrics>>,
}

/// Latency histogram range: 100 ns .. 100 s, 90 log-spaced buckets
/// (≈ 26% bucket ratio, so quantiles over-read by at most that factor).
const LATENCY_LO_NS: f64 = 1e2;
const LATENCY_HI_NS: f64 = 1e11;
const LATENCY_BUCKETS: usize = 90;

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            evaluations: AtomicUsize::new(0),
            mvms: AtomicUsize::new(0),
            solves: AtomicUsize::new(0),
            block_applies: AtomicUsize::new(0),
            coalesced_cols: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            latency_ns: Mutex::new(Histogram::log_spaced(
                LATENCY_LO_NS,
                LATENCY_HI_NS,
                LATENCY_BUCKETS,
            )),
            per_model: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Metrics {
    pub fn add_eval(&self) {
        self.evaluations.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_mvms(&self, k: usize) {
        self.mvms.fetch_add(k, Ordering::Relaxed);
    }
    pub fn add_solve(&self) {
        self.solves.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_block_applies(&self, k: usize) {
        self.block_applies.fetch_add(k, Ordering::Relaxed);
    }
    pub fn add_coalesced(&self, cols: usize) {
        self.coalesced_cols.fetch_add(cols, Ordering::Relaxed);
    }
    pub fn add_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }
    /// Record one request's latency (nanoseconds).
    pub fn record_latency_ns(&self, ns: f64) {
        self.latency_ns.lock().unwrap().record(ns);
    }
    /// Latency quantile in nanoseconds (NaN when nothing recorded).
    pub fn latency_quantile_ns(&self, q: f64) -> f64 {
        self.latency_ns.lock().unwrap().quantile(q)
    }
    /// Exact latency summary `(count, mean, min, max)` in nanoseconds —
    /// the histogram's exact tallies, not bucket approximations. The
    /// floats are NaN when nothing has been recorded.
    pub fn latency_exact_ns(&self) -> (u64, f64, f64, f64) {
        let h = self.latency_ns.lock().unwrap();
        (h.count(), h.mean(), h.min(), h.max())
    }
    /// Mutate one model's rollup under the lock.
    fn with_model(&self, model: usize, f: impl FnOnce(&mut PerModelMetrics)) {
        f(self.per_model.lock().unwrap().entry(model).or_default());
    }
    /// Per-model rollups in ascending model-id order.
    pub fn per_model_snapshot(&self) -> Vec<(usize, PerModelMetrics)> {
        self.per_model.lock().unwrap().iter().map(|(&k, &v)| (k, v)).collect()
    }
    /// `(evaluations, mvms)` — the original throughput snapshot.
    pub fn snapshot(&self) -> (usize, usize) {
        (
            self.evaluations.load(Ordering::Relaxed),
            self.mvms.load(Ordering::Relaxed),
        )
    }
    /// `(solves, block_applies, coalesced_cols, rejected)` — the
    /// serving-layer accounting snapshot.
    pub fn serving_snapshot(&self) -> (usize, usize, usize, usize) {
        (
            self.solves.load(Ordering::Relaxed),
            self.block_applies.load(Ordering::Relaxed),
            self.coalesced_cols.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
        )
    }
}

// ---------------- dispatcher ----------------

/// Drain every pending request and answer them, coalescing per model.
///
/// Grouping is by model id (ascending) and, within a model, by submission
/// order; the returned responses are in the original submission order.
/// All `Var` requests of one model share **one** cold fused
/// [`pcg_block`](crate::solvers::pcg_block) solve (answers sliced out by
/// column — bit-identical to solo solves, see the module docs); `Mean`
/// requests share the model's cached `alpha`. Per-request latency is
/// recorded into `metrics` as each response is produced.
pub fn dispatch<O: PredictiveOp>(
    reg: &mut ModelRegistry<O>,
    queue: &RequestQueue,
    metrics: &Metrics,
) -> Vec<Response> {
    let _span = crate::span!("dispatch");
    let requests = queue.drain();
    let mut out: Vec<Option<Response>> = (0..requests.len()).map(|_| None).collect();
    // Deterministic model order; within a model, submission order.
    let mut by_model: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, r) in requests.iter().enumerate() {
        by_model.entry(r.model).or_default().push(i);
    }
    for (&model, idxs) in &by_model {
        let _mspan = crate::span!("dispatch_model");
        let Some(gp) = reg.get_mut(model) else {
            // Unknown model: answer NaN, unconverged — the replay driver
            // validates ids up front, so this is a programming error
            // surfaced loudly rather than a panic in the serving loop.
            for &i in idxs {
                let r = &requests[i];
                out[i] = Some(Response {
                    model,
                    kind: r.kind,
                    value: f64::NAN,
                    converged: false,
                    half_width: None,
                });
            }
            continue;
        };
        let mean_idx: Vec<usize> =
            idxs.iter().copied().filter(|&i| requests[i].kind == RequestKind::Mean).collect();
        let var_idx: Vec<usize> =
            idxs.iter().copied().filter(|&i| requests[i].kind == RequestKind::Var).collect();
        if !mean_idx.is_empty() {
            // One cached-alpha solve serves every mean request; after the
            // first batch this hits the cache and costs only the
            // cross-kernel applies.
            let (_, ainfo) = gp.alpha();
            metrics.add_mvms(ainfo.mvms);
            metrics.with_model(model, |m| {
                m.mean_requests += mean_idx.len();
                m.mvms += ainfo.mvms;
            });
            let xs: Vec<Vec<f64>> = mean_idx.iter().map(|&i| requests[i].x.clone()).collect();
            let values = gp.predict_mean(&xs);
            for (&i, v) in mean_idx.iter().zip(&values) {
                out[i] = Some(Response {
                    model,
                    kind: RequestKind::Mean,
                    value: *v,
                    converged: ainfo.converged,
                    half_width: None,
                });
            }
        }
        if !var_idx.is_empty() {
            // Fuse every pending variance request into ONE cold block
            // solve. The cold path is forced (and restored) because the
            // group-sequential warm-start path is not bitwise-reproducible
            // against solo per-request answers.
            let saved_warm = gp.warm_start_predict_var;
            gp.warm_start_predict_var = false;
            let xs: Vec<Vec<f64>> = var_idx.iter().map(|&i| requests[i].x.clone()).collect();
            let (vars, info) = gp.predict_var_info(&xs);
            gp.warm_start_predict_var = saved_warm;
            metrics.add_solve();
            metrics.add_coalesced(xs.len());
            metrics.add_mvms(info.mvms);
            metrics.add_block_applies(info.block_applies);
            metrics.with_model(model, |m| {
                m.var_requests += var_idx.len();
                m.solves += 1;
                m.coalesced_cols += xs.len();
                m.mvms += info.mvms;
                m.block_applies += info.block_applies;
            });
            let s2 = gp.op.noise_var();
            for ((&i, v), cinfo) in var_idx.iter().zip(&vars).zip(&info.cols) {
                // Per-request error bound (see `Response::half_width`):
                // the column's exit residual is scaled (relative to
                // `‖k_*‖`, absolute for near-zero columns), so undo the
                // scale before applying `‖r‖ · ‖k_*‖ / σ²`.
                let knorm = crate::util::stats::norm2(&gp.op.cross_col(&requests[i].x));
                let hw = cinfo.residual * crate::solvers::cg::residual_scale(knorm)
                    * knorm
                    / s2;
                out[i] = Some(Response {
                    model,
                    kind: RequestKind::Var,
                    value: *v,
                    converged: cinfo.converged,
                    half_width: hw.is_finite().then_some(hw),
                });
            }
        }
    }
    // Stamp latency + evaluation count in submission order. One clock
    // reading covers the whole batch: each request's latency is the
    // difference of two readings off the shared [`obs::now_ns`] anchor
    // (submit-side and here), never a mix of independent `Instant`s.
    let now = obs::now_ns();
    let mut wait_total: u64 = 0;
    let responses: Vec<Response> = requests
        .iter()
        .zip(out)
        .map(|(r, resp)| {
            metrics.add_eval();
            let ns = now.saturating_sub(r.submitted_ns);
            wait_total += ns;
            metrics.record_latency_ns(ns as f64);
            resp.expect("every drained request answered")
        })
        .collect();
    obs::add(obs::Counter::QueueWaitNs, wait_total);
    responses
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::DenseKernelOp;
    use crate::solvers::{CgOptions, PrecondOptions};
    use crate::util::rng::Rng;

    #[test]
    fn map_matches_serial_and_counts_builders() {
        let built = AtomicUsize::new(0);
        let hypers: Vec<Vec<f64>> = (0..37).map(|i| vec![i as f64]).collect();
        let got = map_hyper_batch(
            || {
                built.fetch_add(1, Ordering::Relaxed);
                |h: &[f64]| h[0] * 2.0
            },
            &hypers,
            4,
        );
        let want: Vec<f64> = hypers.iter().map(|h| h[0] * 2.0).collect();
        assert_eq!(got, want);
        assert!(built.load(Ordering::Relaxed) <= 4);
    }

    #[test]
    fn single_thread_path() {
        let hypers = vec![vec![1.0], vec![2.0]];
        let got = map_hyper_batch(|| |h: &[f64]| h[0] + 1.0, &hypers, 1);
        assert_eq!(got, vec![2.0, 3.0]);
    }

    #[test]
    fn metrics_counters() {
        let m = Metrics::default();
        m.add_eval();
        m.add_mvms(10);
        m.add_mvms(5);
        assert_eq!(m.snapshot(), (1, 15));
        m.add_solve();
        m.add_block_applies(7);
        m.add_coalesced(4);
        m.add_rejected();
        assert_eq!(m.serving_snapshot(), (1, 7, 4, 1));
        assert!(m.latency_quantile_ns(0.5).is_nan()); // nothing recorded
        assert_eq!(m.latency_exact_ns().0, 0);
        m.record_latency_ns(1e4);
        assert!(m.latency_quantile_ns(0.5).is_finite());
        let (cnt, mean, lo, hi) = m.latency_exact_ns();
        assert_eq!(cnt, 1);
        assert_eq!(mean, 1e4);
        assert_eq!((lo, hi), (1e4, 1e4));
        assert!(m.per_model_snapshot().is_empty());
    }

    /// A model with explicit (process-default-independent) solver options
    /// so the coalescing tests are immune to other tests mutating the
    /// global cg-block / precond defaults.
    fn demo_model(n: usize, seed: u64, rank: usize) -> GpRegression<DenseKernelOp> {
        let mut rng = Rng::new(seed);
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        let y: Vec<f64> =
            pts.iter().map(|p| (1.3 * p[0]).sin() + 0.1 * rng.gaussian()).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.05, // small noise: solves take real iterations
        );
        let mut gp = GpRegression::new(op, y);
        gp.cg = CgOptions {
            tol: 1e-10,
            max_iters: 400,
            block_size: 16,
            threads: 1,
            precond: PrecondOptions::rank(rank), // rank 0 = off
            ..gp.cg
        };
        gp
    }

    fn test_points(k: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..k).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect()
    }

    /// The coalescing contract: N single-point variance requests fused
    /// into one solve answer bitwise identically to N solo dispatches,
    /// while doing strictly fewer solves AND strictly fewer block applies
    /// at equal convergence.
    #[test]
    fn coalesced_var_matches_solo_bitwise_with_fewer_applies() {
        for rank in [0usize, 8] {
            let xs = test_points(7, 99);

            // Coalesced: all 7 requests pending in one drain.
            let mut reg = ModelRegistry::new();
            let id = reg.insert(demo_model(64, 7, rank));
            let queue = RequestQueue::bounded(64);
            let metrics = Metrics::default();
            for x in &xs {
                queue.submit(id, RequestKind::Var, x.clone()).unwrap();
            }
            let fused = dispatch(&mut reg, &queue, &metrics);
            let (fused_solves, fused_applies, fused_cols, _) = metrics.serving_snapshot();
            assert_eq!(fused_cols, 7);
            assert_eq!(fused_solves, 1);

            // Solo: identical model, one dispatch per request.
            let mut reg_solo = ModelRegistry::new();
            let id_solo = reg_solo.insert(demo_model(64, 7, rank));
            let solo_metrics = Metrics::default();
            let mut solo: Vec<Response> = Vec::new();
            for x in &xs {
                let q = RequestQueue::bounded(64);
                q.submit(id_solo, RequestKind::Var, x.clone()).unwrap();
                solo.extend(dispatch(&mut reg_solo, &q, &solo_metrics));
            }
            let (solo_solves, solo_applies, _, _) = solo_metrics.serving_snapshot();

            for (f, s) in fused.iter().zip(&solo) {
                assert_eq!(f.value.to_bits(), s.value.to_bits(), "rank={rank}");
                assert_eq!(f.converged, s.converged, "rank={rank}");
                assert!(f.converged, "rank={rank}: solves must converge");
                // The per-request error bound is present on var answers
                // and identical fused vs. solo (same column residual).
                let fh = f.half_width.expect("var answers carry a bound");
                let sh = s.half_width.expect("var answers carry a bound");
                assert_eq!(fh.to_bits(), sh.to_bits(), "rank={rank}");
                assert!(fh.is_finite() && fh >= 0.0, "rank={rank}: bound {fh}");
            }
            assert!(
                fused_solves < solo_solves,
                "rank={rank}: {fused_solves} !< {solo_solves}"
            );
            assert!(
                fused_applies < solo_applies,
                "rank={rank}: {fused_applies} !< {solo_applies}"
            );
        }
    }

    /// Mean requests ride the cached alpha: the first batch pays the
    /// training solve, later batches add no block solves and answer
    /// exactly like `predict_mean`.
    #[test]
    fn mean_requests_use_cached_alpha() {
        let xs = test_points(5, 17);
        let mut reg = ModelRegistry::new();
        let id = reg.insert(demo_model(48, 3, 0));
        reg.warm(id);
        let metrics = Metrics::default();
        let queue = RequestQueue::bounded(16);
        for x in &xs {
            queue.submit(id, RequestKind::Mean, x.clone()).unwrap();
        }
        let got = dispatch(&mut reg, &queue, &metrics);
        let want = {
            let mut gp = demo_model(48, 3, 0);
            gp.predict_mean(&xs)
        };
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.value.to_bits(), w.to_bits());
            assert!(g.converged);
            assert!(g.half_width.is_none(), "mean answers carry no solve bound");
        }
        // Mean traffic dispatched zero block solves.
        assert_eq!(metrics.serving_snapshot().0, 0);
        assert_eq!(metrics.snapshot().0, 5);
    }

    /// Mixed kinds and models in one drain: responses come back in
    /// submission order with the right kind, and per-model var traffic is
    /// coalesced (one solve per model, not per request).
    #[test]
    fn mixed_batch_keeps_submission_order_and_coalesces_per_model() {
        let mut reg = ModelRegistry::new();
        let a = reg.insert(demo_model(40, 11, 0));
        let b = reg.insert(demo_model(40, 13, 0));
        let metrics = Metrics::default();
        let queue = RequestQueue::bounded(16);
        let pts = test_points(6, 5);
        let plan = [
            (b, RequestKind::Var),
            (a, RequestKind::Mean),
            (a, RequestKind::Var),
            (b, RequestKind::Var),
            (a, RequestKind::Var),
            (b, RequestKind::Mean),
        ];
        for ((m, k), x) in plan.iter().zip(&pts) {
            queue.submit(*m, *k, x.clone()).unwrap();
        }
        let got = dispatch(&mut reg, &queue, &metrics);
        assert_eq!(got.len(), 6);
        for (r, (m, k)) in got.iter().zip(&plan) {
            assert_eq!((r.model, r.kind), (*m, *k));
            assert!(r.value.is_finite());
        }
        // Two models with var traffic -> exactly two fused solves, and
        // 4 var columns coalesced in total.
        let (solves, applies, cols, _) = metrics.serving_snapshot();
        assert_eq!(solves, 2);
        assert_eq!(cols, 4);
        // Per-model rollups reconcile with the global counters.
        let pm = metrics.per_model_snapshot();
        assert_eq!(pm.len(), 2);
        assert_eq!((pm[0].0, pm[1].0), (a, b));
        let (ma, mb) = (pm[0].1, pm[1].1);
        assert_eq!((ma.mean_requests, ma.var_requests), (1, 2));
        assert_eq!((mb.mean_requests, mb.var_requests), (1, 2));
        assert_eq!(ma.solves + mb.solves, solves);
        assert_eq!(ma.coalesced_cols + mb.coalesced_cols, cols);
        assert_eq!(ma.block_applies + mb.block_applies, applies);
        // p50/p99 are readable after a batch.
        assert!(metrics.latency_quantile_ns(0.5).is_finite());
        assert!(metrics.latency_quantile_ns(0.99).is_finite());
    }

    #[test]
    fn queue_backpressure_rejects_when_full() {
        let queue = RequestQueue::bounded(2);
        let metrics = Metrics::default();
        assert!(queue.submit(0, RequestKind::Mean, vec![0.0]).is_ok());
        assert!(queue.submit(0, RequestKind::Mean, vec![1.0]).is_ok());
        let r = queue.submit(0, RequestKind::Mean, vec![2.0]);
        assert_eq!(r, Err(QueueFull));
        metrics.add_rejected();
        assert_eq!(queue.len(), 2);
        assert_eq!(metrics.serving_snapshot().3, 1);
        // Draining frees capacity.
        let mut reg: ModelRegistry<DenseKernelOp> = ModelRegistry::new();
        let _ = dispatch(&mut reg, &queue, &metrics); // unknown model -> NaN
        assert!(queue.is_empty());
        assert!(queue.submit(0, RequestKind::Mean, vec![3.0]).is_ok());
    }

    /// Unknown model ids answer NaN/unconverged instead of panicking the
    /// serving loop.
    #[test]
    fn unknown_model_answers_nan() {
        let mut reg: ModelRegistry<DenseKernelOp> = ModelRegistry::new();
        let queue = RequestQueue::bounded(4);
        let metrics = Metrics::default();
        queue.submit(5, RequestKind::Var, vec![1.0]).unwrap();
        let got = dispatch(&mut reg, &queue, &metrics);
        assert_eq!(got.len(), 1);
        assert!(got[0].value.is_nan());
        assert!(!got[0].converged);
    }
}
