//! Hand-rolled CLI (clap is not in the offline registry).
//!
//! ```text
//! gpsld exp <id> [--scale small|paper] [--block <b>] [--cg-block <b>] [--precond-rank <k>] [--threads <t>] [--precision f64|f32f64] [--logdet-tol <t>] [--max-steps <s>] [--trace] [--trace-json <file>]
//! gpsld exp all  [--scale small|paper] [--block <b>] [--cg-block <b>] [--precond-rank <k>] [--threads <t>] [--precision f64|f32f64] [--logdet-tol <t>] [--max-steps <s>] [--trace] [--trace-json <file>]
//! gpsld serve --requests <file> [--threads <t>] [--n <train>] [--queue-cap <c>] [--precision f64|f32f64] [--trace] [--trace-json <file>]
//! gpsld artifacts                                      list/verify PJRT artifacts
//! gpsld info                                           version + feature summary
//! ```
//!
//! `--block <b>` sets the probe-block width used by every estimator in the
//! run (the default for `SlqOptions`/`ChebOptions` and the service layer);
//! `--cg-block <b>` sets the right-hand-side block width for the block-CG
//! solver (the default for `CgOptions`); `--precond-rank <k>` sets the
//! pivoted-Cholesky preconditioner rank for every solve and SLQ logdet
//! (0, the default, disables preconditioning — bit-identical to not
//! passing the flag); `--threads <t>` sets the process-default worker
//! count for RHS-group and probe-block fan-out
//! (`util::parallel::set_default_threads`; results are bit-identical at
//! any thread count, only wall-clock changes); `--precision f64|f32f64`
//! sets the process-default MVM precision for block solves and estimators
//! (`util::precision::set_default_precision`; `f64`, the default, is
//! bit-identical to not passing the flag, and block-CG convergence is
//! always confirmed against the f64 true residual in either mode — see
//! the `solvers` module docs); `--probes <p>` / `--steps <m>` set the
//! process-default probe count and per-probe step budget (Lanczos steps
//! and Chebyshev degree alike) for every stochastic estimator
//! (`estimators::set_default_probes`/`set_default_steps`);
//! `--logdet-tol <t>` turns every SLQ/Chebyshev logdet into a two-axis
//! adaptive run: the driver splits the 95% confidence interval's
//! half-width into its Monte-Carlo and truncation parts and grows
//! whichever axis dominates — new probes, or deeper retained
//! Lanczos/Chebyshev sessions — until the half-width clears `t`
//! (`estimators::set_default_logdet_tol`; unset, the default, keeps
//! fixed budgets bit-identical to not passing the flag — see the
//! `estimators` module docs for the session/two-axis contract);
//! `--max-steps <s>` caps the adaptive step/degree axis at `s`
//! (`estimators::set_default_max_steps`; unset the axis may grow to
//! `2 × steps`, and `--max-steps` equal to `--steps` pins the step axis,
//! restoring the probes-only adaptive driver — fixed-budget runs ignore
//! the flag entirely); `--trace` enables the `util::obs` span/counter
//! registry for the run and prints the flat + tree profile afterwards;
//! `--trace-json <file>` writes the same profile as a stable JSON
//! document (schema `gpsld-trace-v1`). Both flags work on `exp` and
//! `serve`, may be combined, and are observation-only: tracing on or off,
//! every numeric result is bit-identical (pinned by the tracing-inert
//! proptests).
//!
//! `serve` is the offline request-replay driver for the streaming service
//! layer (`coordinator::service`): it reads one request per line
//! (`<model> <mean|var> <x>`; blank lines and `#` comments skipped),
//! builds one trained demo model per referenced id, replays the batch
//! through the coalescing dispatcher AND the solo per-request baseline,
//! and prints the amortization report (solves / block applies vs. solo,
//! convergence, bitwise-equality check, p50/p99 latency). Variance
//! answers print `value ± bound`, the deterministic solve-error bound
//! from the column's exit residual (`service::Response::half_width`);
//! a non-converged column prints an explicit `UNCONVERGED` marker
//! instead of a bound. `--precision f32f64` runs the replay's block
//! solves in mixed precision (convergence is still confirmed against
//! the f64 true residual, so answers remain bitwise-equal between the
//! coalesced and solo paths). Garbage —
//! unknown flags, malformed lines, out-of-range model ids, unreadable
//! files — exits 2 before any replay runs; queue back-pressure drops are
//! reported, not fatal.

use super::{experiments, figures, ExpResult, Scale};

const EXP_IDS: &[&str] = &[
    "fig1", "table1", "table2", "table3", "table4", "table5",
    "fig3_fig4", "fig5", "fig6", "fig7", "perf",
];

pub fn usage() -> String {
    format!(
        "gpsld {} — Scalable Log Determinants for GP Kernel Learning (NIPS 2017 repro)\n\n\
         USAGE:\n  gpsld exp <id|all> [--scale small|paper] [--block <b>] [--cg-block <b>] [--precond-rank <k>] [--threads <t>] [--precision f64|f32f64] [--probes <p>] [--steps <m>] [--logdet-tol <t>] [--max-steps <s>] [--md <file>] [--trace] [--trace-json <file>]\n  gpsld serve --requests <file> [--threads <t>] [--n <train>] [--queue-cap <c>] [--precision f64|f32f64] [--trace] [--trace-json <file>]\n  gpsld artifacts\n  gpsld info\n\n\
         `--block <b>` sets the default probe-block width for blocked MVMs.\n\
         `--cg-block <b>` sets the default RHS block width for block-CG solves.\n\
         `--precond-rank <k>` sets the pivoted-Cholesky preconditioner rank (0 = off).\n\
         `--threads <t>` sets the default worker count for RHS-group/probe-block fan-out.\n\
         `--precision f64|f32f64` sets the default MVM precision (f32 storage / f64 accumulation; solves still confirm in f64).\n\
         `--probes <p>` sets the default probe count for stochastic estimators.\n\
         `--steps <m>` sets the default per-probe step budget (Lanczos steps / Chebyshev degree).\n\
         `--logdet-tol <t>` makes logdet estimates adaptive on two axes: grow probes or deepen the\n\
         retained Lanczos/Chebyshev sessions (whichever CI term dominates) until the 95% half-width <= t.\n\
         `--max-steps <s>` caps the adaptive step/degree axis (unset: up to 2x --steps; equal to --steps:\n\
         probes-only growth). Fixed-budget runs ignore it.\n\
         `--trace` prints the hierarchical span profile (timings + mvm/apply/probe counters) after the run;\n\
         `--trace-json <file>` writes the same profile as a stable JSON document (schema gpsld-trace-v1).\n\
         Tracing is observation-only: every numeric result is bit-identical with it on or off.\n\n\
         `serve` replays a request file (one `<model> <mean|var> <x>` per line; blank/# lines skipped)\n\
         through the coalescing dispatcher and the solo baseline, and prints the amortization report;\n\
         var answers print `value ± bound` (solve-error bound) or an UNCONVERGED marker.\n\
         `--n <train>` sets the demo models' training-set size (default 96); `--queue-cap <c>` the\n\
         bounded queue depth (default 1024; overflow is counted as back-pressure, not an error);\n\
         `--precision f32f64` replays the block solves in mixed precision (f64-confirmed).\n\
         The replay report includes a per-model metrics snapshot: request mix, fused-column totals,\n\
         solver spend, and alpha/preconditioner cache hit rates; `--trace`/`--trace-json` work here too.\n\n\
         EXPERIMENTS: {}\n",
        crate::version(),
        EXP_IDS.join(", ")
    )
}

pub fn run_experiment(id: &str, scale: Scale) -> Option<ExpResult> {
    let res = match id {
        "fig1" => experiments::fig1_sound(scale),
        "table1" => experiments::table1_precipitation(scale),
        "table2" => experiments::table2_hickory(scale),
        "table3" => experiments::table3_crime(scale),
        "table4" => experiments::table4_dkl(scale),
        "table5" => experiments::table5_recovery(scale),
        "fig3_fig4" => figures::fig3_fig4_cross_sections(scale),
        "fig5" => figures::fig5_spectrum(scale),
        "fig6" => figures::fig6_diag_correction(scale),
        "fig7" => figures::fig7_surrogate(scale),
        "perf" => figures::perf_mvm(scale),
        _ => return None,
    };
    Some(res)
}

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn main_with_args(args: &[String]) -> i32 {
    match args.first().map(String::as_str) {
        Some("exp") => {
            let Some(id) = args.get(1) else {
                eprintln!("{}", usage());
                return 2;
            };
            let mut scale = Scale::Small;
            let mut md_out: Option<String> = None;
            let mut trace = false;
            let mut trace_json: Option<String> = None;
            let mut i = 2;
            while i < args.len() {
                match args[i].as_str() {
                    "--scale" => {
                        // Reject garbage like every other flag — silently
                        // falling back to small-scale would let a typo'd
                        // "paper" run (and record) the wrong experiment.
                        match args.get(i + 1).and_then(|s| Scale::parse(s)) {
                            Some(s) => scale = s,
                            None => {
                                eprintln!("--scale needs 'small' or 'paper'");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--md" => {
                        // Like the other flags: a missing operand is an
                        // error, not a silent no-op that runs the whole
                        // experiment and writes nothing.
                        match args.get(i + 1) {
                            Some(p) => md_out = Some(p.clone()),
                            None => {
                                eprintln!("--md needs an output path");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--block" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(b) if b >= 1 => crate::estimators::set_default_block_size(b),
                            _ => {
                                eprintln!("--block needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--cg-block" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(b) if b >= 1 => crate::solvers::set_default_cg_block_size(b),
                            _ => {
                                eprintln!("--cg-block needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--threads" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(t) if t >= 1 => {
                                crate::util::parallel::set_default_threads(t)
                            }
                            _ => {
                                eprintln!("--threads needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--precision" => {
                        match args.get(i + 1).and_then(|s| {
                            crate::util::precision::Precision::parse(s)
                        }) {
                            Some(p) => crate::util::precision::set_default_precision(p),
                            None => {
                                eprintln!("--precision needs 'f64' or 'f32f64'");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--probes" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(p) if p >= 1 => crate::estimators::set_default_probes(p),
                            _ => {
                                eprintln!("--probes needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--steps" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(m) if m >= 1 => crate::estimators::set_default_steps(m),
                            _ => {
                                eprintln!("--steps needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--logdet-tol" => {
                        match args.get(i + 1).and_then(|s| s.parse::<f64>().ok()) {
                            Some(t) if t > 0.0 && t.is_finite() => {
                                crate::estimators::set_default_logdet_tol(Some(t))
                            }
                            _ => {
                                eprintln!("--logdet-tol needs a positive finite number");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--max-steps" => {
                        // 0 is the internal "auto" sentinel; the CLI keeps
                        // the flag convention (a cap you pass must be a
                        // positive integer — omit the flag for auto).
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(s) if s >= 1 => {
                                crate::estimators::set_default_max_steps(s)
                            }
                            _ => {
                                eprintln!("--max-steps needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--precond-rank" => {
                        // 0 is legal: it means "preconditioning off".
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(k) => crate::solvers::set_default_precond_rank(k),
                            None => {
                                eprintln!("--precond-rank needs a non-negative integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--trace" => {
                        trace = true;
                        i += 1;
                    }
                    "--trace-json" => {
                        match args.get(i + 1) {
                            Some(p) => trace_json = Some(p.clone()),
                            None => {
                                eprintln!("--trace-json needs an output path");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    other => {
                        eprintln!("unknown flag {other}");
                        return 2;
                    }
                }
            }
            // Tracing is observation-only (bit-inert on every numeric
            // result — see `util::obs`), so enabling it here cannot change
            // what the experiments compute, only what gets reported.
            let tracing = trace || trace_json.is_some();
            if tracing {
                crate::util::obs::set_enabled(true);
                crate::util::obs::reset();
            }
            let ids: Vec<&str> = if id == "all" {
                EXP_IDS.to_vec()
            } else {
                vec![id.as_str()]
            };
            let mut md = String::new();
            for id in ids {
                let t0 = std::time::Instant::now();
                match run_experiment(id, scale) {
                    Some(res) => {
                        res.print(&format!("{id} (scale={scale:?})"));
                        println!("[{}s]", super::fmt_s(t0.elapsed().as_secs_f64()));
                        md.push_str(&format!("\n### {id}\n\n{}", res.to_markdown()));
                    }
                    None => {
                        eprintln!("unknown experiment {id}\n{}", usage());
                        return 2;
                    }
                }
            }
            if let Some(path) = md_out {
                if let Err(e) = std::fs::write(&path, md) {
                    eprintln!("failed to write {path}: {e}");
                    if tracing {
                        crate::util::obs::set_enabled(false);
                    }
                    return 1;
                }
                println!("wrote {path}");
            }
            if let Some(code) = finish_trace(trace, trace_json, tracing) {
                return code;
            }
            0
        }
        Some("serve") => run_serve(&args[1..]),
        Some("artifacts") => match crate::runtime::PjrtRuntime::new("artifacts") {
            Ok(rt) => {
                println!("platform: {}", rt.platform());
                for name in rt.names() {
                    let s = &rt.specs[&name];
                    println!(
                        "  {name}  graph={} kind={} in={:?} out={:?}",
                        s.graph, s.kind, s.in_shapes, s.out_shapes
                    );
                }
                0
            }
            Err(e) => {
                eprintln!("artifacts unavailable: {e}");
                1
            }
        },
        Some("info") => {
            println!("gpsld {}", crate::version());
            println!("estimators: lanczos(slq), chebyshev, surrogate, scaled_eig, exact");
            println!(
                "confidence: per-probe spectral evidence + 95% intervals on every \
                 logdet; two-axis adaptive budgets over resumable sessions — \
                 probes vs. Lanczos steps / Chebyshev degree \
                 (--probes, --steps, --logdet-tol, --max-steps)"
            );
            println!(
                "solvers: cg/block-cg with pivoted-Cholesky PCG (--precond-rank), \
                 parallel RHS groups (--threads)"
            );
            println!(
                "precision: f64 (default) | f32f64 mixed MVMs with f64 \
                 iterative-refinement confirmation (--precision)"
            );
            println!("operators: dense, toeplitz, kronecker, ski(+diag), fitc/sor, sum");
            println!("likelihoods: gaussian, poisson(lgcp), negative-binomial");
            println!("runtime: PJRT CPU via xla crate; artifacts from python/compile (JAX+Pallas)");
            0
        }
        _ => {
            eprintln!("{}", usage());
            2
        }
    }
}

/// Emit the requested trace surfaces after a traced `exp`/`serve` run and
/// restore the disabled default: `--trace` prints the flat + tree profile
/// to stdout, `--trace-json` writes the stable `gpsld-trace-v1` document.
/// Returns `Some(exit_code)` when writing the JSON file fails, `None`
/// otherwise (including the untraced case, which touches nothing).
fn finish_trace(trace: bool, trace_json: Option<String>, tracing: bool) -> Option<i32> {
    use crate::util::obs;
    if trace {
        print!("{}", obs::report_text());
    }
    if let Some(path) = trace_json {
        if let Err(e) = std::fs::write(&path, obs::report_json()) {
            eprintln!("failed to write {path}: {e}");
            obs::set_enabled(false);
            return Some(1);
        }
        println!("wrote {path}");
    }
    if tracing {
        obs::set_enabled(false);
    }
    None
}

/// Demo-registry size cap for `serve`: the replay driver builds one
/// trained demo model per model id referenced in the request file, so an
/// id typo (say, `1000000`) must be rejected at parse time rather than
/// silently training a million models.
const MAX_SERVE_MODELS: usize = 16;

/// Parse the `serve --requests` replay file: one request per line,
/// `<model> <mean|var> <x>`; blank lines and `#` comments are skipped.
/// Any malformed line is an error naming the line — the driver validates
/// the whole file before building a single model.
fn parse_requests(
    text: &str,
) -> Result<Vec<(usize, super::service::RequestKind, f64)>, String> {
    use super::service::RequestKind;
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(m), Some(k), Some(x), None) = (it.next(), it.next(), it.next(), it.next())
        else {
            return Err(format!(
                "line {}: expected `<model> <mean|var> <x>`, got {line:?}",
                lineno + 1
            ));
        };
        let model: usize = m.parse().map_err(|_| {
            format!("line {}: model id {m:?} is not a non-negative integer", lineno + 1)
        })?;
        if model >= MAX_SERVE_MODELS {
            return Err(format!(
                "line {}: model id {model} out of range for the demo registry \
                 (0..{MAX_SERVE_MODELS})",
                lineno + 1
            ));
        }
        let kind = match k {
            "mean" => RequestKind::Mean,
            "var" => RequestKind::Var,
            _ => {
                return Err(format!(
                    "line {}: kind {k:?} must be `mean` or `var`",
                    lineno + 1
                ))
            }
        };
        let x: f64 = x
            .parse()
            .ok()
            .filter(|v: &f64| v.is_finite())
            .ok_or_else(|| format!("line {}: x {x:?} is not a finite number", lineno + 1))?;
        out.push((model, kind, x));
    }
    Ok(out)
}

/// `gpsld serve`: validate flags and the request file, then replay.
fn run_serve(args: &[String]) -> i32 {
    let mut req_path: Option<String> = None;
    let mut threads: Option<usize> = None;
    let mut n_train = 96usize;
    let mut queue_cap = 1024usize;
    let mut precision = crate::util::precision::Precision::F64;
    let mut trace = false;
    let mut trace_json: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            // The only flag with no operand: advance by one and skip the
            // loop's uniform two-token step.
            "--trace" => {
                trace = true;
                i += 1;
                continue;
            }
            "--trace-json" => match args.get(i + 1) {
                Some(p) => trace_json = Some(p.clone()),
                None => {
                    eprintln!("--trace-json needs an output path");
                    return 2;
                }
            },
            "--requests" => match args.get(i + 1) {
                Some(p) => req_path = Some(p.clone()),
                None => {
                    eprintln!("--requests needs a file path");
                    return 2;
                }
            },
            "--precision" => match args
                .get(i + 1)
                .and_then(|s| crate::util::precision::Precision::parse(s))
            {
                Some(p) => precision = p,
                None => {
                    eprintln!("--precision needs 'f64' or 'f32f64'");
                    return 2;
                }
            },
            "--threads" => match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                Some(t) if t >= 1 => threads = Some(t),
                _ => {
                    eprintln!("--threads needs a positive integer");
                    return 2;
                }
            },
            "--n" => match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                Some(n) if n >= 8 => n_train = n,
                _ => {
                    eprintln!("--n needs an integer >= 8 (demo training-set size)");
                    return 2;
                }
            },
            "--queue-cap" => match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                Some(c) if c >= 1 => queue_cap = c,
                _ => {
                    eprintln!("--queue-cap needs a positive integer");
                    return 2;
                }
            },
            other => {
                eprintln!("unknown flag {other}");
                return 2;
            }
        }
        i += 2;
    }
    let Some(path) = req_path else {
        eprintln!("serve needs --requests <file>\n{}", usage());
        return 2;
    };
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("failed to read {path}: {e}");
            return 2;
        }
    };
    let reqs = match parse_requests(&text) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 2;
        }
    };
    if reqs.is_empty() {
        eprintln!("{path}: no requests (blank lines and `#` comments are skipped)");
        return 2;
    }
    // Tracing is observation-only (bit-inert on every answer the replay
    // produces — see `util::obs`), so enabling it cannot perturb the
    // fused-vs-solo bitwise comparison the report prints.
    let tracing = trace || trace_json.is_some();
    if tracing {
        crate::util::obs::set_enabled(true);
        crate::util::obs::reset();
    }
    let code = match threads {
        Some(t) => crate::util::parallel::with_default_threads(t, || {
            serve_replay(&reqs, n_train, queue_cap, precision)
        }),
        None => serve_replay(&reqs, n_train, queue_cap, precision),
    };
    if let Some(err) = finish_trace(trace, trace_json, tracing) {
        return err;
    }
    code
}

/// Replay the parsed requests through the coalescing dispatcher and the
/// solo per-request baseline, and print the amortization report. Always
/// returns 0: garbage was rejected at parse time, and queue back-pressure
/// drops are reported, not fatal.
fn serve_replay(
    reqs: &[(usize, super::service::RequestKind, f64)],
    n_train: usize,
    queue_cap: usize,
    precision: crate::util::precision::Precision,
) -> i32 {
    use super::service::{dispatch, Metrics, ModelRegistry, RequestKind, RequestQueue};
    use crate::gp::GpRegression;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::DenseKernelOp;
    use crate::solvers::{CgOptions, PrecondOptions};
    use crate::util::rng::Rng;

    let threads = crate::util::parallel::default_threads();
    let n_models = reqs.iter().map(|&(m, _, _)| m).max().unwrap_or(0) + 1;
    let make_model = |id: usize| {
        // One trained demo model per id: a dense RBF posterior with
        // explicit solver options, so replays are independent of the other
        // process-wide defaults (threads and precision are the only knobs
        // the CLI forwards — results are bit-identical across thread
        // counts, and mixed precision still confirms against the f64 true
        // residual).
        let mut rng = Rng::new(100 + id as u64);
        let pts: Vec<Vec<f64>> =
            (0..n_train).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let y: Vec<f64> =
            pts.iter().map(|p| (1.4 * p[0]).sin() + 0.1 * rng.gaussian()).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.1,
        );
        let mut gp = GpRegression::new(op, y);
        gp.cg = CgOptions {
            tol: 1e-8,
            max_iters: 5000,
            block_size: 16,
            threads,
            precond: PrecondOptions::rank(16),
            precision,
        };
        gp
    };

    // Registry with cached factors: alpha + pivoted Cholesky are built
    // once per model here and reused by every request below.
    let mut reg = ModelRegistry::new();
    for id in 0..n_models {
        reg.insert(make_model(id));
        reg.warm(id);
    }

    // Coalesced replay: everything pending in one drain. Back-pressure
    // drops are counted and reported; the solo baseline replays only the
    // accepted subset so the comparison stays apples-to-apples.
    let metrics = Metrics::default();
    let queue = RequestQueue::bounded(queue_cap);
    let mut accepted: Vec<usize> = Vec::new();
    for (i, &(m, k, x)) in reqs.iter().enumerate() {
        match queue.submit(m, k, vec![x]) {
            Ok(()) => accepted.push(i),
            Err(_) => metrics.add_rejected(),
        }
    }
    let fused = dispatch(&mut reg, &queue, &metrics);
    let (solves, applies, cols, rejected) = metrics.serving_snapshot();

    // Solo baseline: identical fresh models, one dispatch per request.
    let mut solo_reg = ModelRegistry::new();
    for id in 0..n_models {
        solo_reg.insert(make_model(id));
        solo_reg.warm(id);
    }
    let solo_metrics = Metrics::default();
    let mut solo = Vec::new();
    for &i in &accepted {
        let (m, k, x) = reqs[i];
        let q = RequestQueue::bounded(2);
        q.submit(m, k, vec![x]).expect("serve: solo queue sized for one request");
        solo.extend(dispatch(&mut solo_reg, &q, &solo_metrics));
    }
    let (solo_solves, solo_applies, _, _) = solo_metrics.serving_snapshot();

    let mut bitwise = true;
    for ((&i, f), s) in accepted.iter().zip(&fused).zip(&solo) {
        let (m, k, x) = reqs[i];
        let kind = if k == RequestKind::Var { "var" } else { "mean" };
        // Var answers carry the deterministic solve-error bound; a
        // non-converged column gets an explicit marker instead of a
        // bound that its residual no longer backs.
        match f.half_width.filter(|_| f.converged) {
            Some(hw) => println!(
                "#{i} model={m} {kind} x={x:.6} -> {:.12e} ± {hw:.3e} (converged)",
                f.value
            ),
            None => println!(
                "#{i} model={m} {kind} x={x:.6} -> {:.12e} ({})",
                f.value,
                if f.converged { "converged" } else { "UNCONVERGED" }
            ),
        }
        bitwise &= f.value.to_bits() == s.value.to_bits() && f.converged == s.converged;
    }
    let n_var =
        accepted.iter().filter(|&&i| reqs[i].1 == RequestKind::Var).count();
    let n_conv = fused.iter().filter(|r| r.converged).count();
    println!(
        "serve: {} requests ({} var, {} mean) across {} model(s), n={}, threads={}, \
         precision={}, rejected={}",
        fused.len(),
        n_var,
        fused.len() - n_var,
        n_models,
        n_train,
        threads,
        precision.name(),
        rejected,
    );
    println!(
        "  coalesced: {solves} solves / {applies} block applies ({cols} fused cols)  \
         solo: {solo_solves} solves / {solo_applies} block applies"
    );
    println!(
        "  converged {n_conv}/{}  bitwise-equal to solo: {}  latency p50 {:.3} ms  p99 {:.3} ms",
        fused.len(),
        if bitwise { "yes" } else { "NO" },
        metrics.latency_quantile_ns(0.5) / 1e6,
        metrics.latency_quantile_ns(0.99) / 1e6,
    );
    let (lat_n, lat_mean, lat_min, lat_max) = metrics.latency_exact_ns();
    println!(
        "  latency exact: n={lat_n}  mean {:.3} ms  min {:.3} ms  max {:.3} ms  \
         queue-full rejections {rejected}",
        lat_mean / 1e6,
        lat_min / 1e6,
        lat_max / 1e6,
    );
    // Per-model metrics snapshot: request mix, coalescing totals, solver
    // spend, and the model-cache hit rates (alpha = training solve,
    // precond = pivoted-Cholesky factor). Only the coalesced replay's
    // registry is inspected — the solo baseline exists for comparison.
    println!("  per-model:");
    for (id, m) in metrics.per_model_snapshot() {
        let cs = reg.get_mut(id).map(|gp| gp.cache_stats).unwrap_or_default();
        println!(
            "    model {id}: {} mean + {} var requests | {} solves, {} fused cols, \
             {} mvms, {} block applies | alpha cache {}/{} hits, precond cache {}/{} hits",
            m.mean_requests,
            m.var_requests,
            m.solves,
            m.coalesced_cols,
            m.mvms,
            m.block_applies,
            cs.alpha_hits,
            cs.alpha_hits + cs.alpha_misses,
            cs.pc_hits,
            cs.pc_hits + cs.pc_misses,
        );
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_lists_all_experiments() {
        let u = usage();
        for id in EXP_IDS {
            assert!(u.contains(id), "{id} missing from usage");
        }
    }

    #[test]
    fn unknown_command_is_error() {
        assert_eq!(main_with_args(&["bogus".into()]), 2);
        assert_eq!(main_with_args(&[]), 2);
    }

    #[test]
    fn unknown_experiment_is_none() {
        assert!(run_experiment("nope", Scale::Small).is_none());
    }

    #[test]
    fn precond_rank_flag_accepts_zero_rejects_garbage() {
        // 0 means "off" and must be accepted; non-numeric input is an
        // error before any experiment runs.
        assert_eq!(
            main_with_args(&["exp".into(), "nope".into(), "--precond-rank".into(), "0".into()]),
            2 // unknown experiment, but the flag itself parsed fine
        );
        assert_eq!(crate::solvers::default_precond_rank(), 0);
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--precond-rank".into(), "x".into()]),
            2
        );
    }

    #[test]
    fn threads_flag_sets_default_and_rejects_zero() {
        // A valid value lands in the process default (restored to auto
        // afterwards — every consumer is bit-identical across thread
        // counts, so a transient override only changes scheduling). The
        // lock serializes against the util::parallel test mutating the
        // same process-wide default.
        let _guard = crate::util::parallel::TEST_DEFAULT_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        // Pin to the current raw value: the drop guard restores whatever
        // was set before this test on every exit path (asserts included).
        crate::util::parallel::with_default_threads(
            crate::util::parallel::raw_default_threads(),
            || {
                assert_eq!(
                    main_with_args(&[
                        "exp".into(),
                        "nope".into(),
                        "--threads".into(),
                        "2".into()
                    ]),
                    2 // unknown experiment, but the flag itself parsed fine
                );
                assert_eq!(crate::util::parallel::default_threads(), 2);
                // 0 and garbage are rejected before any experiment runs.
                assert_eq!(
                    main_with_args(&[
                        "exp".into(),
                        "fig1".into(),
                        "--threads".into(),
                        "0".into()
                    ]),
                    2
                );
                assert_eq!(
                    main_with_args(&[
                        "exp".into(),
                        "fig1".into(),
                        "--threads".into(),
                        "x".into()
                    ]),
                    2
                );
            },
        );
    }

    #[test]
    fn precision_flag_sets_default_and_rejects_garbage() {
        use crate::util::precision::{
            default_precision, with_default_precision, Precision, TEST_DEFAULT_PRECISION_LOCK,
        };
        // Serialize against the util::precision tests mutating the same
        // process-wide default; the drop guard restores the prior value on
        // every exit path.
        let _guard = TEST_DEFAULT_PRECISION_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        with_default_precision(default_precision(), || {
            assert_eq!(
                main_with_args(&[
                    "exp".into(),
                    "nope".into(),
                    "--precision".into(),
                    "f32f64".into()
                ]),
                2 // unknown experiment, but the flag itself parsed fine
            );
            assert_eq!(default_precision(), Precision::F32F64);
            // Garbage and a missing operand are rejected (exit 2) before
            // any experiment runs.
            assert_eq!(
                main_with_args(&[
                    "exp".into(),
                    "fig1".into(),
                    "--precision".into(),
                    "f16".into()
                ]),
                2
            );
            assert_eq!(
                main_with_args(&["exp".into(), "fig1".into(), "--precision".into()]),
                2
            );
        });
    }

    #[test]
    fn scale_flag_rejects_garbage() {
        // A typo'd scale must error before any experiment runs, not
        // silently fall back to small.
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--scale".into(), "Paper".into()]),
            2
        );
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--scale".into()]),
            2
        );
        // --md with no operand must error too, before any experiment runs.
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--md".into()]),
            2
        );
    }

    #[test]
    fn probes_steps_flags_set_defaults_and_reject_garbage() {
        // Valid values land in the process-wide estimator defaults; 0 and
        // garbage are rejected (exit 2) before any experiment runs. The
        // defaults are restored afterwards so other tests see the
        // built-ins (estimator tests construct options explicitly, so a
        // transient override here cannot skew their budgets).
        assert_eq!(
            main_with_args(&["exp".into(), "nope".into(), "--probes".into(), "9".into()]),
            2 // unknown experiment, but the flag itself parsed fine
        );
        assert_eq!(crate::estimators::default_probes(), Some(9));
        assert_eq!(
            main_with_args(&["exp".into(), "nope".into(), "--steps".into(), "33".into()]),
            2
        );
        assert_eq!(crate::estimators::default_steps(), Some(33));
        crate::estimators::set_default_probes(0);
        crate::estimators::set_default_steps(0);
        for flag in ["--probes", "--steps"] {
            for bad in ["0", "x", "-1"] {
                assert_eq!(
                    main_with_args(&[
                        "exp".into(),
                        "fig1".into(),
                        flag.into(),
                        bad.into()
                    ]),
                    2,
                    "{flag} {bad} must be rejected"
                );
            }
            assert_eq!(main_with_args(&["exp".into(), "fig1".into(), flag.into()]), 2);
        }
        // Rejected values must not have landed in the defaults.
        assert_eq!(crate::estimators::default_probes(), None);
        assert_eq!(crate::estimators::default_steps(), None);
    }

    #[test]
    fn logdet_tol_flag_sets_default_and_rejects_garbage() {
        assert_eq!(
            main_with_args(&[
                "exp".into(),
                "nope".into(),
                "--logdet-tol".into(),
                "0.25".into()
            ]),
            2 // unknown experiment, but the flag itself parsed fine
        );
        assert_eq!(crate::estimators::default_logdet_tol(), Some(0.25));
        crate::estimators::set_default_logdet_tol(None);
        // Zero, negatives, non-finite, and garbage are rejected before
        // any experiment runs.
        for bad in ["0", "-1e-3", "nan", "inf", "x"] {
            assert_eq!(
                main_with_args(&[
                    "exp".into(),
                    "fig1".into(),
                    "--logdet-tol".into(),
                    bad.into()
                ]),
                2,
                "--logdet-tol {bad} must be rejected"
            );
        }
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--logdet-tol".into()]),
            2
        );
        assert_eq!(crate::estimators::default_logdet_tol(), None);
    }

    #[test]
    fn max_steps_flag_sets_default_and_rejects_garbage() {
        // A valid cap lands in the process-wide adaptive ceiling; 0 (the
        // internal auto sentinel), negatives, and garbage are rejected
        // (exit 2) before any experiment runs. Restored to auto afterwards
        // so other tests see the built-in.
        assert_eq!(
            main_with_args(&[
                "exp".into(),
                "nope".into(),
                "--max-steps".into(),
                "48".into()
            ]),
            2 // unknown experiment, but the flag itself parsed fine
        );
        assert_eq!(crate::estimators::default_max_steps(), 48);
        crate::estimators::set_default_max_steps(0);
        for bad in ["0", "-1", "nan", "x"] {
            assert_eq!(
                main_with_args(&[
                    "exp".into(),
                    "fig1".into(),
                    "--max-steps".into(),
                    bad.into()
                ]),
                2,
                "--max-steps {bad} must be rejected"
            );
        }
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--max-steps".into()]),
            2
        );
        assert_eq!(crate::estimators::default_max_steps(), 0);
    }

    #[test]
    fn serve_flag_validation_rejects_garbage() {
        // Missing --requests, missing operand, unreadable file, unknown
        // flags, and bad numeric operands all exit 2 before any replay
        // (or model build) runs.
        assert_eq!(main_with_args(&["serve".into()]), 2);
        assert_eq!(main_with_args(&["serve".into(), "--requests".into()]), 2);
        assert_eq!(
            main_with_args(&[
                "serve".into(),
                "--requests".into(),
                "/definitely/not/here.txt".into()
            ]),
            2
        );
        assert_eq!(main_with_args(&["serve".into(), "--bogus".into(), "1".into()]), 2);
        for (flag, bad) in [
            ("--threads", "0"),
            ("--threads", "x"),
            ("--n", "4"),
            ("--queue-cap", "0"),
            ("--precision", "f16"),
        ] {
            assert_eq!(
                main_with_args(&["serve".into(), flag.into(), bad.into()]),
                2,
                "{flag} {bad} must be rejected"
            );
        }
    }

    #[test]
    fn serve_request_file_parses_and_rejects_garbage() {
        use crate::coordinator::service::RequestKind;
        let good = "# comment\n0 var 1.25\n\n1 mean 0.5\n0 var 2.0\n";
        let got = parse_requests(good).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], (0, RequestKind::Var, 1.25));
        assert_eq!(got[1], (1, RequestKind::Mean, 0.5));
        for bad in [
            "0 var",           // missing x
            "0 var 1.0 extra", // trailing token
            "x var 1.0",       // non-numeric model id
            "99 var 1.0",      // model id out of demo-registry range
            "0 median 1.0",    // unknown kind
            "0 var nan",       // non-finite x
            "0 var z",         // non-numeric x
        ] {
            assert!(parse_requests(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn serve_replays_file_and_reports_amortization() {
        // End-to-end: a small mixed request file replays cleanly (exit 0).
        // The replay itself asserts nothing here — the coalescing
        // contract (bitwise equality, fewer solves) is pinned by the
        // service tests and proptests; this pins the driver wiring.
        let path = std::env::temp_dir()
            .join(format!("gpsld_serve_replay_{}.txt", std::process::id()));
        std::fs::write(
            &path,
            "0 var 0.3\n0 var 1.1\n# a mean rides the cached alpha\n0 mean 2.2\n0 var 1.9\n",
        )
        .unwrap();
        let code = main_with_args(&[
            "serve".into(),
            "--requests".into(),
            path.to_string_lossy().into_owned(),
            "--n".into(),
            "24".into(),
        ]);
        // Mixed precision replays the same file cleanly too (the solves
        // confirm against the f64 true residual, so the driver's
        // bitwise fused-vs-solo check still holds).
        let code_mixed = main_with_args(&[
            "serve".into(),
            "--requests".into(),
            path.to_string_lossy().into_owned(),
            "--n".into(),
            "24".into(),
            "--precision".into(),
            "f32f64".into(),
        ]);
        std::fs::remove_file(&path).ok();
        assert_eq!(code, 0);
        assert_eq!(code_mixed, 0);
    }

    #[test]
    fn trace_json_flag_needs_operand() {
        // Both subcommands reject a bare --trace-json before running
        // anything (and before tracing is enabled).
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--trace-json".into()]),
            2
        );
        assert_eq!(
            main_with_args(&["serve".into(), "--trace-json".into()]),
            2
        );
    }

    #[test]
    fn serve_trace_flags_print_profile_and_write_json() {
        // A traced replay exits 0, restores the disabled default, and the
        // JSON document carries the stable schema marker. The obs test
        // lock serializes against other tests toggling the global
        // registry.
        let _guard = crate::util::obs::test_lock()
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir();
        let req = dir.join(format!("gpsld_trace_req_{}.txt", std::process::id()));
        let out = dir.join(format!("gpsld_trace_out_{}.json", std::process::id()));
        std::fs::write(&req, "0 var 0.4\n0 mean 1.0\n0 var 2.1\n").unwrap();
        let code = main_with_args(&[
            "serve".into(),
            "--requests".into(),
            req.to_string_lossy().into_owned(),
            "--n".into(),
            "24".into(),
            "--trace".into(),
            "--trace-json".into(),
            out.to_string_lossy().into_owned(),
        ]);
        let doc = std::fs::read_to_string(&out).unwrap_or_default();
        std::fs::remove_file(&req).ok();
        std::fs::remove_file(&out).ok();
        assert_eq!(code, 0);
        assert!(!crate::util::obs::enabled(), "trace run must restore disabled");
        assert!(doc.contains("gpsld-trace-v1"), "schema marker missing: {doc}");
        assert!(doc.contains("dispatch"), "dispatch span missing from trace");
    }

    #[test]
    fn cg_block_flag_rejects_zero_and_garbage() {
        // Rejected before any experiment runs (and before the process-wide
        // default is touched).
        assert_eq!(main_with_args(&["exp".into(), "fig1".into(), "--cg-block".into(), "0".into()]), 2);
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--cg-block".into(), "x".into()]),
            2
        );
    }
}
