//! Hand-rolled CLI (clap is not in the offline registry).
//!
//! ```text
//! gpsld exp <id> [--scale small|paper] [--block <b>] [--cg-block <b>] [--precond-rank <k>] [--threads <t>] [--precision f64|f32f64]
//! gpsld exp all  [--scale small|paper] [--block <b>] [--cg-block <b>] [--precond-rank <k>] [--threads <t>] [--precision f64|f32f64]
//! gpsld artifacts                                      list/verify PJRT artifacts
//! gpsld info                                           version + feature summary
//! ```
//!
//! `--block <b>` sets the probe-block width used by every estimator in the
//! run (the default for `SlqOptions`/`ChebOptions` and the service layer);
//! `--cg-block <b>` sets the right-hand-side block width for the block-CG
//! solver (the default for `CgOptions`); `--precond-rank <k>` sets the
//! pivoted-Cholesky preconditioner rank for every solve and SLQ logdet
//! (0, the default, disables preconditioning — bit-identical to not
//! passing the flag); `--threads <t>` sets the process-default worker
//! count for RHS-group and probe-block fan-out
//! (`util::parallel::set_default_threads`; results are bit-identical at
//! any thread count, only wall-clock changes); `--precision f64|f32f64`
//! sets the process-default MVM precision for block solves and estimators
//! (`util::precision::set_default_precision`; `f64`, the default, is
//! bit-identical to not passing the flag, and block-CG convergence is
//! always confirmed against the f64 true residual in either mode — see
//! the `solvers` module docs); `--probes <p>` / `--steps <m>` set the
//! process-default probe count and per-probe step budget (Lanczos steps
//! and Chebyshev degree alike) for every stochastic estimator
//! (`estimators::set_default_probes`/`set_default_steps`);
//! `--logdet-tol <t>` turns every SLQ/Chebyshev logdet into an adaptive
//! run that grows the probe budget until the 95% confidence interval's
//! half-width clears `t` (`estimators::set_default_logdet_tol`; unset,
//! the default, keeps fixed budgets bit-identical to not passing the
//! flag — see the `estimators` module docs for the evidence/confidence
//! contract).

use super::{experiments, figures, ExpResult, Scale};

const EXP_IDS: &[&str] = &[
    "fig1", "table1", "table2", "table3", "table4", "table5",
    "fig3_fig4", "fig5", "fig6", "fig7", "perf",
];

pub fn usage() -> String {
    format!(
        "gpsld {} — Scalable Log Determinants for GP Kernel Learning (NIPS 2017 repro)\n\n\
         USAGE:\n  gpsld exp <id|all> [--scale small|paper] [--block <b>] [--cg-block <b>] [--precond-rank <k>] [--threads <t>] [--precision f64|f32f64] [--probes <p>] [--steps <m>] [--logdet-tol <t>] [--md <file>]\n  gpsld artifacts\n  gpsld info\n\n\
         `--block <b>` sets the default probe-block width for blocked MVMs.\n\
         `--cg-block <b>` sets the default RHS block width for block-CG solves.\n\
         `--precond-rank <k>` sets the pivoted-Cholesky preconditioner rank (0 = off).\n\
         `--threads <t>` sets the default worker count for RHS-group/probe-block fan-out.\n\
         `--precision f64|f32f64` sets the default MVM precision (f32 storage / f64 accumulation; solves still confirm in f64).\n\
         `--probes <p>` sets the default probe count for stochastic estimators.\n\
         `--steps <m>` sets the default per-probe step budget (Lanczos steps / Chebyshev degree).\n\
         `--logdet-tol <t>` makes logdet estimates adaptive: grow probes until the 95% CI half-width <= t.\n\n\
         EXPERIMENTS: {}\n",
        crate::version(),
        EXP_IDS.join(", ")
    )
}

pub fn run_experiment(id: &str, scale: Scale) -> Option<ExpResult> {
    let res = match id {
        "fig1" => experiments::fig1_sound(scale),
        "table1" => experiments::table1_precipitation(scale),
        "table2" => experiments::table2_hickory(scale),
        "table3" => experiments::table3_crime(scale),
        "table4" => experiments::table4_dkl(scale),
        "table5" => experiments::table5_recovery(scale),
        "fig3_fig4" => figures::fig3_fig4_cross_sections(scale),
        "fig5" => figures::fig5_spectrum(scale),
        "fig6" => figures::fig6_diag_correction(scale),
        "fig7" => figures::fig7_surrogate(scale),
        "perf" => figures::perf_mvm(scale),
        _ => return None,
    };
    Some(res)
}

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn main_with_args(args: &[String]) -> i32 {
    match args.first().map(String::as_str) {
        Some("exp") => {
            let Some(id) = args.get(1) else {
                eprintln!("{}", usage());
                return 2;
            };
            let mut scale = Scale::Small;
            let mut md_out: Option<String> = None;
            let mut i = 2;
            while i < args.len() {
                match args[i].as_str() {
                    "--scale" => {
                        // Reject garbage like every other flag — silently
                        // falling back to small-scale would let a typo'd
                        // "paper" run (and record) the wrong experiment.
                        match args.get(i + 1).and_then(|s| Scale::parse(s)) {
                            Some(s) => scale = s,
                            None => {
                                eprintln!("--scale needs 'small' or 'paper'");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--md" => {
                        // Like the other flags: a missing operand is an
                        // error, not a silent no-op that runs the whole
                        // experiment and writes nothing.
                        match args.get(i + 1) {
                            Some(p) => md_out = Some(p.clone()),
                            None => {
                                eprintln!("--md needs an output path");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--block" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(b) if b >= 1 => crate::estimators::set_default_block_size(b),
                            _ => {
                                eprintln!("--block needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--cg-block" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(b) if b >= 1 => crate::solvers::set_default_cg_block_size(b),
                            _ => {
                                eprintln!("--cg-block needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--threads" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(t) if t >= 1 => {
                                crate::util::parallel::set_default_threads(t)
                            }
                            _ => {
                                eprintln!("--threads needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--precision" => {
                        match args.get(i + 1).and_then(|s| {
                            crate::util::precision::Precision::parse(s)
                        }) {
                            Some(p) => crate::util::precision::set_default_precision(p),
                            None => {
                                eprintln!("--precision needs 'f64' or 'f32f64'");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--probes" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(p) if p >= 1 => crate::estimators::set_default_probes(p),
                            _ => {
                                eprintln!("--probes needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--steps" => {
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(m) if m >= 1 => crate::estimators::set_default_steps(m),
                            _ => {
                                eprintln!("--steps needs a positive integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--logdet-tol" => {
                        match args.get(i + 1).and_then(|s| s.parse::<f64>().ok()) {
                            Some(t) if t > 0.0 && t.is_finite() => {
                                crate::estimators::set_default_logdet_tol(Some(t))
                            }
                            _ => {
                                eprintln!("--logdet-tol needs a positive finite number");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    "--precond-rank" => {
                        // 0 is legal: it means "preconditioning off".
                        match args.get(i + 1).and_then(|s| s.parse::<usize>().ok()) {
                            Some(k) => crate::solvers::set_default_precond_rank(k),
                            None => {
                                eprintln!("--precond-rank needs a non-negative integer");
                                return 2;
                            }
                        }
                        i += 2;
                    }
                    other => {
                        eprintln!("unknown flag {other}");
                        return 2;
                    }
                }
            }
            let ids: Vec<&str> = if id == "all" {
                EXP_IDS.to_vec()
            } else {
                vec![id.as_str()]
            };
            let mut md = String::new();
            for id in ids {
                let t0 = std::time::Instant::now();
                match run_experiment(id, scale) {
                    Some(res) => {
                        res.print(&format!("{id} (scale={scale:?})"));
                        println!("[{}s]", super::fmt_s(t0.elapsed().as_secs_f64()));
                        md.push_str(&format!("\n### {id}\n\n{}", res.to_markdown()));
                    }
                    None => {
                        eprintln!("unknown experiment {id}\n{}", usage());
                        return 2;
                    }
                }
            }
            if let Some(path) = md_out {
                if let Err(e) = std::fs::write(&path, md) {
                    eprintln!("failed to write {path}: {e}");
                    return 1;
                }
                println!("wrote {path}");
            }
            0
        }
        Some("artifacts") => match crate::runtime::PjrtRuntime::new("artifacts") {
            Ok(rt) => {
                println!("platform: {}", rt.platform());
                for name in rt.names() {
                    let s = &rt.specs[&name];
                    println!(
                        "  {name}  graph={} kind={} in={:?} out={:?}",
                        s.graph, s.kind, s.in_shapes, s.out_shapes
                    );
                }
                0
            }
            Err(e) => {
                eprintln!("artifacts unavailable: {e}");
                1
            }
        },
        Some("info") => {
            println!("gpsld {}", crate::version());
            println!("estimators: lanczos(slq), chebyshev, surrogate, scaled_eig, exact");
            println!(
                "confidence: per-probe spectral evidence + 95% intervals on every \
                 logdet; adaptive probe budgets (--probes, --steps, --logdet-tol)"
            );
            println!(
                "solvers: cg/block-cg with pivoted-Cholesky PCG (--precond-rank), \
                 parallel RHS groups (--threads)"
            );
            println!(
                "precision: f64 (default) | f32f64 mixed MVMs with f64 \
                 iterative-refinement confirmation (--precision)"
            );
            println!("operators: dense, toeplitz, kronecker, ski(+diag), fitc/sor, sum");
            println!("likelihoods: gaussian, poisson(lgcp), negative-binomial");
            println!("runtime: PJRT CPU via xla crate; artifacts from python/compile (JAX+Pallas)");
            0
        }
        _ => {
            eprintln!("{}", usage());
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_lists_all_experiments() {
        let u = usage();
        for id in EXP_IDS {
            assert!(u.contains(id), "{id} missing from usage");
        }
    }

    #[test]
    fn unknown_command_is_error() {
        assert_eq!(main_with_args(&["bogus".into()]), 2);
        assert_eq!(main_with_args(&[]), 2);
    }

    #[test]
    fn unknown_experiment_is_none() {
        assert!(run_experiment("nope", Scale::Small).is_none());
    }

    #[test]
    fn precond_rank_flag_accepts_zero_rejects_garbage() {
        // 0 means "off" and must be accepted; non-numeric input is an
        // error before any experiment runs.
        assert_eq!(
            main_with_args(&["exp".into(), "nope".into(), "--precond-rank".into(), "0".into()]),
            2 // unknown experiment, but the flag itself parsed fine
        );
        assert_eq!(crate::solvers::default_precond_rank(), 0);
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--precond-rank".into(), "x".into()]),
            2
        );
    }

    #[test]
    fn threads_flag_sets_default_and_rejects_zero() {
        // A valid value lands in the process default (restored to auto
        // afterwards — every consumer is bit-identical across thread
        // counts, so a transient override only changes scheduling). The
        // lock serializes against the util::parallel test mutating the
        // same process-wide default.
        let _guard = crate::util::parallel::TEST_DEFAULT_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        // Pin to the current raw value: the drop guard restores whatever
        // was set before this test on every exit path (asserts included).
        crate::util::parallel::with_default_threads(
            crate::util::parallel::raw_default_threads(),
            || {
                assert_eq!(
                    main_with_args(&[
                        "exp".into(),
                        "nope".into(),
                        "--threads".into(),
                        "2".into()
                    ]),
                    2 // unknown experiment, but the flag itself parsed fine
                );
                assert_eq!(crate::util::parallel::default_threads(), 2);
                // 0 and garbage are rejected before any experiment runs.
                assert_eq!(
                    main_with_args(&[
                        "exp".into(),
                        "fig1".into(),
                        "--threads".into(),
                        "0".into()
                    ]),
                    2
                );
                assert_eq!(
                    main_with_args(&[
                        "exp".into(),
                        "fig1".into(),
                        "--threads".into(),
                        "x".into()
                    ]),
                    2
                );
            },
        );
    }

    #[test]
    fn precision_flag_sets_default_and_rejects_garbage() {
        use crate::util::precision::{
            default_precision, with_default_precision, Precision, TEST_DEFAULT_PRECISION_LOCK,
        };
        // Serialize against the util::precision tests mutating the same
        // process-wide default; the drop guard restores the prior value on
        // every exit path.
        let _guard = TEST_DEFAULT_PRECISION_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        with_default_precision(default_precision(), || {
            assert_eq!(
                main_with_args(&[
                    "exp".into(),
                    "nope".into(),
                    "--precision".into(),
                    "f32f64".into()
                ]),
                2 // unknown experiment, but the flag itself parsed fine
            );
            assert_eq!(default_precision(), Precision::F32F64);
            // Garbage and a missing operand are rejected (exit 2) before
            // any experiment runs.
            assert_eq!(
                main_with_args(&[
                    "exp".into(),
                    "fig1".into(),
                    "--precision".into(),
                    "f16".into()
                ]),
                2
            );
            assert_eq!(
                main_with_args(&["exp".into(), "fig1".into(), "--precision".into()]),
                2
            );
        });
    }

    #[test]
    fn scale_flag_rejects_garbage() {
        // A typo'd scale must error before any experiment runs, not
        // silently fall back to small.
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--scale".into(), "Paper".into()]),
            2
        );
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--scale".into()]),
            2
        );
        // --md with no operand must error too, before any experiment runs.
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--md".into()]),
            2
        );
    }

    #[test]
    fn probes_steps_flags_set_defaults_and_reject_garbage() {
        // Valid values land in the process-wide estimator defaults; 0 and
        // garbage are rejected (exit 2) before any experiment runs. The
        // defaults are restored afterwards so other tests see the
        // built-ins (estimator tests construct options explicitly, so a
        // transient override here cannot skew their budgets).
        assert_eq!(
            main_with_args(&["exp".into(), "nope".into(), "--probes".into(), "9".into()]),
            2 // unknown experiment, but the flag itself parsed fine
        );
        assert_eq!(crate::estimators::default_probes(), Some(9));
        assert_eq!(
            main_with_args(&["exp".into(), "nope".into(), "--steps".into(), "33".into()]),
            2
        );
        assert_eq!(crate::estimators::default_steps(), Some(33));
        crate::estimators::set_default_probes(0);
        crate::estimators::set_default_steps(0);
        for flag in ["--probes", "--steps"] {
            for bad in ["0", "x", "-1"] {
                assert_eq!(
                    main_with_args(&[
                        "exp".into(),
                        "fig1".into(),
                        flag.into(),
                        bad.into()
                    ]),
                    2,
                    "{flag} {bad} must be rejected"
                );
            }
            assert_eq!(main_with_args(&["exp".into(), "fig1".into(), flag.into()]), 2);
        }
        // Rejected values must not have landed in the defaults.
        assert_eq!(crate::estimators::default_probes(), None);
        assert_eq!(crate::estimators::default_steps(), None);
    }

    #[test]
    fn logdet_tol_flag_sets_default_and_rejects_garbage() {
        assert_eq!(
            main_with_args(&[
                "exp".into(),
                "nope".into(),
                "--logdet-tol".into(),
                "0.25".into()
            ]),
            2 // unknown experiment, but the flag itself parsed fine
        );
        assert_eq!(crate::estimators::default_logdet_tol(), Some(0.25));
        crate::estimators::set_default_logdet_tol(None);
        // Zero, negatives, non-finite, and garbage are rejected before
        // any experiment runs.
        for bad in ["0", "-1e-3", "nan", "inf", "x"] {
            assert_eq!(
                main_with_args(&[
                    "exp".into(),
                    "fig1".into(),
                    "--logdet-tol".into(),
                    bad.into()
                ]),
                2,
                "--logdet-tol {bad} must be rejected"
            );
        }
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--logdet-tol".into()]),
            2
        );
        assert_eq!(crate::estimators::default_logdet_tol(), None);
    }

    #[test]
    fn cg_block_flag_rejects_zero_and_garbage() {
        // Rejected before any experiment runs (and before the process-wide
        // default is touched).
        assert_eq!(main_with_args(&["exp".into(), "fig1".into(), "--cg-block".into(), "0".into()]), 2);
        assert_eq!(
            main_with_args(&["exp".into(), "fig1".into(), "--cg-block".into(), "x".into()]),
            2
        );
    }
}
