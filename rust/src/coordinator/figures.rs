//! Drivers for the supplementary figures: 1-D cross-sections (Figs. 3–4),
//! spectrum comparison (Fig. 5), diagonal correction (Fig. 6), surrogate
//! level curves (Fig. 7), and the §Perf MVM study.

use std::time::Instant;

use super::{ExpResult, Scale};
use crate::data;
use crate::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
use crate::estimators::exact;
use crate::estimators::lanczos::lanczos;
use crate::estimators::slq::{slq_logdet, SlqOptions};
use crate::estimators::surrogate::LogdetSurrogate;
use crate::gp::regression::GpRegression;
use crate::grid::{Grid, GridDim, InterpOrder};
use crate::kernels::{IsoKernel, Kernel, SeparableKernel, Shape};
use crate::operators::{DenseKernelOp, FitcOp, KernelOp, LinOp, SkiOp};
use crate::util::rng::Rng;
use crate::util::stats;

/// Figs. 3–4 — 1-D cross sections of log|K̃| and d log|K̃|/d(log ell) as one
/// hyper is perturbed around the truth (ell, sf, sigma) = (0.1, 1, 0.1),
/// for exact vs Lanczos vs Chebyshev, on the exact kernel (fig3) and on the
/// SKI kernel with/without diagonal replacement (fig4).
pub fn fig3_fig4_cross_sections(scale: Scale) -> ExpResult {
    let (n, steps, degree, sweep) = match scale {
        Scale::Small => (400, 40, 60, vec![-0.6, -0.3, 0.0, 0.3, 0.6]),
        Scale::Paper => (1000, 100, 150, vec![-0.9, -0.6, -0.3, 0.0, 0.3, 0.6, 0.9]),
    };
    let truth = [(0.1f64).ln(), (1.0f64).ln(), (0.1f64).ln()];
    let mut rows = Vec::new();

    for shape in [Shape::Rbf, Shape::Matern12] {
        // fig3: exact kernel on equispaced points (Toeplitz structure).
        let xs: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![4.0 * i as f64 / (n - 1) as f64])
            .collect();
        for &dlog in &sweep {
            let h = [truth[0] + dlog, truth[1], truth[2]];
            let op = DenseKernelOp::new(
                xs.clone(),
                Box::new(IsoKernel { shape, input_dim: 1, log_ell: h[0], log_sf: h[1] }),
                h[2].exp(),
            );
            let (ev, eg) = exact::exact_logdet_grads_dense(&op).unwrap();
            let slq = slq_logdet(
                &op,
                &SlqOptions { steps, probes: 5, seed: 61, ..Default::default() },
            )
            .unwrap();
            let cheb = chebyshev_logdet(
                &op,
                &ChebOptions { degree, probes: 5, seed: 61, ..Default::default() },
            )
            .unwrap();
            rows.push(vec![
                format!("fig3/{}", shape.name()),
                format!("{:+.1}", dlog),
                format!("{:.1}", ev),
                format!("{:.1}", slq.value),
                format!("{:.1}", cheb.value),
                format!("{:.1}", eg[0]),
                format!("{:.1}", slq.grad[0]),
                format!("{:.1}", cheb.grad[0]),
                format!("{:.2}", slq.interval.width()),
                format!("{:.2}", cheb.interval.width()),
            ]);
        }

        // fig4: SKI kernel, uniform-random points, diag replacement on/off.
        let mut rng = Rng::new(67);
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        for diag in [true, false] {
            for &dlog in &[-0.3f64, 0.0, 0.3] {
                let h = [truth[0] + dlog, truth[1], truth[2]];
                let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 4.1, m: n }]);
                let mut kern = SeparableKernel::iso(shape, 1, 1.0, 1.0);
                kern.set_hypers(&[h[0], h[1]]);
                let ski = SkiOp::new(&xs, grid, kern, h[2].exp(), InterpOrder::Cubic, diag);
                let ev = exact::exact_logdet(&ski).unwrap();
                let slq = slq_logdet(
                    &ski,
                    &SlqOptions { steps, probes: 5, grads: false, seed: 63, ..Default::default() },
                )
                .unwrap();
                rows.push(vec![
                    format!("fig4/{}/diag={}", shape.name(), diag),
                    format!("{:+.1}", dlog),
                    format!("{:.1}", ev),
                    format!("{:.1}", slq.value),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("{:.2}", slq.interval.width()),
                    "-".into(),
                ]);
            }
        }
    }
    ExpResult {
        id: "fig3_fig4",
        header: vec![
            "case", "dlog_ell", "exact", "lanczos", "chebyshev", "g_exact", "g_lanczos",
            "g_chebyshev", "ci_lanczos", "ci_chebyshev",
        ],
        rows,
    }
}

/// Fig. 5 — why Lanczos beats Chebyshev: Ritz values lock onto the true
/// spectrum while the Chebyshev approximation spends its error budget near
/// zero, where the eigenvalue mass (and the log singularity) is.
pub fn fig5_spectrum(scale: Scale) -> ExpResult {
    let n = match scale {
        Scale::Small => 300,
        Scale::Paper => 1000,
    };
    let xs: Vec<Vec<f64>> = (0..n)
        .map(|i| vec![4.0 * i as f64 / (n - 1) as f64])
        .collect();
    let op = DenseKernelOp::new(
        xs,
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.3, 1.0)),
        0.1,
    );
    // True spectrum.
    let eig = crate::linalg::eigh::eigh(&op.to_dense()).unwrap();
    // Ritz values from one probe (m = 50 like the figure).
    let mut rng = Rng::new(71);
    let mut z = vec![0.0; n];
    rng.fill_gaussian(&mut z);
    let res = lanczos(&op, &z, 50.min(n));
    let ritz =
        crate::linalg::tridiag::tridiag_eig_first_row(&res.alphas, &res.betas).unwrap();

    // Bucket both spectra logarithmically and compare mass + report the
    // Chebyshev pointwise error near the smallest eigenvalue.
    let lam_min = eig.eigvals[0].max(1e-12);
    let lam_max = eig.eigvals[n - 1];
    let nb = 10;
    let edges: Vec<f64> = (0..=nb)
        .map(|i| (lam_min.ln() + (lam_max.ln() - lam_min.ln()) * i as f64 / nb as f64).exp())
        .collect();
    let coeffs = crate::estimators::chebyshev::cheb_coeffs(
        |t| (0.5 * ((lam_max * 1.01 - lam_min * 0.99) * t + lam_max * 1.01 + lam_min * 0.99)).ln(),
        100,
    );
    let cheb_at = |lam: f64| {
        let t = (2.0 * lam - (lam_max * 1.01 + lam_min * 0.99)) / (lam_max * 1.01 - lam_min * 0.99);
        let mut b1 = 0.0;
        let mut b2 = 0.0;
        for j in (1..coeffs.len()).rev() {
            let b0 = 2.0 * t * b1 - b2 + coeffs[j];
            b2 = b1;
            b1 = b0;
        }
        t * b1 - b2 + coeffs[0]
    };
    let mut rows = Vec::new();
    for b in 0..nb {
        let (lo, hi) = (edges[b], edges[b + 1]);
        let true_count = eig.eigvals.iter().filter(|&&l| l >= lo && l < hi).count();
        let ritz_mass: f64 = ritz
            .eigvals
            .iter()
            .zip(&ritz.first_components)
            .filter(|(&l, _)| l >= lo && l < hi)
            .map(|(_, w)| w * w)
            .sum();
        let mid = (lo * hi).sqrt();
        let cheb_err = (cheb_at(mid) - mid.ln()).abs();
        rows.push(vec![
            format!("[{:.2e},{:.2e})", lo, hi),
            true_count.to_string(),
            format!("{:.3}", ritz_mass * n as f64),
            format!("{:.2e}", cheb_err),
        ]);
    }
    ExpResult {
        id: "fig5",
        header: vec!["eig_bucket", "true_count", "ritz_weighted_count", "cheb_log_err"],
        rows,
    }
}

/// Fig. 6 — the importance of diagonal correction: predictive uncertainty
/// inside an inducing-point gap, for SKI+diag / SKI no-diag / FITC /
/// scaled-eig-style (no correction possible).
pub fn fig6_diag_correction(scale: Scale) -> ExpResult {
    let (n, m_grid) = match scale {
        Scale::Small => (400, 60),
        Scale::Paper => (1000, 120),
    };
    let mut rng = Rng::new(73);
    let xs: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            // Leave a data-free + inducing-free hole in (2, 5).
            loop {
                let x = rng.uniform_in(-10.0, 10.0);
                if !(2.0..5.0).contains(&x) {
                    return vec![x];
                }
            }
        })
        .collect();
    let y: Vec<f64> = xs
        .iter()
        .map(|x| 1.0 + x[0] / 2.0 + x[0].sin() + 0.05 * rng.gaussian())
        .collect();
    // "Optimal" hypers from the generating process.
    let (ell, sf, sigma) = (1.2, 1.5, 0.06);
    // Inducing grid with the same hole (forces SKI diagonal error there).
    let grid = Grid::new(vec![GridDim { lo: -10.5, hi: 10.5, m: m_grid }]);
    let gap_test: Vec<Vec<f64>> = (0..25).map(|i| vec![2.2 + 2.6 * i as f64 / 24.0]).collect();
    let data_test: Vec<Vec<f64>> = (0..25).map(|i| vec![-9.0 + 10.0 * i as f64 / 24.0]).collect();

    let mut rows = Vec::new();
    for (name, diag) in [("ski_diag", true), ("ski_nodiag", false)] {
        let kern = SeparableKernel::iso(Shape::Matern32, 1, ell, sf);
        let ski = SkiOp::new(&xs, grid.clone(), kern, sigma, InterpOrder::Cubic, diag);
        let mut gp = GpRegression::new(ski, y.clone());
        let vg = gp.predict_var(&gap_test);
        let vd = gp.predict_var(&data_test);
        rows.push(vec![
            name.into(),
            format!("{:.4}", stats::mean(&vg).sqrt()),
            format!("{:.4}", stats::mean(&vd).sqrt()),
        ]);
    }
    // FITC reference: honest uncertainty growth away from inducing points.
    let m_fitc = m_grid.min(48);
    let inducing: Vec<Vec<f64>> = (0..m_fitc)
        .map(|i| {
            let t = -10.0 + 20.0 * i as f64 / (m_fitc - 1) as f64;
            // Same hole in the inducing set.
            vec![if (2.0..5.0).contains(&t) { 1.9 } else { t }]
        })
        .collect();
    let fitc = FitcOp::new(
        xs.clone(),
        inducing,
        Box::new(IsoKernel::new(Shape::Matern32, 1, ell, sf)),
        sigma,
        true,
    )
    .unwrap();
    let vg = fitc.predict_var(&gap_test).unwrap();
    let vd = fitc.predict_var(&data_test).unwrap();
    rows.push(vec![
        "fitc".into(),
        format!("{:.4}", stats::mean(&vg).sqrt()),
        format!("{:.4}", stats::mean(&vd).sqrt()),
    ]);
    ExpResult {
        id: "fig6",
        header: vec!["method", "sd_in_gap", "sd_near_data"],
        rows,
    }
}

/// Fig. 7 — surrogate level curves: exact vs surrogate log determinant over
/// an (ell, sigma) grid at fixed sf = 1.
pub fn fig7_surrogate(scale: Scale) -> ExpResult {
    let (n, n_design, grid_pts) = match scale {
        Scale::Small => (300, 30, 5),
        Scale::Paper => (1000, 50, 7),
    };
    let mut rows = Vec::new();
    for shape in [Shape::Rbf, Shape::Matern32] {
        let xs: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![4.0 * i as f64 / (n - 1) as f64])
            .collect();
        let mut op = DenseKernelOp::new(
            xs.clone(),
            Box::new(IsoKernel::new(shape, 1, 0.3, 1.0)),
            0.15,
        );
        // Surrogate over (log ell, log sf, log sigma); sweep slices sf = 1.
        let bounds = vec![
            ((0.05f64).ln(), (1.0f64).ln()),
            ((0.999f64).ln(), (1.001f64).ln()),
            ((0.03f64).ln(), (0.5f64).ln()),
        ];
        let sur = LogdetSurrogate::build(
            &mut op,
            &bounds,
            n_design,
            &SlqOptions { steps: 30, probes: 6, seed: 81, ..Default::default() },
            83,
        )
        .unwrap();
        let mut max_rel: f64 = 0.0;
        let mut sum_rel = 0.0;
        let mut count = 0.0;
        for i in 0..grid_pts {
            for j in 0..grid_pts {
                let lell = bounds[0].0 + (bounds[0].1 - bounds[0].0) * (i as f64 + 0.5) / grid_pts as f64;
                let lsig = bounds[2].0 + (bounds[2].1 - bounds[2].0) * (j as f64 + 0.5) / grid_pts as f64;
                let h = [lell, 0.0, lsig];
                op.set_hypers(&h);
                let ev = exact::exact_logdet(&op).unwrap();
                let sv = sur.eval(&h);
                let rel = (sv - ev).abs() / ev.abs().max(1.0);
                max_rel = max_rel.max(rel);
                sum_rel += rel;
                count += 1.0;
            }
        }
        rows.push(vec![
            shape.name().into(),
            format!("{:.4}", sum_rel / count),
            format!("{:.4}", max_rel),
        ]);
    }
    ExpResult {
        id: "fig7",
        header: vec!["kernel", "mean_rel_err", "max_rel_err"],
        rows,
    }
}

/// §Perf — MVM and estimator throughput across operator structures
/// (native dense vs PJRT artifact vs Toeplitz-SKI), plus SLQ end-to-end.
pub fn perf_mvm(scale: Scale) -> ExpResult {
    let reps = match scale {
        Scale::Small => 5,
        Scale::Paper => 20,
    };
    let mut rows = Vec::new();
    let mut rng = Rng::new(91);

    // Dense native at n=2048.
    let pts: Vec<Vec<f64>> = (0..2048).map(|_| vec![rng.gaussian(), rng.gaussian()]).collect();
    let dense = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 2, 0.5, 1.0)),
        0.3,
    );
    let x: Vec<f64> = (0..2048).map(|_| rng.gaussian()).collect();
    let mut y = vec![0.0; 2048];
    let t0 = Instant::now();
    for _ in 0..reps {
        crate::operators::LinOp::apply(&dense, &x, &mut y);
    }
    rows.push(vec![
        "dense_native_n2048".into(),
        format!("{:.3}", t0.elapsed().as_secs_f64() * 1e3 / reps as f64),
    ]);

    // Block-size sweep over the native blocked path (ms per probe-column):
    // the b=1 vs b=32 ratio is the headline block-amortization win.
    for &bsz in &[1usize, 8, 32] {
        let xb = crate::linalg::dense::Mat::from_fn(2048, bsz, |_, _| rng.gaussian());
        let t0 = Instant::now();
        for _ in 0..reps {
            crate::util::bench::black_box(dense.apply_mat(&xb).data[0]);
        }
        rows.push(vec![
            format!("dense_apply_mat_n2048_b{bsz}_per_col"),
            format!("{:.4}", t0.elapsed().as_secs_f64() * 1e3 / (reps * bsz) as f64),
        ]);
    }

    // Toeplitz block sweep (shared circulant spectrum + FFT plan).
    {
        let m = 16384;
        let tcol: Vec<f64> = (0..m).map(|k| (-0.002 * k as f64).exp()).collect();
        let top = crate::operators::ToeplitzOp::new(tcol);
        for &bsz in &[1usize, 8, 32] {
            let xb = crate::linalg::dense::Mat::from_fn(m, bsz, |_, _| rng.gaussian());
            let t0 = Instant::now();
            for _ in 0..reps {
                crate::util::bench::black_box(top.apply_mat(&xb).data[0]);
            }
            rows.push(vec![
                format!("toeplitz_apply_mat_m16384_b{bsz}_per_col"),
                format!("{:.4}", t0.elapsed().as_secs_f64() * 1e3 / (reps * bsz) as f64),
            ]);
        }
    }

    // PJRT artifact (8-wide block amortized per column).
    if let Ok(rt) = crate::runtime::PjrtRuntime::new("artifacts") {
        let rt = std::sync::Arc::new(rt);
        if let Ok(op) =
            crate::runtime::ops::PjrtMvmOp::new(rt, "mvm_rbf_n2048_d2_b8", &pts, 0.5, 1.0, 0.3)
        {
            let block = crate::linalg::dense::Mat::from_fn(2048, 8, |_, _| rng.gaussian());
            let _ = op.apply_block(&block); // compile once
            let t0 = Instant::now();
            for _ in 0..reps {
                let _ = op.apply_block(&block).unwrap();
            }
            rows.push(vec![
                "pjrt_mvm_n2048_b8_per_col".into(),
                format!("{:.3}", t0.elapsed().as_secs_f64() * 1e3 / (reps * 8) as f64),
            ]);
        }
    }

    // Toeplitz-SKI at several m (the O(n + m log m) scaling).
    let d = data::sound(8000, 3, 40, 95);
    for m in [1000usize, 4000, 16000] {
        let grid = Grid::covering(&d.x_train, &[m], 0.05);
        let ski = SkiOp::new(
            &d.x_train,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.004, 0.5),
            0.1,
            InterpOrder::Cubic,
            false,
        );
        let x: Vec<f64> = (0..d.n_train()).map(|_| rng.gaussian()).collect();
        let mut y = vec![0.0; d.n_train()];
        let t0 = Instant::now();
        for _ in 0..reps {
            crate::operators::LinOp::apply(&ski, &x, &mut y);
        }
        rows.push(vec![
            format!("ski_toeplitz_n8000_m{m}"),
            format!("{:.3}", t0.elapsed().as_secs_f64() * 1e3 / reps as f64),
        ]);
    }

    // §Precond — the shared rank × σ preconditioning sweep (see
    // [`precond_sweep`]; `bench_perf_mvm --json-precond` emits the same
    // rows machine-readably). Iteration/step counts land in the value
    // column alongside the timing rows.
    {
        let n = match scale {
            Scale::Small => 400,
            Scale::Paper => 1000,
        };
        let mut seen_rank: std::collections::HashMap<(usize, u64, usize), usize> =
            std::collections::HashMap::new();
        for r in precond_sweep(&[n], &[0.1, 0.01], &[0, 8, 32], &[1, SWEEP_THREADS]) {
            // Iteration/step metrics are printed once per (n, sigma,
            // rank), on that rank's first row whatever its (block,
            // threads) config, so the table cannot silently lose (or
            // duplicate) them if the sweep's configs or their ordering
            // change. cg_iters is re-measured per config, so the other
            // configs' values are checked against the printed one rather
            // than assumed block/thread-invariant; lanczos_steps is a
            // single scalar Lanczos run shared across configs by
            // construction, so there is nothing to cross-check.
            let rank_key = (r.n, r.sigma.to_bits(), r.rank);
            match seen_rank.get(&rank_key) {
                None => {
                    seen_rank.insert(rank_key, r.cg_iters);
                    rows.push(vec![
                        format!("precond_n{}_sig{}_r{}_cg_iters", r.n, r.sigma, r.rank),
                        format!("{}", r.cg_iters),
                    ]);
                    rows.push(vec![
                        format!("precond_n{}_sig{}_r{}_lanczos_steps", r.n, r.sigma, r.rank),
                        format!("{}", r.lanczos_steps),
                    ]);
                }
                // Plain assert: the perf experiment runs in release
                // builds, where a debug_assert would silently vanish.
                Some(&first) => assert_eq!(
                    first,
                    r.cg_iters,
                    "precond sweep cg_iters must be block/thread-invariant \
                     (n={} sigma={} rank={} block={} threads={})",
                    r.n,
                    r.sigma,
                    r.rank,
                    r.block,
                    r.threads
                ),
            }
            rows.push(vec![
                format!(
                    "precond_n{}_sig{}_r{}_b{}_t{}_solve8_ms",
                    r.n, r.sigma, r.rank, r.block, r.threads
                ),
                format!("{:.3}", r.ns_per_solve_col * 8.0 / 1e6),
            ]);
        }
    }

    // §Confidence — the shared tolerance × σ adaptive-budget sweep (see
    // [`conf_sweep`]; `bench_perf_mvm --json-conf` emits the same rows
    // machine-readably). Probe/step counts and calibration land in the
    // value column alongside the timing rows.
    {
        let n = match scale {
            Scale::Small => 300,
            Scale::Paper => 800,
        };
        for r in conf_sweep(&[n], &[0.1, 0.01], &[0.0, 60.0, 40.0]) {
            let case = format!("conf_n{}_sig{}_tol{}", r.n, r.sigma, r.tol);
            rows.push(vec![
                format!("{case}_probes_used"),
                format!("{}", r.probes_used),
            ]);
            rows.push(vec![
                format!("{case}_steps_used"),
                format!("{}", r.steps_used),
            ]);
            rows.push(vec![format!("{case}_mvms"), format!("{}", r.mvms)]);
            rows.push(vec![
                format!("{case}_ci_width"),
                format!("{:.3}", r.interval_width),
            ]);
            rows.push(vec![
                format!("{case}_calibrated"),
                format!("{}", r.calibrated),
            ]);
            rows.push(vec![
                format!("{case}_estimate_ms"),
                format!("{:.3}", r.ns_per_estimate / 1e6),
            ]);
        }
    }

    // §Service — the shared streaming-serving request-replay sweep (see
    // [`service_sweep`]; `bench_perf_mvm --json-service` emits the same
    // rows machine-readably). The sweep itself asserts the coalescing
    // contract (bitwise-equal answers, strictly fewer solves/applies than
    // solo) in release builds; the table reports the amortization.
    {
        let n = match scale {
            Scale::Small => 256,
            Scale::Paper => 1024,
        };
        for r in service_sweep(&[n], &[8, 32], &[1, SWEEP_THREADS]) {
            // f64 rows keep their historical case names; the mixed-precision
            // rows are new identities and carry the precision suffix.
            let mut case =
                format!("service_n{}_req{}_t{}", r.n, r.requests, r.threads);
            if r.precision != "f64" {
                case = format!("{case}_{}", r.precision);
            }
            rows.push(vec![
                format!("{case}_solves_vs_solo"),
                format!("{}/{}", r.solves, r.solo_solves),
            ]);
            rows.push(vec![
                format!("{case}_applies_vs_solo"),
                format!("{}/{}", r.block_applies, r.solo_block_applies),
            ]);
            rows.push(vec![
                format!("{case}_converged"),
                format!("{}", r.converged),
            ]);
            rows.push(vec![
                format!("{case}_p50_ms"),
                format!("{:.3}", r.p50_ns / 1e6),
            ]);
            rows.push(vec![
                format!("{case}_p99_ms"),
                format!("{:.3}", r.p99_ns / 1e6),
            ]);
        }
    }

    // End-to-end SLQ (25 steps, 5 probes, with grads) on SKI m=4000, plus
    // the SKI block sweep.
    {
        let grid = Grid::covering(&d.x_train, &[4000], 0.05);
        let ski = SkiOp::new(
            &d.x_train,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.004, 0.5),
            0.1,
            InterpOrder::Cubic,
            false,
        );
        for &bsz in &[1usize, 8, 32] {
            let xb =
                crate::linalg::dense::Mat::from_fn(d.n_train(), bsz, |_, _| rng.gaussian());
            let t0 = Instant::now();
            for _ in 0..reps {
                crate::util::bench::black_box(ski.apply_mat(&xb).data[0]);
            }
            rows.push(vec![
                format!("ski_apply_mat_n8000_m4000_b{bsz}_per_col"),
                format!("{:.4}", t0.elapsed().as_secs_f64() * 1e3 / (reps * bsz) as f64),
            ]);
        }
        let t0 = Instant::now();
        let _ = slq_logdet(
            &ski,
            &SlqOptions { steps: 25, probes: 5, seed: 97, ..Default::default() },
        )
        .unwrap();
        rows.push(vec![
            "slq_e2e_ski_n8000_m4000".into(),
            format!("{:.3}", t0.elapsed().as_secs_f64() * 1e3),
        ]);
    }

    ExpResult { id: "perf", header: vec!["case", "value"], rows }
}

/// Multi-thread arm of the shared 1-vs-N thread sweeps (the CLI perf
/// table and `bench_perf_mvm --json-cg` / `--json-precond` all use this
/// one constant, so the two surfaces cannot drift). Fixed rather than
/// auto-detected so bench row identities stay comparable across machines.
pub const SWEEP_THREADS: usize = 4;

/// One case of the rank × σ (× threads) pivoted-Cholesky preconditioning
/// sweep.
pub struct PrecondSweepRow {
    pub op: &'static str,
    pub n: usize,
    pub sigma: f64,
    pub rank: usize,
    /// RHS-group width of the timed solve: 8 (all right-hand sides in one
    /// amortized group — the configuration production `pcg_block` callers
    /// run) or 2 (the 4-group split that exercises the thread fan-out).
    pub block: usize,
    /// Total worker budget of the timed solve (the process default is
    /// pinned to this for the measurement): RHS-group workers for the
    /// multi-group `block = 2` rows, operator-internal threading for the
    /// single-group `block = 8` rows. Iteration counts are thread- and
    /// block-invariant, only wall time moves.
    pub threads: usize,
    /// Worst-column PCG iteration count of an 8-RHS block solve (tol 1e-8).
    pub cg_iters: usize,
    /// Columns of the solve that converged (of 8). Emitted so the bench
    /// gate's higher-is-better rule catches a solve that stops converging
    /// — iteration counts saturate at their caps, so they (and the
    /// resulting faster wall time) would otherwise read as "fine".
    pub converged: usize,
    /// Lanczos quadrature steps per probe to 1e-4
    /// ([`crate::estimators::lanczos::logdet_steps_to_tol`]).
    pub lanczos_steps: usize,
    /// Wall time per solved column (one warmup + one timed block solve).
    pub ns_per_solve_col: f64,
}

/// One case of the tolerance × σ confidence/adaptive-budget sweep.
pub struct ConfSweepRow {
    pub op: &'static str,
    pub n: usize,
    pub sigma: f64,
    /// Requested adaptive half-width target (`--logdet-tol` semantics);
    /// 0 means the fixed-budget reference run (`target_tol` unset).
    pub tol: f64,
    /// Probes the estimate actually consumed (== the fixed budget for
    /// `tol = 0`; the adaptive stopping point otherwise).
    pub probes_used: usize,
    /// Longest per-probe Lanczos tridiagonal of the run. Fixed for
    /// `tol = 0`; grown past the seed budget by the two-axis driver when
    /// the truncation term dominates (the small-σ rows).
    pub steps_used: usize,
    /// Total operator MVMs of the estimate — the cost the two-axis
    /// driver's axis choice is about. Gated lower-is-better.
    pub mvms: usize,
    /// Full width of the 95% posterior interval.
    pub interval_width: f64,
    /// 1 when the interval contains the exact log determinant, else 0.
    /// Emitted per row so the bench gate's higher-is-better rule catches a
    /// calibration regression loudly (a sum over rows would average a
    /// miss away).
    pub calibrated: usize,
    /// Wall time of one full logdet estimate (warmup + averaged reps).
    pub ns_per_estimate: f64,
}

/// The tolerance × σ adaptive-budget sweep on an ill-conditioned dense
/// RBF kernel — the one definition shared by the CLI perf table and
/// `bench_perf_mvm --json-conf` (`BENCH_conf.json`), so the two surfaces
/// report identically-defined numbers. `tol = 0` is the fixed-budget
/// baseline; adaptive rows must stay calibrated against
/// `exact::exact_logdet`.
///
/// The seed step budget is deliberately short (10): at σ = 0.1 the
/// truncation term is already negligible there and the driver only adds
/// probes, while at σ = 0.01 truncation dominates and the two-axis
/// driver must deepen its sessions to reach the same tolerance. Each
/// adaptive case also runs a probes-only reference (`max_steps == steps`
/// pins the step axis) and asserts the two-axis contract in release
/// builds: when the driver deepened, it reached the target with strictly
/// fewer MVMs than the probes-only driver spends — unless the target is
/// beyond the probes-only driver's reach entirely, in which case
/// exhausting it is already the loss being demonstrated; when it did not
/// deepen, the two drivers are one and the same run, bit for bit.
pub fn conf_sweep(ns: &[usize], sigmas: &[f64], tols: &[f64]) -> Vec<ConfSweepRow> {
    use crate::util::bench::black_box;
    let mut rows = Vec::new();
    let mut rng = Rng::new(41);
    for &n in ns {
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        for &sigma in sigmas {
            let op = DenseKernelOp::new(
                pts.clone(),
                Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
                sigma,
            );
            let truth = exact::exact_logdet(&op)
                .expect("conf sweep: exact logdet failed");
            for &tol in tols {
                let opts = SlqOptions {
                    steps: 10,
                    probes: 16,
                    grads: false,
                    seed: 43,
                    target_tol: if tol > 0.0 { Some(tol) } else { None },
                    ..Default::default()
                };
                // Warmup run doubles as the (deterministic) accounting
                // run; the timing then averages a few reps so
                // single-sample wall-clock noise doesn't flake the bench
                // gate.
                let est = slq_logdet(&op, &opts)
                    .expect("conf sweep: slq failed");
                if tol > 0.0 {
                    let flat = slq_logdet(
                        &op,
                        &SlqOptions { max_steps: opts.steps, ..opts },
                    )
                    .expect("conf sweep: slq failed");
                    if est.steps_used > opts.steps {
                        assert!(
                            est.mvms < flat.mvms
                                || flat.interval.half_width() > tol,
                            "conf sweep n={n} sigma={sigma} tol={tol}: \
                             two-axis driver deepened to {} steps yet spent \
                             {} MVMs where probes-only reached the target \
                             in {}",
                            est.steps_used,
                            est.mvms,
                            flat.mvms,
                        );
                    } else {
                        // Step axis never engaged: pinning it must be a
                        // no-op, not merely close.
                        assert_eq!(
                            (est.mvms, est.value.to_bits()),
                            (flat.mvms, flat.value.to_bits()),
                            "conf sweep n={n} sigma={sigma} tol={tol}: \
                             pinned step axis diverged from the two-axis \
                             run that never grew steps",
                        );
                    }
                }
                let t0 = Instant::now();
                let mut reps = 0usize;
                loop {
                    let e = slq_logdet(&op, &opts).expect("conf sweep: slq failed");
                    black_box(e.value);
                    reps += 1;
                    if reps >= 5 || t0.elapsed().as_secs_f64() > 0.4 {
                        break;
                    }
                }
                rows.push(ConfSweepRow {
                    op: "dense_rbf",
                    n,
                    sigma,
                    tol,
                    probes_used: est.probes_used,
                    steps_used: est.steps_used,
                    mvms: est.mvms,
                    interval_width: est.interval.width(),
                    calibrated: est.interval.contains(truth) as usize,
                    ns_per_estimate: t0.elapsed().as_secs_f64() / reps as f64 * 1e9,
                });
            }
        }
    }
    rows
}

/// One case of the streaming-service request-replay sweep.
pub struct ServiceSweepRow {
    pub model: &'static str,
    pub n: usize,
    /// Single-column predictive-variance requests replayed through the
    /// coalescing dispatcher (all pending in one drain).
    pub requests: usize,
    /// Total worker budget of the timed dispatch (process default pinned).
    pub threads: usize,
    /// Precision identity of the model's solves. The sweep pins each row
    /// explicitly (`f64` and `f32f64` rows per case) so rows stay
    /// comparable when the process default changes.
    pub precision: &'static str,
    /// Columns fused into dispatched solves (== `requests` here: one
    /// drain, one model).
    pub coalesced_cols: usize,
    /// Block solves the coalescing dispatcher executed (1 per drain).
    /// Gated lower-is-better: coalescing regressing into per-request
    /// solves must fail loudly.
    pub solves: usize,
    /// Blocked operator applies of the dispatched solves — the amortized
    /// cost the coalescing headline is about.
    pub block_applies: usize,
    /// Baseline: solves when each request is dispatched alone (== requests).
    pub solo_solves: usize,
    /// Baseline: blocked applies summed over the solo dispatches.
    pub solo_block_applies: usize,
    /// Responses whose solve column converged (of `requests`). Emitted so
    /// the bench gate's higher-is-better rule catches a service that
    /// stops converging (fewer applies would otherwise read as a win).
    pub converged: usize,
    /// Per-request latency quantiles over the timed replay reps
    /// (submit → response, fixed-bucket log-spaced histogram).
    pub p50_ns: f64,
    pub p99_ns: f64,
}

/// The request-replay sweep of the streaming serving layer — the one
/// definition shared by the CLI perf table and `bench_perf_mvm
/// --json-service` (`BENCH_service.json`), so the two surfaces report
/// identically-defined numbers. Each case replays `requests`
/// single-column predictive-variance requests through the coalescing
/// dispatcher (one fused cold block solve) and through the solo
/// per-request baseline, asserting along the way that the fused answers
/// are bitwise equal to the solo ones at equal convergence and that
/// coalescing did strictly fewer solves and blocked applies — the
/// acceptance invariant runs in release builds, not just under test.
/// Every case runs at both solve precisions (`f64` and `f32f64`, the
/// serve driver's `--precision` axis): the contract is
/// precision-independent because fused and solo columns share one
/// refinement path, and the rows let the bench surface the mixed
/// pipeline's latency side by side with the reference.
pub fn service_sweep(
    ns: &[usize],
    request_counts: &[usize],
    threads: &[usize],
) -> Vec<ServiceSweepRow> {
    use super::service::{dispatch, Metrics, ModelRegistry, RequestKind, RequestQueue};
    use crate::solvers::{CgOptions, PrecondOptions};
    use crate::util::bench::black_box;
    let mut rows = Vec::new();
    let mut rng = Rng::new(53);
    for &n in ns {
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let y: Vec<f64> = pts
            .iter()
            .map(|p| (1.4 * p[0]).sin() + 0.1 * rng.gaussian())
            .collect();
        let make_model = |t: usize, prec: crate::util::precision::Precision| {
            let op = DenseKernelOp::new(
                pts.clone(),
                Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
                0.1,
            );
            let mut gp = GpRegression::new(op, y.clone());
            gp.cg = CgOptions {
                tol: 1e-8,
                max_iters: 5000,
                block_size: 16,
                threads: t,
                precond: PrecondOptions::rank(16),
                precision: prec,
            };
            gp
        };
        for &requests in request_counts {
            let test_pts: Vec<Vec<f64>> = {
                let mut prng = Rng::new(59);
                (0..requests).map(|_| vec![prng.uniform_in(0.0, 3.0)]).collect()
            };
            for &t in threads {
                for prec in [
                    crate::util::precision::Precision::F64,
                    crate::util::precision::Precision::F32F64,
                ] {
                    crate::util::parallel::with_default_threads(t, || {
                        // Registry with cached factors: alpha + pivoted
                        // Cholesky are solved/built once here and reused by
                        // every replay below.
                        let mut reg = ModelRegistry::new();
                        let id = reg.insert(make_model(t, prec));
                        reg.warm(id);
                        // Accounting replay (deterministic): one coalesced
                        // drain of all requests.
                        let acct = Metrics::default();
                        let queue = RequestQueue::bounded(requests.max(1) * 2);
                        for x in &test_pts {
                            queue
                                .submit(id, RequestKind::Var, x.clone())
                                .expect("service sweep: queue sized for the replay");
                        }
                        let fused = dispatch(&mut reg, &queue, &acct);
                        let (solves, block_applies, coalesced_cols, _) =
                            acct.serving_snapshot();
                        // Solo baseline on an identical fresh model: one
                        // dispatch per request.
                        let mut solo_reg = ModelRegistry::new();
                        let solo_id = solo_reg.insert(make_model(t, prec));
                        solo_reg.warm(solo_id);
                        let solo_acct = Metrics::default();
                        let mut solo = Vec::new();
                        for x in &test_pts {
                            let q = RequestQueue::bounded(2);
                            q.submit(solo_id, RequestKind::Var, x.clone())
                                .expect("service sweep: solo submit");
                            solo.extend(dispatch(&mut solo_reg, &q, &solo_acct));
                        }
                        let (solo_solves, solo_block_applies, _, _) =
                            solo_acct.serving_snapshot();
                        // The coalescing contract, asserted in release builds:
                        // bitwise-equal answers at equal convergence, strictly
                        // fewer solves AND blocked applies.
                        let pname = prec.name();
                        for (i, (f, s)) in fused.iter().zip(&solo).enumerate() {
                            assert_eq!(
                                f.value.to_bits(),
                                s.value.to_bits(),
                                "service sweep n={n} requests={requests} t={t} \
                                 prec={pname} req {i}: fused {} != solo {}",
                                f.value,
                                s.value
                            );
                            assert_eq!(
                                f.converged, s.converged,
                                "service sweep n={n} requests={requests} t={t} \
                                 prec={pname} req {i}"
                            );
                        }
                        if requests > 1 {
                            assert!(
                                solves < solo_solves && block_applies < solo_block_applies,
                                "service sweep n={n} requests={requests} t={t} \
                                 prec={pname}: coalescing must amortize \
                                 ({solves} vs {solo_solves} solves, \
                                 {block_applies} vs {solo_block_applies} applies)"
                            );
                        }
                        // Timed replay: repeat the coalesced drain; latencies
                        // from every rep accumulate in one histogram so the
                        // p50/p99 readout has rep × requests samples.
                        let timed = Metrics::default();
                        let t0 = Instant::now();
                        let mut reps = 0usize;
                        loop {
                            let q = RequestQueue::bounded(requests.max(1) * 2);
                            for x in &test_pts {
                                q.submit(id, RequestKind::Var, x.clone())
                                    .expect("service sweep: timed submit");
                            }
                            let resp = dispatch(&mut reg, &q, &timed);
                            black_box(resp.last().map_or(0.0, |r| r.value));
                            reps += 1;
                            if reps >= 5 || t0.elapsed().as_secs_f64() > 0.4 {
                                break;
                            }
                        }
                        rows.push(ServiceSweepRow {
                            model: "dense_rbf",
                            n,
                            requests,
                            threads: t,
                            precision: pname,
                            coalesced_cols,
                            solves,
                            block_applies,
                            solo_solves,
                            solo_block_applies,
                            converged: fused.iter().filter(|r| r.converged).count(),
                            p50_ns: timed.latency_quantile_ns(0.5),
                            p99_ns: timed.latency_quantile_ns(0.99),
                        });
                    });
                }
            }
        }
    }
    rows
}

/// The rank × σ × (block, threads) preconditioning sweep on an
/// ill-conditioned dense RBF kernel — the one definition shared by the
/// CLI perf table and `bench_perf_mvm --json-precond`
/// (`BENCH_precond.json`), so the two surfaces report
/// identically-defined numbers. rank 0 is the unpreconditioned baseline,
/// the single-group `block = 8` rows the amortized production
/// configuration, and `threads = 1` the serial baseline of each block's
/// thread pair: the iteration-count and wall-clock reductions are
/// measured, not asserted.
pub fn precond_sweep(
    ns: &[usize],
    sigmas: &[f64],
    ranks: &[usize],
    threads: &[usize],
) -> Vec<PrecondSweepRow> {
    use crate::estimators::lanczos::logdet_steps_to_tol;
    use crate::linalg::dense::Mat;
    use crate::solvers::{
        build_preconditioner, pcg_block, CgOptions, PrecondOptions, Preconditioner,
    };
    use crate::util::bench::black_box;
    let mut rows = Vec::new();
    let mut rng = Rng::new(29);
    for &n in ns {
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        for &sigma in sigmas {
            let op = DenseKernelOp::new(
                pts.clone(),
                Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
                sigma,
            );
            let b = Mat::from_fn(n, 8, |_, _| rng.gaussian());
            let mut z = vec![0.0; n];
            rng.fill_gaussian(&mut z);
            for &rank in ranks {
                let pc = build_preconditioner(&op, PrecondOptions::rank(rank));
                let pcd = pc.as_ref().map(|p| p as &dyn Preconditioner);
                // The Lanczos-step metric is a scalar run — thread-count
                // independent, computed once per (σ, rank).
                let lanczos_steps = logdet_steps_to_tol(&op, pcd, &z, n.min(200), 1e-4)
                    .expect("precond sweep: lanczos quadrature failed");
                // Timed configurations: the single-group amortized solve
                // (block 8 — what production pcg_block callers run; its
                // thread budget flows to operator-internal threading)
                // and the 4-group split (block 2 — the RHS-group
                // fan-out), each swept over the worker counts.
                let mut configs: Vec<(usize, usize)> = Vec::new();
                for &blk in &[8usize, 2] {
                    configs.extend(threads.iter().map(|&t| (blk, t)));
                }
                for (blk, t) in configs {
                    // The process default is pinned to `t` for the
                    // measured solves so the row's `threads` means the
                    // TOTAL worker budget — operator-internal threading
                    // included — making the 1-vs-N comparison fair on any
                    // core count.
                    let (secs, info) = crate::util::parallel::with_default_threads(t, || {
                        let opts = CgOptions {
                            tol: 1e-8,
                            max_iters: 5000,
                            block_size: blk,
                            threads: t,
                            ..Default::default()
                        };
                        // Warmup solve doubles as the (deterministic)
                        // accounting run; the timing then averages a few
                        // reps so single-sample wall-clock noise doesn't
                        // flake the 20% regression gate.
                        let (_, info) = pcg_block(&op, &b, None, pcd, &opts);
                        let t0 = Instant::now();
                        let mut reps = 0usize;
                        loop {
                            let (x, _) = pcg_block(&op, &b, None, pcd, &opts);
                            black_box(x.data[0]);
                            reps += 1;
                            // A sample past the noise threshold is already
                            // well inside the 20% gate — don't repeat
                            // multi-second solves for no noise benefit.
                            if reps >= 5 || t0.elapsed().as_secs_f64() > 0.4 {
                                break;
                            }
                        }
                        (t0.elapsed().as_secs_f64() / reps as f64, info)
                    });
                    rows.push(PrecondSweepRow {
                        op: "dense_rbf",
                        n,
                        sigma,
                        rank,
                        block: blk,
                        threads: t,
                        cg_iters: info.max_iters(),
                        converged: info.cols.iter().filter(|c| c.converged).count(),
                        lanczos_steps,
                        ns_per_solve_col: secs * 1e9 / 8.0,
                    });
                }
            }
        }
    }
    rows
}
