//! Drivers for the paper's main-text experiments (Fig. 1, Tables 1–4) and
//! the hyper-recovery study (supp. Table 5). Figures from the supplement
//! live in [`super::figures`].

use std::time::Instant;

use super::{fmt_s, ExpResult, Scale};
use crate::data;
use crate::estimators::chebyshev::ChebOptions;
use crate::estimators::slq::SlqOptions;
use crate::estimators::surrogate::LogdetSurrogate;
use crate::gp::laplace::{LaplaceGp, LaplaceOptions};
use crate::gp::likelihoods::Likelihood;
use crate::gp::regression::{Estimator, GpRegression};
use crate::grid::{Grid, GridDim, InterpOrder};
use crate::kernels::{Factor1d, IsoKernel, SeparableKernel, Shape, SpectralMixtureKernel};
use crate::kernels::Kernel;
use crate::operators::ski::KronKernelOp;
use crate::operators::{FitcOp, KernelOp, LinOp, SkiOp};
use crate::opt::lbfgs::LbfgsOptions;
use crate::opt::neldermead::{nelder_mead, NelderMeadOptions};
use crate::util::stats;

fn ski_1d(d: &data::Dataset, m: usize, ell: f64, sf: f64, sigma: f64, diag: bool) -> SkiOp {
    let grid = Grid::covering(&d.x_train, &[m], 0.05);
    SkiOp::new(
        &d.x_train,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, ell, sf),
        sigma,
        InterpOrder::Cubic,
        diag,
    )
}

/// Fig. 1 — natural sound modeling: hyper-training time vs number of
/// inducing points m, inference time, and SMAE, for surrogate / Lanczos /
/// Chebyshev / scaled eigenvalues / FITC.
pub fn fig1_sound(scale: Scale) -> ExpResult {
    let (n, gaps, gap_len, ms, fitc_m, opt_iters) = match scale {
        Scale::Small => (4000, 4, 60, vec![250, 500, 1000], 64, 6),
        Scale::Paper => (59_306, 6, 115, vec![1000, 3000, 8000, 20000], 256, 12),
    };
    let d = data::sound(n, gaps, gap_len, 42);
    let (ell0, sf0, sg0) = (0.004, 0.5, 0.1);
    let lopts = LbfgsOptions { max_iters: opt_iters, g_tol: 1e-3, ..Default::default() };
    let mut rows = Vec::new();

    // Cap for the scaled-eigenvalue baseline: its dense factor
    // eigendecomposition is O(m^3) — exactly the cost the paper plots.
    let scaled_cap = match scale {
        Scale::Small => 500,
        Scale::Paper => 2000,
    };

    for &m in &ms {
        // --- Lanczos (SLQ) ---
        let slq = SlqOptions { steps: 25, probes: 5, seed: 1, ..Default::default() };
        let mut gp = GpRegression::new(ski_1d(&d, m, ell0, sf0, sg0, false), d.y_train.clone());
        let stats_l = gp.train(&Estimator::Slq(slq), &lopts).unwrap();
        let t0 = Instant::now();
        let pred = gp.predict_mean(&d.x_test);
        let infer_s = t0.elapsed().as_secs_f64();
        rows.push(vec![
            "lanczos".into(),
            m.to_string(),
            fmt_s(stats_l.seconds),
            fmt_s(infer_s),
            format!("{:.3}", stats::smae(&pred, &d.y_test)),
        ]);

        // --- Surrogate (build + optimize on the surrogate) ---
        let t0 = Instant::now();
        let mut op = ski_1d(&d, m, ell0, sf0, sg0, false);
        let h0 = op.hypers();
        let bounds: Vec<(f64, f64)> = h0.iter().map(|&h| (h - 1.2, h + 1.2)).collect();
        let sur = LogdetSurrogate::build(
            &mut op,
            &bounds,
            20,
            &SlqOptions { steps: 25, probes: 5, seed: 2, ..Default::default() },
            3,
        )
        .unwrap();
        let mut gp = GpRegression::new(op, d.y_train.clone());
        let stats_s = gp.train(&Estimator::Surrogate(sur), &lopts).unwrap();
        let train_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let pred = gp.predict_mean(&d.x_test);
        let infer_s = t0.elapsed().as_secs_f64();
        let _ = stats_s;
        rows.push(vec![
            "surrogate".into(),
            m.to_string(),
            fmt_s(train_s),
            fmt_s(infer_s),
            format!("{:.3}", stats::smae(&pred, &d.y_test)),
        ]);

        // --- Chebyshev ---
        let deg = if scale == Scale::Small { 50 } else { 100 };
        let cheb = ChebOptions { degree: deg, probes: 5, seed: 1, ..Default::default() };
        let mut gp = GpRegression::new(ski_1d(&d, m, ell0, sf0, sg0, false), d.y_train.clone());
        let stats_c = gp.train(&Estimator::Chebyshev(cheb), &lopts).unwrap();
        let t0 = Instant::now();
        let pred = gp.predict_mean(&d.x_test);
        let infer_s = t0.elapsed().as_secs_f64();
        rows.push(vec![
            "chebyshev".into(),
            m.to_string(),
            fmt_s(stats_c.seconds),
            fmt_s(infer_s),
            format!("{:.3}", stats::smae(&pred, &d.y_test)),
        ]);

        // --- Scaled eigenvalues (skipped beyond the cap, like the paper's
        // "computationally prohibitive" note) ---
        if m <= scaled_cap {
            let mut gp =
                GpRegression::new(ski_1d(&d, m, ell0, sf0, sg0, false), d.y_train.clone());
            let se_opts = LbfgsOptions { max_iters: opt_iters.min(4), ..lopts };
            let stats_e = gp.train(&Estimator::ScaledEig, &se_opts).unwrap();
            let t0 = Instant::now();
            let pred = gp.predict_mean(&d.x_test);
            let infer_s = t0.elapsed().as_secs_f64();
            rows.push(vec![
                "scaled_eig".into(),
                m.to_string(),
                fmt_s(stats_e.seconds),
                fmt_s(infer_s),
                format!("{:.3}", stats::smae(&pred, &d.y_test)),
            ]);
        } else {
            rows.push(vec![
                "scaled_eig".into(),
                m.to_string(),
                "—".into(),
                "—".into(),
                "—".into(),
            ]);
        }
    }

    // --- FITC (single small m; the paper reports it took hours) ---
    let mut rng = crate::util::rng::Rng::new(5);
    let lo = d.x_train.first().unwrap()[0];
    let hi = d.x_train.last().unwrap()[0];
    let inducing: Vec<Vec<f64>> = (0..fitc_m)
        .map(|i| vec![lo + (hi - lo) * i as f64 / (fitc_m - 1) as f64])
        .collect();
    let _ = &mut rng;
    let fitc = FitcOp::new(
        d.x_train.clone(),
        inducing,
        Box::new(IsoKernel::new(Shape::Rbf, 1, ell0, sf0)),
        sg0,
        true,
    )
    .unwrap();
    let mut gp = GpRegression::new(fitc, d.y_train.clone());
    let t0 = Instant::now();
    // FITC trains with exact logdet (determinant lemma) + FD grads; keep
    // iterations small — it is the slow baseline.
    let stats_f = gp
        .train(
            &Estimator::Exact,
            &LbfgsOptions { max_iters: opt_iters.min(4), g_tol: 1e-3, ..Default::default() },
        )
        .map(|s| s.seconds)
        .unwrap_or(f64::NAN);
    let train_s = t0.elapsed().as_secs_f64().max(stats_f);
    let t0 = Instant::now();
    let pred = gp.predict_mean(&d.x_test);
    let infer_s = t0.elapsed().as_secs_f64();
    rows.push(vec![
        "fitc".into(),
        fitc_m.to_string(),
        fmt_s(train_s),
        fmt_s(infer_s),
        format!("{:.3}", stats::smae(&pred, &d.y_test)),
    ]);

    ExpResult {
        id: "fig1",
        header: vec!["method", "m", "train_s", "infer_s", "smae"],
        rows,
    }
}

/// Table 1 — daily precipitation: MSE and time for Lanczos vs scaled
/// eigenvalues (3-D Kronecker SKI) vs exact on a subset.
pub fn table1_precipitation(scale: Scale) -> ExpResult {
    let (n, gdims, n_exact, opt_iters) = match scale {
        Scale::Small => (4000, [12usize, 12, 16], 800, 5),
        Scale::Paper => (60_000, [40, 40, 60], 4000, 10),
    };
    let d = data::precipitation(n, 0.16, 7);
    let (ell0, sf0, sg0) = (0.15, 1.0, 0.4);
    let lopts = LbfgsOptions { max_iters: opt_iters, g_tol: 1e-3, ..Default::default() };

    let make_ski = || {
        let grid = Grid::covering(&d.x_train, &gdims, 0.05);
        SkiOp::new(
            &d.x_train,
            grid,
            SeparableKernel::iso(Shape::Rbf, 3, ell0, sf0),
            sg0,
            InterpOrder::Cubic,
            false,
        )
    };
    let m: usize = gdims.iter().product();
    let mut rows = Vec::new();

    for (name, est) in [
        ("lanczos", Estimator::Slq(SlqOptions { steps: 25, probes: 5, seed: 3, ..Default::default() })),
        ("scaled_eig", Estimator::ScaledEig),
    ] {
        let t0 = Instant::now();
        let mut gp = GpRegression::new(make_ski(), d.y_train.clone());
        gp.train(&est, &lopts).unwrap();
        let pred = gp.predict_mean(&d.x_test);
        let secs = t0.elapsed().as_secs_f64();
        rows.push(vec![
            name.into(),
            d.n_train().to_string(),
            m.to_string(),
            format!("{:.3}", stats::mse(&pred, &d.y_test)),
            fmt_s(secs),
        ]);
    }

    // Exact on a subset (paper: 12k of 528k).
    let t0 = Instant::now();
    let sub: Vec<usize> = (0..n_exact.min(d.n_train())).collect();
    let xs: Vec<Vec<f64>> = sub.iter().map(|&i| d.x_train[i].clone()).collect();
    let ys: Vec<f64> = sub.iter().map(|&i| d.y_train[i]).collect();
    let op = crate::operators::DenseKernelOp::new(
        xs,
        Box::new(IsoKernel::new(Shape::Rbf, 3, ell0, sf0)),
        sg0,
    );
    let mut gp = GpRegression::new(op, ys);
    gp.train(&Estimator::Exact, &LbfgsOptions { max_iters: opt_iters.min(4), g_tol: 1e-3, ..Default::default() })
        .unwrap();
    let pred = gp.predict_mean(&d.x_test);
    let secs = t0.elapsed().as_secs_f64();
    rows.push(vec![
        "exact".into(),
        n_exact.to_string(),
        "-".into(),
        format!("{:.3}", stats::mse(&pred, &d.y_test)),
        fmt_s(secs),
    ]);

    ExpResult {
        id: "table1",
        header: vec!["method", "n", "m", "mse", "time_s"],
        rows,
    }
}

/// Laplace-objective optimization over (log ell1, log ell2, log sf) with a
/// pluggable logdet mode; returns (hypers, -log p, seconds).
fn fit_lgcp_rbf(
    cg: &data::CountGrid,
    mode: &str,
    nm_iters: usize,
    seed: u64,
) -> (Vec<f64>, f64, f64) {
    let t0 = Instant::now();
    let offset = cg.offset;
    let obj = |h: &[f64]| -> f64 {
        let kern = SeparableKernel::new(
            vec![
                Box::new(Factor1d { shape: Shape::Rbf, log_ell: h[0] }) as Box<dyn Kernel>,
                Box::new(Factor1d { shape: Shape::Rbf, log_ell: h[1] }),
            ],
            1.0,
        );
        let mut kern = kern;
        kern.log_sf = h[2];
        let op = KronKernelOp::new(cg.grid.clone(), kern, 1e-2);
        let mut gp = LaplaceGp::new(op, cg.counts.clone(), Likelihood::Poisson { offset });
        let opts = LaplaceOptions { slq_probes: 4, slq_steps: 20, seed, ..Default::default() };
        match mode {
            "lanczos" => gp.fit(&opts).map(|f| -f.log_marginal).unwrap_or(f64::INFINITY),
            "exact" => {
                // Dense log|B| (O(n^3)) — the ground-truth baseline.
                match gp.fit(&opts) {
                    Ok(fit) => {
                        let n = gp.n();
                        let w: Vec<f64> = (0..n)
                            .map(|i| gp.lik.neg_d2logp(gp.y[i], fit.f_hat[i]))
                            .collect();
                        let bop = crate::operators::LaplaceBOp::new(&gp.op, &w);
                        let ld = crate::estimators::exact::exact_logdet(&bop)
                            .unwrap_or(f64::INFINITY);
                        -(gp.lik.logp_sum(&gp.y, &fit.f_hat)
                            - 0.5 * stats::dot(&fit.a, &fit.f_hat)
                            - 0.5 * ld)
                    }
                    Err(_) => f64::INFINITY,
                }
            }
            "fiedler" => {
                let opts2 = opts;
                gp.log_marginal_fiedler(&opts2, |op| op.kuu().all_eigvals())
                    .map(|(lm, _)| -lm)
                    .unwrap_or(f64::INFINITY)
            }
            _ => unreachable!(),
        }
    };
    let start = vec![(0.15f64).ln(), (0.15f64).ln(), (0.7f64).ln()];
    let res = nelder_mead(
        obj,
        &start,
        &NelderMeadOptions { max_iters: nm_iters, init_step: 0.4, f_tol: 1e-5 },
    );
    (res.x, res.fx, t0.elapsed().as_secs_f64())
}

/// Table 2 — Hickory LGCP: recovered hypers (s_f, l1, l2), −log p, time for
/// exact / Lanczos / scaled-eig(Fiedler).
pub fn table2_hickory(scale: Scale) -> ExpResult {
    let (m, nm_iters, run_exact) = match scale {
        Scale::Small => (24, 18, true),
        Scale::Paper => (60, 40, true),
    };
    let cg = data::hickory(m, 0.7, 0.18, 700.0, 11);
    let mut rows = Vec::new();
    let modes: Vec<&str> = if run_exact {
        vec!["exact", "lanczos", "fiedler"]
    } else {
        vec!["lanczos", "fiedler"]
    };
    for mode in modes {
        let (h, neglogp, secs) = fit_lgcp_rbf(&cg, mode, nm_iters, 21);
        let label = match mode {
            "fiedler" => "scaled_eig",
            x => x,
        };
        rows.push(vec![
            label.into(),
            format!("{:.3}", h[2].exp()),
            format!("{:.3}", h[0].exp()),
            format!("{:.3}", h[1].exp()),
            format!("{:.2}", neglogp),
            fmt_s(secs),
        ]);
    }
    ExpResult {
        id: "table2",
        header: vec!["method", "s_f", "l1", "l2", "-logp", "time_s"],
        rows,
    }
}

/// Table 3 — crime LGCP with Matérn-5/2 (space) x spectral-mixture (time)
/// kernel and negative-binomial likelihood: Lanczos vs scaled-eig+Fiedler.
pub fn table3_crime(scale: Scale) -> ExpResult {
    let (nx, ny, weeks, q, nm_iters) = match scale {
        Scale::Small => (10, 12, 32, 3, 12),
        Scale::Paper => (17, 26, 104, 10, 30),
    };
    let train_weeks = weeks * 4 / 5;
    let cg = data::crime(nx, ny, weeks, 3.0, 13);

    // Split train/test along the time axis.
    let train_grid = Grid::new(vec![
        cg.grid.dims[0],
        cg.grid.dims[1],
        GridDim {
            lo: cg.grid.dims[2].lo,
            hi: cg.grid.dims[2].point(train_weeks - 1),
            m: train_weeks,
        },
    ]);
    let mut y_train = Vec::with_capacity(nx * ny * train_weeks);
    let mut y_test = Vec::new();
    for i in 0..cg.grid.size() {
        let p_idx = i % weeks;
        if p_idx < train_weeks {
            y_train.push(cg.counts[i]);
        } else {
            y_test.push(cg.counts[i]);
        }
    }

    let offset = cg.offset;
    let lik = Likelihood::NegBinomial { offset, r: 3.0 };
    let make_kernel = |h: &[f64]| {
        // h = [log_ell1, log_ell2, log_sm_scale, log_sf]
        let mut sm = SpectralMixtureKernel::new(q, 0.5, f64::from(train_weeks as u32) / 8.0, 1.0, true);
        // Scale all SM weights jointly (keeps the NM dimension small).
        for w in sm.log_w.iter_mut() {
            *w += h[2];
        }
        let mut kern = SeparableKernel::new(
            vec![
                Box::new(Factor1d { shape: Shape::Matern52, log_ell: h[0] }) as Box<dyn Kernel>,
                Box::new(Factor1d { shape: Shape::Matern52, log_ell: h[1] }),
                Box::new(sm),
            ],
            1.0,
        );
        kern.log_sf = h[3];
        kern
    };

    let mut rows = Vec::new();
    for mode in ["lanczos", "fiedler"] {
        let t0 = Instant::now();
        let obj = |h: &[f64]| -> f64 {
            let op = KronKernelOp::new(train_grid.clone(), make_kernel(h), 1e-2);
            let mut gp = LaplaceGp::new(op, y_train.clone(), lik);
            let opts =
                LaplaceOptions { slq_probes: 4, slq_steps: 20, seed: 17, ..Default::default() };
            match mode {
                "lanczos" => gp.fit(&opts).map(|f| -f.log_marginal).unwrap_or(f64::INFINITY),
                _ => gp
                    .log_marginal_fiedler(&opts, |op| op.kuu().all_eigvals())
                    .map(|(lm, _)| -lm)
                    .unwrap_or(f64::INFINITY),
            }
        };
        let start = vec![(0.2f64).ln(), (0.2f64).ln(), 0.0, (0.8f64).ln()];
        let res = nelder_mead(
            obj,
            &start,
            &NelderMeadOptions { max_iters: nm_iters, init_step: 0.35, f_tol: 1e-5 },
        );
        let t_recover = t0.elapsed().as_secs_f64();

        // Fit at the recovered hypers, predict all cells (train smoothing +
        // test forecasting through the Kronecker cross-covariance).
        let t0 = Instant::now();
        let op = KronKernelOp::new(train_grid.clone(), make_kernel(&res.x), 1e-2);
        let mut gp = LaplaceGp::new(op, y_train.clone(), lik);
        let fit = gp
            .fit(&LaplaceOptions { slq_probes: 4, slq_steps: 20, seed: 19, ..Default::default() })
            .unwrap();
        let rate_train = gp.predict_rate(&fit);
        // Forecast: f*(., t*) = sum_t k_time(t*, t) S[., t] with
        // S = (K_space a) reshaped; a = fit.a.
        let kern = make_kernel(&res.x);
        let spatial = KronKernelOp::new(
            Grid::new(vec![train_grid.dims[0], train_grid.dims[1]]),
            SeparableKernel::new(
                vec![kern.factors[0].clone(), kern.factors[1].clone()],
                kern.log_sf.exp(),
            ),
            1e-6,
        );
        let cells = nx * ny;
        // Reshape a (cells x train_weeks): time is the fastest axis.
        let mut s = vec![0.0; cells * train_weeks];
        {
            let mut acol = vec![0.0; cells];
            let mut scol = vec![0.0; cells];
            for t in 0..train_weeks {
                for c in 0..cells {
                    acol[c] = fit.a[c * train_weeks + t];
                }
                spatial.kuu().apply(&acol, &mut scol);
                for c in 0..cells {
                    s[c * train_weeks + t] = scol[c];
                }
            }
        }
        let tdim = cg.grid.dims[2];
        let tfac = &kern.factors[2];
        let mut rate_test = Vec::with_capacity(cells * (weeks - train_weeks));
        let mut preds_by_cell = vec![vec![0.0; weeks - train_weeks]; cells];
        for (ti, t_idx) in (train_weeks..weeks).enumerate() {
            let tstar = tdim.point(t_idx);
            for c in 0..cells {
                let mut f = 0.0;
                for t in 0..train_weeks {
                    let kt = tfac.eval(&[tstar], &[tdim.point(t)]);
                    f += kt * s[c * train_weeks + t];
                }
                preds_by_cell[c][ti] = lik.mean(f);
            }
        }
        for c in 0..cells {
            for ti in 0..(weeks - train_weeks) {
                rate_test.push(preds_by_cell[c][ti]);
            }
        }
        let t_predict = t0.elapsed().as_secs_f64();
        // y_test ordering: cells-major then time (matches construction).
        let rmse_train = stats::rmse(&rate_train, &y_train);
        let rmse_test = stats::rmse(&rate_test, &y_test);
        let label = if mode == "fiedler" { "scaled_eig" } else { "lanczos" };
        rows.push(vec![
            label.into(),
            format!("{:.2}", res.x[0].exp()),
            format!("{:.2}", res.x[1].exp()),
            format!("{:.2}", res.x[3].exp().powi(2)),
            fmt_s(t_recover),
            fmt_s(t_predict),
            format!("{:.2}", rmse_train),
            format!("{:.2}", rmse_test),
        ]);
    }
    ExpResult {
        id: "table3",
        header: vec!["method", "l1", "l2", "sf2", "t_recover_s", "t_predict_s", "rmse_train", "rmse_test"],
        rows,
    }
}

/// Table 4 — deep kernel learning on gas-sensor-like data: RMSE and
/// per-iteration time for the plain DNN, DKL+Lanczos, and DKL+scaled-eig.
pub fn table4_dkl(scale: Scale) -> ExpResult {
    let (n_train, n_test, dim, pre_epochs, dkl_iters) = match scale {
        Scale::Small => (400, 100, 32, 150, 8),
        Scale::Paper => (2565, 640, 128, 400, 25),
    };
    let (xtr, ytr, xte, yte) = data::gas(n_train, n_test, dim, 23);
    let mut rng = crate::util::rng::Rng::new(29);
    let net = crate::kernels::deep::Mlp::new(&[dim, 32, 2], &mut rng);
    let mut rows = Vec::new();

    // --- Plain DNN (pretrained net + linear head == our pretrain stage) ---
    let mut dkl = crate::gp::dkl::DeepKernelGp::new(net, xtr.clone(), ytr.clone(), 1.0, 1.0, 0.3);
    let t0 = Instant::now();
    dkl.pretrain(pre_epochs, 0.05, 31);
    let pre_s = t0.elapsed().as_secs_f64() / pre_epochs as f64;
    let pred_dnn = dkl.predict(&xte).unwrap();
    rows.push(vec![
        "dnn".into(),
        format!("{:.4}", stats::rmse(&pred_dnn, &yte)),
        format!("{:.4}", pre_s),
    ]);

    // --- DKL + Lanczos (stochastic estimators through the GP) ---
    let t0 = Instant::now();
    dkl.train(dkl_iters, 0.01, 37).unwrap();
    let per_iter = t0.elapsed().as_secs_f64() / dkl_iters as f64;
    let pred = dkl.predict(&xte).unwrap();
    rows.push(vec![
        "lanczos".into(),
        format!("{:.4}", stats::rmse(&pred, &yte)),
        format!("{:.4}", per_iter),
    ]);

    // --- DKL features + SKI + scaled-eig hyper training ---
    let feats = dkl.features();
    let fpts: Vec<Vec<f64>> = (0..feats.rows).map(|i| feats.row(i).to_vec()).collect();
    let grid = Grid::covering(&fpts, &[40, 40], 0.08);
    let ski = SkiOp::new(
        &fpts,
        grid,
        SeparableKernel::iso(Shape::Rbf, 2, 0.6, 1.0),
        0.3,
        InterpOrder::Cubic,
        false,
    );
    let mut gp = GpRegression::new(ski, ytr.clone());
    let t0 = Instant::now();
    gp.train(
        &Estimator::ScaledEig,
        &LbfgsOptions { max_iters: dkl_iters.min(10), g_tol: 1e-3, ..Default::default() },
    )
    .unwrap();
    let iters = gp_train_iters();
    let per_iter = t0.elapsed().as_secs_f64() / iters as f64;
    let (zte, _) = dkl.net.forward(&xte);
    let tpts: Vec<Vec<f64>> = (0..zte.rows).map(|i| zte.row(i).to_vec()).collect();
    let pred = gp.predict_mean(&tpts);
    rows.push(vec![
        "scaled_eig".into(),
        format!("{:.4}", stats::rmse(&pred, &yte)),
        format!("{:.4}", per_iter),
    ]);

    ExpResult {
        id: "table4",
        header: vec!["method", "rmse", "per_iter_s"],
        rows,
    }
}

fn gp_train_iters() -> usize {
    10 // normalization constant for per-iteration reporting
}

/// Supp. Table 5 — kernel hyperparameter recovery for RBF and Matérn 3/2:
/// exact / Lanczos / Chebyshev / surrogate / scaled-eig / FITC. Reports the
/// recovered hypers, exact −log p at the recovered point, and the time.
pub fn table5_recovery(scale: Scale) -> ExpResult {
    let (n, m, fitc_m, opt_iters) = match scale {
        Scale::Small => (800, 400, 80, 6),
        Scale::Paper => (5000, 2000, 750, 15),
    };
    let truth = (0.05f64, 0.5f64, 0.05f64); // (ell, sf, sigma)
    let start = [(0.1f64).ln(), (1.0f64).ln(), (0.1f64).ln()];
    let mut rows = Vec::new();

    for shape in [Shape::Rbf, Shape::Matern32] {
        let kern_true = IsoKernel::new(shape, 1, truth.0, truth.1);
        let d = data::gp_1d(n, -3.0, 3.0, false, &kern_true, truth.2, 47);
        let diag_corr = shape == Shape::Matern32; // paper applies it to Matérn
        let kname = shape.name();

        // Exact -log p evaluator at recovered hypers (for the table's
        // "value of the log marginal likelihood" column).
        let exact_neglogp = |h: &[f64]| -> f64 {
            let op = crate::operators::DenseKernelOp::new(
                d.x_train.clone(),
                Box::new(IsoKernel { shape, input_dim: 1, log_ell: h[0], log_sf: h[1] }),
                h[2].exp(),
            );
            let mut gp = GpRegression::new(op, d.y_train.clone());
            gp.mean = 0.0;
            -(gp.mll(&Estimator::Exact, false).unwrap().0)
        };

        let make_ski = |diag: bool| {
            let grid = Grid::covering(&d.x_train, &[m], 0.05);
            SkiOp::new(
                &d.x_train,
                grid,
                SeparableKernel::iso(shape, 1, start[0].exp(), start[1].exp()),
                start[2].exp(),
                InterpOrder::Cubic,
                diag,
            )
        };
        let lopts = LbfgsOptions { max_iters: opt_iters, g_tol: 1e-3, ..Default::default() };

        let mut push = |name: &str, h: Vec<f64>, secs: f64| {
            rows.push(vec![
                kname.into(),
                name.into(),
                format!("{:.3}/{:.3}/{:.3}", h[0].exp(), h[1].exp(), h[2].exp()),
                format!("{:.1}", exact_neglogp(&h)),
                fmt_s(secs),
            ]);
        };

        // exact (dense, on a subset when n is large)
        {
            let n_ex = n.min(1500);
            let op = crate::operators::DenseKernelOp::new(
                d.x_train[..n_ex].to_vec(),
                Box::new(IsoKernel { shape, input_dim: 1, log_ell: start[0], log_sf: start[1] }),
                start[2].exp(),
            );
            let mut gp = GpRegression::new(op, d.y_train[..n_ex].to_vec());
            gp.mean = 0.0;
            let t = gp.train(&Estimator::Exact, &LbfgsOptions { max_iters: opt_iters.min(8), ..lopts }).unwrap();
            push("exact", t.final_hypers, t.seconds);
        }
        // lanczos / chebyshev / scaled_eig on SKI
        for (name, est) in [
            ("lanczos", Estimator::Slq(SlqOptions { steps: 25, probes: 5, seed: 51, ..Default::default() })),
            ("chebyshev", Estimator::Chebyshev(ChebOptions { degree: 80, probes: 5, seed: 51, ..Default::default() })),
        ] {
            let mut gp = GpRegression::new(make_ski(diag_corr), d.y_train.clone());
            gp.mean = 0.0;
            let t = gp.train(&est, &lopts).unwrap();
            push(name, t.final_hypers, t.seconds);
        }
        {
            // scaled-eig can't use diag correction — plain SKI.
            let mut gp = GpRegression::new(make_ski(false), d.y_train.clone());
            gp.mean = 0.0;
            let t = gp.train(&Estimator::ScaledEig, &lopts).unwrap();
            push("scaled_eig", t.final_hypers, t.seconds);
        }
        // surrogate
        {
            let t0 = Instant::now();
            let mut op = make_ski(diag_corr);
            let bounds: Vec<(f64, f64)> =
                start.iter().map(|&h| (h - 1.5, h + 1.5)).collect();
            let sur = LogdetSurrogate::build(
                &mut op,
                &bounds,
                24,
                &SlqOptions { steps: 25, probes: 5, seed: 53, ..Default::default() },
                55,
            )
            .unwrap();
            let mut gp = GpRegression::new(op, d.y_train.clone());
            gp.mean = 0.0;
            let t = gp.train(&Estimator::Surrogate(sur), &lopts).unwrap();
            push("surrogate", t.final_hypers, t0.elapsed().as_secs_f64().max(t.seconds));
        }
        // FITC
        {
            let lo = -3.0;
            let hi = 3.0;
            let inducing: Vec<Vec<f64>> = (0..fitc_m)
                .map(|i| vec![lo + (hi - lo) * i as f64 / (fitc_m - 1) as f64])
                .collect();
            let fitc = FitcOp::new(
                d.x_train.clone(),
                inducing,
                Box::new(IsoKernel { shape, input_dim: 1, log_ell: start[0], log_sf: start[1] }),
                start[2].exp(),
                true,
            )
            .unwrap();
            let mut gp = GpRegression::new(fitc, d.y_train.clone());
            gp.mean = 0.0;
            let t = gp
                .train(&Estimator::Exact, &LbfgsOptions { max_iters: opt_iters.min(5), ..lopts })
                .unwrap();
            push("fitc", t.final_hypers, t.seconds);
        }
    }
    ExpResult {
        id: "table5",
        header: vec!["kernel", "method", "ell/sf/sigma", "-logp(exact)", "time_s"],
        rows,
    }
}
