//! Shared utilities: RNG, parallel helpers, statistics, bench harness.
pub mod bench;
pub mod parallel;
pub mod rng;
pub mod stats;
