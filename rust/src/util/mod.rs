//! Shared utilities: RNG, parallel helpers, statistics, bench harness,
//! column-block partitioning, precision mode, observability.
pub mod bench;
pub mod blocks;
pub mod obs;
pub mod parallel;
pub mod precision;
pub mod rng;
pub mod stats;
