//! Shared utilities: RNG, parallel helpers, statistics, bench harness,
//! column-block partitioning, precision mode.
pub mod bench;
pub mod blocks;
pub mod parallel;
pub mod precision;
pub mod rng;
pub mod stats;
