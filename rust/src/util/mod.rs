//! Shared utilities: RNG, parallel helpers, statistics, bench harness,
//! column-block partitioning.
pub mod bench;
pub mod blocks;
pub mod parallel;
pub mod rng;
pub mod stats;
