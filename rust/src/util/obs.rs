//! Zero-dependency hierarchical span tracing + typed runtime counters —
//! the attribution layer behind `--trace`, `--trace-json`, and
//! `service::Metrics`.
//!
//! # The span contract
//!
//! A span is an RAII guard ([`Span`], usually via the [`span!`] macro)
//! timing one region with the process-wide monotonic clock ([`now_ns`]).
//! Spans nest through a **thread-local stack**: a span entered while
//! another is active becomes its child, and the registry aggregates by
//! *span path* (root → … → name), so `pcg_block` under `dispatch` and
//! `pcg_block` under `exp perf` roll up separately. Every path node keeps
//! call count, total time, a duration [`Histogram`] (whose exact
//! `min`/`max` ride along the bucketed quantiles), and one cell per
//! [`Counter`].
//!
//! # Worker-thread stitching
//!
//! `util::parallel`'s pools spawn OS threads whose stacks start empty. At
//! every spawn point (`par_map`, `par_map_steal`, `par_chunks_mut`, the
//! service pool) the spawning thread captures [`stitch_handle`] and the
//! worker installs it with [`adopt`]: spans and counters from stolen RHS
//! groups then attach under the span that spawned them, exactly as if the
//! work had run inline. Stitching moves **no numeric data** — it only
//! redirects attribution.
//!
//! # Counters and the accounting audit
//!
//! Counters ([`Counter`]) are monotone `u64`s added to the innermost
//! active span's node *and* to a global total. Operator applies are
//! counted at the `LinOp` implementations through [`apply_site`], which
//! suppresses **nested** applies (a `SumKernelOp` charging its parts, the
//! preconditioned split operator charging its inner `K̃`) so the count
//! matches the estimators'/solvers' own convention: `block_applies` per
//! top-level blocked apply, `mvms` per probe column. Because the
//! convention is the same, every solver/estimator driver can *audit*
//! itself: [`audit_begin`]/[`Audit::end_assert`] snapshot the global
//! totals around a solve and assert (in release builds too) that the
//! window's `mvms`/`block_applies` delta equals the `BlockCgInfo` /
//! `LogdetEstimate` accounting it returns. Windows that overlap another
//! window (concurrent drivers under `map_hyper_batch`) skip the assert —
//! deltas are only meaningful when exclusive.
//!
//! # Disabled state
//!
//! Tracing is off by default. Every site then costs a few relaxed atomic
//! loads — no clock reads, no locks, no allocation. Enabled or not, this
//! module never touches numeric accumulation order (pinned bitwise by
//! `tests/proptests.rs`): all instrumentation is observation-only.

use crate::util::stats::Histogram;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Typed counter kinds. `QueueWaitNs` accumulates nanoseconds measured on
/// the shared [`now_ns`] clock (the queueing-delay half of satellite
/// latency attribution); everything else is a plain event count.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum Counter {
    /// Probe-column MVMs (block-size independent unit).
    Mvms = 0,
    /// Block-amortized operator applications (what the hardware runs).
    BlockApplies,
    /// Probe columns consumed by estimator drivers.
    Probes,
    /// Lanczos steps / Chebyshev degrees granted by budget decisions.
    Steps,
    /// Pivot columns appended by `PivotedCholesky::grow`.
    PcholCols,
    /// Requests rejected by a full `RequestQueue`.
    QueueFull,
    /// Serving-cache hits (alpha or factor).
    CacheHits,
    /// Serving-cache misses (alpha or factor).
    CacheMisses,
    /// Nanoseconds requests spent queued before dispatch drained them.
    QueueWaitNs,
}

/// Number of counter kinds (array sizing).
pub const NUM_COUNTERS: usize = 9;

/// Stable counter names, in `Counter` discriminant order — the JSON
/// schema's key set.
pub const COUNTER_NAMES: [&str; NUM_COUNTERS] = [
    "mvms",
    "block_applies",
    "probes",
    "steps",
    "pchol_cols",
    "queue_full",
    "cache_hits",
    "cache_misses",
    "queue_wait_ns",
];

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Whether tracing is on. One relaxed load — the entire disabled-state
/// cost of a counter site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on/off process-wide. Tests flipping this must hold
/// [`test_lock`] (the flag is global).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Serializes tests that enable tracing (same pattern as the process-
/// default knob locks in `estimators`/`util::parallel`).
pub fn test_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Run `f` with tracing forced to `on`, restoring the previous state even
/// on panic (drop guard). Callers in tests should hold [`test_lock`].
pub fn with_enabled<T>(on: bool, f: impl FnOnce() -> T) -> T {
    struct Restore(bool);
    impl Drop for Restore {
        fn drop(&mut self) {
            set_enabled(self.0);
        }
    }
    let _r = Restore(enabled());
    set_enabled(on);
    f()
}

// ---------------------------------------------------------------------
// The shared monotonic clock.
// ---------------------------------------------------------------------

fn process_start() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Nanoseconds since process start on one monotonic source — the single
/// clock behind span timing, `RequestQueue` submit stamps, and the
/// dispatcher's batch clock, so queueing delay and solve time subtract
/// cleanly. Always available (not gated on [`enabled`]).
pub fn now_ns() -> u64 {
    process_start().elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------
// Registry: one node per distinct span path.
// ---------------------------------------------------------------------

/// Duration histogram bounds: 100 ns .. 100 s, 72 log buckets.
const SPAN_HIST_LO: f64 = 1e2;
const SPAN_HIST_HI: f64 = 1e11;
const SPAN_HIST_BUCKETS: usize = 72;

struct Node {
    name: &'static str,
    parent: usize,
    depth: usize,
    calls: AtomicU64,
    total_ns: AtomicU64,
    ctrs: [AtomicU64; NUM_COUNTERS],
    /// Span durations (ns). Exact `min`/`max`/`sum` ride along the
    /// buckets (the `util::stats::Histogram` satellite).
    hist: Mutex<Histogram>,
}

impl Node {
    fn new(name: &'static str, parent: usize, depth: usize) -> Node {
        const Z: AtomicU64 = AtomicU64::new(0);
        Node {
            name,
            parent,
            depth,
            calls: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            ctrs: [Z; NUM_COUNTERS],
            hist: Mutex::new(Histogram::log_spaced(
                SPAN_HIST_LO,
                SPAN_HIST_HI,
                SPAN_HIST_BUCKETS,
            )),
        }
    }
}

struct Inner {
    nodes: Vec<Arc<Node>>,
    index: HashMap<(usize, &'static str), usize>,
}

fn registry() -> &'static Mutex<Inner> {
    static REG: OnceLock<Mutex<Inner>> = OnceLock::new();
    REG.get_or_init(|| {
        Mutex::new(Inner {
            nodes: vec![Arc::new(Node::new("run", 0, 0))],
            index: HashMap::new(),
        })
    })
}

const ZERO_CTR: AtomicU64 = AtomicU64::new(0);
static GLOBAL: [AtomicU64; NUM_COUNTERS] = [ZERO_CTR; NUM_COUNTERS];

thread_local! {
    /// Active span stack: (node id, node). Top = innermost span.
    static STACK: RefCell<Vec<(usize, Arc<Node>)>> = const { RefCell::new(Vec::new()) };
    /// Adopted parent node id for worker threads (0 = root).
    static BASE: Cell<usize> = const { Cell::new(0) };
    /// Set while inside an instrumented operator apply — nested applies
    /// (wrapper/sum/preconditioned-split internals) are suppressed.
    static IN_APPLY: Cell<bool> = const { Cell::new(false) };
}

fn current_parent_id() -> usize {
    STACK.with(|s| s.borrow().last().map(|(id, _)| *id)).unwrap_or_else(|| BASE.get())
}

fn intern(parent: usize, name: &'static str) -> (usize, Arc<Node>) {
    let mut reg = registry().lock().expect("obs registry");
    if let Some(&id) = reg.index.get(&(parent, name)) {
        return (id, Arc::clone(&reg.nodes[id]));
    }
    let depth = reg.nodes[parent].depth + 1;
    let id = reg.nodes.len();
    let node = Arc::new(Node::new(name, parent, depth));
    reg.nodes.push(Arc::clone(&node));
    reg.index.insert((parent, name), id);
    (id, node)
}

/// Clear every span path and counter (root survives, zeroed). Only call
/// between runs, with no spans active anywhere — the CLI calls it before
/// a traced run, tests under [`test_lock`].
pub fn reset() {
    let mut reg = registry().lock().expect("obs registry");
    reg.nodes.truncate(1);
    reg.index.clear();
    let root = &reg.nodes[0];
    root.calls.store(0, Ordering::Relaxed);
    root.total_ns.store(0, Ordering::Relaxed);
    for c in root.ctrs.iter() {
        c.store(0, Ordering::Relaxed);
    }
    *root.hist.lock().expect("root hist") =
        Histogram::log_spaced(SPAN_HIST_LO, SPAN_HIST_HI, SPAN_HIST_BUCKETS);
    for g in GLOBAL.iter() {
        g.store(0, Ordering::Relaxed);
    }
    STACK.with(|s| s.borrow_mut().clear());
    BASE.set(0);
}

// ---------------------------------------------------------------------
// Spans.
// ---------------------------------------------------------------------

/// RAII span guard: created by [`span`] / the [`span!`] macro, records
/// elapsed time into its path node on drop. Inert (one relaxed load, no
/// clock read) when tracing is disabled.
pub struct Span {
    live: Option<(Arc<Node>, Instant)>,
}

/// Enter a span named `name` under the innermost active span (or the
/// thread's adopted parent). See the module docs for the path contract.
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { live: None };
    }
    let parent = current_parent_id();
    let (id, node) = intern(parent, name);
    STACK.with(|s| s.borrow_mut().push((id, Arc::clone(&node))));
    Span { live: Some((node, Instant::now())) }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((node, start)) = self.live.take() {
            let ns = start.elapsed().as_nanos() as u64;
            node.calls.fetch_add(1, Ordering::Relaxed);
            node.total_ns.fetch_add(ns, Ordering::Relaxed);
            if let Ok(mut h) = node.hist.lock() {
                h.record(ns as f64);
            }
            STACK.with(|s| {
                let mut st = s.borrow_mut();
                if let Some(pos) = st.iter().rposition(|(_, n)| Arc::ptr_eq(n, &node)) {
                    st.truncate(pos);
                }
            });
        }
    }
}

/// `let _g = span!("pcg_block");` — the span-site macro.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::util::obs::span($name)
    };
}

/// Capture the current span node for worker-thread stitching (pass the
/// handle into the spawned closure, then [`adopt`] it there). Returns the
/// root handle when tracing is off.
pub fn stitch_handle() -> usize {
    if !enabled() {
        return 0;
    }
    current_parent_id()
}

/// Install a [`stitch_handle`] as this thread's span parent: spans and
/// counters recorded here now attach under the spawning span. Workers
/// call this right after `set_worker_budget`.
pub fn adopt(handle: usize) {
    BASE.set(handle);
}

// ---------------------------------------------------------------------
// Counters.
// ---------------------------------------------------------------------

/// Add `v` to counter `c` on the innermost active span (and the global
/// totals). No-op (one relaxed load) when tracing is off.
pub fn add(c: Counter, v: u64) {
    if v == 0 || !enabled() {
        return;
    }
    GLOBAL[c as usize].fetch_add(v, Ordering::Relaxed);
    let hit = STACK.with(|s| {
        let st = s.borrow();
        match st.last() {
            Some((_, node)) => {
                node.ctrs[c as usize].fetch_add(v, Ordering::Relaxed);
                true
            }
            None => false,
        }
    });
    if !hit {
        // Rare path: no span active on this thread — charge the adopted
        // parent (or root).
        let id = BASE.get();
        let reg = registry().lock().expect("obs registry");
        let node = reg.nodes.get(id).unwrap_or(&reg.nodes[0]);
        node.ctrs[c as usize].fetch_add(v, Ordering::Relaxed);
    }
}

/// Snapshot of the global counter totals.
pub fn totals() -> [u64; NUM_COUNTERS] {
    let mut out = [0u64; NUM_COUNTERS];
    for (o, g) in out.iter_mut().zip(GLOBAL.iter()) {
        *o = g.load(Ordering::Relaxed);
    }
    out
}

/// Instrumented-operator-apply guard: opens a span named `kind` and
/// charges `applies` block applies / `mvms` probe-column MVMs — unless
/// this apply is nested inside another instrumented apply, in which case
/// it is fully suppressed (the outer apply already charged the work under
/// the estimators' accounting convention). Inert when tracing is off.
pub struct ApplyGuard {
    _span: Span,
    claimed: bool,
}

impl Drop for ApplyGuard {
    fn drop(&mut self) {
        if self.claimed {
            IN_APPLY.set(false);
        }
    }
}

/// Open an operator-apply site. `applies`/`mvms` follow the accounting
/// convention of `estimators` (one `apply_grad_all_mat` = `nh` applies,
/// `nh * cols` MVMs).
pub fn apply_site(kind: &'static str, applies: u64, mvms: u64) -> ApplyGuard {
    if !enabled() || IN_APPLY.get() {
        return ApplyGuard { _span: Span { live: None }, claimed: false };
    }
    IN_APPLY.set(true);
    let sp = span(kind);
    add(Counter::BlockApplies, applies);
    add(Counter::Mvms, mvms);
    ApplyGuard { _span: sp, claimed: true }
}

/// Suppress apply-site counting on this thread for the guard's lifetime —
/// for driver-internal helper MVMs that are deliberately **outside** the
/// estimate accounting (e.g. the Chebyshev spectrum bracket, whose
/// Lanczos MVMs are not charged to `LogdetEstimate::mvms`). Timing spans
/// still record; only the apply counters go quiet.
pub fn suppress_applies() -> ApplyGuard {
    if !enabled() || IN_APPLY.get() {
        return ApplyGuard { _span: Span { live: None }, claimed: false };
    }
    IN_APPLY.set(true);
    ApplyGuard { _span: Span { live: None }, claimed: true }
}

// ---------------------------------------------------------------------
// Accounting audits.
// ---------------------------------------------------------------------

static AUDIT_ACTIVE: AtomicUsize = AtomicUsize::new(0);
static AUDIT_EPOCH: AtomicU64 = AtomicU64::new(0);

/// An open audit window (see module docs). Dropping without
/// [`end_assert`](Audit::end_assert) just closes the window.
pub struct Audit {
    state: Option<(Box<[u64; NUM_COUNTERS]>, u64, bool)>,
}

/// Open an audit window over the global counter totals. Returns an inert
/// window when tracing is off.
pub fn audit_begin() -> Audit {
    if !enabled() {
        return Audit { state: None };
    }
    let exclusive = AUDIT_ACTIVE.fetch_add(1, Ordering::SeqCst) == 0;
    let epoch = AUDIT_EPOCH.fetch_add(1, Ordering::SeqCst) + 1;
    Audit { state: Some((Box::new(totals()), epoch, exclusive)) }
}

impl Audit {
    /// Close the window and, if it stayed exclusive (no concurrent driver
    /// opened a window), assert each counter's delta equals `expect`.
    /// This is the release-build guarantee that span-tree totals match
    /// the `LogdetEstimate`/`BlockCgInfo` accounting.
    pub fn end_assert(mut self, what: &str, expect: &[(Counter, u64)]) {
        if let Some((base, epoch, exclusive)) = self.state.take() {
            let clean = exclusive
                && AUDIT_EPOCH.load(Ordering::SeqCst) == epoch
                && AUDIT_ACTIVE.load(Ordering::SeqCst) == 1;
            let t = totals();
            AUDIT_ACTIVE.fetch_sub(1, Ordering::SeqCst);
            if clean {
                for &(c, want) in expect {
                    let got = t[c as usize] - base[c as usize];
                    assert!(
                        got == want,
                        "obs audit [{what}]: {} delta {got} != accounting {want}",
                        COUNTER_NAMES[c as usize]
                    );
                }
            }
        }
    }
}

impl Drop for Audit {
    fn drop(&mut self) {
        if self.state.take().is_some() {
            AUDIT_ACTIVE.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

// ---------------------------------------------------------------------
// Reports.
// ---------------------------------------------------------------------

/// One span path's aggregated stats, as reported.
pub struct SpanStat {
    /// `run/…/name` path string.
    pub path: String,
    pub name: String,
    /// Tree depth (root = 0).
    pub depth: usize,
    pub calls: u64,
    pub total_ns: u64,
    /// Total minus children's totals (saturating — concurrent children
    /// can overlap the parent on the wall clock).
    pub self_ns: u64,
    /// Exact duration extrema off the per-node histogram.
    pub min_ns: f64,
    pub max_ns: f64,
    /// Bucketed quantiles (upper-edge over-read, as documented on
    /// `util::stats::Histogram`).
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub ctrs: [u64; NUM_COUNTERS],
}

/// Snapshot every span path in tree (preorder) order.
pub fn snapshot() -> Vec<SpanStat> {
    let reg = registry().lock().expect("obs registry");
    let n = reg.nodes.len();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (id, node) in reg.nodes.iter().enumerate().skip(1) {
        children[node.parent].push(id);
    }
    // Child totals for self-time.
    let totals_ns: Vec<u64> =
        reg.nodes.iter().map(|nd| nd.total_ns.load(Ordering::Relaxed)).collect();
    let mut paths: Vec<String> = vec![String::from("run"); n];
    for (id, node) in reg.nodes.iter().enumerate().skip(1) {
        paths[id] = format!("{}/{}", paths[node.parent], node.name);
    }
    let mut out = Vec::with_capacity(n);
    let mut stack = vec![0usize];
    while let Some(id) = stack.pop() {
        for &c in children[id].iter().rev() {
            stack.push(c);
        }
        let node = &reg.nodes[id];
        let kids_ns: u64 = children[id].iter().map(|&c| totals_ns[c]).sum();
        let total = if id == 0 {
            // The root never runs as a span; report it as the envelope of
            // its children so percentages are well defined.
            kids_ns
        } else {
            totals_ns[id]
        };
        let mut ctrs = [0u64; NUM_COUNTERS];
        for (o, c) in ctrs.iter_mut().zip(node.ctrs.iter()) {
            *o = c.load(Ordering::Relaxed);
        }
        let h = node.hist.lock().expect("span hist");
        out.push(SpanStat {
            path: paths[id].clone(),
            name: node.name.to_string(),
            depth: node.depth,
            calls: node.calls.load(Ordering::Relaxed),
            total_ns: total,
            self_ns: total.saturating_sub(kids_ns),
            min_ns: h.min(),
            max_ns: h.max(),
            p50_ns: h.quantile(0.5),
            p99_ns: h.quantile(0.99),
            ctrs,
        });
    }
    out
}

fn fmt_ms(ns: u64) -> String {
    format!("{:.2}", ns as f64 / 1e6)
}

/// Human-readable profile: tree section (indent = depth) then a flat
/// rollup aggregated by span name, sorted by self time. Counter columns
/// cover `mvms`/`block_applies`; other nonzero counters are listed
/// inline.
pub fn report_text() -> String {
    let stats = snapshot();
    let mut s = String::new();
    s.push_str("== trace: span tree ==\n");
    s.push_str(&format!(
        "{:<44} {:>8} {:>10} {:>10} {:>10} {:>10}\n",
        "span", "calls", "total_ms", "self_ms", "mvms", "blk_appl"
    ));
    for st in &stats {
        let mut label = String::new();
        for _ in 0..st.depth {
            label.push_str("  ");
        }
        label.push_str(&st.name);
        let extras: Vec<String> = st
            .ctrs
            .iter()
            .enumerate()
            .filter(|&(i, &v)| {
                v > 0 && i != Counter::Mvms as usize && i != Counter::BlockApplies as usize
            })
            .map(|(i, &v)| format!("{}={v}", COUNTER_NAMES[i]))
            .collect();
        s.push_str(&format!(
            "{:<44} {:>8} {:>10} {:>10} {:>10} {:>10}{}{}\n",
            label,
            st.calls,
            fmt_ms(st.total_ns),
            fmt_ms(st.self_ns),
            st.ctrs[Counter::Mvms as usize],
            st.ctrs[Counter::BlockApplies as usize],
            if extras.is_empty() { "" } else { "  " },
            extras.join(" ")
        ));
    }
    // Flat rollup by name.
    let mut flat: HashMap<String, (u64, u64, [u64; NUM_COUNTERS])> = HashMap::new();
    for st in stats.iter().skip(1) {
        let e = flat.entry(st.name.clone()).or_insert((0, 0, [0; NUM_COUNTERS]));
        e.0 += st.calls;
        e.1 += st.self_ns;
        for (a, b) in e.2.iter_mut().zip(st.ctrs.iter()) {
            *a += b;
        }
    }
    let total_self: u64 = flat.values().map(|e| e.1).sum();
    let mut rows: Vec<(String, (u64, u64, [u64; NUM_COUNTERS]))> = flat.into_iter().collect();
    rows.sort_by(|a, b| b.1 .1.cmp(&a.1 .1).then(a.0.cmp(&b.0)));
    s.push_str("\n== trace: flat (by self time) ==\n");
    s.push_str(&format!(
        "{:<28} {:>8} {:>10} {:>6} {:>10} {:>10}\n",
        "name", "calls", "self_ms", "%", "mvms", "blk_appl"
    ));
    for (name, (calls, self_ns, ctrs)) in &rows {
        let pct = if total_self > 0 {
            100.0 * *self_ns as f64 / total_self as f64
        } else {
            0.0
        };
        s.push_str(&format!(
            "{:<28} {:>8} {:>10} {:>6.1} {:>10} {:>10}\n",
            name,
            calls,
            fmt_ms(*self_ns),
            pct,
            ctrs[Counter::Mvms as usize],
            ctrs[Counter::BlockApplies as usize]
        ));
    }
    let t = totals();
    s.push_str("\n== trace: counter totals ==\n");
    for (name, v) in COUNTER_NAMES.iter().zip(t.iter()) {
        if *v > 0 {
            s.push_str(&format!("{name} = {v}\n"));
        }
    }
    s
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Stable machine-readable schema (`gpsld-trace-v1`): one object per span
/// path in preorder, plus global counter totals. Counter keys follow
/// [`COUNTER_NAMES`]; zero counters are omitted per span but the totals
/// object always carries every key.
pub fn report_json() -> String {
    let stats = snapshot();
    let mut s = String::from("{\n  \"schema\": \"gpsld-trace-v1\",\n  \"spans\": [\n");
    for (i, st) in stats.iter().enumerate() {
        let ctrs: Vec<String> = st
            .ctrs
            .iter()
            .enumerate()
            .filter(|&(_, &v)| v > 0)
            .map(|(k, &v)| format!("\"{}\": {v}", COUNTER_NAMES[k]))
            .collect();
        let fmt_or_null = |v: f64| {
            if v.is_finite() { format!("{v:.1}") } else { String::from("null") }
        };
        s.push_str(&format!(
            "    {{\"path\": \"{}\", \"name\": \"{}\", \"depth\": {}, \"calls\": {}, \
             \"total_ns\": {}, \"self_ns\": {}, \"min_ns\": {}, \"max_ns\": {}, \
             \"p50_ns\": {}, \"p99_ns\": {}, \"counters\": {{{}}}}}{}\n",
            json_escape(&st.path),
            json_escape(&st.name),
            st.depth,
            st.calls,
            st.total_ns,
            st.self_ns,
            fmt_or_null(st.min_ns),
            fmt_or_null(st.max_ns),
            fmt_or_null(st.p50_ns),
            fmt_or_null(st.p99_ns),
            ctrs.join(", "),
            if i + 1 == stats.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n  \"totals\": {");
    let t = totals();
    let items: Vec<String> = COUNTER_NAMES
        .iter()
        .zip(t.iter())
        .map(|(n, v)| format!("\"{n}\": {v}"))
        .collect();
    s.push_str(&items.join(", "));
    s.push_str("}\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sites_are_inert() {
        let _l = test_lock().lock().unwrap();
        with_enabled(false, || {
            let before = totals();
            {
                let _s = span("obs_test_disabled");
                add(Counter::Mvms, 7);
                let _g = apply_site("obs_test_disabled_op", 1, 3);
            }
            assert_eq!(totals(), before, "disabled sites must not count");
        });
    }

    #[test]
    fn spans_nest_and_counters_attach() {
        let _l = test_lock().lock().unwrap();
        with_enabled(true, || {
            {
                let _a = span("obs_test_outer");
                add(Counter::Probes, 2);
                {
                    let _b = span("obs_test_inner");
                    add(Counter::Probes, 3);
                }
            }
            let stats = snapshot();
            let outer = stats
                .iter()
                .find(|s| s.name == "obs_test_outer")
                .expect("outer span recorded");
            assert_eq!(outer.ctrs[Counter::Probes as usize], 2);
            assert!(outer.calls >= 1);
            let inner = stats
                .iter()
                .find(|s| s.path.ends_with("obs_test_outer/obs_test_inner"))
                .expect("inner span nested under outer");
            assert_eq!(inner.ctrs[Counter::Probes as usize], 3);
            assert!(outer.total_ns >= inner.total_ns);
        });
    }

    #[test]
    fn nested_apply_sites_are_suppressed() {
        let _l = test_lock().lock().unwrap();
        with_enabled(true, || {
            let base = totals();
            {
                let _outer = apply_site("obs_test_sum_op", 1, 4);
                // A part charging itself inside the sum: suppressed.
                let _inner = apply_site("obs_test_part_op", 1, 4);
            }
            let t = totals();
            assert_eq!(t[Counter::Mvms as usize] - base[Counter::Mvms as usize], 4);
            assert_eq!(
                t[Counter::BlockApplies as usize] - base[Counter::BlockApplies as usize],
                1
            );
            // Sequential (non-nested) applies both count.
            {
                let _second = apply_site("obs_test_part_op", 1, 4);
            }
            let t2 = totals();
            assert_eq!(t2[Counter::Mvms as usize] - base[Counter::Mvms as usize], 8);
        });
    }

    #[test]
    fn stitching_attaches_worker_spans_to_spawner() {
        let _l = test_lock().lock().unwrap();
        with_enabled(true, || {
            {
                let _parent = span("obs_test_spawner");
                let h = stitch_handle();
                std::thread::scope(|scope| {
                    scope.spawn(move || {
                        adopt(h);
                        let _w = span("obs_test_worker");
                        add(Counter::Steps, 5);
                    });
                });
            }
            let stats = snapshot();
            let worker = stats
                .iter()
                .find(|s| s.path.ends_with("obs_test_spawner/obs_test_worker"))
                .expect("worker span stitched under spawner");
            assert_eq!(worker.ctrs[Counter::Steps as usize], 5);
        });
    }

    #[test]
    fn audit_window_asserts_exact_deltas() {
        let _l = test_lock().lock().unwrap();
        with_enabled(true, || {
            let a = audit_begin();
            add(Counter::Mvms, 11);
            add(Counter::BlockApplies, 2);
            a.end_assert(
                "obs_test_audit",
                &[(Counter::Mvms, 11), (Counter::BlockApplies, 2)],
            );
        });
    }

    #[test]
    fn json_report_is_stable_shape() {
        let _l = test_lock().lock().unwrap();
        with_enabled(true, || {
            {
                let _s = span("obs_test_json");
                add(Counter::Mvms, 1);
            }
            let j = report_json();
            assert!(j.contains("\"schema\": \"gpsld-trace-v1\""));
            assert!(j.contains("\"spans\""));
            assert!(j.contains("\"totals\""));
            assert!(j.contains("obs_test_json"));
            let text = report_text();
            assert!(text.contains("span tree"));
            assert!(text.contains("obs_test_json"));
        });
    }

    #[test]
    fn now_ns_is_monotone() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }
}
