//! Small statistics helpers shared by estimators, experiments, and benches.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance; 0 when fewer than two samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Standard error of the mean — the paper's a-posteriori stochastic error
/// estimate across probe vectors (§4).
///
/// Fewer than two samples carry no spread information, so the standard
/// error is `+inf` (documented sentinel), NOT 0: a 1-probe estimate used
/// to report a zero standard error, which an adaptive stopping rule would
/// read as "converged after one probe". Deterministic estimates that
/// genuinely have zero error (`LogdetEstimate::exact`) set their
/// `std_err: 0.0` explicitly rather than deriving it from one sample.
pub fn std_err(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::INFINITY;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Median (copies + sorts). A NaN anywhere in the input propagates to a
/// NaN median — like [`mean`] — rather than panicking in the sort
/// comparator (the old `partial_cmp().unwrap()`) or silently skewing the
/// order statistics (a NaN sorted to one end shifts which element the
/// middle index selects).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    if xs.iter().any(|x| x.is_nan()) {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Mean squared error between predictions and targets (allocation-free).
pub fn mse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let mut s = 0.0;
    for (p, t) in pred.iter().zip(truth) {
        s += (p - t) * (p - t);
    }
    s / pred.len() as f64
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    mse(pred, truth).sqrt()
}

/// Standardized mean absolute error: MAE(pred, truth) / MAE(mean(truth), truth).
/// This is Fig. 1(d)'s metric — 1.0 means "no better than the constant mean".
pub fn smae(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mu = mean(truth);
    let mae: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t).abs())
        .sum::<f64>()
        / pred.len() as f64;
    let base: f64 =
        truth.iter().map(|t| (t - mu).abs()).sum::<f64>() / truth.len() as f64;
    if base == 0.0 {
        mae
    } else {
        mae / base
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = alpha * y
#[inline]
pub fn scal(alpha: f64, y: &mut [f64]) {
    for v in y.iter_mut() {
        *v *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    /// Bugfix regression: NaN input used to panic inside the sort
    /// comparator (`partial_cmp().unwrap()`); it now propagates — a NaN
    /// median is detectable, a panic (or a silently shifted middle
    /// element) is not. Signs pinned via copysign since `f64::NAN`'s sign
    /// bit is unspecified.
    #[test]
    fn median_propagates_nan_input() {
        let pnan = f64::NAN.copysign(1.0);
        let nnan = f64::NAN.copysign(-1.0);
        assert!(median(&[3.0, pnan, 1.0]).is_nan());
        assert!(median(&[1.0, 2.0, 5.0, pnan, 3.0]).is_nan());
        assert!(median(&[nnan, 0.5, 2.0]).is_nan());
        assert!(median(&[f64::NAN]).is_nan());
        assert!(median(&[pnan, nnan]).is_nan());
    }

    /// The other NaN-adjacent helpers propagate rather than panic.
    #[test]
    fn stats_helpers_propagate_nan() {
        assert!(mean(&[1.0, f64::NAN]).is_nan());
        assert!(mse(&[f64::NAN, 1.0], &[0.0, 1.0]).is_nan());
        assert!(std_err(&[1.0, f64::NAN, 2.0]).is_nan());
        assert!(smae(&[f64::NAN, 1.0], &[0.0, 1.0]).is_nan());
    }

    #[test]
    fn mse_empty_is_zero() {
        assert_eq!(mse(&[], &[]), 0.0);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    /// Bugfix regression: a 0- or 1-sample standard error is +inf (no
    /// spread information), never a misleading 0 that a stopping rule
    /// could act on.
    #[test]
    fn std_err_degenerate_is_infinite() {
        assert!(std_err(&[]).is_infinite());
        assert!(std_err(&[3.25]).is_infinite());
        assert!(std_err(&[1.0, 1.0]).is_finite());
        assert_eq!(std_err(&[1.0, 1.0]), 0.0);
    }

    #[test]
    fn smae_of_mean_predictor_is_one() {
        let truth = [1.0, 2.0, 3.0, 10.0];
        let pred = [4.0; 4]; // the mean of truth
        assert!((smae(&pred, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mse_zero_for_exact() {
        let t = [1.0, -2.0, 3.5];
        assert_eq!(mse(&t, &t), 0.0);
        assert_eq!(rmse(&t, &t), 0.0);
    }

    #[test]
    fn blas_helpers() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![3.5, 4.5]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
