//! Small statistics helpers shared by estimators, experiments, and benches.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance; 0 when fewer than two samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Standard error of the mean — the paper's a-posteriori stochastic error
/// estimate across probe vectors (§4).
///
/// Fewer than two samples carry no spread information, so the standard
/// error is `+inf` (documented sentinel), NOT 0: a 1-probe estimate used
/// to report a zero standard error, which an adaptive stopping rule would
/// read as "converged after one probe". Deterministic estimates that
/// genuinely have zero error (`LogdetEstimate::exact`) set their
/// `std_err: 0.0` explicitly rather than deriving it from one sample.
pub fn std_err(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::INFINITY;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Median (copies + sorts). A NaN anywhere in the input propagates to a
/// NaN median — like [`mean`] — rather than panicking in the sort
/// comparator (the old `partial_cmp().unwrap()`) or silently skewing the
/// order statistics (a NaN sorted to one end shifts which element the
/// middle index selects).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    if xs.iter().any(|x| x.is_nan()) {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Mean squared error between predictions and targets (allocation-free).
pub fn mse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let mut s = 0.0;
    for (p, t) in pred.iter().zip(truth) {
        s += (p - t) * (p - t);
    }
    s / pred.len() as f64
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    mse(pred, truth).sqrt()
}

/// Standardized mean absolute error: MAE(pred, truth) / MAE(mean(truth), truth).
/// This is Fig. 1(d)'s metric — 1.0 means "no better than the constant mean".
pub fn smae(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mu = mean(truth);
    let mae: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t).abs())
        .sum::<f64>()
        / pred.len() as f64;
    let base: f64 =
        truth.iter().map(|t| (t - mu).abs()).sum::<f64>() / truth.len() as f64;
    if base == 0.0 {
        mae
    } else {
        mae / base
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = alpha * y
#[inline]
pub fn scal(alpha: f64, y: &mut [f64]) {
    for v in y.iter_mut() {
        *v *= alpha;
    }
}

/// Fixed-bucket histogram with log-spaced bucket edges — the serving
/// layer's latency recorder (p50/p99 readout with no dependencies and
/// O(buckets) memory, regardless of request count).
///
/// Buckets are log-spaced over `[lo, hi)`: bucket `k` covers
/// `[lo·r^k, lo·r^(k+1))` with `r = (hi/lo)^(1/buckets)`. Values below
/// `lo` land in bucket 0; values at or above `hi` **saturate into the top
/// bucket** (they are counted, not dropped — a quantile that falls there
/// reports the top bucket's upper edge, i.e. `hi`, as a floor-biased
/// answer rather than pretending the tail was observed). Quantiles are
/// read out as the *upper edge* of the bucket holding the q-th sample, so
/// the readout over-estimates by at most one bucket width (a ratio of `r`
/// for log-spaced buckets).
///
/// NaN inputs follow the PR 4 propagation convention of [`median`]: a
/// recorded NaN is remembered and poisons every subsequent
/// [`Histogram::quantile`] readout — and the exact [`sum`](Self::sum) /
/// [`min`](Self::min) / [`max`](Self::max) readouts alike (NaN out, never
/// a silently shifted order statistic). An empty histogram reads NaN too —
/// "no data" must not look like a zero-latency service.
///
/// Alongside the bucketed quantiles (whose one-bucket over-read is
/// inherent to the representation and documented on
/// [`quantile`](Self::quantile)), the histogram tracks the **exact**
/// count, sum, min, and max of the recorded samples — `util::obs` span
/// timing rollups read extrema and means off these without paying any
/// bucket quantization.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    /// Per-bucket sample counts; `counts.len()` is the bucket count.
    counts: Vec<u64>,
    total: u64,
    saw_nan: bool,
    /// Exact sum of recorded samples (unbucketed).
    sum: f64,
    /// Exact extrema of recorded samples (unbucketed; +inf/-inf when
    /// nothing was recorded).
    min: f64,
    max: f64,
}

impl Histogram {
    /// Log-spaced histogram over `[lo, hi)` with `buckets` buckets.
    /// Requires `0 < lo < hi` and `buckets >= 1`.
    pub fn log_spaced(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(lo > 0.0 && lo < hi, "need 0 < lo < hi, got [{lo}, {hi})");
        assert!(buckets >= 1, "need at least one bucket");
        Histogram {
            lo,
            hi,
            counts: vec![0; buckets],
            total: 0,
            saw_nan: false,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample. Below-range clamps to bucket 0, at-or-above-range
    /// saturates into the top bucket (the exact `sum`/`min`/`max` still see
    /// the unclamped value), NaN poisons future readouts.
    pub fn record(&mut self, v: f64) {
        if v.is_nan() {
            self.saw_nan = true;
            self.total += 1;
            return;
        }
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        let nb = self.counts.len();
        let k = if v < self.lo {
            0
        } else if v >= self.hi {
            nb - 1
        } else {
            // log-spaced index: k = floor(nb * ln(v/lo) / ln(hi/lo)),
            // clamped against edge-of-range rounding.
            let frac = (v / self.lo).ln() / (self.hi / self.lo).ln();
            ((frac * nb as f64) as usize).min(nb - 1)
        };
        self.counts[k] += 1;
        self.total += 1;
    }

    /// Number of recorded samples (NaNs included).
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact sum of recorded samples. NaN when empty or poisoned (same
    /// convention as [`Histogram::quantile`]).
    pub fn sum(&self) -> f64 {
        if self.total == 0 || self.saw_nan {
            return f64::NAN;
        }
        self.sum
    }

    /// Exact minimum of recorded samples (unbucketed). NaN when empty or
    /// poisoned.
    pub fn min(&self) -> f64 {
        if self.total == 0 || self.saw_nan {
            return f64::NAN;
        }
        self.min
    }

    /// Exact maximum of recorded samples (unbucketed). NaN when empty or
    /// poisoned.
    pub fn max(&self) -> f64 {
        if self.total == 0 || self.saw_nan {
            return f64::NAN;
        }
        self.max
    }

    /// Exact mean (`sum / count`). NaN when empty or poisoned.
    pub fn mean(&self) -> f64 {
        self.sum() / self.total as f64
    }

    /// Quantile readout, `q` in `[0, 1]`: the upper edge of the bucket
    /// holding the ceil(q·total)-th sample. NaN when empty or when any
    /// recorded sample was NaN (propagation, matching [`median`]).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 || self.saw_nan || q.is_nan() {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (k, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return self.upper_edge(k);
            }
        }
        self.upper_edge(self.counts.len() - 1)
    }

    /// Upper edge of bucket `k`: `lo · (hi/lo)^((k+1)/buckets)`; the top
    /// bucket's edge is exactly `hi` (saturated samples read back as the
    /// range ceiling).
    fn upper_edge(&self, k: usize) -> f64 {
        let nb = self.counts.len();
        if k + 1 >= nb {
            return self.hi;
        }
        self.lo * (self.hi / self.lo).powf((k + 1) as f64 / nb as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    /// Bugfix regression: NaN input used to panic inside the sort
    /// comparator (`partial_cmp().unwrap()`); it now propagates — a NaN
    /// median is detectable, a panic (or a silently shifted middle
    /// element) is not. Signs pinned via copysign since `f64::NAN`'s sign
    /// bit is unspecified.
    #[test]
    fn median_propagates_nan_input() {
        let pnan = f64::NAN.copysign(1.0);
        let nnan = f64::NAN.copysign(-1.0);
        assert!(median(&[3.0, pnan, 1.0]).is_nan());
        assert!(median(&[1.0, 2.0, 5.0, pnan, 3.0]).is_nan());
        assert!(median(&[nnan, 0.5, 2.0]).is_nan());
        assert!(median(&[f64::NAN]).is_nan());
        assert!(median(&[pnan, nnan]).is_nan());
    }

    /// The other NaN-adjacent helpers propagate rather than panic.
    #[test]
    fn stats_helpers_propagate_nan() {
        assert!(mean(&[1.0, f64::NAN]).is_nan());
        assert!(mse(&[f64::NAN, 1.0], &[0.0, 1.0]).is_nan());
        assert!(std_err(&[1.0, f64::NAN, 2.0]).is_nan());
        assert!(smae(&[f64::NAN, 1.0], &[0.0, 1.0]).is_nan());
    }

    #[test]
    fn mse_empty_is_zero() {
        assert_eq!(mse(&[], &[]), 0.0);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    /// Bugfix regression: a 0- or 1-sample standard error is +inf (no
    /// spread information), never a misleading 0 that a stopping rule
    /// could act on.
    #[test]
    fn std_err_degenerate_is_infinite() {
        assert!(std_err(&[]).is_infinite());
        assert!(std_err(&[3.25]).is_infinite());
        assert!(std_err(&[1.0, 1.0]).is_finite());
        assert_eq!(std_err(&[1.0, 1.0]), 0.0);
    }

    #[test]
    fn smae_of_mean_predictor_is_one() {
        let truth = [1.0, 2.0, 3.0, 10.0];
        let pred = [4.0; 4]; // the mean of truth
        assert!((smae(&pred, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mse_zero_for_exact() {
        let t = [1.0, -2.0, 3.5];
        assert_eq!(mse(&t, &t), 0.0);
        assert_eq!(rmse(&t, &t), 0.0);
    }

    #[test]
    fn histogram_quantiles_land_on_bucket_edges() {
        // 3 log-spaced buckets over [1, 1000): [1,10), [10,100), [100,1000).
        let mut h = Histogram::log_spaced(1.0, 1000.0, 3);
        for v in [2.0, 3.0, 50.0, 200.0, 5000.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        // rank(0.5) = 3rd sample -> second bucket -> upper edge 100.
        assert!((h.quantile(0.5) - 100.0).abs() < 1e-9);
        // rank(0.99) = 5th sample -> top bucket; 5000 saturated, edge = hi.
        assert_eq!(h.quantile(0.99), 1000.0);
        // rank(0.0) clamps to the first sample's bucket -> edge 10.
        assert!((h.quantile(0.0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_out_of_range_clamps_and_saturates() {
        let mut h = Histogram::log_spaced(10.0, 100.0, 4);
        h.record(0.001); // below lo -> bucket 0
        h.record(1e12); // above hi -> top bucket, counted not dropped
        assert_eq!(h.count(), 2);
        // First sample: bucket 0's upper edge 10 * 10^(1/4).
        let edge0 = 10.0 * 10f64.powf(0.25);
        assert!((h.quantile(0.25) - edge0).abs() < 1e-9);
        // Second sample saturated: reads back the range ceiling.
        assert_eq!(h.quantile(1.0), 100.0);
    }

    /// NaN convention matches [`median`]: a recorded NaN propagates to
    /// every quantile readout instead of skewing which bucket the rank
    /// selects; an empty histogram reads NaN, never a fake zero latency.
    #[test]
    fn histogram_nan_propagates_and_empty_is_nan() {
        let h = Histogram::log_spaced(1.0, 100.0, 8);
        assert!(h.quantile(0.5).is_nan());
        let mut h = Histogram::log_spaced(1.0, 100.0, 8);
        h.record(5.0);
        assert!(h.quantile(0.5).is_finite());
        h.record(f64::NAN);
        assert!(h.quantile(0.5).is_nan());
        assert!(h.quantile(0.99).is_nan());
        assert_eq!(h.count(), 2);
        // NaN q is NaN out, even on a clean histogram.
        let mut clean = Histogram::log_spaced(1.0, 100.0, 8);
        clean.record(5.0);
        assert!(clean.quantile(f64::NAN).is_nan());
    }

    /// The readout over-estimates the exact quantile by at most one
    /// bucket ratio r = (hi/lo)^(1/buckets) — checked against the exact
    /// order statistic on a deterministic sample set.
    #[test]
    fn histogram_quantile_within_one_bucket_of_exact() {
        let mut h = Histogram::log_spaced(1.0, 1e6, 60);
        let r = (1e6f64).powf(1.0 / 60.0);
        let mut xs: Vec<f64> = Vec::new();
        let mut v = 1.3;
        for _ in 0..500 {
            v = (v * 1.37) % 9000.0 + 1.0; // deterministic, in-range spread
            xs.push(v);
            h.record(v);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(f64::total_cmp);
        for q in [0.5, 0.9, 0.99] {
            let exact = sorted[((q * 500.0).ceil() as usize - 1).min(499)];
            let est = h.quantile(q);
            assert!(est >= exact, "q={q}: est {est} < exact {exact}");
            assert!(est <= exact * r * (1.0 + 1e-12), "q={q}: est {est} > {exact}*r");
        }
    }

    /// The exact side-channel: count/sum/min/max are unbucketed (min/max
    /// sharper than any bucket edge, sum exact), and the NaN poisoning
    /// convention covers them exactly like the quantiles.
    #[test]
    fn histogram_exact_sum_min_max() {
        let mut h = Histogram::log_spaced(1.0, 1000.0, 3);
        assert!(h.sum().is_nan() && h.min().is_nan() && h.max().is_nan());
        for v in [2.0, 3.0, 50.0, 200.0, 5000.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 5255.0).abs() < 1e-9);
        assert_eq!(h.min(), 2.0);
        // Saturation clamps the bucket, never the exact max.
        assert_eq!(h.max(), 5000.0);
        assert!((h.mean() - 1051.0).abs() < 1e-9);
        h.record(f64::NAN);
        assert!(h.sum().is_nan());
        assert!(h.min().is_nan());
        assert!(h.max().is_nan());
        assert!(h.mean().is_nan());
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn blas_helpers() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![3.5, 4.5]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
