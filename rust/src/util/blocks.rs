//! Column-block partitioning shared by the estimators' probe drivers and
//! the solvers' right-hand-side batching — the one place the clamp/rounding
//! lives so every blocked consumer slices a column set identically.

/// Partition of `count` columns into `block_size`-wide blocks.
#[derive(Clone, Copy, Debug)]
pub struct BlockPartition {
    pub bs: usize,
    pub nblocks: usize,
    count: usize,
}

impl BlockPartition {
    pub fn new(count: usize, block_size: usize) -> Self {
        let bs = block_size.max(1).min(count.max(1));
        BlockPartition { bs, nblocks: count.div_ceil(bs), count }
    }

    /// (first column, width) of block `bi`.
    pub fn range(&self, bi: usize) -> (usize, usize) {
        let j0 = bi * self.bs;
        (j0, self.bs.min(self.count - j0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_columns_once() {
        for count in [0usize, 1, 5, 8, 9, 17] {
            for bsz in [1usize, 2, 4, 8, 100] {
                let part = BlockPartition::new(count, bsz);
                let mut covered = 0;
                for bi in 0..part.nblocks {
                    let (j0, w) = part.range(bi);
                    assert_eq!(j0, covered, "count={count} bs={bsz}");
                    assert!(w >= 1);
                    covered += w;
                }
                assert_eq!(covered, count, "count={count} bs={bsz}");
            }
        }
    }

    #[test]
    fn clamps_block_size() {
        let part = BlockPartition::new(3, 100);
        assert_eq!(part.bs, 3);
        assert_eq!(part.nblocks, 1);
        let part = BlockPartition::new(5, 0);
        assert_eq!(part.bs, 1);
        assert_eq!(part.nblocks, 5);
    }
}
