//! Minimal scoped-thread parallelism (the offline registry has no rayon or
//! tokio). Probe-level and experiment-level fan-out only needs a parallel
//! indexed map with static partitioning, which `std::thread::scope` gives us
//! safely.
//!
//! Nesting guard: the estimators fan out over probe blocks while the
//! operators fan out inside a block apply; without a guard that multiplies
//! into `threads^2` OS threads. Worker threads spawned here mark
//! themselves, and any nested `par_map` / `par_chunks_mut` /
//! [`default_threads`] call from inside a worker runs serially — so
//! parallelism lives at the outermost level that asked for it (block level
//! when there are many blocks, operator level when one block runs on the
//! caller's thread).

use std::cell::Cell;

thread_local! {
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True on a thread spawned by this module (or marked by a worker pool):
/// nested fan-out should stay serial.
pub fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|c| c.get())
}

/// Mark the current thread as a pool worker (used by the batch service's
/// own worker pool so estimator calls inside it don't nest-fan-out).
pub fn mark_pool_worker() {
    IN_POOL_WORKER.with(|c| c.set(true));
}

/// Number of worker threads to use (capped so tests stay polite; 1 inside
/// a pool worker to prevent nested oversubscription).
pub fn default_threads() -> usize {
    if in_pool_worker() {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Parallel indexed map: computes `f(i)` for `i in 0..n`, preserving order.
///
/// Falls back to a sequential loop when `n` is small or one thread is
/// requested — the closure must be `Sync` (called from many threads) and the
/// result `Send`.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = if in_pool_worker() { 1 } else { threads.max(1).min(n.max(1)) };
    if threads == 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, slot_chunk) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                mark_pool_worker();
                let base = t * chunk;
                for (k, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(base + k));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map slot filled")).collect()
}

/// Parallel for over mutable chunks of a slice: `f(chunk_index, chunk)`.
///
/// At most `threads` workers are spawned; chunks are partitioned into
/// contiguous groups, one group per worker. (The previous implementation
/// spawned one thread *per chunk* — `data.len() / chunk` threads — which
/// oversubscribed badly on large slices.)
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    let threads = if in_pool_worker() { 1 } else { threads.max(1) };
    let nchunks = data.len().div_ceil(chunk);
    if threads == 1 || nchunks <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let workers = threads.min(nchunks);
    let per_worker = nchunks.div_ceil(workers);
    std::thread::scope(|scope| {
        for (w, group) in data.chunks_mut(chunk * per_worker).enumerate() {
            let f = &f;
            scope.spawn(move || {
                mark_pool_worker();
                for (k, c) in group.chunks_mut(chunk).enumerate() {
                    f(w * per_worker + k, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let serial: Vec<u64> = (0..257).map(|i| (i * i) as u64).collect();
        let par = par_map(257, 8, |i| (i * i) as u64);
        assert_eq!(serial, par);
    }

    #[test]
    fn par_map_single_item() {
        assert_eq!(par_map(1, 8, |i| i + 1), vec![1]);
        assert_eq!(par_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_chunks_mut_writes_all() {
        let mut v = vec![0usize; 100];
        par_chunks_mut(&mut v, 7, 8, |i, c| {
            for x in c.iter_mut() {
                *x = i + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
    }

    #[test]
    fn par_chunks_mut_indices_match_serial() {
        // Chunk indices must be the global chunk numbers regardless of how
        // chunks are grouped onto workers.
        for threads in [1usize, 2, 3, 8, 64] {
            let mut v = vec![0usize; 103];
            par_chunks_mut(&mut v, 10, threads, |i, c| {
                for x in c.iter_mut() {
                    *x = i;
                }
            });
            for (pos, &x) in v.iter().enumerate() {
                assert_eq!(x, pos / 10, "threads={threads} pos={pos}");
            }
        }
    }

    #[test]
    fn par_chunks_mut_caps_spawned_workers() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        // 50 chunks but only 4 threads allowed: at most 4 distinct workers.
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        let mut v = vec![0u8; 500];
        par_chunks_mut(&mut v, 10, 4, |_, _| {
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() <= 4, "spawned {}", ids.lock().unwrap().len());
    }
}
