//! Minimal scoped-thread parallelism (the offline registry has no rayon or
//! tokio). Probe-level and experiment-level fan-out only needs a parallel
//! indexed map with static partitioning, which `std::thread::scope` gives us
//! safely.

/// Number of worker threads to use (capped so tests stay polite).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Parallel indexed map: computes `f(i)` for `i in 0..n`, preserving order.
///
/// Falls back to a sequential loop when `n` is small or one thread is
/// requested — the closure must be `Sync` (called from many threads) and the
/// result `Send`.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, slot_chunk) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = t * chunk;
                for (k, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(base + k));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map slot filled")).collect()
}

/// Parallel for over mutable chunks of a slice: `f(chunk_index, chunk)`.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || data.len() <= chunk {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    std::thread::scope(|scope| {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(i, c));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let serial: Vec<u64> = (0..257).map(|i| (i * i) as u64).collect();
        let par = par_map(257, 8, |i| (i * i) as u64);
        assert_eq!(serial, par);
    }

    #[test]
    fn par_map_single_item() {
        assert_eq!(par_map(1, 8, |i| i + 1), vec![1]);
        assert_eq!(par_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_chunks_mut_writes_all() {
        let mut v = vec![0usize; 100];
        par_chunks_mut(&mut v, 7, 8, |i, c| {
            for x in c.iter_mut() {
                *x = i + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
    }
}
