//! Minimal scoped-thread parallelism (the offline registry has no rayon or
//! tokio). Probe-level, RHS-group, and experiment-level fan-out only needs
//! a parallel indexed map with static partitioning, which
//! `std::thread::scope` gives us safely.
//!
//! # The RHS-group / probe-block worker contract
//!
//! The *callers* own the pool: the solvers (`solvers::block::cg_block` /
//! `pcg_block`) spawn one worker per `BlockPartition` right-hand-side
//! group, and the estimators' probe drivers (SLQ, Chebyshev, the Hessian
//! probe solves) fan their probe blocks across the same [`par_map`]
//! machinery. Workers never share solver state: each group carries its own
//! lockstep/deflation/true-residual (solvers) or Lanczos/Chebyshev
//! recurrence (estimators) state, and per-column arithmetic is untouched
//! by the fan-out — so results are **bit-identical for every thread
//! count** (the groups are data-independent; only wall-clock changes).
//! Cross-group reductions (per-column infos, `block_applies` sums,
//! per-probe value vectors) are indexed by global column/probe position,
//! so the reduction order is also thread-count independent.
//!
//! Nesting guard (thread *budget*): the solvers/estimators fan out over
//! groups while the operators fan out inside a block apply; without a
//! guard that multiplies into `threads^2` OS threads. Each worker spawned
//! here inherits its share of the requested thread count
//! (`requested / workers`, remainder to the first workers, at least 1),
//! and any nested `par_map` /
//! `par_chunks_mut` / [`default_threads`] call from inside a worker is
//! capped by that budget — so total concurrency never exceeds what the
//! outermost caller asked for, while leftover threads still flow down
//! when there are fewer groups than threads (e.g. 2 RHS groups on a
//! 16-thread request leave each group an 8-thread budget for its blocked
//! applies, instead of serializing them). With as many workers as
//! threads the budget is 1 and nested calls run serially, which is the
//! classic guard.
//!
//! # Work stealing ([`par_map_steal`])
//!
//! Static partitioning strands workers when per-item cost is ragged: a
//! worker whose RHS groups all converge in a handful of CG iterations
//! idles while another grinds through the hard groups it was dealt.
//! [`par_map_steal`] replaces the static chunk assignment with a shared
//! atomic index queue — every worker pulls the next unclaimed item when
//! its current one finishes, so raggedness costs at most one item of
//! imbalance. The bit-identity contract is unchanged **for every steal
//! order**: items are data-independent, each `f(i)` computes exactly what
//! it would under static partitioning, and results land in an
//! index-addressed slot — which worker ran item `i`, and in what order,
//! is unobservable in the output. Budgets compose exactly as in
//! [`par_map`]: `requested / workers`, remainder to the first workers.
//!
//! The process-wide default worker count is settable
//! ([`set_default_threads`], CLI `--threads`); 0 (the initial state) means
//! "auto": `available_parallelism`, capped at 16.
//!
//! # Span stitching ([`util::obs`](crate::util::obs))
//!
//! Every spawn site here captures an [`obs::stitch_handle`] on the
//! spawning thread and [`obs::adopt`]s it inside the worker, right next
//! to the `WORKER_BUDGET` setup — so spans opened inside a worker (and
//! counter increments outside any worker-local span) attach to the span
//! that was live when the fan-out was requested, for every steal order.
//! Stitching only routes *observations*; it never touches the data flow,
//! so the bit-identity contract above is unaffected.

use crate::util::obs;
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

thread_local! {
    /// `None` off-pool; `Some(b)` on a pool worker with a nested-fan-out
    /// budget of `b` threads.
    static WORKER_BUDGET: Cell<Option<usize>> = const { Cell::new(None) };
}

/// True on a thread spawned by this module (or marked by a worker pool):
/// nested fan-out is capped by the worker's thread budget.
pub fn in_pool_worker() -> bool {
    WORKER_BUDGET.with(|c| c.get().is_some())
}

/// Mark the current thread as a pool worker with a serial (budget 1)
/// nested fan-out — used by the batch service's own worker pool so
/// estimator calls inside it don't nest-fan-out.
pub fn mark_pool_worker() {
    set_worker_budget(1);
}

/// Mark the current thread as a pool worker with the given nested budget.
fn set_worker_budget(budget: usize) {
    WORKER_BUDGET.with(|c| c.set(Some(budget.max(1))));
}

/// Hard ceiling on workers spawned by any single fan-out. Every spawn
/// path funnels through [`effective_threads`], so an absurd request
/// (`--threads 100000`, or a huge `CgOptions::threads`) degrades to this
/// cap instead of attempting one scoped OS thread per row/group.
pub const MAX_THREADS: usize = 256;

/// Clamp a requested thread count by the enclosing worker's budget (the
/// request itself off-pool) and by [`MAX_THREADS`]; always >= 1.
fn effective_threads(threads: usize) -> usize {
    let t = threads.clamp(1, MAX_THREADS);
    WORKER_BUDGET.with(|c| c.get()).map_or(t, |b| b.max(1).min(t))
}

/// Process-wide default worker count; 0 = auto-detect. The coordinator
/// CLI's `--threads` flag threads through [`set_default_threads`].
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Serializes the tests that mutate the process-wide thread default (this
/// module's and the CLI flag's) — they assert on the value they just set,
/// so concurrent test threads must not interleave between set and read.
#[cfg(test)]
pub(crate) static TEST_DEFAULT_THREADS_LOCK: std::sync::Mutex<()> =
    std::sync::Mutex::new(());

/// Set the process-wide default worker count used by [`default_threads`]
/// (and therefore by `CgOptions::default`, `SlqOptions::default`,
/// `ChebOptions::default`, ...). 0 restores auto-detection.
pub fn set_default_threads(t: usize) {
    DEFAULT_THREADS.store(t, Ordering::Relaxed);
}

/// The raw process-wide default (0 = auto) — lets benches save and
/// restore the setting around a controlled thread sweep.
pub fn raw_default_threads() -> usize {
    DEFAULT_THREADS.load(Ordering::Relaxed)
}

/// Run `f` with the process-wide default pinned to `t`, restoring the
/// previous raw setting afterwards — on panic too (drop guard). The bench
/// thread sweeps use this so a row's `threads` means the total worker
/// budget; results are thread-invariant, so pinning only affects timing.
pub fn with_default_threads<R>(t: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            set_default_threads(self.0);
        }
    }
    let _restore = Restore(raw_default_threads());
    set_default_threads(t);
    f()
}

/// Number of worker threads to use: the process default when one was set
/// (capped at [`MAX_THREADS`]), otherwise `available_parallelism` capped
/// at 16 so tests stay polite. Inside a pool worker this is the worker's
/// nested budget (1 when the pool above used every requested thread),
/// preventing oversubscription.
pub fn default_threads() -> usize {
    if let Some(b) = WORKER_BUDGET.with(|c| c.get()) {
        return b.max(1);
    }
    match DEFAULT_THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16),
        t => t.min(MAX_THREADS),
    }
}

/// Parallel indexed map: computes `f(i)` for `i in 0..n`, preserving order.
///
/// Falls back to a sequential loop when `n` is small or one thread is
/// requested (or allowed by the enclosing worker's budget) — the closure
/// must be `Sync` (called from many threads) and the result `Send`. Each
/// spawned worker carries a nested budget of its share of the requested
/// threads, so fan-out levels compose to at most the requested total.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let requested = effective_threads(threads);
    let fanout = requested.min(n.max(1));
    if fanout == 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(fanout);
    let workers = n.div_ceil(chunk);
    // Divide the requested threads over the workers, handing the
    // remainder to the first workers so none of the budget is stranded
    // (e.g. 8 threads over 3 workers -> budgets 3, 3, 2).
    let (base_budget, extra) = (requested / workers, requested % workers);
    let stitch = obs::stitch_handle();
    std::thread::scope(|scope| {
        for (t, slot_chunk) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            let budget = (base_budget + usize::from(t < extra)).max(1);
            scope.spawn(move || {
                set_worker_budget(budget);
                obs::adopt(stitch);
                let base = t * chunk;
                for (k, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(base + k));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map slot filled")).collect()
}

/// Work-stealing indexed map: computes `f(i)` for `i in 0..n`, preserving
/// order, with workers pulling items from a shared atomic queue instead
/// of a static partition.
///
/// Use this when per-item cost is ragged (e.g. RHS groups whose CG
/// convergence varies wildly): a worker that finishes early steals the
/// next unclaimed index instead of idling. Results are **bit-identical to
/// [`par_map`] and to the serial loop for every thread count and steal
/// order** — items are data-independent, each result lands in the slot of
/// its index, and no worker-local state leaks between items. Each worker
/// buffers its `(index, value)` results privately and the buffers are
/// merged after the scope joins, so the hot path takes no locks.
///
/// Worker budgets compose exactly as in [`par_map`]: the requested thread
/// count is divided over the spawned workers (`requested / workers`,
/// remainder to the first workers, at least 1), so nested fan-out from
/// inside `f` never oversubscribes the outermost request.
pub fn par_map_steal<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let requested = effective_threads(threads);
    let workers = requested.min(n.max(1));
    if workers == 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let (base_budget, extra) = (requested / workers, requested % workers);
    let stitch = obs::stitch_handle();
    let mut buffers: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let f = &f;
                let next = &next;
                let budget = (base_budget + usize::from(w < extra)).max(1);
                scope.spawn(move || {
                    set_worker_budget(budget);
                    obs::adopt(stitch);
                    let mut buf: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        buf.push((i, f(i)));
                    }
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("steal worker panicked")).collect()
    });
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for buf in buffers.drain(..) {
        for (i, v) in buf {
            debug_assert!(out[i].is_none(), "index {i} claimed twice");
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|o| o.expect("par_map_steal slot filled")).collect()
}

/// Parallel for over mutable chunks of a slice: `f(chunk_index, chunk)`.
///
/// At most `threads` workers are spawned; chunks are partitioned into
/// contiguous groups, one group per worker. (The previous implementation
/// spawned one thread *per chunk* — `data.len() / chunk` threads — which
/// oversubscribed badly on large slices.)
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    let requested = effective_threads(threads);
    let nchunks = data.len().div_ceil(chunk);
    if requested == 1 || nchunks <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let workers = requested.min(nchunks);
    let per_worker = nchunks.div_ceil(workers);
    let spawned = nchunks.div_ceil(per_worker);
    // Remainder threads go to the first workers (see par_map).
    let (base_budget, extra) = (requested / spawned, requested % spawned);
    let stitch = obs::stitch_handle();
    std::thread::scope(|scope| {
        for (w, group) in data.chunks_mut(chunk * per_worker).enumerate() {
            let f = &f;
            let budget = (base_budget + usize::from(w < extra)).max(1);
            scope.spawn(move || {
                set_worker_budget(budget);
                obs::adopt(stitch);
                for (k, c) in group.chunks_mut(chunk).enumerate() {
                    f(w * per_worker + k, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let serial: Vec<u64> = (0..257).map(|i| (i * i) as u64).collect();
        let par = par_map(257, 8, |i| (i * i) as u64);
        assert_eq!(serial, par);
    }

    #[test]
    fn par_map_single_item() {
        assert_eq!(par_map(1, 8, |i| i + 1), vec![1]);
        assert_eq!(par_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_map_steal_matches_serial() {
        let serial: Vec<u64> = (0..257).map(|i| (i * i) as u64).collect();
        for threads in [1usize, 2, 3, 8, 64] {
            assert_eq!(par_map_steal(257, threads, |i| (i * i) as u64), serial);
        }
        assert_eq!(par_map_steal(1, 8, |i| i + 1), vec![1]);
        assert_eq!(par_map_steal(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_map_steal_ragged_items_land_by_index() {
        // Items with wildly different costs: order of completion varies,
        // but every result must land in its own slot.
        for _ in 0..8 {
            let got = par_map_steal(40, 8, |i| {
                if i % 7 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                i * 3
            });
            assert_eq!(got, (0..40).map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn par_map_steal_budgets_match_par_map() {
        // Same budget composition as the static fan-out: 2 workers on an
        // 8-thread request inherit 4 threads each, remainder to the first.
        assert_eq!(par_map_steal(2, 8, |_| default_threads()), vec![4, 4]);
        // 3 workers on 8 threads get budgets {3, 3, 2}; which worker runs
        // which item depends on the steal order, so assert the range only.
        let budgets = par_map_steal(3, 8, |_| default_threads());
        assert!(budgets.iter().all(|&b| b == 2 || b == 3), "{budgets:?}");
        assert_eq!(par_map_steal(8, 8, |_| default_threads()), vec![1; 8]);
        // Workers are pool-marked, so nested fan-out stays budgeted.
        let nested = par_map_steal(4, 4, |_| par_map(3, 16, |_| in_pool_worker()));
        assert!(nested.iter().flatten().all(|&w| w));
    }

    #[test]
    fn par_map_steal_caps_spawned_workers() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        par_map_steal(50, 4, |i| {
            ids.lock().unwrap().insert(std::thread::current().id());
            i
        });
        assert!(ids.lock().unwrap().len() <= 4, "spawned {}", ids.lock().unwrap().len());
    }

    #[test]
    fn par_chunks_mut_writes_all() {
        let mut v = vec![0usize; 100];
        par_chunks_mut(&mut v, 7, 8, |i, c| {
            for x in c.iter_mut() {
                *x = i + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
    }

    #[test]
    fn par_chunks_mut_indices_match_serial() {
        // Chunk indices must be the global chunk numbers regardless of how
        // chunks are grouped onto workers.
        for threads in [1usize, 2, 3, 8, 64] {
            let mut v = vec![0usize; 103];
            par_chunks_mut(&mut v, 10, threads, |i, c| {
                for x in c.iter_mut() {
                    *x = i;
                }
            });
            for (pos, &x) in v.iter().enumerate() {
                assert_eq!(x, pos / 10, "threads={threads} pos={pos}");
            }
        }
    }

    #[test]
    fn leftover_threads_flow_down_to_workers() {
        // 2 workers on an 8-thread request: each inherits a 4-thread
        // nested budget; with as many workers as threads the budget is 1;
        // a remainder goes to the first workers so no thread is stranded.
        assert_eq!(par_map(2, 8, |_| default_threads()), vec![4, 4]);
        assert_eq!(par_map(8, 8, |_| default_threads()), vec![1; 8]);
        assert_eq!(par_map(3, 8, |_| default_threads()), vec![3, 3, 2]);
        // A budget-1 worker runs nested fan-out serially, still marked.
        let nested = par_map(4, 4, |_| par_map(3, 16, |_| in_pool_worker()));
        assert!(nested.iter().flatten().all(|&w| w));
        // mark_pool_worker (the service pool) keeps the serial semantics.
        std::thread::scope(|s| {
            s.spawn(|| {
                mark_pool_worker();
                assert!(in_pool_worker());
                assert_eq!(default_threads(), 1);
                assert_eq!(par_map(4, 8, |i| i), vec![0, 1, 2, 3]);
            });
        });
    }

    #[test]
    fn default_threads_honors_process_override() {
        // Other tests in this process read default_threads() concurrently,
        // but every consumer is bit-identical across thread counts, so a
        // transiently overridden default only changes their scheduling.
        // Pinning to the current raw value restores it on every exit path
        // (including assert panics).
        let _guard =
            TEST_DEFAULT_THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        with_default_threads(raw_default_threads(), || {
            set_default_threads(3);
            assert_eq!(default_threads(), 3);
            // Absurd requests degrade to the spawn ceiling instead of
            // attempting thousands of scoped OS threads.
            set_default_threads(100_000);
            assert_eq!(default_threads(), MAX_THREADS);
            set_default_threads(0);
            assert!(default_threads() >= 1);
        });
    }

    #[test]
    fn par_chunks_mut_caps_spawned_workers() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        // 50 chunks but only 4 threads allowed: at most 4 distinct workers.
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        let mut v = vec![0u8; 500];
        par_chunks_mut(&mut v, 10, 4, |_, _| {
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(ids.lock().unwrap().len() <= 4, "spawned {}", ids.lock().unwrap().len());
    }
}
