//! Criterion-style micro/meso benchmark harness (criterion itself is not in
//! the offline registry). Warmup, timed iterations, mean/std/min/median, and
//! aligned table reporting used by every `cargo bench` target.

use std::time::Instant;

use super::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub median_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
        )
    }
}

/// Human time formatting (s / ms / µs / ns).
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bench {
    /// Target wall time to spend measuring each case (seconds).
    pub budget_s: f64,
    /// Warmup iterations before measurement.
    pub warmup: usize,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { budget_s: 1.0, warmup: 1, max_iters: 50, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(budget_s: f64) -> Self {
        Bench { budget_s, ..Default::default() }
    }

    /// Single-iteration runner (for end-to-end experiment timing where one
    /// run is already seconds-to-minutes).
    pub fn one_shot() -> Self {
        Bench { budget_s: 0.0, warmup: 0, max_iters: 1, ..Default::default() }
    }

    /// Time `f`, which must return something observable so the optimizer
    /// cannot elide the work; the value is black-boxed.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.max_iters
            && (times.len() < 3 || start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: times.len(),
            mean_s: stats::mean(&times),
            std_s: stats::std_dev(&times),
            min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
            median_s: stats::median(&times),
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Print the column header used by `run` rows.
    pub fn header(title: &str) {
        println!("\n== {} ==", title);
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            "case", "iters", "mean", "std", "min"
        );
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Optimizer black box (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render a markdown-ish table with aligned columns from header + rows.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n### {}", title);
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate().take(ncol) {
            s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        s
    };
    println!("{}", line(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    println!("{}", line(&sep));
    for row in rows {
        println!("{}", line(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench { budget_s: 0.01, warmup: 1, max_iters: 5, results: vec![] };
        b.run("noop", || 1 + 1);
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters >= 3);
        assert!(b.results()[0].mean_s >= 0.0);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
