//! Deterministic, dependency-free random number generation.
//!
//! The offline crate registry carries no `rand`, so the stochastic trace
//! estimators get their probes from this xoshiro256** generator (public
//! domain construction by Blackman & Vigna), seeded through SplitMix64.
//! Everything downstream of an experiment seed is fully reproducible.

/// xoshiro256** PRNG with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller pair.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-thread / per-probe use).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (with pair caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Rademacher variate (+1 or -1 with equal probability).
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Poisson variate (Knuth for small mean, normal approx for large).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = mean + mean.sqrt() * self.gaussian();
            if v < 0.0 {
                0
            } else {
                v.round() as u64
            }
        }
    }

    /// Gamma variate, shape `k` > 0, scale 1 (Marsaglia–Tsang).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gaussian();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Negative-binomial variate with `r` failures and success prob derived
    /// from the mean (gamma–Poisson mixture).
    pub fn neg_binomial(&mut self, mean: f64, r: f64) -> u64 {
        let lambda = self.gamma(r) * mean / r;
        self.poisson(lambda)
    }

    /// Fill a slice with standard normals.
    pub fn fill_gaussian(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.gaussian();
        }
    }

    /// Fill a slice with Rademacher variates.
    pub fn fill_rademacher(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.rademacher();
        }
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            p.swap(i, j);
        }
        p
    }

    /// Latin-hypercube sample of `n` points in the unit box of dim `d`.
    /// Used for surrogate design points (paper §3.5).
    pub fn latin_hypercube(&mut self, n: usize, d: usize) -> Vec<Vec<f64>> {
        let mut pts = vec![vec![0.0; d]; n];
        for j in 0..d {
            let perm = self.permutation(n);
            for (i, &cell) in perm.iter().enumerate() {
                pts[i][j] = (cell as f64 + self.uniform()) / n as f64;
            }
        }
        pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 20_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn rademacher_is_pm_one() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.rademacher();
            assert!(v == 1.0 || v == -1.0);
            sum += v;
        }
        assert!(sum.abs() / 10_000.0 < 0.05);
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(5);
        for &lam in &[0.5, 4.0, 60.0] {
            let n = 20_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += r.poisson(lam) as f64;
            }
            let m = sum / n as f64;
            assert!((m - lam).abs() < 0.15 * lam.max(1.0), "lam={lam} m={m}");
        }
    }

    #[test]
    fn neg_binomial_mean() {
        let mut r = Rng::new(9);
        let (mean, disp) = (6.0, 3.0);
        let n = 30_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.neg_binomial(mean, disp) as f64;
        }
        assert!((sum / n as f64 - mean).abs() < 0.3);
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(1);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn lhs_stratified() {
        let mut r = Rng::new(2);
        let pts = r.latin_hypercube(10, 3);
        for j in 0..3 {
            let mut cells: Vec<usize> =
                pts.iter().map(|p| (p[j] * 10.0) as usize).collect();
            cells.sort_unstable();
            assert_eq!(cells, (0..10).collect::<Vec<_>>());
        }
    }
}
