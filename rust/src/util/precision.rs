//! Process-wide precision mode for the MVM hot paths.
//!
//! # The precision contract
//!
//! Every estimator in the paper reduces log-determinant and derivative
//! cost to fast MVMs, and those MVMs are bandwidth-bound: an f32 storage
//! panel halves the bytes the dense GEMM, CSR sweep, and FFT staging move
//! per apply. [`Precision`] selects between:
//!
//! * [`Precision::F64`] — every apply path is **bit-identical** to the
//!   historical f64-only code. This is not "approximately equal": the
//!   `F64` arm of every `apply_mat_prec` implementation calls the same
//!   `apply_mat` code that existed before the knob, so proptests pin the
//!   equality bitwise.
//! * [`Precision::F32F64`] — operator *storage* panels (the dense kernel
//!   matrix, CSR interpolation weights, FFT input/output staging) are
//!   read as f32 while every **accumulation stays f64**. Solver
//!   convergence is still only ever declared from the f64 true-residual
//!   confirmation (`solvers::block`), so `converged == true` keeps its
//!   f64 meaning under iterative refinement.
//!
//! The process default mirrors `--threads` / `--cg-block`: the CLI's
//! `--precision` flag calls [`set_default_precision`], and
//! `CgOptions::default` / `SlqOptions::default` / `ChebOptions::default`
//! read [`default_precision`].

use std::sync::atomic::{AtomicU8, Ordering};

/// Precision mode for blocked operator applies (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full f64 storage and arithmetic — bit-identical to the pre-knob
    /// code paths.
    F64,
    /// f32 storage panels with f64 accumulators; solves stay correct to
    /// f64 tolerance via iterative refinement.
    F32F64,
}

impl Precision {
    /// Parse the CLI spelling (`"f64"` / `"f32f64"`).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f64" => Some(Precision::F64),
            "f32f64" => Some(Precision::F32F64),
            _ => None,
        }
    }

    /// The CLI spelling (inverse of [`Precision::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32F64 => "f32f64",
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Process-wide default precision; 0 = F64 (the initial state), 1 = F32F64.
static DEFAULT_PRECISION: AtomicU8 = AtomicU8::new(0);

/// Set the process-wide default precision used by [`default_precision`]
/// (and therefore by `CgOptions::default`, `SlqOptions::default`,
/// `ChebOptions::default`). The CLI `--precision` flag threads through
/// here, mirroring `parallel::set_default_threads`.
pub fn set_default_precision(p: Precision) {
    let v = match p {
        Precision::F64 => 0,
        Precision::F32F64 => 1,
    };
    DEFAULT_PRECISION.store(v, Ordering::Relaxed);
}

/// The process-wide default precision (initially [`Precision::F64`], so
/// every path is bit-identical to the historical code until someone opts
/// into mixed precision).
pub fn default_precision() -> Precision {
    match DEFAULT_PRECISION.load(Ordering::Relaxed) {
        0 => Precision::F64,
        _ => Precision::F32F64,
    }
}

/// Run `f` with the process-wide default pinned to `p`, restoring the
/// previous setting afterwards — on panic too (drop guard). Benches use
/// this for controlled f64-vs-f32f64 sweeps, like
/// `parallel::with_default_threads`.
pub fn with_default_precision<R>(p: Precision, f: impl FnOnce() -> R) -> R {
    struct Restore(Precision);
    impl Drop for Restore {
        fn drop(&mut self) {
            set_default_precision(self.0);
        }
    }
    let _restore = Restore(default_precision());
    set_default_precision(p);
    f()
}

/// Serializes tests that mutate the process-wide precision default — they
/// assert on the value they just set, so concurrent test threads must not
/// interleave between set and read.
#[cfg(test)]
pub(crate) static TEST_DEFAULT_PRECISION_LOCK: std::sync::Mutex<()> =
    std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects_garbage() {
        assert_eq!(Precision::parse("f64"), Some(Precision::F64));
        assert_eq!(Precision::parse("f32f64"), Some(Precision::F32F64));
        assert_eq!(Precision::parse("f32"), None);
        assert_eq!(Precision::parse("mixed"), None);
        assert_eq!(Precision::parse(""), None);
        for p in [Precision::F64, Precision::F32F64] {
            assert_eq!(Precision::parse(p.name()), Some(p));
            assert_eq!(format!("{p}"), p.name());
        }
    }

    #[test]
    fn default_honors_process_override_and_restores() {
        let _guard =
            TEST_DEFAULT_PRECISION_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let before = default_precision();
        with_default_precision(Precision::F32F64, || {
            assert_eq!(default_precision(), Precision::F32F64);
            with_default_precision(Precision::F64, || {
                assert_eq!(default_precision(), Precision::F64);
            });
            assert_eq!(default_precision(), Precision::F32F64);
        });
        assert_eq!(default_precision(), before);
    }
}
