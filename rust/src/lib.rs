//! # gpsld — Scalable Log Determinants for GP Kernel Learning
//!
//! Reproduction of Dong, Eriksson, Nickisch, Bindel & Wilson (NIPS 2017):
//! stochastic Chebyshev, stochastic Lanczos quadrature, and RBF-surrogate
//! estimators of `log|K̃|` and its hyperparameter derivatives from fast
//! matrix-vector multiplies only, applied to scalable Gaussian-process
//! kernel learning over SKI/Toeplitz/Kronecker structure.
//!
//! See DESIGN.md for the three-layer (rust / JAX / Pallas) architecture and
//! EXPERIMENTS.md for paper-vs-measured results.
pub mod error;
pub mod util;
pub mod linalg;
pub mod solvers;
pub mod kernels;
pub mod operators;
pub mod grid;
pub mod estimators;
pub mod gp;
pub mod runtime;
pub mod data;
pub mod coordinator;
pub mod opt;

pub use error::{Error, Result};

/// Crate version.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
