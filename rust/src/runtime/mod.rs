//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` (L2 JAX graphs wrapping the L1 Pallas kernel)
//! and executes them on the XLA CPU client — Python never runs at serving
//! time.
//!
//! Artifacts are described by `artifacts/manifest.tsv`
//! (`name \t file \t graph \t kind \t in-shapes \t out-shapes`); compiled
//! executables are cached per name.

pub mod ops;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Error, Result};

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub graph: String,
    pub kind: String,
    pub in_shapes: Vec<Vec<usize>>,
    pub out_shapes: Vec<Vec<usize>>,
}

fn parse_shapes(field: &str) -> Result<Vec<Vec<usize>>> {
    field
        .split(';')
        .map(|s| {
            s.split('x')
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|e| Error::Artifact(format!("bad shape {s}: {e}")))
                })
                .collect()
        })
        .collect()
}

/// Parse `manifest.tsv`.
pub fn load_manifest(dir: &Path) -> Result<HashMap<String, ArtifactSpec>> {
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| Error::Artifact(format!("{}: {e} (run `make artifacts`)", path.display())))?;
    let mut out = HashMap::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 6 {
            return Err(Error::Artifact(format!("bad manifest line: {line}")));
        }
        out.insert(
            cols[0].to_string(),
            ArtifactSpec {
                name: cols[0].to_string(),
                file: cols[1].to_string(),
                graph: cols[2].to_string(),
                kind: cols[3].to_string(),
                in_shapes: parse_shapes(cols[4])?,
                out_shapes: parse_shapes(cols[5])?,
            },
        );
    }
    Ok(out)
}

/// Compiled-executable cache over the PJRT CPU client.
///
/// The PJRT CPU client is internally synchronized; we nevertheless serialize
/// executions per runtime through a mutex so the wrapper is trivially Sync.
///
/// Without the `pjrt` cargo feature (the `xla` crate must be vendored — it
/// is not in the offline registry), `new` always returns an error so every
/// caller takes its artifacts-unavailable fallback path.
pub struct PjrtRuntime {
    #[allow(dead_code)]
    dir: PathBuf,
    pub specs: HashMap<String, ArtifactSpec>,
    #[allow(dead_code)]
    inner: Mutex<Inner>,
}

#[cfg(feature = "pjrt")]
struct Inner {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(not(feature = "pjrt"))]
#[allow(dead_code)]
struct Inner {}

// SAFETY: all access to the client/executables goes through the mutex.
#[cfg(feature = "pjrt")]
unsafe impl Send for PjrtRuntime {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Open the artifact directory and create a CPU PJRT client.
    #[cfg(feature = "pjrt")]
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let specs = load_manifest(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { dir, specs, inner: Mutex::new(Inner { client, cache: HashMap::new() }) })
    }

    /// Without the `pjrt` feature there is no XLA client: always errors
    /// (with the feature-flag message, not a manifest I/O error — the
    /// missing feature is the thing to fix first).
    #[cfg(not(feature = "pjrt"))]
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = dir.as_ref();
        Err(Error::Artifact(
            "PJRT backend not compiled in (build with --features pjrt and a vendored xla crate)"
                .into(),
        ))
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.inner.lock().unwrap().client.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "pjrt-disabled".into()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name}")))
    }

    /// Execute artifact `name` on f32 inputs (flattened, row-major). Shapes
    /// are validated against the manifest. Returns flattened f32 outputs.
    #[cfg(not(feature = "pjrt"))]
    pub fn run_f32(&self, _name: &str, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        // Unreachable in practice: `new` errors without the feature.
        Err(Error::Artifact("PJRT backend not compiled in".into()))
    }

    /// Execute artifact `name` on f32 inputs (flattened, row-major). Shapes
    /// are validated against the manifest. Returns flattened f32 outputs.
    #[cfg(feature = "pjrt")]
    pub fn run_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let spec = self.spec(name)?.clone();
        if inputs.len() != spec.in_shapes.len() {
            return Err(Error::Artifact(format!(
                "{name}: expected {} inputs, got {}",
                spec.in_shapes.len(),
                inputs.len()
            )));
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&spec.in_shapes) {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                return Err(Error::Artifact(format!(
                    "{name}: input length {} != shape {:?}",
                    buf.len(),
                    shape
                )));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = if dims.len() == 1 {
                xla::Literal::vec1(buf)
            } else {
                xla::Literal::vec1(buf).reshape(&dims)?
            };
            lits.push(lit);
        }

        let mut inner = self.inner.lock().unwrap();
        if !inner.cache.contains_key(name) {
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner.client.compile(&comp)?;
            inner.cache.insert(name.to_string(), exe);
        }
        let exe = inner.cache.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        if parts.len() != spec.out_shapes.len() {
            return Err(Error::Artifact(format!(
                "{name}: expected {} outputs, got {}",
                spec.out_shapes.len(),
                parts.len()
            )));
        }
        let mut outs = Vec::with_capacity(parts.len());
        for p in parts {
            outs.push(p.to_vec::<f32>()?);
        }
        Ok(outs)
    }

    /// Names of loaded artifacts, sorted (for the CLI `artifacts` command).
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_shapes_roundtrip() {
        let s = parse_shapes("2048x2;2048x8;3").unwrap();
        assert_eq!(s, vec![vec![2048, 2], vec![2048, 8], vec![3]]);
        assert!(parse_shapes("2048xx2").is_err());
    }

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(load_manifest(Path::new("/nonexistent/dir")).is_err());
    }
}
