//! Operator adapters over the PJRT runtime: the L1 Pallas kernel-MVM
//! artifacts exposed as [`LinOp`]/[`KernelOp`] so every estimator can run
//! its iterations against the AOT-compiled hot path.

use std::sync::Arc;

use super::PjrtRuntime;
use crate::error::Result;
use crate::kernels::{IsoKernel, Shape};
use crate::linalg::dense::Mat;
use crate::operators::{DenseKernelOp, KernelOp, LinOp};

/// Dense kernel MVM backed by an AOT `mvm_<kind>_n<n>_d<d>_b<b>` artifact.
///
/// The artifact computes `(K(X,X) + σ² I) V` for a fixed-shape probe block;
/// single-vector applies are padded to the block width. Data are f32 on the
/// PJRT side (the estimator accumulations stay f64 in rust).
pub struct PjrtMvmOp {
    rt: Arc<PjrtRuntime>,
    artifact: String,
    /// Flattened f32 row-major X (n x d).
    x_flat: Vec<f32>,
    n: usize,
    d: usize,
    b: usize,
    /// Raw-space [ell, sf, sigma].
    hypers_raw: [f32; 3],
}

impl PjrtMvmOp {
    pub fn new(
        rt: Arc<PjrtRuntime>,
        artifact: &str,
        points: &[Vec<f64>],
        ell: f64,
        sf: f64,
        sigma: f64,
    ) -> Result<Self> {
        let spec = rt.spec(artifact)?.clone();
        if spec.graph != "mvm" {
            return Err(crate::error::Error::Artifact(format!(
                "{artifact} is a {} artifact, need mvm",
                spec.graph
            )));
        }
        let (n, d) = (spec.in_shapes[0][0], spec.in_shapes[0][1]);
        let b = spec.in_shapes[1][1];
        if points.len() != n || points[0].len() != d {
            return Err(crate::error::Error::Artifact(format!(
                "{artifact} expects X {n}x{d}, got {}x{}",
                points.len(),
                points[0].len()
            )));
        }
        let mut x_flat = Vec::with_capacity(n * d);
        for p in points {
            for &v in p {
                x_flat.push(v as f32);
            }
        }
        Ok(PjrtMvmOp {
            rt,
            artifact: artifact.to_string(),
            x_flat,
            n,
            d,
            b,
            hypers_raw: [ell as f32, sf as f32, sigma as f32],
        })
    }

    pub fn batch_width(&self) -> usize {
        self.b
    }

    pub fn set_raw_hypers(&mut self, ell: f64, sf: f64, sigma: f64) {
        self.hypers_raw = [ell as f32, sf as f32, sigma as f32];
    }

    /// Apply to a full (n x b) block in one artifact execution.
    pub fn apply_block(&self, block: &Mat) -> Result<Mat> {
        assert_eq!(block.rows, self.n);
        assert_eq!(block.cols, self.b);
        let v: Vec<f32> = block.data.iter().map(|&x| x as f32).collect();
        let outs = self.rt.run_f32(
            &self.artifact,
            &[self.x_flat.clone(), v, self.hypers_raw.to_vec()],
        )?;
        let mut out = Mat::zeros(self.n, self.b);
        for (o, v) in out.data.iter_mut().zip(&outs[0]) {
            *o = *v as f64;
        }
        Ok(out)
    }
}

impl LinOp for PjrtMvmOp {
    fn n(&self) -> usize {
        self.n
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        // Pad the single vector into the artifact's fixed probe block.
        let mut block = Mat::zeros(self.n, self.b);
        for i in 0..self.n {
            block[(i, 0)] = x[i];
        }
        let out = self.apply_block(&block).expect("pjrt mvm failed");
        for i in 0..self.n {
            y[i] = out[(i, 0)];
        }
    }
    fn obs_kind(&self) -> &'static str {
        "pjrt_mvm"
    }
    fn apply_mat(&self, x: &Mat) -> Mat {
        let _obs =
            crate::util::obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        // Chunk columns into artifact-width blocks.
        let mut out = Mat::zeros(x.rows, x.cols);
        let mut j0 = 0;
        while j0 < x.cols {
            let w = (x.cols - j0).min(self.b);
            let mut block = Mat::zeros(self.n, self.b);
            for j in 0..w {
                for i in 0..self.n {
                    block[(i, j)] = x[(i, j0 + j)];
                }
            }
            let res = self.apply_block(&block).expect("pjrt mvm failed");
            for j in 0..w {
                for i in 0..self.n {
                    out[(i, j0 + j)] = res[(i, j)];
                }
            }
            j0 += w;
        }
        out
    }
}

/// Hybrid kernel operator: **PJRT artifact for the hot MVM**, native dense
/// kernel for the (hyper-dependent) derivative MVMs. This is the
/// configuration used when benchmarking the AOT path inside the estimators:
/// iterations hit the Pallas-lowered graph, gradients stay exact.
pub struct HybridKernelOp {
    pub pjrt: PjrtMvmOp,
    pub native: DenseKernelOp,
}

impl HybridKernelOp {
    pub fn new(
        rt: Arc<PjrtRuntime>,
        artifact: &str,
        points: Vec<Vec<f64>>,
        ell: f64,
        sf: f64,
        sigma: f64,
    ) -> Result<Self> {
        let d = points[0].len();
        let pjrt = PjrtMvmOp::new(rt, artifact, &points, ell, sf, sigma)?;
        let native = DenseKernelOp::new(
            points,
            Box::new(IsoKernel::new(Shape::Rbf, d, ell, sf)),
            sigma,
        );
        Ok(HybridKernelOp { pjrt, native })
    }
}

impl LinOp for HybridKernelOp {
    fn n(&self) -> usize {
        self.pjrt.n()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.pjrt.apply(x, y);
    }
    fn obs_kind(&self) -> &'static str {
        "pjrt_hybrid"
    }
    fn apply_mat(&self, x: &Mat) -> Mat {
        let _obs =
            crate::util::obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        self.pjrt.apply_mat(x)
    }
}

impl KernelOp for HybridKernelOp {
    fn num_hypers(&self) -> usize {
        self.native.num_hypers()
    }
    fn hypers(&self) -> Vec<f64> {
        self.native.hypers()
    }
    fn set_hypers(&mut self, h: &[f64]) {
        self.native.set_hypers(h);
        self.pjrt
            .set_raw_hypers(h[0].exp(), h[1].exp(), h[2].exp());
    }
    fn hyper_names(&self) -> Vec<String> {
        self.native.hyper_names()
    }
    fn apply_grad(&self, i: usize, x: &[f64], y: &mut [f64]) {
        self.native.apply_grad(i, x, y);
    }
    fn apply_grad_all(&self, x: &[f64], ys: &mut [Vec<f64>]) {
        self.native.apply_grad_all(x, ys);
    }
    fn apply_grad_mat(&self, i: usize, x: &Mat) -> Mat {
        self.native.apply_grad_mat(i, x)
    }
    fn apply_grad_all_mat(&self, x: &Mat) -> Vec<Mat> {
        self.native.apply_grad_all_mat(x)
    }
    fn noise_var(&self) -> f64 {
        self.native.noise_var()
    }
}

/// Batched Lanczos via the `lanczos_*` artifact: probes in, tridiagonal
/// coefficients + solves out. Used by the accelerated SLQ path.
pub struct PjrtLanczos {
    rt: Arc<PjrtRuntime>,
    artifact: String,
    pub n: usize,
    pub p: usize,
    pub m: usize,
    x_flat: Vec<f32>,
}

/// Output of the Lanczos artifact (per probe column).
pub struct PjrtLanczosOut {
    /// (m, p) alpha coefficients.
    pub alphas: Mat,
    /// (m-1, p) beta coefficients.
    pub betas: Mat,
    /// (n, p) solve vectors g ≈ K̃^{-1} z.
    pub g: Mat,
    /// (p,) probe norms.
    pub znorm: Vec<f64>,
}

impl PjrtLanczos {
    pub fn new(rt: Arc<PjrtRuntime>, artifact: &str, points: &[Vec<f64>]) -> Result<Self> {
        let spec = rt.spec(artifact)?.clone();
        if spec.graph != "lanczos" {
            return Err(crate::error::Error::Artifact(format!(
                "{artifact} is a {} artifact, need lanczos",
                spec.graph
            )));
        }
        let (n, d) = (spec.in_shapes[0][0], spec.in_shapes[0][1]);
        let p = spec.in_shapes[1][1];
        let m = spec.out_shapes[0][0];
        if points.len() != n || points[0].len() != d {
            return Err(crate::error::Error::Artifact(format!(
                "{artifact} expects X {n}x{d}"
            )));
        }
        let mut x_flat = Vec::with_capacity(n * d);
        for pnt in points {
            for &v in pnt {
                x_flat.push(v as f32);
            }
        }
        Ok(PjrtLanczos { rt, artifact: artifact.to_string(), n, p, m, x_flat })
    }

    /// Run the whole m-step batched Lanczos in one execution.
    ///
    /// The solve vectors `g` are recombined HERE from the returned Krylov
    /// basis Q with an f64 Thomas solve of `T t = e1 ||z||` — the in-graph
    /// f32 backward-scan version loses too much accuracy after the HLO-text
    /// round trip, and the f64 finish costs only O(m n) flops.
    pub fn run(&self, z: &Mat, ell: f64, sf: f64, sigma: f64) -> Result<PjrtLanczosOut> {
        assert_eq!((z.rows, z.cols), (self.n, self.p));
        let zf: Vec<f32> = z.data.iter().map(|&v| v as f32).collect();
        let h = vec![ell as f32, sf as f32, sigma as f32];
        let outs = self.rt.run_f32(&self.artifact, &[self.x_flat.clone(), zf, h])?;
        let to_mat = |v: &[f32], r: usize, c: usize| {
            let mut m = Mat::zeros(r, c);
            for (o, x) in m.data.iter_mut().zip(v) {
                *o = *x as f64;
            }
            m
        };
        let alphas = to_mat(&outs[0], self.m, self.p);
        let betas = to_mat(&outs[1], self.m - 1, self.p);
        let znorm: Vec<f64> = outs[3].iter().map(|&v| v as f64).collect();
        // outs[4] is Q with shape (m, n, p), row-major.
        let qbuf = &outs[4];
        let mut g = Mat::zeros(self.n, self.p);
        for pcol in 0..self.p {
            let a: Vec<f64> = (0..self.m).map(|i| alphas[(i, pcol)]).collect();
            let b: Vec<f64> = (0..self.m - 1).map(|i| betas[(i, pcol)]).collect();
            let t = crate::estimators::lanczos::thomas_solve_e1(&a, &b, znorm[pcol]);
            for k in 0..self.m {
                let tk = t[k];
                if tk == 0.0 {
                    continue;
                }
                let base = k * self.n * self.p;
                for i in 0..self.n {
                    g[(i, pcol)] += tk * qbuf[base + i * self.p + pcol] as f64;
                }
            }
        }
        Ok(PjrtLanczosOut { alphas, betas, g, znorm })
    }

    /// SLQ log-determinant estimate from one artifact execution: finishes
    /// the Gauss quadrature on the returned tridiagonals in rust.
    pub fn slq_logdet(&self, z: &Mat, ell: f64, sf: f64, sigma: f64) -> Result<(f64, f64)> {
        let out = self.run(z, ell, sf, sigma)?;
        let mut per_probe = Vec::with_capacity(self.p);
        for pcol in 0..self.p {
            let alphas: Vec<f64> = (0..self.m).map(|i| out.alphas[(i, pcol)]).collect();
            // f32 Lanczos can produce tiny trailing betas; truncate at the
            // first (near-)breakdown to keep the quadrature stable.
            let mut betas: Vec<f64> = Vec::new();
            let mut steps = self.m;
            for i in 0..self.m - 1 {
                let b = out.betas[(i, pcol)];
                if b <= 1e-7 {
                    steps = i + 1;
                    break;
                }
                betas.push(b);
            }
            let quad = crate::linalg::tridiag::lanczos_quadrature(
                &alphas[..steps],
                &betas[..steps - 1],
                out.znorm[pcol] * out.znorm[pcol],
                |lam| lam.max(1e-12).ln(),
            )?;
            per_probe.push(quad);
        }
        Ok(crate::estimators::probes::combine(&per_probe))
    }
}
