//! Small MLP feature extractor for deep kernel learning (paper §5.5).
//!
//! DKL replaces the inputs of a base kernel with the outputs of a network:
//! `k_deep(x, z) = k_base(g_w(x), g_w(z))`. We implement a tanh MLP with
//! manual forward/backward; the GP layer supplies `dL/d(features)` (built
//! from stochastic estimators, see [`crate::gp::dkl`]) and this module
//! backpropagates it into the weights.

use crate::linalg::dense::Mat;
use crate::util::rng::Rng;

/// Fully-connected tanh network, linear output layer.
#[derive(Clone)]
pub struct Mlp {
    /// Per-layer weight matrices (out x in).
    pub weights: Vec<Mat>,
    /// Per-layer biases.
    pub biases: Vec<Vec<f64>>,
}

/// Cached activations from a forward pass, needed for backprop.
pub struct MlpTape {
    /// Layer inputs: inputs[0] is the batch input, inputs[l+1] the
    /// activation after layer l (post-nonlinearity except last layer).
    pub inputs: Vec<Mat>,
}

impl Mlp {
    /// Xavier-initialized MLP with layer sizes, e.g. `[128, 64, 16, 2]`.
    pub fn new(sizes: &[usize], rng: &mut Rng) -> Self {
        assert!(sizes.len() >= 2);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for w in sizes.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let scale = (2.0 / (fan_in + fan_out) as f64).sqrt();
            let mut m = Mat::zeros(fan_out, fan_in);
            for v in m.data.iter_mut() {
                *v = rng.gaussian() * scale;
            }
            weights.push(m);
            biases.push(vec![0.0; fan_out]);
        }
        Mlp { weights, biases }
    }

    pub fn num_layers(&self) -> usize {
        self.weights.len()
    }

    pub fn in_dim(&self) -> usize {
        self.weights[0].cols
    }

    pub fn out_dim(&self) -> usize {
        self.weights.last().unwrap().rows
    }

    /// Total number of scalar parameters.
    pub fn num_params(&self) -> usize {
        self.weights
            .iter()
            .map(|w| w.data.len())
            .sum::<usize>()
            + self.biases.iter().map(|b| b.len()).sum::<usize>()
    }

    /// Forward pass on a batch `x` (n x in_dim). Returns features and tape.
    pub fn forward(&self, x: &Mat) -> (Mat, MlpTape) {
        assert_eq!(x.cols, self.in_dim());
        let mut inputs = vec![x.clone()];
        let mut cur = x.clone();
        let last = self.num_layers() - 1;
        for (l, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            // cur (n x in) * w^T (in x out) + b
            let mut next = Mat::zeros(cur.rows, w.rows);
            for i in 0..cur.rows {
                let xi = cur.row(i);
                for o in 0..w.rows {
                    let wrow = w.row(o);
                    let mut s = b[o];
                    for j in 0..w.cols {
                        s += wrow[j] * xi[j];
                    }
                    next[(i, o)] = if l == last { s } else { s.tanh() };
                }
            }
            inputs.push(next.clone());
            cur = next;
        }
        (cur, MlpTape { inputs })
    }

    /// Backward pass: given `dL/d(output)` (n x out_dim), returns gradients
    /// with the same shapes as `(weights, biases)`.
    pub fn backward(&self, tape: &MlpTape, dout: &Mat) -> (Vec<Mat>, Vec<Vec<f64>>) {
        let last = self.num_layers() - 1;
        let mut dw: Vec<Mat> = self.weights.iter().map(|w| Mat::zeros(w.rows, w.cols)).collect();
        let mut db: Vec<Vec<f64>> = self.biases.iter().map(|b| vec![0.0; b.len()]).collect();
        let mut delta = dout.clone(); // dL/d(pre-activation of current layer)
        for l in (0..=last).rev() {
            let act_in = &tape.inputs[l]; // input to layer l (n x in)
            let act_out = &tape.inputs[l + 1]; // output of layer l (n x out)
            if l != last {
                // delta currently holds dL/d(activation); apply tanh'.
                for i in 0..delta.rows {
                    for o in 0..delta.cols {
                        let a = act_out[(i, o)];
                        delta[(i, o)] *= 1.0 - a * a;
                    }
                }
            }
            // dW = delta^T * act_in ; db = column sums of delta.
            let w = &self.weights[l];
            for i in 0..delta.rows {
                let drow = delta.row(i);
                let xrow = act_in.row(i);
                for o in 0..w.rows {
                    let d = drow[o];
                    if d == 0.0 {
                        continue;
                    }
                    db[l][o] += d;
                    let wrow = dw[l].row_mut(o);
                    for j in 0..w.cols {
                        wrow[j] += d * xrow[j];
                    }
                }
            }
            if l > 0 {
                // Propagate: d(act_in) = delta * W
                let mut dprev = Mat::zeros(delta.rows, w.cols);
                for i in 0..delta.rows {
                    let drow = delta.row(i);
                    for o in 0..w.rows {
                        let d = drow[o];
                        if d == 0.0 {
                            continue;
                        }
                        let wrow = w.row(o);
                        let prow = dprev.row_mut(i);
                        for j in 0..w.cols {
                            prow[j] += d * wrow[j];
                        }
                    }
                }
                delta = dprev;
            }
        }
        (dw, db)
    }

    /// Flatten parameters into a vector (for generic optimizers).
    pub fn params(&self) -> Vec<f64> {
        let mut p = Vec::with_capacity(self.num_params());
        for w in &self.weights {
            p.extend_from_slice(&w.data);
        }
        for b in &self.biases {
            p.extend_from_slice(b);
        }
        p
    }

    pub fn set_params(&mut self, p: &[f64]) {
        let mut off = 0;
        for w in self.weights.iter_mut() {
            let len = w.data.len();
            w.data.copy_from_slice(&p[off..off + len]);
            off += len;
        }
        for b in self.biases.iter_mut() {
            let len = b.len();
            b.copy_from_slice(&p[off..off + len]);
            off += len;
        }
        assert_eq!(off, p.len());
    }

    /// Flatten gradients in the same layout as [`params`].
    pub fn flatten_grads(&self, dw: &[Mat], db: &[Vec<f64>]) -> Vec<f64> {
        let mut g = Vec::with_capacity(self.num_params());
        for w in dw {
            g.extend_from_slice(&w.data);
        }
        for b in db {
            g.extend_from_slice(b);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loss(mlp: &Mlp, x: &Mat, t: &Mat) -> f64 {
        // 0.5 * || f(x) - t ||^2
        let (y, _) = mlp.forward(x);
        let mut s = 0.0;
        for i in 0..y.rows {
            for j in 0..y.cols {
                let d = y[(i, j)] - t[(i, j)];
                s += 0.5 * d * d;
            }
        }
        s
    }

    #[test]
    fn backprop_matches_finite_differences() {
        let mut rng = Rng::new(3);
        let mlp = Mlp::new(&[4, 5, 2], &mut rng);
        let x = Mat::from_fn(6, 4, |i, j| ((i + j) as f64 * 0.37).sin());
        let t = Mat::from_fn(6, 2, |i, j| ((i * 2 + j) as f64 * 0.21).cos());

        let (y, tape) = mlp.forward(&x);
        let mut dout = Mat::zeros(6, 2);
        for i in 0..6 {
            for j in 0..2 {
                dout[(i, j)] = y[(i, j)] - t[(i, j)];
            }
        }
        let (dw, db) = mlp.backward(&tape, &dout);
        let g = mlp.flatten_grads(&dw, &db);

        let p0 = mlp.params();
        let eps = 1e-6;
        for idx in [0usize, 3, 10, p0.len() - 1, p0.len() / 2] {
            let mut m = mlp.clone();
            let mut p = p0.clone();
            p[idx] += eps;
            m.set_params(&p);
            let up = loss(&m, &x, &t);
            p[idx] -= 2.0 * eps;
            m.set_params(&p);
            let dn = loss(&m, &x, &t);
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (g[idx] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "param {idx}: {} vs {}",
                g[idx],
                fd
            );
        }
    }

    #[test]
    fn params_roundtrip() {
        let mut rng = Rng::new(9);
        let mut mlp = Mlp::new(&[3, 4, 2], &mut rng);
        let p = mlp.params();
        assert_eq!(p.len(), mlp.num_params());
        let mut p2 = p.clone();
        p2[0] = 42.0;
        mlp.set_params(&p2);
        assert_eq!(mlp.params()[0], 42.0);
    }

    #[test]
    fn output_shape() {
        let mut rng = Rng::new(1);
        let mlp = Mlp::new(&[8, 6, 3], &mut rng);
        let x = Mat::zeros(5, 8);
        let (y, _) = mlp.forward(&x);
        assert_eq!((y.rows, y.cols), (5, 3));
    }
}
