//! Spectral mixture kernel (Wilson & Adams 2013), used in the crime
//! experiment's temporal dimension (paper §5.4: 20 components plus a
//! constant component).
//!
//! 1-D form: k(tau) = sum_q w_q exp(-2 pi^2 tau^2 v_q) cos(2 pi mu_q tau)
//! (+ optional constant w_0). All hypers are learned in log space:
//! `[log_w_1.., log_v_1.., log_mu_1.., (log_w0)]`.

use super::Kernel;
use std::f64::consts::PI;

#[derive(Clone, Debug)]
pub struct SpectralMixtureKernel {
    pub q: usize,
    pub log_w: Vec<f64>,
    pub log_v: Vec<f64>,
    pub log_mu: Vec<f64>,
    /// Optional constant component weight (paper's "extra constant
    /// component" in §5.4); `None` disables it.
    pub log_w0: Option<f64>,
}

impl SpectralMixtureKernel {
    /// Initialize `q` components spread over frequencies `[f_lo, f_hi]`
    /// with equal weights summing to `total_power`.
    pub fn new(q: usize, f_lo: f64, f_hi: f64, total_power: f64, constant: bool) -> Self {
        let w = (total_power / q as f64).max(1e-12);
        let log_w = vec![w.ln(); q];
        let log_v = vec![(0.1 * (f_hi - f_lo)).powi(2).max(1e-12).ln(); q];
        let log_mu = (0..q)
            .map(|i| {
                let f = f_lo + (f_hi - f_lo) * (i as f64 + 0.5) / q as f64;
                f.max(1e-8).ln()
            })
            .collect();
        SpectralMixtureKernel {
            q,
            log_w,
            log_v,
            log_mu,
            log_w0: if constant { Some((0.1 * total_power).max(1e-12).ln()) } else { None },
        }
    }

    #[inline]
    fn comp(&self, i: usize, tau: f64) -> (f64, f64, f64) {
        // Returns (value, d/dlog_v, d/dlog_mu) for component i at lag tau.
        let w = self.log_w[i].exp();
        let v = self.log_v[i].exp();
        let mu = self.log_mu[i].exp();
        let e = (-2.0 * PI * PI * tau * tau * v).exp();
        let c = (2.0 * PI * mu * tau).cos();
        let s = (2.0 * PI * mu * tau).sin();
        let val = w * e * c;
        let dv = -2.0 * PI * PI * tau * tau * v * val; // chain: * v for log
        let dmu = -w * e * s * 2.0 * PI * tau * mu;
        (val, dv, dmu)
    }
}

impl Kernel for SpectralMixtureKernel {
    fn dim(&self) -> usize {
        1
    }
    fn num_hypers(&self) -> usize {
        3 * self.q + usize::from(self.log_w0.is_some())
    }
    fn hypers(&self) -> Vec<f64> {
        let mut h = Vec::with_capacity(self.num_hypers());
        h.extend_from_slice(&self.log_w);
        h.extend_from_slice(&self.log_v);
        h.extend_from_slice(&self.log_mu);
        if let Some(w0) = self.log_w0 {
            h.push(w0);
        }
        h
    }
    fn set_hypers(&mut self, h: &[f64]) {
        assert_eq!(h.len(), self.num_hypers());
        let q = self.q;
        self.log_w.copy_from_slice(&h[..q]);
        self.log_v.copy_from_slice(&h[q..2 * q]);
        self.log_mu.copy_from_slice(&h[2 * q..3 * q]);
        if self.log_w0.is_some() {
            self.log_w0 = Some(h[3 * q]);
        }
    }
    fn hyper_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for i in 0..self.q {
            names.push(format!("log_w{i}"));
        }
        for i in 0..self.q {
            names.push(format!("log_v{i}"));
        }
        for i in 0..self.q {
            names.push(format!("log_mu{i}"));
        }
        if self.log_w0.is_some() {
            names.push("log_w0".into());
        }
        names
    }
    fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        let tau = x[0] - z[0];
        let mut v: f64 = (0..self.q).map(|i| self.comp(i, tau).0).sum();
        if let Some(w0) = self.log_w0 {
            v += w0.exp();
        }
        v
    }
    fn grad(&self, x: &[f64], z: &[f64], out: &mut [f64]) {
        let tau = x[0] - z[0];
        let q = self.q;
        for i in 0..q {
            let (val, dv, dmu) = self.comp(i, tau);
            out[i] = val; // d/dlog_w = w * e * c = val
            out[q + i] = dv;
            out[2 * q + i] = dmu;
        }
        if let Some(w0) = self.log_w0 {
            out[3 * q] = w0.exp();
        }
    }
    fn clone_box(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::fd_grad;

    #[test]
    fn value_at_zero_is_total_weight() {
        let k = SpectralMixtureKernel::new(4, 0.01, 0.5, 2.0, true);
        let v = k.eval(&[3.0], &[3.0]);
        let want: f64 = k.log_w.iter().map(|w| w.exp()).sum::<f64>()
            + k.log_w0.unwrap().exp();
        assert!((v - want).abs() < 1e-12);
    }

    #[test]
    fn symmetric_in_lag() {
        let k = SpectralMixtureKernel::new(3, 0.05, 0.4, 1.0, false);
        assert!((k.eval(&[1.0], &[2.3]) - k.eval(&[2.3], &[1.0])).abs() < 1e-14);
    }

    #[test]
    fn grad_matches_fd() {
        let k = SpectralMixtureKernel::new(3, 0.05, 0.4, 1.5, true);
        let mut g = vec![0.0; k.num_hypers()];
        k.grad(&[0.7], &[0.1], &mut g);
        let fd = fd_grad(&k, &[0.7], &[0.1], 1e-6);
        for i in 0..g.len() {
            assert!(
                (g[i] - fd[i]).abs() < 1e-5 * (1.0 + fd[i].abs()),
                "hyper {i}: {} vs {}",
                g[i],
                fd[i]
            );
        }
    }

    #[test]
    fn oscillates_with_frequency() {
        // A single high-frequency component must go negative at half period.
        let mut k = SpectralMixtureKernel::new(1, 1.0, 1.0, 1.0, false);
        k.log_v = vec![(1e-6f64).ln()]; // nearly pure cosine
        let half_period = 0.5; // mu = 1 -> cos(2 pi * 0.5) = -1
        assert!(k.eval(&[0.0], &[half_period]) < 0.0);
    }

    #[test]
    fn hyper_roundtrip() {
        let mut k = SpectralMixtureKernel::new(2, 0.1, 0.3, 1.0, true);
        let mut h = k.hypers();
        assert_eq!(h.len(), 7);
        h[3] = -2.0;
        k.set_hypers(&h);
        assert_eq!(k.hypers()[3], -2.0);
    }
}
