//! Covariance kernels with analytic derivatives w.r.t. **log**
//! hyperparameters (the optimization is done in log space, which keeps
//! positivity constraints implicit — standard GPML practice).
//!
//! The paper's experiments use RBF, the Matérn family, and spectral mixture
//! kernels (plus deep kernels, built in [`crate::gp::dkl`] as an MLP feature
//! map feeding an RBF). SKI's Kronecker algebra additionally needs
//! *separable* (per-dimension product) kernels, provided by
//! [`SeparableKernel`].

pub mod deep;
pub mod spectral;

pub use spectral::SpectralMixtureKernel;

/// Radial profile shared by the isotropic kernels (unit amplitude).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    Rbf,
    Matern12,
    Matern32,
    Matern52,
}

impl Shape {
    pub fn name(&self) -> &'static str {
        match self {
            Shape::Rbf => "rbf",
            Shape::Matern12 => "mat12",
            Shape::Matern32 => "mat32",
            Shape::Matern52 => "mat52",
        }
    }

    /// Unit-amplitude kernel value at distance `r` with lengthscale `ell`.
    #[inline]
    pub fn k(&self, r: f64, ell: f64) -> f64 {
        match self {
            Shape::Rbf => (-0.5 * (r / ell) * (r / ell)).exp(),
            Shape::Matern12 => (-r / ell).exp(),
            Shape::Matern32 => {
                let a = 3f64.sqrt() * r / ell;
                (1.0 + a) * (-a).exp()
            }
            Shape::Matern52 => {
                let a = 5f64.sqrt() * r / ell;
                (1.0 + a + a * a / 3.0) * (-a).exp()
            }
        }
    }

    /// d k / d log(ell) at distance r.
    #[inline]
    pub fn dk_dlog_ell(&self, r: f64, ell: f64) -> f64 {
        match self {
            Shape::Rbf => {
                let s = (r / ell) * (r / ell);
                (-0.5 * s).exp() * s
            }
            Shape::Matern12 => {
                let a = r / ell;
                (-a).exp() * a
            }
            Shape::Matern32 => {
                let a = 3f64.sqrt() * r / ell;
                a * a * (-a).exp()
            }
            Shape::Matern52 => {
                let a = 5f64.sqrt() * r / ell;
                (a * a / 3.0) * (1.0 + a) * (-a).exp()
            }
        }
    }
}

/// A covariance kernel with analytic log-hyperparameter gradients.
pub trait Kernel: Send + Sync {
    /// Input dimensionality.
    fn dim(&self) -> usize;
    /// Number of hyperparameters (all log-space).
    fn num_hypers(&self) -> usize;
    /// Current hyperparameters (log-space).
    fn hypers(&self) -> Vec<f64>;
    /// Set hyperparameters (log-space).
    fn set_hypers(&mut self, h: &[f64]);
    /// Human-readable hyper names, for experiment tables.
    fn hyper_names(&self) -> Vec<String>;
    /// k(x, z).
    fn eval(&self, x: &[f64], z: &[f64]) -> f64;
    /// out[i] = d k(x, z) / d hyper_i.
    fn grad(&self, x: &[f64], z: &[f64], out: &mut [f64]);
    fn clone_box(&self) -> Box<dyn Kernel>;
}

impl Clone for Box<dyn Kernel> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

#[inline]
pub fn dist(x: &[f64], z: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), z.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        let d = x[i] - z[i];
        s += d * d;
    }
    s.sqrt()
}

/// Isotropic kernel: `sf^2 * shape(||x - z|| / ell)`.
/// Hypers: `[log_ell, log_sf]`.
#[derive(Clone, Debug)]
pub struct IsoKernel {
    pub shape: Shape,
    pub input_dim: usize,
    pub log_ell: f64,
    pub log_sf: f64,
}

impl IsoKernel {
    pub fn new(shape: Shape, input_dim: usize, ell: f64, sf: f64) -> Self {
        IsoKernel { shape, input_dim, log_ell: ell.ln(), log_sf: sf.ln() }
    }
}

impl Kernel for IsoKernel {
    fn dim(&self) -> usize {
        self.input_dim
    }
    fn num_hypers(&self) -> usize {
        2
    }
    fn hypers(&self) -> Vec<f64> {
        vec![self.log_ell, self.log_sf]
    }
    fn set_hypers(&mut self, h: &[f64]) {
        assert_eq!(h.len(), 2);
        self.log_ell = h[0];
        self.log_sf = h[1];
    }
    fn hyper_names(&self) -> Vec<String> {
        vec!["log_ell".into(), "log_sf".into()]
    }
    fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        let sf2 = (2.0 * self.log_sf).exp();
        sf2 * self.shape.k(dist(x, z), self.log_ell.exp())
    }
    fn grad(&self, x: &[f64], z: &[f64], out: &mut [f64]) {
        let sf2 = (2.0 * self.log_sf).exp();
        let r = dist(x, z);
        let ell = self.log_ell.exp();
        out[0] = sf2 * self.shape.dk_dlog_ell(r, ell);
        out[1] = 2.0 * sf2 * self.shape.k(r, ell);
    }
    fn clone_box(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// One-dimensional unit-amplitude kernel factor (for separable products).
/// Hypers: `[log_ell]`.
#[derive(Clone, Debug)]
pub struct Factor1d {
    pub shape: Shape,
    pub log_ell: f64,
}

impl Factor1d {
    pub fn new(shape: Shape, ell: f64) -> Self {
        Factor1d { shape, log_ell: ell.ln() }
    }
}

impl Kernel for Factor1d {
    fn dim(&self) -> usize {
        1
    }
    fn num_hypers(&self) -> usize {
        1
    }
    fn hypers(&self) -> Vec<f64> {
        vec![self.log_ell]
    }
    fn set_hypers(&mut self, h: &[f64]) {
        self.log_ell = h[0];
    }
    fn hyper_names(&self) -> Vec<String> {
        vec!["log_ell".into()]
    }
    fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        self.shape.k((x[0] - z[0]).abs(), self.log_ell.exp())
    }
    fn grad(&self, x: &[f64], z: &[f64], out: &mut [f64]) {
        out[0] = self
            .shape
            .dk_dlog_ell((x[0] - z[0]).abs(), self.log_ell.exp());
    }
    fn clone_box(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// Separable (per-dimension product) kernel with a global amplitude:
/// `k(x,z) = sf^2 * prod_j f_j(x_j, z_j)`.
///
/// This is the form SKI's Kronecker algebra requires on multi-dimensional
/// grids: `K_UU = sf^2 * T_1 (x) T_2 (x) ... (x) T_d` with each `T_j` a
/// symmetric Toeplitz matrix from the 1-D factor. Hypers: concatenation of
/// factor hypers, then `log_sf` last.
#[derive(Clone)]
pub struct SeparableKernel {
    pub factors: Vec<Box<dyn Kernel>>,
    pub log_sf: f64,
}

impl SeparableKernel {
    pub fn new(factors: Vec<Box<dyn Kernel>>, sf: f64) -> Self {
        for f in &factors {
            assert_eq!(f.dim(), 1, "separable factors must be 1-D");
        }
        SeparableKernel { factors, log_sf: sf.ln() }
    }

    /// Convenience: isotropic-like separable kernel (same shape every dim,
    /// one shared-initial-but-independent lengthscale per dim).
    pub fn iso(shape: Shape, dims: usize, ell: f64, sf: f64) -> Self {
        SeparableKernel::new(
            (0..dims)
                .map(|_| Box::new(Factor1d::new(shape, ell)) as Box<dyn Kernel>)
                .collect(),
            sf,
        )
    }

    /// Evaluate factor `j` on scalar inputs.
    pub fn factor_eval(&self, j: usize, a: f64, b: f64) -> f64 {
        self.factors[j].eval(&[a], &[b])
    }

    /// Index range of factor `j`'s hypers within `self.hypers()`.
    pub fn factor_hyper_range(&self, j: usize) -> std::ops::Range<usize> {
        let mut start = 0;
        for f in &self.factors[..j] {
            start += f.num_hypers();
        }
        start..start + self.factors[j].num_hypers()
    }

    pub fn sf2(&self) -> f64 {
        (2.0 * self.log_sf).exp()
    }
}

impl Kernel for SeparableKernel {
    fn dim(&self) -> usize {
        self.factors.len()
    }
    fn num_hypers(&self) -> usize {
        self.factors.iter().map(|f| f.num_hypers()).sum::<usize>() + 1
    }
    fn hypers(&self) -> Vec<f64> {
        let mut h: Vec<f64> = self.factors.iter().flat_map(|f| f.hypers()).collect();
        h.push(self.log_sf);
        h
    }
    fn set_hypers(&mut self, h: &[f64]) {
        assert_eq!(h.len(), self.num_hypers());
        let mut off = 0;
        for f in self.factors.iter_mut() {
            let k = f.num_hypers();
            f.set_hypers(&h[off..off + k]);
            off += k;
        }
        self.log_sf = h[off];
    }
    fn hyper_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for (j, f) in self.factors.iter().enumerate() {
            for n in f.hyper_names() {
                names.push(format!("dim{j}.{n}"));
            }
        }
        names.push("log_sf".into());
        names
    }
    fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        let mut v = self.sf2();
        for (j, f) in self.factors.iter().enumerate() {
            v *= f.eval(&x[j..=j], &z[j..=j]);
        }
        v
    }
    fn grad(&self, x: &[f64], z: &[f64], out: &mut [f64]) {
        let vals: Vec<f64> = self
            .factors
            .iter()
            .enumerate()
            .map(|(j, f)| f.eval(&x[j..=j], &z[j..=j]))
            .collect();
        let sf2 = self.sf2();
        let total: f64 = sf2 * vals.iter().product::<f64>();
        let mut off = 0;
        for (j, f) in self.factors.iter().enumerate() {
            let k = f.num_hypers();
            let mut g = vec![0.0; k];
            f.grad(&x[j..=j], &z[j..=j], &mut g);
            // Product rule: replace factor value by its gradient.
            let others: f64 = sf2
                * vals
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| *i != j)
                    .map(|(_, v)| v)
                    .product::<f64>();
            for (t, gv) in g.iter().enumerate() {
                out[off + t] = others * gv;
            }
            off += k;
        }
        out[off] = 2.0 * total; // d/d log_sf of sf^2 * (...)
    }
    fn clone_box(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// Central finite-difference gradient of any kernel (test utility and
/// fallback for kernels without analytic gradients).
pub fn fd_grad(k: &dyn Kernel, x: &[f64], z: &[f64], eps: f64) -> Vec<f64> {
    let h0 = k.hypers();
    let mut kc = k.clone_box();
    let mut g = vec![0.0; h0.len()];
    for i in 0..h0.len() {
        let mut hp = h0.clone();
        hp[i] += eps;
        kc.set_hypers(&hp);
        let up = kc.eval(x, z);
        hp[i] -= 2.0 * eps;
        kc.set_hypers(&hp);
        let dn = kc.eval(x, z);
        g[i] = (up - dn) / (2.0 * eps);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_grad(k: &dyn Kernel, x: &[f64], z: &[f64]) {
        let mut g = vec![0.0; k.num_hypers()];
        k.grad(x, z, &mut g);
        let fd = fd_grad(k, x, z, 1e-6);
        for i in 0..g.len() {
            assert!(
                (g[i] - fd[i]).abs() < 1e-5 * (1.0 + fd[i].abs()),
                "hyper {i}: analytic {} vs fd {}",
                g[i],
                fd[i]
            );
        }
    }

    #[test]
    fn iso_kernel_values() {
        let k = IsoKernel::new(Shape::Rbf, 2, 0.5, 2.0);
        // k(x,x) = sf^2
        assert!((k.eval(&[1.0, 2.0], &[1.0, 2.0]) - 4.0).abs() < 1e-12);
        // decreasing in distance
        let near = k.eval(&[0.0, 0.0], &[0.1, 0.0]);
        let far = k.eval(&[0.0, 0.0], &[1.0, 0.0]);
        assert!(near > far);
    }

    #[test]
    fn gradients_match_fd_all_shapes() {
        for shape in [Shape::Rbf, Shape::Matern12, Shape::Matern32, Shape::Matern52] {
            let k = IsoKernel::new(shape, 3, 0.7, 1.3);
            check_grad(&k, &[0.1, -0.4, 0.8], &[0.5, 0.2, -0.1]);
        }
    }

    #[test]
    fn separable_matches_iso_rbf() {
        // Product of 1-D RBFs with equal ell == d-dim isotropic RBF.
        let sep = SeparableKernel::iso(Shape::Rbf, 3, 0.6, 1.2);
        let iso = IsoKernel::new(Shape::Rbf, 3, 0.6, 1.2);
        let (x, z) = ([0.3, -0.2, 0.9], [-0.1, 0.4, 0.5]);
        assert!((sep.eval(&x, &z) - iso.eval(&x, &z)).abs() < 1e-12);
    }

    #[test]
    fn separable_grad_matches_fd() {
        let sep = SeparableKernel::new(
            vec![
                Box::new(Factor1d::new(Shape::Matern32, 0.4)),
                Box::new(Factor1d::new(Shape::Rbf, 0.9)),
            ],
            1.5,
        );
        check_grad(&sep, &[0.2, -0.7], &[-0.3, 0.1]);
    }

    #[test]
    fn matern_smoothness_ordering_at_midrange() {
        // At moderate r/ell, smoother kernels decay slower near 0 but all
        // must be in (0,1].
        for shape in [Shape::Rbf, Shape::Matern12, Shape::Matern32, Shape::Matern52] {
            let v = shape.k(0.5, 1.0);
            assert!(v > 0.0 && v <= 1.0, "{shape:?} -> {v}");
        }
        assert!(Shape::Rbf.k(0.1, 1.0) > Shape::Matern12.k(0.1, 1.0));
    }

    #[test]
    fn hyper_roundtrip() {
        let mut k = SeparableKernel::iso(Shape::Matern52, 2, 0.3, 2.0);
        let h = k.hypers();
        assert_eq!(h.len(), 3);
        let mut h2 = h.clone();
        h2[0] = 0.123;
        k.set_hypers(&h2);
        assert_eq!(k.hypers()[0], 0.123);
        assert_eq!(k.hyper_names().len(), 3);
    }
}
