//! Laplace approximation for GPs with non-Gaussian likelihoods — the
//! log-Gaussian Cox process models of §5.3 (Hickory, Poisson) and §5.4
//! (crime, negative binomial).
//!
//! Everything is MVM-only:
//!   * Newton mode finding uses the stable B-parameterization
//!     `B = I + W^{1/2} K W^{1/2}` with CG inner solves (GPML Alg. 3.1
//!     re-expressed over operators);
//!   * the Occam term `log|B|` is estimated by stochastic Lanczos
//!     quadrature — exactly the setting where the scaled-eigenvalue
//!     baseline needs the Fiedler-bound workaround (§5.3), because `B`
//!     has no exploitable eigenstructure.

use crate::error::Result;
use crate::estimators::slq::slq_trace_fn_ev;
use crate::estimators::ConfidenceInterval;
use crate::linalg::dense::Mat;
use crate::linalg::pchol::pivoted_cholesky;
use crate::operators::{KernelOp, LaplaceBOp};
use crate::solvers::{
    pcg_with_guess, CgOptions, PivCholPrecond, PreconditionedOp, Preconditioner,
};
use crate::util::stats::dot;

use super::likelihoods::Likelihood;

/// Options for the Laplace approximation.
#[derive(Clone, Copy, Debug)]
pub struct LaplaceOptions {
    pub newton_max_iters: usize,
    pub newton_tol: f64,
    /// Newton inner-solve settings (shared [`CgOptions`] struct).
    pub cg: CgOptions,
    /// SLQ settings for log|B|.
    pub slq_steps: usize,
    pub slq_probes: usize,
    pub seed: u64,
    /// Worker threads for the `log|B|` probe blocks (the Newton inner
    /// solves are single-RHS and stay scalar; the shared `cg.threads` knob
    /// applies wherever a multi-group block solve appears). Defaults to
    /// the process default (CLI `--threads`).
    pub threads: usize,
}

impl Default for LaplaceOptions {
    fn default() -> Self {
        LaplaceOptions {
            newton_max_iters: 50,
            newton_tol: 1e-6,
            cg: CgOptions { tol: 1e-8, max_iters: 500, ..Default::default() },
            slq_steps: 25,
            slq_probes: 6,
            seed: 0,
            threads: crate::util::parallel::default_threads(),
        }
    }
}

/// Result of a Laplace fit at fixed hypers.
#[derive(Clone, Debug)]
pub struct LaplaceFit {
    /// Posterior mode of the latent function.
    pub f_hat: Vec<f64>,
    /// a = K^{-1} f_hat (from the Newton recurrence, no explicit inverse).
    pub a: Vec<f64>,
    /// Approximate log marginal likelihood
    /// `log q(y|θ) = log p(y|f̂) − ½ a^T f̂ − ½ log|B|`.
    pub log_marginal: f64,
    /// SLQ standard error of the log|B| term.
    pub logdet_std_err: f64,
    /// 95% confidence interval on the log|B| term, synthesized from the
    /// retained Lanczos evidence (shifted by the exact `log|P_B|`
    /// correction when the fit ran preconditioned).
    pub logdet_interval: ConfidenceInterval,
    /// Probe vectors consumed by the log|B| estimate.
    pub logdet_probes_used: usize,
    pub newton_iters: usize,
}

/// GP with non-Gaussian likelihood via Laplace. The operator supplies the
/// *prior* covariance K (its σ² acts as jitter and should be small).
pub struct LaplaceGp<O: KernelOp> {
    pub op: O,
    pub y: Vec<f64>,
    pub lik: Likelihood,
    f_warm: Option<Vec<f64>>,
}

impl<O: KernelOp> LaplaceGp<O> {
    pub fn new(op: O, y: Vec<f64>, lik: Likelihood) -> Self {
        assert_eq!(op.n(), y.len());
        LaplaceGp { op, y, lik, f_warm: None }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn set_hypers(&mut self, h: &[f64]) {
        self.op.set_hypers(h);
    }

    /// Newton iteration for the posterior mode (warm-started across hyper
    /// steps). Returns the fit including the SLQ `log|B|`.
    ///
    /// With `opts.cg.precond.rank > 0`, one pivoted Cholesky `K ≈ L Lᵀ` of
    /// the prior covariance is reused across all Newton iterations: each
    /// iteration's `B = I + W^{1/2} K̃ W^{1/2}` is preconditioned by
    /// `P_B = I + (W^{1/2} L)(W^{1/2} L)ᵀ` (the row-scaled factor; the
    /// residual `σ² W` jitter term is dropped — preconditioners need not
    /// be exact), and the `log|B|` SLQ runs on the flattened split
    /// spectrum with the exact `log|P_B|` correction.
    pub fn fit(&mut self, opts: &LaplaceOptions) -> Result<LaplaceFit> {
        let n = self.n();
        let mut f = self.f_warm.clone().unwrap_or_else(|| vec![0.0; n]);
        let mut a = vec![0.0; n];
        let mut psi_old = f64::NEG_INFINITY;
        let mut iters = 0;
        let mut bsol_warm: Option<Vec<f64>> = None;
        // Factor the prior once per fit (hypers are fixed during a fit).
        let popts = opts.cg.precond;
        let k_factor = if popts.rank > 0 {
            let f = pivoted_cholesky(&self.op, popts.rank, popts.rel_tol).map(|p| p.l);
            if f.is_none() {
                eprintln!(
                    "laplace: operator does not expose diag(); Newton solves \
                     and log|B| run unpreconditioned"
                );
            }
            f
        } else {
            None
        };
        // P_B = I + (W^{1/2} L)(W^{1/2} L)ᵀ for the current weights.
        let precond_b = |l: &Mat, sqrt_w: &[f64]| -> PivCholPrecond {
            let mut scaled = l.clone();
            for i in 0..scaled.rows {
                let s = sqrt_w[i];
                for v in scaled.row_mut(i) {
                    *v *= s;
                }
            }
            PivCholPrecond::new(&scaled, 1.0)
        };
        for it in 0..opts.newton_max_iters {
            iters = it + 1;
            let w: Vec<f64> =
                (0..n).map(|i| self.lik.neg_d2logp(self.y[i], f[i])).collect();
            let grad: Vec<f64> =
                (0..n).map(|i| self.lik.dlogp(self.y[i], f[i])).collect();
            // b = W f + ∇ log p(y|f)
            let b: Vec<f64> = (0..n).map(|i| w[i] * f[i] + grad[i]).collect();
            // a_new = b − W^{1/2} B^{-1} W^{1/2} K b
            let kb = self.op.apply_vec(&b);
            let sqrt_w: Vec<f64> = w.iter().map(|v| v.max(0.0).sqrt()).collect();
            let rhs: Vec<f64> = (0..n).map(|i| sqrt_w[i] * kb[i]).collect();
            let bop = LaplaceBOp::new(&self.op, &w);
            let pc_b = k_factor.as_ref().map(|l| precond_b(l, &sqrt_w));
            let (sol, info) = pcg_with_guess(
                &bop,
                &rhs,
                bsol_warm.as_deref(),
                pc_b.as_ref().map(|p| p as &dyn Preconditioner),
                &opts.cg,
            );
            if !info.converged {
                eprintln!(
                    "laplace: Newton inner solve did not converge at iteration {it} \
                     (residual {:.3e}); mode estimate may be off",
                    info.residual
                );
            }
            bsol_warm = Some(sol.clone());
            for i in 0..n {
                a[i] = b[i] - sqrt_w[i] * sol[i];
            }
            f = self.op.apply_vec(&a);
            // Objective ψ(f) = log p(y|f) − ½ a^T f (ascending).
            let psi = self.lik.logp_sum(&self.y, &f) - 0.5 * dot(&a, &f);
            if (psi - psi_old).abs() < opts.newton_tol * (1.0 + psi.abs()) {
                break;
            }
            psi_old = psi;
        }
        self.f_warm = Some(f.clone());

        // log|B| via SLQ (B is SPD with eigenvalues >= 1), preconditioned
        // when the factor is available: log|B| = log|P_B| + tr log of the
        // split operator.
        let w: Vec<f64> = (0..n).map(|i| self.lik.neg_d2logp(self.y[i], f[i])).collect();
        let sqrt_w: Vec<f64> = w.iter().map(|v| v.max(0.0).sqrt()).collect();
        let bop = LaplaceBOp::new(&self.op, &w);
        let (logdet_b, se, interval, probes_used) =
            match k_factor.as_ref().map(|l| precond_b(l, &sqrt_w)) {
                Some(pc_b) => {
                    let pop = PreconditionedOp::new(&bop, &pc_b);
                    let est = slq_trace_fn_ev(
                        &pop,
                        |lam| lam.max(1e-12).ln(),
                        opts.slq_steps,
                        opts.slq_probes,
                        opts.seed,
                        opts.threads,
                    )?;
                    // The exact log|P_B| correction shifts value and
                    // interval rigidly (zero extra uncertainty).
                    let ld = pc_b.logdet();
                    let shifted = ConfidenceInterval {
                        lo: est.interval.lo + ld,
                        hi: est.interval.hi + ld,
                        level: est.interval.level,
                    };
                    (est.value + ld, est.std_err, shifted, est.probes_used)
                }
                None => {
                    let est = slq_trace_fn_ev(
                        &bop,
                        |lam| lam.max(1e-12).ln(),
                        opts.slq_steps,
                        opts.slq_probes,
                        opts.seed,
                        opts.threads,
                    )?;
                    (est.value, est.std_err, est.interval, est.probes_used)
                }
            };
        let log_marginal =
            self.lik.logp_sum(&self.y, &f) - 0.5 * dot(&a, &f) - 0.5 * logdet_b;
        Ok(LaplaceFit {
            f_hat: f,
            a,
            log_marginal,
            logdet_std_err: se,
            logdet_interval: interval,
            logdet_probes_used: probes_used,
            newton_iters: iters,
        })
    }

    /// Predicted mean counts on the training grid (LGCP intensity).
    pub fn predict_rate(&self, fit: &LaplaceFit) -> Vec<f64> {
        fit.f_hat.iter().map(|&f| self.lik.mean(f)).collect()
    }

    /// Fiedler-bound variant of the Laplace objective for the
    /// scaled-eigenvalue baseline comparison (§5.3/§5.4): same mode finding,
    /// but `log|B|` replaced by the Fiedler pairing of the eigenvalues of K
    /// with the diagonal of W. The closure supplies K's eigenvalues.
    pub fn log_marginal_fiedler(
        &mut self,
        opts: &LaplaceOptions,
        k_eigs: impl FnOnce(&O) -> Result<Vec<f64>>,
    ) -> Result<(f64, LaplaceFit)> {
        let mut fit = self.fit(opts)?;
        let n = self.n();
        let w: Vec<f64> =
            (0..n).map(|i| self.lik.neg_d2logp(self.y[i], fit.f_hat[i])).collect();
        let eigs = k_eigs(&self.op)?;
        let logdet_b = crate::estimators::scaled_eig::fiedler_logdet_b(&eigs, &w);
        let lm = self.lik.logp_sum(&self.y, &fit.f_hat) - 0.5 * dot(&fit.a, &fit.f_hat)
            - 0.5 * logdet_b;
        fit.log_marginal = lm;
        Ok((lm, fit))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::likelihoods::Likelihood;
    use crate::grid::{Grid, GridDim};
    use crate::kernels::{SeparableKernel, Shape};
    use crate::linalg::chol::Cholesky;
    use crate::linalg::dense::Mat;
    use crate::operators::ski::KronKernelOp;
    use crate::operators::LinOp;
    use crate::util::rng::Rng;

    fn toy_lgcp(seed: u64) -> (KronKernelOp, Vec<f64>) {
        // 8x8 grid, Poisson counts from a smooth latent field.
        let grid = Grid::new(vec![
            GridDim { lo: 0.0, hi: 1.0, m: 8 },
            GridDim { lo: 0.0, hi: 1.0, m: 8 },
        ]);
        let kern = SeparableKernel::iso(Shape::Rbf, 2, 0.3, 0.8);
        let op = KronKernelOp::new(grid.clone(), kern, 1e-3);
        let mut rng = Rng::new(seed);
        let y: Vec<f64> = (0..64)
            .map(|i| {
                let p = grid.point(i);
                let lam = (1.0 + (3.0 * p[0]).sin() + (2.0 * p[1]).cos()).exp() * 0.8;
                rng.poisson(lam) as f64
            })
            .collect();
        (op, y)
    }

    /// Dense reference Laplace fit (Newton with exact solves).
    fn dense_laplace(k: &Mat, y: &[f64], lik: Likelihood) -> (Vec<f64>, f64) {
        let n = y.len();
        let mut f = vec![0.0; n];
        for _ in 0..100 {
            let w: Vec<f64> = (0..n).map(|i| lik.neg_d2logp(y[i], f[i])).collect();
            let grad: Vec<f64> = (0..n).map(|i| lik.dlogp(y[i], f[i])).collect();
            let b: Vec<f64> = (0..n).map(|i| w[i] * f[i] + grad[i]).collect();
            // f_new = K (I + W K)^{-1} b solved densely via B-form.
            let mut bmat = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    bmat[(i, j)] = w[i].sqrt() * k[(i, j)] * w[j].sqrt()
                        + if i == j { 1.0 } else { 0.0 };
                }
            }
            let chol = Cholesky::new(&bmat).unwrap();
            let kb = k.matvec(&b);
            let rhs: Vec<f64> = (0..n).map(|i| w[i].sqrt() * kb[i]).collect();
            let sol = chol.solve(&rhs);
            let a: Vec<f64> = (0..n).map(|i| b[i] - w[i].sqrt() * sol[i]).collect();
            let f_new = k.matvec(&a);
            let diff: f64 = f_new.iter().zip(&f).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            f = f_new;
            if diff < 1e-10 {
                break;
            }
        }
        // log|B| exact.
        let w: Vec<f64> = (0..n).map(|i| lik.neg_d2logp(y[i], f[i])).collect();
        let mut bmat = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                bmat[(i, j)] =
                    w[i].sqrt() * k[(i, j)] * w[j].sqrt() + if i == j { 1.0 } else { 0.0 };
            }
        }
        let logdet_b = Cholesky::new(&bmat).unwrap().logdet();
        (f, logdet_b)
    }

    #[test]
    fn mode_matches_dense_newton() {
        let (op, y) = toy_lgcp(1);
        let lik = Likelihood::Poisson { offset: 0.0 };
        let mut gp = LaplaceGp::new(op, y.clone(), lik);
        let fit = gp.fit(&LaplaceOptions::default()).unwrap();
        let k = gp.op.to_dense();
        let (f_ref, _) = dense_laplace(&k, &y, lik);
        for i in 0..64 {
            assert!(
                (fit.f_hat[i] - f_ref[i]).abs() < 1e-5,
                "i={i}: {} vs {}",
                fit.f_hat[i],
                f_ref[i]
            );
        }
    }

    #[test]
    fn log_marginal_close_to_dense_reference() {
        let (op, y) = toy_lgcp(2);
        let lik = Likelihood::Poisson { offset: 0.0 };
        let mut gp = LaplaceGp::new(op, y.clone(), lik);
        let fit = gp
            .fit(&LaplaceOptions { slq_probes: 16, slq_steps: 40, ..Default::default() })
            .unwrap();
        let k = gp.op.to_dense();
        let (f_ref, logdet_b) = dense_laplace(&k, &y, lik);
        // Reference log marginal.
        let chol = Cholesky::new(&k).unwrap();
        let kinvf = chol.solve(&f_ref);
        let want = lik.logp_sum(&y, &f_ref) - 0.5 * dot(&kinvf, &f_ref) - 0.5 * logdet_b;
        assert!(
            (fit.log_marginal - want).abs() < 0.05 * want.abs().max(1.0) + 5.0 * fit.logdet_std_err,
            "{} vs {}",
            fit.log_marginal,
            want
        );
    }

    /// The fit reports the log|B| confidence interval and probe count, and
    /// the 95% interval brackets the dense-reference log|B|.
    #[test]
    fn fit_reports_calibrated_logdet_interval() {
        let (op, y) = toy_lgcp(7);
        let lik = Likelihood::Poisson { offset: 0.0 };
        let mut gp = LaplaceGp::new(op, y.clone(), lik);
        let fit = gp
            .fit(&LaplaceOptions { slq_probes: 16, slq_steps: 40, ..Default::default() })
            .unwrap();
        assert_eq!(fit.logdet_probes_used, 16);
        let w = fit.logdet_interval.width();
        assert!(w.is_finite() && w > 0.0, "width {w}");
        let k = gp.op.to_dense();
        let (_, logdet_b) = dense_laplace(&k, &y, lik);
        assert!(
            fit.logdet_interval.contains(logdet_b),
            "[{}, {}] misses {}",
            fit.logdet_interval.lo,
            fit.logdet_interval.hi,
            logdet_b
        );
    }

    #[test]
    fn mode_increases_posterior_vs_zero() {
        let (op, y) = toy_lgcp(3);
        let lik = Likelihood::Poisson { offset: 0.0 };
        let mut gp = LaplaceGp::new(op, y.clone(), lik);
        let fit = gp.fit(&LaplaceOptions::default()).unwrap();
        let psi_mode = lik.logp_sum(&y, &fit.f_hat) - 0.5 * dot(&fit.a, &fit.f_hat);
        let psi_zero = lik.logp_sum(&y, &vec![0.0; 64]);
        assert!(psi_mode >= psi_zero, "{psi_mode} vs {psi_zero}");
    }

    #[test]
    fn rates_track_observed_counts() {
        let (op, y) = toy_lgcp(4);
        let lik = Likelihood::Poisson { offset: 0.0 };
        let mut gp = LaplaceGp::new(op, y.clone(), lik);
        let fit = gp.fit(&LaplaceOptions::default()).unwrap();
        let rates = gp.predict_rate(&fit);
        // Smoothing: correlation between rates and counts should be strong.
        let my = crate::util::stats::mean(&y);
        let mr = crate::util::stats::mean(&rates);
        let mut num = 0.0;
        let mut dy = 0.0;
        let mut dr = 0.0;
        for i in 0..64 {
            num += (y[i] - my) * (rates[i] - mr);
            dy += (y[i] - my).powi(2);
            dr += (rates[i] - mr).powi(2);
        }
        let corr = num / (dy.sqrt() * dr.sqrt()).max(1e-12);
        assert!(corr > 0.5, "corr {corr}");
    }

    /// Preconditioned Newton solves + preconditioned log|B| reproduce the
    /// unpreconditioned fit (same mode, same marginal within SLQ error).
    #[test]
    fn preconditioned_fit_matches_plain_fit() {
        let (op, y) = toy_lgcp(6);
        let lik = Likelihood::Poisson { offset: 0.0 };
        let mut gp = LaplaceGp::new(op, y.clone(), lik);
        let opts = LaplaceOptions { slq_probes: 16, slq_steps: 40, ..Default::default() };
        let plain = gp.fit(&opts).unwrap();
        gp.f_warm = None;
        let mut popts = opts;
        popts.cg.precond = crate::solvers::PrecondOptions::rank(24);
        let pre = gp.fit(&popts).unwrap();
        for i in 0..64 {
            assert!(
                (plain.f_hat[i] - pre.f_hat[i]).abs() < 1e-5,
                "mode i={i}: {} vs {}",
                plain.f_hat[i],
                pre.f_hat[i]
            );
        }
        let tol = 5.0 * (plain.logdet_std_err + pre.logdet_std_err)
            + 0.02 * plain.log_marginal.abs().max(1.0);
        assert!(
            (plain.log_marginal - pre.log_marginal).abs() < tol,
            "{} vs {} (tol {tol})",
            plain.log_marginal,
            pre.log_marginal
        );
    }

    #[test]
    fn fiedler_variant_differs_from_slq() {
        let (op, y) = toy_lgcp(5);
        let lik = Likelihood::Poisson { offset: 0.0 };
        let mut gp = LaplaceGp::new(op, y, lik);
        let slq_lm = gp.fit(&LaplaceOptions::default()).unwrap().log_marginal;
        let (fiedler_lm, _) = gp
            .log_marginal_fiedler(&LaplaceOptions::default(), |op| {
                op.kuu().all_eigvals()
            })
            .unwrap();
        // Both finite; Fiedler is an approximation and generally differs.
        assert!(fiedler_lm.is_finite() && slq_lm.is_finite());
        assert!((fiedler_lm - slq_lm).abs() > 1e-6);
    }
}
