//! Gaussian-likelihood GP regression with MVM-only marginal likelihood and
//! gradients (paper Eq. 1):
//!
//!   L(θ|y) = -1/2 [ (y-μ)^T α + log|K̃| + n log 2π ],   α = K̃^{-1}(y-μ)
//!   ∂L/∂θi = -1/2 [ tr(K̃^{-1} ∂K̃/∂θi) − α^T (∂K̃/∂θi) α ]
//!
//! α comes from CG (warm-started across optimizer steps); the trace terms
//! come from whichever estimator the caller picks — SLQ, Chebyshev,
//! surrogate, scaled-eigenvalue, or exact Cholesky.

use crate::error::{Error, Result};
use crate::kernels::Kernel as _;
use crate::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
use crate::estimators::slq::SlqOptions;
use crate::estimators::surrogate::LogdetSurrogate;
use crate::estimators::{exact, LogdetEstimate};
use crate::opt::lbfgs::{lbfgs, LbfgsOptions};
use crate::opt::OptResult;
use crate::operators::{KernelOp, LinOp};
use crate::linalg::dense::Mat;
use crate::linalg::pchol::{pivoted_cholesky, PivotedCholesky};
use crate::solvers::{
    pcg_block, pcg_with_guess, precond_from_factor, BlockCgInfo, CgInfo, CgOptions,
    PivCholPrecond, PrecondOptions, Preconditioner,
};
use crate::util::blocks::BlockPartition;
use crate::util::stats::dot;

/// Kernel operators that can also produce predictive quantities.
pub trait PredictiveOp: KernelOp {
    /// `K(X*, X) v` (no noise).
    fn cross_apply(&self, test: &[Vec<f64>], v: &[f64]) -> Vec<f64>;
    /// `k(X, x*)` as a column (for predictive variance solves).
    fn cross_col(&self, x: &[f64]) -> Vec<f64>;
    /// Prior variance `k(x*, x*)`.
    fn prior_var(&self, x: &[f64]) -> f64;
    /// Scaled-eigenvalue log determinant, where the structure allows it.
    fn scaled_eig_logdet(&self) -> Result<f64> {
        Err(Error::Config("scaled-eigenvalue method unavailable for this operator".into()))
    }
    /// Fast exact logdet + grads, when the operator has a cheaper route
    /// than the generic unit-vector probing (dense ops, FITC's lemma).
    fn exact_logdet_grads_fast(&self) -> Option<Result<(f64, Vec<f64>)>> {
        None
    }
}

/// Log-determinant estimator selection for training.
pub enum Estimator {
    Slq(SlqOptions),
    Chebyshev(ChebOptions),
    /// Exact O(n^3) Cholesky (ground truth / small n).
    Exact,
    /// Scaled-eigenvalue baseline; gradients by finite differences.
    ScaledEig,
    /// Pre-built surrogate over log-hyper space (paper §3.5).
    Surrogate(LogdetSurrogate),
}

impl Estimator {
    /// Human-readable name for experiment tables.
    pub fn name(&self) -> &'static str {
        match self {
            Estimator::Slq(_) => "lanczos",
            Estimator::Chebyshev(_) => "chebyshev",
            Estimator::Exact => "exact",
            Estimator::ScaledEig => "scaled_eig",
            Estimator::Surrogate(_) => "surrogate",
        }
    }
}

/// Hit/miss tallies for the model's cached artifacts — the serving
/// layer's cache-effectiveness report (`gpsld serve` prints hit rates per
/// model). A *hit* means the request was served from (or warm-started by)
/// the retained artifact: `alpha` present before the solve, or the
/// preconditioner cache found fresh. Mirrored into the global
/// [`obs`](crate::util::obs) counters (`cache_hits`/`cache_misses`) when
/// tracing is enabled.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub alpha_hits: usize,
    pub alpha_misses: usize,
    pub pc_hits: usize,
    pub pc_misses: usize,
}

/// Statistics from one training run.
#[derive(Clone, Debug)]
pub struct TrainStats {
    pub opt: OptResult,
    pub seconds: f64,
    pub final_hypers: Vec<f64>,
    pub final_mll: f64,
}

/// GP regression model over any predictive kernel operator.
pub struct GpRegression<O: PredictiveOp> {
    pub op: O,
    pub y: Vec<f64>,
    /// Constant mean (defaults to mean(y)).
    pub mean: f64,
    /// Solver settings shared by the training `alpha` solve and the
    /// predictive-variance block solve. Its `precond` knob (CLI
    /// `--precond-rank`, 0 = off) controls the pivoted-Cholesky
    /// preconditioner built (and cached per hyper setting) for every
    /// solve and SLQ logdet on this model; its `threads` knob (CLI
    /// `--threads`) fans multi-group predictive-variance solves across
    /// RHS-group workers (bit-identical results at any thread count).
    pub cg: CgOptions,
    /// Warm-start later predictive-variance column groups from the nearest
    /// already-solved test column (neighboring test points have similar
    /// `k_*` columns). On by default; only kicks in when the test set
    /// spans more than one `block_size`-wide group, so single-group solves
    /// stay bit-identical to cold ones.
    pub warm_start_predict_var: bool,
    /// Keep the pivoted-Cholesky factor alive across `set_hypers` calls
    /// (optimizer steps). Sound for correctness — the SLQ identity
    /// `log|K̃| = log|P| + tr log(P^{-1/2} K̃ P^{-1/2})` and PCG both hold
    /// for *any* fixed SPD `P` — but a stale factor preconditions less
    /// well, so this trades factor rebuild time against solver/estimator
    /// iterations. Off by default; the adaptive `--logdet-tol` path turns
    /// it on implicitly so the grown rank seeds later steps.
    pub reuse_precond_across_steps: bool,
    /// The logdet estimate from the most recent [`GpRegression::mll`]
    /// call — confidence interval, `probes_used`, and retained spectral
    /// evidence included — so experiment tables and the CLI can report
    /// uncertainty without re-estimating.
    pub last_logdet: Option<LogdetEstimate>,
    /// Cache hit/miss tallies for the retained artifacts (see
    /// [`CacheStats`]); read by the serving layer's per-model report.
    pub cache_stats: CacheStats,
    alpha_cache: Option<Vec<f64>>,
    /// Preconditioner cache: the options it was built under, plus the
    /// factor (`None` when building was skipped or impossible).
    pc_cache: Option<(PrecondOptions, Option<PivCholPrecond>)>,
    /// The pivoted-Cholesky factor behind `pc_cache`, retained together
    /// with the `rel_tol` it was grown under, so a later rank bump (the
    /// adaptive `--logdet-tol` growth loop) appends pivots — one kernel
    /// MVM each — instead of refactorizing from scratch. Invalidated on
    /// every hyper change: appending new-kernel columns to an old-kernel
    /// factor would silently mix factorizations.
    pchol_cache: Option<(f64, PivotedCholesky)>,
}

impl<O: PredictiveOp> GpRegression<O> {
    pub fn new(op: O, y: Vec<f64>) -> Self {
        assert_eq!(op.n(), y.len());
        let mean = crate::util::stats::mean(&y);
        GpRegression {
            op,
            y,
            mean,
            cg: CgOptions { tol: 1e-8, max_iters: 1000, ..Default::default() },
            warm_start_predict_var: true,
            reuse_precond_across_steps: false,
            last_logdet: None,
            cache_stats: CacheStats::default(),
            alpha_cache: None,
            pc_cache: None,
            pchol_cache: None,
        }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    fn residual(&self) -> Vec<f64> {
        self.y.iter().map(|v| v - self.mean).collect()
    }

    /// (Re)build the pivoted-Cholesky preconditioner if the knob asks for
    /// one and the cache is stale (hypers or options changed). When the
    /// retained factor sits at or below the requested rank (and was grown
    /// under the same `rel_tol`), new pivots are **appended** to it —
    /// bitwise the factor a from-scratch run at the new rank would
    /// produce, at the incremental MVM cost only; otherwise the factor is
    /// rebuilt. Only the cheap k×k eigendecomposition is redone either
    /// way.
    fn refresh_precond(&mut self) {
        let popts = self.cg.precond;
        if popts.rank == 0 {
            self.pc_cache = None;
            self.pchol_cache = None;
            return;
        }
        let stale = match &self.pc_cache {
            Some((cached, _)) => *cached != popts,
            None => true,
        };
        if !stale {
            self.cache_stats.pc_hits += 1;
            crate::util::obs::add(crate::util::obs::Counter::CacheHits, 1);
            return;
        }
        self.cache_stats.pc_misses += 1;
        crate::util::obs::add(crate::util::obs::Counter::CacheMisses, 1);
        let s2 = self.op.noise_var();
        let pc = if !(s2 > 0.0) {
            self.pchol_cache = None;
            eprintln!(
                "precond: operator has no positive noise floor; solves run unpreconditioned"
            );
            None
        } else {
            let factor = match self.pchol_cache.take() {
                Some((tol, mut f)) if tol == popts.rel_tol && f.rank() <= popts.rank => {
                    f.grow(&self.op, popts.rank, popts.rel_tol);
                    Some(f)
                }
                _ => pivoted_cholesky(&self.op, popts.rank, popts.rel_tol),
            };
            match factor {
                Some(f) => {
                    let pc = precond_from_factor(&f, s2);
                    self.pchol_cache = Some((popts.rel_tol, f));
                    Some(pc)
                }
                None => {
                    eprintln!(
                        "precond: operator does not expose diag(); solves run unpreconditioned"
                    );
                    None
                }
            }
        };
        self.pc_cache = Some((popts, pc));
    }

    /// The cached preconditioner as a trait object (None when off).
    fn precond(&self) -> Option<&dyn Preconditioner> {
        self.pc_cache
            .as_ref()
            .and_then(|(_, pc)| pc.as_ref())
            .map(|p| p as &dyn Preconditioner)
    }

    /// α = K̃^{-1}(y - μ) by warm-started (preconditioned) CG.
    pub fn alpha(&mut self) -> (Vec<f64>, CgInfo) {
        self.refresh_precond();
        if self.alpha_cache.is_some() {
            self.cache_stats.alpha_hits += 1;
            crate::util::obs::add(crate::util::obs::Counter::CacheHits, 1);
        } else {
            self.cache_stats.alpha_misses += 1;
            crate::util::obs::add(crate::util::obs::Counter::CacheMisses, 1);
        }
        let r = self.residual();
        let (a, info) = pcg_with_guess(
            &self.op,
            &r,
            self.alpha_cache.as_deref(),
            self.precond(),
            &self.cg,
        );
        self.alpha_cache = Some(a.clone());
        (a, info)
    }

    /// Invalidate caches after a hyper change.
    pub fn set_hypers(&mut self, h: &[f64]) {
        self.op.set_hypers(h);
        // keep alpha as warm start — K̃ changed only slightly per step.
        // The preconditioner tracks K̃ exactly, so it is rebuilt unless the
        // caller opted into cross-step reuse (any fixed SPD P stays valid
        // for both PCG and the preconditioned-SLQ identity; a stale one is
        // merely a weaker preconditioner).
        if !self.reuse_precond_across_steps {
            self.pc_cache = None;
        }
        // The growth frontier is tied to the current kernel regardless:
        // a later rank bump must refactorize under the new hypers, never
        // append new-kernel pivots to an old-kernel factor.
        self.pchol_cache = None;
    }

    /// Adaptive preconditioner rank (the `--logdet-tol` satellite of the
    /// confidence refactor): with a tolerance requested and preconditioning
    /// on, grow `cg.precond.rank` (doubling, capped at n) until the pivoted
    /// Cholesky's exact residual trace `tr(K − L Lᵀ)` clears a tenth of the
    /// tolerance — a cheap a-priori proxy for how much spectrum the factor
    /// leaves to the stochastic part. The grown rank is written back into
    /// `cg.precond` and `reuse_precond_across_steps` is switched on, so
    /// later optimizer steps start from the grown factor instead of
    /// re-growing from the seed rank.
    fn grow_precond_rank(&mut self, tol: f64) {
        if self.cg.precond.rank == 0 {
            return;
        }
        let n = self.op.n();
        let budget = 0.1 * tol;
        self.reuse_precond_across_steps = true;
        let mut rank = self.cg.precond.rank.min(n);
        loop {
            self.cg.precond.rank = rank;
            self.refresh_precond();
            let Some(pc) = self.pc_cache.as_ref().and_then(|(_, pc)| pc.as_ref()) else {
                return; // structurally unavailable — nothing to grow
            };
            // Stop when the factor is good enough, fully grown, or the
            // pivoted Cholesky terminated early on its own rel_tol (more
            // rank would not change the factor).
            if pc.trace_error() <= budget || rank >= n || pc.rank() < rank {
                return;
            }
            rank = (rank * 2).min(n);
        }
    }

    /// Log-determinant estimate under the chosen estimator. SLQ runs
    /// preconditioned when the `cg.precond` knob is on (the identity
    /// `log|K̃| = log|P| + tr log(P^{-1/2} K̃ P^{-1/2})` keeps the estimate
    /// unbiased; see `estimators::slq::slq_logdet_pc`).
    pub fn logdet(&mut self, est: &Estimator, grads: bool) -> Result<LogdetEstimate> {
        match est {
            Estimator::Slq(o) => {
                let mut o = *o;
                o.grads = grads;
                if let Some(tol) = o.target_tol {
                    self.grow_precond_rank(tol);
                }
                self.refresh_precond();
                crate::estimators::slq::slq_logdet_pc(&self.op, self.precond(), &o)
            }
            Estimator::Chebyshev(o) => {
                let mut o = *o;
                o.grads = grads;
                chebyshev_logdet(&self.op, &o)
            }
            Estimator::Exact => {
                if let Some(fast) = self.op.exact_logdet_grads_fast() {
                    let (v, g) = fast?;
                    return Ok(LogdetEstimate::exact(v, if grads { g } else { vec![] }));
                }
                if grads {
                    let (v, g) = exact::exact_logdet_grads_any(&self.op)?;
                    Ok(LogdetEstimate::exact(v, g))
                } else {
                    Ok(LogdetEstimate::exact(exact::exact_logdet(&self.op)?, vec![]))
                }
            }
            Estimator::ScaledEig => {
                let value = self.op.scaled_eig_logdet()?;
                let mut grad = Vec::new();
                if grads {
                    let h0 = self.op.hypers();
                    let eps = 1e-5;
                    grad = vec![0.0; h0.len()];
                    for i in 0..h0.len() {
                        let mut hp = h0.clone();
                        hp[i] += eps;
                        self.op.set_hypers(&hp);
                        let up = self.op.scaled_eig_logdet()?;
                        hp[i] -= 2.0 * eps;
                        self.op.set_hypers(&hp);
                        let dn = self.op.scaled_eig_logdet()?;
                        grad[i] = (up - dn) / (2.0 * eps);
                    }
                    self.op.set_hypers(&h0);
                }
                Ok(LogdetEstimate::exact(value, grad))
            }
            Estimator::Surrogate(s) => {
                let h = self.op.hypers();
                let v = s.eval(&h);
                let g = if grads { s.grad(&h) } else { vec![] };
                Ok(LogdetEstimate::exact(v, g))
            }
        }
    }

    /// Log marginal likelihood and gradient w.r.t. hypers.
    pub fn mll(&mut self, est: &Estimator, grads: bool) -> Result<(f64, Vec<f64>)> {
        let n = self.n() as f64;
        let (alpha, _info) = self.alpha();
        let r = self.residual();
        let fit = dot(&r, &alpha);
        let ld = self.logdet(est, grads)?;
        let value = -0.5 * (fit + ld.value + n * (2.0 * std::f64::consts::PI).ln());
        let mut grad = Vec::new();
        if grads {
            let nh = self.op.num_hypers();
            let mut dkalpha = vec![0.0; self.n()];
            grad = vec![0.0; nh];
            for i in 0..nh {
                self.op.apply_grad(i, &alpha, &mut dkalpha);
                let quad = dot(&alpha, &dkalpha);
                grad[i] = -0.5 * (ld.grad[i] - quad);
            }
        }
        self.last_logdet = Some(ld);
        Ok((value, grad))
    }

    /// Maximize the marginal likelihood over hypers with L-BFGS.
    pub fn train(&mut self, est: &Estimator, opts: &LbfgsOptions) -> Result<TrainStats> {
        let start = std::time::Instant::now();
        let h0 = self.op.hypers();
        // Interior mutability dance: lbfgs drives a closure over &mut self.
        let cell = std::cell::RefCell::new(self);
        let obj = |h: &[f64]| {
            let mut me = cell.borrow_mut();
            me.set_hypers(h);
            match me.mll(est, true) {
                Ok((v, g)) => (-v, g.iter().map(|x| -x).collect()),
                Err(_) => (f64::INFINITY, vec![0.0; h.len()]),
            }
        };
        let res = lbfgs(obj, &h0, opts);
        let me = cell.into_inner();
        me.set_hypers(&res.x);
        let final_mll = -res.fx;
        Ok(TrainStats {
            seconds: start.elapsed().as_secs_f64(),
            final_hypers: res.x.clone(),
            final_mll,
            opt: res,
        })
    }

    /// Predictive mean at test points: `μ + K(X*, X) α`.
    pub fn predict_mean(&mut self, test: &[Vec<f64>]) -> Vec<f64> {
        let (alpha, _) = self.alpha();
        let cross = self.op.cross_apply(test, &alpha);
        cross.iter().map(|v| v + self.mean).collect()
    }

    /// Predictive variance of the latent + noise at test points:
    /// `k(x*,x*) + σ² − k_*^T K̃^{-1} k_*`. All test-point columns are
    /// batched through **one** block-CG solve; non-converged columns are
    /// reported on stderr (use [`GpRegression::predict_var_info`] to
    /// inspect convergence programmatically).
    pub fn predict_var(&mut self, test: &[Vec<f64>]) -> Vec<f64> {
        let (vars, info) = self.predict_var_info(test);
        if !info.all_converged() {
            let bad = info.cols.iter().filter(|c| !c.converged).count();
            eprintln!(
                "predict_var: {bad}/{} solves did not converge \
                 (worst residual {:.3e}); variances may be unreliable",
                info.cols.len(),
                info.worst_residual()
            );
        }
        vars
    }

    /// [`GpRegression::predict_var`] plus the block-solve convergence
    /// report: per-column `CgInfo` and the `mvms`/`block_applies`
    /// accounting. A column that did not converge yields a variance from
    /// the best available iterate — callers deciding on calibrated
    /// uncertainties should check `info.all_converged()`.
    ///
    /// When the test set spans more than one `block_size`-wide column
    /// group and [`GpRegression::warm_start_predict_var`] is on, groups
    /// after the first are warm-started from the nearest already-solved
    /// column (`k_*` columns of neighboring test points are close, so the
    /// previous solution is a good starting iterate).
    /// `info.warm_saved_iters` reports the iterations observed saved
    /// relative to the cold first group's worst column; a single-group
    /// solve is always cold and bit-identical to the unwarmed path.
    ///
    /// Threading: with warm starts off the groups are independent and the
    /// block engine fans them across `cg.threads` workers. The
    /// warm-started path is group-*sequential* by construction (group `b`
    /// seeds from group `b−1`'s solution), so it stays serial at the group
    /// level regardless of `cg.threads` — the strategy choice is
    /// deliberately independent of the thread count so results never
    /// depend on it.
    pub fn predict_var_info(&mut self, test: &[Vec<f64>]) -> (Vec<f64>, BlockCgInfo) {
        self.refresh_precond();
        let s2 = self.op.noise_var();
        let n = self.n();
        let mut kmat = Mat::zeros(n, test.len());
        for (t, x) in test.iter().enumerate() {
            kmat.set_col(t, &self.op.cross_col(x));
        }
        let part = BlockPartition::new(test.len(), self.cg.block_size);
        let (sols, info) = if !self.warm_start_predict_var || part.nblocks <= 1 {
            pcg_block(&self.op, &kmat, None, self.precond(), &self.cg)
        } else {
            // Group-sequential warm starting: solve the first group cold,
            // then seed every column of group b with the solution of the
            // last column of group b-1 (its nearest solved neighbor).
            let mut sols = Mat::zeros(n, test.len());
            let mut cols = Vec::with_capacity(test.len());
            let mut mvms = 0;
            let mut block_applies = 0;
            let mut cold_baseline = 0usize;
            let mut warm_saved_iters = 0usize;
            let mut prev_last: Option<Vec<f64>> = None;
            for bi in 0..part.nblocks {
                let (j0, w) = part.range(bi);
                let bblk = kmat.sub_cols(j0, w);
                let x0 = prev_last.as_ref().map(|seed| {
                    let mut g = Mat::zeros(n, w);
                    for c in 0..w {
                        g.set_col(c, seed);
                    }
                    g
                });
                let gopts = CgOptions { block_size: w, ..self.cg };
                let (x, ginfo) =
                    pcg_block(&self.op, &bblk, x0.as_ref(), self.precond(), &gopts);
                if bi == 0 {
                    cold_baseline = ginfo.max_iters();
                } else {
                    for c in &ginfo.cols {
                        warm_saved_iters += cold_baseline.saturating_sub(c.iters);
                    }
                }
                prev_last = Some(x.col(w - 1));
                for c in 0..w {
                    sols.set_col(j0 + c, &x.col(c));
                }
                cols.extend(ginfo.cols);
                mvms += ginfo.mvms;
                block_applies += ginfo.block_applies;
            }
            (sols, BlockCgInfo { cols, mvms, block_applies, warm_saved_iters })
        };
        let vars = test
            .iter()
            .enumerate()
            .map(|(t, x)| {
                let quad = kmat.col_dot_pair(&sols, t);
                (self.op.prior_var(x) + s2 - quad).max(1e-12)
            })
            .collect();
        (vars, info)
    }
}

// ---------------- PredictiveOp implementations ----------------

impl PredictiveOp for crate::operators::SkiOp {
    fn cross_apply(&self, test: &[Vec<f64>], v: &[f64]) -> Vec<f64> {
        self.cross_mvm(test, v)
    }
    fn cross_col(&self, x: &[f64]) -> Vec<f64> {
        // k(X, x*) ≈ W K_UU W*^T e — one-point cross MVM transposed.
        let one = vec![x.to_vec()];
        let (wstar, _) = self.grid.interp_matrix(&one, self.order);
        let m = self.m();
        let mut e = vec![0.0; m];
        wstar.apply_t(&[1.0], &mut e);
        let mut kg = vec![0.0; m];
        self.kuu().apply(&e, &mut kg);
        let mut out = vec![0.0; self.n()];
        self.w_matrix().apply(&kg, &mut out);
        out
    }
    fn prior_var(&self, x: &[f64]) -> f64 {
        self.kernel.eval(x, x)
    }
    fn scaled_eig_logdet(&self) -> Result<f64> {
        crate::estimators::scaled_eig::scaled_eig_logdet_ski(self)
    }
}

impl PredictiveOp for crate::operators::DenseKernelOp {
    fn exact_logdet_grads_fast(&self) -> Option<Result<(f64, Vec<f64>)>> {
        Some(exact::exact_logdet_grads_dense(self))
    }
    fn cross_apply(&self, test: &[Vec<f64>], v: &[f64]) -> Vec<f64> {
        test.iter()
            .map(|t| {
                let mut s = 0.0;
                for (p, vi) in self.points.iter().zip(v) {
                    s += self.kernel.eval(t, p) * vi;
                }
                s
            })
            .collect()
    }
    fn cross_col(&self, x: &[f64]) -> Vec<f64> {
        self.points.iter().map(|p| self.kernel.eval(p, x)).collect()
    }
    fn prior_var(&self, x: &[f64]) -> f64 {
        self.kernel.eval(x, x)
    }
}

impl PredictiveOp for crate::operators::FitcOp {
    fn exact_logdet_grads_fast(&self) -> Option<Result<(f64, Vec<f64>)>> {
        // Determinant lemma for the value; central FD (re-building the
        // low-rank factorization, O(n m^2) per probe) for the gradient —
        // the honest cost profile of the FITC baseline.
        let run = || -> Result<(f64, Vec<f64>)> {
            let value = self.exact_logdet()?;
            let h0 = self.hypers();
            let eps = 1e-5;
            let mut grad = vec![0.0; h0.len()];
            let mut probe = crate::operators::FitcOp::new(
                self.points.clone(),
                self.inducing.clone(),
                self.kernel.clone_box(),
                1.0,
                self.fitc,
            )?;
            for i in 0..h0.len() {
                let mut hp = h0.clone();
                hp[i] += eps;
                probe.set_hypers(&hp);
                let up = probe.exact_logdet()?;
                hp[i] -= 2.0 * eps;
                probe.set_hypers(&hp);
                let dn = probe.exact_logdet()?;
                grad[i] = (up - dn) / (2.0 * eps);
            }
            Ok((value, grad))
        };
        Some(run())
    }
    fn cross_apply(&self, test: &[Vec<f64>], v: &[f64]) -> Vec<f64> {
        self.predict_mean(test, v)
    }
    fn cross_col(&self, x: &[f64]) -> Vec<f64> {
        // Direct kernel evaluation (the exact cross-covariance; FITC's own
        // predictive equations are exposed via FitcOp::predict_var).
        self.points.iter().map(|p| self.kernel.eval(p, x)).collect()
    }
    fn prior_var(&self, x: &[f64]) -> f64 {
        self.kernel.eval(x, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::linalg::chol::Cholesky;
    use crate::operators::DenseKernelOp;
    use crate::solvers::cg_with_guess;
    use crate::util::rng::Rng;

    /// Sample y from the GP prior at given hypers (exact, small n).
    fn sample_gp(pts: &[Vec<f64>], kern: &IsoKernel, sigma: f64, seed: u64) -> Vec<f64> {
        use crate::kernels::Kernel;
        let n = pts.len();
        let mut k = crate::linalg::dense::Mat::from_fn(n, n, |i, j| kern.eval(&pts[i], &pts[j]));
        k.add_diag(sigma * sigma + 1e-10);
        let chol = Cholesky::new(&k).unwrap();
        let mut rng = Rng::new(seed);
        let mut zn = vec![0.0; n];
        rng.fill_gaussian(&mut zn);
        // y = L z
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..=i {
                s += chol.l[(i, j)] * zn[j];
            }
            y[i] = s;
        }
        y
    }

    fn setup(n: usize, seed: u64) -> GpRegression<DenseKernelOp> {
        let mut rng = Rng::new(seed);
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        let kern = IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0);
        let y = sample_gp(&pts, &kern, 0.2, seed ^ 1);
        let op = DenseKernelOp::new(pts, Box::new(kern), 0.2);
        GpRegression::new(op, y)
    }

    #[test]
    fn mll_matches_closed_form() {
        let mut gp = setup(60, 1);
        let (mll, _) = gp.mll(&Estimator::Exact, false).unwrap();
        // Closed form via Cholesky.
        let a = gp.op.full_matrix();
        let chol = Cholesky::new(&a).unwrap();
        let r = gp.residual();
        let alpha = chol.solve(&r);
        let want = -0.5
            * (dot(&r, &alpha)
                + chol.logdet()
                + 60.0 * (2.0 * std::f64::consts::PI).ln());
        assert!((mll - want).abs() < 1e-6, "{mll} vs {want}");
    }

    #[test]
    fn mll_grad_matches_fd() {
        let mut gp = setup(50, 2);
        let (_, g) = gp.mll(&Estimator::Exact, true).unwrap();
        let h0 = gp.op.hypers();
        let eps = 1e-5;
        for i in 0..h0.len() {
            let mut hp = h0.clone();
            hp[i] += eps;
            gp.set_hypers(&hp);
            gp.alpha_cache = None;
            let (up, _) = gp.mll(&Estimator::Exact, false).unwrap();
            hp[i] -= 2.0 * eps;
            gp.set_hypers(&hp);
            gp.alpha_cache = None;
            let (dn, _) = gp.mll(&Estimator::Exact, false).unwrap();
            gp.set_hypers(&h0);
            gp.alpha_cache = None;
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (g[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "hyper {i}: {} vs {}",
                g[i],
                fd
            );
        }
    }

    #[test]
    fn slq_mll_close_to_exact_mll() {
        let mut gp = setup(80, 3);
        let (exact, _) = gp.mll(&Estimator::Exact, false).unwrap();
        let (slq, _) = gp
            .mll(
                &Estimator::Slq(SlqOptions { steps: 30, probes: 10, seed: 4, ..Default::default() }),
                false,
            )
            .unwrap();
        assert!((slq - exact).abs() < 0.02 * exact.abs().max(1.0) + 2.0);
    }

    #[test]
    fn training_improves_mll_from_wrong_hypers() {
        let mut gp = setup(60, 5);
        // Start far from truth.
        gp.set_hypers(&[(0.1f64).ln(), (3.0f64).ln(), (1.0f64).ln()]);
        gp.alpha_cache = None;
        let (before, _) = gp.mll(&Estimator::Exact, false).unwrap();
        let stats = gp
            .train(
                &Estimator::Exact,
                &LbfgsOptions { max_iters: 30, ..Default::default() },
            )
            .unwrap();
        assert!(stats.final_mll > before + 1.0, "{} -> {}", before, stats.final_mll);
    }

    #[test]
    fn prediction_matches_dense_smoother() {
        // predict_mean at the training inputs must equal the closed-form
        // smoother mu + K (K + sigma^2 I)^{-1} (y - mu) computed densely.
        let mut gp = setup(40, 6);
        let pts = gp.op.points.clone();
        let pred = gp.predict_mean(&pts);
        let full = gp.op.full_matrix();
        let chol = Cholesky::new(&full).unwrap();
        let r = gp.residual();
        let alpha = chol.solve(&r);
        let kmat = gp.op.kernel_matrix();
        for i in 0..40 {
            let mut want = gp.mean;
            for j in 0..40 {
                want += kmat[(i, j)] * alpha[j];
            }
            assert!((pred[i] - want).abs() < 1e-5, "i={i}: {} vs {want}", pred[i]);
        }
    }

    #[test]
    fn predict_var_block_matches_per_point_cg() {
        // The batched predictive-variance solve must be bit-identical to
        // the old one-cold-CG-per-point formulation, while consuming fewer
        // block-amortized applies.
        let mut gp = setup(40, 8);
        gp.cg.block_size = 8;
        let test_pts: Vec<Vec<f64>> =
            (0..6).map(|t| vec![0.3 + 0.6 * t as f64]).collect();
        let (vars, info) = gp.predict_var_info(&test_pts);
        assert!(info.all_converged());
        assert!(info.block_applies <= info.mvms);
        assert!(info.block_applies < info.mvms, "blocking should amortize");
        let s2 = gp.op.noise_var();
        for (t, x) in test_pts.iter().enumerate() {
            let kstar = gp.op.cross_col(x);
            let (sol, si) = cg_with_guess(&gp.op, &kstar, None, &gp.cg);
            assert!(si.converged);
            let want = (gp.op.prior_var(x) + s2 - dot(&kstar, &sol)).max(1e-12);
            assert_eq!(vars[t].to_bits(), want.to_bits(), "point {t}");
        }
    }

    #[test]
    fn warm_started_predict_var_matches_cold_and_saves_iters() {
        // Closely spaced test points across several column groups at small
        // noise (the regime where neighboring k_* solves genuinely share
        // information): warm starts must not change the variances beyond
        // solver tolerance, and should demonstrably save iterations.
        let mut gp = setup(60, 11);
        gp.set_hypers(&[(0.5f64).ln(), 0.0, (0.05f64).ln()]);
        gp.cg.block_size = 4;
        gp.cg.tol = 1e-10;
        let test_pts: Vec<Vec<f64>> =
            (0..16).map(|t| vec![1.0 + 0.002 * t as f64]).collect();
        let (warm_vars, warm_info) = gp.predict_var_info(&test_pts);
        assert!(warm_info.all_converged());
        gp.warm_start_predict_var = false;
        let (cold_vars, cold_info) = gp.predict_var_info(&test_pts);
        assert!(cold_info.all_converged());
        assert_eq!(cold_info.warm_saved_iters, 0);
        for (w, c) in warm_vars.iter().zip(&cold_vars) {
            assert!((w - c).abs() < 1e-6 * (1.0 + c.abs()), "{w} vs {c}");
        }
        assert!(
            warm_info.warm_saved_iters > 0,
            "clustered test points should save iterations ({} groups)",
            4
        );
        assert!(warm_info.mvms < cold_info.mvms, "warm starts should cut MVMs");
    }

    #[test]
    fn preconditioned_training_path_matches_unpreconditioned() {
        // Same model, same estimator: the rank-16 preconditioned mll must
        // agree with the unpreconditioned one (both to solver/SLQ
        // accuracy), with fewer alpha-solve iterations at small sigma.
        let mut gp = setup(80, 12);
        gp.set_hypers(&[(0.5f64).ln(), 0.0, (0.05f64).ln()]);
        // Cold unpreconditioned alpha solve + mll.
        gp.alpha_cache = None;
        let (_, info0) = gp.alpha();
        let (mll0, _) = gp.mll(&Estimator::Exact, false).unwrap();
        // Cold preconditioned alpha solve + mll.
        gp.cg.precond = crate::solvers::PrecondOptions::rank(16);
        gp.alpha_cache = None;
        let (_, info1) = gp.alpha();
        let (mll1, _) = gp.mll(&Estimator::Exact, false).unwrap();
        assert!(
            (mll0 - mll1).abs() < 1e-4 * (1.0 + mll0.abs()),
            "{mll0} vs {mll1}"
        );
        assert!(info0.converged && info1.converged);
        assert!(
            info1.iters < info0.iters,
            "preconditioned alpha solve should take fewer iterations: {} vs {}",
            info1.iters,
            info0.iters
        );
    }

    #[test]
    fn predict_var_info_flags_non_converged_solves() {
        // Bugfix regression: a starved iteration budget must be *visible*
        // to callers instead of silently yielding garbage variances.
        let mut gp = setup(50, 9);
        gp.cg = CgOptions { tol: 1e-12, max_iters: 1, ..Default::default() };
        let (vars, info) = gp.predict_var_info(&[vec![0.7], vec![2.1]]);
        assert_eq!(vars.len(), 2);
        assert!(!info.all_converged());
        assert!(info.cols.iter().any(|c| !c.converged));
        assert!(info.worst_residual() > 1e-12);
    }

    /// The acceptance case of the confidence refactor: small-sigma RBF,
    /// preconditioner on — adaptive mode reaches the same tolerance the
    /// fixed 16-probe budget delivers with strictly fewer probes, and the
    /// interval machinery is threaded through `mll` via `last_logdet`.
    #[test]
    fn adaptive_slq_uses_fewer_probes_at_small_sigma() {
        let mut gp = setup(100, 21);
        gp.set_hypers(&[(0.5f64).ln(), 0.0, (0.05f64).ln()]);
        gp.cg.precond = crate::solvers::PrecondOptions::rank(8);
        let fixed_opts =
            SlqOptions { steps: 30, probes: 16, grads: false, seed: 7, ..Default::default() };
        let fixed = gp.logdet(&Estimator::Slq(fixed_opts), false).unwrap();
        assert_eq!(fixed.probes_used, 16);
        let tol = fixed.interval.half_width() * 2.0;
        let adaptive = gp
            .logdet(
                &Estimator::Slq(SlqOptions {
                    target_tol: Some(tol),
                    max_probes: 64,
                    ..fixed_opts
                }),
                false,
            )
            .unwrap();
        assert!(
            adaptive.probes_used < 16,
            "adaptive used {} probes vs fixed 16",
            adaptive.probes_used
        );
        assert!(adaptive.interval.half_width() <= tol);
        assert!(gp.reuse_precond_across_steps, "adaptive path should arm factor reuse");
        // mll threads the estimate (with interval) through last_logdet.
        let (_, _) = gp
            .mll(
                &Estimator::Slq(SlqOptions {
                    target_tol: Some(tol),
                    max_probes: 64,
                    ..fixed_opts
                }),
                false,
            )
            .unwrap();
        let last = gp.last_logdet.as_ref().expect("mll records last_logdet");
        assert!(last.probes_used >= 2);
        assert!(last.interval.half_width() <= tol);
    }

    /// A tight tolerance forces the preconditioner rank to grow until the
    /// pivoted-Cholesky trace error clears a tenth of it, and the grown
    /// factor survives the next hyper step (cross-step reuse).
    #[test]
    fn tight_tolerance_grows_precond_rank_and_reuses_factor() {
        let mut gp = setup(80, 22);
        gp.set_hypers(&[(0.5f64).ln(), 0.0, (0.05f64).ln()]);
        gp.cg.precond = crate::solvers::PrecondOptions { rank: 4, rel_tol: 0.0 };
        let _ = gp
            .logdet(
                &Estimator::Slq(SlqOptions {
                    steps: 30,
                    probes: 4,
                    grads: false,
                    seed: 3,
                    target_tol: Some(1e-3),
                    max_probes: 8,
                    ..Default::default()
                }),
                false,
            )
            .unwrap();
        assert!(gp.cg.precond.rank > 4, "rank stayed {}", gp.cg.precond.rank);
        let grown = gp.cg.precond.rank;
        let err = gp
            .pc_cache
            .as_ref()
            .and_then(|(_, pc)| pc.as_ref())
            .map(|p| p.trace_error())
            .unwrap();
        assert!(
            err <= 1e-4 || grown == 80,
            "growth stopped at rank {grown} with trace error {err}"
        );
        // The doubling loop appended pivots to one retained factor instead
        // of refactorizing at every bump, and the grown factor matches a
        // from-scratch factorization at the final rank bitwise.
        let (_, factor) = gp.pchol_cache.as_ref().expect("growth retains the factor");
        let scratch = pivoted_cholesky(&gp.op, factor.rank(), 0.0).unwrap();
        assert_eq!(factor.pivots, scratch.pivots);
        for (a, b) in factor.l.data.iter().zip(&scratch.l.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // The preconditioner now survives a hyper step instead of being
        // rebuilt — but the growth frontier does not (new kernel).
        gp.set_hypers(&[(0.4f64).ln(), 0.0, (0.06f64).ln()]);
        assert!(gp.pc_cache.is_some(), "reuse flag should keep the preconditioner");
        assert!(gp.pchol_cache.is_none(), "hyper change must drop the frontier");
        assert_eq!(gp.cg.precond.rank, grown);
    }

    /// White-box: a rank bump appends to the retained factor rather than
    /// refactorizing. The factor's cumulative MVM counter is inflated by
    /// hand before the bump — a rebuild would reset it, an append carries
    /// it forward — and the appended factor still matches a from-scratch
    /// run bitwise. Lowering the rank (or changing `rel_tol`) falls back
    /// to a fresh factorization.
    #[test]
    fn refresh_precond_appends_to_retained_factor() {
        let mut gp = setup(60, 23);
        gp.cg.precond = crate::solvers::PrecondOptions { rank: 5, rel_tol: 0.0 };
        gp.refresh_precond();
        let before_pivots = gp.pchol_cache.as_ref().unwrap().1.pivots.clone();
        assert_eq!(before_pivots.len(), 5);
        gp.pchol_cache.as_mut().unwrap().1.mvms += 1000;
        gp.cg.precond.rank = 12;
        gp.refresh_precond();
        {
            let (_, f) = gp.pchol_cache.as_ref().unwrap();
            assert!(f.mvms >= 1000, "factor was rebuilt, not grown");
            assert_eq!(f.rank(), 12);
            assert_eq!(&f.pivots[..5], &before_pivots[..]);
            let scratch = pivoted_cholesky(&gp.op, 12, 0.0).unwrap();
            assert_eq!(f.pivots, scratch.pivots);
            for (a, b) in f.l.data.iter().zip(&scratch.l.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // Shrinking the rank cannot truncate a grown factor — it rebuilds.
        gp.cg.precond.rank = 3;
        gp.refresh_precond();
        let (_, f) = gp.pchol_cache.as_ref().unwrap();
        assert!(f.mvms < 1000, "shrink must refactorize from scratch");
        assert_eq!(f.rank(), 3);
    }

    #[test]
    fn predictive_variance_shrinks_near_data() {
        let mut gp = setup(50, 7);
        let near = gp.op.points[0].clone();
        let far = vec![50.0];
        let vars = gp.predict_var(&[near, far]);
        assert!(vars[0] < vars[1], "{vars:?}");
        // Far away: prior variance + noise.
        let want_far = gp.op.prior_var(&[50.0]) + gp.op.noise_var();
        assert!((vars[1] - want_far).abs() < 0.05 * want_far);
    }
}
