//! Gaussian-process models built on the estimators: Gaussian-likelihood
//! regression, Laplace-approximated non-Gaussian models (LGCP), and deep
//! kernel learning.
pub mod dkl;
pub mod laplace;
pub mod likelihoods;
pub mod regression;

pub use regression::{Estimator, GpRegression, PredictiveOp};
