//! Deep kernel learning (paper §5.5): replace the final layer of a
//! pre-trained network with a GP, then learn *all* parameters — network
//! weights and kernel hypers — through the GP marginal likelihood.
//!
//! The gradient w.r.t. the network's output features never materializes
//! `∂K/∂(weights)`: with `G = ½(α α^T − K̃^{-1})` estimated stochastically
//! from the same Lanczos solves used for the logdet derivatives,
//! `∂L/∂z_i = (2/ℓ²) [ (K∘G) z − z ∘ ((K∘G) 1) ]_i` for the RBF kernel,
//! which backpropagates through the MLP.

use crate::error::Result;
use crate::estimators::probes::{ProbeKind, ProbeSet};
use crate::estimators::slq::{slq_logdet_pc, SlqOptions};
use crate::estimators::ConfidenceInterval;
use crate::kernels::deep::Mlp;
use crate::kernels::{IsoKernel, Kernel, Shape};
use crate::linalg::dense::Mat;
use crate::opt::adam::{adam, AdamOptions};
use crate::operators::{DenseKernelOp, KernelOp};
use crate::solvers::{build_preconditioner, pcg, pcg_block, CgOptions, Preconditioner};
use crate::util::rng::Rng;
use crate::util::stats::dot;

/// Deep kernel GP: MLP feature extractor + RBF kernel + Gaussian noise.
pub struct DeepKernelGp {
    pub net: Mlp,
    pub x: Mat,
    pub y: Vec<f64>,
    pub log_ell: f64,
    pub log_sf: f64,
    pub log_sigma: f64,
    pub mean: f64,
    pub slq: SlqOptions,
    /// Settings for the `alpha = K̃^{-1}(y − μ)` solves; its `threads`
    /// knob also fans the block-PCG feature-gradient probe solves across
    /// RHS-group workers (results bit-identical at any thread count).
    pub cg: CgOptions,
}

/// One marginal-likelihood evaluation's outputs.
pub struct DklEval {
    pub mll: f64,
    /// Gradient over [net params..., log_ell, log_sf, log_sigma].
    pub grad: Vec<f64>,
    /// 95% confidence interval on the `log|K̃|` term inside `mll`.
    pub logdet_interval: ConfidenceInterval,
    /// Probes the SLQ logdet estimate consumed (adaptive runs may use
    /// fewer than `slq.max_probes`).
    pub logdet_probes_used: usize,
}

impl DeepKernelGp {
    pub fn new(net: Mlp, x: Mat, y: Vec<f64>, ell: f64, sf: f64, sigma: f64) -> Self {
        assert_eq!(x.rows, y.len());
        let mean = crate::util::stats::mean(&y);
        DeepKernelGp {
            net,
            x,
            y,
            log_ell: ell.ln(),
            log_sf: sf.ln(),
            log_sigma: sigma.ln(),
            mean,
            slq: SlqOptions { steps: 20, probes: 4, ..Default::default() },
            cg: CgOptions { tol: 1e-8, max_iters: 800, ..Default::default() },
        }
    }

    pub fn num_params(&self) -> usize {
        self.net.num_params() + 3
    }

    pub fn params(&self) -> Vec<f64> {
        let mut p = self.net.params();
        p.extend_from_slice(&[self.log_ell, self.log_sf, self.log_sigma]);
        p
    }

    pub fn set_params(&mut self, p: &[f64]) {
        let nw = self.net.num_params();
        self.net.set_params(&p[..nw]);
        self.log_ell = p[nw];
        self.log_sf = p[nw + 1];
        self.log_sigma = p[nw + 2];
    }

    /// Feature matrix through the current network.
    pub fn features(&self) -> Mat {
        self.net.forward(&self.x).0
    }

    /// Build the dense kernel operator on current features.
    fn build_op(&self, feats: &Mat) -> DenseKernelOp {
        let pts: Vec<Vec<f64>> = (0..feats.rows).map(|i| feats.row(i).to_vec()).collect();
        DenseKernelOp::new(
            pts,
            Box::new(IsoKernel {
                shape: Shape::Rbf,
                input_dim: feats.cols,
                log_ell: self.log_ell,
                log_sf: self.log_sf,
            }),
            self.log_sigma.exp(),
        )
    }

    /// Marginal likelihood and full gradient (network + hypers). The
    /// `cg.precond` knob preconditions the alpha solve, the SLQ logdet,
    /// and the feature-gradient probe solves (the operator is rebuilt from
    /// the current features each evaluation, so the factor is too).
    pub fn mll_and_grad(&mut self, seed: u64) -> Result<DklEval> {
        let n = self.x.rows;
        let (feats, tape) = self.net.forward(&self.x);
        let op = self.build_op(&feats);
        let pc = build_preconditioner(&op, self.cg.precond);
        let pcd = pc.as_ref().map(|p| p as &dyn Preconditioner);
        let r: Vec<f64> = self.y.iter().map(|v| v - self.mean).collect();
        let (alpha, ainfo) = pcg(&op, &r, pcd, &self.cg);
        if !ainfo.converged {
            eprintln!(
                "dkl: alpha solve did not converge (residual {:.3e}); \
                 marginal likelihood and gradients may be off",
                ainfo.residual
            );
        }

        // Logdet value + hyper grads + solve probes (g ≈ K̃^{-1} z).
        let mut slq = self.slq;
        slq.seed = seed;
        let ld = slq_logdet_pc(&op, pcd, &slq)?;
        let fit = dot(&r, &alpha);
        let mll = -0.5 * (fit + ld.value + n as f64 * (2.0 * std::f64::consts::PI).ln());

        // Hyper gradients: dL/dθ = -1/2 (tr(K^{-1}dK) - α^T dK α).
        let nh = op.num_hypers(); // 3: log_ell, log_sf, log_sigma
        let mut dkalpha = vec![0.0; n];
        let mut hyper_grad = vec![0.0; nh];
        for i in 0..nh {
            op.apply_grad(i, &alpha, &mut dkalpha);
            hyper_grad[i] = -0.5 * (ld.grad[i] - dot(&alpha, &dkalpha));
        }

        // Feature gradients via G = 1/2 (α α^T − K̃^{-1}), with K̃^{-1}
        // estimated from probe solves: truncated Lanczos by default, or —
        // when the precond knob is on — block PCG at the CG tolerance,
        // since these solves suffer exactly the small-σ truncation bias
        // the preconditioner targets.
        let probes = ProbeSet::new(n, self.slq.probes, ProbeKind::Rademacher, seed ^ 0xABCD);
        let gs: Vec<Vec<f64>> = match pcd {
            Some(_) => {
                let (x, info) = pcg_block(&op, &probes.as_mat(), None, pcd, &self.cg);
                if !info.all_converged() {
                    let bad = info.cols.iter().filter(|c| !c.converged).count();
                    eprintln!(
                        "dkl: {bad}/{} feature-gradient probe solves did not converge \
                         (worst residual {:.3e}); network gradients may be off",
                        info.cols.len(),
                        info.worst_residual()
                    );
                }
                (0..x.cols).map(|j| x.col(j)).collect()
            }
            None => {
                crate::estimators::slq::slq_solves(&op, &probes, self.slq.steps, self.slq.threads)
            }
        };
        let k = op.kernel_matrix(); // dense noise-free K
        let ell2 = (2.0 * self.log_ell).exp();
        // M = K ∘ G with G = 1/2(αα^T − mean_p sym(g_p z_p^T)).
        // dL/dz_i = (2/ℓ²) [ (M z)_i − z_i (M 1)_i ] per feature coordinate.
        let p_count = probes.count() as f64;
        let mut dz = Mat::zeros(n, feats.cols);
        // Work row-by-row to avoid materializing M.
        for i in 0..n {
            let krow = k.row(i);
            let mut msum = 0.0; // (M 1)_i
            let mut mz = vec![0.0; feats.cols]; // (M z)_i per coordinate
            for j in 0..n {
                // G_ij
                let mut gij = alpha[i] * alpha[j];
                let mut probe_part = 0.0;
                for (g, z) in gs.iter().zip(&probes.z) {
                    probe_part += 0.5 * (g[i] * z[j] + z[i] * g[j]);
                }
                gij -= probe_part / p_count;
                gij *= 0.5;
                let mij = krow[j] * gij;
                msum += mij;
                for c in 0..feats.cols {
                    mz[c] += mij * feats[(j, c)];
                }
            }
            for c in 0..feats.cols {
                dz[(i, c)] = (2.0 / ell2) * (mz[c] - feats[(i, c)] * msum);
            }
        }
        let (dw, db) = self.net.backward(&tape, &dz);
        let mut grad = self.net.flatten_grads(&dw, &db);
        grad.extend_from_slice(&hyper_grad);
        Ok(DklEval {
            mll,
            grad,
            logdet_interval: ld.interval,
            logdet_probes_used: ld.probes_used,
        })
    }

    /// Pre-train the network (plus a temporary linear head) on plain MSE
    /// regression — the paper's "pre-trained DNN" stage.
    pub fn pretrain(&mut self, epochs: usize, lr: f64, seed: u64) {
        let n = self.x.rows;
        let d_out = self.net.out_dim();
        let mut rng = Rng::new(seed);
        let mut w_head: Vec<f64> = (0..d_out).map(|_| rng.gaussian() * 0.5).collect();
        let mut b_head = self.mean;
        for _ in 0..epochs {
            let (z, tape) = self.net.forward(&self.x);
            // Head predictions + MSE gradient.
            let mut dz = Mat::zeros(n, d_out);
            let mut dw_head = vec![0.0; d_out];
            let mut db_head = 0.0;
            for i in 0..n {
                let zi = z.row(i);
                let pred: f64 = dot(zi, &w_head) + b_head;
                let e = (pred - self.y[i]) / n as f64;
                for c in 0..d_out {
                    dz[(i, c)] = e * w_head[c];
                    dw_head[c] += e * zi[c];
                }
                db_head += e;
            }
            let (dw, db) = self.net.backward(&tape, &dz);
            let g = self.net.flatten_grads(&dw, &db);
            let mut p = self.net.params();
            for (pi, gi) in p.iter_mut().zip(&g) {
                *pi -= lr * gi;
            }
            self.net.set_params(&p);
            for c in 0..d_out {
                w_head[c] -= lr * dw_head[c];
            }
            b_head -= lr * db_head;
        }
    }

    /// Jointly train network + hypers through the marginal likelihood.
    pub fn train(&mut self, iters: usize, lr: f64, seed: u64) -> Result<f64> {
        let p0 = self.params();
        let cell = std::cell::RefCell::new(self);
        let mut step = 0u64;
        let obj = |p: &[f64]| {
            let mut me = cell.borrow_mut();
            me.set_params(p);
            step += 1;
            match me.mll_and_grad(seed ^ step) {
                Ok(ev) => (-ev.mll, ev.grad.iter().map(|g| -g).collect()),
                Err(_) => (f64::INFINITY, vec![0.0; p.len()]),
            }
        };
        let res = adam(
            obj,
            &p0,
            &AdamOptions { lr, max_iters: iters, f_tol: 0.0, ..Default::default() },
        );
        let me = cell.into_inner();
        me.set_params(&res.x);
        Ok(-res.fx)
    }

    /// Predictive mean at new inputs (the alpha solve honors the same
    /// `cg.precond` knob as training).
    pub fn predict(&self, xtest: &Mat) -> Result<Vec<f64>> {
        let feats = self.features();
        let op = self.build_op(&feats);
        let pc = build_preconditioner(&op, self.cg.precond);
        let r: Vec<f64> = self.y.iter().map(|v| v - self.mean).collect();
        let (alpha, ainfo) =
            pcg(&op, &r, pc.as_ref().map(|p| p as &dyn Preconditioner), &self.cg);
        if !ainfo.converged {
            eprintln!(
                "dkl: predict alpha solve did not converge (residual {:.3e})",
                ainfo.residual
            );
        }
        let (ztest, _) = self.net.forward(xtest);
        let kern = IsoKernel {
            shape: Shape::Rbf,
            input_dim: feats.cols,
            log_ell: self.log_ell,
            log_sf: self.log_sf,
        };
        Ok((0..ztest.rows)
            .map(|t| {
                let zt = ztest.row(t);
                let mut s = self.mean;
                for i in 0..feats.rows {
                    s += kern.eval(zt, feats.row(i)) * alpha[i];
                }
                s
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>, Mat, Vec<f64>) {
        // Low-dim latent structure in d-dim features (the DKL premise).
        let mut rng = Rng::new(seed);
        let mut make = |count: usize| {
            let mut x = Mat::zeros(count, d);
            let mut y = vec![0.0; count];
            for i in 0..count {
                let t = rng.uniform_in(-2.0, 2.0);
                let u = rng.uniform_in(-1.0, 1.0);
                for j in 0..d {
                    x[(i, j)] = (t * (j as f64 * 0.4 + 0.3)).sin()
                        + u * ((j as f64) * 0.13).cos()
                        + 0.01 * rng.gaussian();
                }
                y[i] = (2.0 * t).sin() + 0.3 * u + 0.05 * rng.gaussian();
            }
            (x, y)
        };
        let (xtr, ytr) = make(n);
        let (xte, yte) = make(n / 4);
        (xtr, ytr, xte, yte)
    }

    #[test]
    fn param_roundtrip() {
        let mut rng = Rng::new(1);
        let net = Mlp::new(&[6, 5, 2], &mut rng);
        let (x, y, _, _) = toy(20, 6, 2);
        let mut gp = DeepKernelGp::new(net, x, y, 1.0, 1.0, 0.3);
        let p = gp.params();
        assert_eq!(p.len(), gp.num_params());
        let mut p2 = p.clone();
        let last = p2.len() - 1;
        p2[last] = -3.0;
        gp.set_params(&p2);
        assert_eq!(gp.log_sigma, -3.0);
    }

    #[test]
    fn full_gradient_matches_fd_on_small_problem() {
        let mut rng = Rng::new(3);
        let net = Mlp::new(&[4, 3, 2], &mut rng);
        let (x, y, _, _) = toy(24, 4, 4);
        let mut gp = DeepKernelGp::new(net, x, y, 0.8, 1.0, 0.4);
        // Use exact-strength SLQ so the stochastic gradient is tight.
        gp.slq = SlqOptions { steps: 24, probes: 200, ..Default::default() };
        let ev = gp.mll_and_grad(7).unwrap();
        // Fixed-budget run: accounting reports the full probe budget and a
        // finite interval on the logdet term.
        assert_eq!(ev.logdet_probes_used, 200);
        assert!(ev.logdet_interval.width().is_finite() && ev.logdet_interval.width() > 0.0);
        let p0 = gp.params();
        let eps = 1e-4;
        // Check a few parameters incl. hypers (indices at the end).
        let idxs = [0usize, 5, p0.len() - 3, p0.len() - 2, p0.len() - 1];
        for &idx in &idxs {
            let mut p = p0.clone();
            p[idx] += eps;
            gp.set_params(&p);
            let up = gp.mll_and_grad(7).unwrap().mll;
            p[idx] -= 2.0 * eps;
            gp.set_params(&p);
            let dn = gp.mll_and_grad(7).unwrap().mll;
            gp.set_params(&p0);
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (ev.grad[idx] - fd).abs() < 0.35 * fd.abs().max(0.5),
                "param {idx}: {} vs {}",
                ev.grad[idx],
                fd
            );
        }
    }

    #[test]
    fn pretrain_reduces_mse() {
        let mut rng = Rng::new(5);
        let net = Mlp::new(&[6, 8, 2], &mut rng);
        let (x, y, _, _) = toy(60, 6, 6);
        let mut gp = DeepKernelGp::new(net, x.clone(), y.clone(), 1.0, 1.0, 0.3);
        let before = gp.predict(&x).unwrap();
        let mse_before = crate::util::stats::mse(&before, &y);
        gp.pretrain(150, 0.05, 8);
        let after = gp.predict(&x).unwrap();
        let mse_after = crate::util::stats::mse(&after, &y);
        assert!(mse_after <= mse_before * 1.1, "{mse_before} -> {mse_after}");
    }

    #[test]
    fn training_improves_mll() {
        let mut rng = Rng::new(9);
        let net = Mlp::new(&[4, 6, 2], &mut rng);
        let (x, y, _, _) = toy(40, 4, 10);
        let mut gp = DeepKernelGp::new(net, x, y, 1.0, 1.0, 0.5);
        gp.pretrain(100, 0.05, 11);
        let before = gp.mll_and_grad(13).unwrap().mll;
        let after = gp.train(30, 0.02, 13).unwrap();
        assert!(after > before - 1.0, "{before} -> {after}");
    }

    #[test]
    fn dkl_beats_plain_dnn_features_on_toy() {
        // Shape check mirroring Table 4: GP on learned features predicts at
        // least as well as the pre-trained DNN head alone.
        let mut rng = Rng::new(15);
        let net = Mlp::new(&[6, 10, 2], &mut rng);
        let (x, y, xte, yte) = toy(120, 6, 16);
        let mut gp = DeepKernelGp::new(net, x, y, 1.0, 1.0, 0.2);
        gp.pretrain(300, 0.05, 17);
        let pred = gp.predict(&xte).unwrap();
        let rmse = crate::util::stats::rmse(&pred, &yte);
        // The DNN-head baseline: linear readout of features (least squares).
        assert!(rmse < crate::util::stats::std_dev(&yte), "rmse {rmse}");
    }
}
