//! Observation likelihoods. The Gaussian case folds into the kernel (noise
//! σ²); Poisson and negative-binomial drive the log-Gaussian Cox process
//! experiments (§5.3 Hickory, §5.4 crime) through the Laplace
//! approximation, which needs the log-density and its first two derivatives
//! in the latent function f.

/// Non-Gaussian likelihood over counts with latent log-intensity f.
#[derive(Clone, Copy, Debug)]
pub enum Likelihood {
    /// y ~ Poisson(exp(f + offset)).
    Poisson { offset: f64 },
    /// y ~ NegBinomial(mean = exp(f + offset), dispersion r): variance
    /// mean + mean^2 / r (r -> inf recovers Poisson).
    NegBinomial { offset: f64, r: f64 },
}

impl Likelihood {
    /// log p(y | f) for one observation (up to y-only constants).
    pub fn logp(&self, y: f64, f: f64) -> f64 {
        match *self {
            Likelihood::Poisson { offset } => {
                let eta = f + offset;
                y * eta - eta.exp()
            }
            Likelihood::NegBinomial { offset, r } => {
                // log p = y log(mu/(mu+r)) + r log(r/(mu+r)) + const(y, r)
                let mu = (f + offset).exp();
                y * (mu.ln() - (mu + r).ln()) + r * (r.ln() - (mu + r).ln())
            }
        }
    }

    /// d log p / d f.
    pub fn dlogp(&self, y: f64, f: f64) -> f64 {
        match *self {
            Likelihood::Poisson { offset } => y - (f + offset).exp(),
            Likelihood::NegBinomial { offset, r } => {
                let mu = (f + offset).exp();
                (y - mu) * r / (mu + r)
            }
        }
    }

    /// -d² log p / d f² (the Laplace W weights; nonnegative for these
    /// log-concave likelihoods).
    pub fn neg_d2logp(&self, y: f64, f: f64) -> f64 {
        match *self {
            Likelihood::Poisson { offset } => (f + offset).exp(),
            Likelihood::NegBinomial { offset, r } => {
                let mu = (f + offset).exp();
                // d/df [ (y - mu) r / (mu + r) ] = -mu r (y + r) / (mu+r)^2
                mu * r * (y + r) / ((mu + r) * (mu + r))
            }
        }
    }

    /// Total log likelihood over vectors.
    pub fn logp_sum(&self, y: &[f64], f: &[f64]) -> f64 {
        y.iter().zip(f).map(|(&yi, &fi)| self.logp(yi, fi)).sum()
    }

    /// Predicted mean count at latent f.
    pub fn mean(&self, f: f64) -> f64 {
        match *self {
            Likelihood::Poisson { offset } | Likelihood::NegBinomial { offset, .. } => {
                (f + offset).exp()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd_check(lik: Likelihood, y: f64, f: f64) {
        let eps = 1e-6;
        let d = lik.dlogp(y, f);
        let fd = (lik.logp(y, f + eps) - lik.logp(y, f - eps)) / (2.0 * eps);
        assert!((d - fd).abs() < 1e-5 * (1.0 + fd.abs()), "dlogp {} vs {}", d, fd);
        let d2 = -lik.neg_d2logp(y, f);
        let fd2 = (lik.dlogp(y, f + eps) - lik.dlogp(y, f - eps)) / (2.0 * eps);
        assert!((d2 - fd2).abs() < 1e-4 * (1.0 + fd2.abs()), "d2 {} vs {}", d2, fd2);
    }

    #[test]
    fn poisson_derivatives() {
        for &(y, f) in &[(0.0, -1.0), (3.0, 0.5), (10.0, 2.0)] {
            fd_check(Likelihood::Poisson { offset: 0.3 }, y, f);
        }
    }

    #[test]
    fn negbinomial_derivatives() {
        for &(y, f) in &[(0.0, -1.0), (3.0, 0.5), (12.0, 1.5)] {
            fd_check(Likelihood::NegBinomial { offset: 0.1, r: 4.0 }, y, f);
        }
    }

    #[test]
    fn w_nonnegative() {
        let liks = [
            Likelihood::Poisson { offset: 0.0 },
            Likelihood::NegBinomial { offset: 0.0, r: 2.0 },
        ];
        for lik in liks {
            for f in [-3.0, 0.0, 2.0] {
                for y in [0.0, 1.0, 7.0] {
                    assert!(lik.neg_d2logp(y, f) >= 0.0);
                }
            }
        }
    }

    #[test]
    fn negbinomial_limits_to_poisson() {
        // Large r: neg-binomial ~ Poisson.
        let nb = Likelihood::NegBinomial { offset: 0.0, r: 1e7 };
        let po = Likelihood::Poisson { offset: 0.0 };
        let (y, f) = (4.0, 1.2);
        assert!((nb.dlogp(y, f) - po.dlogp(y, f)).abs() < 1e-5);
        assert!((nb.neg_d2logp(y, f) - po.neg_d2logp(y, f)).abs() < 1e-4);
    }
}
