//! Regular inducing-point grids and local interpolation weights — the "I"
//! in SKI (Wilson & Nickisch 2015). Cubic convolution interpolation (Keys
//! 1981) gives 4 weights per dimension; tensor products across dimensions
//! give each data point 4^d sparse weights in W.

use crate::operators::sparse::Csr;

/// One grid dimension: `m` equispaced points spanning `[lo, hi]`.
#[derive(Clone, Copy, Debug)]
pub struct GridDim {
    pub lo: f64,
    pub hi: f64,
    pub m: usize,
}

impl GridDim {
    pub fn spacing(&self) -> f64 {
        if self.m <= 1 {
            return 1.0;
        }
        (self.hi - self.lo) / (self.m - 1) as f64
    }

    pub fn point(&self, i: usize) -> f64 {
        self.lo + self.spacing() * i as f64
    }
}

/// Cartesian-product grid. Row-major linearization: the **last** dimension
/// varies fastest (matches [`crate::operators::kron::KronOp`]).
#[derive(Clone, Debug)]
pub struct Grid {
    pub dims: Vec<GridDim>,
}

/// Interpolation order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InterpOrder {
    /// 2 points per dim.
    Linear,
    /// 4 points per dim (cubic convolution, Keys a=-1/2) — SKI's default.
    Cubic,
}

/// Per-dimension interpolation stencil for one point: grid indices and
/// weights (already boundary-clamped).
#[derive(Clone, Debug)]
pub struct Stencil {
    pub idx: Vec<usize>,
    pub w: Vec<f64>,
}

impl Grid {
    pub fn new(dims: Vec<GridDim>) -> Self {
        assert!(!dims.is_empty());
        Grid { dims }
    }

    /// Convenience: grid covering the data's bounding box with margins.
    pub fn covering(points: &[Vec<f64>], ms: &[usize], margin_frac: f64) -> Self {
        let d = ms.len();
        let mut dims = Vec::with_capacity(d);
        for j in 0..d {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for p in points {
                lo = lo.min(p[j]);
                hi = hi.max(p[j]);
            }
            let span = (hi - lo).max(1e-12);
            dims.push(GridDim {
                lo: lo - margin_frac * span,
                hi: hi + margin_frac * span,
                m: ms[j],
            });
        }
        Grid::new(dims)
    }

    pub fn ndims(&self) -> usize {
        self.dims.len()
    }

    /// Total number of grid points.
    pub fn size(&self) -> usize {
        self.dims.iter().map(|d| d.m).product()
    }

    /// Multi-index -> linear index (last dim fastest).
    pub fn lin_index(&self, sub: &[usize]) -> usize {
        let mut idx = 0;
        for (j, d) in self.dims.iter().enumerate() {
            idx = idx * d.m + sub[j];
        }
        idx
    }

    /// Grid point coordinates for a linear index.
    pub fn point(&self, mut lin: usize) -> Vec<f64> {
        let d = self.ndims();
        let mut sub = vec![0usize; d];
        for j in (0..d).rev() {
            sub[j] = lin % self.dims[j].m;
            lin /= self.dims[j].m;
        }
        sub.iter().zip(&self.dims).map(|(&s, dim)| dim.point(s)).collect()
    }

    /// 1-D stencil for coordinate `x` in dimension `j`.
    pub fn stencil_1d(&self, j: usize, x: f64, order: InterpOrder) -> Stencil {
        let dim = &self.dims[j];
        let m = dim.m;
        let h = dim.spacing();
        // Position in grid units, clamped to the grid's span.
        let t = ((x - dim.lo) / h).clamp(0.0, (m - 1) as f64);
        match order {
            InterpOrder::Linear => {
                let i0 = (t.floor() as usize).min(m.saturating_sub(2));
                if m == 1 {
                    return Stencil { idx: vec![0], w: vec![1.0] };
                }
                let u = t - i0 as f64;
                Stencil { idx: vec![i0, i0 + 1], w: vec![1.0 - u, u] }
            }
            InterpOrder::Cubic => {
                if m < 4 {
                    // Degenerate tiny grids fall back to linear.
                    return self.stencil_1d(j, x, InterpOrder::Linear);
                }
                let i0 = t.floor() as isize;
                let u = t - i0 as f64;
                // Keys cubic convolution weights (a = -1/2), exact for
                // cubics, C1 continuous.
                let w = [
                    ((-0.5 * u + 1.0) * u - 0.5) * u,
                    (1.5 * u - 2.5) * u * u + 1.0,
                    ((-1.5 * u + 2.0) * u + 0.5) * u,
                    (0.5 * u - 0.5) * u * u,
                ];
                let mut idx = Vec::with_capacity(4);
                let mut wout = Vec::with_capacity(4);
                for (k, &wk) in w.iter().enumerate() {
                    // Offsets -1, 0, 1, 2 relative to i0; clamp at edges
                    // (accumulate weight onto the boundary point).
                    let raw = i0 + k as isize - 1;
                    let clamped = raw.clamp(0, (m - 1) as isize) as usize;
                    if let Some(pos) = idx.iter().position(|&p| p == clamped) {
                        wout[pos] += wk;
                    } else {
                        idx.push(clamped);
                        wout.push(wk);
                    }
                }
                Stencil { idx, w: wout }
            }
        }
    }

    /// Per-dimension stencils for a point.
    pub fn stencils(&self, x: &[f64], order: InterpOrder) -> Vec<Stencil> {
        (0..self.ndims()).map(|j| self.stencil_1d(j, x[j], order)).collect()
    }

    /// Sparse interpolation matrix W (n x grid size): tensor products of the
    /// 1-D stencils. Also returns the per-point per-dim stencils, which the
    /// SKI diagonal correction reuses (O(16 d) per point instead of 16^d).
    pub fn interp_matrix(
        &self,
        points: &[Vec<f64>],
        order: InterpOrder,
    ) -> (Csr, Vec<Vec<Stencil>>) {
        let mut rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(points.len());
        let mut all_stencils = Vec::with_capacity(points.len());
        for p in points {
            let sts = self.stencils(p, order);
            // Tensor-product expansion.
            let mut entries: Vec<(usize, f64)> = vec![(0usize, 1.0)];
            for (j, st) in sts.iter().enumerate() {
                let mut next = Vec::with_capacity(entries.len() * st.idx.len());
                for &(base, bw) in &entries {
                    for (gi, gw) in st.idx.iter().zip(&st.w) {
                        next.push((base * self.dims[j].m + gi, bw * gw));
                    }
                }
                entries = next;
            }
            // Merge duplicate columns (possible after boundary clamping).
            entries.sort_by_key(|e| e.0);
            let mut merged: Vec<(usize, f64)> = Vec::with_capacity(entries.len());
            for (c, v) in entries {
                if let Some(last) = merged.last_mut() {
                    if last.0 == c {
                        last.1 += v;
                        continue;
                    }
                }
                merged.push((c, v));
            }
            rows.push(merged);
            all_stencils.push(sts);
        }
        (Csr::from_rows(self.size(), rows.as_slice()), all_stencils)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid1d(m: usize) -> Grid {
        Grid::new(vec![GridDim { lo: 0.0, hi: 1.0, m }])
    }

    #[test]
    fn weights_sum_to_one() {
        let g = grid1d(20);
        for &x in &[0.0, 0.013, 0.5, 0.77, 0.999, 1.0] {
            for order in [InterpOrder::Linear, InterpOrder::Cubic] {
                let st = g.stencil_1d(0, x, order);
                let s: f64 = st.w.iter().sum();
                assert!((s - 1.0).abs() < 1e-12, "x={x} {order:?} sum={s}");
            }
        }
    }

    #[test]
    fn cubic_exact_on_quadratics() {
        // Keys (a=-1/2) cubic convolution is 3rd-order accurate: exact for
        // polynomials up to degree 2, away from boundaries.
        let g = grid1d(30);
        let f = |x: f64| 2.0 + 3.0 * x - x * x;
        let vals: Vec<f64> = (0..30).map(|i| f(g.dims[0].point(i))).collect();
        for &x in &[0.21, 0.43, 0.67, 0.85] {
            let st = g.stencil_1d(0, x, InterpOrder::Cubic);
            let approx: f64 = st.idx.iter().zip(&st.w).map(|(&i, &w)| w * vals[i]).sum();
            assert!((approx - f(x)).abs() < 1e-10, "x={x}");
        }
    }

    #[test]
    fn linear_exact_on_lines() {
        let g = grid1d(10);
        let f = |x: f64| 1.0 - 4.0 * x;
        let vals: Vec<f64> = (0..10).map(|i| f(g.dims[0].point(i))).collect();
        for &x in &[0.05, 0.5, 0.94] {
            let st = g.stencil_1d(0, x, InterpOrder::Linear);
            let approx: f64 = st.idx.iter().zip(&st.w).map(|(&i, &w)| w * vals[i]).sum();
            assert!((approx - f(x)).abs() < 1e-12);
        }
    }

    #[test]
    fn lin_index_roundtrip() {
        let g = Grid::new(vec![
            GridDim { lo: 0.0, hi: 1.0, m: 3 },
            GridDim { lo: -1.0, hi: 1.0, m: 4 },
        ]);
        assert_eq!(g.size(), 12);
        for lin in 0..12 {
            let p = g.point(lin);
            // Reconstruct sub-indices from coordinates.
            let s0 = ((p[0] - 0.0) / g.dims[0].spacing()).round() as usize;
            let s1 = ((p[1] + 1.0) / g.dims[1].spacing()).round() as usize;
            assert_eq!(g.lin_index(&[s0, s1]), lin);
        }
    }

    #[test]
    fn interp_matrix_rows_sum_to_one() {
        let g = Grid::new(vec![
            GridDim { lo: 0.0, hi: 1.0, m: 8 },
            GridDim { lo: 0.0, hi: 2.0, m: 6 },
        ]);
        let pts = vec![vec![0.3, 0.5], vec![0.9, 1.9], vec![0.0, 0.0]];
        let (w, st) = g.interp_matrix(&pts, InterpOrder::Cubic);
        assert_eq!(w.nrows, 3);
        assert_eq!(w.ncols, 48);
        assert_eq!(st.len(), 3);
        for i in 0..3 {
            let (_, vals) = w.row(i);
            let s: f64 = vals.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn interpolates_2d_bilinear_function() {
        // f(x,y) = x*y is bilinear; cubic interpolation over a fine grid
        // should approximate it very well in the interior.
        let g = Grid::new(vec![
            GridDim { lo: 0.0, hi: 1.0, m: 16 },
            GridDim { lo: 0.0, hi: 1.0, m: 16 },
        ]);
        let grid_vals: Vec<f64> =
            (0..g.size()).map(|i| { let p = g.point(i); p[0] * p[1] }).collect();
        let pts = vec![vec![0.37, 0.61], vec![0.52, 0.18]];
        let (w, _) = g.interp_matrix(&pts, InterpOrder::Cubic);
        let mut out = vec![0.0; 2];
        w.apply(&grid_vals, &mut out);
        for (p, o) in pts.iter().zip(&out) {
            assert!((o - p[0] * p[1]).abs() < 1e-6, "{o} vs {}", p[0] * p[1]);
        }
    }

    #[test]
    fn covering_grid_bounds() {
        let pts = vec![vec![1.0], vec![3.0], vec![2.0]];
        let g = Grid::covering(&pts, &[5], 0.1);
        assert!(g.dims[0].lo < 1.0);
        assert!(g.dims[0].hi > 3.0);
    }
}
