//! Symmetric Toeplitz operator with O(m log m) MVMs via circulant
//! embedding — the structure SKI exploits on 1-D grids (paper §2: "if K_UU
//! is Toeplitz, each MVM with the approximate K_XX costs only
//! O(n + m log m)").

use super::LinOp;
use crate::linalg::fft::{fft_in_place, next_pow2, rfft, Cpx};

/// Symmetric Toeplitz matrix given by its first column, with a cached FFT
/// of the circulant embedding.
pub struct ToeplitzOp {
    /// First column, length m.
    pub col: Vec<f64>,
    /// FFT length (power of two >= 2m - 1).
    len: usize,
    /// FFT of the circulant's first column.
    circ_fft: Vec<Cpx>,
}

impl ToeplitzOp {
    pub fn new(col: Vec<f64>) -> Self {
        let m = col.len();
        assert!(m > 0);
        let len = next_pow2((2 * m).saturating_sub(1).max(1));
        // Circulant first column: [c0 .. c_{m-1}, 0 .., c_{m-1} .. c_1].
        let mut circ = vec![0.0; len];
        circ[..m].copy_from_slice(&col);
        for k in 1..m {
            circ[len - k] = col[k];
        }
        let circ_fft = rfft(&circ, len);
        ToeplitzOp { col, len, circ_fft }
    }

    pub fn m(&self) -> usize {
        self.col.len()
    }

    /// Apply into a caller-provided FFT scratch buffer (used by the Kron
    /// fiber loop to avoid per-fiber allocation).
    pub fn apply_with_scratch(&self, x: &[f64], y: &mut [f64], scratch: &mut Vec<Cpx>) {
        let m = self.m();
        assert_eq!(x.len(), m);
        assert_eq!(y.len(), m);
        scratch.clear();
        scratch.resize(self.len, Cpx::default());
        for (i, &v) in x.iter().enumerate() {
            scratch[i] = Cpx::new(v, 0.0);
        }
        fft_in_place(scratch, false);
        for (s, c) in scratch.iter_mut().zip(&self.circ_fft) {
            *s = s.mul(*c);
        }
        fft_in_place(scratch, true);
        let scale = 1.0 / self.len as f64;
        for i in 0..m {
            y[i] = scratch[i].re * scale;
        }
    }

    /// Dense materialization (for the scaled-eigenvalue baseline's factor
    /// eigendecompositions and for tests).
    pub fn to_dense_mat(&self) -> crate::linalg::dense::Mat {
        let m = self.m();
        crate::linalg::dense::Mat::from_fn(m, m, |i, j| {
            self.col[i.abs_diff(j)]
        })
    }
}

impl LinOp for ToeplitzOp {
    fn n(&self) -> usize {
        self.m()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let mut scratch = Vec::new();
        self.apply_with_scratch(x, y, &mut scratch);
    }
    fn to_dense(&self) -> crate::linalg::dense::Mat {
        self.to_dense_mat()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_apply(col: &[f64], x: &[f64]) -> Vec<f64> {
        let m = col.len();
        (0..m)
            .map(|i| (0..m).map(|j| col[i.abs_diff(j)] * x[j]).sum())
            .collect()
    }

    #[test]
    fn matches_naive_small() {
        let col = vec![4.0, 2.0, 1.0, 0.5];
        let op = ToeplitzOp::new(col.clone());
        let x = vec![1.0, -1.0, 2.0, 0.5];
        let got = op.apply_vec(&x);
        let want = naive_apply(&col, &x);
        for i in 0..4 {
            assert!((got[i] - want[i]).abs() < 1e-10, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn matches_naive_random_sizes() {
        let mut rng = Rng::new(77);
        for m in [1usize, 2, 3, 7, 16, 33, 100] {
            let col: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
            let x: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
            let op = ToeplitzOp::new(col.clone());
            let got = op.apply_vec(&x);
            let want = naive_apply(&col, &x);
            for i in 0..m {
                assert!((got[i] - want[i]).abs() < 1e-9, "m={m} i={i}");
            }
        }
    }

    #[test]
    fn dense_agrees() {
        let col = vec![3.0, 1.0, 0.2];
        let op = ToeplitzOp::new(col);
        let d = op.to_dense_mat();
        assert_eq!(d[(0, 2)], 0.2);
        assert_eq!(d[(2, 0)], 0.2);
        assert_eq!(d[(1, 1)], 3.0);
        let x = vec![0.5, -1.5, 2.0];
        let via_dense = d.matvec(&x);
        let via_fft = op.apply_vec(&x);
        for i in 0..3 {
            assert!((via_dense[i] - via_fft[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn identity_column() {
        let mut col = vec![0.0; 8];
        col[0] = 1.0;
        let op = ToeplitzOp::new(col);
        let x: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let y = op.apply_vec(&x);
        for i in 0..8 {
            assert!((y[i] - x[i]).abs() < 1e-10);
        }
    }
}
