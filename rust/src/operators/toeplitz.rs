//! Symmetric Toeplitz operator with O(m log m) MVMs via circulant
//! embedding — the structure SKI exploits on 1-D grids (paper §2: "if K_UU
//! is Toeplitz, each MVM with the approximate K_XX costs only
//! O(n + m log m)").
//!
//! Block applies share one circulant spectrum and one cached [`FftPlan`]
//! (bit-reversal + twiddle tables) across every probe column; the per-column
//! transforms are arithmetically identical to the single-vector path, so
//! blocked results are bitwise equal to column-by-column `apply`.
//!
//! Mixed precision (`Precision::F32F64`): the FFT *input/output staging*
//! buffers are the f32 part — the probe block is rounded once on the way
//! in and the result once on the way out, modeling f32 staging arrays
//! between the CSR gather and the transform — while the circulant
//! **spectrum and every FFT butterfly stay f64** (an f32 spectrum would
//! compound rounding across all log m stages). Error is therefore one
//! storage rounding on each side of an exact-in-f64 transform.

use super::LinOp;
use crate::linalg::dense::Mat;
use crate::linalg::fft::{next_pow2, rfft, Cpx, FftPlan};
use crate::util::obs;
use crate::util::parallel;
use crate::util::precision::Precision;

/// Symmetric Toeplitz matrix given by its first column, with a cached FFT
/// of the circulant embedding and a cached FFT plan.
pub struct ToeplitzOp {
    /// First column, length m.
    pub col: Vec<f64>,
    /// FFT length (power of two >= 2m - 1).
    len: usize,
    /// FFT of the circulant's first column.
    circ_fft: Vec<Cpx>,
    /// Shared transform plan (twiddles/bit-reversal computed once).
    plan: FftPlan,
}

impl ToeplitzOp {
    pub fn new(col: Vec<f64>) -> Self {
        let m = col.len();
        assert!(m > 0);
        let len = next_pow2((2 * m).saturating_sub(1).max(1));
        // Circulant first column: [c0 .. c_{m-1}, 0 .., c_{m-1} .. c_1].
        let mut circ = vec![0.0; len];
        circ[..m].copy_from_slice(&col);
        for k in 1..m {
            circ[len - k] = col[k];
        }
        let circ_fft = rfft(&circ, len);
        let plan = FftPlan::new(len);
        ToeplitzOp { col, len, circ_fft, plan }
    }

    pub fn m(&self) -> usize {
        self.col.len()
    }

    /// Apply into a caller-provided FFT scratch buffer (used by the Kron
    /// fiber loop and the blocked apply to avoid per-fiber allocation).
    pub fn apply_with_scratch(&self, x: &[f64], y: &mut [f64], scratch: &mut Vec<Cpx>) {
        let m = self.m();
        assert_eq!(x.len(), m);
        assert_eq!(y.len(), m);
        scratch.clear();
        scratch.resize(self.len, Cpx::default());
        for (i, &v) in x.iter().enumerate() {
            scratch[i] = Cpx::new(v, 0.0);
        }
        self.plan.process(scratch, false);
        for (s, c) in scratch.iter_mut().zip(&self.circ_fft) {
            *s = s.mul(*c);
        }
        self.plan.process(scratch, true);
        let scale = 1.0 / self.len as f64;
        for i in 0..m {
            y[i] = scratch[i].re * scale;
        }
    }

    /// Diagonal of the (constant-diagonal) Toeplitz matrix — feeds
    /// `KronOp::diag` and the pivoted-Cholesky preconditioner of the
    /// grid kernel operators.
    pub fn diag(&self) -> Vec<f64> {
        vec![self.col[0]; self.m()]
    }

    /// Dense materialization (for the scaled-eigenvalue baseline's factor
    /// eigendecompositions and for tests).
    pub fn to_dense_mat(&self) -> crate::linalg::dense::Mat {
        let m = self.m();
        crate::linalg::dense::Mat::from_fn(m, m, |i, j| {
            self.col[i.abs_diff(j)]
        })
    }
}

impl LinOp for ToeplitzOp {
    fn n(&self) -> usize {
        self.m()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let mut scratch = Vec::new();
        self.apply_with_scratch(x, y, &mut scratch);
    }
    /// Batched circulant MVM: one spectrum, one plan, one scratch buffer per
    /// worker; columns fan out across threads for large blocks.
    fn apply_mat(&self, x: &Mat) -> Mat {
        let m = self.m();
        assert_eq!(x.rows, m);
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let b = x.cols;
        let mut out = Mat::zeros(m, b);
        // ~len log2(len) complex ops per column.
        let fft_work = self.len * (self.len.trailing_zeros().max(1) as usize);
        let threads = if b >= 2 && fft_work * b >= 250_000 {
            parallel::default_threads().min(b)
        } else {
            1
        };
        if threads <= 1 {
            let mut scratch = Vec::new();
            let mut xin = vec![0.0; m];
            let mut y = vec![0.0; m];
            for j in 0..b {
                x.col_into(j, &mut xin);
                self.apply_with_scratch(&xin, &mut y, &mut scratch);
                out.set_col(j, &y);
            }
        } else {
            // One worker per column group; each worker reuses its scratch.
            let per = b.div_ceil(threads);
            let ngroups = b.div_ceil(per);
            let groups: Vec<Vec<Vec<f64>>> = parallel::par_map(ngroups, threads, |gi| {
                let j0 = gi * per;
                let j1 = (j0 + per).min(b);
                let mut scratch = Vec::new();
                let mut xin = vec![0.0; m];
                let mut cols = Vec::with_capacity(j1 - j0);
                for j in j0..j1 {
                    x.col_into(j, &mut xin);
                    let mut y = vec![0.0; m];
                    self.apply_with_scratch(&xin, &mut y, &mut scratch);
                    cols.push(y);
                }
                cols
            });
            for (gi, g) in groups.iter().enumerate() {
                for (k, y) in g.iter().enumerate() {
                    out.set_col(gi * per + k, y);
                }
            }
        }
        out
    }
    /// Mixed mode stages the block through f32 on both sides of the
    /// (still fully f64) circulant transform — see the module docs.
    fn apply_mat_prec(&self, x: &Mat, prec: Precision) -> Mat {
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        match prec {
            Precision::F64 => self.apply_mat(x),
            Precision::F32F64 => {
                let staged = Mat {
                    rows: x.rows,
                    cols: x.cols,
                    data: x.data.iter().map(|&v| f64::from(v as f32)).collect(),
                };
                let mut out = self.apply_mat(&staged);
                for v in out.data.iter_mut() {
                    *v = f64::from(*v as f32);
                }
                out
            }
        }
    }
    fn to_dense(&self) -> crate::linalg::dense::Mat {
        self.to_dense_mat()
    }
    fn obs_kind(&self) -> &'static str {
        "toeplitz"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_apply(col: &[f64], x: &[f64]) -> Vec<f64> {
        let m = col.len();
        (0..m)
            .map(|i| (0..m).map(|j| col[i.abs_diff(j)] * x[j]).sum())
            .collect()
    }

    #[test]
    fn matches_naive_small() {
        let col = vec![4.0, 2.0, 1.0, 0.5];
        let op = ToeplitzOp::new(col.clone());
        let x = vec![1.0, -1.0, 2.0, 0.5];
        let got = op.apply_vec(&x);
        let want = naive_apply(&col, &x);
        for i in 0..4 {
            assert!((got[i] - want[i]).abs() < 1e-10, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn matches_naive_random_sizes() {
        let mut rng = Rng::new(77);
        for m in [1usize, 2, 3, 7, 16, 33, 100] {
            let col: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
            let x: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
            let op = ToeplitzOp::new(col.clone());
            let got = op.apply_vec(&x);
            let want = naive_apply(&col, &x);
            for i in 0..m {
                assert!((got[i] - want[i]).abs() < 1e-9, "m={m} i={i}");
            }
        }
    }

    #[test]
    fn apply_mat_bitwise_matches_columns() {
        let mut rng = Rng::new(78);
        for m in [1usize, 5, 32, 65] {
            let col: Vec<f64> =
                (0..m).map(|k| (1.0 + rng.uniform()) * (-0.07 * k as f64).exp()).collect();
            let op = ToeplitzOp::new(col);
            let x = Mat::from_fn(m, 6, |_, _| rng.gaussian());
            let y = op.apply_mat(&x);
            for j in 0..6 {
                let want = op.apply_vec(&x.col(j));
                for i in 0..m {
                    assert_eq!(
                        y[(i, j)].to_bits(),
                        want[i].to_bits(),
                        "m={m} ({i},{j})"
                    );
                }
            }
        }
    }

    /// Mixed mode is exactly "round in, f64 transform, round out": pinned
    /// against that reference bitwise, and F64 mode is `apply_mat` itself.
    #[test]
    fn apply_mat_prec_matches_staging_reference() {
        let mut rng = Rng::new(91);
        let col: Vec<f64> = (0..33).map(|k| (-0.05 * k as f64).exp() * (1.0 + rng.uniform())).collect();
        let op = ToeplitzOp::new(col);
        let x = Mat::from_fn(33, 4, |_, _| rng.gaussian());
        let f64_path = op.apply_mat_prec(&x, Precision::F64);
        let plain = op.apply_mat(&x);
        for (a, b) in f64_path.data.iter().zip(&plain.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mixed = op.apply_mat_prec(&x, Precision::F32F64);
        let staged = Mat {
            rows: x.rows,
            cols: x.cols,
            data: x.data.iter().map(|&v| f64::from(v as f32)).collect(),
        };
        let mut want = op.apply_mat(&staged);
        for v in want.data.iter_mut() {
            *v = f64::from(*v as f32);
        }
        for (a, b) in mixed.data.iter().zip(&want.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dense_agrees() {
        let col = vec![3.0, 1.0, 0.2];
        let op = ToeplitzOp::new(col);
        let d = op.to_dense_mat();
        assert_eq!(d[(0, 2)], 0.2);
        assert_eq!(d[(2, 0)], 0.2);
        assert_eq!(d[(1, 1)], 3.0);
        let x = vec![0.5, -1.5, 2.0];
        let via_dense = d.matvec(&x);
        let via_fft = op.apply_vec(&x);
        for i in 0..3 {
            assert!((via_dense[i] - via_fft[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn identity_column() {
        let mut col = vec![0.0; 8];
        col[0] = 1.0;
        let op = ToeplitzOp::new(col);
        let x: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let y = op.apply_vec(&x);
        for i in 0..8 {
            assert!((y[i] - x[i]).abs() < 1e-10);
        }
    }
}
