//! Additive (sum) kernel operators — one of the paper's headline cases
//! where fast MVMs compose but fast *eigendecompositions* do not (§1:
//! "additive covariance functions" break the scaled-eigenvalue approach;
//! MVM-based estimators are unaffected).

use super::{KernelOp, LinOp};
use crate::util::obs;

/// `K̃ = sum_p K_p + σ² I`, where each part is a noise-free kernel operator
/// (parts are built with their `log σ = -inf`, i.e. σ² = 0, and their noise
/// hyper is hidden from the combined hyper vector).
pub struct SumKernelOp {
    pub parts: Vec<Box<dyn KernelOp>>,
    pub log_sigma: f64,
}

impl SumKernelOp {
    pub fn new(mut parts: Vec<Box<dyn KernelOp>>, sigma: f64) -> Self {
        assert!(!parts.is_empty());
        let n = parts[0].n();
        for p in parts.iter_mut() {
            assert_eq!(p.n(), n, "additive parts must share the data");
            // Zero the part's own noise.
            let mut h = p.hypers();
            let last = h.len() - 1;
            h[last] = f64::NEG_INFINITY;
            p.set_hypers(&h);
        }
        SumKernelOp { parts, log_sigma: sigma.ln() }
    }

    /// Per-part hyper count (noise excluded).
    fn part_nh(&self, p: usize) -> usize {
        self.parts[p].num_hypers() - 1
    }

    /// Map a combined hyper index to (part, local index), or None for σ.
    fn locate(&self, i: usize) -> Option<(usize, usize)> {
        let mut off = 0;
        for p in 0..self.parts.len() {
            let k = self.part_nh(p);
            if i < off + k {
                return Some((p, i - off));
            }
            off += k;
        }
        None
    }
}

impl LinOp for SumKernelOp {
    fn n(&self) -> usize {
        self.parts[0].n()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let n = self.n();
        y.fill(0.0);
        let mut tmp = vec![0.0; n];
        for p in &self.parts {
            p.apply(x, &mut tmp);
            for i in 0..n {
                y[i] += tmp[i];
            }
        }
        let s2 = self.noise_var();
        for i in 0..n {
            y[i] += s2 * x[i];
        }
    }
    /// Blocked sum: each part contributes its own blocked apply (fast MVMs
    /// compose under addition — paper §1).
    fn apply_mat(&self, x: &crate::linalg::dense::Mat) -> crate::linalg::dense::Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let mut out = crate::linalg::dense::Mat::zeros(x.rows, x.cols);
        for p in &self.parts {
            out.add_assign(&p.apply_mat(x));
        }
        let s2 = self.noise_var();
        for (o, xi) in out.data.iter_mut().zip(&x.data) {
            *o += s2 * xi;
        }
        out
    }
    /// Precision distributes over the sum: each part applies in the requested
    /// mode (parts without an f32 path fall through to exact f64 via the
    /// trait default), and the shared noise term stays f64. F64 mode is
    /// `apply_mat` itself.
    fn apply_mat_prec(
        &self,
        x: &crate::linalg::dense::Mat,
        prec: crate::util::precision::Precision,
    ) -> crate::linalg::dense::Mat {
        use crate::util::precision::Precision;
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        match prec {
            Precision::F64 => self.apply_mat(x),
            Precision::F32F64 => {
                assert_eq!(x.rows, self.n());
                let mut out = crate::linalg::dense::Mat::zeros(x.rows, x.cols);
                for p in &self.parts {
                    out.add_assign(&p.apply_mat_prec(x, prec));
                }
                let s2 = self.noise_var();
                for (o, xi) in out.data.iter_mut().zip(&x.data) {
                    *o += s2 * xi;
                }
                out
            }
        }
    }
    fn obs_kind(&self) -> &'static str {
        "sum_kernel"
    }
}

impl KernelOp for SumKernelOp {
    fn num_hypers(&self) -> usize {
        (0..self.parts.len()).map(|p| self.part_nh(p)).sum::<usize>() + 1
    }
    fn obs_grad_kind(&self) -> &'static str {
        "sum_kernel_grad"
    }
    fn hypers(&self) -> Vec<f64> {
        let mut h = Vec::new();
        for p in &self.parts {
            let ph = p.hypers();
            h.extend_from_slice(&ph[..ph.len() - 1]);
        }
        h.push(self.log_sigma);
        h
    }
    fn set_hypers(&mut self, h: &[f64]) {
        assert_eq!(h.len(), self.num_hypers());
        let mut off = 0;
        for p in self.parts.iter_mut() {
            let k = p.num_hypers() - 1;
            let mut ph = h[off..off + k].to_vec();
            ph.push(f64::NEG_INFINITY);
            p.set_hypers(&ph);
            off += k;
        }
        self.log_sigma = h[off];
    }
    fn hyper_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for (i, p) in self.parts.iter().enumerate() {
            let pn = p.hyper_names();
            for n in &pn[..pn.len() - 1] {
                names.push(format!("part{i}.{n}"));
            }
        }
        names.push("log_sigma".into());
        names
    }
    fn apply_grad(&self, i: usize, x: &[f64], y: &mut [f64]) {
        match self.locate(i) {
            Some((p, local)) => self.parts[p].apply_grad(local, x, y),
            None => {
                let s = 2.0 * self.noise_var();
                for (yi, xi) in y.iter_mut().zip(x) {
                    *yi = s * xi;
                }
            }
        }
    }
    fn apply_grad_mat(&self, i: usize, x: &crate::linalg::dense::Mat) -> crate::linalg::dense::Mat {
        let _obs = obs::apply_site(self.obs_grad_kind(), 1, x.cols as u64);
        match self.locate(i) {
            Some((p, local)) => self.parts[p].apply_grad_mat(local, x),
            None => {
                let s = 2.0 * self.noise_var();
                let mut out = x.clone();
                for v in out.data.iter_mut() {
                    *v *= s;
                }
                out
            }
        }
    }
    /// Concatenate each part's blocked derivative set (their hidden noise
    /// hypers dropped), then the shared-noise block.
    fn apply_grad_all_mat(&self, x: &crate::linalg::dense::Mat) -> Vec<crate::linalg::dense::Mat> {
        let nhyp = self.num_hypers() as u64;
        let _obs =
            obs::apply_site(self.obs_grad_kind(), nhyp, nhyp * x.cols as u64);
        let mut outs = Vec::with_capacity(self.num_hypers());
        for p in &self.parts {
            let mut sub = p.apply_grad_all_mat(x);
            sub.pop(); // the part's own (zeroed) noise hyper is hidden
            outs.extend(sub);
        }
        let s = 2.0 * self.noise_var();
        let mut noise = x.clone();
        for v in noise.data.iter_mut() {
            *v *= s;
        }
        outs.push(noise);
        outs
    }
    fn noise_var(&self) -> f64 {
        (2.0 * self.log_sigma).exp()
    }
    fn diag(&self) -> Option<Vec<f64>> {
        let n = self.n();
        let mut d = vec![self.noise_var(); n];
        for p in &self.parts {
            let pd = p.diag()?;
            for i in 0..n {
                d[i] += pd[i]; // parts have zero noise
            }
        }
        Some(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::DenseKernelOp;
    use crate::util::rng::Rng;

    fn parts(n: usize) -> (Vec<Vec<f64>>, SumKernelOp) {
        let mut rng = Rng::new(21);
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.gaussian()]).collect();
        let a = DenseKernelOp::new(
            pts.clone(),
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            1.0,
        );
        let b = DenseKernelOp::new(
            pts.clone(),
            Box::new(IsoKernel::new(Shape::Matern32, 1, 1.5, 0.7)),
            1.0,
        );
        (pts.clone(), SumKernelOp::new(vec![Box::new(a), Box::new(b)], 0.25))
    }

    #[test]
    fn sum_matches_manual() {
        let (pts, op) = parts(12);
        let k1 = IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0);
        let k2 = IsoKernel::new(Shape::Matern32, 1, 1.5, 0.7);
        use crate::kernels::Kernel;
        let mut rng = Rng::new(3);
        let x: Vec<f64> = (0..12).map(|_| rng.gaussian()).collect();
        let got = op.apply_vec(&x);
        for i in 0..12 {
            let mut want = 0.0625 * x[i];
            for j in 0..12 {
                want += (k1.eval(&pts[i], &pts[j]) + k2.eval(&pts[i], &pts[j])) * x[j];
            }
            assert!((got[i] - want).abs() < 1e-10, "{} vs {}", got[i], want);
        }
    }

    #[test]
    fn hyper_layout() {
        let (_, op) = parts(6);
        // 2 + 2 kernel hypers + 1 shared noise.
        assert_eq!(op.num_hypers(), 5);
        assert_eq!(op.hyper_names().last().unwrap(), "log_sigma");
    }

    /// F64 mode is bitwise `apply_mat`; mixed mode is bitwise the sum of the
    /// parts' own mixed applies plus the exact f64 noise term.
    #[test]
    fn apply_mat_prec_distributes_over_parts() {
        use crate::linalg::dense::Mat;
        use crate::util::precision::Precision;
        let (_, op) = parts(10);
        let mut rng = Rng::new(9);
        let x = Mat::from_fn(10, 3, |_, _| rng.gaussian());
        let f64_path = op.apply_mat_prec(&x, Precision::F64);
        let plain = op.apply_mat(&x);
        for (a, b) in f64_path.data.iter().zip(&plain.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mixed = op.apply_mat_prec(&x, Precision::F32F64);
        let mut want = Mat::zeros(10, 3);
        for p in &op.parts {
            want.add_assign(&p.apply_mat_prec(&x, Precision::F32F64));
        }
        let s2 = op.noise_var();
        for (o, xi) in want.data.iter_mut().zip(&x.data) {
            *o += s2 * xi;
        }
        for (a, b) in mixed.data.iter().zip(&want.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn grad_matches_fd() {
        let (_, mut op) = parts(8);
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..8).map(|_| rng.gaussian()).collect();
        let h0 = op.hypers();
        let eps = 1e-6;
        for i in 0..op.num_hypers() {
            let mut y = vec![0.0; 8];
            op.apply_grad(i, &x, &mut y);
            let mut hp = h0.clone();
            hp[i] += eps;
            op.set_hypers(&hp);
            let up = op.apply_vec(&x);
            hp[i] -= 2.0 * eps;
            op.set_hypers(&hp);
            let dn = op.apply_vec(&x);
            op.set_hypers(&h0);
            for p in 0..8 {
                let fd = (up[p] - dn[p]) / (2.0 * eps);
                assert!((y[p] - fd).abs() < 1e-5 * (1.0 + fd.abs()), "hyper {i}");
            }
        }
    }
}
