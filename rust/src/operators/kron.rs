//! Kronecker-product operator over Toeplitz/dense factors — the structure of
//! `K_UU` when SKI's inducing points live on a multi-dimensional grid with a
//! separable kernel (paper §2 and §5.2: 3 *million* inducing points are
//! possible exactly because this never materializes `K_UU`).

use super::toeplitz::ToeplitzOp;
use super::LinOp;
use crate::linalg::dense::Mat;
use crate::linalg::eigh::eigh;
use crate::linalg::fft::Cpx;
use crate::util::obs;
use crate::util::precision::Precision;

/// One factor of the Kronecker product.
pub enum KronFactor {
    Dense(Mat),
    Toeplitz(ToeplitzOp),
}

impl KronFactor {
    pub fn m(&self) -> usize {
        match self {
            KronFactor::Dense(a) => a.rows,
            KronFactor::Toeplitz(t) => t.m(),
        }
    }

    pub fn to_dense(&self) -> Mat {
        match self {
            KronFactor::Dense(a) => a.clone(),
            KronFactor::Toeplitz(t) => t.to_dense_mat(),
        }
    }

    /// Eigenvalues of the factor (dense eigendecomposition — this is the
    /// O(m^3)-per-factor step the scaled-eigenvalue baseline pays and our
    /// estimators avoid).
    pub fn eigvals(&self) -> crate::error::Result<Vec<f64>> {
        Ok(eigh(&self.to_dense())?.eigvals)
    }

    /// Diagonal of the factor (O(m); Toeplitz diagonals are constant).
    pub fn diag(&self) -> Vec<f64> {
        match self {
            KronFactor::Dense(a) => a.diag(),
            KronFactor::Toeplitz(t) => t.diag(),
        }
    }
}

/// `scale * (F_1 ⊗ F_2 ⊗ ... ⊗ F_d)` acting on vectors of length
/// `prod_j m_j` (row-major layout: the **last** factor varies fastest).
pub struct KronOp {
    pub factors: Vec<KronFactor>,
    pub scale: f64,
}

impl KronOp {
    pub fn new(factors: Vec<KronFactor>, scale: f64) -> Self {
        assert!(!factors.is_empty());
        KronOp { factors, scale }
    }

    pub fn shape(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.m()).collect()
    }

    /// Apply factor `k` along mode `k` of the tensor view of `x`, where `x`
    /// holds `bcols` stacked probe columns as one extra (fastest-varying)
    /// trailing dimension — the fused block apply: every fiber contraction
    /// and FFT is shared machinery across the whole probe block, and the
    /// dense inner loops run over `right * bcols` contiguous elements.
    ///
    /// Per-column arithmetic is identical for any `bcols` (the column index
    /// only changes strides), so block results are bitwise equal to
    /// column-by-column applies.
    fn mode_apply_block(
        &self,
        k: usize,
        x: &mut Vec<f64>,
        scratch: &mut Vec<f64>,
        bcols: usize,
        prec: Precision,
    ) {
        let dims = self.shape();
        let m = dims[k];
        let right: usize = dims[k + 1..].iter().product::<usize>() * bcols;
        let left: usize = dims[..k].iter().product();

        if left == 1 && right == bcols {
            // Contiguous (m x b) block: delegate to the factor's own blocked
            // apply (Toeplitz shares its FFT plan and fans columns out
            // across threads; dense uses the cache-blocked matmul). The
            // precision knob reaches the Toeplitz staging here — the 1-D
            // SKI hot path is exactly this branch; dense factors stay f64
            // (they are small and exact).
            let xm = Mat { rows: m, cols: bcols, data: std::mem::take(x) };
            let ym = match &self.factors[k] {
                KronFactor::Dense(a) => a.matmul(&xm),
                KronFactor::Toeplitz(t) => t.apply_mat_prec(&xm, prec),
            };
            *x = ym.data;
            return;
        }

        scratch.clear();
        scratch.resize(x.len(), 0.0);
        match &self.factors[k] {
            KronFactor::Dense(a) => {
                // For each (l, r) fiber: y[l, :, r] = A x[l, :, r].
                // Process r-contiguous blocks: for fixed l, x block is
                // (m x right) row-major => matmul A * block.
                for l in 0..left {
                    let base = l * m * right;
                    for i in 0..m {
                        let arow = a.row(i);
                        let out = &mut scratch[base + i * right..base + (i + 1) * right];
                        for (j, &aij) in arow.iter().enumerate() {
                            if aij == 0.0 {
                                continue;
                            }
                            let xin = &x[base + j * right..base + (j + 1) * right];
                            for r in 0..right {
                                out[r] += aij * xin[r];
                            }
                        }
                    }
                }
            }
            KronFactor::Toeplitz(t) => {
                let mut fiber = vec![0.0; m];
                let mut yfib = vec![0.0; m];
                let mut fft_scratch: Vec<Cpx> = Vec::new();
                for l in 0..left {
                    let base = l * m * right;
                    for r in 0..right {
                        for i in 0..m {
                            fiber[i] = x[base + i * right + r];
                        }
                        t.apply_with_scratch(&fiber, &mut yfib, &mut fft_scratch);
                        for i in 0..m {
                            scratch[base + i * right + r] = yfib[i];
                        }
                    }
                }
            }
        }
        std::mem::swap(x, scratch);
    }

    /// Run all mode products over `bcols` stacked columns in place. The
    /// precision knob only reaches the contiguous single-factor branch
    /// (the 1-D SKI hot path); the strided multi-factor fiber loops stay
    /// f64 — mixed precision is an opt-in bandwidth optimization, and an
    /// exact path is always a valid implementation of it.
    fn block_apply_data(&self, data: &mut Vec<f64>, bcols: usize, prec: Precision) {
        let mut scratch = Vec::new();
        for k in 0..self.factors.len() {
            self.mode_apply_block(k, data, &mut scratch, bcols, prec);
        }
        if self.scale != 1.0 {
            for v in data.iter_mut() {
                *v *= self.scale;
            }
        }
    }

    /// Diagonal of the (scaled) Kronecker product: the outer product of
    /// the factor diagonals, in the operator's row-major layout (last
    /// factor fastest). O(n) — needed by the pivoted-Cholesky
    /// preconditioner and FITC-style corrections.
    pub fn diag(&self) -> Vec<f64> {
        let mut out = vec![self.scale];
        for f in &self.factors {
            let fd = f.diag();
            let mut next = Vec::with_capacity(out.len() * fd.len());
            for &o in &out {
                for &d in &fd {
                    next.push(o * d);
                }
            }
            out = next;
        }
        out
    }

    /// All eigenvalues of the (scaled) Kronecker product: outer products of
    /// factor eigenvalues. Length is the full grid size — fine up to a few
    /// million.
    pub fn all_eigvals(&self) -> crate::error::Result<Vec<f64>> {
        let mut evs: Vec<Vec<f64>> = Vec::new();
        for f in &self.factors {
            evs.push(f.eigvals()?);
        }
        let mut out = vec![self.scale];
        for ev in &evs {
            let mut next = Vec::with_capacity(out.len() * ev.len());
            for &o in &out {
                for &e in ev {
                    next.push(o * e);
                }
            }
            out = next;
        }
        Ok(out)
    }
}

impl LinOp for KronOp {
    fn n(&self) -> usize {
        self.shape().iter().product()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n());
        assert_eq!(y.len(), self.n());
        let mut cur = x.to_vec();
        self.block_apply_data(&mut cur, 1, Precision::F64);
        y.copy_from_slice(&cur);
    }
    /// Fused block apply: the probe block is one extra trailing tensor mode,
    /// so each factor contraction sweeps all b columns at once.
    fn apply_mat(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let b = x.cols;
        let mut data = x.data.clone();
        self.block_apply_data(&mut data, b, Precision::F64);
        Mat { rows: x.rows, cols: b, data }
    }
    fn apply_mat_prec(&self, x: &Mat, prec: Precision) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let b = x.cols;
        let mut data = x.data.clone();
        self.block_apply_data(&mut data, b, prec);
        Mat { rows: x.rows, cols: b, data }
    }
    fn obs_kind(&self) -> &'static str {
        "kron"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn kron_dense(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows * b.rows, a.cols * b.cols);
        for i in 0..a.rows {
            for j in 0..a.cols {
                for k in 0..b.rows {
                    for l in 0..b.cols {
                        out[(i * b.rows + k, j * b.cols + l)] = a[(i, j)] * b[(k, l)];
                    }
                }
            }
        }
        out
    }

    fn rand_sym(m: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::from_fn(m, m, |_, _| rng.gaussian());
        a.symmetrize();
        a.add_diag(m as f64);
        a
    }

    #[test]
    fn two_factor_dense_matches_kron() {
        let mut rng = Rng::new(5);
        let a = rand_sym(3, &mut rng);
        let b = rand_sym(4, &mut rng);
        let op = KronOp::new(
            vec![KronFactor::Dense(a.clone()), KronFactor::Dense(b.clone())],
            1.0,
        );
        let full = kron_dense(&a, &b);
        let x: Vec<f64> = (0..12).map(|i| (i as f64 * 0.17).sin()).collect();
        let got = op.apply_vec(&x);
        let want = full.matvec(&x);
        for i in 0..12 {
            assert!((got[i] - want[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn three_factor_with_toeplitz_matches_dense() {
        let mut rng = Rng::new(8);
        let a = rand_sym(2, &mut rng);
        let tcol: Vec<f64> = vec![3.0, 1.0, 0.2];
        let t = ToeplitzOp::new(tcol);
        let c = rand_sym(3, &mut rng);
        let tdense = t.to_dense_mat();
        let op = KronOp::new(
            vec![
                KronFactor::Dense(a.clone()),
                KronFactor::Toeplitz(ToeplitzOp::new(vec![3.0, 1.0, 0.2])),
                KronFactor::Dense(c.clone()),
            ],
            2.0,
        );
        let mut full = kron_dense(&kron_dense(&a, &tdense), &c);
        full.scale(2.0);
        let n = 2 * 3 * 3;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.31).cos()).collect();
        let got = op.apply_vec(&x);
        let want = full.matvec(&x);
        for i in 0..n {
            assert!((got[i] - want[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn eigvals_match_dense() {
        let mut rng = Rng::new(13);
        let a = rand_sym(3, &mut rng);
        let b = rand_sym(2, &mut rng);
        let op = KronOp::new(
            vec![KronFactor::Dense(a.clone()), KronFactor::Dense(b.clone())],
            1.5,
        );
        let mut got = op.all_eigvals().unwrap();
        got.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let mut full = kron_dense(&a, &b);
        full.scale(1.5);
        let want = crate::linalg::eigh::eigh(&full).unwrap().eigvals;
        for i in 0..6 {
            assert!((got[i] - want[i]).abs() < 1e-8, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn diag_matches_dense() {
        let mut rng = Rng::new(17);
        let a = rand_sym(2, &mut rng);
        let c = rand_sym(3, &mut rng);
        let op = KronOp::new(
            vec![
                KronFactor::Dense(a),
                KronFactor::Toeplitz(ToeplitzOp::new(vec![3.0, 1.0, 0.2])),
                KronFactor::Dense(c),
            ],
            1.7,
        );
        let got = op.diag();
        let want = op.to_dense().diag();
        assert_eq!(got.len(), want.len());
        for i in 0..got.len() {
            assert!((got[i] - want[i]).abs() < 1e-10, "i={i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn scale_applies() {
        let a = Mat::eye(2);
        let op = KronOp::new(vec![KronFactor::Dense(a)], 3.0);
        assert_eq!(op.apply_vec(&[1.0, 2.0]), vec![3.0, 6.0]);
    }
}
