//! SoR / FITC low-rank(+diagonal) operators (paper §2) — the classical
//! inducing-point baseline. FITC is "exactly a diagonal correction of SoR"
//! (§3.3); both admit *exact* O(n m^2) log determinants via the matrix
//! determinant lemma, which is what we benchmark the stochastic estimators
//! against in Fig. 1 and Table 5.

use super::{KernelOp, LinOp};
use crate::kernels::Kernel;
use crate::linalg::chol::Cholesky;
use crate::linalg::dense::{Mat, MatF32};
use crate::util::obs;
use crate::util::precision::Precision;

/// `K̃ = K_xu K_uu^{-1} K_ux + D` where `D = σ² I` (SoR) or
/// `D = diag(k(x,x) - q(x,x)) + σ² I` (FITC).
pub struct FitcOp {
    pub points: Vec<Vec<f64>>,
    pub inducing: Vec<Vec<f64>>,
    pub kernel: Box<dyn Kernel>,
    pub log_sigma: f64,
    /// true = FITC diagonal correction; false = plain SoR.
    pub fitc: bool,

    kxu: Mat,
    kuu_chol: Cholesky,
    /// Full diagonal D (noise included).
    dvec: Vec<f64>,
    /// Lazily built f32 storage panels of the cross factor for
    /// `Precision::F32F64` applies: `K_xu` (n x m) and its transpose
    /// `K_ux` (m x n), so both factor contractions of the blocked apply
    /// stream half the memory traffic. Invalidated by `refresh()` (and
    /// therefore by `set_hypers`), mirroring the dense-kernel panel
    /// contract.
    kxu32: std::sync::OnceLock<MatF32>,
    kux32: std::sync::OnceLock<MatF32>,
}

impl FitcOp {
    pub fn new(
        points: Vec<Vec<f64>>,
        inducing: Vec<Vec<f64>>,
        kernel: Box<dyn Kernel>,
        sigma: f64,
        fitc: bool,
    ) -> crate::error::Result<Self> {
        let mut op = FitcOp {
            points,
            inducing,
            kernel,
            log_sigma: sigma.ln(),
            fitc,
            kxu: Mat::zeros(0, 0),
            kuu_chol: Cholesky { l: Mat::eye(1) },
            dvec: Vec::new(),
            kxu32: std::sync::OnceLock::new(),
            kux32: std::sync::OnceLock::new(),
        };
        op.refresh()?;
        Ok(op)
    }

    pub fn m(&self) -> usize {
        self.inducing.len()
    }

    fn refresh(&mut self) -> crate::error::Result<()> {
        // Hypers changed: the f32 mirrors of the cross factor are stale.
        self.kxu32 = std::sync::OnceLock::new();
        self.kux32 = std::sync::OnceLock::new();
        let (n, m) = (self.points.len(), self.inducing.len());
        let kuu = Mat::from_fn(m, m, |i, j| {
            self.kernel.eval(&self.inducing[i], &self.inducing[j])
        });
        self.kuu_chol = Cholesky::new_jittered(&kuu, 1e-8 * kuu[(0, 0)].max(1e-12), 10)?;
        self.kxu = Mat::from_fn(n, m, |i, j| {
            self.kernel.eval(&self.points[i], &self.inducing[j])
        });
        let s2 = self.noise_var();
        self.dvec = (0..n)
            .map(|i| {
                if self.fitc {
                    // q(x,x) = k_xu Kuu^{-1} k_ux.
                    let row = self.kxu.row(i).to_vec();
                    let sol = self.kuu_chol.solve(&row);
                    let q: f64 = row.iter().zip(&sol).map(|(a, b)| a * b).sum();
                    let kxx = self.kernel.eval(&self.points[i], &self.points[i]);
                    (kxx - q).max(0.0) + s2
                } else {
                    s2
                }
            })
            .collect();
        Ok(())
    }

    /// Exact log|K̃| via the matrix determinant lemma:
    /// `log|Q + D| = log|D| + log|K_uu + K_ux D^{-1} K_xu| - log|K_uu|`.
    pub fn exact_logdet(&self) -> crate::error::Result<f64> {
        let (n, m) = (self.points.len(), self.m());
        let mut inner = Mat::zeros(m, m);
        // K_ux D^{-1} K_xu
        for i in 0..n {
            let row = self.kxu.row(i);
            let dinv = 1.0 / self.dvec[i];
            for a in 0..m {
                let ra = row[a] * dinv;
                if ra == 0.0 {
                    continue;
                }
                for b in 0..m {
                    inner[(a, b)] += ra * row[b];
                }
            }
        }
        // + K_uu
        let kuu = Mat::from_fn(m, m, |i, j| {
            self.kernel.eval(&self.inducing[i], &self.inducing[j])
        });
        inner.add_assign(&kuu);
        inner.symmetrize();
        let inner_chol = Cholesky::new_jittered(&inner, 1e-8, 10)?;
        let logdet_d: f64 = self.dvec.iter().map(|d| d.ln()).sum();
        Ok(logdet_d + inner_chol.logdet() - self.kuu_chol.logdet())
    }

    /// Exact solve `K̃^{-1} b` via Woodbury (O(n m^2)).
    pub fn woodbury_solve(&self, b: &[f64]) -> crate::error::Result<Vec<f64>> {
        let (n, m) = (self.points.len(), self.m());
        assert_eq!(b.len(), n);
        // A = K_uu + K_ux D^{-1} K_xu (same inner matrix as the logdet).
        let mut inner = Mat::zeros(m, m);
        let mut rhs = vec![0.0; m];
        for i in 0..n {
            let row = self.kxu.row(i);
            let dinv = 1.0 / self.dvec[i];
            for a in 0..m {
                let ra = row[a] * dinv;
                rhs[a] += ra * b[i];
                if ra == 0.0 {
                    continue;
                }
                for bb in 0..m {
                    inner[(a, bb)] += ra * row[bb];
                }
            }
        }
        let kuu = Mat::from_fn(m, m, |i, j| {
            self.kernel.eval(&self.inducing[i], &self.inducing[j])
        });
        inner.add_assign(&kuu);
        inner.symmetrize();
        let chol = Cholesky::new_jittered(&inner, 1e-8, 10)?;
        let t = chol.solve(&rhs);
        // x = D^{-1} b - D^{-1} K_xu t
        let mut x = vec![0.0; n];
        for i in 0..n {
            let row = self.kxu.row(i);
            let mut s = 0.0;
            for a in 0..m {
                s += row[a] * t[a];
            }
            x[i] = (b[i] - s) / self.dvec[i];
        }
        Ok(x)
    }

    /// Predictive mean at test points (SoR/FITC predictive equations).
    pub fn predict_mean(&self, test: &[Vec<f64>], alpha_data: &[f64]) -> Vec<f64> {
        // mean = K_*u K_uu^{-1} K_ux alpha where alpha = K̃^{-1} y.
        let m = self.m();
        let mut kux_alpha = vec![0.0; m];
        for i in 0..self.points.len() {
            let row = self.kxu.row(i);
            for a in 0..m {
                kux_alpha[a] += row[a] * alpha_data[i];
            }
        }
        let t = self.kuu_chol.solve(&kux_alpha);
        test.iter()
            .map(|p| {
                let mut s = 0.0;
                for a in 0..m {
                    s += self.kernel.eval(p, &self.inducing[a]) * t[a];
                }
                s
            })
            .collect()
    }

    /// Predictive variance at test points (FITC predictive equations,
    /// Quiñonero-Candela & Rasmussen 2005).
    pub fn predict_var(&self, test: &[Vec<f64>]) -> crate::error::Result<Vec<f64>> {
        let (n, m) = (self.points.len(), self.m());
        // Sigma = (K_uu + K_ux D^{-1} K_xu)^{-1}
        let mut inner = Mat::zeros(m, m);
        for i in 0..n {
            let row = self.kxu.row(i);
            let dinv = 1.0 / self.dvec[i];
            for a in 0..m {
                let ra = row[a] * dinv;
                if ra == 0.0 {
                    continue;
                }
                for b in 0..m {
                    inner[(a, b)] += ra * row[b];
                }
            }
        }
        let kuu = Mat::from_fn(m, m, |i, j| {
            self.kernel.eval(&self.inducing[i], &self.inducing[j])
        });
        inner.add_assign(&kuu);
        inner.symmetrize();
        let sig_chol = Cholesky::new_jittered(&inner, 1e-8, 10)?;
        let s2 = self.noise_var();
        Ok(test
            .iter()
            .map(|p| {
                let kstar_u: Vec<f64> =
                    (0..m).map(|a| self.kernel.eval(p, &self.inducing[a])).collect();
                let kss = self.kernel.eval(p, p);
                // q** = k*u Kuu^{-1} k_u*
                let t = self.kuu_chol.solve(&kstar_u);
                let qss: f64 = kstar_u.iter().zip(&t).map(|(a, b)| a * b).sum();
                // k*u Sigma k_u*
                let u = sig_chol.solve(&kstar_u);
                let vss: f64 = kstar_u.iter().zip(&u).map(|(a, b)| a * b).sum();
                (kss - qss + vss + s2).max(0.0)
            })
            .collect())
    }
}

impl LinOp for FitcOp {
    fn n(&self) -> usize {
        self.points.len()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        // y = K_xu (K_uu^{-1} (K_ux x)) + D x
        let kux_x = self.kxu.matvec_t(x);
        let t = self.kuu_chol.solve(&kux_x);
        self.kxu.matvec_into(&t, y);
        for i in 0..x.len() {
            y[i] += self.dvec[i] * x[i];
        }
    }
    /// Blocked low-rank apply: both factor contractions become `n x m x b`
    /// matmuls and the m x m solve is amortized over the whole block.
    fn apply_mat(&self, x: &Mat) -> Mat {
        let (n, m) = (self.points.len(), self.m());
        assert_eq!(x.rows, n);
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let b = x.cols;
        // T = K_ux X (m x b), accumulated in the same ascending-i order as
        // `matvec_t` so columns match single-vector applies bitwise.
        let mut t = Mat::zeros(m, b);
        for i in 0..n {
            let row = self.kxu.row(i);
            let xrow = x.row(i);
            for a in 0..m {
                let ra = row[a];
                let trow = &mut t.data[a * b..(a + 1) * b];
                for j in 0..b {
                    trow[j] += ra * xrow[j];
                }
            }
        }
        let tsol = self.kuu_chol.solve_mat(&t);
        let mut out = Mat::zeros(n, b);
        for i in 0..n {
            let row = self.kxu.row(i);
            let orow = out.row_mut(i);
            for a in 0..m {
                let ra = row[a];
                let trow = tsol.row(a);
                for j in 0..b {
                    orow[j] += ra * trow[j];
                }
            }
        }
        for i in 0..n {
            let di = self.dvec[i];
            for (o, xi) in out.row_mut(i).iter_mut().zip(x.row(i)) {
                *o += di * xi;
            }
        }
        out
    }
    /// Mixed mode streams both factor contractions (`K_ux X` and
    /// `K_xu ·`) through lazily cached f32 panels with f64-accumulating
    /// GEMMs — half the memory traffic of the n×m factor both ways. The
    /// m×m Cholesky solve and the diagonal `D ∘ X` stay exact f64, and
    /// F64 mode is `apply_mat` itself (bitwise).
    fn apply_mat_prec(&self, x: &Mat, prec: Precision) -> Mat {
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        match prec {
            Precision::F64 => self.apply_mat(x),
            Precision::F32F64 => {
                let (n, m) = (self.points.len(), self.m());
                assert_eq!(x.rows, n);
                let b = x.cols;
                if b == 0 || n == 0 {
                    return Mat::zeros(n, b);
                }
                let kux = self.kux32.get_or_init(|| MatF32::from_mat(&self.kxu.transpose()));
                let kxu = self.kxu32.get_or_init(|| MatF32::from_mat(&self.kxu));
                // ~4 n m b flops across the two panels; same spawn-worthiness
                // gate style as the dense panel (flop count unchanged vs f64).
                let threads = if 2 * n * m * b >= 4_000_000 {
                    crate::util::parallel::default_threads()
                } else {
                    1
                };
                let mut t = Mat::zeros(m, b);
                kux.matmul_into_threads(x, &mut t, threads);
                let tsol = self.kuu_chol.solve_mat(&t);
                let mut out = Mat::zeros(n, b);
                kxu.matmul_into_threads(&tsol, &mut out, threads);
                for i in 0..n {
                    let di = self.dvec[i];
                    for (o, xi) in out.row_mut(i).iter_mut().zip(x.row(i)) {
                        *o += di * xi;
                    }
                }
                out
            }
        }
    }
    fn obs_kind(&self) -> &'static str {
        "fitc"
    }
}

impl KernelOp for FitcOp {
    fn num_hypers(&self) -> usize {
        self.kernel.num_hypers() + 1
    }
    fn obs_grad_kind(&self) -> &'static str {
        "fitc_grad"
    }
    fn hypers(&self) -> Vec<f64> {
        let mut h = self.kernel.hypers();
        h.push(self.log_sigma);
        h
    }
    fn set_hypers(&mut self, h: &[f64]) {
        self.kernel.set_hypers(&h[..h.len() - 1]);
        self.log_sigma = h[h.len() - 1];
        self.refresh().expect("FITC refresh failed");
    }
    fn hyper_names(&self) -> Vec<String> {
        let mut names = self.kernel.hyper_names();
        names.push("log_sigma".into());
        names
    }
    /// Derivative MVMs by central finite differences on the whole operator
    /// (FITC's analytic gradients involve derivative terms through
    /// K_uu^{-1} and the FITC diagonal; FD keeps the baseline honest at the
    /// same asymptotic cost that makes it slow in Fig. 1). Thin wrapper
    /// over the single-column case of `apply_grad_mat` so the two FD paths
    /// cannot drift.
    fn apply_grad(&self, i: usize, x: &[f64], y: &mut [f64]) {
        let n = self.n();
        assert_eq!(x.len(), n);
        assert_eq!(y.len(), n);
        let out = self.apply_grad_mat(i, &Mat::from_col(x));
        y.copy_from_slice(&out.data);
    }
    /// Blocked FD derivative: the shifted operators are built **once per
    /// block** (the per-column default would re-factor K_uu per probe) and
    /// applied with the blocked path.
    fn apply_grad_mat(&self, i: usize, x: &Mat) -> Mat {
        let _obs = obs::apply_site(self.obs_grad_kind(), 1, x.cols as u64);
        let h0 = self.hypers();
        let eps = 1e-5;
        let mut fd_op = FitcOp::new(
            self.points.clone(),
            self.inducing.clone(),
            self.kernel.clone_box(),
            1.0,
            self.fitc,
        )
        .expect("fd op");
        let mut hp = h0.clone();
        hp[i] += eps;
        fd_op.set_hypers(&hp);
        let up = fd_op.apply_mat(x);
        hp[i] -= 2.0 * eps;
        fd_op.set_hypers(&hp);
        let dn = fd_op.apply_mat(x);
        let mut out = Mat::zeros(x.rows, x.cols);
        for ((o, u), d) in out.data.iter_mut().zip(&up.data).zip(&dn.data) {
            *o = (u - d) / (2.0 * eps);
        }
        out
    }
    fn noise_var(&self) -> f64 {
        (2.0 * self.log_sigma).exp()
    }
    fn diag(&self) -> Option<Vec<f64>> {
        let m = self.m();
        Some(
            (0..self.n())
                .map(|i| {
                    let row = self.kxu.row(i).to_vec();
                    let sol = self.kuu_chol.solve(&row);
                    let q: f64 = row.iter().zip(&sol).map(|(a, b)| a * b).sum();
                    let _ = m;
                    q + self.dvec[i]
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::util::rng::Rng;

    fn setup(n: usize, m: usize, fitc: bool) -> FitcOp {
        let mut rng = Rng::new(31);
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let ind: Vec<Vec<f64>> =
            (0..m).map(|i| vec![3.0 * i as f64 / (m - 1) as f64]).collect();
        FitcOp::new(
            pts,
            ind,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.2,
            fitc,
        )
        .unwrap()
    }

    #[test]
    fn fitc_diag_is_exact() {
        let op = setup(25, 8, true);
        let dense = op.to_dense();
        let want = op.kernel.eval(&op.points[0], &op.points[0]) + 0.04;
        for i in 0..25 {
            assert!((dense[(i, i)] - want).abs() < 1e-9);
        }
    }

    #[test]
    fn exact_logdet_matches_dense() {
        for fitc in [false, true] {
            let op = setup(20, 6, fitc);
            let dense = op.to_dense();
            let chol = Cholesky::new(&dense).unwrap();
            let got = op.exact_logdet().unwrap();
            assert!(
                (got - chol.logdet()).abs() < 1e-7,
                "fitc={fitc}: {got} vs {}",
                chol.logdet()
            );
        }
    }

    #[test]
    fn woodbury_matches_dense_solve() {
        let op = setup(18, 5, true);
        let dense = op.to_dense();
        let chol = Cholesky::new(&dense).unwrap();
        let mut rng = Rng::new(9);
        let b: Vec<f64> = (0..18).map(|_| rng.gaussian()).collect();
        let want = chol.solve(&b);
        let got = op.woodbury_solve(&b).unwrap();
        for i in 0..18 {
            assert!((got[i] - want[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn sor_is_low_rank() {
        // SoR's noise-free part has rank <= m: check via eigenvalues.
        let op = setup(15, 4, false);
        let mut dense = op.to_dense();
        dense.add_diag(-0.04); // strip noise
        let eig = crate::linalg::eigh::eigh(&dense).unwrap();
        let nonzero = eig.eigvals.iter().filter(|&&v| v.abs() > 1e-8).count();
        assert!(nonzero <= 4, "rank {nonzero}");
    }

    /// F64 mode is bitwise `apply_mat`; mixed mode equals the f64 pipeline
    /// run on the *rounded* cross factor (bitwise, via the MatF32 GEMM
    /// contract) with the Cholesky solve and diagonal exact; `set_hypers`
    /// drops the stale panels so they track the new factor.
    #[test]
    fn apply_mat_prec_contract_and_refresh() {
        for fitc in [false, true] {
            let mut op = setup(22, 6, fitc);
            let mut rng = Rng::new(12);
            let x = Mat::from_fn(22, 3, |_, _| rng.gaussian());
            let f64_path = op.apply_mat_prec(&x, Precision::F64);
            let plain = op.apply_mat(&x);
            for (a, b) in f64_path.data.iter().zip(&plain.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            let check_mixed = |op: &FitcOp, x: &Mat| {
                let mixed = op.apply_mat_prec(x, Precision::F32F64);
                // Reference: the same pipeline on the rounded K_xu, all-f64.
                let rounded = Mat {
                    rows: op.kxu.rows,
                    cols: op.kxu.cols,
                    data: op.kxu.data.iter().map(|&v| f64::from(v as f32)).collect(),
                };
                let t = rounded.transpose().matmul(x);
                let tsol = op.kuu_chol.solve_mat(&t);
                let mut want = rounded.matmul(&tsol);
                for i in 0..op.n() {
                    let di = op.dvec[i];
                    for (o, xi) in want.row_mut(i).iter_mut().zip(x.row(i)) {
                        *o += di * xi;
                    }
                }
                for (a, b) in mixed.data.iter().zip(&want.data) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                // The knob reaches storage: rounding the factor must move
                // *something* at f32 scale.
                let diff = mixed
                    .data
                    .iter()
                    .zip(&op.apply_mat(x).data)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                assert!(diff > 0.0, "fitc={fitc}: panel apply identical to f64");
            };
            check_mixed(&op, &x);
            // Changing hypers rebuilds the factor; panels must follow.
            let mut h = op.hypers();
            h[0] += 0.2;
            op.set_hypers(&h);
            check_mixed(&op, &x);
        }
    }

    #[test]
    fn fd_grad_close_to_true_fd(){
        let op = setup(10, 4, true);
        let mut rng = Rng::new(3);
        let x: Vec<f64> = (0..10).map(|_| rng.gaussian()).collect();
        // apply_grad is itself FD; just verify it runs and is symmetric-ish
        let mut y = vec![0.0; 10];
        op.apply_grad(0, &x, &mut y);
        assert!(y.iter().all(|v| v.is_finite()));
    }
}
