//! Dense kernel operator — the exact-kernel path (baselines, small-n
//! problems, and the deep-kernel-learning experiment where n is a few
//! thousand). Materializes K once per hyper setting; derivative MVMs share
//! a single pass over all pairs via `apply_grad_all`.

use super::{KernelOp, LinOp};
use crate::kernels::Kernel;
use crate::linalg::dense::{Mat, MatF32};
use crate::util::obs;
use crate::util::parallel;
use crate::util::precision::Precision;

/// `K̃ = K(X, X) + σ² I` with `K` materialized.
pub struct DenseKernelOp {
    pub points: Vec<Vec<f64>>,
    pub kernel: Box<dyn Kernel>,
    pub log_sigma: f64,
    k: Mat,
    /// Lazily built f32 storage panel of `k` for mixed-precision applies;
    /// invalidated by `refresh()` whenever the kernel matrix changes.
    k32: std::sync::OnceLock<MatF32>,
}

impl DenseKernelOp {
    pub fn new(points: Vec<Vec<f64>>, kernel: Box<dyn Kernel>, sigma: f64) -> Self {
        let mut op = DenseKernelOp {
            points,
            kernel,
            log_sigma: sigma.ln(),
            k: Mat::zeros(0, 0),
            k32: std::sync::OnceLock::new(),
        };
        op.refresh();
        op
    }

    /// The materialized noise-free kernel matrix.
    pub fn kernel_matrix(&self) -> &Mat {
        &self.k
    }

    /// Materialized K̃ (with noise) — for the exact Cholesky baseline.
    pub fn full_matrix(&self) -> Mat {
        let mut a = self.k.clone();
        a.add_diag(self.noise_var());
        a
    }

    /// Materialized ∂K̃/∂θ_i — exact-gradient baseline only (O(n^2) memory).
    pub fn grad_matrix(&self, i: usize) -> Mat {
        let n = self.points.len();
        let nh = self.kernel.num_hypers();
        if i == nh {
            let mut m = Mat::zeros(n, n);
            m.add_diag(2.0 * self.noise_var());
            return m;
        }
        let mut m = Mat::zeros(n, n);
        let mut g = vec![0.0; nh];
        for r in 0..n {
            for c in 0..n {
                self.kernel.grad(&self.points[r], &self.points[c], &mut g);
                m[(r, c)] = g[i];
            }
        }
        m
    }

    fn refresh(&mut self) {
        let n = self.points.len();
        let threads = parallel::default_threads();
        let rows: Vec<Vec<f64>> = parallel::par_map(n, threads, |i| {
            let mut row = vec![0.0; n];
            for j in 0..n {
                row[j] = self.kernel.eval(&self.points[i], &self.points[j]);
            }
            row
        });
        self.k = Mat::from_rows(&rows);
        // Any cached f32 panel mirrors the old K: drop it.
        self.k32 = std::sync::OnceLock::new();
    }
}

impl LinOp for DenseKernelOp {
    fn n(&self) -> usize {
        self.points.len()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.k.matvec_into(x, y);
        let s2 = self.noise_var();
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += s2 * xi;
        }
    }
    /// Blocked apply: one k-blocked pass over the materialized K drives all
    /// b columns (each K entry is loaded once per block instead of once per
    /// probe), row-partitioned across threads for large problems. Per-column
    /// accumulation order matches `apply` exactly.
    fn apply_mat(&self, x: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(x.rows, n);
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let b = x.cols;
        let mut out = Mat::zeros(n, b);
        if b == 0 || n == 0 {
            return out;
        }
        // ~2 n^2 b flops; only fan out when the block is worth a spawn.
        let threads = if n * n * b >= 4_000_000 { parallel::default_threads() } else { 1 };
        self.k.matmul_into_threads(x, &mut out, threads);
        let s2 = self.noise_var();
        for (o, xi) in out.data.iter_mut().zip(&x.data) {
            *o += s2 * xi;
        }
        out
    }
    /// Mixed mode streams the lazily cached f32 panel of K through the
    /// f64-accumulating GEMM (half the memory traffic of the n×n term);
    /// the noise diagonal `σ² x` stays exact f64, and F64 mode is
    /// `apply_mat` itself.
    fn apply_mat_prec(&self, x: &Mat, prec: Precision) -> Mat {
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        match prec {
            Precision::F64 => self.apply_mat(x),
            Precision::F32F64 => {
                let n = self.n();
                assert_eq!(x.rows, n);
                let b = x.cols;
                let mut out = Mat::zeros(n, b);
                if b == 0 || n == 0 {
                    return out;
                }
                let panel = self.k32.get_or_init(|| MatF32::from_mat(&self.k));
                // Same thread gate as the f64 path (flop count unchanged).
                let threads =
                    if n * n * b >= 4_000_000 { parallel::default_threads() } else { 1 };
                panel.matmul_into_threads(x, &mut out, threads);
                let s2 = self.noise_var();
                for (o, xi) in out.data.iter_mut().zip(&x.data) {
                    *o += s2 * xi;
                }
                out
            }
        }
    }
    fn to_dense(&self) -> Mat {
        self.full_matrix()
    }
    fn obs_kind(&self) -> &'static str {
        "dense_kernel"
    }
}

impl KernelOp for DenseKernelOp {
    fn num_hypers(&self) -> usize {
        self.kernel.num_hypers() + 1
    }
    fn obs_grad_kind(&self) -> &'static str {
        "dense_kernel_grad"
    }
    fn hypers(&self) -> Vec<f64> {
        let mut h = self.kernel.hypers();
        h.push(self.log_sigma);
        h
    }
    fn set_hypers(&mut self, h: &[f64]) {
        assert_eq!(h.len(), self.num_hypers());
        self.kernel.set_hypers(&h[..h.len() - 1]);
        self.log_sigma = h[h.len() - 1];
        self.refresh();
    }
    fn hyper_names(&self) -> Vec<String> {
        let mut names = self.kernel.hyper_names();
        names.push("log_sigma".into());
        names
    }
    fn apply_grad(&self, i: usize, x: &[f64], y: &mut [f64]) {
        let n = self.n();
        let nh = self.kernel.num_hypers();
        if i == nh {
            let s = 2.0 * self.noise_var();
            for (yi, xi) in y.iter_mut().zip(x) {
                *yi = s * xi;
            }
            return;
        }
        let mut g = vec![0.0; nh];
        for r in 0..n {
            let mut s = 0.0;
            for c in 0..n {
                self.kernel.grad(&self.points[r], &self.points[c], &mut g);
                s += g[i] * x[c];
            }
            y[r] = s;
        }
    }
    fn apply_grad_all(&self, x: &[f64], ys: &mut [Vec<f64>]) {
        // One pass over all pairs computes every hyper's derivative MVM.
        let n = self.n();
        let nh = self.kernel.num_hypers();
        assert_eq!(ys.len(), nh + 1);
        let threads = parallel::default_threads();
        let rows: Vec<Vec<f64>> = parallel::par_map(n, threads, |r| {
            let mut acc = vec![0.0; nh];
            let mut g = vec![0.0; nh];
            for c in 0..n {
                self.kernel.grad(&self.points[r], &self.points[c], &mut g);
                for t in 0..nh {
                    acc[t] += g[t] * x[c];
                }
            }
            acc
        });
        for t in 0..nh {
            for r in 0..n {
                ys[t][r] = rows[r][t];
            }
        }
        let s = 2.0 * self.noise_var();
        for (yi, xi) in ys[nh].iter_mut().zip(x) {
            *yi = s * xi;
        }
    }
    /// Blocked single-hyper derivative: one pass over all pairs drives every
    /// column of the probe block.
    fn apply_grad_mat(&self, i: usize, x: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(x.rows, n);
        let _obs = obs::apply_site(self.obs_grad_kind(), 1, x.cols as u64);
        let b = x.cols;
        let nh = self.kernel.num_hypers();
        if i == nh {
            let s = 2.0 * self.noise_var();
            let mut out = x.clone();
            for v in out.data.iter_mut() {
                *v *= s;
            }
            return out;
        }
        let threads = parallel::default_threads();
        let rows: Vec<Vec<f64>> = parallel::par_map(n, threads, |r| {
            let mut acc = vec![0.0; b];
            let mut g = vec![0.0; nh];
            for c in 0..n {
                self.kernel.grad(&self.points[r], &self.points[c], &mut g);
                let gi = g[i];
                let xrow = x.row(c);
                for j in 0..b {
                    acc[j] += gi * xrow[j];
                }
            }
            acc
        });
        let mut out = Mat::zeros(n, b);
        for (r, row) in rows.iter().enumerate() {
            out.row_mut(r).copy_from_slice(row);
        }
        out
    }
    /// Blocked all-hypers derivative: a single pass over all pairs computes
    /// every hyper's derivative block for every probe column — the per-pair
    /// `kernel.grad` evaluation (the expensive part) is amortized over
    /// `num_hypers x b` accumulations.
    fn apply_grad_all_mat(&self, x: &Mat) -> Vec<Mat> {
        let n = self.n();
        assert_eq!(x.rows, n);
        let nhyp = self.num_hypers() as u64;
        let _obs =
            obs::apply_site(self.obs_grad_kind(), nhyp, nhyp * x.cols as u64);
        let b = x.cols;
        let nh = self.kernel.num_hypers();
        let threads = parallel::default_threads();
        // Per row: nh x b accumulators, flattened hyper-major.
        let rows: Vec<Vec<f64>> = parallel::par_map(n, threads, |r| {
            let mut acc = vec![0.0; nh * b];
            let mut g = vec![0.0; nh];
            for c in 0..n {
                self.kernel.grad(&self.points[r], &self.points[c], &mut g);
                let xrow = x.row(c);
                for t in 0..nh {
                    let gt = g[t];
                    let a = &mut acc[t * b..(t + 1) * b];
                    for j in 0..b {
                        a[j] += gt * xrow[j];
                    }
                }
            }
            acc
        });
        let mut outs = vec![Mat::zeros(n, b); nh + 1];
        for (r, row) in rows.iter().enumerate() {
            for t in 0..nh {
                outs[t].row_mut(r).copy_from_slice(&row[t * b..(t + 1) * b]);
            }
        }
        let s = 2.0 * self.noise_var();
        for i in 0..n {
            let xrow = x.row(i);
            for (o, xi) in outs[nh].row_mut(i).iter_mut().zip(xrow) {
                *o = s * xi;
            }
        }
        outs
    }
    fn noise_var(&self) -> f64 {
        (2.0 * self.log_sigma).exp()
    }
    fn diag(&self) -> Option<Vec<f64>> {
        let s2 = self.noise_var();
        Some((0..self.n()).map(|i| self.k[(i, i)] + s2).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::util::rng::Rng;

    fn make(n: usize, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.gaussian(), rng.gaussian()]).collect();
        DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 2, 0.7, 1.2)),
            0.3,
        )
    }

    #[test]
    fn apply_matches_matrix() {
        let op = make(30, 1);
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..30).map(|_| rng.gaussian()).collect();
        let via_mat = op.full_matrix().matvec(&x);
        let via_op = op.apply_vec(&x);
        for i in 0..30 {
            assert!((via_mat[i] - via_op[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn grad_all_matches_single() {
        let op = make(15, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..15).map(|_| rng.gaussian()).collect();
        let nh = op.num_hypers();
        let mut all: Vec<Vec<f64>> = vec![vec![0.0; 15]; nh];
        op.apply_grad_all(&x, &mut all);
        for i in 0..nh {
            let mut single = vec![0.0; 15];
            op.apply_grad(i, &x, &mut single);
            for p in 0..15 {
                assert!((all[i][p] - single[p]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn grad_matches_fd() {
        let mut op = make(12, 5);
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..12).map(|_| rng.gaussian()).collect();
        let h0 = op.hypers();
        let eps = 1e-6;
        for i in 0..op.num_hypers() {
            let mut y = vec![0.0; 12];
            op.apply_grad(i, &x, &mut y);
            let mut hp = h0.clone();
            hp[i] += eps;
            op.set_hypers(&hp);
            let up = op.apply_vec(&x);
            hp[i] -= 2.0 * eps;
            op.set_hypers(&hp);
            let dn = op.apply_vec(&x);
            op.set_hypers(&h0);
            for p in 0..12 {
                let fd = (up[p] - dn[p]) / (2.0 * eps);
                assert!((y[p] - fd).abs() < 1e-5 * (1.0 + fd.abs()));
            }
        }
    }

    /// F64 mode is bitwise `apply_mat`; mixed mode equals the f64 GEMM run on
    /// the rounded K (bitwise, via the MatF32 contract) and stays within the
    /// storage-rounding error bound; `set_hypers` drops the stale panel.
    #[test]
    fn apply_mat_prec_contract_and_refresh() {
        let mut op = make(24, 11);
        let mut rng = Rng::new(12);
        let x = Mat::from_fn(24, 3, |_, _| rng.gaussian());
        let f64_path = op.apply_mat_prec(&x, Precision::F64);
        let plain = op.apply_mat(&x);
        for (a, b) in f64_path.data.iter().zip(&plain.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let check_mixed = |op: &DenseKernelOp, x: &Mat| {
            let mixed = op.apply_mat_prec(x, Precision::F32F64);
            // Reference: f64 GEMM on the rounded K + exact noise term.
            let rounded = Mat {
                rows: op.kernel_matrix().rows,
                cols: op.kernel_matrix().cols,
                data: op
                    .kernel_matrix()
                    .data
                    .iter()
                    .map(|&v| f64::from(v as f32))
                    .collect(),
            };
            let mut want = rounded.matmul(x);
            let s2 = op.noise_var();
            for (o, xi) in want.data.iter_mut().zip(&x.data) {
                *o += s2 * xi;
            }
            for (a, b) in mixed.data.iter().zip(&want.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        };
        check_mixed(&op, &x);
        // Changing hypers rebuilds K; the panel must follow the new K.
        let mut h = op.hypers();
        h[0] += 0.25;
        op.set_hypers(&h);
        check_mixed(&op, &x);
    }

    #[test]
    fn diag_exposed() {
        let op = make(10, 7);
        let d = op.diag().unwrap();
        let full = op.full_matrix();
        for i in 0..10 {
            assert!((d[i] - full[(i, i)]).abs() < 1e-12);
        }
    }
}
