//! Linear operators: the paper's entire method consumes matrices *only*
//! through fast MVMs, so everything — dense baselines, Toeplitz/Kronecker
//! structure, SKI, low-rank FITC, sums — implements [`LinOp`], and kernel
//! matrices with learnable hyperparameters implement [`KernelOp`] which adds
//! derivative MVMs `(∂K̃/∂θ_i) x`.
//!
//! # The block-probe contract
//!
//! The estimators batch all their probe vectors into one `n x b` [`Mat`] and
//! drive operators through [`LinOp::apply_mat`] /
//! [`KernelOp::apply_grad_mat`] / [`KernelOp::apply_grad_all_mat`] — the
//! blocked entry points are the **hot path**; single-vector `apply` is the
//! convenience wrapper. The contract every implementation obeys:
//!
//! * **Who owns blocking.** Operators never re-chunk a block: they process
//!   all `b` columns in one pass over their structure (one sweep of dense
//!   kernel entries, one shared circulant spectrum + FFT plan, one fused
//!   Kronecker mode sweep). Callers (estimators, the batch service) choose
//!   `b` via their `block_size` options and slice the probe matrix.
//! * **Column independence.** Column `j` of `apply_mat(X)` must be bitwise
//!   identical to `apply(X[:, j])` — same floating-point accumulation order,
//!   no cross-column arithmetic (e.g. no two-reals-in-one-complex FFT
//!   packing). This is what makes blocked estimates seed-identical to the
//!   `b = 1` path and is enforced by `tests/proptests.rs`.
//! * **Scratch buffers.** Per-apply workspaces (FFT scratch, fiber buffers,
//!   grid-sized temporaries) are either cached on the operator at
//!   construction (FFT plans, circulant spectra) or allocated once per
//!   *block*, never once per column. Single-vector `apply` may reuse an
//!   internal mutex-guarded scratch where profiling showed per-call
//!   allocation (e.g. [`LaplaceBOp`]).
//! * **MVM accounting.** Estimators count work in probe-column MVMs
//!   (`mvms`, comparable across block sizes) and separately in block applies
//!   (`block_applies`, what the hardware actually executes). Operators don't
//!   keep counts of their own — but every blocked entry point opens a
//!   [`crate::util::obs::apply_site`] span (named [`LinOp::obs_kind`]) that
//!   mirrors exactly that convention when `--trace` is on: one
//!   `block_applies` / `cols` `mvms` per top-level blocked apply, with
//!   *nested* applies (a sum charging its parts, a wrapper charging its
//!   inner operator, `apply_mat_prec` falling through to `apply_mat`)
//!   suppressed so the traced totals equal the estimators' accounting.
//!   Scalar `apply`/`apply_vec` is deliberately uninstrumented (pivoted-
//!   Cholesky pivot probes and bracket estimation are outside the
//!   `LogdetEstimate` accounting).
//!
//! # The precision contract (see [`crate::util::precision`])
//!
//! [`LinOp::apply_mat_prec`] is the precision-aware entry point the
//! solvers and estimators drive. Its contract:
//!
//! * **`Precision::F64` is `apply_mat`, bitwise.** The default
//!   implementation *is* `apply_mat`, and every override must route the
//!   `F64` arm to the identical code — proptests pin this per operator.
//! * **`Precision::F32F64` stores f32, accumulates f64.** Operators with a
//!   bandwidth-bound storage panel (the dense kernel matrix, the SKI
//!   interpolation CSR values, the Toeplitz FFT input/output staging)
//!   read that panel as f32; every multiply-accumulate widens back to f64
//!   first, and exact structural terms (the noise diagonal `σ² x`, the
//!   Toeplitz circulant spectrum, Kronecker factor algebra) stay f64.
//!   The resulting forward error is bounded by a small multiple of
//!   `eps(f32) · Σ_k |A_ik||x_kj|` per element.
//! * **Operators without an f32 panel fall through to f64.** Mixed
//!   precision is a bandwidth optimization, never an accuracy
//!   *requirement*: an operator with nothing worth storing in f32
//!   (diagonal, low-rank, already-factored) simply runs its f64 path, and
//!   the solvers' refinement logic is still correct (zero extra error).
//! * **Convergence is still f64.** `residual_mat` has no precision knob on
//!   purpose — the solvers' true-residual confirmation always runs full
//!   f64, so `converged == true` keeps its f64 meaning in every mode.
//!
//! The PJRT runtime ops (`runtime::ops`) already exposed exactly this
//! batched interface; the native operators now match it.

pub mod combine;
pub mod dense_kernel;
pub mod kron;
pub mod lowrank;
pub mod sparse;
pub mod ski;
pub mod toeplitz;

pub use combine::SumKernelOp;
pub use dense_kernel::DenseKernelOp;
pub use kron::{KronFactor, KronOp};
pub use lowrank::FitcOp;
pub use sparse::{Csr, CsrF32};
pub use ski::SkiOp;
pub use toeplitz::ToeplitzOp;

use crate::linalg::dense::Mat;
use crate::util::obs;
use crate::util::precision::Precision;

/// A symmetric linear operator exposed through matrix–vector products.
pub trait LinOp: Send + Sync {
    /// Dimension (operators here are square).
    fn n(&self) -> usize;

    /// Stable short name for this operator's tracing span
    /// ([`crate::util::obs::apply_site`]); concrete operators override it
    /// so the `--trace` tree attributes applies per operator type.
    fn obs_kind(&self) -> &'static str {
        "linop"
    }

    /// y = A x (no aliasing; `y` is fully overwritten).
    fn apply(&self, x: &[f64], y: &mut [f64]);

    /// Allocating convenience wrapper.
    fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n()];
        self.apply(x, &mut y);
        y
    }

    /// Y = A X for an `n x b` block of columns — the primary (hot) entry
    /// point; see the module docs for the block-probe contract. The default
    /// loops over `apply`; structured operators override it with a real
    /// blocked implementation.
    fn apply_mat(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let mut out = Mat::zeros(x.rows, x.cols);
        let mut xin = vec![0.0; x.rows];
        let mut yout = vec![0.0; x.rows];
        for j in 0..x.cols {
            x.col_into(j, &mut xin);
            self.apply(&xin, &mut yout);
            out.set_col(j, &yout);
        }
        out
    }

    /// Precision-aware blocked apply (see the module-level precision
    /// contract). The default ignores the knob and runs [`LinOp::apply_mat`]
    /// — which makes `Precision::F64` bit-identical to the historical path
    /// by construction, and leaves operators without an f32 storage panel
    /// on their (exact) f64 path in every mode. Operators with a
    /// bandwidth-bound panel override the `F32F64` arm only.
    fn apply_mat_prec(&self, x: &Mat, prec: Precision) -> Mat {
        let _ = prec;
        self.apply_mat(x)
    }

    /// `R = B − A X` in one blocked apply — the shared residual update
    /// behind the iterative solvers (warm-start initialization,
    /// true-residual confirmation). Entry `(i, j)` is computed as
    /// `b[(i, j)] − (A x_j)[i]`, exactly the single-vector path's
    /// arithmetic, so it inherits the column-independence contract of
    /// [`LinOp::apply_mat`].
    fn residual_mat(&self, b: &Mat, x: &Mat) -> Mat {
        assert_eq!(b.rows, self.n());
        assert_eq!((b.rows, b.cols), (x.rows, x.cols));
        let mut r = self.apply_mat(x);
        for (ri, bi) in r.data.iter_mut().zip(&b.data) {
            *ri = bi - *ri;
        }
        r
    }

    /// Materialize as a dense matrix (test/baseline utility: O(n^2) applies).
    fn to_dense(&self) -> Mat {
        let n = self.n();
        let mut a = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        let mut col = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            self.apply(&e, &mut col);
            e[j] = 0.0;
            for i in 0..n {
                a[(i, j)] = col[i];
            }
        }
        a
    }
}

/// A noisy kernel operator `K̃(θ) = K(θ) + σ² I` with derivative MVMs.
///
/// Convention: hypers are log-space, the **last** hyper is `log σ`.
pub trait KernelOp: LinOp {
    /// Number of hyperparameters including the noise (last).
    fn num_hypers(&self) -> usize;
    fn hypers(&self) -> Vec<f64>;
    fn set_hypers(&mut self, h: &[f64]);
    fn hyper_names(&self) -> Vec<String>;

    /// y = (∂K̃/∂θ_i) x.
    fn apply_grad(&self, i: usize, x: &[f64], y: &mut [f64]);

    /// All derivative MVMs at once; overriding lets dense ops share a
    /// single pass over entries.
    fn apply_grad_all(&self, x: &[f64], ys: &mut [Vec<f64>]) {
        assert_eq!(ys.len(), self.num_hypers());
        for (i, y) in ys.iter_mut().enumerate() {
            self.apply_grad(i, x, y);
        }
    }

    /// Span name for derivative applies — defaults to `obs_kind` + a
    /// `_grad` suffix convention is impossible with `&'static str` concat,
    /// so concrete operators override this when they override `obs_kind`.
    fn obs_grad_kind(&self) -> &'static str {
        "linop_grad"
    }

    /// Y = (∂K̃/∂θ_i) X for an `n x b` probe block (blocked derivative MVM).
    /// Same column-independence contract as [`LinOp::apply_mat`].
    fn apply_grad_mat(&self, i: usize, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_grad_kind(), 1, x.cols as u64);
        let mut out = Mat::zeros(x.rows, x.cols);
        let mut xin = vec![0.0; x.rows];
        let mut yout = vec![0.0; x.rows];
        for j in 0..x.cols {
            x.col_into(j, &mut xin);
            self.apply_grad(i, &xin, &mut yout);
            out.set_col(j, &yout);
        }
        out
    }

    /// All hyper-derivative blocks at once: `out[i] = (∂K̃/∂θ_i) X`. The
    /// default takes one *blocked* derivative pass per hyper (so operators
    /// that only override [`KernelOp::apply_grad_mat`] — SKI, Kron, FITC —
    /// still amortize each pass over all b columns); dense ops override
    /// this again to fold every hyper *and* every column into a single
    /// pass over kernel entries.
    fn apply_grad_all_mat(&self, x: &Mat) -> Vec<Mat> {
        assert_eq!(x.rows, self.n());
        (0..self.num_hypers()).map(|i| self.apply_grad_mat(i, x)).collect()
    }

    /// σ² (from the last hyper).
    fn noise_var(&self) -> f64 {
        let h = self.hypers();
        (2.0 * h[h.len() - 1]).exp()
    }

    /// Diagonal of K̃, when cheaply available — used by predictive
    /// variance, FITC-style corrections, and the pivoted-Cholesky
    /// preconditioner (`linalg::pchol` seeds its greedy pivot selection
    /// from this diagonal; an operator returning `None` simply runs
    /// unpreconditioned). Dense, SKI, grid-Kronecker, FITC/SoR, and sum
    /// operators all return `Some`.
    fn diag(&self) -> Option<Vec<f64>> {
        None
    }
}

/// Plain dense symmetric matrix as an operator (tests and small baselines).
pub struct DenseMatOp {
    /// The matrix. Treated as immutable after construction — the mixed-
    /// precision panel below caches its f32 rounding at first use.
    pub a: Mat,
    /// Lazily built f32 storage panel for `Precision::F32F64` applies.
    a32: std::sync::OnceLock<crate::linalg::dense::MatF32>,
}

impl DenseMatOp {
    pub fn new(a: Mat) -> Self {
        assert_eq!(a.rows, a.cols);
        DenseMatOp { a, a32: std::sync::OnceLock::new() }
    }
}

impl LinOp for DenseMatOp {
    fn n(&self) -> usize {
        self.a.rows
    }
    fn obs_kind(&self) -> &'static str {
        "dense_mat"
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.a.matvec_into(x, y);
    }
    fn apply_mat(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        self.a.matmul(x)
    }
    fn apply_mat_prec(&self, x: &Mat, prec: Precision) -> Mat {
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        match prec {
            Precision::F64 => self.apply_mat(x),
            Precision::F32F64 => {
                assert_eq!(x.rows, self.n());
                let panel = self.a32.get_or_init(|| {
                    crate::linalg::dense::MatF32::from_mat(&self.a)
                });
                panel.matmul_threads(x, 1)
            }
        }
    }
    fn to_dense(&self) -> Mat {
        self.a.clone()
    }
}

/// Diagonal operator.
pub struct DiagOp {
    pub d: Vec<f64>,
}

impl LinOp for DiagOp {
    fn n(&self) -> usize {
        self.d.len()
    }
    fn obs_kind(&self) -> &'static str {
        "diag"
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n());
        assert_eq!(y.len(), self.n());
        for i in 0..x.len() {
            y[i] = self.d[i] * x[i];
        }
    }
    fn apply_mat(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let mut out = x.clone();
        for i in 0..out.rows {
            let di = self.d[i];
            for v in out.row_mut(i) {
                *v *= di;
            }
        }
        out
    }
}

/// `A + c I` view over a borrowed operator (e.g. Laplace's B matrices).
pub struct ShiftedOp<'a> {
    pub inner: &'a dyn LinOp,
    pub shift: f64,
}

impl LinOp for ShiftedOp<'_> {
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn obs_kind(&self) -> &'static str {
        "shifted"
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n());
        assert_eq!(y.len(), self.n());
        self.inner.apply(x, y);
        for i in 0..x.len() {
            y[i] += self.shift * x[i];
        }
    }
    fn apply_mat(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let mut out = self.inner.apply_mat(x);
        for (o, xi) in out.data.iter_mut().zip(&x.data) {
            *o += self.shift * xi;
        }
        out
    }
    /// Forwards the precision knob to the wrapped operator; the shift term
    /// is exact structural arithmetic and stays f64 in every mode.
    fn apply_mat_prec(&self, x: &Mat, prec: Precision) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let mut out = self.inner.apply_mat_prec(x, prec);
        for (o, xi) in out.data.iter_mut().zip(&x.data) {
            *o += self.shift * xi;
        }
        out
    }
}

/// `D^{1/2} A D^{1/2} + I` — the Laplace approximation's B operator, where
/// `D = diag(w)` holds the likelihood curvature (w >= 0).
pub struct LaplaceBOp<'a> {
    pub inner: &'a dyn LinOp,
    pub sqrt_w: Vec<f64>,
    /// Reusable per-apply workspace (Lanczos calls `apply` thousands of
    /// times; allocating n doubles per call showed up in profiles).
    scratch: std::sync::Mutex<Vec<f64>>,
}

impl<'a> LaplaceBOp<'a> {
    pub fn new(inner: &'a dyn LinOp, w: &[f64]) -> Self {
        assert_eq!(inner.n(), w.len());
        LaplaceBOp {
            inner,
            sqrt_w: w.iter().map(|v| v.max(0.0).sqrt()).collect(),
            scratch: std::sync::Mutex::new(Vec::new()),
        }
    }
}

impl LinOp for LaplaceBOp<'_> {
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn obs_kind(&self) -> &'static str {
        "laplace_b"
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let n = self.n();
        assert_eq!(x.len(), n);
        assert_eq!(y.len(), n);
        let mut t = self.scratch.lock().unwrap();
        t.resize(n, 0.0);
        for i in 0..n {
            t[i] = self.sqrt_w[i] * x[i];
        }
        self.inner.apply(&t, y);
        for i in 0..n {
            y[i] = self.sqrt_w[i] * y[i] + x[i];
        }
    }
    fn apply_mat(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let mut t = x.clone();
        for i in 0..t.rows {
            let s = self.sqrt_w[i];
            for v in t.row_mut(i) {
                *v *= s;
            }
        }
        let mut out = self.inner.apply_mat(&t);
        for i in 0..out.rows {
            let s = self.sqrt_w[i];
            let xrow = x.row(i);
            for (v, xi) in out.row_mut(i).iter_mut().zip(xrow) {
                *v = s * *v + xi;
            }
        }
        out
    }
    /// Forwards the precision knob to the wrapped operator; the curvature
    /// scaling and `+ x` term are exact and stay f64 in every mode.
    fn apply_mat_prec(&self, x: &Mat, prec: Precision) -> Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let mut t = x.clone();
        for i in 0..t.rows {
            let s = self.sqrt_w[i];
            for v in t.row_mut(i) {
                *v *= s;
            }
        }
        let mut out = self.inner.apply_mat_prec(&t, prec);
        for i in 0..out.rows {
            let s = self.sqrt_w[i];
            let xrow = x.row(i);
            for (v, xi) in out.row_mut(i).iter_mut().zip(xrow) {
                *v = s * *v + xi;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_op_roundtrip() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]);
        let op = DenseMatOp::new(a.clone());
        assert_eq!(op.apply_vec(&[1.0, 1.0]), vec![3.0, 4.0]);
        assert_eq!(op.to_dense().data, a.data);
    }

    #[test]
    fn apply_mat_matches_columns() {
        let a = Mat::from_fn(4, 4, |i, j| (i + j) as f64);
        let op = DenseMatOp::new(a);
        let x = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64 * 0.1);
        let y = op.apply_mat(&x);
        for j in 0..3 {
            let col = op.apply_vec(&x.col(j));
            for i in 0..4 {
                assert!((y[(i, j)] - col[i]).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn shifted_and_diag_ops() {
        let a = Mat::eye(3);
        let op = DenseMatOp::new(a);
        let sh = ShiftedOp { inner: &op, shift: 2.0 };
        assert_eq!(sh.apply_vec(&[1.0, 2.0, 3.0]), vec![3.0, 6.0, 9.0]);
        let d = DiagOp { d: vec![1.0, 2.0, 3.0] };
        assert_eq!(d.apply_vec(&[1.0, 1.0, 1.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn diag_op_rejects_short_input() {
        let d = DiagOp { d: vec![1.0, 2.0, 3.0] };
        let mut y = vec![0.0; 2];
        d.apply(&[1.0, 1.0], &mut y);
    }

    #[test]
    #[should_panic]
    fn shifted_op_rejects_short_input() {
        let a = Mat::eye(3);
        let op = DenseMatOp::new(a);
        let sh = ShiftedOp { inner: &op, shift: 1.0 };
        let mut y = vec![0.0; 2];
        sh.apply(&[1.0, 1.0], &mut y);
    }

    #[test]
    fn shifted_diag_laplace_apply_mat_match_columns() {
        let a = Mat::from_rows(&[vec![1.0, 0.5, 0.1], vec![0.5, 2.0, 0.3], vec![0.1, 0.3, 1.5]]);
        let op = DenseMatOp::new(a);
        let x = Mat::from_fn(3, 4, |i, j| (i as f64 + 1.0) * 0.3 - j as f64 * 0.2);
        let sh = ShiftedOp { inner: &op, shift: 0.7 };
        let dg = DiagOp { d: vec![0.5, 1.5, -2.0] };
        let lb = LaplaceBOp::new(&op, &[0.2, 1.0, 3.0]);
        for (name, o) in
            [("shifted", &sh as &dyn LinOp), ("diag", &dg), ("laplace_b", &lb)]
        {
            let y = o.apply_mat(&x);
            for j in 0..x.cols {
                let col = o.apply_vec(&x.col(j));
                for i in 0..3 {
                    assert!(
                        (y[(i, j)] - col[i]).abs() < 1e-14,
                        "{name} ({i},{j}): {} vs {}",
                        y[(i, j)],
                        col[i]
                    );
                }
            }
        }
    }

    #[test]
    fn residual_mat_matches_per_column() {
        let a = Mat::from_rows(&[vec![1.5, 0.4, 0.1], vec![0.4, 2.0, 0.2], vec![0.1, 0.2, 1.2]]);
        let op = DenseMatOp::new(a);
        let x = Mat::from_fn(3, 2, |i, j| (i as f64 - j as f64) * 0.5);
        let b = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f64 * 0.3);
        let r = op.residual_mat(&b, &x);
        for j in 0..2 {
            let ax = op.apply_vec(&x.col(j));
            for i in 0..3 {
                let want = b[(i, j)] - ax[i];
                assert_eq!(r[(i, j)].to_bits(), want.to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn laplace_b_op_identity_weights() {
        // W = I: B x = A x + x.
        let a = Mat::from_rows(&[vec![1.0, 0.5], vec![0.5, 2.0]]);
        let op = DenseMatOp::new(a.clone());
        let b = LaplaceBOp::new(&op, &[1.0, 1.0]);
        let x = [1.0, -1.0];
        let want = [a[(0, 0)] - a[(0, 1)] + 1.0, a[(1, 0)] - a[(1, 1)] - 1.0];
        let got = b.apply_vec(&x);
        assert!((got[0] - want[0]).abs() < 1e-14);
        assert!((got[1] - want[1]).abs() < 1e-14);
    }
}
