//! Compressed sparse row matrices — SKI's interpolation matrix W has 4^d
//! nonzeros per row (local cubic interpolation), which is what keeps the
//! n-dependent part of every MVM at O(n).
//!
//! [`CsrF32`] is the mixed-precision (`Precision::F32F64`) storage mirror
//! of a [`Csr`]: f32 values plus u32 column indices, 8 bytes per nonzero
//! against the f64/usize 16 — the CSR sweep is pure streaming, so the
//! mirror halves its memory traffic. Accumulation stays f64 (each stored
//! value is widened before the multiply), matching the sweep order of
//! [`Csr::apply_mat`] exactly.

/// CSR matrix.
#[derive(Clone, Debug)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl Csr {
    /// Build from row-wise (col, value) lists.
    pub fn from_rows(ncols: usize, rows: &[Vec<(usize, f64)>]) -> Self {
        let nrows = rows.len();
        let mut indptr = Vec::with_capacity(nrows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for r in rows {
            for &(c, v) in r {
                assert!(c < ncols);
                indices.push(c);
                data.push(v);
            }
            indptr.push(indices.len());
        }
        Csr { nrows, ncols, indptr, indices, data }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// y = A x.
    pub fn apply(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        for i in 0..self.nrows {
            let mut s = 0.0;
            for k in self.indptr[i]..self.indptr[i + 1] {
                s += self.data[k] * x[self.indices[k]];
            }
            y[i] = s;
        }
    }

    /// Y = A X for a dense column block (`nrows x b` from `ncols x b`):
    /// one pass over the sparsity pattern drives all b columns, so each
    /// stored entry is loaded once per block instead of once per probe.
    /// Per-column accumulation order matches `apply` exactly.
    pub fn apply_mat(&self, x: &crate::linalg::dense::Mat) -> crate::linalg::dense::Mat {
        assert_eq!(x.rows, self.ncols);
        let b = x.cols;
        let mut out = crate::linalg::dense::Mat::zeros(self.nrows, b);
        for i in 0..self.nrows {
            let orow = &mut out.data[i * b..(i + 1) * b];
            for k in self.indptr[i]..self.indptr[i + 1] {
                let v = self.data[k];
                let xrow = x.row(self.indices[k]);
                for j in 0..b {
                    orow[j] += v * xrow[j];
                }
            }
        }
        out
    }

    /// y = A^T x (accumulating; y is zeroed first).
    pub fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.ncols);
        y.fill(0.0);
        for i in 0..self.nrows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for k in self.indptr[i]..self.indptr[i + 1] {
                y[self.indices[k]] += self.data[k] * xi;
            }
        }
    }

    /// Explicit transpose (when A^T is applied often, a materialized CSR
    /// transpose is faster than scattered accumulation).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.ncols];
        for &c in &self.indices {
            counts[c] += 1;
        }
        let mut indptr = vec![0usize; self.ncols + 1];
        for j in 0..self.ncols {
            indptr[j + 1] = indptr[j] + counts[j];
        }
        let mut indices = vec![0usize; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        let mut next = indptr.clone();
        for i in 0..self.nrows {
            for k in self.indptr[i]..self.indptr[i + 1] {
                let c = self.indices[k];
                let pos = next[c];
                indices[pos] = i;
                data[pos] = self.data[k];
                next[c] += 1;
            }
        }
        Csr { nrows: self.ncols, ncols: self.nrows, indptr, indices, data }
    }

    /// Row i as a slice pair (indices, values).
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let r = self.indptr[i]..self.indptr[i + 1];
        (&self.indices[r.clone()], &self.data[r])
    }

    /// Dense materialization (tests).
    pub fn to_dense(&self) -> crate::linalg::dense::Mat {
        let mut m = crate::linalg::dense::Mat::zeros(self.nrows, self.ncols);
        for i in 0..self.nrows {
            let (idx, val) = self.row(i);
            for (c, v) in idx.iter().zip(val) {
                m[(i, *c)] = *v;
            }
        }
        m
    }
}

/// f32-value / u32-index storage mirror of a [`Csr`] (module docs). Built
/// once from the f64 source and invalidated by the owner whenever the
/// source is rebuilt (e.g. `SkiOp::refresh`).
#[derive(Clone, Debug)]
pub struct CsrF32 {
    pub nrows: usize,
    pub ncols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub data: Vec<f32>,
}

impl CsrF32 {
    /// Round a CSR to its mixed-precision mirror (one `as f32` rounding
    /// per stored value; indices must fit u32).
    pub fn from_csr(a: &Csr) -> Self {
        assert!(
            a.ncols <= u32::MAX as usize,
            "CsrF32 mirror needs column indices that fit u32"
        );
        CsrF32 {
            nrows: a.nrows,
            ncols: a.ncols,
            indptr: a.indptr.clone(),
            indices: a.indices.iter().map(|&c| c as u32).collect(),
            data: a.data.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Y = A X with f64 accumulation: the same one-pass-over-sparsity
    /// sweep as [`Csr::apply_mat`], streaming half the bytes per nonzero.
    /// Bitwise equal to [`Csr::apply_mat`] on the rounded matrix.
    pub fn apply_mat(&self, x: &crate::linalg::dense::Mat) -> crate::linalg::dense::Mat {
        assert_eq!(x.rows, self.ncols);
        let b = x.cols;
        let mut out = crate::linalg::dense::Mat::zeros(self.nrows, b);
        for i in 0..self.nrows {
            let orow = &mut out.data[i * b..(i + 1) * b];
            for k in self.indptr[i]..self.indptr[i + 1] {
                let v = f64::from(self.data[k]);
                let xrow = x.row(self.indices[k] as usize);
                for j in 0..b {
                    orow[j] += v * xrow[j];
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Csr::from_rows(
            4,
            &[
                vec![(0, 1.0), (2, 2.0)],
                vec![(1, 3.0)],
                vec![(0, -1.0), (3, 4.0)],
            ],
        )
    }

    #[test]
    fn apply_matches_dense() {
        let a = sample();
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.0; 3];
        a.apply(&x, &mut y);
        assert_eq!(y, vec![7.0, 6.0, 15.0]);
        let d = a.to_dense();
        let yd = d.matvec(&x);
        assert_eq!(y, yd);
    }

    #[test]
    fn apply_mat_matches_columns() {
        let a = sample();
        let x = crate::linalg::dense::Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64 * 0.5 - 2.0);
        let y = a.apply_mat(&x);
        for j in 0..3 {
            let mut col = vec![0.0; 3];
            a.apply(&x.col(j), &mut col);
            for i in 0..3 {
                assert_eq!(y[(i, j)].to_bits(), col[i].to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn transpose_apply_consistency() {
        let a = sample();
        let x = [1.0, -1.0, 0.5];
        let mut y1 = vec![0.0; 4];
        a.apply_t(&x, &mut y1);
        let at = a.transpose();
        let mut y2 = vec![0.0; 4];
        at.apply(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn transpose_twice_identity() {
        let a = sample();
        let att = a.transpose().transpose();
        assert_eq!(a.to_dense().data, att.to_dense().data);
    }

    /// The f32 mirror is "round stored values once, then f64 arithmetic":
    /// bitwise equal to the f64 sweep over the rounded CSR.
    #[test]
    fn f32_mirror_matches_rounded_csr_bitwise() {
        let rows: Vec<Vec<(usize, f64)>> = (0..7)
            .map(|i| {
                (0..4)
                    .map(|k| ((i * 3 + k * 5) % 9, ((i * 7 + k) as f64).sin() * 1.7))
                    .collect()
            })
            .collect();
        let a = Csr::from_rows(9, &rows);
        let mirror = CsrF32::from_csr(&a);
        let rounded = Csr {
            data: a.data.iter().map(|&v| f64::from(v as f32)).collect(),
            ..a.clone()
        };
        let x = crate::linalg::dense::Mat::from_fn(9, 5, |i, j| {
            (i as f64 * 0.21 - j as f64 * 0.13).cos()
        });
        let got = mirror.apply_mat(&x);
        let want = rounded.apply_mat(&x);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn nnz_and_rows() {
        let a = sample();
        assert_eq!(a.nnz(), 5);
        let (idx, val) = a.row(2);
        assert_eq!(idx, &[0, 3]);
        assert_eq!(val, &[-1.0, 4.0]);
    }
}
