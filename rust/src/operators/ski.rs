//! SKI / KISS-GP operator: `K̃ = W K_UU W^T + σ² I (+ D)` (paper Eq. 2 and
//! the diagonal correction of §3.3).
//!
//! * `W` — sparse local-interpolation weights (cubic: 4^d nnz/row),
//! * `K_UU` — Kronecker product of per-dimension symmetric Toeplitz
//!   matrices (separable kernel on an equispaced grid),
//! * `D` — optional diagonal correction making diag(K̃) exact, which the
//!   scaled-eigenvalue baseline *cannot* absorb but MVM-based estimators
//!   handle for free.
//!
//! Hyperparameters: the separable kernel's (factor hypers + `log_sf`),
//! then `log σ` last.

use super::kron::{KronFactor, KronOp};
use super::sparse::{Csr, CsrF32};
use super::toeplitz::ToeplitzOp;
use super::{KernelOp, LinOp};
use crate::grid::{Grid, InterpOrder, Stencil};
use crate::kernels::{Kernel, SeparableKernel};
use crate::util::obs;
use crate::util::precision::Precision;

impl Clone for ToeplitzOp {
    fn clone(&self) -> Self {
        ToeplitzOp::new(self.col.clone())
    }
}

/// Quadratic form of a 1-D stencil against a Toeplitz column:
/// `w^T T w = sum_{a,b} w_a w_b col[|i_a - i_b|]`.
fn stencil_quadform(st: &Stencil, col: &[f64]) -> f64 {
    let mut s = 0.0;
    for (a, &ia) in st.idx.iter().enumerate() {
        for (b, &ib) in st.idx.iter().enumerate() {
            s += st.w[a] * st.w[b] * col[ia.abs_diff(ib)];
        }
    }
    s
}

/// The SKI kernel operator.
pub struct SkiOp {
    pub grid: Grid,
    pub kernel: SeparableKernel,
    pub log_sigma: f64,
    pub order: InterpOrder,
    /// Whether the §3.3 diagonal correction is active.
    pub diag_correction: bool,

    w: Csr,
    wt: Csr,
    /// Lazily built f32/u32 mirrors of `w`/`wt` for mixed-precision sweeps.
    /// The interpolation weights depend only on points/grid/order — never
    /// on hypers — so the mirrors cannot go stale across `set_hypers`.
    w32: std::sync::OnceLock<CsrF32>,
    wt32: std::sync::OnceLock<CsrF32>,
    /// Memoized test-set interpolation matrix for [`SkiOp::cross_mvm`]:
    /// `(fingerprint, W*)` of the last test set seen, so repeated
    /// predict/variance calls over one test set build `W*` once.
    wstar_cache: std::sync::Mutex<Option<(u64, Csr)>>,
    stencils: Vec<Vec<Stencil>>,
    n: usize,

    // Rebuilt by `refresh()` whenever hypers change:
    /// Unit-amplitude Toeplitz first columns per dimension.
    cols: Vec<Vec<f64>>,
    /// Derivative columns: per factor, per local hyper.
    dcols: Vec<Vec<Vec<f64>>>,
    /// K_UU as a (sf^2-scaled) Kronecker operator.
    kuu: KronOp,
    /// Cached derivative Kronecker operators, one per factor hyper (in
    /// kernel-hyper order) — rebuilding these per apply_grad call costs a
    /// fresh circulant FFT each time (§Perf opt 1).
    dkrons: Vec<KronOp>,
    /// Per-point per-dim quadratic forms w^T T_j w (n x d, row-major).
    q_forms: Vec<f64>,
    /// k(x, x) (constant for stationary separable kernels).
    tdiag: f64,
    /// d k(x,x) / d hyper (constant across points), kernel hypers only.
    tdiag_grad: Vec<f64>,
    /// Diagonal correction vector D (empty when disabled).
    dvec: Vec<f64>,
}

impl SkiOp {
    /// Build a SKI operator for data `points` on `grid`.
    pub fn new(
        points: &[Vec<f64>],
        grid: Grid,
        kernel: SeparableKernel,
        sigma: f64,
        order: InterpOrder,
        diag_correction: bool,
    ) -> Self {
        assert_eq!(grid.ndims(), kernel.dim());
        let (w, stencils) = grid.interp_matrix(points, order);
        let wt = w.transpose();
        let n = points.len();
        let d = grid.ndims();
        let mut op = SkiOp {
            grid,
            kernel,
            log_sigma: sigma.ln(),
            order,
            diag_correction,
            w,
            wt,
            w32: std::sync::OnceLock::new(),
            wt32: std::sync::OnceLock::new(),
            wstar_cache: std::sync::Mutex::new(None),
            stencils,
            n,
            cols: vec![Vec::new(); d],
            dcols: Vec::new(),
            kuu: KronOp::new(vec![KronFactor::Dense(crate::linalg::dense::Mat::eye(1))], 1.0),
            dkrons: Vec::new(),
            q_forms: Vec::new(),
            tdiag: 0.0,
            tdiag_grad: Vec::new(),
            dvec: Vec::new(),
        };
        op.refresh();
        op
    }

    /// Number of kernel hypers (excluding noise).
    pub fn num_kernel_hypers(&self) -> usize {
        self.kernel.num_hypers()
    }

    /// The interpolation matrix (for prediction and tests).
    pub fn w_matrix(&self) -> &Csr {
        &self.w
    }

    /// The (scaled) K_UU Kronecker operator.
    pub fn kuu(&self) -> &KronOp {
        &self.kuu
    }

    /// Grid size m (total inducing points).
    pub fn m(&self) -> usize {
        self.grid.size()
    }

    /// Diagonal correction vector (empty when disabled).
    pub fn dvec(&self) -> &[f64] {
        &self.dvec
    }

    /// Rebuild all hyper-dependent caches.
    fn refresh(&mut self) {
        let d = self.grid.ndims();
        // Toeplitz first columns and their derivatives from the 1-D factors.
        self.cols.clear();
        self.dcols.clear();
        for j in 0..d {
            let dim = &self.grid.dims[j];
            let f = &self.kernel.factors[j];
            let nh = f.num_hypers();
            let mut col = Vec::with_capacity(dim.m);
            let mut dcol = vec![Vec::with_capacity(dim.m); nh];
            let mut g = vec![0.0; nh];
            for k in 0..dim.m {
                let tau = k as f64 * dim.spacing();
                col.push(f.eval(&[tau], &[0.0]));
                f.grad(&[tau], &[0.0], &mut g);
                for (t, gv) in g.iter().enumerate() {
                    dcol[t].push(*gv);
                }
            }
            self.cols.push(col);
            self.dcols.push(dcol);
        }
        // K_UU = sf^2 * kron(T_j).
        let factors: Vec<KronFactor> = self
            .cols
            .iter()
            .map(|c| KronFactor::Toeplitz(ToeplitzOp::new(c.clone())))
            .collect();
        self.kuu = KronOp::new(factors, self.kernel.sf2());
        // Cached derivative operators (factor hypers only; log_sf and
        // log_sigma are handled analytically in apply_grad).
        self.dkrons.clear();
        for (jf, f) in self.kernel.factors.iter().enumerate() {
            for local in 0..f.num_hypers() {
                let factors: Vec<KronFactor> = (0..d)
                    .map(|j| {
                        let col = if j == jf {
                            self.dcols[j][local].clone()
                        } else {
                            self.cols[j].clone()
                        };
                        KronFactor::Toeplitz(ToeplitzOp::new(col))
                    })
                    .collect();
                self.dkrons.push(KronOp::new(factors, self.kernel.sf2()));
            }
        }

        // Per-point quadratic forms and diagonal correction.
        self.q_forms = vec![0.0; self.n * d];
        for (i, sts) in self.stencils.iter().enumerate() {
            for j in 0..d {
                self.q_forms[i * d + j] = stencil_quadform(&sts[j], &self.cols[j]);
            }
        }
        let x0 = vec![0.0; d];
        self.tdiag = self.kernel.eval(&x0, &x0);
        self.tdiag_grad = vec![0.0; self.kernel.num_hypers()];
        self.kernel.grad(&x0, &x0, &mut self.tdiag_grad);

        if self.diag_correction {
            let sf2 = self.kernel.sf2();
            self.dvec = (0..self.n)
                .map(|i| {
                    let mut prod = sf2;
                    for j in 0..d {
                        prod *= self.q_forms[i * d + j];
                    }
                    self.tdiag - prod
                })
                .collect();
        } else {
            self.dvec.clear();
        }
    }

    /// y = (W K_UU W^T) x using a replacement Kronecker operator (shared by
    /// apply and the derivative MVMs).
    fn apply_wkw(&self, kron: &KronOp, x: &[f64], y: &mut [f64]) {
        let m = self.m();
        let mut xg = vec![0.0; m];
        self.wt.apply(x, &mut xg);
        let mut yg = vec![0.0; m];
        kron.apply(&xg, &mut yg);
        self.w.apply(&yg, y);
    }

    /// Y = (W K_UU W^T) X for a probe block: one CSR sweep per interpolation
    /// matrix and one fused Kronecker block apply, instead of b round trips.
    fn apply_wkw_mat(&self, kron: &KronOp, x: &crate::linalg::dense::Mat) -> crate::linalg::dense::Mat {
        let xg = self.wt.apply_mat(x);
        let yg = kron.apply_mat(&xg);
        self.w.apply_mat(&yg)
    }

    /// Map a kernel-hyper index to its (factor, local) pair, or None for
    /// `log_sf`.
    fn hyper_location(&self, i: usize) -> Option<(usize, usize)> {
        let mut off = 0;
        for (j, f) in self.kernel.factors.iter().enumerate() {
            let k = f.num_hypers();
            if i < off + k {
                return Some((j, i - off));
            }
            off += k;
        }
        None // log_sf
    }

    /// d D / d hyper_i (kernel hypers only), evaluated on the fly.
    fn dvec_grad(&self, i: usize, out: &mut [f64]) {
        let d = self.grid.ndims();
        let sf2 = self.kernel.sf2();
        match self.hyper_location(i) {
            Some((jf, local)) => {
                for (p, o) in out.iter_mut().enumerate() {
                    let mut others = sf2;
                    for j in 0..d {
                        if j != jf {
                            others *= self.q_forms[p * d + j];
                        }
                    }
                    let qd = stencil_quadform(&self.stencils[p][jf], &self.dcols[jf][local]);
                    *o = self.tdiag_grad[i] - others * qd;
                }
            }
            None => {
                // log_sf: both terms scale with sf^2, so dD = 2 D.
                for (p, o) in out.iter_mut().enumerate() {
                    *o = 2.0 * self.dvec.get(p).copied().unwrap_or(0.0);
                }
            }
        }
    }

    /// Fingerprint of a test set for the `W*` memo: the exact coordinate
    /// bit patterns plus the point count and interpolation order, so any
    /// change to any coordinate (even by one ulp) misses the cache.
    fn test_set_fingerprint(&self, test_points: &[Vec<f64>]) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        test_points.len().hash(&mut h);
        std::mem::discriminant(&self.order).hash(&mut h);
        for p in test_points {
            p.len().hash(&mut h);
            for &c in p {
                c.to_bits().hash(&mut h);
            }
        }
        h.finish()
    }

    /// Predictive cross-covariance product `K(X*, X) alpha ≈ W* K_UU W^T alpha`.
    ///
    /// The test-set interpolation matrix `W*` is memoized per test set
    /// (keyed on the exact coordinate bits): GP prediction calls this once
    /// per output (mean, then per-variance solves) over the same `X*`, and
    /// rebuilding the stencils each call dominated predict profiles.
    pub fn cross_mvm(&self, test_points: &[Vec<f64>], alpha: &[f64]) -> Vec<f64> {
        let key = self.test_set_fingerprint(test_points);
        let mut cache = self.wstar_cache.lock().unwrap();
        let rebuild = match cache.as_ref() {
            Some((k, w)) => *k != key || w.nrows != test_points.len(),
            None => true,
        };
        if rebuild {
            let (wstar, _) = self.grid.interp_matrix(test_points, self.order);
            *cache = Some((key, wstar));
        }
        let (_, wstar) = cache.as_ref().expect("wstar cache populated above");
        let m = self.m();
        let mut ag = vec![0.0; m];
        self.wt.apply(alpha, &mut ag);
        let mut kg = vec![0.0; m];
        self.kuu.apply(&ag, &mut kg);
        let mut out = vec![0.0; test_points.len()];
        wstar.apply(&kg, &mut out);
        out
    }
}

impl LinOp for SkiOp {
    fn n(&self) -> usize {
        self.n
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.apply_wkw(&self.kuu, x, y);
        let s2 = self.noise_var();
        if self.diag_correction {
            for i in 0..self.n {
                y[i] += (s2 + self.dvec[i]) * x[i];
            }
        } else {
            for i in 0..self.n {
                y[i] += s2 * x[i];
            }
        }
    }
    fn apply_mat(&self, x: &crate::linalg::dense::Mat) -> crate::linalg::dense::Mat {
        assert_eq!(x.rows, self.n);
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let mut out = self.apply_wkw_mat(&self.kuu, x);
        let s2 = self.noise_var();
        if self.diag_correction {
            for i in 0..self.n {
                let c = s2 + self.dvec[i];
                for (o, xi) in out.row_mut(i).iter_mut().zip(x.row(i)) {
                    *o += c * xi;
                }
            }
        } else {
            for (o, xi) in out.data.iter_mut().zip(&x.data) {
                *o += s2 * xi;
            }
        }
        out
    }
    /// Mixed mode runs the two CSR sweeps over the f32/u32 mirrors of
    /// `W`/`Wᵀ` (half the bytes per nonzero) and stages the grid-factor
    /// circulant through `KronOp`'s precision path; the noise term and the
    /// §3.3 diagonal correction stay exact f64, like every structural term.
    fn apply_mat_prec(
        &self,
        x: &crate::linalg::dense::Mat,
        prec: Precision,
    ) -> crate::linalg::dense::Mat {
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        match prec {
            Precision::F64 => self.apply_mat(x),
            Precision::F32F64 => {
                assert_eq!(x.rows, self.n);
                let wt32 = self.wt32.get_or_init(|| CsrF32::from_csr(&self.wt));
                let w32 = self.w32.get_or_init(|| CsrF32::from_csr(&self.w));
                let xg = wt32.apply_mat(x);
                let yg = self.kuu.apply_mat_prec(&xg, prec);
                let mut out = w32.apply_mat(&yg);
                let s2 = self.noise_var();
                if self.diag_correction {
                    for i in 0..self.n {
                        let c = s2 + self.dvec[i];
                        for (o, xi) in out.row_mut(i).iter_mut().zip(x.row(i)) {
                            *o += c * xi;
                        }
                    }
                } else {
                    for (o, xi) in out.data.iter_mut().zip(&x.data) {
                        *o += s2 * xi;
                    }
                }
                out
            }
        }
    }
    fn obs_kind(&self) -> &'static str {
        "ski"
    }
}

impl KernelOp for SkiOp {
    fn num_hypers(&self) -> usize {
        self.kernel.num_hypers() + 1
    }
    fn obs_grad_kind(&self) -> &'static str {
        "ski_grad"
    }
    fn hypers(&self) -> Vec<f64> {
        let mut h = self.kernel.hypers();
        h.push(self.log_sigma);
        h
    }
    fn set_hypers(&mut self, h: &[f64]) {
        assert_eq!(h.len(), self.num_hypers());
        self.kernel.set_hypers(&h[..h.len() - 1]);
        self.log_sigma = h[h.len() - 1];
        self.refresh();
    }
    fn hyper_names(&self) -> Vec<String> {
        let mut names = self.kernel.hyper_names();
        names.push("log_sigma".into());
        names
    }
    fn apply_grad(&self, i: usize, x: &[f64], y: &mut [f64]) {
        let nk = self.kernel.num_hypers();
        if i == nk {
            // Noise: d(sigma^2)/d log sigma = 2 sigma^2.
            let s = 2.0 * self.noise_var();
            for (yi, xi) in y.iter_mut().zip(x) {
                *yi = s * xi;
            }
            return;
        }
        match self.hyper_location(i) {
            Some((_jf, _local)) => {
                self.apply_wkw(&self.dkrons[i], x, y);
            }
            None => {
                // log_sf: d(sf^2 K)/d log sf = 2 sf^2 K = 2 (W K_UU W^T).
                self.apply_wkw(&self.kuu, x, y);
                for yi in y.iter_mut() {
                    *yi *= 2.0;
                }
            }
        }
        if self.diag_correction {
            let mut dd = vec![0.0; self.n];
            self.dvec_grad(i, &mut dd);
            for p in 0..self.n {
                y[p] += dd[p] * x[p];
            }
        }
    }
    fn apply_grad_mat(&self, i: usize, x: &crate::linalg::dense::Mat) -> crate::linalg::dense::Mat {
        assert_eq!(x.rows, self.n);
        let _obs = obs::apply_site(self.obs_grad_kind(), 1, x.cols as u64);
        let nk = self.kernel.num_hypers();
        if i == nk {
            let s = 2.0 * self.noise_var();
            let mut out = x.clone();
            for v in out.data.iter_mut() {
                *v *= s;
            }
            return out;
        }
        let mut out = match self.hyper_location(i) {
            Some((_jf, _local)) => self.apply_wkw_mat(&self.dkrons[i], x),
            None => {
                // log_sf: d(sf^2 K)/d log sf = 2 (W K_UU W^T).
                let mut y = self.apply_wkw_mat(&self.kuu, x);
                for v in y.data.iter_mut() {
                    *v *= 2.0;
                }
                y
            }
        };
        if self.diag_correction {
            let mut dd = vec![0.0; self.n];
            self.dvec_grad(i, &mut dd);
            for p in 0..self.n {
                let dp = dd[p];
                for (o, xi) in out.row_mut(p).iter_mut().zip(x.row(p)) {
                    *o += dp * xi;
                }
            }
        }
        out
    }
    fn noise_var(&self) -> f64 {
        (2.0 * self.log_sigma).exp()
    }
    fn diag(&self) -> Option<Vec<f64>> {
        let d = self.grid.ndims();
        let sf2 = self.kernel.sf2();
        let s2 = self.noise_var();
        Some(
            (0..self.n)
                .map(|i| {
                    if self.diag_correction {
                        // Corrected: exact kernel diagonal + noise.
                        self.tdiag + s2
                    } else {
                        let mut prod = sf2;
                        for j in 0..d {
                            prod *= self.q_forms[i * d + j];
                        }
                        prod + s2
                    }
                })
                .collect(),
        )
    }
}

/// Kernel operator directly on the grid (`W = I`): the latent covariance of
/// log-Gaussian Cox process models whose observations live on grid cells
/// (Hickory §5.3, crime §5.4). `K̃ = sf^2 kron(T_j) + σ² I`.
pub struct KronKernelOp {
    pub grid: Grid,
    pub kernel: SeparableKernel,
    pub log_sigma: f64,
    cols: Vec<Vec<f64>>,
    dcols: Vec<Vec<Vec<f64>>>,
    kuu: KronOp,
}

impl KronKernelOp {
    pub fn new(grid: Grid, kernel: SeparableKernel, sigma: f64) -> Self {
        let mut op = KronKernelOp {
            grid,
            kernel,
            log_sigma: sigma.ln(),
            cols: Vec::new(),
            dcols: Vec::new(),
            kuu: KronOp::new(vec![KronFactor::Dense(crate::linalg::dense::Mat::eye(1))], 1.0),
        };
        op.refresh();
        op
    }

    fn refresh(&mut self) {
        self.cols.clear();
        self.dcols.clear();
        for j in 0..self.grid.ndims() {
            let dim = &self.grid.dims[j];
            let f = &self.kernel.factors[j];
            let nh = f.num_hypers();
            let mut col = Vec::with_capacity(dim.m);
            let mut dcol = vec![Vec::with_capacity(dim.m); nh];
            let mut g = vec![0.0; nh];
            for k in 0..dim.m {
                let tau = k as f64 * dim.spacing();
                col.push(f.eval(&[tau], &[0.0]));
                f.grad(&[tau], &[0.0], &mut g);
                for (t, gv) in g.iter().enumerate() {
                    dcol[t].push(*gv);
                }
            }
            self.cols.push(col);
            self.dcols.push(dcol);
        }
        let factors: Vec<KronFactor> = self
            .cols
            .iter()
            .map(|c| KronFactor::Toeplitz(ToeplitzOp::new(c.clone())))
            .collect();
        self.kuu = KronOp::new(factors, self.kernel.sf2());
    }

    pub fn kuu(&self) -> &KronOp {
        &self.kuu
    }

    fn hyper_location(&self, i: usize) -> Option<(usize, usize)> {
        let mut off = 0;
        for (j, f) in self.kernel.factors.iter().enumerate() {
            let k = f.num_hypers();
            if i < off + k {
                return Some((j, i - off));
            }
            off += k;
        }
        None
    }

    /// Derivative Kronecker operator for factor hyper `(jf, local)` —
    /// shared by the single-vector and blocked derivative MVMs.
    fn grad_kron(&self, jf: usize, local: usize) -> KronOp {
        let factors: Vec<KronFactor> = (0..self.grid.ndims())
            .map(|j| {
                let col = if j == jf {
                    self.dcols[j][local].clone()
                } else {
                    self.cols[j].clone()
                };
                KronFactor::Toeplitz(ToeplitzOp::new(col))
            })
            .collect();
        KronOp::new(factors, self.kernel.sf2())
    }
}

impl LinOp for KronKernelOp {
    fn n(&self) -> usize {
        self.grid.size()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.kuu.apply(x, y);
        let s2 = self.noise_var();
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += s2 * xi;
        }
    }
    fn apply_mat(&self, x: &crate::linalg::dense::Mat) -> crate::linalg::dense::Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_kind(), 1, x.cols as u64);
        let mut out = self.kuu.apply_mat(x);
        let s2 = self.noise_var();
        for (o, xi) in out.data.iter_mut().zip(&x.data) {
            *o += s2 * xi;
        }
        out
    }
    fn obs_kind(&self) -> &'static str {
        "kron_kernel"
    }
}

impl KernelOp for KronKernelOp {
    fn num_hypers(&self) -> usize {
        self.kernel.num_hypers() + 1
    }
    fn obs_grad_kind(&self) -> &'static str {
        "kron_kernel_grad"
    }
    fn hypers(&self) -> Vec<f64> {
        let mut h = self.kernel.hypers();
        h.push(self.log_sigma);
        h
    }
    fn set_hypers(&mut self, h: &[f64]) {
        self.kernel.set_hypers(&h[..h.len() - 1]);
        self.log_sigma = h[h.len() - 1];
        self.refresh();
    }
    fn hyper_names(&self) -> Vec<String> {
        let mut names = self.kernel.hyper_names();
        names.push("log_sigma".into());
        names
    }
    fn apply_grad(&self, i: usize, x: &[f64], y: &mut [f64]) {
        let nk = self.kernel.num_hypers();
        if i == nk {
            let s = 2.0 * self.noise_var();
            for (yi, xi) in y.iter_mut().zip(x) {
                *yi = s * xi;
            }
            return;
        }
        match self.hyper_location(i) {
            Some((jf, local)) => {
                self.grad_kron(jf, local).apply(x, y);
            }
            None => {
                self.kuu.apply(x, y);
                for yi in y.iter_mut() {
                    *yi *= 2.0;
                }
            }
        }
    }
    fn apply_grad_mat(&self, i: usize, x: &crate::linalg::dense::Mat) -> crate::linalg::dense::Mat {
        assert_eq!(x.rows, self.n());
        let _obs = obs::apply_site(self.obs_grad_kind(), 1, x.cols as u64);
        let nk = self.kernel.num_hypers();
        if i == nk {
            let s = 2.0 * self.noise_var();
            let mut out = x.clone();
            for v in out.data.iter_mut() {
                *v *= s;
            }
            return out;
        }
        match self.hyper_location(i) {
            Some((jf, local)) => self.grad_kron(jf, local).apply_mat(x),
            None => {
                let mut out = self.kuu.apply_mat(x);
                for v in out.data.iter_mut() {
                    *v *= 2.0;
                }
                out
            }
        }
    }
    fn noise_var(&self) -> f64 {
        (2.0 * self.log_sigma).exp()
    }
    fn diag(&self) -> Option<Vec<f64>> {
        // diag(sf² kron(T_j)) + σ²: the Kronecker diagonal is O(n).
        let s2 = self.noise_var();
        Some(self.kuu.diag().iter().map(|&v| v + s2).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridDim;
    use crate::kernels::Shape;
    use crate::util::rng::Rng;

    fn points_1d(n: usize, lo: f64, hi: f64, rng: &mut Rng) -> Vec<Vec<f64>> {
        (0..n).map(|_| vec![rng.uniform_in(lo, hi)]).collect()
    }

    #[test]
    fn ski_approximates_exact_kernel_mvm() {
        let mut rng = Rng::new(4);
        let pts = points_1d(60, 0.0, 4.0, &mut rng);
        let kern = SeparableKernel::iso(Shape::Rbf, 1, 0.5, 1.0);
        let grid = Grid::new(vec![GridDim { lo: -0.2, hi: 4.2, m: 200 }]);
        let ski = SkiOp::new(&pts, grid, kern.clone(), 0.1, InterpOrder::Cubic, false);
        // Exact dense K + sigma^2 I.
        let x: Vec<f64> = (0..60).map(|_| rng.gaussian()).collect();
        let mut exact = vec![0.0; 60];
        for i in 0..60 {
            let mut s = 0.01 * x[i];
            for j in 0..60 {
                s += kern.eval(&pts[i], &pts[j]) * x[j];
            }
            exact[i] = s;
        }
        let got = ski.apply_vec(&x);
        let scale: f64 = exact.iter().map(|v| v.abs()).fold(0.0, f64::max);
        for i in 0..60 {
            assert!(
                (got[i] - exact[i]).abs() / scale < 2e-3,
                "i={i}: {} vs {}",
                got[i],
                exact[i]
            );
        }
    }

    /// The memoized cross_mvm must be invisible: identical results for
    /// repeated calls on one test set, and a changed test set (even by a
    /// single coordinate) must not reuse the stale `W*`.
    #[test]
    fn cross_mvm_memo_is_invisible() {
        let mut rng = Rng::new(21);
        let pts = points_1d(30, 0.0, 3.0, &mut rng);
        let kern = SeparableKernel::iso(Shape::Rbf, 1, 0.4, 1.0);
        let grid = Grid::new(vec![GridDim { lo: -0.2, hi: 3.2, m: 64 }]);
        let ski = SkiOp::new(&pts, grid.clone(), kern.clone(), 0.1, InterpOrder::Cubic, false);
        let fresh = SkiOp::new(&pts, grid, kern, 0.1, InterpOrder::Cubic, false);
        let alpha: Vec<f64> = (0..30).map(|_| rng.gaussian()).collect();
        let test_a = points_1d(12, 0.1, 2.9, &mut rng);
        let mut test_b = test_a.clone();
        test_b[7][0] += 0.37;
        // Warm the cache on A, query B, then A again — every answer must
        // match a never-cached operator bitwise.
        for tp in [&test_a, &test_b, &test_a, &test_b] {
            let got = ski.cross_mvm(tp, &alpha);
            let want = fresh.cross_mvm(tp, &alpha);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
        }
    }

    /// F64 mode is apply_mat bitwise; mixed mode stays within an n-scaled
    /// f32 storage-rounding bound of the f64 result.
    #[test]
    fn apply_mat_prec_contract() {
        let mut rng = Rng::new(23);
        let pts = points_1d(48, 0.0, 3.0, &mut rng);
        let kern = SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.1);
        let grid = Grid::new(vec![GridDim { lo: -0.2, hi: 3.2, m: 80 }]);
        for diag_corr in [false, true] {
            let ski =
                SkiOp::new(&pts, grid.clone(), kern.clone(), 0.2, InterpOrder::Cubic, diag_corr);
            let x = crate::linalg::dense::Mat::from_fn(48, 5, |_, _| rng.gaussian());
            let exact = ski.apply_mat(&x);
            let pinned = ski.apply_mat_prec(&x, Precision::F64);
            for (a, b) in pinned.data.iter().zip(&exact.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "diag_corr={diag_corr}");
            }
            let mixed = ski.apply_mat_prec(&x, Precision::F32F64);
            let xmax = x.data.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            let ymax = exact.data.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            // Generous forward bound: a handful of f32 roundings, each
            // amplified by at most the operator's row mass (O(m) terms).
            let bound = 64.0 * f64::from(f32::EPSILON) * (ski.m() as f64) * xmax.max(ymax);
            for (a, b) in mixed.data.iter().zip(&exact.data) {
                assert!(
                    (a - b).abs() <= bound,
                    "diag_corr={diag_corr}: {a} vs {b} (bound {bound:e})"
                );
            }
        }
    }

    #[test]
    fn diag_correction_makes_diag_exact() {
        let mut rng = Rng::new(6);
        let pts = points_1d(40, 0.0, 2.0, &mut rng);
        // A sparse grid so SKI's diagonal is visibly off without correction.
        let kern = SeparableKernel::iso(Shape::Matern12, 1, 0.3, 1.2);
        let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 2.1, m: 24 }]);
        let ski_d = SkiOp::new(&pts, grid, kern.clone(), 0.1, InterpOrder::Cubic, true);
        let dense = ski_d.to_dense();
        let want = kern.eval(&pts[0], &pts[0]) + 0.01;
        for i in 0..40 {
            assert!(
                (dense[(i, i)] - want).abs() < 1e-10,
                "corrected diag {} vs {}",
                dense[(i, i)],
                want
            );
        }
    }

    #[test]
    fn grad_matches_finite_difference() {
        let mut rng = Rng::new(11);
        let pts: Vec<Vec<f64>> = (0..25)
            .map(|_| vec![rng.uniform_in(0.0, 1.0), rng.uniform_in(0.0, 1.0)])
            .collect();
        let kern = SeparableKernel::iso(Shape::Rbf, 2, 0.4, 1.1);
        let grid = Grid::new(vec![
            GridDim { lo: -0.1, hi: 1.1, m: 12 },
            GridDim { lo: -0.1, hi: 1.1, m: 10 },
        ]);
        for diag_corr in [false, true] {
            let mut ski =
                SkiOp::new(&pts, grid.clone(), kern.clone(), 0.2, InterpOrder::Cubic, diag_corr);
            let x: Vec<f64> = (0..25).map(|_| rng.gaussian()).collect();
            let h0 = ski.hypers();
            let eps = 1e-6;
            for i in 0..ski.num_hypers() {
                let mut y = vec![0.0; 25];
                ski.apply_grad(i, &x, &mut y);
                let mut hp = h0.clone();
                hp[i] += eps;
                ski.set_hypers(&hp);
                let up = ski.apply_vec(&x);
                hp[i] -= 2.0 * eps;
                ski.set_hypers(&hp);
                let dn = ski.apply_vec(&x);
                ski.set_hypers(&h0);
                for p in 0..25 {
                    let fd = (up[p] - dn[p]) / (2.0 * eps);
                    assert!(
                        (y[p] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                        "diag_corr={diag_corr} hyper {i} entry {p}: {} vs {}",
                        y[p],
                        fd
                    );
                }
            }
        }
    }

    #[test]
    fn kron_kernel_op_matches_direct_eval() {
        let kern = SeparableKernel::iso(Shape::Matern32, 2, 0.5, 0.9);
        let grid = Grid::new(vec![
            GridDim { lo: 0.0, hi: 1.0, m: 4 },
            GridDim { lo: 0.0, hi: 1.0, m: 3 },
        ]);
        let op = KronKernelOp::new(grid.clone(), kern.clone(), 0.05);
        let dense = op.to_dense();
        for a in 0..12 {
            for b in 0..12 {
                let pa = grid.point(a);
                let pb = grid.point(b);
                let mut want = kern.eval(&pa, &pb);
                if a == b {
                    want += 0.05f64.powi(2);
                }
                assert!(
                    (dense[(a, b)] - want).abs() < 1e-10,
                    "({a},{b}): {} vs {}",
                    dense[(a, b)],
                    want
                );
            }
        }
    }

    #[test]
    fn kron_kernel_diag_matches_dense() {
        let kern = SeparableKernel::iso(Shape::Rbf, 2, 0.4, 1.3);
        let grid = Grid::new(vec![
            GridDim { lo: 0.0, hi: 1.0, m: 5 },
            GridDim { lo: 0.0, hi: 1.0, m: 3 },
        ]);
        let op = KronKernelOp::new(grid, kern, 0.2);
        let got = op.diag().expect("KronKernelOp exposes its diagonal");
        let want = op.to_dense().diag();
        for i in 0..15 {
            assert!((got[i] - want[i]).abs() < 1e-10, "i={i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn kron_kernel_grad_fd() {
        let kern = SeparableKernel::iso(Shape::Rbf, 2, 0.4, 1.0);
        let grid = Grid::new(vec![
            GridDim { lo: 0.0, hi: 1.0, m: 4 },
            GridDim { lo: 0.0, hi: 1.0, m: 4 },
        ]);
        let mut op = KronKernelOp::new(grid, kern, 0.1);
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..16).map(|_| rng.gaussian()).collect();
        let h0 = op.hypers();
        let eps = 1e-6;
        for i in 0..op.num_hypers() {
            let mut y = vec![0.0; 16];
            op.apply_grad(i, &x, &mut y);
            let mut hp = h0.clone();
            hp[i] += eps;
            op.set_hypers(&hp);
            let up = op.apply_vec(&x);
            hp[i] -= 2.0 * eps;
            op.set_hypers(&hp);
            let dn = op.apply_vec(&x);
            op.set_hypers(&h0);
            for p in 0..16 {
                let fd = (up[p] - dn[p]) / (2.0 * eps);
                assert!((y[p] - fd).abs() < 1e-5 * (1.0 + fd.abs()));
            }
        }
    }
}
