//! L-BFGS (two-loop recursion) with Armijo backtracking line search.
//!
//! The marginal-likelihood objectives here are *stochastic* (trace
//! estimators with fixed probe seeds per optimization, so the surface is
//! deterministic but noisy) — the line search therefore accepts on simple
//! sufficient decrease rather than strong Wolfe.

use super::OptResult;

/// L-BFGS options.
#[derive(Clone, Copy, Debug)]
pub struct LbfgsOptions {
    pub max_iters: usize,
    /// History size.
    pub m: usize,
    /// Gradient-norm convergence tolerance.
    pub g_tol: f64,
    /// Armijo sufficient-decrease constant.
    pub c1: f64,
    /// Max backtracking steps per iteration.
    pub max_ls: usize,
    /// Initial step scale on the first iteration.
    pub init_step: f64,
}

impl Default for LbfgsOptions {
    fn default() -> Self {
        LbfgsOptions { max_iters: 100, m: 8, g_tol: 1e-5, c1: 1e-4, max_ls: 20, init_step: 1.0 }
    }
}

/// Minimize `f` (returning value and gradient) from `x0`.
pub fn lbfgs<F>(mut f: F, x0: &[f64], opts: &LbfgsOptions) -> OptResult
where
    F: FnMut(&[f64]) -> (f64, Vec<f64>),
{
    let n = x0.len();
    let mut x = x0.to_vec();
    let (mut fx, mut g) = f(&x);
    let mut evals = 1;
    let mut s_hist: Vec<Vec<f64>> = Vec::new();
    let mut y_hist: Vec<Vec<f64>> = Vec::new();
    let mut rho_hist: Vec<f64> = Vec::new();

    let gnorm = |g: &[f64]| g.iter().map(|v| v * v).sum::<f64>().sqrt();
    let mut converged = gnorm(&g) <= opts.g_tol;
    let mut iters = 0;

    while !converged && iters < opts.max_iters {
        iters += 1;
        // Two-loop recursion for the search direction d = -H g.
        let mut q = g.clone();
        let k = s_hist.len();
        let mut alpha = vec![0.0; k];
        for i in (0..k).rev() {
            let a = rho_hist[i]
                * s_hist[i].iter().zip(&q).map(|(s, q)| s * q).sum::<f64>();
            alpha[i] = a;
            for t in 0..n {
                q[t] -= a * y_hist[i][t];
            }
        }
        // Initial Hessian scaling gamma = s.y / y.y.
        if k > 0 {
            let sy: f64 = s_hist[k - 1].iter().zip(&y_hist[k - 1]).map(|(s, y)| s * y).sum();
            let yy: f64 = y_hist[k - 1].iter().map(|y| y * y).sum();
            if yy > 0.0 {
                let gamma = sy / yy;
                for t in 0..n {
                    q[t] *= gamma;
                }
            }
        }
        for i in 0..k {
            let b = rho_hist[i]
                * y_hist[i].iter().zip(&q).map(|(y, q)| y * q).sum::<f64>();
            for t in 0..n {
                q[t] += (alpha[i] - b) * s_hist[i][t];
            }
        }
        let d: Vec<f64> = q.iter().map(|v| -v).collect();
        let dg: f64 = d.iter().zip(&g).map(|(d, g)| d * g).sum();
        let (d, dg) = if dg >= 0.0 {
            // Not a descent direction (stochastic objective): steepest descent.
            let d: Vec<f64> = g.iter().map(|v| -v).collect();
            let dg = -g.iter().map(|v| v * v).sum::<f64>();
            (d, dg)
        } else {
            (d, dg)
        };

        // Backtracking Armijo.
        let mut step = if iters == 1 {
            opts.init_step / gnorm(&g).max(1.0)
        } else {
            1.0
        };
        let mut accepted = false;
        for _ in 0..opts.max_ls {
            let x_new: Vec<f64> = x.iter().zip(&d).map(|(x, d)| x + step * d).collect();
            let (f_new, g_new) = f(&x_new);
            evals += 1;
            if f_new <= fx + opts.c1 * step * dg {
                // Update history.
                let s: Vec<f64> = x_new.iter().zip(&x).map(|(a, b)| a - b).collect();
                let y: Vec<f64> = g_new.iter().zip(&g).map(|(a, b)| a - b).collect();
                let sy: f64 = s.iter().zip(&y).map(|(s, y)| s * y).sum();
                if sy > 1e-12 {
                    if s_hist.len() == opts.m {
                        s_hist.remove(0);
                        y_hist.remove(0);
                        rho_hist.remove(0);
                    }
                    rho_hist.push(1.0 / sy);
                    s_hist.push(s);
                    y_hist.push(y);
                }
                x = x_new;
                fx = f_new;
                g = g_new;
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        if !accepted {
            break; // line search failed: stop at current point
        }
        converged = gnorm(&g) <= opts.g_tol;
    }
    OptResult { x, fx, evals, iters, converged }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        let f = |x: &[f64]| {
            let v = (x[0] - 1.0).powi(2) + 10.0 * (x[1] + 2.0).powi(2);
            let g = vec![2.0 * (x[0] - 1.0), 20.0 * (x[1] + 2.0)];
            (v, g)
        };
        let res = lbfgs(f, &[0.0, 0.0], &LbfgsOptions::default());
        assert!(res.converged);
        assert!((res.x[0] - 1.0).abs() < 1e-4);
        assert!((res.x[1] + 2.0).abs() < 1e-4);
    }

    #[test]
    fn minimizes_rosenbrock() {
        let f = |x: &[f64]| {
            let (a, b) = (x[0], x[1]);
            let v = (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2);
            let g = vec![
                -2.0 * (1.0 - a) - 400.0 * a * (b - a * a),
                200.0 * (b - a * a),
            ];
            (v, g)
        };
        let res = lbfgs(
            f,
            &[-1.2, 1.0],
            &LbfgsOptions { max_iters: 500, g_tol: 1e-8, ..Default::default() },
        );
        assert!((res.x[0] - 1.0).abs() < 1e-3, "{:?}", res.x);
        assert!((res.x[1] - 1.0).abs() < 1e-3);
    }

    #[test]
    fn respects_max_iters() {
        let f = |x: &[f64]| (x[0] * x[0], vec![2.0 * x[0]]);
        let res = lbfgs(f, &[100.0], &LbfgsOptions { max_iters: 2, ..Default::default() });
        assert!(res.iters <= 2);
    }
}
