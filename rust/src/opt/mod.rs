//! Optimizers for hyperparameter learning: L-BFGS with Armijo backtracking
//! (gradient-based marginal-likelihood optimization, as in the paper's
//! experiments), Adam (deep kernel learning), and Nelder–Mead (gradient-free
//! fallback for Laplace objectives with few hypers).

pub mod adam;
pub mod lbfgs;
pub mod neldermead;

/// Result of an optimization run.
#[derive(Clone, Debug)]
pub struct OptResult {
    /// Best parameters found.
    pub x: Vec<f64>,
    /// Objective value at `x`.
    pub fx: f64,
    /// Objective/gradient evaluations used.
    pub evals: usize,
    /// Iterations taken.
    pub iters: usize,
    /// Whether the convergence tolerance was met.
    pub converged: bool,
}
