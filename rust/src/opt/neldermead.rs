//! Nelder–Mead simplex search — gradient-free optimizer for the Laplace
//! marginal-likelihood objectives (few hypers, stochastic values), used in
//! the Hickory experiment (§5.3).

use super::OptResult;

#[derive(Clone, Copy, Debug)]
pub struct NelderMeadOptions {
    pub max_iters: usize,
    /// Initial simplex scale (per coordinate).
    pub init_step: f64,
    /// Convergence: simplex function-value spread.
    pub f_tol: f64,
}

impl Default for NelderMeadOptions {
    fn default() -> Self {
        NelderMeadOptions { max_iters: 200, init_step: 0.5, f_tol: 1e-6 }
    }
}

/// Minimize `f` from `x0`.
pub fn nelder_mead<F>(mut f: F, x0: &[f64], opts: &NelderMeadOptions) -> OptResult
where
    F: FnMut(&[f64]) -> f64,
{
    let n = x0.len();
    let (alpha, gamma, rho, sigma) = (1.0, 2.0, 0.5, 0.5);
    // Initial simplex.
    let mut simplex: Vec<Vec<f64>> = vec![x0.to_vec()];
    for i in 0..n {
        let mut p = x0.to_vec();
        p[i] += opts.init_step;
        simplex.push(p);
    }
    let mut fvals: Vec<f64> = simplex.iter().map(|p| f(p)).collect();
    let mut evals = n + 1;
    let mut iters = 0;
    let mut converged = false;

    while iters < opts.max_iters {
        iters += 1;
        // Order.
        let mut idx: Vec<usize> = (0..=n).collect();
        idx.sort_by(|&a, &b| fvals[a].partial_cmp(&fvals[b]).unwrap());
        simplex = idx.iter().map(|&i| simplex[i].clone()).collect();
        fvals = idx.iter().map(|&i| fvals[i]).collect();

        // Converged only when BOTH the value spread and the simplex
        // diameter are small (value spread alone false-triggers when
        // vertices straddle the minimum symmetrically).
        let diam = simplex
            .iter()
            .skip(1)
            .map(|p| {
                p.iter()
                    .zip(&simplex[0])
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f64::max)
            })
            .fold(0.0, f64::max);
        if (fvals[n] - fvals[0]).abs() <= opts.f_tol * (1.0 + fvals[0].abs())
            && diam <= (opts.f_tol.sqrt() * 0.1).max(1e-8) * (1.0 + simplex[0][0].abs())
        {
            converged = true;
            break;
        }

        // Centroid of all but worst.
        let mut centroid = vec![0.0; n];
        for p in &simplex[..n] {
            for i in 0..n {
                centroid[i] += p[i] / n as f64;
            }
        }
        // Reflect.
        let xr: Vec<f64> = (0..n)
            .map(|i| centroid[i] + alpha * (centroid[i] - simplex[n][i]))
            .collect();
        let fr = f(&xr);
        evals += 1;
        if fr < fvals[0] {
            // Expand.
            let xe: Vec<f64> = (0..n)
                .map(|i| centroid[i] + gamma * (xr[i] - centroid[i]))
                .collect();
            let fe = f(&xe);
            evals += 1;
            if fe < fr {
                simplex[n] = xe;
                fvals[n] = fe;
            } else {
                simplex[n] = xr;
                fvals[n] = fr;
            }
        } else if fr < fvals[n - 1] {
            simplex[n] = xr;
            fvals[n] = fr;
        } else {
            // Contract.
            let xc: Vec<f64> = (0..n)
                .map(|i| centroid[i] + rho * (simplex[n][i] - centroid[i]))
                .collect();
            let fc = f(&xc);
            evals += 1;
            if fc < fvals[n] {
                simplex[n] = xc;
                fvals[n] = fc;
            } else {
                // Shrink toward best.
                for k in 1..=n {
                    for i in 0..n {
                        simplex[k][i] =
                            simplex[0][i] + sigma * (simplex[k][i] - simplex[0][i]);
                    }
                    fvals[k] = f(&simplex[k]);
                    evals += 1;
                }
            }
        }
    }
    let mut best = 0;
    for i in 1..=n {
        if fvals[i] < fvals[best] {
            best = i;
        }
    }
    OptResult { x: simplex[best].clone(), fx: fvals[best], evals, iters, converged }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic_bowl() {
        let f = |x: &[f64]| (x[0] - 2.0).powi(2) + (x[1] + 1.0).powi(2) + 3.0;
        let res = nelder_mead(f, &[0.0, 0.0], &NelderMeadOptions { max_iters: 500, ..Default::default() });
        assert!((res.x[0] - 2.0).abs() < 1e-3, "{:?}", res.x);
        assert!((res.x[1] + 1.0).abs() < 1e-3);
        assert!((res.fx - 3.0).abs() < 1e-5);
    }

    #[test]
    fn handles_1d() {
        let f = |x: &[f64]| (x[0] - 0.5).powi(2);
        let res = nelder_mead(f, &[5.0], &NelderMeadOptions::default());
        assert!((res.x[0] - 0.5).abs() < 1e-3);
    }

    #[test]
    fn robust_to_mild_noise() {
        // Deterministic pseudo-noise on top of a quadratic.
        let f = |x: &[f64]| {
            let noise = ((x[0] * 1000.0).sin() * 1e-4).abs();
            (x[0] - 1.0).powi(2) + noise
        };
        let res = nelder_mead(f, &[-3.0], &NelderMeadOptions { max_iters: 300, ..Default::default() });
        assert!((res.x[0] - 1.0).abs() < 0.05);
    }
}
