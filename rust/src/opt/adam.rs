//! Adam optimizer — used for deep kernel learning (paper §5.5), where the
//! parameter vector includes hundreds of thousands of network weights and
//! the marginal-likelihood gradient is stochastic.

use super::OptResult;

#[derive(Clone, Copy, Debug)]
pub struct AdamOptions {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub max_iters: usize,
    /// Stop when the objective improves less than this over a window.
    pub f_tol: f64,
}

impl Default for AdamOptions {
    fn default() -> Self {
        AdamOptions { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8, max_iters: 200, f_tol: 1e-8 }
    }
}

/// Minimize `f` (value and gradient) from `x0` with Adam.
pub fn adam<F>(mut f: F, x0: &[f64], opts: &AdamOptions) -> OptResult
where
    F: FnMut(&[f64]) -> (f64, Vec<f64>),
{
    let n = x0.len();
    let mut x = x0.to_vec();
    let mut m = vec![0.0; n];
    let mut v = vec![0.0; n];
    let mut best_x = x.clone();
    let mut best_f = f64::INFINITY;
    let mut evals = 0;
    let mut last_f = f64::INFINITY;
    let mut iters = 0;
    let mut converged = false;
    for t in 1..=opts.max_iters {
        iters = t;
        let (fx, g) = f(&x);
        evals += 1;
        if fx < best_f {
            best_f = fx;
            best_x = x.clone();
        }
        if (last_f - fx).abs() < opts.f_tol * (1.0 + fx.abs()) && t > 5 {
            converged = true;
            break;
        }
        last_f = fx;
        let b1t = 1.0 - opts.beta1.powi(t as i32);
        let b2t = 1.0 - opts.beta2.powi(t as i32);
        for i in 0..n {
            m[i] = opts.beta1 * m[i] + (1.0 - opts.beta1) * g[i];
            v[i] = opts.beta2 * v[i] + (1.0 - opts.beta2) * g[i] * g[i];
            let mhat = m[i] / b1t;
            let vhat = v[i] / b2t;
            x[i] -= opts.lr * mhat / (vhat.sqrt() + opts.eps);
        }
    }
    OptResult { x: best_x, fx: best_f, evals, iters, converged }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        let f = |x: &[f64]| {
            let v: f64 = x.iter().map(|v| v * v).sum();
            (v, x.iter().map(|v| 2.0 * v).collect())
        };
        let res = adam(
            f,
            &[3.0, -2.0, 1.0],
            &AdamOptions { lr: 0.1, max_iters: 500, ..Default::default() },
        );
        assert!(res.fx < 1e-3, "fx {}", res.fx);
    }

    #[test]
    fn tracks_best_iterate() {
        // Objective that worsens after some steps should keep the best.
        let mut count = 0;
        let f = move |x: &[f64]| {
            count += 1;
            let v = if count > 50 { 100.0 } else { x[0] * x[0] };
            (v, vec![2.0 * x[0]])
        };
        let res = adam(f, &[1.0], &AdamOptions { lr: 0.05, max_iters: 100, f_tol: 0.0, ..Default::default() });
        assert!(res.fx < 1.0);
    }
}
