//! Symmetric tridiagonal eigensolver (implicit-shift QL, "tqli").
//!
//! This finishes stochastic Lanczos quadrature: the m x m tridiagonal T from
//! a Lanczos run is eigendecomposed, the Gauss-quadrature nodes are its
//! eigenvalues and the weights are the squared first components of its
//! eigenvectors (paper §3.2 / Golub & Meurant).

use crate::error::{Error, Result};

/// Eigen-decomposition of a symmetric tridiagonal matrix.
pub struct TridiagEig {
    /// Eigenvalues, ascending.
    pub eigvals: Vec<f64>,
    /// First components of the corresponding (orthonormal) eigenvectors.
    pub first_components: Vec<f64>,
}

#[inline]
fn hypot2(a: f64, b: f64) -> f64 {
    a.hypot(b)
}

/// Implicit-shift QL on (diag, offdiag), accumulating only the first row of
/// the eigenvector matrix (all the quadrature needs). `offdiag.len()` must be
/// `diag.len() - 1`.
pub fn tridiag_eig_first_row(diag: &[f64], offdiag: &[f64]) -> Result<TridiagEig> {
    let n = diag.len();
    assert!(n > 0);
    assert_eq!(offdiag.len(), n.saturating_sub(1));
    let mut d = diag.to_vec();
    // e is padded to n with a trailing 0 (classic tqli layout).
    let mut e = Vec::with_capacity(n);
    e.extend_from_slice(offdiag);
    e.push(0.0);
    // z holds the first row of the accumulated rotation product (starts e1^T).
    let mut z = vec![0.0; n];
    z[0] = 1.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small off-diagonal to split.
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(Error::EigFailed { index: l });
            }
            // Form shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = hypot2(g, 1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = hypot2(f, g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate first row of eigenvector product.
                f = z[i + 1];
                z[i + 1] = s * z[i] + c * f;
                z[i] = c * z[i] - s * f;
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // Sort ascending by eigenvalue, carrying first components. Total
    // order: identical to partial_cmp on the finite values QL converges
    // to, but never panics if a NaN slips through (NaN-poisoned input
    // normally exhausts the QL iteration budget and errors above).
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| d[a].total_cmp(&d[b]));
    let eigvals: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let first_components: Vec<f64> = idx.iter().map(|&i| z[i]).collect();
    Ok(TridiagEig { eigvals, first_components })
}

/// Gauss quadrature of `f` against the Lanczos tridiagonal: returns
/// `||z||^2 * sum_k tau_k f(lambda_k)` where `tau_k` are the squared first
/// eigenvector components — i.e. the estimate of `z^T f(A) z` (Eq. 3).
pub fn lanczos_quadrature(
    diag: &[f64],
    offdiag: &[f64],
    znorm2: f64,
    f: impl Fn(f64) -> f64,
) -> Result<f64> {
    let eig = tridiag_eig_first_row(diag, offdiag)?;
    let mut s = 0.0;
    for (lam, w) in eig.eigvals.iter().zip(&eig.first_components) {
        s += w * w * f(*lam);
    }
    Ok(znorm2 * s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::linalg::eigh::eigh;

    #[test]
    fn diagonal_matrix_eigs() {
        let eig = tridiag_eig_first_row(&[3.0, 1.0, 2.0], &[0.0, 0.0]).unwrap();
        assert!((eig.eigvals[0] - 1.0).abs() < 1e-12);
        assert!((eig.eigvals[1] - 2.0).abs() < 1e-12);
        assert!((eig.eigvals[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn two_by_two() {
        // [[2, 1], [1, 2]] -> eigvals 1, 3; eigvecs (1,-1)/sqrt2, (1,1)/sqrt2.
        let eig = tridiag_eig_first_row(&[2.0, 2.0], &[1.0]).unwrap();
        assert!((eig.eigvals[0] - 1.0).abs() < 1e-12);
        assert!((eig.eigvals[1] - 3.0).abs() < 1e-12);
        for w in &eig.first_components {
            assert!((w.abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-12);
        }
    }

    #[test]
    fn weights_sum_to_one() {
        let d = [4.0, 3.0, 5.0, 2.0, 6.0];
        let e = [1.0, 0.5, 0.7, 0.3];
        let eig = tridiag_eig_first_row(&d, &e).unwrap();
        let s: f64 = eig.first_components.iter().map(|w| w * w).sum();
        assert!((s - 1.0).abs() < 1e-10);
    }

    #[test]
    fn matches_dense_eigh() {
        let d = [4.0, 3.0, 5.0, 2.0];
        let e = [1.2, 0.4, 0.9];
        let eig = tridiag_eig_first_row(&d, &e).unwrap();
        let mut a = Mat::zeros(4, 4);
        for i in 0..4 {
            a[(i, i)] = d[i];
        }
        for i in 0..3 {
            a[(i, i + 1)] = e[i];
            a[(i + 1, i)] = e[i];
        }
        let dense = eigh(&a).unwrap();
        for i in 0..4 {
            assert!((eig.eigvals[i] - dense.eigvals[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn quadrature_exact_for_identity_function() {
        // f(x) = x: z^T A z. Take a known tridiagonal and z = e1 * ||z||.
        let d = [2.0, 3.0];
        let e = [0.5];
        // z = e1, so z^T A z = 2.
        let q = lanczos_quadrature(&d, &e, 1.0, |x| x).unwrap();
        assert!((q - 2.0).abs() < 1e-12);
    }
}
