//! Rank-k pivoted (partial) Cholesky `K ≈ L Lᵀ` over a [`KernelOp`] —
//! the factor behind the solvers' pivoted-Cholesky preconditioner
//! (`solvers::precond`). Both Chebyshev and Lanczos iteration counts
//! degrade with the condition number of `K̃ = K + σ²I` (Han et al. 2015
//! make the κ-dependence explicit), and kernel learning drives σ small;
//! a rank-k capture of K's dominant spectrum flattens exactly the part of
//! the spectrum the iterations pay for.
//!
//! The factorization never materializes K: it is driven by
//! [`KernelOp::diag`] (minus the noise, which the preconditioner re-adds
//! in closed form) plus one on-demand column MVM `K e_p = K̃ e_p − σ² e_p`
//! per selected pivot — k MVMs total for rank k. Greedy pivot selection
//! takes the largest remaining Schur-complement diagonal entry (the
//! classic trace-greedy rule); the trace of the remaining diagonal is an
//! exact upper bound on `tr(K − L Lᵀ) ≥ 0`, giving the stopping rule.

use super::dense::Mat;
use crate::operators::KernelOp;
use crate::util::stats::axpy;

/// Result of a rank-k pivoted Cholesky run. Retains its Schur-complement
/// frontier, so [`grow`](Self::grow) can append further pivots later
/// without re-running (or re-paying the MVMs of) the ones already taken —
/// the trajectory is bitwise the same factorization a from-scratch run at
/// the larger rank would produce, because greedy pivot selection only
/// reads the current Schur diagonal.
pub struct PivotedCholesky {
    /// The `n x k` factor: `K ≈ L Lᵀ` (noise-free part of the operator).
    pub l: Mat,
    /// Pivot order (data indices, most dominant first), length k.
    pub pivots: Vec<usize>,
    /// Trace of K̃'s noise-free diagonal before any pivots were taken.
    pub initial_trace: f64,
    /// Remaining `tr(K − L Lᵀ)` when the run stopped (the a-posteriori
    /// approximation-error bound in the trace norm).
    pub trace_error: f64,
    /// Operator MVMs consumed (one per pivot, cumulative across grows).
    pub mvms: usize,
    /// Factor columns in pivot order (the rows of `l`, kept separately so
    /// `grow` appends without reshaping the public matrix mid-run).
    cols: Vec<Vec<f64>>,
    /// Remaining Schur-complement diagonal — the growth frontier. The
    /// sequential per-pivot downdate-and-clamp is order-sensitive, so this
    /// is retained verbatim rather than reconstructed from `l`.
    schur_diag: Vec<f64>,
    /// Below this pivot size the Schur complement is numerically exhausted
    /// and further columns would amplify rounding noise.
    pivot_floor: f64,
}

impl PivotedCholesky {
    /// Rank-0 state: the Schur diagonal is the (noise-free) kernel
    /// diagonal and no pivots are taken. `None` when the operator cannot
    /// supply its diagonal.
    fn empty(op: &dyn KernelOp) -> Option<Self> {
        let s2 = op.noise_var();
        let d: Vec<f64> = op.diag()?.iter().map(|&v| (v - s2).max(0.0)).collect();
        let initial_trace: f64 = d.iter().sum();
        let pivot_floor = f64::EPSILON * d.iter().fold(0.0f64, |a, &b| a.max(b));
        Some(PivotedCholesky {
            l: Mat::zeros(op.n(), 0),
            pivots: Vec::new(),
            initial_trace,
            trace_error: initial_trace,
            mvms: 0,
            cols: Vec::new(),
            schur_diag: d,
            pivot_floor,
        })
    }

    /// Current rank (number of pivot columns taken).
    pub fn rank(&self) -> usize {
        self.cols.len()
    }

    /// Append greedy pivots until the **total** rank reaches `max_rank`,
    /// the remaining trace drops below `rel_tol * initial_trace`, or the
    /// Schur complement is numerically exhausted. One MVM per appended
    /// pivot; a call at or below the current rank (or after exhaustion)
    /// spends nothing. Growing `r1 → r2` is bitwise identical to a fresh
    /// factorization at rank `r2` with the same stopping tolerance.
    pub fn grow(&mut self, op: &dyn KernelOp, max_rank: usize, rel_tol: f64) {
        let _span = crate::span!("pchol_grow");
        let rank_before = self.cols.len();
        let n = op.n();
        let s2 = op.noise_var();
        let mut e = vec![0.0; n];
        let floor = rel_tol.max(0.0) * self.initial_trace;
        while self.cols.len() < max_rank.min(n) {
            if self.trace_error <= floor {
                break;
            }
            // Greedy pivot: largest remaining Schur diagonal.
            let (p, &dp) = self
                .schur_diag
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .expect("n > 0");
            if dp <= self.pivot_floor || !dp.is_finite() {
                break;
            }
            // Column K e_p via one MVM on K̃ (only entry p carries the noise).
            e[p] = 1.0;
            let mut c = op.apply_vec(&e);
            e[p] = 0.0;
            c[p] -= s2;
            // Schur update against the columns already taken.
            for lj in &self.cols {
                axpy(-lj[p], lj, &mut c);
            }
            let scale = 1.0 / dp.sqrt();
            for v in c.iter_mut() {
                *v *= scale;
            }
            // Diagonal downdate; clamp tiny negatives from cancellation.
            for (di, ci) in self.schur_diag.iter_mut().zip(&c) {
                *di = (*di - ci * ci).max(0.0);
            }
            self.schur_diag[p] = 0.0;
            self.trace_error = self.schur_diag.iter().sum();
            self.cols.push(c);
            self.pivots.push(p);
            self.mvms += 1;
        }
        let k = self.cols.len();
        crate::util::obs::add(
            crate::util::obs::Counter::PcholCols,
            (k - rank_before) as u64,
        );
        let mut l = Mat::zeros(n, k);
        for (j, c) in self.cols.iter().enumerate() {
            l.set_col(j, c);
        }
        self.l = l;
    }
}

/// Greedy pivoted Cholesky of the noise-free kernel part of `op`, stopping
/// at `max_rank` columns or when the remaining trace drops below
/// `rel_tol * initial_trace`. Returns `None` when the operator cannot
/// supply its diagonal ([`KernelOp::diag`] is `None`) — the caller should
/// fall back to unpreconditioned solves. Implemented as a rank-0 state
/// plus one [`PivotedCholesky::grow`]; callers that may need a larger
/// rank later should keep the returned value and `grow` it instead of
/// refactorizing.
pub fn pivoted_cholesky(
    op: &dyn KernelOp,
    max_rank: usize,
    rel_tol: f64,
) -> Option<PivotedCholesky> {
    let mut pc = PivotedCholesky::empty(op)?;
    pc.grow(op, max_rank, rel_tol);
    Some(pc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{IsoKernel, Shape};
    use crate::operators::DenseKernelOp;
    use crate::util::rng::Rng;

    fn rbf_op(n: usize, sigma: f64, seed: u64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            sigma,
        )
    }

    /// `tr(K − L Lᵀ)` computed densely must match the reported bound.
    #[test]
    fn trace_error_is_exact_remaining_trace() {
        let op = rbf_op(40, 0.3, 1);
        let pc = pivoted_cholesky(&op, 10, 0.0).unwrap();
        let k = op.kernel_matrix();
        let llt = pc.l.matmul(&pc.l.transpose());
        let tr: f64 = (0..40).map(|i| k[(i, i)] - llt[(i, i)]).sum();
        assert!(
            (tr - pc.trace_error).abs() < 1e-8 * (1.0 + tr.abs()),
            "{tr} vs {}",
            pc.trace_error
        );
        assert!(pc.trace_error >= 0.0);
        assert_eq!(pc.mvms, pc.l.cols);
    }

    /// The trace error is monotone non-increasing in the rank, and the
    /// factorization reconstructs K at full rank.
    #[test]
    fn error_decreases_with_rank_and_full_rank_is_exact() {
        let op = rbf_op(24, 0.2, 2);
        let mut prev = f64::INFINITY;
        for rank in [1usize, 2, 4, 8, 24] {
            let pc = pivoted_cholesky(&op, rank, 0.0).unwrap();
            assert!(pc.trace_error <= prev + 1e-12, "rank {rank}");
            prev = pc.trace_error;
        }
        let pc = pivoted_cholesky(&op, 24, 0.0).unwrap();
        let k = op.kernel_matrix();
        let llt = pc.l.matmul(&pc.l.transpose());
        assert!(
            k.max_abs_diff(&llt) < 1e-7,
            "full-rank reconstruction error {}",
            k.max_abs_diff(&llt)
        );
    }

    /// The trace stopping rule halts the run early on a fast-decaying
    /// spectrum (RBF): far fewer than n columns at a loose tolerance.
    #[test]
    fn trace_tolerance_stops_early() {
        let op = rbf_op(60, 0.1, 3);
        let pc = pivoted_cholesky(&op, 60, 1e-2).unwrap();
        assert!(pc.l.cols < 30, "took {} columns", pc.l.cols);
        assert!(pc.trace_error <= 1e-2 * pc.initial_trace + 1e-12);
    }

    /// Growing a retained factor `r1 → r2` is bitwise identical to a
    /// from-scratch factorization at rank `r2`: same pivots, same factor
    /// entries, same trace bound — and the appended run pays only the
    /// incremental MVMs while its cumulative count matches.
    #[test]
    fn grow_matches_from_scratch_bitwise() {
        let op = rbf_op(50, 0.2, 6);
        let mut grown = pivoted_cholesky(&op, 4, 0.0).unwrap();
        assert_eq!(grown.rank(), 4);
        assert_eq!(grown.mvms, 4);
        grown.grow(&op, 9, 0.0);
        grown.grow(&op, 16, 0.0);
        let scratch = pivoted_cholesky(&op, 16, 0.0).unwrap();
        assert_eq!(grown.rank(), scratch.rank());
        assert_eq!(grown.pivots, scratch.pivots);
        assert_eq!(grown.mvms, scratch.mvms);
        assert_eq!(grown.trace_error.to_bits(), scratch.trace_error.to_bits());
        assert_eq!((grown.l.rows, grown.l.cols), (scratch.l.rows, scratch.l.cols));
        for (a, b) in grown.l.data.iter().zip(&scratch.l.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Growing to the current rank or below appends nothing.
        let before = grown.mvms;
        grown.grow(&op, 16, 0.0);
        grown.grow(&op, 3, 0.0);
        assert_eq!(grown.mvms, before);
        assert_eq!(grown.rank(), 16);
    }

    /// Pivots are distinct and greedy: the first pivot has the largest
    /// kernel diagonal (all equal for stationary kernels — index 0 wins).
    #[test]
    fn pivots_are_distinct() {
        let op = rbf_op(30, 0.2, 4);
        let pc = pivoted_cholesky(&op, 12, 0.0).unwrap();
        let mut seen = std::collections::HashSet::new();
        for &p in &pc.pivots {
            assert!(seen.insert(p), "pivot {p} repeated");
        }
    }
}
