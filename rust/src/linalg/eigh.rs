//! Dense symmetric eigendecomposition: Householder tridiagonalization
//! ("tred2") followed by implicit-shift QL with full eigenvector
//! accumulation ("tqli"). Needed by the scaled-eigenvalue baseline (dense
//! eigendecomposition of each Kronecker factor of K_UU) and by the spectrum
//! figure (Fig. 5).

use super::dense::Mat;
use crate::error::{Error, Result};

/// Full symmetric eigendecomposition A = V diag(w) V^T.
pub struct Eigh {
    /// Eigenvalues ascending.
    pub eigvals: Vec<f64>,
    /// Columns are eigenvectors (same order as eigvals).
    pub eigvecs: Mat,
}

/// Eigendecomposition of a symmetric matrix (upper/lower are assumed equal).
pub fn eigh(a: &Mat) -> Result<Eigh> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut v = a.clone();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];

    // --- Householder reduction to tridiagonal (tred2, with vectors). ---
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += v[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = v[(i, l)];
            } else {
                for k in 0..=l {
                    v[(i, k)] /= scale;
                    h += v[(i, k)] * v[(i, k)];
                }
                let mut f = v[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                v[(i, l)] = f - g;
                let mut sum = 0.0;
                for j in 0..=l {
                    v[(j, i)] = v[(i, j)] / h;
                    let mut g2 = 0.0;
                    for k in 0..=j {
                        g2 += v[(j, k)] * v[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g2 += v[(k, j)] * v[(i, k)];
                    }
                    e[j] = g2 / h;
                    sum += e[j] * v[(i, j)];
                }
                let hh = sum / (2.0 * h);
                for j in 0..=l {
                    f = v[(i, j)];
                    let g2 = e[j] - hh * f;
                    e[j] = g2;
                    for k in 0..=j {
                        let upd = f * e[k] + g2 * v[(i, k)];
                        v[(j, k)] -= upd;
                    }
                }
            }
        } else {
            e[i] = v[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += v[(i, k)] * v[(k, j)];
                }
                for k in 0..i {
                    let upd = g * v[(k, i)];
                    v[(k, j)] -= upd;
                }
            }
        }
        d[i] = v[(i, i)];
        v[(i, i)] = 1.0;
        for j in 0..i {
            v[(j, i)] = 0.0;
            v[(i, j)] = 0.0;
        }
    }

    // --- Implicit-shift QL with eigenvector accumulation (tqli). ---
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(Error::EigFailed { index: l });
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                for k in 0..n {
                    f = v[(k, i + 1)];
                    v[(k, i + 1)] = s * v[(k, i)] + c * f;
                    v[(k, i)] = c * v[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // Sort ascending, permuting vector columns.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    let eigvals: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let mut eigvecs = Mat::zeros(n, n);
    for (newj, &oldj) in idx.iter().enumerate() {
        for i in 0..n {
            eigvecs[(i, newj)] = v[(i, oldj)];
        }
    }
    Ok(Eigh { eigvals, eigvecs })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sym(n: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut a = Mat::from_fn(n, n, f);
        a.symmetrize();
        a
    }

    #[test]
    fn two_by_two_known() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = eigh(&a).unwrap();
        assert!((e.eigvals[0] - 1.0).abs() < 1e-12);
        assert!((e.eigvals[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction() {
        let a = sym(10, |i, j| ((i * 7 + j * 3) % 11) as f64 / 11.0 + if i == j { 2.0 } else { 0.0 });
        let e = eigh(&a).unwrap();
        // A V = V diag(w)
        for j in 0..10 {
            let vj = e.eigvecs.col(j);
            let av = a.matvec(&vj);
            for i in 0..10 {
                assert!(
                    (av[i] - e.eigvals[j] * vj[i]).abs() < 1e-9,
                    "col {j} row {i}"
                );
            }
        }
    }

    #[test]
    fn orthonormal_vectors() {
        let a = sym(8, |i, j| (i as f64 - j as f64).cos());
        let e = eigh(&a).unwrap();
        let vtv = e.eigvecs.transpose().matmul(&e.eigvecs);
        assert!(vtv.max_abs_diff(&Mat::eye(8)) < 1e-9);
    }

    #[test]
    fn trace_and_logdet_consistency() {
        let a = sym(9, |i, j| if i == j { 3.0 + i as f64 } else { 0.3 / (1.0 + (i as f64 - j as f64).abs()) });
        let e = eigh(&a).unwrap();
        let tr: f64 = a.diag().iter().sum();
        let tr_eig: f64 = e.eigvals.iter().sum();
        assert!((tr - tr_eig).abs() < 1e-9);
        let ld: f64 = e.eigvals.iter().map(|v| v.ln()).sum();
        let chol = crate::linalg::chol::Cholesky::new(&a).unwrap();
        assert!((ld - chol.logdet()).abs() < 1e-8);
    }
}
