//! Dense Cholesky factorization — the O(n^3) exact baseline the paper is
//! replacing, and the small-m workhorse inside FITC/SoR (Woodbury) and the
//! surrogate.

use super::dense::Mat;
use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct Cholesky {
    /// n x n, lower triangle holds L, strict upper is garbage.
    pub l: Mat,
}

impl Cholesky {
    /// Factor `a` (symmetric positive definite). Fails with
    /// [`Error::NotPositiveDefinite`] otherwise.
    pub fn new(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = a.clone();
        for j in 0..n {
            // Diagonal.
            let mut d = l[(j, j)];
            for k in 0..j {
                let v = l[(j, k)];
                d -= v * v;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(Error::NotPositiveDefinite { pivot: j, value: d });
            }
            let dsqrt = d.sqrt();
            l[(j, j)] = dsqrt;
            // Column below.
            for i in (j + 1)..n {
                let mut s = l[(i, j)];
                let (ri, rj) = (i * n, j * n);
                for k in 0..j {
                    s -= l.data[ri + k] * l.data[rj + k];
                }
                l[(i, j)] = s / dsqrt;
            }
        }
        // Zero strict upper for cleanliness.
        for i in 0..n {
            for j in (i + 1)..n {
                l[(i, j)] = 0.0;
            }
        }
        Ok(Cholesky { l })
    }

    /// Factor with additive jitter escalation: tries `a + jitter*I` with
    /// jitter in {0, j0, 10 j0, ...} until SPD (standard GP practice).
    pub fn new_jittered(a: &Mat, j0: f64, tries: usize) -> Result<Self> {
        let mut jitter = 0.0;
        for t in 0..=tries {
            let mut aj = a.clone();
            if jitter > 0.0 {
                aj.add_diag(jitter);
            }
            match Cholesky::new(&aj) {
                Ok(c) => return Ok(c),
                Err(_) if t < tries => {
                    jitter = if jitter == 0.0 { j0 } else { jitter * 10.0 };
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!()
    }

    pub fn n(&self) -> usize {
        self.l.rows
    }

    /// log|A| = 2 sum log diag(L).
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    pub fn solve_in_place(&self, x: &mut [f64]) {
        let n = self.n();
        assert_eq!(x.len(), n);
        // Forward: L y = b
        for i in 0..n {
            let mut s = x[i];
            let ri = i * n;
            for k in 0..i {
                s -= self.l.data[ri + k] * x[k];
            }
            x[i] = s / self.l.data[ri + i];
        }
        // Backward: L^T x = y
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.l.data[k * n + i] * x[k];
            }
            x[i] = s / self.l.data[i * n + i];
        }
    }

    /// Solve A X = B for a matrix right-hand side.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let mut out = b.clone();
        for j in 0..b.cols {
            let mut col = b.col(j);
            self.solve_in_place(&mut col);
            out.set_col(j, &col);
        }
        out
    }

    /// A^{-1} (dense) — used by the exact-gradient baseline.
    pub fn inverse(&self) -> Mat {
        self.solve_mat(&Mat::eye(self.n()))
    }

    /// Solve L y = b only (forward substitution).
    pub fn solve_l(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        let mut x = b.to_vec();
        for i in 0..n {
            let mut s = x[i];
            let ri = i * n;
            for k in 0..i {
                s -= self.l.data[ri + k] * x[k];
            }
            x[i] = s / self.l.data[ri + i];
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize) -> Mat {
        // A = B B^T + n I
        let b = Mat::from_fn(n, n, |i, j| ((i * 31 + j * 17) % 13) as f64 / 13.0);
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64);
        a
    }

    #[test]
    fn factor_and_solve() {
        let a = spd(8);
        let c = Cholesky::new(&a).unwrap();
        let x_true: Vec<f64> = (0..8).map(|i| i as f64 - 3.5).collect();
        let b = a.matvec(&x_true);
        let x = c.solve(&b);
        for i in 0..8 {
            assert!((x[i] - x_true[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Mat::from_rows(&[vec![4.0, 1.0], vec![1.0, 3.0]]);
        let c = Cholesky::new(&a).unwrap();
        assert!((c.logdet() - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn jitter_rescues_semidefinite() {
        let mut a = Mat::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        a.symmetrize();
        let c = Cholesky::new_jittered(&a, 1e-8, 12).unwrap();
        assert!(c.logdet().is_finite());
    }

    #[test]
    fn inverse_roundtrip() {
        let a = spd(6);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&Mat::eye(6)) < 1e-9);
    }
}
