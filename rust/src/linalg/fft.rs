//! Iterative radix-2 complex FFT — powers the O(m log m) symmetric-Toeplitz
//! MVM (circulant embedding), which is what makes SKI fast on 1-D grids
//! (sound experiment) and inside Kronecker factors (precipitation, crime).

use std::f64::consts::PI;

/// Complex number (no external deps).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cpx {
    pub re: f64,
    pub im: f64,
}

impl Cpx {
    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        Cpx { re, im }
    }

    #[inline]
    pub fn mul(self, o: Cpx) -> Cpx {
        Cpx::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    #[inline]
    pub fn add(self, o: Cpx) -> Cpx {
        Cpx::new(self.re + o.re, self.im + o.im)
    }

    #[inline]
    pub fn sub(self, o: Cpx) -> Cpx {
        Cpx::new(self.re - o.re, self.im - o.im)
    }
}

/// Next power of two >= n.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// In-place iterative Cooley–Tukey FFT. `data.len()` must be a power of two.
/// `inverse` applies the conjugate transform *without* the 1/n scale.
pub fn fft_in_place(data: &mut [Cpx], inverse: bool) {
    let n = data.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    if n <= 1 {
        return;
    }
    // Bit reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            data.swap(i, j);
        }
    }
    // Butterflies.
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = Cpx::new(ang.cos(), ang.sin());
        let half = len / 2;
        for start in (0..n).step_by(len) {
            let mut w = Cpx::new(1.0, 0.0);
            for k in 0..half {
                let u = data[start + k];
                let v = data[start + k + half].mul(w);
                data[start + k] = u.add(v);
                data[start + k + half] = u.sub(v);
                w = w.mul(wlen);
            }
        }
        len <<= 1;
    }
}

/// Forward FFT of a real sequence zero-padded to a power of two length `n`.
pub fn rfft(x: &[f64], n: usize) -> Vec<Cpx> {
    let mut buf = vec![Cpx::default(); n];
    for (i, &v) in x.iter().enumerate() {
        buf[i].re = v;
    }
    fft_in_place(&mut buf, false);
    buf
}

/// Elementwise product then inverse FFT, returning the real parts scaled by
/// 1/n — the core of circulant multiplication.
pub fn mul_ifft_real(a: &[Cpx], b: &[Cpx]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut buf: Vec<Cpx> = a.iter().zip(b).map(|(x, y)| x.mul(*y)).collect();
    fft_in_place(&mut buf, true);
    let scale = 1.0 / n as f64;
    buf.iter().map(|c| c.re * scale).collect()
}

/// Circular convolution of two real sequences of length n (padded pow2).
pub fn circular_convolve(x: &[f64], h: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), h.len());
    let n = x.len();
    assert!(n.is_power_of_two());
    let fx = rfft(x, n);
    let fh = rfft(h, n);
    mul_ifft_real(&fx, &fh)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dft(x: &[Cpx]) -> Vec<Cpx> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut s = Cpx::default();
                for (j, v) in x.iter().enumerate() {
                    let ang = -2.0 * PI * (k * j) as f64 / n as f64;
                    s = s.add(v.mul(Cpx::new(ang.cos(), ang.sin())));
                }
                s
            })
            .collect()
    }

    #[test]
    fn fft_matches_naive_dft() {
        let n = 16;
        let x: Vec<Cpx> = (0..n)
            .map(|i| Cpx::new((i as f64 * 0.7).sin(), (i as f64 * 0.3).cos()))
            .collect();
        let mut got = x.clone();
        fft_in_place(&mut got, false);
        let want = naive_dft(&x);
        for i in 0..n {
            assert!((got[i].re - want[i].re).abs() < 1e-9);
            assert!((got[i].im - want[i].im).abs() < 1e-9);
        }
    }

    #[test]
    fn roundtrip() {
        let n = 64;
        let x: Vec<Cpx> = (0..n).map(|i| Cpx::new(i as f64, -(i as f64) * 0.5)).collect();
        let mut buf = x.clone();
        fft_in_place(&mut buf, false);
        fft_in_place(&mut buf, true);
        for i in 0..n {
            assert!((buf[i].re / n as f64 - x[i].re).abs() < 1e-9);
            assert!((buf[i].im / n as f64 - x[i].im).abs() < 1e-9);
        }
    }

    #[test]
    fn circular_convolution_matches_naive() {
        let n = 8;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 1.1).sin()).collect();
        let h: Vec<f64> = (0..n).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let got = circular_convolve(&x, &h);
        for k in 0..n {
            let mut want = 0.0;
            for j in 0..n {
                want += x[j] * h[(k + n - j) % n];
            }
            assert!((got[k] - want).abs() < 1e-9, "k={k}");
        }
    }
}
