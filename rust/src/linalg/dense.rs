//! Row-major dense matrix with the handful of BLAS-3 style operations the
//! estimators and baselines need. Deliberately simple; the hot paths of the
//! paper's method are MVMs against *structured* operators, not dense algebra.

use std::fmt;

/// Row-major dense matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column copy (rows are contiguous; columns are strided).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * x for a vector x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = self * x, no allocation.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            let mut s = 0.0;
            for j in 0..self.cols {
                s += row[j] * x[j];
            }
            y[i] = s;
        }
    }

    /// self^T * x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let xi = x[i];
            for j in 0..self.cols {
                y[j] += row[j] * xi;
            }
        }
        y
    }

    /// Blocked i-k-j matmul: cache-friendly without a BLAS dependency.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for i in 0..m {
                let arow = self.row(i);
                let orow_ptr = i * n;
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = other.row(kk);
                    let orow = &mut out.data[orow_ptr..orow_ptr + n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry difference to another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Symmetrize in place: A <- (A + A^T)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    pub fn diag(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    /// A += alpha * I
    pub fn add_diag(&mut self, alpha: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += alpha;
        }
    }

    pub fn scale(&mut self, alpha: f64) {
        for v in self.data.iter_mut() {
            *v *= alpha;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Trace of self * other (elementwise dot with other^T) — the exact
    /// baseline's tr(K^{-1} dK) building block.
    pub fn trace_product(&self, other: &Mat) -> f64 {
        assert_eq!(self.cols, other.rows);
        assert_eq!(self.rows, other.cols);
        let mut tr = 0.0;
        for i in 0..self.rows {
            for j in 0..self.cols {
                tr += self[(i, j)] * other[(j, i)];
            }
        }
        tr
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_and_transpose() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
    }

    #[test]
    fn matmul_matches_matvec() {
        let a = Mat::from_fn(7, 5, |i, j| (i * 5 + j) as f64 * 0.1);
        let b = Mat::from_fn(5, 1, |i, _| i as f64 - 2.0);
        let c = a.matmul(&b);
        let v = a.matvec(&b.col(0));
        for i in 0..7 {
            assert!((c[(i, 0)] - v[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn trace_product_matches_naive() {
        let a = Mat::from_fn(4, 4, |i, j| (i + 2 * j) as f64);
        let b = Mat::from_fn(4, 4, |i, j| (3 * i) as f64 - j as f64);
        let ab = a.matmul(&b);
        let tr: f64 = ab.diag().iter().sum();
        assert!((a.trace_product(&b) - tr).abs() < 1e-10);
    }

    #[test]
    fn symmetrize_and_diag() {
        let mut a = Mat::from_rows(&[vec![1.0, 2.0], vec![4.0, 5.0]]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], 3.0);
        assert_eq!(a[(1, 0)], 3.0);
        a.add_diag(1.0);
        assert_eq!(a.diag(), vec![2.0, 6.0]);
    }
}
